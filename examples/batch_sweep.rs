//! E3 + E7: batch-size sweep (analytical vs MEASURED from executed engine
//! steps) and the n_layers savings-cap sweep.
//!
//! The measured column runs real decode steps at each batch size on both
//! paths and reports the ratio of the traffic recorder's counters; it must
//! match the analytical model exactly (same formulas, but one side is
//! derived from actual executed steps — E3's point).
//!
//! ```bash
//! cargo run --release --example batch_sweep              # tiny-serial live
//! cargo run --release --example batch_sweep -- --layers-sweep
//! ```

use firstlayer::config::{zoo_get, ServingConfig};
use firstlayer::costmodel;
use firstlayer::coordinator::Coordinator;
use firstlayer::runtime::{CacheBatch, StepPath};
use firstlayer::util::fmt;

fn layers_sweep() {
    println!("== E7: one-layer savings cap vs model depth ==");
    println!("(paper: 4-layer models cap at 25%, 32-layer at ~3%)\n");
    println!(
        "{:>10} {:>16} {:>22}",
        "n_layers", "cap = 1/n", "realized FLOP frac"
    );
    for n in [2usize, 4, 8, 16, 32, 64] {
        // Scale a Mistral-like config to n layers for the realized fraction.
        let mut cfg = zoo_get("mistral-7b").unwrap();
        cfg.n_layers = n;
        println!(
            "{:>10} {:>15.1}% {:>21.2}%",
            n,
            100.0 * costmodel::max_savings_fraction(n),
            100.0 * costmodel::flops_saved_fraction(&cfg),
        );
    }
    // Whisper-tiny 4-layer example from the abstract (E8).
    let wt = zoo_get("whisper-tiny4").unwrap();
    println!(
        "\nwhisper-tiny4 (the paper's 4-layer example): cap {:.0}%, realized {:.1}% (serial: QKV only)",
        100.0 * costmodel::max_savings_fraction(wt.n_layers),
        100.0 * costmodel::flops_saved_fraction(&wt),
    );
}

fn live_sweep(model: &str) -> firstlayer::Result<()> {
    println!("== E3: first-layer reads per batch — analytical vs measured ==\n");
    println!("paper-scale models (analytical only):");
    for name in ["pythia-6.9b", "mistral-7b", "mixtral-8x7b-parallel"] {
        let cfg = zoo_get(name).unwrap();
        let factors: Vec<String> = costmodel::PAPER_BATCHES
            .iter()
            .map(|b| fmt::factor(costmodel::reduction_factor(&cfg, *b)))
            .collect();
        println!("  {name:<24} B=1/16/256/1024: {}", factors.join(" / "));
    }

    println!("\nlive model {model} (measured from executed PJRT decode steps):");
    let scfg = ServingConfig {
        model: model.to_string(),
        ..Default::default()
    };
    let c = Coordinator::from_config(&scfg)?;
    let engine = c.engine();
    let mc = engine.config().clone();
    println!(
        "{:>6} {:>16} {:>16} {:>12} {:>12}",
        "batch", "measured w/o", "measured with", "measured", "analytical"
    );
    for &b in &[1usize, 2, 4, 8] {
        if engine.decode_bucket(b, StepPath::Baseline).is_err() {
            continue;
        }
        engine.traffic.reset();
        let bucket = engine.decode_bucket(b, StepPath::Baseline)?;
        let caches = CacheBatch::zeros(
            mc.n_layers,
            bucket,
            mc.max_seq,
            mc.n_kv_heads,
            mc.head_dim(),
        );
        let tokens: Vec<u32> = (0..b as u32).collect();
        let pos = vec![0u32; b];
        let n_steps = 5;
        for _ in 0..n_steps {
            engine.decode(StepPath::Baseline, &tokens, &pos, &caches)?;
            engine.decode(StepPath::Precompute, &tokens, &pos, &caches)?;
        }
        let t = engine.traffic.snapshot();
        let measured = t.l1_reads_baseline as f64 / t.l1_reads_precomp as f64;
        let analytical = costmodel::reduction_factor(&mc, b as u64);
        assert!(
            (measured - analytical).abs() / analytical < 1e-9,
            "measured and analytical must agree exactly"
        );
        println!(
            "{:>6} {:>16} {:>16} {:>11.1}x {:>11.1}x",
            b,
            fmt::commas(t.l1_reads_baseline / n_steps),
            fmt::commas(t.l1_reads_precomp / n_steps),
            measured,
            analytical,
        );
    }
    println!("\nmeasured == analytical on every row (the recorder counts the paper's quantities on live steps).");
    Ok(())
}

fn main() -> firstlayer::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if args.iter().any(|a| a == "--layers-sweep") {
        layers_sweep();
        return Ok(());
    }
    let model = args.first().map(|s| s.as_str()).unwrap_or("tiny-serial");
    live_sweep(model)?;
    println!();
    layers_sweep();
    Ok(())
}
