//! Open-loop load generator: Poisson arrivals against the coordinator, the
//! workload shape a serving paper's latency-under-load evaluation uses.
//!
//! Simulated-time open loop: requests carry Poisson arrival timestamps; the
//! engine loop admits a request once its arrival time has passed (wall
//! clock), so queueing delay shows up in TTFT/e2e exactly as it would
//! against the TCP front.
//!
//! ```bash
//! cargo run --release --example loadgen -- [model] [rate_rps] [n_requests]
//! ```

use std::time::{Duration, Instant};

use firstlayer::config::ServingConfig;
use firstlayer::coordinator::{Coordinator, Request};
use firstlayer::runtime::StepPath;
use firstlayer::util::rng::Rng;

const PROMPTS: [&str; 6] = [
    "the quick brown fox",
    "attention is all you need",
    "memory bandwidth limits decoding",
    "a key value cache stores",
    "the scheduler admits requests",
    "experts route tokens",
];

fn run(model: &str, precompute: bool, rate: f64, n: usize) -> firstlayer::Result<()> {
    let cfg = ServingConfig {
        model: model.to_string(),
        use_precompute: precompute,
        ..Default::default()
    };
    let mut c = Coordinator::from_config(&cfg)?;
    c.engine().warmup(if precompute {
        StepPath::Precompute
    } else {
        StepPath::Baseline
    })?;

    // Pre-draw the arrival schedule.
    let mut rng = Rng::new(42);
    let mut t = 0.0;
    let mut schedule: Vec<(f64, &str, usize)> = Vec::with_capacity(n);
    for _ in 0..n {
        t += rng.exp(rate);
        let p = PROMPTS[rng.range(0, PROMPTS.len())];
        let gen = rng.range(8, 24);
        schedule.push((t, p, gen));
    }

    let t0 = Instant::now();
    let mut next = 0usize;
    while next < schedule.len() || c.busy() {
        let now = t0.elapsed().as_secs_f64();
        while next < schedule.len() && schedule[next].0 <= now {
            let (_, p, gen) = schedule[next];
            c.submit(Request::from_text(p, gen))?;
            next += 1;
        }
        if c.busy() {
            c.step()?;
        } else if next < schedule.len() {
            let wait = schedule[next].0 - t0.elapsed().as_secs_f64();
            if wait > 0.0 {
                std::thread::sleep(Duration::from_secs_f64(wait.min(0.05)));
            }
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    let m = &c.metrics;
    let done = m.requests_done.load(std::sync::atomic::Ordering::Relaxed);
    let toks = m.tokens_out.load(std::sync::atomic::Ordering::Relaxed);
    println!(
        "{:<11} rate={rate:>5.1}/s  done={done:>4}  tok/s={:>7.1}  \
         ttft p50={:>6.1?} p95={:>8.1?}  e2e p50={:>6.1?} p95={:>8.1?}  preempt={}",
        if precompute { "precompute" } else { "baseline" },
        toks as f64 / wall,
        m.ttft.quantile(0.5),
        m.ttft.quantile(0.95),
        m.e2e.quantile(0.5),
        m.e2e.quantile(0.95),
        m.preemptions.load(std::sync::atomic::Ordering::Relaxed),
    );
    Ok(())
}

fn main() -> firstlayer::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let model = args.first().map(|s| s.as_str()).unwrap_or("tiny-serial");
    let rate: f64 = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(0.0);
    let n: usize = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(40);

    println!("== load test: {model}, {n} requests, Poisson arrivals ==\n");
    let rates = if rate > 0.0 {
        vec![rate]
    } else {
        vec![20.0, 60.0, 120.0]
    };
    for r in rates {
        for pre in [false, true] {
            run(model, pre, r, n)?;
        }
        println!();
    }
    Ok(())
}
