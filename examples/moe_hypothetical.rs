//! E2 (third column): the paper's hypothetical parallel-attention Mixtral.
//!
//! The paper's most striking number: making Mixtral-8x7B's blocks parallel
//! lets the first layer's 1.4B MoE FFN weights be precomputed away — a
//! 140,084x read reduction at batch 1 and a NET MEMORY SHRINK of 3%.
//!
//! This example (a) reproduces that analytical column, (b) runs the
//! runnable analogue (tiny-moe vs tiny-moe-parallel) live and shows the
//! same qualitative flip: the parallel variant eliminates the expert
//! weights from the first layer and its table pays for itself.
//!
//! ```bash
//! cargo run --release --example moe_hypothetical
//! ```

use firstlayer::config::{zoo_get, ServingConfig};
use firstlayer::coordinator::{Coordinator, Request};
use firstlayer::costmodel;
use firstlayer::util::fmt;

fn analytical() {
    println!("== paper-scale: serial Mixtral vs hypothetical parallel Mixtral ==\n");
    let serial = zoo_get("mixtral-8x7b").unwrap();
    let parallel = zoo_get("mixtral-8x7b-parallel").unwrap();
    println!(
        "{:<34} {:>18} {:>18}",
        "", "mixtral (serial)", "mixtral (parallel)"
    );
    let row = |k: &str, a: String, b: String| println!("{k:<34} {a:>18} {b:>18}");
    row(
        "weights eliminated",
        fmt::commas(costmodel::eliminated_weights(&serial)),
        fmt::commas(costmodel::eliminated_weights(&parallel)),
    );
    for b in costmodel::PAPER_BATCHES {
        row(
            &format!("read reduction @ B={b}"),
            fmt::factor(costmodel::reduction_factor(&serial, b)),
            fmt::factor(costmodel::reduction_factor(&parallel, b)),
        );
    }
    let ms = costmodel::memory_delta(&serial);
    let mp = costmodel::memory_delta(&parallel);
    row(
        "net memory delta (values)",
        fmt::commas_i(ms.net),
        fmt::commas_i(mp.net),
    );
    row(
        "relative memory delta",
        format!("{:+}%", ms.relative_pct),
        format!("{:+}%", mp.relative_pct),
    );
    println!(
        "\nparallelizing the blocks turns the trick's memory cost into a 3% memory WIN,\n\
         because the 8-expert FFN of layer 1 ({} weights) disappears from serving memory.",
        fmt::commas(costmodel::weight_counts(&parallel).ffn_per_layer)
    );
}

fn live() -> firstlayer::Result<()> {
    println!("\n== runnable analogue: tiny-moe (serial) vs tiny-moe-parallel ==\n");
    for model in ["tiny-moe", "tiny-moe-parallel"] {
        let cfg = ServingConfig {
            model: model.to_string(),
            use_precompute: true,
            max_batch: 4,
            ..Default::default()
        };
        let mut c = Coordinator::from_config(&cfg)?;
        let ids: Vec<u64> = (0..4)
            .map(|i| {
                c.submit(Request::from_text(
                    ["the fox", "a cache", "experts route", "blocks allocate"][i],
                    8,
                ))
            })
            .collect::<firstlayer::Result<_>>()?;
        c.run_to_completion(10_000)?;
        let mc = c.engine().config();
        let t = c.engine().traffic.snapshot();
        println!(
            "{model}: arch={:?}, eliminated={} weights, live first-layer reads={} values, \
             all {} requests ok",
            mc.arch,
            fmt::commas(costmodel::eliminated_weights(mc)),
            fmt::commas(t.l1_reads_precomp),
            ids.len(),
        );
        let md = costmodel::memory_delta(mc);
        println!(
            "         memory: table {:+} values vs weights -{} => net {} ({:+}%)",
            md.embedding_increase,
            fmt::commas(md.weights_decrease),
            fmt::commas_i(md.net),
            md.relative_pct,
        );
    }
    Ok(())
}

fn main() -> firstlayer::Result<()> {
    analytical();
    live()
}
