//! E1/E2: regenerate the paper's §3 tables from the analytical cost model
//! and verify every printed number against the values in the paper.
//!
//! ```bash
//! cargo run --release --example paper_tables
//! ```

use firstlayer::config::{zoo_get, ModelConfig};
use firstlayer::costmodel::{
    eliminated_weights, memory_delta, reads_with, reads_without, reduction_factor,
    weight_counts, PAPER_BATCHES,
};

fn check(label: &str, got: u64, want: u64) {
    let mark = if got == want { "ok" } else { "MISMATCH" };
    println!("  [{mark}] {label}: got {got}, paper {want}");
    assert_eq!(got, want, "{label}");
}

fn check_i(label: &str, got: i64, want: i64) {
    let mark = if got == want { "ok" } else { "MISMATCH" };
    println!("  [{mark}] {label}: got {got}, paper {want}");
    assert_eq!(got, want, "{label}");
}

fn main() {
    // The paper's tables, verbatim.
    firstlayer::costmodel::print_paper_tables();

    println!("\n== Verification against the paper's printed values ==");
    let pythia = zoo_get("pythia-6.9b").unwrap();
    let mistral = zoo_get("mistral-7b").unwrap();
    let mixtral = zoo_get("mixtral-8x7b").unwrap();
    let mixtral_par = zoo_get("mixtral-8x7b-parallel").unwrap();

    println!("Table 1 (weights):");
    check("Pythia Q+P/layer", weight_counts(&pythia).qp_per_layer, 33_554_432);
    check("Pythia K+V/layer", weight_counts(&pythia).kv_per_layer, 33_554_432);
    check("Pythia FFN/layer", weight_counts(&pythia).ffn_per_layer, 134_217_728);
    check("Pythia embeddings", weight_counts(&pythia).embeddings, 412_876_800);
    check("Mistral K+V/layer", weight_counts(&mistral).kv_per_layer, 8_388_608);
    check("Mistral FFN/layer", weight_counts(&mistral).ffn_per_layer, 176_160_768);
    check("Mixtral FFN/layer", weight_counts(&mixtral).ffn_per_layer, 1_409_286_144);

    println!("Table 2 (reads + memory):");
    let cases: [(&str, &ModelConfig, u64, u64, u64, [u64; 4], i64, i64); 3] = [
        (
            "Pythia-6.9B",
            &pythia,
            184_549_376,
            184_553_472,
            16_384,
            [11_264, 704, 44, 11],
            434_765_824,
            6,
        ),
        (
            "Mistral-7B",
            &mistral,
            25_165_824,
            25_169_920,
            10_240,
            [2_458, 154, 10, 3],
            171_442_176,
            2,
        ),
        (
            "Mixtral-8x7B (parallel)",
            &mixtral_par,
            1_434_451_968,
            1_434_456_064,
            10_240,
            [140_084, 8_756, 548, 137],
            -1_237_843_968,
            -3,
        ),
    ];
    for (name, cfg, elim, r_wo, r_w, factors, net, pct) in cases {
        println!(" {name}:");
        check("eliminated weights", eliminated_weights(cfg), elim);
        check("reads w/o precompute B=1", reads_without(cfg, 1), r_wo);
        check("reads with precompute B=1", reads_with(cfg, 1), r_w);
        for (b, want) in PAPER_BATCHES.iter().zip(factors) {
            check(
                &format!("reduction factor B={b}"),
                reduction_factor(cfg, *b).round() as u64,
                want,
            );
        }
        let md = memory_delta(cfg);
        check_i("net memory delta", md.net, net);
        check_i("relative memory delta %", md.relative_pct, pct);
    }
    println!("\nAll paper numbers reproduced exactly.");
}
