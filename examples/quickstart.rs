//! Quickstart: load a tiny model, serve a few prompts on the precompute
//! path, print outputs + the paper's first-layer read accounting.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use firstlayer::config::ServingConfig;
use firstlayer::coordinator::{Coordinator, Request};
use firstlayer::costmodel;
use firstlayer::util::fmt;

fn main() -> firstlayer::Result<()> {
    let cfg = ServingConfig {
        model: "tiny-serial".to_string(),
        use_precompute: true,
        ..Default::default()
    };
    let mut c = Coordinator::from_config(&cfg)?;
    println!(
        "model={} path={} (first layer served from the {}-row precompute table)",
        cfg.model,
        c.path().label(),
        c.engine().table().vocab()
    );

    let prompts = [
        "the quick brown fox",
        "attention is all",
        "memory bandwidth limits autoregressive decoding",
    ];
    let ids: Vec<u64> = prompts
        .iter()
        .map(|p| c.submit(Request::from_text(*p, 16)))
        .collect::<firstlayer::Result<_>>()?;

    c.run_to_completion(10_000)?;

    for (p, id) in prompts.iter().zip(&ids) {
        let toks = c.generated(*id).unwrap();
        println!("\nprompt : {p}");
        println!("output : {:?}", c.tokenizer.decode(toks));
        println!(
            "tokens : {} generated, finish={:?}",
            toks.len(),
            c.finished(*id)
        );
    }

    println!("\n--- serving metrics ---\n{}", c.metrics.report());
    let t = c.engine().traffic.snapshot();
    println!(
        "first-layer reads (measured): {} values ({}) gathered from the table",
        fmt::commas(t.l1_reads_precomp),
        fmt::bytes(t.table_bytes_read),
    );
    // Baseline comparison for the same executed step mix:
    // each decode step streams W weight values + d per token.
    let mc = c.engine().config();
    let w = costmodel::eliminated_weights(mc);
    let baseline_equiv = t.decode_tokens * mc.d as u64
        + (t.decode_steps_precomp + t.decode_steps_baseline) * w;
    println!(
        "the baseline path would have read ~{} values for the same steps \
         ({}x more)",
        fmt::commas(baseline_equiv),
        fmt::commas(baseline_equiv / t.l1_reads_precomp.max(1)),
    );
    Ok(())
}
