//! E6 — the end-to-end validation driver: load a small real model, serve a
//! batched request workload through the full stack (tokenizer → scheduler →
//! paged KV → PJRT engine), on BOTH serving paths, and report
//! latency/throughput plus the paper's read accounting.
//!
//! This is the run recorded in EXPERIMENTS.md §E6.
//!
//! ```bash
//! make artifacts && cargo run --release --example serve_e2e [-- tiny-serial 24 16]
//! ```
//! args: [model] [n_requests] [max_new_tokens]

use std::time::Instant;

use firstlayer::config::ServingConfig;
use firstlayer::coordinator::{Coordinator, Request};
use firstlayer::costmodel;
use firstlayer::runtime::StepPath;
use firstlayer::util::fmt;
use firstlayer::util::rng::Rng;

const PROMPTS: [&str; 8] = [
    "the quick brown fox jumps",
    "attention is all you need",
    "memory bandwidth limits autoregressive decoding",
    "the first layer of a transformer",
    "a key value cache stores past",
    "batching amortizes weight reads",
    "the scheduler admits requests",
    "rotary position embeddings rotate",
];

struct RunResult {
    wall_s: f64,
    tokens: u64,
    p50_decode_us: u128,
    p95_decode_us: u128,
    ttft_p50_ms: u128,
    l1_reads: u64,
    outputs: Vec<Vec<u32>>,
}

fn run(model: &str, precompute: bool, n_req: usize, max_new: usize) -> firstlayer::Result<RunResult> {
    let cfg = ServingConfig {
        model: model.to_string(),
        use_precompute: precompute,
        ..Default::default()
    };
    let mut c = Coordinator::from_config(&cfg)?;
    // Warm up (compile) outside the timed region, as a server would.
    c.engine().warmup(if precompute {
        StepPath::Precompute
    } else {
        StepPath::Baseline
    })?;
    c.engine().traffic.reset();

    let mut rng = Rng::new(7);
    let t0 = Instant::now();
    let ids: Vec<u64> = (0..n_req)
        .map(|_| {
            let p = PROMPTS[rng.range(0, PROMPTS.len())];
            c.submit(Request::from_text(p, max_new))
        })
        .collect::<firstlayer::Result<_>>()?;
    c.run_to_completion(100_000)?;
    let wall_s = t0.elapsed().as_secs_f64();

    let t = c.engine().traffic.snapshot();
    Ok(RunResult {
        wall_s,
        tokens: c
            .metrics
            .tokens_out
            .load(std::sync::atomic::Ordering::Relaxed),
        p50_decode_us: c.metrics.decode_step.quantile(0.5).as_micros(),
        p95_decode_us: c.metrics.decode_step.quantile(0.95).as_micros(),
        ttft_p50_ms: c.metrics.ttft.quantile(0.5).as_millis(),
        l1_reads: if precompute {
            t.l1_reads_precomp
        } else {
            t.l1_reads_baseline
        },
        outputs: ids
            .iter()
            .map(|id| c.generated(*id).unwrap().to_vec())
            .collect(),
    })
}

fn main() -> firstlayer::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let model = args.first().map(|s| s.as_str()).unwrap_or("tiny-serial");
    let n_req: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(24);
    let max_new: usize = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(16);

    println!("== E6: end-to-end serving, {model}, {n_req} requests x {max_new} new tokens ==\n");

    let base = run(model, false, n_req, max_new)?;
    let pre = run(model, true, n_req, max_new)?;

    assert_eq!(
        base.outputs, pre.outputs,
        "greedy outputs must be identical across paths (the paper's equivalence)"
    );
    println!("outputs: IDENTICAL across both paths ({} requests, greedy) — Figure 1/2 equivalence holds live\n", n_req);

    println!(
        "{:<26} {:>14} {:>14}",
        "metric", "baseline", "precompute"
    );
    let row = |k: &str, a: String, b: String| println!("{k:<26} {a:>14} {b:>14}");
    row(
        "wall time (s)",
        format!("{:.2}", base.wall_s),
        format!("{:.2}", pre.wall_s),
    );
    row(
        "throughput (tok/s)",
        format!("{:.1}", base.tokens as f64 / base.wall_s),
        format!("{:.1}", pre.tokens as f64 / pre.wall_s),
    );
    row(
        "decode p50 (us)",
        base.p50_decode_us.to_string(),
        pre.p50_decode_us.to_string(),
    );
    row(
        "decode p95 (us)",
        base.p95_decode_us.to_string(),
        pre.p95_decode_us.to_string(),
    );
    row(
        "ttft p50 (ms)",
        base.ttft_p50_ms.to_string(),
        pre.ttft_p50_ms.to_string(),
    );
    row(
        "first-layer reads",
        fmt::commas(base.l1_reads),
        fmt::commas(pre.l1_reads),
    );
    let measured = base.l1_reads as f64 / pre.l1_reads as f64;
    println!(
        "\nmeasured first-layer read reduction: {:.1}x",
        measured
    );

    // Cross-check the measured ratio against the analytical model for the
    // same step mix (it is exact by construction — the point of E3).
    let cfg = firstlayer::config::zoo_get(model).unwrap();
    println!(
        "analytical reduction at B=1:  {:.1}x   at B=8: {:.1}x",
        costmodel::reduction_factor(&cfg, 1),
        costmodel::reduction_factor(&cfg, 8),
    );
    println!(
        "\n(the tiny model has {} layers, so the paper's whole-model savings cap is {:.0}%)",
        cfg.n_layers,
        100.0 * costmodel::max_savings_fraction(cfg.n_layers)
    );
    Ok(())
}
