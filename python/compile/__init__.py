"""Build-time compile path: L1 Pallas kernels + L2 JAX model + AOT emitter.

Never imported at serving time — the rust binary only consumes the
artifacts this package writes (HLO text, weights, precompute tables,
manifest).
"""
