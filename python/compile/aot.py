"""AOT artifact emitter: jax → StableHLO → HLO *text* → ``artifacts/``.

Run once at build time (``make artifacts``); the rust serving binary is
self-contained afterwards.  HLO text (NOT ``HloModuleProto.serialize``) is
the interchange format: jax ≥ 0.5 emits protos with 64-bit instruction ids
that xla_extension 0.5.1 rejects; the text parser reassigns ids.

Per runnable model this writes:
  weights_<m>.fw             deterministic weights (params.py)
  table_<m>.fpt              precomputed first-layer table (precompute.py)
  <m>/decode_baseline_b{B}.hlo.txt      full first layer
  <m>/decode_precomp_b{B}.hlo.txt       paper's trick (rows from rust gather)
  <m>/decode_precomp_gather_b{B}.hlo.txt  ablation: in-graph Pallas gather
  <m>/prefill_baseline_b{B}t{T}.hlo.txt
  <m>/prefill_precomp_b{B}t{T}.hlo.txt
  <m>/span_baseline_t{T}.hlo.txt        batched span: T tokens, one execution
  <m>/span_precomp_t{T}.hlo.txt         (rows for the whole span from rust)
  <m>/span_baseline_b{B}_t{T}.hlo.txt   multi-sequence span: B lanes × T tokens
  <m>/span_precomp_b{B}_t{T}.hlo.txt    (per-lane starts + valid lengths)
  <m>/precompute_build.hlo.txt          lets rust (re)build the table itself
  manifest.json              everything the rust side needs to load them
"""

from __future__ import annotations

import argparse
import dataclasses
import functools
import json
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import configs, model, params, precompute
from .configs import ModelConfig

DECODE_BATCHES = {
    "tiny-serial": [1, 2, 4, 8],
    "tiny-parallel": [1, 2, 4, 8],
    "tiny-moe": [1, 4],
    "tiny-moe-parallel": [1, 4],
}
PREFILL_BUCKETS = {
    "tiny-serial": [(1, 32), (4, 32)],
    "tiny-parallel": [(1, 32), (4, 32)],
    "tiny-moe": [(1, 32)],
    "tiny-moe-parallel": [(1, 32)],
}
# Batched span artifact buckets (tokens per execution, B = 1): a span of
# S tokens tiles into ceil(S/T) executions instead of S single-token
# decode dispatches.  Ragged tails pad to the smallest fitting bucket.
SPAN_BUCKETS = {
    "tiny-serial": [8, 32],
    "tiny-parallel": [8, 32],
    "tiny-moe": [8, 16],
    "tiny-moe-parallel": [8, 16],
}
# Multi-sequence span buckets (lanes × tokens per execution): one device
# execution advances up to B independent sequences through up to T tokens
# each, with per-lane start positions and valid lengths (Prepacking-style
# ragged batching).  Unoccupied lanes are inert; a group of N < B
# same-bucket continuations pads lanes, not executions.
SPAN_BATCHES = {
    "tiny-serial": [(4, 8), (4, 32)],
    "tiny-parallel": [(4, 8), (4, 32)],
    "tiny-moe": [(2, 8), (2, 16)],
    "tiny-moe-parallel": [(2, 8), (2, 16)],
}
GATHER_ABLATION_BATCH = 4
BUILD_CHUNK = 256  # vocab rows per precompute_build invocation


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype="f32"):
    return jax.ShapeDtypeStruct(
        tuple(shape), {"f32": jnp.float32, "i32": jnp.int32}[dtype]
    )


def _io(name, shape, dtype="f32"):
    return {"name": name, "shape": list(shape), "dtype": dtype}


class Emitter:
    def __init__(self, cfg: ModelConfig, out_dir: str):
        self.cfg = cfg
        self.out = out_dir
        self.w = params.init_weights(cfg)
        self.artifacts = []
        os.makedirs(os.path.join(out_dir, cfg.name), exist_ok=True)

    def wspecs(self, order):
        return [_spec(params.tensor_shape(self.cfg, n)) for n in order]

    def emit(self, name, kind, fn, inputs, outputs, weight_params, extra=None):
        """Lower fn(data..., *weights) and record the artifact."""
        rel = f"{self.cfg.name}/{name}.hlo.txt"
        path = os.path.join(self.out, rel)
        in_specs = [_spec(i["shape"], i["dtype"]) for i in inputs]
        w_specs = self.wspecs([p for p in weight_params if not p.startswith("@")])
        if "@table" in weight_params:
            w_specs.insert(
                weight_params.index("@table"),
                _spec((self.cfg.vocab_size, self.cfg.precomp_row_width)),
            )
        lowered = jax.jit(fn).lower(*in_specs, *w_specs)
        text = to_hlo_text(lowered)
        with open(path, "w") as f:
            f.write(text)
        art = {
            "name": name,
            "kind": kind,
            "file": rel,
            "inputs": inputs,
            "outputs": outputs,
            "weight_params": weight_params,
        }
        art.update(extra or {})
        self.artifacts.append(art)
        print(f"  {rel}  ({len(text) / 1e6:.2f} MB)", flush=True)

    # -- artifact families ---------------------------------------------------

    def decode(self, B: int, path: str):
        cfg = self.cfg
        L, S = cfg.n_layers, cfg.max_seq
        KH, hd = cfg.n_kv_heads, cfg.head_dim
        cache = [L, B, S, KH, hd]
        outputs = [
            _io("logits", [B, cfg.vocab_size]),
            _io("kcaches", cache),
            _io("vcaches", cache),
        ]
        common = dict(extra={"batch": B, "max_seq": S})
        if path == "baseline":
            order = model.weight_order_baseline(cfg)

            def fn(tokens, pos, kc, vc, *ws):
                w = dict(zip(order, ws))
                return model.decode_baseline(cfg, w, tokens, pos, kc, vc)

            self.emit(
                f"decode_baseline_b{B}", "decode", fn,
                [_io("tokens", [B], "i32"), _io("pos", [B], "i32"),
                 _io("kcaches", cache), _io("vcaches", cache)],
                outputs, order, **common,
            )
        elif path == "precomp":
            order = model.weight_order_precomp(cfg)
            W = cfg.precomp_row_width

            def fn(rows, pos, kc, vc, *ws):
                w = dict(zip(order, ws))
                return model.decode_precomp(cfg, w, rows, pos, kc, vc)

            self.emit(
                f"decode_precomp_b{B}", "decode", fn,
                [_io("rows", [B, W]), _io("pos", [B], "i32"),
                 _io("kcaches", cache), _io("vcaches", cache)],
                outputs, order, **common,
            )
        else:  # precomp_gather ablation: table is a resident device buffer
            order = ["@table"] + model.weight_order_precomp(cfg)

            def fn(tokens, pos, kc, vc, table, *ws):
                w = dict(zip(order[1:], ws))
                return model.decode_precomp_gather(cfg, w, table, tokens, pos, kc, vc)

            self.emit(
                f"decode_precomp_gather_b{B}", "decode", fn,
                [_io("tokens", [B], "i32"), _io("pos", [B], "i32"),
                 _io("kcaches", cache), _io("vcaches", cache)],
                outputs, order, **common,
            )

    def prefill(self, B: int, T: int, path: str):
        cfg = self.cfg
        L, S = cfg.n_layers, cfg.max_seq
        KH, hd = cfg.n_kv_heads, cfg.head_dim
        cache = [L, B, S, KH, hd]
        outputs = [
            _io("logits", [B, cfg.vocab_size]),
            _io("kcaches", cache),
            _io("vcaches", cache),
        ]
        extra = {"batch": B, "prompt_len": T, "max_seq": S}
        if path == "baseline":
            order = model.weight_order_baseline(cfg)

            def fn(tokens, lens, *ws):
                w = dict(zip(order, ws))
                return model.prefill(cfg, w, tokens, lens, max_seq=S)

            self.emit(
                f"prefill_baseline_b{B}t{T}", "prefill", fn,
                [_io("tokens", [B, T], "i32"), _io("lens", [B], "i32")],
                outputs, order, extra=extra,
            )
        else:
            order = model.weight_order_precomp(cfg)
            W = cfg.precomp_row_width

            def fn(rows, lens, *ws):
                w = dict(zip(order, ws))
                return model.prefill(cfg, w, jnp.zeros((B, T), jnp.int32),
                                     lens, rows=rows, max_seq=S)

            self.emit(
                f"prefill_precomp_b{B}t{T}", "prefill", fn,
                [_io("rows", [B, T, W]), _io("lens", [B], "i32")],
                outputs, order, extra=extra,
            )

    def span(self, T: int, path: str):
        """Batched span artifact: T tokens of ONE sequence against the
        existing KV history in a single execution (`span_*_t{T}`).

        Outputs are [logits, kcaches, vcaches, new_k, new_v]: the caches
        chain through a DeviceCacheSession like decode steps; the fresh
        rows make the per-execution readback logits + rows only (no
        full-pair sync at span end).
        """
        cfg = self.cfg
        L, S = cfg.n_layers, cfg.max_seq
        KH, hd = cfg.n_kv_heads, cfg.head_dim
        cache = [L, 1, S, KH, hd]
        outputs = [
            _io("logits", [T, cfg.vocab_size]),
            _io("kcaches", cache),
            _io("vcaches", cache),
            _io("new_k", [T, L, KH, hd]),
            _io("new_v", [T, L, KH, hd]),
        ]
        extra = {"batch": 1, "span_tokens": T, "max_seq": S}
        if path == "baseline":
            order = model.weight_order_baseline(cfg)

            def fn(tokens, start, kc, vc, *ws):
                w = dict(zip(order, ws))
                return model.decode_span_baseline(cfg, w, tokens, start, kc, vc)

            self.emit(
                f"span_baseline_t{T}", "span", fn,
                [_io("tokens", [T], "i32"), _io("start", [1], "i32"),
                 _io("kcaches", cache), _io("vcaches", cache)],
                outputs, order, extra=extra,
            )
        else:
            order = model.weight_order_precomp(cfg)
            W = cfg.precomp_row_width

            def fn(rows, start, kc, vc, *ws):
                w = dict(zip(order, ws))
                return model.decode_span_precomp(cfg, w, rows, start, kc, vc)

            self.emit(
                f"span_precomp_t{T}", "span", fn,
                [_io("rows", [T, W]), _io("start", [1], "i32"),
                 _io("kcaches", cache), _io("vcaches", cache)],
                outputs, order, extra=extra,
            )

    def span_batched(self, B: int, T: int, path: str):
        """Multi-sequence span artifact: up to B sequences × T tokens per
        execution (`span_*_b{B}_t{T}`), each lane with its own cache row,
        start position and valid length.  Same five outputs as the B=1
        span family, batch-extended: the cache pair chains through one
        B-lane DeviceCacheSession, and `new_k`/`new_v` come back
        `[B, T, L, KH, hd]` so the selective readback slices per lane.
        """
        cfg = self.cfg
        L, S = cfg.n_layers, cfg.max_seq
        KH, hd = cfg.n_kv_heads, cfg.head_dim
        cache = [L, B, S, KH, hd]
        outputs = [
            _io("logits", [B, T, cfg.vocab_size]),
            _io("kcaches", cache),
            _io("vcaches", cache),
            _io("new_k", [B, T, L, KH, hd]),
            _io("new_v", [B, T, L, KH, hd]),
        ]
        extra = {"batch": B, "span_tokens": T, "max_seq": S}
        if path == "baseline":
            order = model.weight_order_baseline(cfg)

            def fn(tokens, starts, lens, kc, vc, *ws):
                w = dict(zip(order, ws))
                return model.decode_span_batched_baseline(
                    cfg, w, tokens, starts, lens, kc, vc
                )

            self.emit(
                f"span_baseline_b{B}_t{T}", "span", fn,
                [_io("tokens", [B, T], "i32"), _io("starts", [B], "i32"),
                 _io("lens", [B], "i32"),
                 _io("kcaches", cache), _io("vcaches", cache)],
                outputs, order, extra=extra,
            )
        else:
            order = model.weight_order_precomp(cfg)
            W = cfg.precomp_row_width

            def fn(rows, starts, lens, kc, vc, *ws):
                w = dict(zip(order, ws))
                return model.decode_span_batched_precomp(
                    cfg, w, rows, starts, lens, kc, vc
                )

            self.emit(
                f"span_precomp_b{B}_t{T}", "span", fn,
                [_io("rows", [B, T, W]), _io("starts", [B], "i32"),
                 _io("lens", [B], "i32"),
                 _io("kcaches", cache), _io("vcaches", cache)],
                outputs, order, extra=extra,
            )

    def precompute_build(self):
        """Vocab-chunk table builder, runnable from rust (`firstlayer precompute`)."""
        cfg = self.cfg
        order = precompute.source_tensor_names(cfg)
        n = min(BUILD_CHUNK, cfg.vocab_size)

        def fn(tokens, *ws):
            w = dict(zip(order, ws))
            return (precompute.build_rows(cfg, w, tokens),)

        self.emit(
            "precompute_build", "precompute_build", fn,
            [_io("tokens", [n], "i32")],
            [_io("rows", [n, cfg.precomp_row_width])],
            order, extra={"chunk": n},
        )


def emit_model(cfg: ModelConfig, out_dir: str) -> dict:
    print(f"[aot] {cfg.name}", flush=True)
    em = Emitter(cfg, out_dir)

    # Weights + table first (the table CRC goes into the manifest).
    worder = params.tensor_names(cfg)
    wfile = f"weights_{cfg.name}.fw"
    params.save_fw(os.path.join(out_dir, wfile), em.w, worder)
    tfile = f"table_{cfg.name}.fpt"
    crc = precompute.build_table(cfg, em.w, os.path.join(out_dir, tfile))
    print(f"  {wfile}, {tfile} (crc {crc:#010x})", flush=True)

    for B in DECODE_BATCHES[cfg.name]:
        em.decode(B, "baseline")
        em.decode(B, "precomp")
    em.decode(GATHER_ABLATION_BATCH, "precomp_gather")
    for B, T in PREFILL_BUCKETS[cfg.name]:
        em.prefill(B, T, "baseline")
        em.prefill(B, T, "precomp")
    for T in SPAN_BUCKETS[cfg.name]:
        em.span(T, "baseline")
        em.span(T, "precomp")
    for B, T in SPAN_BATCHES[cfg.name]:
        em.span_batched(B, T, "baseline")
        em.span_batched(B, T, "precomp")
    em.precompute_build()

    cfg_d = dataclasses.asdict(cfg)
    cfg_d.update(
        e=cfg.e, head_dim=cfg.head_dim, precomp_row_width=cfg.precomp_row_width
    )
    return {
        "config": cfg_d,
        "weights_file": wfile,
        "weights_order": worder,
        "table_file": tfile,
        "weights_crc": crc,
        "artifacts": em.artifacts,
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument(
        "--models",
        default="tiny-serial,tiny-parallel,tiny-moe,tiny-moe-parallel",
        help="comma-separated runnable model names",
    )
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    # Merge into an existing manifest so partial --models runs do not drop
    # previously emitted models.
    mpath = os.path.join(args.out, "manifest.json")
    manifest = {"version": 1, "models": {}}
    if os.path.exists(mpath):
        with open(mpath) as f:
            old = json.load(f)
        if old.get("version") == 1:
            manifest["models"].update(old.get("models", {}))
    for name in args.models.split(","):
        cfg = configs.get(name.strip())
        manifest["models"][cfg.name] = emit_model(cfg, args.out)
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"[aot] wrote {mpath}", flush=True)


if __name__ == "__main__":
    main()
