"""Model configuration zoo.

Mirrors ``rust/src/config/zoo.rs``: the paper-scale configs (Pythia-6.9B,
Mistral-7B, Mixtral-8x7B and the paper's hypothetical parallel Mixtral) are
used for the analytical tables of §3; the ``tiny_*`` configs are runnable
end-to-end on the CPU PJRT client and exercise the same code paths.

Terminology follows the paper:
  d       : embedding dimension (``dim``)
  e       : output dim of K and V; e = d * n_kv_heads / n_heads
  arch    : "parallel" (GPT-J/Pythia/PaLM style parallel attention+FFN)
            or "serial" (Llama/Mistral/Mixtral style)
  ffn_type: "mlp" (2-layer MLP, 2*d*h weights) | "swiglu" (GLU variant,
            3*d*h) | "swiglu_moe" (per-expert SwiGLU, 3*d*h*n_experts)
"""

from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    arch: str  # "parallel" | "serial"
    d: int
    n_layers: int
    n_heads: int
    n_kv_heads: int
    ffn_hidden: int
    ffn_type: str  # "mlp" | "swiglu" | "swiglu_moe"
    n_experts: int
    moe_top_k: int
    vocab_size: int
    max_seq: int
    norm_type: str  # "rmsnorm" | "layernorm"
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    # When False the model uses learned absolute positional embeddings added
    # to the token embedding (the vanilla transformer of paper Figure 2(a)).
    # Precompute is then UNSOUND: the first-layer Q/K/V inputs depend on the
    # position, not only the token.  Kept for the negative test (E5).
    rope: bool = True

    @property
    def head_dim(self) -> int:
        assert self.d % self.n_heads == 0
        return self.d // self.n_heads

    @property
    def e(self) -> int:
        """Output dimension of K and V (paper's ``e``)."""
        return self.d * self.n_kv_heads // self.n_heads

    @property
    def precomp_row_width(self) -> int:
        """Values stored per token with precompute: q(d) + k(e) + v(e) + r(d).

        ``r`` is the residual carried past attention: ``emb + ffn_out`` for
        parallel models, plain ``emb`` for serial ones.  Width is 2(d+e) in
        both cases — the paper's formula.
        """
        return 2 * (self.d + self.e)

    @property
    def ffn_weight_factor(self) -> int:
        """2 for plain MLP, 3 for GLU variants (w1, w3 gate, w2)."""
        return 2 if self.ffn_type == "mlp" else 3

    def validate(self) -> None:
        assert self.arch in ("parallel", "serial"), self.arch
        assert self.ffn_type in ("mlp", "swiglu", "swiglu_moe"), self.ffn_type
        assert self.norm_type in ("rmsnorm", "layernorm"), self.norm_type
        assert self.n_heads % self.n_kv_heads == 0
        assert self.d % self.n_heads == 0
        if self.ffn_type != "swiglu_moe":
            assert self.n_experts == 1
        assert 1 <= self.moe_top_k <= self.n_experts


# ---------------------------------------------------------------------------
# Paper-scale configs (§3 of the paper) — analytics only, not runnable here.
# ---------------------------------------------------------------------------

PYTHIA_6_9B = ModelConfig(
    name="pythia-6.9b",
    arch="parallel",
    d=4096,
    n_layers=32,
    n_heads=32,
    n_kv_heads=32,  # MHA
    ffn_hidden=16384,
    ffn_type="mlp",
    n_experts=1,
    moe_top_k=1,
    vocab_size=50400,
    max_seq=2048,
    norm_type="layernorm",
)

MISTRAL_7B = ModelConfig(
    name="mistral-7b",
    arch="serial",
    d=4096,
    n_layers=32,
    n_heads=32,
    n_kv_heads=8,  # GQA
    ffn_hidden=14336,
    ffn_type="swiglu",
    n_experts=1,
    moe_top_k=1,
    vocab_size=32000,
    max_seq=4096,
    norm_type="rmsnorm",
)

MIXTRAL_8X7B = ModelConfig(
    name="mixtral-8x7b",
    arch="serial",
    d=4096,
    n_layers=32,
    n_heads=32,
    n_kv_heads=8,
    ffn_hidden=14336,
    ffn_type="swiglu_moe",
    n_experts=8,
    moe_top_k=2,
    vocab_size=32000,
    max_seq=4096,
    norm_type="rmsnorm",
)

# The paper's §3 third column: a hypothetical Mixtral-8x7B with parallel
# attention/FFN layers, where the whole first layer (incl. the 8-expert MoE
# FFN) becomes precomputable.
MIXTRAL_8X7B_PARALLEL = dataclasses.replace(
    MIXTRAL_8X7B, name="mixtral-8x7b-parallel", arch="parallel"
)

# Whisper-tiny-like 4-layer config for the "max savings 25%" remark (E8).
# (Whisper is an encoder-decoder; we model the 4-layer decoder dims only.)
TINY4_PAPER = ModelConfig(
    name="whisper-tiny4",
    arch="serial",
    d=384,
    n_layers=4,
    n_heads=6,
    n_kv_heads=6,
    ffn_hidden=1536,
    ffn_type="mlp",
    n_experts=1,
    moe_top_k=1,
    vocab_size=51865,
    max_seq=448,
    norm_type="layernorm",
)

# ---------------------------------------------------------------------------
# Runnable tiny configs — same code paths, CPU-PJRT friendly sizes.
# ---------------------------------------------------------------------------

TINY_PARALLEL = ModelConfig(
    name="tiny-parallel",
    arch="parallel",
    d=128,
    n_layers=4,
    n_heads=4,
    n_kv_heads=4,  # MHA like Pythia
    ffn_hidden=512,
    ffn_type="mlp",
    n_experts=1,
    moe_top_k=1,
    vocab_size=512,
    max_seq=128,
    norm_type="layernorm",
)

TINY_SERIAL = ModelConfig(
    name="tiny-serial",
    arch="serial",
    d=128,
    n_layers=4,
    n_heads=4,
    n_kv_heads=2,  # GQA like Mistral
    ffn_hidden=384,
    ffn_type="swiglu",
    n_experts=1,
    moe_top_k=1,
    vocab_size=512,
    max_seq=128,
    norm_type="rmsnorm",
)

TINY_MOE = ModelConfig(
    name="tiny-moe",
    arch="serial",
    d=64,
    n_layers=2,
    n_heads=4,
    n_kv_heads=2,
    ffn_hidden=128,
    ffn_type="swiglu_moe",
    n_experts=4,
    moe_top_k=2,
    vocab_size=256,
    max_seq=64,
    norm_type="rmsnorm",
)

# Parallel MoE — the runnable analogue of the paper's hypothetical
# parallel Mixtral (E2 third column / examples/moe_hypothetical.rs).
TINY_MOE_PARALLEL = dataclasses.replace(
    TINY_MOE, name="tiny-moe-parallel", arch="parallel"
)

# Vanilla absolute-PE config for the negative test (Figure 2(a)):
# precompute must NOT validate on this one.
TINY_ABSPE = dataclasses.replace(
    TINY_SERIAL, name="tiny-abspe", rope=False
)

ZOO = {
    c.name: c
    for c in [
        PYTHIA_6_9B,
        MISTRAL_7B,
        MIXTRAL_8X7B,
        MIXTRAL_8X7B_PARALLEL,
        TINY4_PAPER,
        TINY_PARALLEL,
        TINY_SERIAL,
        TINY_MOE,
        TINY_MOE_PARALLEL,
        TINY_ABSPE,
    ]
}

RUNNABLE = [TINY_PARALLEL, TINY_SERIAL, TINY_MOE, TINY_MOE_PARALLEL]


def get(name: str) -> ModelConfig:
    cfg = ZOO[name]
    cfg.validate()
    return cfg
