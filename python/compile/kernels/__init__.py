"""Layer-1 Pallas kernels + pure-jnp oracles.

All kernels run with ``interpret=True`` (CPU PJRT cannot execute Mosaic
custom-calls); the BlockSpecs are nevertheless written as they would be
tiled for TPU VMEM — see DESIGN.md §Hardware-Adaptation and §Perf for the
footprint estimates at paper-scale dimensions.
"""

from . import ref  # noqa: F401
from .rmsnorm_qkv import fused_norm_matmul  # noqa: F401
from .rope import rope as rope_kernel  # noqa: F401
from .attention import decode_attention  # noqa: F401
from .ffn import swiglu as swiglu_kernel, gelu_mlp as gelu_mlp_kernel  # noqa: F401
from .gather_rows import gather_rows as gather_rows_kernel  # noqa: F401
from .span_attention import span_attention as span_attention_kernel  # noqa: F401
from .span_attention import (  # noqa: F401
    span_attention_batched as span_attention_batched_kernel,
)

INTERPRET = True  # CPU-PJRT target; see module docstring.
