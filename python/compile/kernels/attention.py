"""Flash-style single-token decode attention Pallas kernel (GQA-aware).

The decode phase is the memory-bandwidth-bound regime the paper's savings
target, and KV-cache streaming is its hot loop.  The kernel processes the
cache in ``block_s`` chunks with an online (running max / running sum)
softmax, so only one KV chunk is resident at a time:

  Grid: ``(B,)`` — one program per sequence in the batch.
  Per chunk s: scores = q·K_s^T, online-rescale of (m, l, acc).

VMEM at paper scale (S chunk 512, KH=8, hd=128, H=32):
  q 32·128 + K,V chunks 2·512·8·128 + acc 32·128 ≈ 4.2 MiB — the
  HBM↔VMEM schedule a CUDA flash-decoding kernel would express with
  threadblocks is expressed here by the fori_loop over chunks (the TPU
  pipeline double-buffers the chunk loads).

The length mask handles both ragged batches and the paper's setting where
the current token's K/V has already been written at slot ``lens[b]-1``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(q_ref, k_ref, v_ref, len_ref, o_ref, *, block_s, n_heads):
    # q: [bb, H, hd]; k/v: [bb, S, KH, hd]; len: [bb]
    q = q_ref[...]  # [bb, H, hd]
    bb, H, hd = q.shape
    S = k_ref.shape[1]
    KH = k_ref.shape[2]
    g = n_heads // KH
    qg = q.reshape(bb, KH, g, hd)
    seq_len = len_ref[...]  # [bb]
    scale = 1.0 / jnp.sqrt(jnp.asarray(hd, jnp.float32))

    n_chunks = S // block_s

    def chunk(c, m, l, acc, k, v):
        # scores: [bb, KH, g, block_s]
        s = jnp.einsum("bkgh,bskh->bkgs", qg, k) * scale
        idx = c * block_s + jax.lax.iota(jnp.int32, block_s)
        valid = idx[None, :] < seq_len[:, None]  # [bb, block_s]
        s = jnp.where(valid[:, None, None, :], s, -jnp.inf)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        # exp with -inf rows guarded: where m_new is still -inf nothing valid
        alpha = jnp.where(jnp.isfinite(m), jnp.exp(m - m_new), 0.0)
        p = jnp.where(jnp.isfinite(s), jnp.exp(s - m_new[..., None]), 0.0)
        l_new = l * alpha + jnp.sum(p, axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum("bkgs,bskh->bkgh", p, v)
        return m_new, l_new, acc_new

    m0 = jnp.full((bb, KH, g), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((bb, KH, g), jnp.float32)
    acc0 = jnp.zeros((bb, KH, g, hd), jnp.float32)
    if n_chunks == 1:
        # Single KV chunk: inline — no while loop in the lowered HLO
        # (§Perf: XLA-CPU executes straight-line einsums far faster).
        m, l, acc = chunk(0, m0, l0, acc0, k_ref[...], v_ref[...])
    else:
        def body(c, carry):
            k = pl.load(
                k_ref, (slice(None), pl.ds(c * block_s, block_s), slice(None), slice(None))
            )
            v = pl.load(
                v_ref, (slice(None), pl.ds(c * block_s, block_s), slice(None), slice(None))
            )
            return chunk(c, *carry, k, v)

        m, l, acc = jax.lax.fori_loop(0, n_chunks, body, (m0, l0, acc0))
    ctx = acc / jnp.maximum(l, 1e-37)[..., None]
    o_ref[...] = ctx.reshape(bb, H, hd).astype(o_ref.dtype)


def decode_attention(
    q: jax.Array,  # [B, H, hd]
    kcache: jax.Array,  # [B, S, KH, hd]
    vcache: jax.Array,  # [B, S, KH, hd]
    lens: jax.Array,  # [B] int32: valid slots incl. the current token
    *,
    block_s: int = 64,
    block_b: int = 8,
    interpret: bool = True,
) -> jax.Array:
    """Online-softmax decode attention. Returns [B, H, hd].

    ``block_b`` batches grid programs (one program per ``block_b``
    sequences): under interpret mode each grid step is a loop iteration in
    the lowered HLO, so covering the whole batch in one program is the
    §Perf-tuned configuration for the tiny CPU models; on TPU smaller
    ``block_b`` trades VMEM for parallelism across cores.
    """
    B, H, hd = q.shape
    S = kcache.shape[1]
    bs = min(block_s, S)
    bb = min(block_b, B)
    Sp = (S + bs - 1) // bs * bs
    Bp = (B + bb - 1) // bb * bb
    kp = jnp.pad(kcache, ((0, Bp - B), (0, Sp - S), (0, 0), (0, 0)))
    vp = jnp.pad(vcache, ((0, Bp - B), (0, Sp - S), (0, 0), (0, 0)))
    qp = jnp.pad(q, ((0, Bp - B), (0, 0), (0, 0)))
    lp = jnp.pad(lens, (0, Bp - B))
    KH = kcache.shape[2]
    out = pl.pallas_call(
        functools.partial(_kernel, block_s=bs, n_heads=H),
        grid=(Bp // bb,),
        in_specs=[
            pl.BlockSpec((bb, H, hd), lambda b: (b, 0, 0)),
            pl.BlockSpec((bb, Sp, KH, hd), lambda b: (b, 0, 0, 0)),
            pl.BlockSpec((bb, Sp, KH, hd), lambda b: (b, 0, 0, 0)),
            pl.BlockSpec((bb,), lambda b: (b,)),
        ],
        out_specs=pl.BlockSpec((bb, H, hd), lambda b: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((Bp, H, hd), q.dtype),
        interpret=interpret,
    )(qp, kp, vp, lp)
    return out[:B]
