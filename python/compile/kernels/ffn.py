"""FFN Pallas kernels: SwiGLU (Llama/Mistral) and GELU-MLP (Pythia).

These are the kernels that the paper's trick DELETES from the first layer
of parallel models — they remain the hot path for layers 2..n, and for the
offline table builder (S3) which runs them over the whole vocabulary.

Tiling: grid ``(B / bb, h / bh)`` over the hidden dimension with output
accumulation — the classic two-GEMM chain where the intermediate
activation never round-trips to HBM:

  step j:  a_j = act(x @ w1[:, j]) (* x @ w3[:, j])   [bb, bh]
           o  += a_j @ w2[j, :]                        [bb, d]

The output block index map pins all ``j`` steps to the same block; the
first step initializes it (``pl.when``).  VMEM at paper scale
(d=4096, bb=8, bh=512): x 8·4096 + w1,w3 2·4096·512 + w2 512·4096 +
o 8·4096 floats ≈ 25 MiB -> use bh=256 for 13 MiB.  (interpret mode:
functional only.)
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _swiglu_kernel(x_ref, w1_ref, w3_ref, w2_ref, o_ref):
    @pl.when(pl.program_id(1) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...]  # [bb, d]
    a = jax.nn.silu(x @ w1_ref[...]) * (x @ w3_ref[...])  # [bb, bh]
    o_ref[...] += a @ w2_ref[...]  # [bb, d]


def _gelu_mlp_kernel(x_ref, w1_ref, w2_ref, o_ref):
    @pl.when(pl.program_id(1) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...]
    a = jax.nn.gelu(x @ w1_ref[...], approximate=True)
    o_ref[...] += a @ w2_ref[...]


def _run_ffn(kernel, x, ws_in, w2, *, block_b, block_h, interpret):
    B, d = x.shape
    h = w2.shape[0]
    bb = min(block_b, B)
    bh = min(block_h, h)
    Bp = (B + bb - 1) // bb * bb
    hp = (h + bh - 1) // bh * bh
    xp = jnp.pad(x, ((0, Bp - B), (0, 0)))
    ws_in = [jnp.pad(w, ((0, 0), (0, hp - h))) for w in ws_in]
    w2p = jnp.pad(w2, ((0, hp - h), (0, 0)))
    grid = (Bp // bb, hp // bh)
    in_specs = [pl.BlockSpec((bb, d), lambda i, j: (i, 0))]
    in_specs += [pl.BlockSpec((d, bh), lambda i, j: (0, j)) for _ in ws_in]
    in_specs += [pl.BlockSpec((bh, d), lambda i, j: (j, 0))]
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bb, d), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((Bp, d), x.dtype),
        interpret=interpret,
    )(xp, *ws_in, w2p)
    return out[:B]


def swiglu(
    x: jax.Array,  # [B, d]
    w1: jax.Array,  # [d, h]
    w3: jax.Array,  # [d, h]
    w2: jax.Array,  # [h, d]
    *,
    block_b: int = 8,
    block_h: int = 256,
    interpret: bool = True,
) -> jax.Array:
    """SwiGLU FFN, hidden-tiled with output accumulation. Returns [B, d]."""
    return _run_ffn(
        _swiglu_kernel, x, [w1, w3], w2,
        block_b=block_b, block_h=block_h, interpret=interpret,
    )


def gelu_mlp(
    x: jax.Array,  # [B, d]
    w1: jax.Array,  # [d, h]
    w2: jax.Array,  # [h, d]
    *,
    block_b: int = 8,
    block_h: int = 256,
    interpret: bool = True,
) -> jax.Array:
    """2-layer GELU MLP, hidden-tiled with output accumulation. Returns [B, d]."""
    return _run_ffn(
        _gelu_mlp_kernel, x, [w1], w2,
        block_b=block_b, block_h=block_h, interpret=interpret,
    )
