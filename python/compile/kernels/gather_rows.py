"""Precomputed-row gather Pallas kernel.

The paper's runtime primitive: "the token-ID provides the read-address to
read ``2(d+e)`` values from memory".  In the serving stack the gather
normally happens in rust against the mmap'd table (rust/src/precompute);
this kernel is the in-graph variant used by the fused-lookup ablation
artifact (``decode_precomp_gather``) where the table lives as a device
buffer and the gather lowers into the same HLO as the rest of the step.

Grid ``(B,)``: one dynamic row read per token.  On TPU the table would be
pinned in HBM (memory_space=ANY) and each program issues a single async
row copy — exactly one ``2(d+e)``-value read per token, which is the
quantity table E2/E3 counts.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(tok_ref, table_ref, o_ref):
    t = tok_ref[0]
    o_ref[...] = pl.load(table_ref, (pl.ds(t, 1), slice(None)))


def gather_rows(
    table: jax.Array,  # [V, W]
    tokens: jax.Array,  # [B] int32
    *,
    interpret: bool = True,
) -> jax.Array:
    """rows = table[tokens]; one row read per token. Returns [B, W]."""
    B = tokens.shape[0]
    V, W = table.shape
    return pl.pallas_call(
        _kernel,
        grid=(B,),
        in_specs=[
            pl.BlockSpec((1,), lambda b: (b,)),
            pl.BlockSpec((V, W), lambda b: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, W), lambda b: (b, 0)),
        out_shape=jax.ShapeDtypeStruct((B, W), table.dtype),
        interpret=interpret,
    )(tokens, table)
