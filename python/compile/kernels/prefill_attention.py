"""Causal (prefill) flash-style attention Pallas kernel, GQA-aware.

Prefill is compute-bound (the paper's batch>1 regime where weight reads
amortize); the kernel tiles queries and keys in blocks with an online
softmax so the [T, S] score matrix never materializes:

  Grid: ``(B, T / block_q)`` — one program per (sequence, query block).
  Inner ``fori_loop`` over KV blocks up to the causal frontier.

VMEM at paper scale (block_q = block_k = 128, H=32, hd=128):
  q 128·32·128 + k,v 2·128·8·128 + acc 128·32·128 floats ≈ 5.3 MiB.

Padding rows (t >= lens[b]) produce zeros, matching ref.attention_prefill.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(q_ref, k_ref, v_ref, len_ref, o_ref, *, block_q, block_k, n_heads):
    # q: [1, bq, H, hd]; k/v: [1, S, KH, hd]; len: [1]
    qi = pl.program_id(1)
    q = q_ref[0]  # [bq, H, hd]
    bq, H, hd = q.shape
    S = k_ref.shape[1]
    KH = k_ref.shape[2]
    g = n_heads // KH
    seq_len = len_ref[0]
    scale = 1.0 / jnp.sqrt(jnp.asarray(hd, jnp.float32))
    qg = q.reshape(bq, KH, g, hd)
    q_pos = qi * block_q + jax.lax.iota(jnp.int32, bq)  # global query rows

    n_chunks = S // block_k

    def body(c, carry):
        m, l, acc = carry  # [bq, KH, g], [bq, KH, g], [bq, KH, g, hd]
        k = pl.load(k_ref, (0, pl.ds(c * block_k, block_k), slice(None), slice(None)))
        v = pl.load(v_ref, (0, pl.ds(c * block_k, block_k), slice(None), slice(None)))
        s = jnp.einsum("qkgh,skh->qkgs", qg, k) * scale  # [bq, KH, g, bk]
        k_pos = c * block_k + jax.lax.iota(jnp.int32, block_k)
        valid = (k_pos[None, :] <= q_pos[:, None]) & (k_pos[None, :] < seq_len)
        s = jnp.where(valid[:, None, None, :], s, -jnp.inf)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        alpha = jnp.where(jnp.isfinite(m), jnp.exp(m - m_new), 0.0)
        p = jnp.where(jnp.isfinite(s), jnp.exp(s - m_new[..., None]), 0.0)
        l_new = l * alpha + jnp.sum(p, axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum("qkgs,skh->qkgh", p, v)
        return m_new, l_new, acc_new

    m0 = jnp.full((bq, KH, g), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((bq, KH, g), jnp.float32)
    acc0 = jnp.zeros((bq, KH, g, hd), jnp.float32)
    m, l, acc = jax.lax.fori_loop(0, n_chunks, body, (m0, l0, acc0))
    ctx = acc / jnp.maximum(l, 1e-37)[..., None]
    # Zero out padding query rows (t >= seq_len): fully-masked rows have l=0
    # already -> ctx = 0 via the epsilon guard, matching the oracle.
    o_ref[0] = ctx.reshape(bq, H, hd).astype(o_ref.dtype)


def prefill_attention(
    q: jax.Array,  # [B, T, H, hd]
    k: jax.Array,  # [B, T, KH, hd]
    v: jax.Array,  # [B, T, KH, hd]
    lens: jax.Array,  # [B] valid prompt lengths
    *,
    block_q: int = 32,
    block_k: int = 32,
    interpret: bool = True,
) -> jax.Array:
    """Causal self-attention over a padded prompt batch: [B, T, H, hd]."""
    B, T, H, hd = q.shape
    KH = k.shape[2]
    bq = min(block_q, T)
    bk = min(block_k, T)
    Tq = (T + bq - 1) // bq * bq
    Tk = (T + bk - 1) // bk * bk
    qp = jnp.pad(q, ((0, 0), (0, Tq - T), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, Tk - T), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, Tk - T), (0, 0), (0, 0)))
    out = pl.pallas_call(
        functools.partial(_kernel, block_q=bq, block_k=bk, n_heads=H),
        grid=(B, Tq // bq),
        in_specs=[
            pl.BlockSpec((1, bq, H, hd), lambda b, i: (b, i, 0, 0)),
            pl.BlockSpec((1, Tk, KH, hd), lambda b, i: (b, 0, 0, 0)),
            pl.BlockSpec((1, Tk, KH, hd), lambda b, i: (b, 0, 0, 0)),
            pl.BlockSpec((1,), lambda b, i: (b,)),
        ],
        out_specs=pl.BlockSpec((1, bq, H, hd), lambda b, i: (b, i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Tq, H, hd), q.dtype),
        interpret=interpret,
    )(qp, kp, vp, lens)
    return out[:, :T]
