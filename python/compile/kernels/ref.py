"""Pure-jnp reference implementations (correctness oracles).

Every Pallas kernel in this package has its oracle here; pytest +
hypothesis sweep shapes/dtypes and ``assert_allclose`` kernel-vs-ref.
These are also used directly by the model when ``use_pallas=False``
(cheap paths and tests).

Conventions:
  * RoPE uses the NeoX/Llama "rotate-half" convention: the head dim is
    split in two halves; frequency ``i`` has angle ``pos * theta^(-2i/hd)``.
  * Attention is causal; decode attends over ``lens[b]`` cache slots
    (the new token's K/V is written into the cache *before* attention,
    so slot ``lens[b]-1`` is the current token).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# Normalization
# ---------------------------------------------------------------------------


def rmsnorm(x: jax.Array, scale: jax.Array, eps: float = 1e-5) -> jax.Array:
    """RMSNorm over the last axis: x / rms(x) * scale."""
    ms = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(ms + eps) * scale


def layernorm(
    x: jax.Array, scale: jax.Array, bias: jax.Array, eps: float = 1e-5
) -> jax.Array:
    """LayerNorm over the last axis."""
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * scale + bias


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    """Inverse frequencies, shape [head_dim // 2]."""
    i = jnp.arange(head_dim // 2, dtype=jnp.float32)
    return theta ** (-2.0 * i / head_dim)


def rope_apply(x: jax.Array, pos: jax.Array, theta: float = 10000.0) -> jax.Array:
    """Apply rotary position embedding.

    x:   [..., n_heads, head_dim]  (head_dim even)
    pos: integer positions, shape == x.shape[:-2]
    """
    hd = x.shape[-1]
    assert hd % 2 == 0
    freqs = rope_freqs(hd, theta)  # [hd/2]
    ang = pos.astype(jnp.float32)[..., None, None] * freqs  # [..., 1, hd/2]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., : hd // 2], x[..., hd // 2 :]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


# ---------------------------------------------------------------------------
# Fused norm + QKV projection (oracle for kernels/rmsnorm_qkv.py)
# ---------------------------------------------------------------------------


def norm_qkv(
    x: jax.Array,
    scale: jax.Array,
    bias,
    wq: jax.Array,
    wk: jax.Array,
    wv: jax.Array,
    norm_type: str = "rmsnorm",
    eps: float = 1e-5,
):
    """x: [B, d] -> (q [B, d], k [B, e], v [B, e])."""
    if norm_type == "rmsnorm":
        xn = rmsnorm(x, scale, eps)
    else:
        xn = layernorm(x, scale, bias, eps)
    return xn @ wq, xn @ wk, xn @ wv


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------


def attention_decode(
    q: jax.Array,  # [B, H, hd]
    kcache: jax.Array,  # [B, S, KH, hd]
    vcache: jax.Array,  # [B, S, KH, hd]
    lens: jax.Array,  # [B] int32, number of VALID slots (incl. current token)
) -> jax.Array:
    """Single-token decode attention with GQA. Returns [B, H, hd]."""
    B, H, hd = q.shape
    S, KH = kcache.shape[1], kcache.shape[2]
    g = H // KH  # query heads per KV head
    qg = q.reshape(B, KH, g, hd)
    scores = jnp.einsum("bkgh,bskh->bkgs", qg, kcache) / jnp.sqrt(
        jnp.asarray(hd, jnp.float32)
    )
    mask = jnp.arange(S)[None, None, None, :] < lens[:, None, None, None]
    scores = jnp.where(mask, scores, -jnp.inf)
    p = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum("bkgs,bskh->bkgh", p, vcache)
    return ctx.reshape(B, H, hd)


def attention_span(
    q: jax.Array,  # [T, H, hd] — span queries at absolute positions start+t
    kcache: jax.Array,  # [S, KH, hd] — full cache, span rows already inserted
    vcache: jax.Array,  # [S, KH, hd]
    start,  # scalar int32: absolute position of span token 0
) -> jax.Array:
    """Causal-over-history span attention for ONE sequence (oracle for
    kernels/span_attention.py).  Token ``t`` attends every cache slot
    ``s <= start + t``: the history below ``start`` plus the span's own
    earlier (and current) rows.  ``start == 0`` degenerates to causal
    prefill; ``T == 1`` to decode attention with ``lens = start + 1``.
    Returns [T, H, hd].
    """
    T, H, hd = q.shape
    S, KH = kcache.shape[0], kcache.shape[1]
    g = H // KH
    qg = q.reshape(T, KH, g, hd)
    scores = jnp.einsum("tkgh,skh->tkgs", qg, kcache) / jnp.sqrt(
        jnp.asarray(hd, jnp.float32)
    )
    pos = start + jnp.arange(T)
    mask = jnp.arange(S)[None, :] <= pos[:, None]  # [T, S]
    scores = jnp.where(mask[:, None, None, :], scores, -jnp.inf)
    # Every row attends at least its own slot, so no all-masked-row guard.
    p = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum("tkgs,skh->tkgh", p, vcache)
    return ctx.reshape(T, H, hd)


def attention_span_batched(
    q: jax.Array,  # [B, T, H, hd] — per-row queries at positions starts[b]+t
    kcache: jax.Array,  # [B, S, KH, hd] — per-row caches, span rows inserted
    vcache: jax.Array,  # [B, S, KH, hd]
    starts: jax.Array,  # [B] int32: absolute position of each row's token 0
    lens: jax.Array,  # [B] int32: valid span tokens per row (0 = inert row)
) -> jax.Array:
    """Multi-sequence causal-over-history span attention (oracle for the
    batched ``kernels/span_attention.span_attention_batched``).  Row ``b``
    token ``t`` attends cache slots ``s <= starts[b] + t`` iff
    ``t < lens[b]``; tokens at ``t >= lens[b]`` (ragged-tail padding) and
    whole rows with ``lens[b] == 0`` (unoccupied batch lanes) are fully
    masked and their output is zeroed.  ``B == 1`` with ``lens = [T]``
    degenerates to :func:`attention_span`.  Returns [B, T, H, hd].
    """
    B, T, H, hd = q.shape
    S, KH = kcache.shape[1], kcache.shape[2]
    g = H // KH
    qg = q.reshape(B, T, KH, g, hd)
    scores = jnp.einsum("btkgh,bskh->bkgts", qg, kcache) / jnp.sqrt(
        jnp.asarray(hd, jnp.float32)
    )
    pos = starts[:, None] + jnp.arange(T)[None, :]  # [B, T]
    causal = jnp.arange(S)[None, None, :] <= pos[:, :, None]  # [B, T, S]
    alive = jnp.arange(T)[None, :] < lens[:, None]  # [B, T]
    mask = causal & alive[:, :, None]
    scores = jnp.where(mask[:, None, None, :, :], scores, -jnp.inf)
    # Fully-masked rows (padding tokens / inert lanes) softmax to NaN;
    # zero them like attention_prefill does.
    p = jax.nn.softmax(scores, axis=-1)
    p = jnp.nan_to_num(p)
    ctx = jnp.einsum("bkgts,bskh->btkgh", p, vcache)
    return ctx.reshape(B, T, H, hd)


def attention_prefill(
    q: jax.Array,  # [B, T, H, hd]
    k: jax.Array,  # [B, T, KH, hd]
    v: jax.Array,  # [B, T, KH, hd]
    lens: jax.Array,  # [B] valid prompt lengths (<= T)
) -> jax.Array:
    """Causal self-attention over a padded prompt batch. Returns [B, T, H, hd].

    Rows with t >= lens[b] are padding; their output is zeroed.
    """
    B, T, H, hd = q.shape
    KH = k.shape[2]
    g = H // KH
    qg = q.reshape(B, T, KH, g, hd)
    scores = jnp.einsum("btkgh,bskh->bkgts", qg, k) / jnp.sqrt(
        jnp.asarray(hd, jnp.float32)
    )
    causal = jnp.arange(T)[:, None] >= jnp.arange(T)[None, :]  # [T, S]
    valid = jnp.arange(T)[None, :] < lens[:, None]  # [B, S]
    mask = causal[None, None, None] & valid[:, None, None, None]
    scores = jnp.where(mask, scores, -jnp.inf)
    # Shift by the row max for stability; fully-masked (padding) rows would
    # produce NaN, so zero them afterwards.
    p = jax.nn.softmax(scores, axis=-1)
    p = jnp.nan_to_num(p)
    ctx = jnp.einsum("bkgts,bskh->btkgh", p, v)
    return ctx.reshape(B, T, H, hd)


# ---------------------------------------------------------------------------
# FFN variants (oracles for kernels/ffn.py)
# ---------------------------------------------------------------------------


def mlp(x: jax.Array, w1: jax.Array, w2: jax.Array) -> jax.Array:
    """2-layer MLP with GELU (Pythia/GPT-NeoX style). x: [..., d]."""
    return jax.nn.gelu(x @ w1, approximate=True) @ w2


def swiglu(x: jax.Array, w1: jax.Array, w3: jax.Array, w2: jax.Array) -> jax.Array:
    """SwiGLU (Llama/Mistral style): (silu(x w1) * (x w3)) w2."""
    return (jax.nn.silu(x @ w1) * (x @ w3)) @ w2


def topk_iterative(logits: jax.Array, k: int):
    """Iterative-argmax top-k over the last axis.

    ``jax.lax.top_k`` lowers to the `topk(..., largest=true)` HLO op which
    the pinned xla_extension 0.5.1 text parser rejects; k is tiny (<= 4) so
    k argmax+mask rounds lower to plain reduce/select ops instead.
    """
    vals, idxs = [], []
    x = logits
    b = jnp.arange(logits.shape[0])
    for _ in range(k):
        i = jnp.argmax(x, axis=-1)  # [B]
        v = jnp.take_along_axis(x, i[:, None], axis=-1)[:, 0]
        vals.append(v)
        idxs.append(i)
        x = x.at[b, i].set(-jnp.inf)
    return jnp.stack(vals, -1), jnp.stack(idxs, -1)


def moe_swiglu(
    x: jax.Array,  # [B, d]
    router: jax.Array,  # [d, E]
    w1: jax.Array,  # [E, d, h]
    w3: jax.Array,  # [E, d, h]
    w2: jax.Array,  # [E, h, d]
    top_k: int,
) -> jax.Array:
    """Dense-computed switch FFN with top-k routing (Mixtral style).

    All experts are evaluated and masked — numerically identical to sparse
    dispatch, simple and correct on CPU.
    """
    logits = x @ router  # [B, E]
    topv, topi = topk_iterative(logits, top_k)  # [B, k]
    w = jax.nn.softmax(topv, axis=-1)  # renormalized over the top-k
    gate = jnp.zeros_like(logits).at[jnp.arange(x.shape[0])[:, None], topi].set(w)
    h = jax.nn.silu(jnp.einsum("bd,edh->beh", x, w1)) * jnp.einsum(
        "bd,edh->beh", x, w3
    )
    y = jnp.einsum("beh,ehd->bed", h, w2)
    return jnp.einsum("bed,be->bd", y, gate)


def ffn_apply(x, lw, ffn_type: str, top_k: int = 1):
    """Dispatch over the FFN variants given a layer-weight dict ``lw``."""
    if ffn_type == "mlp":
        return mlp(x, lw["w1"], lw["w2"])
    if ffn_type == "swiglu":
        return swiglu(x, lw["w1"], lw["w3"], lw["w2"])
    if ffn_type == "swiglu_moe":
        return moe_swiglu(x, lw["router"], lw["w1"], lw["w3"], lw["w2"], top_k)
    raise ValueError(ffn_type)


# ---------------------------------------------------------------------------
# Row gather (oracle for kernels/gather_rows.py)
# ---------------------------------------------------------------------------


def gather_rows(table: jax.Array, tokens: jax.Array) -> jax.Array:
    """table: [V, W], tokens: [B] int32 -> [B, W]. The paper's 'memory read'."""
    return table[tokens]
