"""Fused normalization + packed QKV projection Pallas kernel.

The first layer's hot entry: ``y = norm(x) @ W`` where ``W`` is the packed
``concat(Wq, Wk, Wv)`` — one kernel, one HBM round-trip for the activations
instead of four (norm out, q, k, v separately).

Grid: ``(B / bb, dout / bn)``.  Each instance holds ``x`` block ``[bb, d]``
(full reduction axis — the norm needs the whole row), ``W`` block
``[d, bn]`` and accumulates nothing across steps (no K-tiling: at paper
scale d=4096, bb=8, bn=512 ⇒ VMEM = 8·4096 + 4096·512 + 8·512 floats
≈ 8.6 MiB, comfortably under 16 MiB, and the MXU sees a 4096-deep GEMM).

The norm of the ``x`` block is recomputed per ``bn`` step; it is O(bb·d)
FLOPs vs the O(bb·d·bn) GEMM — noise on the MXU, and it saves a separate
kernel launch + HBM round-trip of the normalized activations.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, scale_ref, bias_ref, w_ref, o_ref, *, norm_type, eps):
    x = x_ref[...]  # [bb, d]
    scale = scale_ref[...]  # [d]
    if norm_type == "rmsnorm":
        ms = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
        xn = x * jax.lax.rsqrt(ms + eps) * scale
    else:
        mu = jnp.mean(x, axis=-1, keepdims=True)
        var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
        xn = (x - mu) * jax.lax.rsqrt(var + eps) * scale + bias_ref[...]
    o_ref[...] = xn @ w_ref[...]  # [bb, bn]


def fused_norm_matmul(
    x: jax.Array,  # [B, d]
    scale: jax.Array,  # [d]
    bias: jax.Array,  # [d] (ignored for rmsnorm but always passed: static arity)
    w: jax.Array,  # [d, dout]
    *,
    norm_type: str = "rmsnorm",
    eps: float = 1e-5,
    block_b: int = 8,
    block_n: int = 128,
    interpret: bool = True,
) -> jax.Array:
    """``norm(x) @ w`` fused. Returns [B, dout]."""
    B, d = x.shape
    dout = w.shape[1]
    bb = min(block_b, B)
    bn = min(block_n, dout)
    # Pad to multiples of the block so the grid divides evenly.
    Bp = (B + bb - 1) // bb * bb
    Np = (dout + bn - 1) // bn * bn
    xp = jnp.pad(x, ((0, Bp - B), (0, 0)))
    wp = jnp.pad(w, ((0, 0), (0, Np - dout)))
    grid = (Bp // bb, Np // bn)
    out = pl.pallas_call(
        functools.partial(_kernel, norm_type=norm_type, eps=eps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, d), lambda i, j: (i, 0)),
            pl.BlockSpec((d,), lambda i, j: (0,)),
            pl.BlockSpec((d,), lambda i, j: (0,)),
            pl.BlockSpec((d, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bb, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Bp, Np), x.dtype),
        interpret=interpret,
    )(xp, scale, bias, wp)
    return out[:B, :dout]
