"""Rotary position embedding (RoPE) Pallas kernel.

RoPE is the one part of the first layer that can NOT be precomputed — it
depends on the token's position — so at serving time it runs on the
gathered, precomputed q/k rows.  That makes it the only per-token compute
left of the first layer's projection path and worth a fused kernel.

Grid: ``(B / bb,)``; block ``[bb, H, hd]`` plus the positions ``[bb]``.
Frequencies are regenerated in-register with ``iota`` (no HBM table).
VMEM at paper scale (bb=8, H=32, hd=128): 8·32·128·2 ≈ 256 KiB.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, pos_ref, o_ref, *, theta):
    x = x_ref[...]  # [bb, H, hd]
    pos = pos_ref[...]  # [bb]
    hd = x.shape[-1]
    i = jax.lax.iota(jnp.float32, hd // 2)
    freqs = theta ** (-2.0 * i / hd)  # [hd/2]
    ang = pos.astype(jnp.float32)[:, None, None] * freqs  # [bb, 1, hd/2]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., : hd // 2], x[..., hd // 2 :]
    o_ref[...] = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def rope(
    x: jax.Array,  # [B, H, hd]
    pos: jax.Array,  # [B] int32
    *,
    theta: float = 10000.0,
    block_b: int = 8,
    interpret: bool = True,
) -> jax.Array:
    """Apply RoPE per batch row. Returns [B, H, hd]."""
    B, H, hd = x.shape
    assert hd % 2 == 0
    bb = min(block_b, B)
    Bp = (B + bb - 1) // bb * bb
    xp = jnp.pad(x, ((0, Bp - B), (0, 0), (0, 0)))
    pp = jnp.pad(pos, (0, Bp - B))
    out = pl.pallas_call(
        functools.partial(_kernel, theta=theta),
        grid=(Bp // bb,),
        in_specs=[
            pl.BlockSpec((bb, H, hd), lambda i: (i, 0, 0)),
            pl.BlockSpec((bb,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((bb, H, hd), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((Bp, H, hd), x.dtype),
        interpret=interpret,
    )(xp, pp)
    return out[:B]
