"""Causal-over-history span attention Pallas kernel, GQA-aware.

The batched span artifact advances ONE sequence through ``T`` new tokens
in a single execution: token ``t`` sits at absolute position
``start + t`` and attends every cache slot up to and including its own
(the span's K/V rows are inserted into the cache *before* attention, so
slots ``start .. start+t`` hold the span's own fresh keys).  This is the
kernel that turns a chunked-prefill continuation from ``T`` PJRT
dispatches into one: the mask generalizes both neighbours —

  * ``start == 0``  →  plain causal prefill attention,
  * ``T == 1``      →  single-token decode attention with ``lens = start+1``.

Grid: ``(T / block_q,)`` — one program per query block; inner
``fori_loop`` over KV chunks with an online softmax, so the ``[T, S]``
score matrix never materializes.

VMEM at paper scale (block_q = 32, block_k = 512, H=32, KH=8, hd=128):
  q 32·32·128 + k,v 2·512·8·128 + acc 32·32·128 floats ≈ 4.3 MiB.

Padding query rows (a ragged span tail padded up to the compiled bucket)
attend garbage slots past the valid frontier but their output is
discarded host-side; every row attends at least its own slot, so the
softmax never sees an all-masked row.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(q_ref, k_ref, v_ref, start_ref, o_ref, *, block_q, block_k, n_heads):
    # q: [bq, H, hd]; k/v: [S, KH, hd]; start: [1]
    qi = pl.program_id(0)
    q = q_ref[...]  # [bq, H, hd]
    bq, H, hd = q.shape
    S = k_ref.shape[0]
    KH = k_ref.shape[1]
    g = n_heads // KH
    qg = q.reshape(bq, KH, g, hd)
    scale = 1.0 / jnp.sqrt(jnp.asarray(hd, jnp.float32))
    # Absolute position of each query row: token t lives at start + t.
    q_pos = start_ref[0] + qi * block_q + jax.lax.iota(jnp.int32, bq)

    n_chunks = S // block_k

    def body(c, carry):
        m, l, acc = carry  # [bq, KH, g], [bq, KH, g], [bq, KH, g, hd]
        k = pl.load(k_ref, (pl.ds(c * block_k, block_k), slice(None), slice(None)))
        v = pl.load(v_ref, (pl.ds(c * block_k, block_k), slice(None), slice(None)))
        s = jnp.einsum("qkgh,skh->qkgs", qg, k) * scale  # [bq, KH, g, bk]
        k_pos = c * block_k + jax.lax.iota(jnp.int32, block_k)
        # Causal over the WHOLE history: slot s is visible iff s <= start+t.
        valid = k_pos[None, :] <= q_pos[:, None]
        s = jnp.where(valid[:, None, None, :], s, -jnp.inf)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        alpha = jnp.where(jnp.isfinite(m), jnp.exp(m - m_new), 0.0)
        p = jnp.where(jnp.isfinite(s), jnp.exp(s - m_new[..., None]), 0.0)
        l_new = l * alpha + jnp.sum(p, axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum("qkgs,skh->qkgh", p, v)
        return m_new, l_new, acc_new

    m0 = jnp.full((bq, KH, g), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((bq, KH, g), jnp.float32)
    acc0 = jnp.zeros((bq, KH, g, hd), jnp.float32)
    m, l, acc = jax.lax.fori_loop(0, n_chunks, body, (m0, l0, acc0))
    ctx = acc / jnp.maximum(l, 1e-37)[..., None]
    o_ref[...] = ctx.reshape(bq, H, hd).astype(o_ref.dtype)


def _kernel_batched(
    q_ref, k_ref, v_ref, start_ref, len_ref, o_ref, *, block_q, block_k, n_heads
):
    # q: [1, bq, H, hd]; k/v: [1, S, KH, hd]; start/len: [1] (this row's).
    qi = pl.program_id(1)
    q = q_ref[0]  # [bq, H, hd]
    bq, H, hd = q.shape
    S = k_ref.shape[1]
    KH = k_ref.shape[2]
    g = n_heads // KH
    qg = q.reshape(bq, KH, g, hd)
    scale = 1.0 / jnp.sqrt(jnp.asarray(hd, jnp.float32))
    # Span-local token index and absolute position of each query row.
    t_idx = qi * block_q + jax.lax.iota(jnp.int32, bq)
    q_pos = start_ref[0] + t_idx
    # Rows past this lane's valid length (ragged tail, or the whole lane
    # when len == 0) get every slot masked; the online-softmax guards
    # below turn an all-masked row into exact zeros instead of NaN.
    alive = t_idx < len_ref[0]

    n_chunks = S // block_k

    def body(c, carry):
        m, l, acc = carry  # [bq, KH, g], [bq, KH, g], [bq, KH, g, hd]
        k = pl.load(
            k_ref, (pl.ds(0, 1), pl.ds(c * block_k, block_k), slice(None), slice(None))
        )[0]
        v = pl.load(
            v_ref, (pl.ds(0, 1), pl.ds(c * block_k, block_k), slice(None), slice(None))
        )[0]
        s = jnp.einsum("qkgh,skh->qkgs", qg, k) * scale  # [bq, KH, g, bk]
        k_pos = c * block_k + jax.lax.iota(jnp.int32, block_k)
        valid = (k_pos[None, :] <= q_pos[:, None]) & alive[:, None]
        s = jnp.where(valid[:, None, None, :], s, -jnp.inf)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        alpha = jnp.where(jnp.isfinite(m), jnp.exp(m - m_new), 0.0)
        p = jnp.where(jnp.isfinite(s), jnp.exp(s - m_new[..., None]), 0.0)
        l_new = l * alpha + jnp.sum(p, axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum("qkgs,skh->qkgh", p, v)
        return m_new, l_new, acc_new

    m0 = jnp.full((bq, KH, g), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((bq, KH, g), jnp.float32)
    acc0 = jnp.zeros((bq, KH, g, hd), jnp.float32)
    m, l, acc = jax.lax.fori_loop(0, n_chunks, body, (m0, l0, acc0))
    ctx = acc / jnp.maximum(l, 1e-37)[..., None]
    o_ref[...] = ctx.reshape(1, bq, H, hd).astype(o_ref.dtype)


def span_attention_batched(
    q: jax.Array,  # [B, T, H, hd] — per-lane spans, RoPE'd at starts[b]+t
    kcache: jax.Array,  # [B, S, KH, hd] — per-lane caches, span rows inserted
    vcache: jax.Array,  # [B, S, KH, hd]
    starts: jax.Array,  # [B] int32: absolute position of each lane's token 0
    lens: jax.Array,  # [B] int32: valid tokens per lane (0 = inert lane)
    *,
    block_q: int = 32,
    block_k: int = 128,
    interpret: bool = True,
) -> jax.Array:
    """Multi-sequence causal-over-history span attention: [B, T, H, hd].

    One device execution advances every lane: lane ``b`` token ``t`` sits
    at absolute position ``starts[b] + t`` and attends every cache slot up
    to and including its own, but only while ``t < lens[b]``.  Ragged
    tails and unoccupied lanes (``lens[b] == 0``) are fully masked and
    produce exact zeros, so padding lanes are inert regardless of cache
    contents.  ``B == 1`` with ``lens = [T]`` matches
    :func:`span_attention` bit-for-bit on the shared block shapes.
    """
    B, T, H, hd = q.shape
    S, KH = kcache.shape[1], kcache.shape[2]
    bq = min(block_q, T)
    bk = min(block_k, S)
    Tq = (T + bq - 1) // bq * bq
    Sk = (S + bk - 1) // bk * bk
    qp = jnp.pad(q, ((0, 0), (0, Tq - T), (0, 0), (0, 0)))
    # Padded KV slots sit at positions >= S > starts[b] + T - 1: always masked.
    kp = jnp.pad(kcache, ((0, 0), (0, Sk - S), (0, 0), (0, 0)))
    vp = jnp.pad(vcache, ((0, 0), (0, Sk - S), (0, 0), (0, 0)))
    starts_arr = jnp.reshape(starts, (B,)).astype(jnp.int32)
    lens_arr = jnp.reshape(lens, (B,)).astype(jnp.int32)
    out = pl.pallas_call(
        functools.partial(_kernel_batched, block_q=bq, block_k=bk, n_heads=H),
        grid=(B, Tq // bq),
        in_specs=[
            pl.BlockSpec((1, bq, H, hd), lambda b, i: (b, i, 0, 0)),
            pl.BlockSpec((1, Sk, KH, hd), lambda b, i: (b, 0, 0, 0)),
            pl.BlockSpec((1, Sk, KH, hd), lambda b, i: (b, 0, 0, 0)),
            pl.BlockSpec((1,), lambda b, i: (b,)),
            pl.BlockSpec((1,), lambda b, i: (b,)),
        ],
        out_specs=pl.BlockSpec((1, bq, H, hd), lambda b, i: (b, i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Tq, H, hd), q.dtype),
        interpret=interpret,
    )(qp, kp, vp, starts_arr, lens_arr)
    return out[:, :T]


def span_attention(
    q: jax.Array,  # [T, H, hd] — span queries, already RoPE'd at start+t
    kcache: jax.Array,  # [S, KH, hd] — full cache, span rows inserted
    vcache: jax.Array,  # [S, KH, hd]
    start: jax.Array,  # [1] (or scalar) int32: absolute position of token 0
    *,
    block_q: int = 32,
    block_k: int = 128,
    interpret: bool = True,
) -> jax.Array:
    """Causal-over-history attention for one sequence's span: [T, H, hd]."""
    T, H, hd = q.shape
    S, KH = kcache.shape[0], kcache.shape[1]
    bq = min(block_q, T)
    bk = min(block_k, S)
    Tq = (T + bq - 1) // bq * bq
    Sk = (S + bk - 1) // bk * bk
    qp = jnp.pad(q, ((0, Tq - T), (0, 0), (0, 0)))
    # Padded KV slots sit at positions >= S > start + T - 1: always masked.
    kp = jnp.pad(kcache, ((0, Sk - S), (0, 0), (0, 0)))
    vp = jnp.pad(vcache, ((0, Sk - S), (0, 0), (0, 0)))
    start_arr = jnp.reshape(start, (1,)).astype(jnp.int32)
    out = pl.pallas_call(
        functools.partial(_kernel, block_q=bq, block_k=bk, n_heads=H),
        grid=(Tq // bq,),
        in_specs=[
            pl.BlockSpec((bq, H, hd), lambda i: (i, 0, 0)),
            pl.BlockSpec((Sk, KH, hd), lambda i: (0, 0, 0)),
            pl.BlockSpec((Sk, KH, hd), lambda i: (0, 0, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bq, H, hd), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((Tq, H, hd), q.dtype),
        interpret=interpret,
    )(qp, kp, vp, start_arr)
    return out[:T]
