"""Causal-over-history span attention Pallas kernel, GQA-aware.

The batched span artifact advances ONE sequence through ``T`` new tokens
in a single execution: token ``t`` sits at absolute position
``start + t`` and attends every cache slot up to and including its own
(the span's K/V rows are inserted into the cache *before* attention, so
slots ``start .. start+t`` hold the span's own fresh keys).  This is the
kernel that turns a chunked-prefill continuation from ``T`` PJRT
dispatches into one: the mask generalizes both neighbours —

  * ``start == 0``  →  plain causal prefill attention,
  * ``T == 1``      →  single-token decode attention with ``lens = start+1``.

Grid: ``(T / block_q,)`` — one program per query block; inner
``fori_loop`` over KV chunks with an online softmax, so the ``[T, S]``
score matrix never materializes.

VMEM at paper scale (block_q = 32, block_k = 512, H=32, KH=8, hd=128):
  q 32·32·128 + k,v 2·512·8·128 + acc 32·32·128 floats ≈ 4.3 MiB.

Padding query rows (a ragged span tail padded up to the compiled bucket)
attend garbage slots past the valid frontier but their output is
discarded host-side; every row attends at least its own slot, so the
softmax never sees an all-masked row.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(q_ref, k_ref, v_ref, start_ref, o_ref, *, block_q, block_k, n_heads):
    # q: [bq, H, hd]; k/v: [S, KH, hd]; start: [1]
    qi = pl.program_id(0)
    q = q_ref[...]  # [bq, H, hd]
    bq, H, hd = q.shape
    S = k_ref.shape[0]
    KH = k_ref.shape[1]
    g = n_heads // KH
    qg = q.reshape(bq, KH, g, hd)
    scale = 1.0 / jnp.sqrt(jnp.asarray(hd, jnp.float32))
    # Absolute position of each query row: token t lives at start + t.
    q_pos = start_ref[0] + qi * block_q + jax.lax.iota(jnp.int32, bq)

    n_chunks = S // block_k

    def body(c, carry):
        m, l, acc = carry  # [bq, KH, g], [bq, KH, g], [bq, KH, g, hd]
        k = pl.load(k_ref, (pl.ds(c * block_k, block_k), slice(None), slice(None)))
        v = pl.load(v_ref, (pl.ds(c * block_k, block_k), slice(None), slice(None)))
        s = jnp.einsum("qkgh,skh->qkgs", qg, k) * scale  # [bq, KH, g, bk]
        k_pos = c * block_k + jax.lax.iota(jnp.int32, block_k)
        # Causal over the WHOLE history: slot s is visible iff s <= start+t.
        valid = k_pos[None, :] <= q_pos[:, None]
        s = jnp.where(valid[:, None, None, :], s, -jnp.inf)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        alpha = jnp.where(jnp.isfinite(m), jnp.exp(m - m_new), 0.0)
        p = jnp.where(jnp.isfinite(s), jnp.exp(s - m_new[..., None]), 0.0)
        l_new = l * alpha + jnp.sum(p, axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum("qkgs,skh->qkgh", p, v)
        return m_new, l_new, acc_new

    m0 = jnp.full((bq, KH, g), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((bq, KH, g), jnp.float32)
    acc0 = jnp.zeros((bq, KH, g, hd), jnp.float32)
    m, l, acc = jax.lax.fori_loop(0, n_chunks, body, (m0, l0, acc0))
    ctx = acc / jnp.maximum(l, 1e-37)[..., None]
    o_ref[...] = ctx.reshape(bq, H, hd).astype(o_ref.dtype)


def span_attention(
    q: jax.Array,  # [T, H, hd] — span queries, already RoPE'd at start+t
    kcache: jax.Array,  # [S, KH, hd] — full cache, span rows inserted
    vcache: jax.Array,  # [S, KH, hd]
    start: jax.Array,  # [1] (or scalar) int32: absolute position of token 0
    *,
    block_q: int = 32,
    block_k: int = 128,
    interpret: bool = True,
) -> jax.Array:
    """Causal-over-history attention for one sequence's span: [T, H, hd]."""
    T, H, hd = q.shape
    S, KH = kcache.shape[0], kcache.shape[1]
    bq = min(block_q, T)
    bk = min(block_k, S)
    Tq = (T + bq - 1) // bq * bq
    Sk = (S + bk - 1) // bk * bk
    qp = jnp.pad(q, ((0, Tq - T), (0, 0), (0, 0)))
    # Padded KV slots sit at positions >= S > start + T - 1: always masked.
    kp = jnp.pad(kcache, ((0, Sk - S), (0, 0), (0, 0)))
    vp = jnp.pad(vcache, ((0, Sk - S), (0, 0), (0, 0)))
    start_arr = jnp.reshape(start, (1,)).astype(jnp.int32)
    out = pl.pallas_call(
        functools.partial(_kernel, block_q=bq, block_k=bk, n_heads=H),
        grid=(Tq // bq,),
        in_specs=[
            pl.BlockSpec((bq, H, hd), lambda i: (i, 0, 0)),
            pl.BlockSpec((Sk, KH, hd), lambda i: (0, 0, 0)),
            pl.BlockSpec((Sk, KH, hd), lambda i: (0, 0, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bq, H, hd), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((Tq, H, hd), q.dtype),
        interpret=interpret,
    )(qp, kp, vp, start_arr)
    return out[:T]
