"""Layer-2 JAX model: parallel and serial transformer variants.

Executable form of the paper's Figures 1 and 2:

  * ``decode_baseline`` / ``prefill_baseline`` — Figure 1(a) / 2(b):
    the full first layer computed from the embedding.
  * ``decode_precomp`` / ``prefill_precomp`` — Figure 1(b) / 2(c): the
    first layer's norm + Q/K/V (+ FFN and skip for parallel models)
    replaced by precomputed rows gathered from the table by the rust
    coordinator.

Row layout (shared with ``precompute.py`` and ``rust/src/precompute``):
  ``row = [ q (d) | k (e) | v (e) | r (d) ]``  — width ``2(d+e)``
where ``r`` is the residual carried past attention: ``emb + ffn_out``
for parallel models (the paper's "FFN and skip-connection"), plain
``emb`` for serial ones.

KV caches are passed in and returned updated (dynamic_update_slice at
slot ``lens[b]``), so the rust engine can keep them resident as PJRT
buffers across steps and only sync to its paged host store on preemption.

Everything here is traced once by ``aot.py`` and lowered to HLO text;
Python never runs at serving time.
"""

from __future__ import annotations

from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp

from . import kernels
from .configs import ModelConfig
from .kernels import ref

Weights = Dict[str, jax.Array]


# ---------------------------------------------------------------------------
# Building blocks
# ---------------------------------------------------------------------------


def _norm(cfg: ModelConfig, w: Weights, prefix: str, x: jax.Array) -> jax.Array:
    if cfg.norm_type == "rmsnorm":
        return ref.rmsnorm(x, w[f"{prefix}.scale"], cfg.norm_eps)
    return ref.layernorm(x, w[f"{prefix}.scale"], w[f"{prefix}.bias"], cfg.norm_eps)


def _norm_params(cfg: ModelConfig, w: Weights, prefix: str):
    scale = w[f"{prefix}.scale"]
    bias = w.get(f"{prefix}.bias", jnp.zeros_like(scale))
    return scale, bias


def _qkv(
    cfg: ModelConfig, w: Weights, i: int, x: jax.Array, use_pallas: bool
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Fused norm + packed QKV projection. x: [B, d]."""
    d, e = cfg.d, cfg.e
    scale, bias = _norm_params(cfg, w, f"l{i}.ln1")
    packed = jnp.concatenate([w[f"l{i}.wq"], w[f"l{i}.wk"], w[f"l{i}.wv"]], axis=1)
    if use_pallas:
        # §Perf CPU tuning: one grid program covers the whole (tiny) problem
        # — under interpret mode every grid step is a lowered loop iteration.
        y = kernels.fused_norm_matmul(
            x, scale, bias, packed, norm_type=cfg.norm_type, eps=cfg.norm_eps,
            block_b=max(8, x.shape[0]), block_n=min(packed.shape[1], 512),
        )
    else:
        xn = _norm(cfg, w, f"l{i}.ln1", x)
        y = xn @ packed
    return y[:, :d], y[:, d : d + e], y[:, d + e :]


def _ffn(
    cfg: ModelConfig, w: Weights, i: int, x: jax.Array, use_pallas: bool
) -> jax.Array:
    """FFN branch on pre-normalized input. x: [B, d]."""
    if cfg.ffn_type == "mlp":
        if use_pallas:
            return kernels.gelu_mlp_kernel(
                x, w[f"l{i}.w1"], w[f"l{i}.w2"],
                block_b=max(8, x.shape[0]),
                block_h=min(w[f"l{i}.w1"].shape[1], 512),
            )
        return ref.mlp(x, w[f"l{i}.w1"], w[f"l{i}.w2"])
    if cfg.ffn_type == "swiglu":
        if use_pallas:
            return kernels.swiglu_kernel(
                x, w[f"l{i}.w1"], w[f"l{i}.w3"], w[f"l{i}.w2"],
                block_b=max(8, x.shape[0]),
                block_h=min(w[f"l{i}.w1"].shape[1], 512),
            )
        return ref.swiglu(x, w[f"l{i}.w1"], w[f"l{i}.w3"], w[f"l{i}.w2"])
    # MoE: expert dispatch is an L2 (graph) concern; the per-expert GEMMs are
    # dense-masked (numerically identical to sparse dispatch, CPU-friendly).
    return ref.moe_swiglu(
        x,
        w[f"l{i}.router"],
        w[f"l{i}.w1"],
        w[f"l{i}.w3"],
        w[f"l{i}.w2"],
        cfg.moe_top_k,
    )


def _rope_pair(cfg, q, k, pos, use_pallas):
    """q: [B, H, hd], k: [B, KH, hd], pos: [B]."""
    if not cfg.rope:
        return q, k
    if use_pallas:
        return (
            kernels.rope_kernel(q, pos, theta=cfg.rope_theta),
            kernels.rope_kernel(k, pos, theta=cfg.rope_theta),
        )
    return (
        ref.rope_apply(q, pos, cfg.rope_theta),
        ref.rope_apply(k, pos, cfg.rope_theta),
    )


def _cache_insert(cache: jax.Array, rows: jax.Array, lens: jax.Array) -> jax.Array:
    """cache: [B, S, KH, hd]; rows: [B, KH, hd]; write at slot lens[b]."""
    B = cache.shape[0]

    def upd(c, r, l):
        return jax.lax.dynamic_update_slice(c, r[None], (l, 0, 0))

    return jax.vmap(upd)(cache, rows, lens)


def _attn_core(
    cfg: ModelConfig,
    w: Weights,
    i: int,
    q: jax.Array,  # [B, d] (pre-reshape)
    k: jax.Array,  # [B, e]
    v: jax.Array,  # [B, e]
    pos: jax.Array,  # [B] position of the new token (= old length)
    kcache: jax.Array,  # [B, S, KH, hd]
    vcache: jax.Array,
    use_pallas: bool,
):
    """Shared decode attention tail: rope, cache insert, attention, P-proj.

    Returns (attn_out [B, d], kcache', vcache').
    """
    B = q.shape[0]
    H, KH, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    qh = q.reshape(B, H, hd)
    kh = k.reshape(B, KH, hd)
    vh = v.reshape(B, KH, hd)
    qh, kh = _rope_pair(cfg, qh, kh, pos, use_pallas)
    kcache = _cache_insert(kcache, kh, pos)
    vcache = _cache_insert(vcache, vh, pos)
    lens = pos + 1  # the new token's slot is now valid
    if use_pallas:
        # §Perf CPU tuning: single KV chunk (inline, no while loop) and the
        # whole batch in one grid program.
        ctx = kernels.decode_attention(
            qh, kcache, vcache, lens,
            block_s=min(kcache.shape[1], 128), block_b=max(8, B),
        )
    else:
        ctx = ref.attention_decode(qh, kcache, vcache, lens)
    attn_out = ctx.reshape(B, cfg.d) @ w[f"l{i}.wp"]
    return attn_out, kcache, vcache


# ---------------------------------------------------------------------------
# Decode-step blocks
# ---------------------------------------------------------------------------


def block_decode(
    cfg: ModelConfig,
    w: Weights,
    i: int,
    x: jax.Array,  # [B, d]
    pos: jax.Array,  # [B]
    kcache: jax.Array,
    vcache: jax.Array,
    use_pallas: bool,
):
    """Full transformer block (baseline path), parallel or serial."""
    q, k, v = _qkv(cfg, w, i, x, use_pallas)
    attn_out, kcache, vcache = _attn_core(
        cfg, w, i, q, k, v, pos, kcache, vcache, use_pallas
    )
    if cfg.arch == "parallel":
        # GPT-NeoX parallel residual: x + attn(ln1 x) + ffn(ln2 x)
        ffn_out = _ffn(cfg, w, i, _norm(cfg, w, f"l{i}.ln2", x), use_pallas)
        x = x + attn_out + ffn_out
    else:
        h = x + attn_out
        x = h + _ffn(cfg, w, i, _norm(cfg, w, f"l{i}.ln2", h), use_pallas)
    return x, kcache, vcache


def block_decode_precomp(
    cfg: ModelConfig,
    w: Weights,
    rows: jax.Array,  # [B, 2(d+e)] gathered precomputed rows
    pos: jax.Array,
    kcache: jax.Array,
    vcache: jax.Array,
    use_pallas: bool,
):
    """First block with precompute (layer index 0): Figure 1(b) / 2(c).

    The projections (and for parallel models the FFN + skip) are already in
    ``rows``; only RoPE, attention and the P projection remain.
    """
    d, e = cfg.d, cfg.e
    q = rows[:, :d]
    k = rows[:, d : d + e]
    v = rows[:, d + e : d + 2 * e]
    r = rows[:, d + 2 * e :]
    attn_out, kcache, vcache = _attn_core(
        cfg, w, 0, q, k, v, pos, kcache, vcache, use_pallas
    )
    if cfg.arch == "parallel":
        x = r + attn_out  # r = emb + ffn_out (paper's precomputed skip)
    else:
        h = r + attn_out  # r = emb
        x = h + _ffn(cfg, w, 0, _norm(cfg, w, "l0.ln2", h), use_pallas)
    return x, kcache, vcache


# ---------------------------------------------------------------------------
# Decode entry points
# ---------------------------------------------------------------------------


def _logits(cfg: ModelConfig, w: Weights, x: jax.Array) -> jax.Array:
    return _norm(cfg, w, "lnf", x) @ w["unemb"]


def decode_baseline(
    cfg: ModelConfig,
    w: Weights,
    tokens: jax.Array,  # [B] int32
    pos: jax.Array,  # [B] int32 current length (slot for the new token)
    kcaches: jax.Array,  # [L, B, S, KH, hd]
    vcaches: jax.Array,
    use_pallas: bool = True,
):
    """One decode step, full first layer. Returns (logits, kcaches', vcaches')."""
    x = w["emb"][tokens]
    if not cfg.rope:
        x = x + w["abspe"][pos]
    kout, vout = [], []
    for i in range(cfg.n_layers):
        x, kc, vc = block_decode(
            cfg, w, i, x, pos, kcaches[i], vcaches[i], use_pallas
        )
        kout.append(kc)
        vout.append(vc)
    return _logits(cfg, w, x), jnp.stack(kout), jnp.stack(vout)


def decode_precomp(
    cfg: ModelConfig,
    w: Weights,
    rows: jax.Array,  # [B, 2(d+e)] rust-gathered precomputed rows
    pos: jax.Array,
    kcaches: jax.Array,
    vcaches: jax.Array,
    use_pallas: bool = True,
):
    """One decode step, precomputed first layer (the paper's trick)."""
    assert cfg.rope, "precompute requires RoPE (paper §2)"
    kout, vout = [], []
    x, kc, vc = block_decode_precomp(
        cfg, w, rows, pos, kcaches[0], vcaches[0], use_pallas
    )
    kout.append(kc)
    vout.append(vc)
    for i in range(1, cfg.n_layers):
        x, kc, vc = block_decode(
            cfg, w, i, x, pos, kcaches[i], vcaches[i], use_pallas
        )
        kout.append(kc)
        vout.append(vc)
    return _logits(cfg, w, x), jnp.stack(kout), jnp.stack(vout)


def decode_precomp_gather(
    cfg: ModelConfig,
    w: Weights,
    table: jax.Array,  # [V, 2(d+e)] precompute table as a device buffer
    tokens: jax.Array,  # [B]
    pos: jax.Array,
    kcaches: jax.Array,
    vcaches: jax.Array,
    use_pallas: bool = True,
):
    """Ablation: in-graph gather (Pallas kernel) instead of rust-side mmap."""
    if use_pallas:
        rows = kernels.gather_rows_kernel(table, tokens)
    else:
        rows = ref.gather_rows(table, tokens)
    return decode_precomp(cfg, w, rows, pos, kcaches, vcaches, use_pallas)


# ---------------------------------------------------------------------------
# Span step: T new tokens of ONE sequence against the existing KV history
# ---------------------------------------------------------------------------
#
# The batched span artifact: a chunked-prefill continuation (or preemption
# replay, prefix-cache suffix fill, chat turn delta) advances ``T`` tokens
# in ONE execution instead of ``T`` single-token decode dispatches.  The
# cache keeps the decode layout ``[L, 1, S, KH, hd]`` so the rust engine
# can chain the output cache buffers through a ``DeviceCacheSession``
# exactly like decode steps.  Ragged spans are padded up to the compiled
# bucket: padding rows write garbage K/V at slots past the valid frontier,
# which the causal-over-history mask keeps invisible to every valid token
# and the next tile (or nothing) overwrites.


def _span_attn_core(
    cfg: ModelConfig,
    w: Weights,
    i: int,
    q: jax.Array,  # [T, d]
    k: jax.Array,  # [T, e]
    v: jax.Array,  # [T, e]
    start,  # scalar int32: absolute position of span token 0
    kcache: jax.Array,  # [1, S, KH, hd]
    vcache: jax.Array,
    use_pallas: bool,
):
    """Span attention tail: RoPE at start+t, contiguous cache insert,
    causal-over-history attention, P projection.

    Returns (attn_out [T, d], kcache', vcache', k_rows, v_rows) where
    k_rows/v_rows are the span's fresh (post-RoPE) rows [T, KH, hd].
    """
    T = q.shape[0]
    H, KH, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    qh = q.reshape(T, H, hd)
    kh = k.reshape(T, KH, hd)
    vh = v.reshape(T, KH, hd)
    pos = start + jnp.arange(T, dtype=jnp.int32)
    qh, kh = _rope_pair(cfg, qh, kh, pos, use_pallas)
    # The span's slots are contiguous: ONE dynamic_update_slice per cache.
    zero = jnp.int32(0)
    kcache = jax.lax.dynamic_update_slice(kcache, kh[None], (zero, start, zero, zero))
    vcache = jax.lax.dynamic_update_slice(vcache, vh[None], (zero, start, zero, zero))
    if use_pallas:
        ctx = kernels.span_attention_kernel(qh, kcache[0], vcache[0], start)
    else:
        ctx = ref.attention_span(qh, kcache[0], vcache[0], start)
    attn_out = ctx.reshape(T, cfg.d) @ w[f"l{i}.wp"]
    return attn_out, kcache, vcache, kh, vh


def block_span(
    cfg: ModelConfig,
    w: Weights,
    i: int,
    x: jax.Array,  # [T, d]
    start,
    kcache: jax.Array,
    vcache: jax.Array,
    use_pallas: bool,
):
    """Full transformer block over a span (baseline path)."""
    q, k, v = _qkv(cfg, w, i, x, use_pallas)
    attn_out, kcache, vcache, kr, vr = _span_attn_core(
        cfg, w, i, q, k, v, start, kcache, vcache, use_pallas
    )
    if cfg.arch == "parallel":
        ffn_out = _ffn(cfg, w, i, _norm(cfg, w, f"l{i}.ln2", x), use_pallas)
        x = x + attn_out + ffn_out
    else:
        h = x + attn_out
        x = h + _ffn(cfg, w, i, _norm(cfg, w, f"l{i}.ln2", h), use_pallas)
    return x, kcache, vcache, kr, vr


def block_span_precomp(
    cfg: ModelConfig,
    w: Weights,
    rows: jax.Array,  # [T, 2(d+e)] gathered precomputed rows
    start,
    kcache: jax.Array,
    vcache: jax.Array,
    use_pallas: bool,
):
    """First span block with precompute: the batched table rows feed the
    span exactly like the single-token gather feeds decode."""
    d, e = cfg.d, cfg.e
    q = rows[:, :d]
    k = rows[:, d : d + e]
    v = rows[:, d + e : d + 2 * e]
    r = rows[:, d + 2 * e :]
    attn_out, kcache, vcache, kr, vr = _span_attn_core(
        cfg, w, 0, q, k, v, start, kcache, vcache, use_pallas
    )
    if cfg.arch == "parallel":
        x = r + attn_out  # r = emb + ffn_out (precomputed skip)
    else:
        h = r + attn_out  # r = emb
        x = h + _ffn(cfg, w, 0, _norm(cfg, w, "l0.ln2", h), use_pallas)
    return x, kcache, vcache, kr, vr


def _span_outputs(cfg, w, x, kout, vout, krows, vrows):
    """Shared span epilogue: logits at EVERY span position plus the fresh
    K/V rows in the token-major [T, L, KH, hd] layout the rust paged-store
    writeback expects (`SpanOut::new_k`)."""
    logits = _logits(cfg, w, x)  # [T, V]
    new_k = jnp.stack(krows).transpose(1, 0, 2, 3)  # [L,T,..] -> [T,L,KH,hd]
    new_v = jnp.stack(vrows).transpose(1, 0, 2, 3)
    return logits, jnp.stack(kout), jnp.stack(vout), new_k, new_v


def decode_span_baseline(
    cfg: ModelConfig,
    w: Weights,
    tokens: jax.Array,  # [T] int32 span tokens
    start: jax.Array,  # [1] int32 absolute position of tokens[0]
    kcaches: jax.Array,  # [L, 1, S, KH, hd]
    vcaches: jax.Array,
    use_pallas: bool = True,
):
    """Advance one sequence through T tokens in a single execution.

    Returns (logits [T, V], kcaches', vcaches', new_k [T, L, KH, hd],
    new_v) — the caches for device buffer chaining, the fresh rows for
    selective readback (the host never needs a full-pair sync).
    """
    s0 = start[0]
    x = w["emb"][tokens]  # [T, d]
    if not cfg.rope:
        T = tokens.shape[0]
        x = x + w["abspe"][s0 + jnp.arange(T, dtype=jnp.int32)]
    kout, vout, krows, vrows = [], [], [], []
    for i in range(cfg.n_layers):
        x, kc, vc, kr, vr = block_span(
            cfg, w, i, x, s0, kcaches[i], vcaches[i], use_pallas
        )
        kout.append(kc)
        vout.append(vc)
        krows.append(kr)
        vrows.append(vr)
    return _span_outputs(cfg, w, x, kout, vout, krows, vrows)


def decode_span_precomp(
    cfg: ModelConfig,
    w: Weights,
    rows: jax.Array,  # [T, 2(d+e)] rust-gathered precomputed rows
    start: jax.Array,  # [1] int32
    kcaches: jax.Array,
    vcaches: jax.Array,
    use_pallas: bool = True,
):
    """Batched-span step with the precomputed first layer: the whole
    span's table rows arrive in one gather (the paper's `len·2(d+e)`
    read) and one execution covers layers 1..L."""
    assert cfg.rope, "precompute requires RoPE (paper §2)"
    s0 = start[0]
    kout, vout, krows, vrows = [], [], [], []
    x, kc, vc, kr, vr = block_span_precomp(
        cfg, w, rows, s0, kcaches[0], vcaches[0], use_pallas
    )
    kout.append(kc)
    vout.append(vc)
    krows.append(kr)
    vrows.append(vr)
    for i in range(1, cfg.n_layers):
        x, kc, vc, kr, vr = block_span(
            cfg, w, i, x, s0, kcaches[i], vcaches[i], use_pallas
        )
        kout.append(kc)
        vout.append(vc)
        krows.append(kr)
        vrows.append(vr)
    return _span_outputs(cfg, w, x, kout, vout, krows, vrows)


# ---------------------------------------------------------------------------
# Multi-sequence span step: T tokens of EACH of B sequences in one execution
# ---------------------------------------------------------------------------
#
# The [B, T] span artifact (Prepacking, arxiv 2404.09529, applied to
# continuation spans): B independent sequences advance through up to T
# tokens each in ONE device execution, amortizing one weight-stream read
# across every occupied lane.  Each lane carries its own cache row, start
# position and valid length; lanes with ``lens[b] < T`` have their ragged
# tail masked per row, and unoccupied lanes (``lens[b] == 0``) are fully
# inert — their attention output is exactly zero and their (garbage)
# logits and cache writes are discarded by the rust engine.  ``B == 1``
# with ``lens = [T]`` reproduces decode_span_* numerics.


def _span_attn_core_batched(
    cfg: ModelConfig,
    w: Weights,
    i: int,
    q: jax.Array,  # [B, T, d]
    k: jax.Array,  # [B, T, e]
    v: jax.Array,  # [B, T, e]
    starts: jax.Array,  # [B] int32: per-lane absolute position of token 0
    lens: jax.Array,  # [B] int32: per-lane valid span tokens
    kcache: jax.Array,  # [B, S, KH, hd]
    vcache: jax.Array,
    use_pallas: bool,
):
    """Batched span attention tail: per-lane RoPE at starts[b]+t, one
    contiguous cache insert per lane, masked causal-over-history
    attention, P projection.

    Returns (attn_out [B, T, d], kcache', vcache', k_rows, v_rows) with
    k_rows/v_rows the fresh post-RoPE rows [B, T, KH, hd].
    """
    B, T = q.shape[0], q.shape[1]
    H, KH, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    qh = q.reshape(B * T, H, hd)
    kh = k.reshape(B * T, KH, hd)
    vh = v.reshape(B, T, KH, hd)
    pos = starts[:, None] + jnp.arange(T, dtype=jnp.int32)[None, :]  # [B, T]
    qh, kh = _rope_pair(cfg, qh, kh, pos.reshape(B * T), use_pallas)
    qh = qh.reshape(B, T, H, hd)
    kh = kh.reshape(B, T, KH, hd)

    # Each lane's slots are contiguous: one dynamic_update_slice per lane.
    def ins(c, r, s):
        return jax.lax.dynamic_update_slice(c, r, (s, jnp.int32(0), jnp.int32(0)))

    kcache = jax.vmap(ins)(kcache, kh, starts)
    vcache = jax.vmap(ins)(vcache, vh, starts)
    if use_pallas:
        ctx = kernels.span_attention_batched_kernel(qh, kcache, vcache, starts, lens)
    else:
        ctx = ref.attention_span_batched(qh, kcache, vcache, starts, lens)
    attn_out = ctx.reshape(B, T, cfg.d) @ w[f"l{i}.wp"]
    return attn_out, kcache, vcache, kh, vh


def block_span_batched(
    cfg: ModelConfig,
    w: Weights,
    i: int,
    x: jax.Array,  # [B, T, d]
    starts: jax.Array,
    lens: jax.Array,
    kcache: jax.Array,
    vcache: jax.Array,
    use_pallas: bool,
):
    """Full transformer block over a lane batch of spans (baseline path)."""
    B, T, d = x.shape
    q, k, v = _qkv(cfg, w, i, x.reshape(B * T, d), use_pallas)
    attn_out, kcache, vcache, kr, vr = _span_attn_core_batched(
        cfg, w, i,
        q.reshape(B, T, -1), k.reshape(B, T, -1), v.reshape(B, T, -1),
        starts, lens, kcache, vcache, use_pallas,
    )
    if cfg.arch == "parallel":
        ffn_out = _ffn(
            cfg, w, i, _norm(cfg, w, f"l{i}.ln2", x).reshape(B * T, d), use_pallas
        ).reshape(B, T, d)
        x = x + attn_out + ffn_out
    else:
        h = x + attn_out
        x = h + _ffn(
            cfg, w, i, _norm(cfg, w, f"l{i}.ln2", h).reshape(B * T, d), use_pallas
        ).reshape(B, T, d)
    return x, kcache, vcache, kr, vr


def block_span_batched_precomp(
    cfg: ModelConfig,
    w: Weights,
    rows: jax.Array,  # [B, T, 2(d+e)] gathered precomputed rows
    starts: jax.Array,
    lens: jax.Array,
    kcache: jax.Array,
    vcache: jax.Array,
    use_pallas: bool,
):
    """First batched-span block with precompute: every lane's table rows
    arrive pre-gathered, so layer 0 is RoPE + attention + P only."""
    d, e = cfg.d, cfg.e
    B, T = rows.shape[0], rows.shape[1]
    q = rows[..., :d]
    k = rows[..., d : d + e]
    v = rows[..., d + e : d + 2 * e]
    r = rows[..., d + 2 * e :]
    attn_out, kcache, vcache, kr, vr = _span_attn_core_batched(
        cfg, w, 0, q, k, v, starts, lens, kcache, vcache, use_pallas
    )
    if cfg.arch == "parallel":
        x = r + attn_out  # r = emb + ffn_out (precomputed skip)
    else:
        h = r + attn_out  # r = emb
        x = h + _ffn(
            cfg, w, 0, _norm(cfg, w, "l0.ln2", h).reshape(B * T, d), use_pallas
        ).reshape(B, T, d)
    return x, kcache, vcache, kr, vr


def _span_outputs_batched(cfg, w, x, kout, vout, krows, vrows):
    """Batched span epilogue: logits at every lane position plus the fresh
    K/V rows in the lane-then-token-major [B, T, L, KH, hd] layout the
    rust selective readback slices per lane."""
    logits = _logits(cfg, w, x)  # [B, T, V]
    new_k = jnp.stack(krows).transpose(1, 2, 0, 3, 4)  # [L,B,T,..] -> [B,T,L,..]
    new_v = jnp.stack(vrows).transpose(1, 2, 0, 3, 4)
    return logits, jnp.stack(kout), jnp.stack(vout), new_k, new_v


def decode_span_batched_baseline(
    cfg: ModelConfig,
    w: Weights,
    tokens: jax.Array,  # [B, T] int32, per-lane span tokens (padded)
    starts: jax.Array,  # [B] int32 per-lane absolute position of token 0
    lens: jax.Array,  # [B] int32 per-lane valid lengths (0 = inert lane)
    kcaches: jax.Array,  # [L, B, S, KH, hd]
    vcaches: jax.Array,
    use_pallas: bool = True,
):
    """Advance B sequences through up to T tokens each in ONE execution.

    Returns (logits [B, T, V], kcaches', vcaches', new_k [B, T, L, KH,
    hd], new_v).  Occupied lanes match decode_span_baseline run per lane;
    inert and ragged-tail positions produce discardable values without
    touching any occupied lane's numerics.
    """
    B, T = tokens.shape
    x = w["emb"][tokens]  # [B, T, d]
    if not cfg.rope:
        pos = starts[:, None] + jnp.arange(T, dtype=jnp.int32)[None, :]
        x = x + w["abspe"][pos]
    kout, vout, krows, vrows = [], [], [], []
    for i in range(cfg.n_layers):
        x, kc, vc, kr, vr = block_span_batched(
            cfg, w, i, x, starts, lens, kcaches[i], vcaches[i], use_pallas
        )
        kout.append(kc)
        vout.append(vc)
        krows.append(kr)
        vrows.append(vr)
    return _span_outputs_batched(cfg, w, x, kout, vout, krows, vrows)


def decode_span_batched_precomp(
    cfg: ModelConfig,
    w: Weights,
    rows: jax.Array,  # [B, T, 2(d+e)] rust-gathered precomputed rows
    starts: jax.Array,  # [B] int32
    lens: jax.Array,  # [B] int32
    kcaches: jax.Array,
    vcaches: jax.Array,
    use_pallas: bool = True,
):
    """Multi-sequence span step with the precomputed first layer: one
    table gather per lane feeds layer 0, one execution covers all lanes
    and all layers — the weight stream is read once for the whole group."""
    assert cfg.rope, "precompute requires RoPE (paper §2)"
    kout, vout, krows, vrows = [], [], [], []
    x, kc, vc, kr, vr = block_span_batched_precomp(
        cfg, w, rows, starts, lens, kcaches[0], vcaches[0], use_pallas
    )
    kout.append(kc)
    vout.append(vc)
    krows.append(kr)
    vrows.append(vr)
    for i in range(1, cfg.n_layers):
        x, kc, vc, kr, vr = block_span_batched(
            cfg, w, i, x, starts, lens, kcaches[i], vcaches[i], use_pallas
        )
        kout.append(kc)
        vout.append(vc)
        krows.append(kr)
        vrows.append(vr)
    return _span_outputs_batched(cfg, w, x, kout, vout, krows, vrows)


# ---------------------------------------------------------------------------
# Prefill (batched prompt processing, causal)
# ---------------------------------------------------------------------------


def _prefill_qkv(cfg, w, i, x, use_pallas):
    """x: [B, T, d] -> q [B,T,H,hd], k,v [B,T,KH,hd] (norm+proj, no rope)."""
    B, T, d = x.shape
    q, k, v = _qkv(cfg, w, i, x.reshape(B * T, d), use_pallas)
    return (
        q.reshape(B, T, cfg.n_heads, cfg.head_dim),
        k.reshape(B, T, cfg.n_kv_heads, cfg.head_dim),
        v.reshape(B, T, cfg.n_kv_heads, cfg.head_dim),
    )


def _prefill_rope(cfg, q, k, T):
    if not cfg.rope:
        return q, k
    pos = jnp.arange(T, dtype=jnp.int32)
    # vmap the decode rope over the time axis: [B,T,H,hd] with pos [T]
    rq = jax.vmap(lambda xt, p: ref.rope_apply(xt, p, cfg.rope_theta), (1, 0), 1)
    return rq(q, pos), rq(k, pos)


def _prefill_attn(q, k, v, lens, use_pallas):
    """Causal attention: Pallas flash kernel or the jnp oracle."""
    if use_pallas:
        from .kernels.prefill_attention import prefill_attention

        T = q.shape[1]
        return prefill_attention(
            q, k, v, lens, block_q=min(T, 32), block_k=min(T, 32)
        )
    return ref.attention_prefill(q, k, v, lens)


def _block_prefill_tail(cfg, w, i, x, q, k, v, lens, use_pallas):
    """Attention + residual/FFN for a prefill block. x: [B, T, d]."""
    B, T, _ = x.shape
    ctx = _prefill_attn(q, k, v, lens, use_pallas)  # [B, T, H, hd]
    attn_out = ctx.reshape(B, T, cfg.d) @ w[f"l{i}.wp"]
    if cfg.arch == "parallel":
        ffn_out = _ffn(
            cfg, w, i, _norm(cfg, w, f"l{i}.ln2", x).reshape(B * T, cfg.d), use_pallas
        ).reshape(B, T, cfg.d)
        return x + attn_out + ffn_out
    h = x + attn_out
    ffn_out = _ffn(
        cfg, w, i, _norm(cfg, w, f"l{i}.ln2", h).reshape(B * T, cfg.d), use_pallas
    ).reshape(B, T, cfg.d)
    return h + ffn_out


def prefill(
    cfg: ModelConfig,
    w: Weights,
    tokens: jax.Array,  # [B, T] int32, padded
    lens: jax.Array,  # [B] valid lengths
    rows: jax.Array | None = None,  # [B, T, 2(d+e)] for the precomp path
    use_pallas: bool = True,
    max_seq: int | None = None,
):
    """Process a padded prompt batch.

    Returns (last_logits [B, V], kcaches [L, B, S, KH, hd], vcaches).
    Cache slots beyond lens[b] contain padding garbage; the scheduler
    tracks validity via lens.
    """
    B, T = tokens.shape
    S = max_seq or cfg.max_seq
    precomp = rows is not None
    if precomp:
        assert cfg.rope, "precompute requires RoPE (paper §2)"
        d, e = cfg.d, cfg.e
        x = None  # layer 0 consumes rows; no embedding lookup at all
    else:
        x = w["emb"][tokens]  # [B, T, d]
        if not cfg.rope:
            x = x + w["abspe"][jnp.arange(T)][None]
    kcaches, vcaches = [], []
    for i in range(cfg.n_layers):
        if i == 0 and precomp:
            q = rows[..., :d].reshape(B, T, cfg.n_heads, cfg.head_dim)
            k = rows[..., d : d + e].reshape(B, T, cfg.n_kv_heads, cfg.head_dim)
            v = rows[..., d + e : d + 2 * e].reshape(
                B, T, cfg.n_kv_heads, cfg.head_dim
            )
            r = rows[..., d + 2 * e :]  # [B, T, d]
            q, k = _prefill_rope(cfg, q, k, T)
            ctx = _prefill_attn(q, k, v, lens, use_pallas)
            attn_out = ctx.reshape(B, T, cfg.d) @ w["l0.wp"]
            if cfg.arch == "parallel":
                x = r + attn_out
            else:
                h = r + attn_out
                ffn_out = _ffn(
                    cfg, w, 0, _norm(cfg, w, "l0.ln2", h).reshape(B * T, cfg.d),
                    use_pallas,
                ).reshape(B, T, cfg.d)
                x = h + ffn_out
        else:
            q, k, v = _prefill_qkv(cfg, w, i, x, use_pallas)
            q, k = _prefill_rope(cfg, q, k, T)
            x = _block_prefill_tail(cfg, w, i, x, q, k, v, lens, use_pallas)
        # Store this layer's K/V (padded out to S slots).
        pad = ((0, 0), (0, S - T), (0, 0), (0, 0))
        kcaches.append(jnp.pad(k, pad))
        vcaches.append(jnp.pad(v, pad))
    # Logits at the last valid position of each sequence.
    xl = jnp.take_along_axis(x, (lens - 1)[:, None, None], axis=1)[:, 0]
    return _logits(cfg, w, xl), jnp.stack(kcaches), jnp.stack(vcaches)


# ---------------------------------------------------------------------------
# Weight plumbing for AOT: flat parameter lists
# ---------------------------------------------------------------------------


def weight_order_baseline(cfg: ModelConfig) -> List[str]:
    """Parameter order for baseline artifacts = canonical .fw order."""
    from .params import tensor_names

    return tensor_names(cfg)


def weight_order_precomp(cfg: ModelConfig) -> List[str]:
    """Precomp artifacts drop the weights the paper eliminates.

    Serial: l0.{ln1, wq, wk, wv}.  Parallel: additionally the entire l0
    FFN branch (ln2, w1/w3/w2/router).  ``emb`` is retained only when the
    serial FFN needs... no — emb is never needed: baseline embeds in-graph,
    precomp gets ``r`` in the row.  BUT the *unembedding* is always kept,
    and serial models still need l0.ln2 + FFN.
    """
    drop = {"l0.ln1.scale", "l0.ln1.bias", "l0.wq", "l0.wk", "l0.wv", "emb"}
    if cfg.arch == "parallel":
        drop |= {
            "l0.ln2.scale",
            "l0.ln2.bias",
            "l0.w1",
            "l0.w2",
            "l0.w3",
            "l0.router",
        }
    return [n for n in weight_order_baseline(cfg) if n not in drop]


def eliminated_weights(cfg: ModelConfig) -> List[str]:
    """Names of tensors removed from serving memory by the trick
    (paper: 'Number of weights that can be eliminated'). ``emb`` is
    *replaced* by the table, not eliminated, so it is not listed here."""
    base = set(weight_order_baseline(cfg)) - {"emb"}
    kept = set(weight_order_precomp(cfg))
    return sorted(base - kept)
