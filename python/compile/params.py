"""Deterministic weight initialization + the ``.fw`` tensor-bag format.

Weights are generated once at build time from a fixed seed (substitution
for public checkpoints — see DESIGN.md §7) and written to
``artifacts/weights_<model>.fw`` so the rust runtime can upload them as
PJRT buffers without any Python in the loop.

``.fw`` layout (little-endian):
  magic   b"FLW1"
  u32     n_tensors
  per tensor:
    u32   name_len, utf-8 name
    u32   ndim, u64 dims[ndim]
    u32   dtype (0 = f32, 1 = i32)
    u64   nbytes, raw data

Canonical tensor names (order matters — it is the artifact parameter
order, mirrored by ``rust/src/model/weights.rs``):
  emb, [abspe,] l{i}.ln1.scale[, l{i}.ln1.bias], l{i}.wq, l{i}.wk,
  l{i}.wv, l{i}.wp, l{i}.ln2.scale[, .bias], l{i}.{w1,w3,w2,router},
  lnf.scale[, lnf.bias], unemb
"""

from __future__ import annotations

import struct
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from .configs import ModelConfig

DT_F32, DT_I32 = 0, 1
MAGIC = b"FLW1"


def layer_tensor_names(cfg: ModelConfig, i: int) -> List[str]:
    """Canonical per-layer tensor name order."""
    names = [f"l{i}.ln1.scale"]
    if cfg.norm_type == "layernorm":
        names.append(f"l{i}.ln1.bias")
    names += [f"l{i}.wq", f"l{i}.wk", f"l{i}.wv", f"l{i}.wp", f"l{i}.ln2.scale"]
    if cfg.norm_type == "layernorm":
        names.append(f"l{i}.ln2.bias")
    if cfg.ffn_type == "mlp":
        names += [f"l{i}.w1", f"l{i}.w2"]
    elif cfg.ffn_type == "swiglu":
        names += [f"l{i}.w1", f"l{i}.w3", f"l{i}.w2"]
    else:  # swiglu_moe
        names += [f"l{i}.router", f"l{i}.w1", f"l{i}.w3", f"l{i}.w2"]
    return names


def tensor_names(cfg: ModelConfig) -> List[str]:
    """Canonical full tensor name order for a model."""
    names = ["emb"]
    if not cfg.rope:
        names.append("abspe")
    for i in range(cfg.n_layers):
        names += layer_tensor_names(cfg, i)
    names += ["lnf.scale"]
    if cfg.norm_type == "layernorm":
        names.append("lnf.bias")
    names.append("unemb")
    return names


def tensor_shape(cfg: ModelConfig, name: str):
    d, e, h, V = cfg.d, cfg.e, cfg.ffn_hidden, cfg.vocab_size
    E = cfg.n_experts
    if name == "emb":
        return (V, d)
    if name == "abspe":
        return (cfg.max_seq, d)
    if name == "unemb":
        return (d, V)
    if name.startswith("lnf"):
        return (d,)
    # layer tensors: l{i}.<rest>
    rest = name.split(".", 1)[1]
    if rest.startswith("ln"):
        return (d,)
    if rest == "wq":
        return (d, d)
    if rest in ("wk", "wv"):
        return (d, e)
    if rest == "wp":
        return (d, d)
    if rest == "router":
        return (d, E)
    if cfg.ffn_type == "swiglu_moe":
        return {"w1": (E, d, h), "w3": (E, d, h), "w2": (E, h, d)}[rest]
    return {"w1": (d, h), "w3": (d, h), "w2": (h, d)}[rest]


def init_weights(cfg: ModelConfig, seed: int = 1234) -> Dict[str, jax.Array]:
    """GPT-2-style init: N(0, 0.02), output projections scaled by 1/sqrt(2L)."""
    key = jax.random.PRNGKey(seed)
    out: Dict[str, jax.Array] = {}
    resid_scale = 1.0 / np.sqrt(2.0 * cfg.n_layers)
    for name in tensor_names(cfg):
        shape = tensor_shape(cfg, name)
        key, sub = jax.random.split(key)
        if name.endswith(".scale"):
            t = jnp.ones(shape, jnp.float32)
        elif name.endswith(".bias"):
            t = jnp.zeros(shape, jnp.float32)
        else:
            t = 0.02 * jax.random.normal(sub, shape, jnp.float32)
            rest = name.split(".", 1)[-1]
            if rest in ("wp", "w2"):
                t = t * resid_scale
        out[name] = t
    return out


# ---------------------------------------------------------------------------
# .fw serialization
# ---------------------------------------------------------------------------


def save_fw(path: str, weights: Dict[str, jax.Array], order: List[str]) -> None:
    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(struct.pack("<I", len(order)))
        for name in order:
            arr = np.asarray(weights[name])
            if arr.dtype == np.float32:
                dt = DT_F32
            elif arr.dtype == np.int32:
                dt = DT_I32
            else:
                raise ValueError(f"{name}: unsupported dtype {arr.dtype}")
            nb = name.encode()
            f.write(struct.pack("<I", len(nb)))
            f.write(nb)
            f.write(struct.pack("<I", arr.ndim))
            for dim in arr.shape:
                f.write(struct.pack("<Q", dim))
            raw = arr.tobytes()
            f.write(struct.pack("<I", dt))
            f.write(struct.pack("<Q", len(raw)))
            f.write(raw)


def load_fw(path: str) -> Dict[str, np.ndarray]:
    out: Dict[str, np.ndarray] = {}
    with open(path, "rb") as f:
        assert f.read(4) == MAGIC, "bad magic"
        (n,) = struct.unpack("<I", f.read(4))
        for _ in range(n):
            (nl,) = struct.unpack("<I", f.read(4))
            name = f.read(nl).decode()
            (nd,) = struct.unpack("<I", f.read(4))
            dims = [struct.unpack("<Q", f.read(8))[0] for _ in range(nd)]
            (dt,) = struct.unpack("<I", f.read(4))
            (nb,) = struct.unpack("<Q", f.read(8))
            raw = f.read(nb)
            dtype = np.float32 if dt == DT_F32 else np.int32
            out[name] = np.frombuffer(raw, dtype=dtype).reshape(dims).copy()
    return out


def fingerprint(weights: Dict[str, jax.Array], names: List[str]) -> int:
    """CRC32 chained over the raw bytes of the named tensors (integrity tag
    that ties a precompute table to the weights it was built from).
    Mirrored by ``rust/src/precompute/table.rs`` via the crc32fast crate."""
    import zlib

    crc = 0
    for name in names:
        crc = zlib.crc32(np.asarray(weights[name]).tobytes(), crc)
    return crc & 0xFFFFFFFF
