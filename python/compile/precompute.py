"""Offline first-layer precompute: the paper's contribution (S3).

For every token in the vocabulary, run the parts of the first layer that
depend only on the embedding and store the results as one table row:

  serial   (Fig. 2c):  row = [ Q(n(emb)) | K(n(emb)) | V(n(emb)) | emb ]
  parallel (Fig. 1b):  row = [ Q(n1(emb)) | K(n1(emb)) | V(n1(emb)) |
                               emb + FFN(n2(emb)) ]

RoPE is NOT applied — it depends on the position and is done at serving
time on the gathered row.  Row width is ``2(d+e)`` in both cases.

The ``.fpt`` on-disk format (little-endian), mmap'd by
``rust/src/precompute/table.rs`` — the normative byte-level spec lives
in ``docs/fpt-format.md``; keep writer, reader, and spec in lockstep:

  magic    b"FPT1"
  u32      version (1)
  u32      arch (0 = parallel, 1 = serial)
  u32      d, u32 e, u32 vocab_size
  u32      dtype (0 = f32)
  u64      row_width (= 2(d+e))
  u32      weights_crc (CRC32 over the layer-0 tensors used, canonical order)
  u32      reserved (0)
  data     vocab_size * row_width * 4 bytes, row-major
"""

from __future__ import annotations

import struct
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from . import kernels
from .configs import ModelConfig
from .kernels import ref
from .model import _ffn, _norm  # shared definitions: single source of truth
from .params import fingerprint

MAGIC = b"FPT1"
VERSION = 1
HEADER_FMT = "<4sIIIIIIQII"  # magic, ver, arch, d, e, vocab, dtype, width, crc, rsvd
HEADER_SIZE = struct.calcsize(HEADER_FMT)


def source_tensor_names(cfg: ModelConfig) -> List[str]:
    """Tensors the table derives from (the CRC fingerprint input)."""
    names = ["emb", "l0.ln1.scale"]
    if cfg.norm_type == "layernorm":
        names.append("l0.ln1.bias")
    names += ["l0.wq", "l0.wk", "l0.wv"]
    if cfg.arch == "parallel":
        names.append("l0.ln2.scale")
        if cfg.norm_type == "layernorm":
            names.append("l0.ln2.bias")
        if cfg.ffn_type == "swiglu_moe":
            names.append("l0.router")
        names.append("l0.w1")
        if cfg.ffn_type != "mlp":
            names.append("l0.w3")
        names.append("l0.w2")
    return names


def build_rows(
    cfg: ModelConfig,
    w: Dict[str, jax.Array],
    tokens: jax.Array | None = None,
    use_pallas: bool = True,
    batch: int = 256,
) -> jax.Array:
    """Compute precomputed rows for ``tokens`` (default: whole vocabulary).

    Returns [n, 2(d+e)] f32.  Batched over the vocab so the FFN of large
    parallel models never materializes [V, hidden] at once.
    """
    assert cfg.rope, "precompute requires RoPE (paper §2)"
    if tokens is None:
        tokens = jnp.arange(cfg.vocab_size, dtype=jnp.int32)
    emb = w["emb"][tokens]  # [n, d]
    outs = []
    for s in range(0, emb.shape[0], batch):
        x = emb[s : s + batch]
        scale = w["l0.ln1.scale"]
        bias = w.get("l0.ln1.bias", jnp.zeros_like(scale))
        packed = jnp.concatenate([w["l0.wq"], w["l0.wk"], w["l0.wv"]], axis=1)
        if use_pallas:
            qkv = kernels.fused_norm_matmul(
                x, scale, bias, packed, norm_type=cfg.norm_type, eps=cfg.norm_eps
            )
        else:
            xn = (
                ref.rmsnorm(x, scale, cfg.norm_eps)
                if cfg.norm_type == "rmsnorm"
                else ref.layernorm(x, scale, bias, cfg.norm_eps)
            )
            qkv = xn @ packed
        if cfg.arch == "parallel":
            r = x + _ffn(cfg, w, 0, _norm(cfg, w, "l0.ln2", x), use_pallas)
        else:
            r = x
        outs.append(jnp.concatenate([qkv, r], axis=1))
    return jnp.concatenate(outs, axis=0)


def save_fpt(path: str, cfg: ModelConfig, rows: jax.Array, crc: int) -> None:
    arr = np.asarray(rows, dtype=np.float32)
    V, W = arr.shape
    assert V == cfg.vocab_size and W == cfg.precomp_row_width
    with open(path, "wb") as f:
        f.write(
            struct.pack(
                HEADER_FMT,
                MAGIC,
                VERSION,
                0 if cfg.arch == "parallel" else 1,
                cfg.d,
                cfg.e,
                cfg.vocab_size,
                0,
                W,
                crc & 0xFFFFFFFF,
                0,
            )
        )
        f.write(arr.tobytes())


def load_fpt(path: str):
    """Returns (header dict, rows ndarray [V, W])."""
    with open(path, "rb") as f:
        hdr = struct.unpack(HEADER_FMT, f.read(HEADER_SIZE))
        magic, ver, arch, d, e, vocab, dtype, width, crc, _ = hdr
        assert magic == MAGIC and ver == VERSION and dtype == 0
        data = np.frombuffer(f.read(vocab * width * 4), dtype=np.float32)
    return (
        dict(arch=arch, d=d, e=e, vocab=vocab, width=width, crc=crc),
        data.reshape(vocab, width).copy(),
    )


def build_table(cfg: ModelConfig, w: Dict[str, jax.Array], path: str) -> int:
    """Build + persist the table; returns the weights CRC."""
    rows = build_rows(cfg, w)
    crc = fingerprint(w, source_tensor_names(cfg))
    save_fpt(path, cfg, rows, crc)
    return crc
