"""AOT pipeline tests: HLO-text lowering + manifest integrity.

The heavyweight end-to-end check (rust loads the artifact and numerics
match) lives in rust/tests; here we verify the python side: lowering
round-trips through the HLO text printer, and the manifest is internally
consistent with the emitted files.
"""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, configs, model, params

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_to_hlo_text_smoke():
    def fn(x, y):
        return (x @ y + 1.0,)

    spec = jax.ShapeDtypeStruct((2, 2), jnp.float32)
    text = aot.to_hlo_text(jax.jit(fn).lower(spec, spec))
    assert "HloModule" in text
    assert "ENTRY" in text


def test_decode_artifact_lowering_has_no_l0_qkv_matmul():
    """E6/§Perf structural check: the precomp decode graph must contain
    fewer dot ops than baseline — the first layer's Q/K/V (and FFN for
    parallel models) matmuls are gone."""
    for name in ["tiny-serial", "tiny-parallel"]:
        cfg = configs.get(name)
        worder_b = model.weight_order_baseline(cfg)
        worder_p = model.weight_order_precomp(cfg)
        B, S = 1, cfg.max_seq
        L, KH, hd = cfg.n_layers, cfg.n_kv_heads, cfg.head_dim
        cache = jax.ShapeDtypeStruct((L, B, S, KH, hd), jnp.float32)
        ws_b = [
            jax.ShapeDtypeStruct(params.tensor_shape(cfg, n), jnp.float32)
            for n in worder_b
        ]
        ws_p = [
            jax.ShapeDtypeStruct(params.tensor_shape(cfg, n), jnp.float32)
            for n in worder_p
        ]
        ti = jax.ShapeDtypeStruct((B,), jnp.int32)
        rows = jax.ShapeDtypeStruct((B, cfg.precomp_row_width), jnp.float32)

        def fb(t, p, kc, vc, *ws):
            return model.decode_baseline(cfg, dict(zip(worder_b, ws)), t, p, kc, vc, False)

        def fp(r, p, kc, vc, *ws):
            return model.decode_precomp(cfg, dict(zip(worder_p, ws)), r, p, kc, vc, False)

        hb = aot.to_hlo_text(jax.jit(fb).lower(ti, ti, cache, cache, *ws_b))
        hp = aot.to_hlo_text(jax.jit(fp).lower(rows, ti, cache, cache, *ws_p))
        assert hb.count(" dot(") > hp.count(" dot("), name


needs_artifacts = pytest.mark.skipif(
    not os.path.exists(os.path.join(ART, "manifest.json")),
    reason="run `make artifacts` first",
)


@needs_artifacts
def test_manifest_files_exist_and_parse():
    with open(os.path.join(ART, "manifest.json")) as f:
        man = json.load(f)
    assert man["version"] == 1
    for mname, m in man["models"].items():
        assert os.path.exists(os.path.join(ART, m["weights_file"]))
        assert os.path.exists(os.path.join(ART, m["table_file"]))
        for art in m["artifacts"]:
            p = os.path.join(ART, art["file"])
            assert os.path.exists(p), p
            with open(p) as f:
                head = f.read(200)
            assert "HloModule" in head


@needs_artifacts
def test_manifest_weight_params_match_config():
    with open(os.path.join(ART, "manifest.json")) as f:
        man = json.load(f)
    for mname, m in man["models"].items():
        cfg = configs.get(mname)
        base = model.weight_order_baseline(cfg)
        pre = model.weight_order_precomp(cfg)
        for art in m["artifacts"]:
            wp = art["weight_params"]
            if "baseline" in art["name"]:
                assert wp == base
            elif "precomp_gather" in art["name"]:
                assert wp == ["@table"] + pre
            elif art["kind"] == "precompute_build":
                pass  # its own (source-tensor) order
            else:
                assert wp == pre


@needs_artifacts
def test_manifest_row_width_consistent():
    with open(os.path.join(ART, "manifest.json")) as f:
        man = json.load(f)
    for mname, m in man["models"].items():
        c = m["config"]
        assert c["precomp_row_width"] == 2 * (c["d"] + c["e"])
        for art in m["artifacts"]:
            for io in art["inputs"]:
                if io["name"] == "rows":
                    assert io["shape"][-1] == c["precomp_row_width"]


@needs_artifacts
def test_weights_crc_matches_table(tmp_path):
    """The manifest CRC, the .fpt header CRC and a recomputed CRC agree."""
    from compile import precompute

    with open(os.path.join(ART, "manifest.json")) as f:
        man = json.load(f)
    m = man["models"]["tiny-serial"]
    hdr, _ = precompute.load_fpt(os.path.join(ART, m["table_file"]))
    assert hdr["crc"] == m["weights_crc"]
    cfg = configs.get("tiny-serial")
    w = params.init_weights(cfg)
    crc = params.fingerprint(w, precompute.source_tensor_names(cfg))
    assert crc == hdr["crc"]
