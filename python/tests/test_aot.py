"""AOT pipeline tests: HLO-text lowering + manifest integrity.

The heavyweight end-to-end check (rust loads the artifact and numerics
match) lives in rust/tests; here we verify the python side: lowering
round-trips through the HLO text printer, and the manifest is internally
consistent with the emitted files.
"""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, configs, model, params

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_to_hlo_text_smoke():
    def fn(x, y):
        return (x @ y + 1.0,)

    spec = jax.ShapeDtypeStruct((2, 2), jnp.float32)
    text = aot.to_hlo_text(jax.jit(fn).lower(spec, spec))
    assert "HloModule" in text
    assert "ENTRY" in text


def test_decode_artifact_lowering_has_no_l0_qkv_matmul():
    """E6/§Perf structural check: the precomp decode graph must contain
    fewer dot ops than baseline — the first layer's Q/K/V (and FFN for
    parallel models) matmuls are gone."""
    for name in ["tiny-serial", "tiny-parallel"]:
        cfg = configs.get(name)
        worder_b = model.weight_order_baseline(cfg)
        worder_p = model.weight_order_precomp(cfg)
        B, S = 1, cfg.max_seq
        L, KH, hd = cfg.n_layers, cfg.n_kv_heads, cfg.head_dim
        cache = jax.ShapeDtypeStruct((L, B, S, KH, hd), jnp.float32)
        ws_b = [
            jax.ShapeDtypeStruct(params.tensor_shape(cfg, n), jnp.float32)
            for n in worder_b
        ]
        ws_p = [
            jax.ShapeDtypeStruct(params.tensor_shape(cfg, n), jnp.float32)
            for n in worder_p
        ]
        ti = jax.ShapeDtypeStruct((B,), jnp.int32)
        rows = jax.ShapeDtypeStruct((B, cfg.precomp_row_width), jnp.float32)

        def fb(t, p, kc, vc, *ws):
            return model.decode_baseline(cfg, dict(zip(worder_b, ws)), t, p, kc, vc, False)

        def fp(r, p, kc, vc, *ws):
            return model.decode_precomp(cfg, dict(zip(worder_p, ws)), r, p, kc, vc, False)

        hb = aot.to_hlo_text(jax.jit(fb).lower(ti, ti, cache, cache, *ws_b))
        hp = aot.to_hlo_text(jax.jit(fp).lower(rows, ti, cache, cache, *ws_p))
        assert hb.count(" dot(") > hp.count(" dot("), name


@pytest.mark.parametrize("path", ["baseline", "precomp"])
def test_span_artifact_lowers_with_five_outputs(path):
    """The batched span artifact must lower through the HLO-text pipeline
    with the [logits, kcaches, vcaches, new_k, new_v] output quintuple the
    rust engine chains/reads (artifact-free structural check)."""
    cfg = configs.get("tiny-serial")
    T = 8
    L, S = cfg.n_layers, cfg.max_seq
    KH, hd = cfg.n_kv_heads, cfg.head_dim
    cache = jax.ShapeDtypeStruct((L, 1, S, KH, hd), jnp.float32)
    start = jax.ShapeDtypeStruct((1,), jnp.int32)
    if path == "baseline":
        order = model.weight_order_baseline(cfg)
        data = jax.ShapeDtypeStruct((T,), jnp.int32)

        def fn(tokens, st, kc, vc, *ws):
            return model.decode_span_baseline(
                cfg, dict(zip(order, ws)), tokens, st, kc, vc, False
            )
    else:
        order = model.weight_order_precomp(cfg)
        data = jax.ShapeDtypeStruct((T, cfg.precomp_row_width), jnp.float32)

        def fn(rows, st, kc, vc, *ws):
            return model.decode_span_precomp(
                cfg, dict(zip(order, ws)), rows, st, kc, vc, False
            )

    ws = [
        jax.ShapeDtypeStruct(params.tensor_shape(cfg, n), jnp.float32)
        for n in order
    ]
    text = aot.to_hlo_text(jax.jit(fn).lower(data, start, cache, cache, *ws))
    assert "HloModule" in text and "ENTRY" in text
    # The root tuple must carry the five output leaves, in these shapes.
    shapes = [
        f"f32[{T},{cfg.vocab_size}]",  # logits
        f"f32[{L},1,{S},{KH},{hd}]",  # chained caches (x2)
        f"f32[{T},{L},{KH},{hd}]",  # fresh rows (x2)
    ]
    for s in shapes:
        assert s in text.replace(" ", ""), f"missing output shape {s}"


@pytest.mark.parametrize("path", ["baseline", "precomp"])
def test_span_batched_artifact_lowers_with_five_outputs(path):
    """The multi-sequence [B, T] span artifact lowers through the HLO-text
    pipeline with the batch-extended output quintuple: logits [B, T, V],
    the B-lane cache pair, and per-lane fresh rows [B, T, L, KH, hd]."""
    cfg = configs.get("tiny-serial")
    B, T = 4, 8
    L, S = cfg.n_layers, cfg.max_seq
    KH, hd = cfg.n_kv_heads, cfg.head_dim
    cache = jax.ShapeDtypeStruct((L, B, S, KH, hd), jnp.float32)
    lane = jax.ShapeDtypeStruct((B,), jnp.int32)
    if path == "baseline":
        order = model.weight_order_baseline(cfg)
        data = jax.ShapeDtypeStruct((B, T), jnp.int32)

        def fn(tokens, starts, lens, kc, vc, *ws):
            return model.decode_span_batched_baseline(
                cfg, dict(zip(order, ws)), tokens, starts, lens, kc, vc, False
            )
    else:
        order = model.weight_order_precomp(cfg)
        data = jax.ShapeDtypeStruct((B, T, cfg.precomp_row_width), jnp.float32)

        def fn(rows, starts, lens, kc, vc, *ws):
            return model.decode_span_batched_precomp(
                cfg, dict(zip(order, ws)), rows, starts, lens, kc, vc, False
            )

    ws = [
        jax.ShapeDtypeStruct(params.tensor_shape(cfg, n), jnp.float32)
        for n in order
    ]
    text = aot.to_hlo_text(jax.jit(fn).lower(data, lane, lane, cache, cache, *ws))
    assert "HloModule" in text and "ENTRY" in text
    shapes = [
        f"f32[{B},{T},{cfg.vocab_size}]",  # logits per lane per position
        f"f32[{L},{B},{S},{KH},{hd}]",  # chained B-lane caches (x2)
        f"f32[{B},{T},{L},{KH},{hd}]",  # per-lane fresh rows (x2)
    ]
    for s in shapes:
        assert s in text.replace(" ", ""), f"missing output shape {s}"


needs_artifacts = pytest.mark.skipif(
    not os.path.exists(os.path.join(ART, "manifest.json")),
    reason="run `make artifacts` first",
)


@needs_artifacts
def test_manifest_files_exist_and_parse():
    with open(os.path.join(ART, "manifest.json")) as f:
        man = json.load(f)
    assert man["version"] == 1
    for mname, m in man["models"].items():
        assert os.path.exists(os.path.join(ART, m["weights_file"]))
        assert os.path.exists(os.path.join(ART, m["table_file"]))
        for art in m["artifacts"]:
            p = os.path.join(ART, art["file"])
            assert os.path.exists(p), p
            with open(p) as f:
                head = f.read(200)
            assert "HloModule" in head


@needs_artifacts
def test_manifest_weight_params_match_config():
    with open(os.path.join(ART, "manifest.json")) as f:
        man = json.load(f)
    for mname, m in man["models"].items():
        cfg = configs.get(mname)
        base = model.weight_order_baseline(cfg)
        pre = model.weight_order_precomp(cfg)
        for art in m["artifacts"]:
            wp = art["weight_params"]
            if "baseline" in art["name"]:
                assert wp == base
            elif "precomp_gather" in art["name"]:
                assert wp == ["@table"] + pre
            elif art["kind"] == "precompute_build":
                pass  # its own (source-tensor) order
            else:
                assert wp == pre


@needs_artifacts
def test_manifest_row_width_consistent():
    with open(os.path.join(ART, "manifest.json")) as f:
        man = json.load(f)
    for mname, m in man["models"].items():
        c = m["config"]
        assert c["precomp_row_width"] == 2 * (c["d"] + c["e"])
        for art in m["artifacts"]:
            for io in art["inputs"]:
                if io["name"] == "rows":
                    assert io["shape"][-1] == c["precomp_row_width"]


@needs_artifacts
def test_weights_crc_matches_table(tmp_path):
    """The manifest CRC, the .fpt header CRC and a recomputed CRC agree."""
    from compile import precompute

    with open(os.path.join(ART, "manifest.json")) as f:
        man = json.load(f)
    m = man["models"]["tiny-serial"]
    hdr, _ = precompute.load_fpt(os.path.join(ART, m["table_file"]))
    assert hdr["crc"] == m["weights_crc"]
    cfg = configs.get("tiny-serial")
    w = params.init_weights(cfg)
    crc = params.fingerprint(w, precompute.source_tensor_names(cfg))
    assert crc == hdr["crc"]
