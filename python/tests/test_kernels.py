"""L1 kernel correctness: every Pallas kernel vs its pure-jnp oracle.

Hypothesis sweeps shapes (batch, dims, heads, cache sizes, block sizes)
and checks assert_allclose against ref.py.  Kernels run in interpret
mode, so tolerances are plain f32 accumulation noise.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile import kernels
from compile.kernels import ref

SETTINGS = dict(deadline=None, max_examples=15)


def _arr(rng, shape, scale=1.0):
    return jnp.asarray(rng.normal(size=shape) * scale, jnp.float32)


# ---------------------------------------------------------------------------
# fused_norm_matmul
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    b=st.integers(1, 9),
    d=st.sampled_from([16, 64, 128]),
    dout=st.sampled_from([8, 48, 160]),
    bb=st.sampled_from([1, 2, 8]),
    bn=st.sampled_from([16, 64]),
    norm=st.sampled_from(["rmsnorm", "layernorm"]),
    seed=st.integers(0, 2**31 - 1),
)
def test_fused_norm_matmul(b, d, dout, bb, bn, norm, seed):
    rng = np.random.default_rng(seed)
    x, scale, bias = _arr(rng, (b, d)), _arr(rng, (d,)), _arr(rng, (d,))
    w = _arr(rng, (d, dout), 0.2)
    got = kernels.fused_norm_matmul(
        x, scale, bias, w, norm_type=norm, block_b=bb, block_n=bn
    )
    xn = ref.rmsnorm(x, scale) if norm == "rmsnorm" else ref.layernorm(x, scale, bias)
    assert_allclose(got, xn @ w, rtol=2e-5, atol=2e-5)


def test_fused_norm_matmul_block_padding_edges():
    """Block sizes that do not divide the dims exercise the padding path."""
    rng = np.random.default_rng(0)
    x, scale, bias = _arr(rng, (5, 48)), _arr(rng, (48,)), _arr(rng, (48,))
    w = _arr(rng, (48, 50), 0.2)
    got = kernels.fused_norm_matmul(
        x, scale, bias, w, norm_type="rmsnorm", block_b=3, block_n=7
    )
    assert_allclose(got, ref.rmsnorm(x, scale) @ w, rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# rope
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    b=st.integers(1, 6),
    h=st.sampled_from([1, 2, 8]),
    hd=st.sampled_from([4, 16, 64]),
    theta=st.sampled_from([1e4, 1e6]),
    seed=st.integers(0, 2**31 - 1),
)
def test_rope_kernel(b, h, hd, theta, seed):
    rng = np.random.default_rng(seed)
    x = _arr(rng, (b, h, hd))
    pos = jnp.asarray(rng.integers(0, 4096, (b,)), jnp.int32)
    got = kernels.rope_kernel(x, pos, theta=theta, block_b=2)
    assert_allclose(got, ref.rope_apply(x, pos, theta), rtol=1e-5, atol=1e-5)


@settings(**SETTINGS)
@given(seed=st.integers(0, 2**31 - 1))
def test_rope_is_norm_preserving(seed):
    """Rotation preserves the norm of each (x1_i, x2_i) pair — the defining
    property of RoPE (it is a block-diagonal rotation matrix)."""
    rng = np.random.default_rng(seed)
    x = _arr(rng, (3, 2, 32))
    pos = jnp.asarray(rng.integers(0, 1000, (3,)), jnp.int32)
    y = np.asarray(kernels.rope_kernel(x, pos))
    xa = np.asarray(x)
    px = np.stack([xa[..., :16], xa[..., 16:]], -1)
    py = np.stack([y[..., :16], y[..., 16:]], -1)
    assert_allclose(
        np.linalg.norm(px, axis=-1), np.linalg.norm(py, axis=-1), rtol=1e-5, atol=1e-5
    )


def test_rope_pos_zero_is_identity():
    rng = np.random.default_rng(1)
    x = _arr(rng, (2, 3, 16))
    pos = jnp.zeros((2,), jnp.int32)
    assert_allclose(kernels.rope_kernel(x, pos), x, rtol=1e-6, atol=1e-6)


def test_rope_relative_property():
    """<rope(q,m), rope(k,n)> depends only on m-n (RoPE's raison d'être)."""
    rng = np.random.default_rng(2)
    q = _arr(rng, (1, 1, 32))
    k = _arr(rng, (1, 1, 32))
    def dot(m, n):
        qr = kernels.rope_kernel(q, jnp.asarray([m], jnp.int32))
        kr = kernels.rope_kernel(k, jnp.asarray([n], jnp.int32))
        return float(jnp.sum(qr * kr))
    assert dot(5, 3) == pytest.approx(dot(12, 10), rel=1e-4)
    assert dot(7, 0) == pytest.approx(dot(107, 100), rel=1e-4)


# ---------------------------------------------------------------------------
# decode attention
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    b=st.integers(1, 5),
    kh=st.sampled_from([1, 2, 4]),
    g=st.sampled_from([1, 2, 4]),
    hd=st.sampled_from([8, 32]),
    s=st.sampled_from([16, 40, 64]),
    bs=st.sampled_from([8, 16, 64]),
    seed=st.integers(0, 2**31 - 1),
)
def test_decode_attention(b, kh, g, hd, s, bs, seed):
    rng = np.random.default_rng(seed)
    h = kh * g
    q = _arr(rng, (b, h, hd))
    kc = _arr(rng, (b, s, kh, hd))
    vc = _arr(rng, (b, s, kh, hd))
    lens = jnp.asarray(rng.integers(1, s + 1, (b,)), jnp.int32)
    got = kernels.decode_attention(q, kc, vc, lens, block_s=bs)
    assert_allclose(
        got, ref.attention_decode(q, kc, vc, lens), rtol=2e-5, atol=2e-5
    )


def test_decode_attention_len_one():
    """With a single valid slot attention must return exactly v[0]."""
    rng = np.random.default_rng(3)
    q = _arr(rng, (2, 4, 8))
    kc = _arr(rng, (2, 32, 2, 8))
    vc = _arr(rng, (2, 32, 2, 8))
    lens = jnp.ones((2,), jnp.int32)
    got = np.asarray(kernels.decode_attention(q, kc, vc, lens, block_s=8))
    want = np.asarray(vc)[:, 0]  # [B, KH, hd]
    want = np.repeat(want, 2, axis=1)  # GQA broadcast KH->H
    assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_decode_attention_ignores_garbage_beyond_len():
    """Slots >= lens must not affect the result (paper: only lens slots read)."""
    rng = np.random.default_rng(4)
    q = _arr(rng, (1, 2, 8))
    kc = _arr(rng, (1, 16, 2, 8))
    vc = _arr(rng, (1, 16, 2, 8))
    lens = jnp.asarray([5], jnp.int32)
    base = kernels.decode_attention(q, kc, vc, lens, block_s=8)
    kc2 = kc.at[:, 5:].set(1e9)
    vc2 = vc.at[:, 5:].set(-1e9)
    poisoned = kernels.decode_attention(q, kc2, vc2, lens, block_s=8)
    assert_allclose(base, poisoned, rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# span attention (causal over history)
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    t=st.integers(1, 9),
    kh=st.sampled_from([1, 2, 4]),
    g=st.sampled_from([1, 2, 4]),
    hd=st.sampled_from([8, 32]),
    s=st.sampled_from([16, 40, 64]),
    bq=st.sampled_from([2, 8, 32]),
    bk=st.sampled_from([8, 16, 64]),
    seed=st.integers(0, 2**31 - 1),
)
def test_span_attention(t, kh, g, hd, s, bq, bk, seed):
    rng = np.random.default_rng(seed)
    h = kh * g
    start = int(rng.integers(0, s - t + 1))
    q = _arr(rng, (t, h, hd))
    kc = _arr(rng, (s, kh, hd))
    vc = _arr(rng, (s, kh, hd))
    st_arr = jnp.asarray([start], jnp.int32)
    got = kernels.span_attention_kernel(q, kc, vc, st_arr, block_q=bq, block_k=bk)
    assert_allclose(
        got, ref.attention_span(q, kc, vc, start), rtol=2e-5, atol=2e-5
    )


def test_span_attention_t1_is_decode_attention():
    """A one-token span at position p equals decode attention with lens=p+1
    — the degenerate case the span kernel must share with the decode path."""
    rng = np.random.default_rng(5)
    q = _arr(rng, (1, 4, 8))
    kc = _arr(rng, (24, 2, 8))
    vc = _arr(rng, (24, 2, 8))
    for p in [0, 3, 23]:
        got = kernels.span_attention_kernel(q, kc, vc, jnp.asarray([p], jnp.int32))
        want = ref.attention_decode(
            q, kc[None], vc[None], jnp.asarray([p + 1], jnp.int32)
        )
        assert_allclose(got, want, rtol=2e-5, atol=2e-5)


def test_span_attention_start_zero_is_causal_prefill():
    """start == 0 degenerates to plain causal prefill attention."""
    rng = np.random.default_rng(6)
    T = 12
    q = _arr(rng, (T, 4, 8))
    kc = _arr(rng, (16, 2, 8))
    vc = _arr(rng, (16, 2, 8))
    got = ref.attention_span(q, kc, vc, 0)
    want = ref.attention_prefill(
        q[None, :, :, :],
        kc[None, :T],
        vc[None, :T],
        jnp.asarray([T], jnp.int32),
    )[0]
    # attention_span sees the full 16-slot cache but masks slots > t, and
    # slots T..16 are never visible (t <= T-1 < T) — identical result.
    assert_allclose(got, want, rtol=2e-5, atol=2e-5)


def test_span_attention_ignores_slots_beyond_own_position():
    """Garbage at slots past start+t (ragged padding rows, future span
    tokens) must not leak into any token's output."""
    rng = np.random.default_rng(7)
    T, S, start = 4, 32, 10
    q = _arr(rng, (T, 2, 8))
    kc = _arr(rng, (S, 2, 8))
    vc = _arr(rng, (S, 2, 8))
    base = kernels.span_attention_kernel(q, kc, vc, jnp.asarray([start], jnp.int32))
    # Poison everything past the LAST span token; earlier tokens also must
    # not see their successors, checked token-wise below.
    kc2 = kc.at[start + T :].set(1e9)
    vc2 = vc.at[start + T :].set(-1e9)
    poisoned = kernels.span_attention_kernel(
        q, kc2, vc2, jnp.asarray([start], jnp.int32)
    )
    assert_allclose(base, poisoned, rtol=1e-6, atol=1e-6)
    for t in range(T):
        kc3 = kc.at[start + t + 1 :].set(1e9)
        vc3 = vc.at[start + t + 1 :].set(-1e9)
        per_tok = kernels.span_attention_kernel(
            q, kc3, vc3, jnp.asarray([start], jnp.int32)
        )
        assert_allclose(base[t], per_tok[t], rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# FFN kernels
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    b=st.integers(1, 6),
    d=st.sampled_from([16, 64]),
    h=st.sampled_from([24, 96, 200]),
    bh=st.sampled_from([16, 64]),
    seed=st.integers(0, 2**31 - 1),
)
def test_swiglu_kernel(b, d, h, bh, seed):
    rng = np.random.default_rng(seed)
    x = _arr(rng, (b, d))
    w1, w3 = _arr(rng, (d, h), 0.1), _arr(rng, (d, h), 0.1)
    w2 = _arr(rng, (h, d), 0.1)
    got = kernels.swiglu_kernel(x, w1, w3, w2, block_h=bh)
    assert_allclose(got, ref.swiglu(x, w1, w3, w2), rtol=2e-5, atol=2e-5)


@settings(**SETTINGS)
@given(
    b=st.integers(1, 6),
    d=st.sampled_from([16, 64]),
    h=st.sampled_from([24, 96]),
    bh=st.sampled_from([16, 32]),
    seed=st.integers(0, 2**31 - 1),
)
def test_gelu_mlp_kernel(b, d, h, bh, seed):
    rng = np.random.default_rng(seed)
    x = _arr(rng, (b, d))
    w1, w2 = _arr(rng, (d, h), 0.1), _arr(rng, (h, d), 0.1)
    got = kernels.gelu_mlp_kernel(x, w1, w2, block_h=bh)
    assert_allclose(got, ref.mlp(x, w1, w2), rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# gather
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    v=st.sampled_from([4, 64, 300]),
    w=st.sampled_from([8, 96]),
    b=st.integers(1, 8),
    seed=st.integers(0, 2**31 - 1),
)
def test_gather_rows(v, w, b, seed):
    rng = np.random.default_rng(seed)
    table = _arr(rng, (v, w))
    toks = jnp.asarray(rng.integers(0, v, (b,)), jnp.int32)
    got = kernels.gather_rows_kernel(table, toks)
    assert_allclose(got, ref.gather_rows(table, toks), rtol=0, atol=0)


def test_gather_rows_repeated_tokens():
    rng = np.random.default_rng(5)
    table = _arr(rng, (10, 6))
    toks = jnp.asarray([3, 3, 3, 0, 9], jnp.int32)
    got = np.asarray(kernels.gather_rows_kernel(table, toks))
    assert_allclose(got[0], got[1])
    assert_allclose(got[0], np.asarray(table)[3])


# ---------------------------------------------------------------------------
# MoE oracle sanity (dispatch math, used directly by L2)
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(seed=st.integers(0, 2**31 - 1), topk=st.integers(1, 4))
def test_moe_gates_sum_to_one(seed, topk):
    rng = np.random.default_rng(seed)
    B, d, E, h = 5, 16, 4, 24
    x = _arr(rng, (B, d))
    router = _arr(rng, (d, E))
    w1, w3 = _arr(rng, (E, d, h), 0.1), _arr(rng, (E, d, h), 0.1)
    w2 = _arr(rng, (E, h, d), 0.1)
    # top_k = E makes MoE a softmax-weighted mixture of all experts; the
    # output must then be a convex combination, bounded by the extremes.
    y = ref.moe_swiglu(x, router, w1, w3, w2, top_k=topk)
    assert np.isfinite(np.asarray(y)).all()


def test_moe_topk_equals_single_expert_when_dominant():
    """If one expert's router logit dominates, top-1 output equals that
    expert's swiglu."""
    rng = np.random.default_rng(6)
    B, d, E, h = 3, 8, 4, 12
    x = _arr(rng, (B, d))
    router = jnp.zeros((d, E)).at[:, 2].set(100.0)  # expert 2 dominates
    w1, w3 = _arr(rng, (E, d, h), 0.1), _arr(rng, (E, d, h), 0.1)
    w2 = _arr(rng, (E, h, d), 0.1)
    y = ref.moe_swiglu(x, router, w1, w3, w2, top_k=1)
    want = ref.swiglu(x, w1[2], w3[2], w2[2])
    assert_allclose(y, want, rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# prefill (causal) attention kernel
# ---------------------------------------------------------------------------

from compile.kernels.prefill_attention import prefill_attention  # noqa: E402


@settings(**SETTINGS)
@given(
    b=st.integers(1, 3),
    kh=st.sampled_from([1, 2]),
    g=st.sampled_from([1, 2]),
    hd=st.sampled_from([8, 32]),
    t=st.sampled_from([8, 24, 33]),
    bq=st.sampled_from([8, 16]),
    bk=st.sampled_from([8, 16]),
    seed=st.integers(0, 2**31 - 1),
)
def test_prefill_attention(b, kh, g, hd, t, bq, bk, seed):
    rng = np.random.default_rng(seed)
    h = kh * g
    q = _arr(rng, (b, t, h, hd))
    k = _arr(rng, (b, t, kh, hd))
    v = _arr(rng, (b, t, kh, hd))
    lens = jnp.asarray(rng.integers(1, t + 1, (b,)), jnp.int32)
    got = prefill_attention(q, k, v, lens, block_q=bq, block_k=bk)
    want = ref.attention_prefill(q, k, v, lens)
    assert_allclose(got, want, rtol=2e-5, atol=2e-5)


def test_prefill_attention_is_causal():
    """Future tokens must not influence earlier positions."""
    rng = np.random.default_rng(8)
    b, t, kh, g, hd = 1, 16, 2, 2, 8
    h = kh * g
    q = _arr(rng, (b, t, h, hd))
    k = _arr(rng, (b, t, kh, hd))
    v = _arr(rng, (b, t, kh, hd))
    lens = jnp.asarray([t], jnp.int32)
    base = prefill_attention(q, k, v, lens, block_q=8, block_k=8)
    # Poison the tail: outputs at positions < 8 must be unchanged.
    k2 = k.at[:, 12:].set(1e3)
    v2 = v.at[:, 12:].set(-1e3)
    poisoned = prefill_attention(q, k2, v2, lens, block_q=8, block_k=8)
    assert_allclose(base[:, :8], poisoned[:, :8], rtol=1e-6, atol=1e-6)


def test_prefill_attention_matches_decode_chain():
    """Prefilling T tokens equals T single-token decode-attention steps."""
    rng = np.random.default_rng(9)
    t, kh, g, hd = 6, 1, 2, 8
    h = kh * g
    q = _arr(rng, (1, t, h, hd))
    k = _arr(rng, (1, t, kh, hd))
    v = _arr(rng, (1, t, kh, hd))
    lens = jnp.asarray([t], jnp.int32)
    pre = prefill_attention(q, k, v, lens, block_q=8, block_k=8)
    for i in range(t):
        step = kernels.decode_attention(
            q[:, i],
            k,  # cache holds all T rows; mask limits to <= i
            v,
            jnp.asarray([i + 1], jnp.int32),
            block_s=8,
        )
        assert_allclose(pre[:, i], step, rtol=2e-5, atol=2e-5)


@settings(**SETTINGS)
@given(
    b=st.integers(1, 6),
    bb=st.sampled_from([1, 2, 8]),
    seed=st.integers(0, 2**31 - 1),
)
def test_decode_attention_block_b_invariance(b, bb, seed):
    """The §Perf batch-blocking of the grid must not change results."""
    rng = np.random.default_rng(seed)
    q = _arr(rng, (b, 4, 8))
    kc = _arr(rng, (b, 16, 2, 8))
    vc = _arr(rng, (b, 16, 2, 8))
    lens = jnp.asarray(rng.integers(1, 17, (b,)), jnp.int32)
    a = kernels.decode_attention(q, kc, vc, lens, block_s=16, block_b=bb)
    want = ref.attention_decode(q, kc, vc, lens)
    assert_allclose(a, want, rtol=2e-5, atol=2e-5)
