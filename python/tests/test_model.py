"""L2 model tests: the paper's equivalence claims as executable checks.

E4 — Figure 1(b): parallel models, precomputed first layer ≡ baseline.
E5 — Figure 2(c): serial models, precomputed Q/K/V ≡ baseline; plus the
     negative control of Figure 2(a): with absolute PE the precomputed
     values are WRONG for every position > 0.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from compile import configs, model, params, precompute
from compile.kernels import ref

RUNNABLE = ["tiny-serial", "tiny-parallel", "tiny-moe", "tiny-moe-parallel"]


def _setup(name, seed=7, B=3, use_zero_cache=False):
    cfg = configs.get(name)
    w = params.init_weights(cfg, seed=seed)
    rng = np.random.default_rng(seed + 1)
    L, S = cfg.n_layers, cfg.max_seq
    KH, hd = cfg.n_kv_heads, cfg.head_dim
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B,)), jnp.int32)
    pos = jnp.asarray(rng.integers(0, S - 1, (B,)), jnp.int32)
    if use_zero_cache:
        kc = jnp.zeros((L, B, S, KH, hd), jnp.float32)
        vc = jnp.zeros_like(kc)
    else:
        kc = jnp.asarray(rng.normal(size=(L, B, S, KH, hd)), jnp.float32)
        vc = jnp.asarray(rng.normal(size=(L, B, S, KH, hd)), jnp.float32)
    return cfg, w, toks, pos, kc, vc


@pytest.mark.parametrize("name", RUNNABLE)
@pytest.mark.parametrize("use_pallas", [False, True])
def test_decode_precompute_equivalence(name, use_pallas):
    """The paper's core claim: first-layer precompute changes nothing."""
    cfg, w, toks, pos, kc, vc = _setup(name)
    lb, kb, vb = model.decode_baseline(cfg, w, toks, pos, kc, vc, use_pallas)
    rows = precompute.build_rows(cfg, w, toks, use_pallas=use_pallas)
    lp, kp, vp = model.decode_precomp(cfg, w, rows, pos, kc, vc, use_pallas)
    assert_allclose(lb, lp, rtol=1e-5, atol=1e-5)
    assert_allclose(kb, kp, rtol=1e-5, atol=1e-5)
    assert_allclose(vb, vp, rtol=1e-5, atol=1e-5)
    assert (np.argmax(np.asarray(lb), -1) == np.argmax(np.asarray(lp), -1)).all()


@pytest.mark.parametrize("name", ["tiny-serial", "tiny-parallel"])
def test_decode_precomp_gather_equivalence(name):
    """Ablation path: in-graph Pallas gather over the full table."""
    cfg, w, toks, pos, kc, vc = _setup(name)
    table = precompute.build_rows(cfg, w, use_pallas=False)
    lb, _, _ = model.decode_baseline(cfg, w, toks, pos, kc, vc, use_pallas=False)
    lg, _, _ = model.decode_precomp_gather(
        cfg, w, table, toks, pos, kc, vc, use_pallas=False
    )
    assert_allclose(lb, lg, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("name", RUNNABLE)
def test_prefill_precompute_equivalence(name):
    cfg, w, _, _, _, _ = _setup(name)
    rng = np.random.default_rng(11)
    B, T = 2, 16
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, T)), jnp.int32)
    lens = jnp.asarray([T, T // 2], jnp.int32)
    lb, kb, vb = model.prefill(cfg, w, toks, lens, use_pallas=False)
    rows = precompute.build_rows(cfg, w, toks.reshape(-1), use_pallas=False)
    rows = rows.reshape(B, T, -1)
    lp, kp, vp = model.prefill(cfg, w, toks, lens, rows=rows, use_pallas=False)
    assert_allclose(lb, lp, rtol=1e-5, atol=1e-5)
    # K/V only meaningful for slots < lens: compare masked.
    for b, l in enumerate([T, T // 2]):
        assert_allclose(kb[:, b, :l], kp[:, b, :l], rtol=1e-5, atol=1e-5)


def _span_setup(name, prefix_len, seed=9):
    """History of `prefix_len` tokens built token-by-token from a zero
    cache; returns (cfg, w, caches after prefix, prefix tokens)."""
    cfg = configs.get(name)
    w = params.init_weights(cfg, seed=seed)
    rng = np.random.default_rng(seed + 1)
    L, S = cfg.n_layers, cfg.max_seq
    KH, hd = cfg.n_kv_heads, cfg.head_dim
    kc = jnp.zeros((L, 1, S, KH, hd), jnp.float32)
    vc = jnp.zeros_like(kc)
    prefix = jnp.asarray(rng.integers(0, cfg.vocab_size, (prefix_len,)), jnp.int32)
    for t in range(prefix_len):
        _, kc, vc = model.decode_baseline(
            cfg, w, prefix[t : t + 1], jnp.asarray([t], jnp.int32), kc, vc, False
        )
    return cfg, w, kc, vc, rng


@pytest.mark.parametrize("name", ["tiny-serial", "tiny-parallel"])
@pytest.mark.parametrize("use_pallas", [False, True])
def test_decode_span_matches_token_by_token(name, use_pallas):
    """The batched span step is a pure re-schedule: one execution over T
    tokens must equal T single-token decode steps — logits at every span
    position, the advanced caches, and the fresh K/V rows."""
    P, T = 5, 6
    cfg, w, kc, vc, rng = _span_setup(name, P)
    span = jnp.asarray(rng.integers(0, cfg.vocab_size, (T,)), jnp.int32)

    # Oracle: token-by-token through the decode step.
    kc_o, vc_o = kc, vc
    logits_o = []
    for t in range(T):
        lg, kc_o, vc_o = model.decode_baseline(
            cfg, w, span[t : t + 1], jnp.asarray([P + t], jnp.int32),
            kc_o, vc_o, False,
        )
        logits_o.append(lg[0])

    lg_s, kc_s, vc_s, new_k, new_v = model.decode_span_baseline(
        cfg, w, span, jnp.asarray([P], jnp.int32), kc, vc, use_pallas
    )
    assert_allclose(lg_s, jnp.stack(logits_o), rtol=1e-4, atol=1e-4)
    end = P + T
    assert_allclose(kc_s[:, :, :end], kc_o[:, :, :end], rtol=1e-4, atol=1e-4)
    assert_allclose(vc_s[:, :, :end], vc_o[:, :, :end], rtol=1e-4, atol=1e-4)
    # The fresh-rows outputs are exactly the span's cache rows,
    # token-major ([T, L, KH, hd] — the rust SpanOut layout).
    for t in range(T):
        for li in range(cfg.n_layers):
            assert_allclose(
                new_k[t, li], kc_s[li, 0, P + t], rtol=1e-6, atol=1e-6
            )
            assert_allclose(
                new_v[t, li], vc_s[li, 0, P + t], rtol=1e-6, atol=1e-6
            )


@pytest.mark.parametrize("name", RUNNABLE)
def test_decode_span_precomp_equivalence(name):
    """Precomputed span == baseline span: the batched table rows feed the
    span artifact exactly like the per-token gather feeds decode."""
    P, T = 4, 5
    cfg, w, kc, vc, rng = _span_setup(name, P)
    span = jnp.asarray(rng.integers(0, cfg.vocab_size, (T,)), jnp.int32)
    lb, kb, vb, nkb, nvb = model.decode_span_baseline(
        cfg, w, span, jnp.asarray([P], jnp.int32), kc, vc, False
    )
    rows = precompute.build_rows(cfg, w, span, use_pallas=False)
    lp, kp, vp, nkp, nvp = model.decode_span_precomp(
        cfg, w, rows, jnp.asarray([P], jnp.int32), kc, vc, False
    )
    assert_allclose(lb, lp, rtol=1e-5, atol=1e-5)
    end = P + T
    assert_allclose(kb[:, :, :end], kp[:, :, :end], rtol=1e-5, atol=1e-5)
    assert_allclose(nkb, nkp, rtol=1e-5, atol=1e-5)
    assert_allclose(nvb, nvp, rtol=1e-5, atol=1e-5)
    assert (np.argmax(np.asarray(lb), -1) == np.argmax(np.asarray(lp), -1)).all()


def test_decode_span_ragged_padding_is_inert():
    """A ragged span padded up to the bucket (garbage tail tokens) must
    leave every VALID position's logits, rows, and cache slots unchanged
    — the engine masks the tail host-side, the graph must keep padding
    from leaking backward."""
    P, n, pad = 6, 3, 5  # 3 valid tokens padded up to an 8-token bucket
    cfg, w, kc, vc, rng = _span_setup("tiny-serial", P)
    valid = jnp.asarray(rng.integers(0, cfg.vocab_size, (n,)), jnp.int32)
    lg_v, kc_v, vc_v, nk_v, nv_v = model.decode_span_baseline(
        cfg, w, valid, jnp.asarray([P], jnp.int32), kc, vc, False
    )
    garbage = jnp.asarray(rng.integers(0, cfg.vocab_size, (pad,)), jnp.int32)
    padded = jnp.concatenate([valid, garbage])
    lg_p, kc_p, vc_p, nk_p, nv_p = model.decode_span_baseline(
        cfg, w, padded, jnp.asarray([P], jnp.int32), kc, vc, False
    )
    assert_allclose(lg_p[:n], lg_v, rtol=1e-5, atol=1e-5)
    assert_allclose(nk_p[:n], nk_v[:n], rtol=1e-6, atol=1e-6)
    assert_allclose(nv_p[:n], nv_v[:n], rtol=1e-6, atol=1e-6)
    end = P + n
    assert_allclose(kc_p[:, :, :end], kc_v[:, :, :end], rtol=1e-6, atol=1e-6)
    assert_allclose(vc_p[:, :, :end], vc_v[:, :, :end], rtol=1e-6, atol=1e-6)


def test_prefill_then_decode_matches_pure_decode():
    """Engine invariant: prefill(prompt) + decode steps == decode from scratch."""
    cfg, w, _, _, _, _ = _setup("tiny-serial")
    rng = np.random.default_rng(5)
    T = 7
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (1, T)), jnp.int32)
    lens = jnp.asarray([T], jnp.int32)
    lg_p, kc, vc = model.prefill(cfg, w, toks, lens, use_pallas=False)

    L, S = cfg.n_layers, cfg.max_seq
    KH, hd = cfg.n_kv_heads, cfg.head_dim
    kc2 = jnp.zeros((L, 1, S, KH, hd), jnp.float32)
    vc2 = jnp.zeros_like(kc2)
    for t in range(T):
        lg_d, kc2, vc2 = model.decode_baseline(
            cfg, w, toks[:, t], jnp.asarray([t], jnp.int32), kc2, vc2, False
        )
    assert_allclose(lg_p, lg_d, rtol=1e-4, atol=1e-5)
    assert_allclose(kc[:, :, :T], kc2[:, :, :T], rtol=1e-4, atol=1e-5)


def test_precompute_invalid_under_absolute_pe():
    """Negative control (Figure 2a): with absolute PE the first-layer QKV
    inputs depend on the position, so a per-token table is wrong for every
    position except the one it was computed at."""
    cfg = configs.get("tiny-abspe")
    assert not cfg.rope
    w = params.init_weights(cfg, seed=3)
    tok = jnp.asarray([17], jnp.int32)
    emb = w["emb"][tok]
    # What a (naive) table would store: Q(norm(emb)).
    xn = ref.rmsnorm(emb, w["l0.ln1.scale"], cfg.norm_eps)
    q_table = xn @ w["l0.wq"]
    # What the model actually needs at position p: Q(norm(emb + pe[p])).
    for p in [1, 5, 50]:
        xp = emb + w["abspe"][jnp.asarray([p])]
        q_true = ref.rmsnorm(xp, w["l0.ln1.scale"], cfg.norm_eps) @ w["l0.wq"]
        diff = float(jnp.max(jnp.abs(q_true - q_table)))
        assert diff > 1e-3, f"abs-PE should break precompute at pos {p}"
    # ... while at position 0 with zero PE it would coincide only if pe[0]=0.
    # (RoPE models, by contrast, pass test_decode_precompute_equivalence.)


def test_precompute_rejected_for_abspe_config():
    cfg = configs.get("tiny-abspe")
    w = params.init_weights(cfg, seed=3)
    with pytest.raises(AssertionError, match="RoPE"):
        precompute.build_rows(cfg, w)


@pytest.mark.parametrize("name", RUNNABLE)
def test_eliminated_weights_match_paper_formula(name):
    """#eliminated = d*d + 2*d*e (QKV) [+ FFN weights for parallel]."""
    cfg = configs.get(name)
    elim = model.eliminated_weights(cfg)
    n = 0
    for t in elim:
        shape = params.tensor_shape(cfg, t)
        sz = 1
        for s in shape:
            sz *= s
        n += sz
    d, e, h, E = cfg.d, cfg.e, cfg.ffn_hidden, cfg.n_experts
    want = d * d + 2 * d * e + d  # wq + wk/wv + ln1.scale
    if cfg.norm_type == "layernorm":
        want += d
    if cfg.arch == "parallel":
        want += cfg.ffn_weight_factor * d * h * E + d  # FFN + ln2.scale
        if cfg.norm_type == "layernorm":
            want += d
        if cfg.ffn_type == "swiglu_moe":
            want += d * E  # router
    assert n == want


def test_weight_order_precomp_is_subset_in_order():
    cfg = configs.get("tiny-serial")
    base = model.weight_order_baseline(cfg)
    pre = model.weight_order_precomp(cfg)
    assert [n for n in base if n in set(pre)] == pre


def test_decode_batch_independence():
    """Each row of a batch must be computed independently (router/batcher
    relies on it when mixing requests)."""
    cfg, w, toks, pos, kc, vc = _setup("tiny-serial", B=3)
    l3, _, _ = model.decode_baseline(cfg, w, toks, pos, kc, vc, False)
    l1, _, _ = model.decode_baseline(
        cfg, w, toks[1:2], pos[1:2], kc[:, 1:2], vc[:, 1:2], False
    )
    assert_allclose(l3[1:2], l1, rtol=1e-5, atol=1e-6)
