"""L2 model tests: the paper's equivalence claims as executable checks.

E4 — Figure 1(b): parallel models, precomputed first layer ≡ baseline.
E5 — Figure 2(c): serial models, precomputed Q/K/V ≡ baseline; plus the
     negative control of Figure 2(a): with absolute PE the precomputed
     values are WRONG for every position > 0.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from compile import configs, model, params, precompute
from compile.kernels import ref

RUNNABLE = ["tiny-serial", "tiny-parallel", "tiny-moe", "tiny-moe-parallel"]


def _setup(name, seed=7, B=3, use_zero_cache=False):
    cfg = configs.get(name)
    w = params.init_weights(cfg, seed=seed)
    rng = np.random.default_rng(seed + 1)
    L, S = cfg.n_layers, cfg.max_seq
    KH, hd = cfg.n_kv_heads, cfg.head_dim
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B,)), jnp.int32)
    pos = jnp.asarray(rng.integers(0, S - 1, (B,)), jnp.int32)
    if use_zero_cache:
        kc = jnp.zeros((L, B, S, KH, hd), jnp.float32)
        vc = jnp.zeros_like(kc)
    else:
        kc = jnp.asarray(rng.normal(size=(L, B, S, KH, hd)), jnp.float32)
        vc = jnp.asarray(rng.normal(size=(L, B, S, KH, hd)), jnp.float32)
    return cfg, w, toks, pos, kc, vc


@pytest.mark.parametrize("name", RUNNABLE)
@pytest.mark.parametrize("use_pallas", [False, True])
def test_decode_precompute_equivalence(name, use_pallas):
    """The paper's core claim: first-layer precompute changes nothing."""
    cfg, w, toks, pos, kc, vc = _setup(name)
    lb, kb, vb = model.decode_baseline(cfg, w, toks, pos, kc, vc, use_pallas)
    rows = precompute.build_rows(cfg, w, toks, use_pallas=use_pallas)
    lp, kp, vp = model.decode_precomp(cfg, w, rows, pos, kc, vc, use_pallas)
    assert_allclose(lb, lp, rtol=1e-5, atol=1e-5)
    assert_allclose(kb, kp, rtol=1e-5, atol=1e-5)
    assert_allclose(vb, vp, rtol=1e-5, atol=1e-5)
    assert (np.argmax(np.asarray(lb), -1) == np.argmax(np.asarray(lp), -1)).all()


@pytest.mark.parametrize("name", ["tiny-serial", "tiny-parallel"])
def test_decode_precomp_gather_equivalence(name):
    """Ablation path: in-graph Pallas gather over the full table."""
    cfg, w, toks, pos, kc, vc = _setup(name)
    table = precompute.build_rows(cfg, w, use_pallas=False)
    lb, _, _ = model.decode_baseline(cfg, w, toks, pos, kc, vc, use_pallas=False)
    lg, _, _ = model.decode_precomp_gather(
        cfg, w, table, toks, pos, kc, vc, use_pallas=False
    )
    assert_allclose(lb, lg, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("name", RUNNABLE)
def test_prefill_precompute_equivalence(name):
    cfg, w, _, _, _, _ = _setup(name)
    rng = np.random.default_rng(11)
    B, T = 2, 16
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, T)), jnp.int32)
    lens = jnp.asarray([T, T // 2], jnp.int32)
    lb, kb, vb = model.prefill(cfg, w, toks, lens, use_pallas=False)
    rows = precompute.build_rows(cfg, w, toks.reshape(-1), use_pallas=False)
    rows = rows.reshape(B, T, -1)
    lp, kp, vp = model.prefill(cfg, w, toks, lens, rows=rows, use_pallas=False)
    assert_allclose(lb, lp, rtol=1e-5, atol=1e-5)
    # K/V only meaningful for slots < lens: compare masked.
    for b, l in enumerate([T, T // 2]):
        assert_allclose(kb[:, b, :l], kp[:, b, :l], rtol=1e-5, atol=1e-5)


def test_prefill_then_decode_matches_pure_decode():
    """Engine invariant: prefill(prompt) + decode steps == decode from scratch."""
    cfg, w, _, _, _, _ = _setup("tiny-serial")
    rng = np.random.default_rng(5)
    T = 7
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (1, T)), jnp.int32)
    lens = jnp.asarray([T], jnp.int32)
    lg_p, kc, vc = model.prefill(cfg, w, toks, lens, use_pallas=False)

    L, S = cfg.n_layers, cfg.max_seq
    KH, hd = cfg.n_kv_heads, cfg.head_dim
    kc2 = jnp.zeros((L, 1, S, KH, hd), jnp.float32)
    vc2 = jnp.zeros_like(kc2)
    for t in range(T):
        lg_d, kc2, vc2 = model.decode_baseline(
            cfg, w, toks[:, t], jnp.asarray([t], jnp.int32), kc2, vc2, False
        )
    assert_allclose(lg_p, lg_d, rtol=1e-4, atol=1e-5)
    assert_allclose(kc[:, :, :T], kc2[:, :, :T], rtol=1e-4, atol=1e-5)


def test_precompute_invalid_under_absolute_pe():
    """Negative control (Figure 2a): with absolute PE the first-layer QKV
    inputs depend on the position, so a per-token table is wrong for every
    position except the one it was computed at."""
    cfg = configs.get("tiny-abspe")
    assert not cfg.rope
    w = params.init_weights(cfg, seed=3)
    tok = jnp.asarray([17], jnp.int32)
    emb = w["emb"][tok]
    # What a (naive) table would store: Q(norm(emb)).
    xn = ref.rmsnorm(emb, w["l0.ln1.scale"], cfg.norm_eps)
    q_table = xn @ w["l0.wq"]
    # What the model actually needs at position p: Q(norm(emb + pe[p])).
    for p in [1, 5, 50]:
        xp = emb + w["abspe"][jnp.asarray([p])]
        q_true = ref.rmsnorm(xp, w["l0.ln1.scale"], cfg.norm_eps) @ w["l0.wq"]
        diff = float(jnp.max(jnp.abs(q_true - q_table)))
        assert diff > 1e-3, f"abs-PE should break precompute at pos {p}"
    # ... while at position 0 with zero PE it would coincide only if pe[0]=0.
    # (RoPE models, by contrast, pass test_decode_precompute_equivalence.)


def test_precompute_rejected_for_abspe_config():
    cfg = configs.get("tiny-abspe")
    w = params.init_weights(cfg, seed=3)
    with pytest.raises(AssertionError, match="RoPE"):
        precompute.build_rows(cfg, w)


@pytest.mark.parametrize("name", RUNNABLE)
def test_eliminated_weights_match_paper_formula(name):
    """#eliminated = d*d + 2*d*e (QKV) [+ FFN weights for parallel]."""
    cfg = configs.get(name)
    elim = model.eliminated_weights(cfg)
    n = 0
    for t in elim:
        shape = params.tensor_shape(cfg, t)
        sz = 1
        for s in shape:
            sz *= s
        n += sz
    d, e, h, E = cfg.d, cfg.e, cfg.ffn_hidden, cfg.n_experts
    want = d * d + 2 * d * e + d  # wq + wk/wv + ln1.scale
    if cfg.norm_type == "layernorm":
        want += d
    if cfg.arch == "parallel":
        want += cfg.ffn_weight_factor * d * h * E + d  # FFN + ln2.scale
        if cfg.norm_type == "layernorm":
            want += d
        if cfg.ffn_type == "swiglu_moe":
            want += d * E  # router
    assert n == want


def test_weight_order_precomp_is_subset_in_order():
    cfg = configs.get("tiny-serial")
    base = model.weight_order_baseline(cfg)
    pre = model.weight_order_precomp(cfg)
    assert [n for n in base if n in set(pre)] == pre


def test_decode_batch_independence():
    """Each row of a batch must be computed independently (router/batcher
    relies on it when mixing requests)."""
    cfg, w, toks, pos, kc, vc = _setup("tiny-serial", B=3)
    l3, _, _ = model.decode_baseline(cfg, w, toks, pos, kc, vc, False)
    l1, _, _ = model.decode_baseline(
        cfg, w, toks[1:2], pos[1:2], kc[:, 1:2], vc[:, 1:2], False
    )
    assert_allclose(l3[1:2], l1, rtol=1e-5, atol=1e-6)
