"""Weight init + .fw format tests."""

import os

import numpy as np
import pytest
from numpy.testing import assert_allclose

from compile import configs, params


@pytest.mark.parametrize(
    "name", ["tiny-serial", "tiny-parallel", "tiny-moe", "tiny-abspe"]
)
def test_tensor_names_shapes_consistent(name):
    cfg = configs.get(name)
    names = params.tensor_names(cfg)
    assert len(names) == len(set(names))
    w = params.init_weights(cfg)
    assert set(w) == set(names)
    for n in names:
        assert w[n].shape == params.tensor_shape(cfg, n), n


def test_init_deterministic():
    cfg = configs.get("tiny-serial")
    a = params.init_weights(cfg, seed=42)
    b = params.init_weights(cfg, seed=42)
    for n in a:
        assert_allclose(a[n], b[n], rtol=0, atol=0)


def test_abspe_only_without_rope():
    assert "abspe" not in params.tensor_names(configs.get("tiny-serial"))
    assert "abspe" in params.tensor_names(configs.get("tiny-abspe"))


def test_layernorm_has_bias_rmsnorm_does_not():
    par = params.tensor_names(configs.get("tiny-parallel"))  # layernorm
    ser = params.tensor_names(configs.get("tiny-serial"))  # rmsnorm
    assert "l0.ln1.bias" in par and "lnf.bias" in par
    assert "l0.ln1.bias" not in ser and "lnf.bias" not in ser


def test_fw_roundtrip(tmp_path):
    cfg = configs.get("tiny-moe")
    w = params.init_weights(cfg)
    order = params.tensor_names(cfg)
    path = os.path.join(tmp_path, "w.fw")
    params.save_fw(path, w, order)
    back = params.load_fw(path)
    assert list(back) == order  # order preserved
    for n in order:
        assert_allclose(back[n], np.asarray(w[n]), rtol=0, atol=0)


def test_total_weight_count_matches_paper_formulas():
    """Paper table 1: total = 2*d*vocab + L*(QP + KV + FFN) (+norm scales)."""
    for name, expect_b in [("pythia-6.9b", 6.9e9), ("mistral-7b", 7.2e9)]:
        cfg = configs.get(name)
        n = 0
        for t in params.tensor_names(cfg):
            sz = 1
            for s in params.tensor_shape(cfg, t):
                sz *= s
            n += sz
        assert abs(n - expect_b) / expect_b < 0.02, (name, n)
