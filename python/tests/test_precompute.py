"""S3 table builder + .fpt format tests."""

import os

import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from compile import configs, model, params, precompute
from compile.kernels import ref


@pytest.mark.parametrize("name", ["tiny-serial", "tiny-parallel", "tiny-moe"])
def test_row_width_is_2_d_plus_e(name):
    cfg = configs.get(name)
    w = params.init_weights(cfg)
    rows = precompute.build_rows(cfg, w, jnp.arange(4, dtype=jnp.int32), False)
    assert rows.shape == (4, 2 * (cfg.d + cfg.e))


def test_row_layout_serial():
    """Serial rows are [Q(n(emb)) | K | V | emb] exactly."""
    cfg = configs.get("tiny-serial")
    w = params.init_weights(cfg)
    toks = jnp.asarray([0, 5, 99], jnp.int32)
    rows = precompute.build_rows(cfg, w, toks, use_pallas=False)
    emb = w["emb"][toks]
    xn = ref.rmsnorm(emb, w["l0.ln1.scale"], cfg.norm_eps)
    d, e = cfg.d, cfg.e
    assert_allclose(rows[:, :d], xn @ w["l0.wq"], rtol=1e-5, atol=1e-6)
    assert_allclose(rows[:, d : d + e], xn @ w["l0.wk"], rtol=1e-5, atol=1e-6)
    assert_allclose(rows[:, d + e : d + 2 * e], xn @ w["l0.wv"], rtol=1e-5, atol=1e-6)
    assert_allclose(rows[:, d + 2 * e :], emb, rtol=0, atol=0)


def test_row_layout_parallel_residual_includes_ffn():
    """Parallel rows carry r = emb + FFN(norm2(emb)) — the paper's
    'FFN and skip-connection' precompute."""
    cfg = configs.get("tiny-parallel")
    w = params.init_weights(cfg)
    toks = jnp.asarray([3, 42], jnp.int32)
    rows = precompute.build_rows(cfg, w, toks, use_pallas=False)
    emb = w["emb"][toks]
    x2 = ref.layernorm(emb, w["l0.ln2.scale"], w["l0.ln2.bias"], cfg.norm_eps)
    r = emb + ref.mlp(x2, w["l0.w1"], w["l0.w2"])
    d, e = cfg.d, cfg.e
    assert_allclose(rows[:, d + 2 * e :], r, rtol=1e-5, atol=1e-6)


def test_fpt_roundtrip(tmp_path):
    cfg = configs.get("tiny-moe")
    w = params.init_weights(cfg)
    path = os.path.join(tmp_path, "t.fpt")
    crc = precompute.build_table(cfg, w, path)
    hdr, rows = precompute.load_fpt(path)
    assert hdr["vocab"] == cfg.vocab_size
    assert hdr["width"] == cfg.precomp_row_width
    assert hdr["crc"] == crc
    assert hdr["arch"] == 1  # serial
    want = precompute.build_rows(cfg, w)
    assert_allclose(rows, np.asarray(want), rtol=0, atol=0)


def test_crc_changes_with_weights(tmp_path):
    cfg = configs.get("tiny-moe")
    w1 = params.init_weights(cfg, seed=1)
    w2 = params.init_weights(cfg, seed=2)
    c1 = params.fingerprint(w1, precompute.source_tensor_names(cfg))
    c2 = params.fingerprint(w2, precompute.source_tensor_names(cfg))
    assert c1 != c2


def test_build_rows_batched_equals_unbatched():
    cfg = configs.get("tiny-serial")
    w = params.init_weights(cfg)
    a = precompute.build_rows(cfg, w, use_pallas=False, batch=64)
    b = precompute.build_rows(cfg, w, use_pallas=False, batch=cfg.vocab_size)
    assert_allclose(a, b, rtol=0, atol=0)


def test_source_tensor_names_cover_eliminated_plus_emb():
    for name in ["tiny-serial", "tiny-parallel", "tiny-moe-parallel"]:
        cfg = configs.get(name)
        src = set(precompute.source_tensor_names(cfg))
        elim = set(model.eliminated_weights(cfg))
        assert elim | {"emb"} == src
