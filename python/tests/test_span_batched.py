"""Multi-sequence [B, T] span tests (kernel + model level).

The batched span artifact must be a pure re-schedule of the per-sequence
span path: every occupied lane's logits, cache rows, and fresh K/V must
match `decode_span_*` run lane-by-lane, regardless of what the other
lanes (or the padding) contain.  Degenerate shapes pin the family
together: B=1 reproduces the PR 5 span artifact, T=1 is batched decode.

Plain pytest only (no hypothesis): the poison sweeps enumerate seeds.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from compile import configs, model, params, precompute
from compile.kernels import ref
from compile.kernels.span_attention import span_attention_batched


# ---------------------------------------------------------------------------
# Kernel level: span_attention_batched vs ref.attention_span per row
# ---------------------------------------------------------------------------


def _rand_attn_case(seed, B=3, T=6, S=32, H=4, KH=2, hd=8):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(B, T, H, hd)), jnp.float32)
    kc = jnp.asarray(rng.normal(size=(B, S, KH, hd)), jnp.float32)
    vc = jnp.asarray(rng.normal(size=(B, S, KH, hd)), jnp.float32)
    starts = jnp.asarray(rng.integers(0, S - T, (B,)), jnp.int32)
    lens = jnp.asarray(rng.integers(0, T + 1, (B,)), jnp.int32)
    return q, kc, vc, starts, lens


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_batched_span_kernel_matches_per_row_ref(seed):
    """Row b of the [B, T] kernel == ref.attention_span on that row's
    slice, for t < lens[b]; rows at t >= lens[b] are exactly zero."""
    q, kc, vc, starts, lens = _rand_attn_case(seed)
    out = span_attention_batched(q, kc, vc, starts, lens)
    B, T = q.shape[0], q.shape[1]
    for b in range(B):
        n = int(lens[b])
        if n > 0:
            want = ref.attention_span(q[b, :n], kc[b], vc[b], int(starts[b]))
            assert_allclose(out[b, :n], want, rtol=1e-4, atol=1e-5)
        # Ragged tail (and n == 0 whole-lane) outputs are exact zeros.
        assert np.all(np.asarray(out[b, n:]) == 0.0)


@pytest.mark.parametrize("seed", [3, 4])
def test_batched_span_kernel_poison_invariance(seed):
    """Poisoning slots beyond each row's causal frontier and the queries
    of dead rows must not change any valid output."""
    q, kc, vc, starts, lens = _rand_attn_case(seed)
    clean = span_attention_batched(q, kc, vc, starts, lens)
    B, T, S = q.shape[0], q.shape[1], kc.shape[1]
    # Finite poison (NaN would propagate through 0·NaN in any oracle).
    kc_p, vc_p, q_p = np.asarray(kc).copy(), np.asarray(vc).copy(), np.asarray(q).copy()
    for b in range(B):
        frontier = int(starts[b]) + int(lens[b])  # first never-visible slot
        kc_p[b, frontier:] = 1e6
        vc_p[b, frontier:] = -1e6
        q_p[b, int(lens[b]) :] = 1e6  # dead query rows
    poisoned = span_attention_batched(
        jnp.asarray(q_p), jnp.asarray(kc_p), jnp.asarray(vc_p), starts, lens
    )
    for b in range(B):
        n = int(lens[b])
        assert_allclose(poisoned[b, :n], clean[b, :n], rtol=1e-5, atol=1e-6)
        assert np.all(np.asarray(poisoned[b, n:]) == 0.0)


def test_batched_span_kernel_matches_batched_ref():
    q, kc, vc, starts, lens = _rand_attn_case(7)
    out = span_attention_batched(q, kc, vc, starts, lens)
    want = ref.attention_span_batched(q, kc, vc, starts, lens)
    assert_allclose(out, want, rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# Model level: decode_span_batched_* vs per-lane decode_span_*
# ---------------------------------------------------------------------------


def _lane_histories(name, prefix_lens, seed=13):
    """Per-lane KV histories built token-by-token from zero caches;
    returns (cfg, w, kc [L,B,S,KH,hd], vc, rng)."""
    cfg = configs.get(name)
    w = params.init_weights(cfg, seed=seed)
    rng = np.random.default_rng(seed + 1)
    L, S = cfg.n_layers, cfg.max_seq
    KH, hd = cfg.n_kv_heads, cfg.head_dim
    lanes_k, lanes_v = [], []
    for p in prefix_lens:
        kc = jnp.zeros((L, 1, S, KH, hd), jnp.float32)
        vc = jnp.zeros_like(kc)
        prefix = jnp.asarray(rng.integers(0, cfg.vocab_size, (p,)), jnp.int32)
        for t in range(p):
            _, kc, vc = model.decode_baseline(
                cfg, w, prefix[t : t + 1], jnp.asarray([t], jnp.int32), kc, vc, False
            )
        lanes_k.append(kc)
        lanes_v.append(vc)
    return (
        cfg,
        w,
        jnp.concatenate(lanes_k, axis=1),
        jnp.concatenate(lanes_v, axis=1),
        rng,
    )


@pytest.mark.parametrize("name", ["tiny-serial", "tiny-parallel"])
@pytest.mark.parametrize("use_pallas", [False, True])
def test_decode_span_batched_matches_per_lane(name, use_pallas):
    """Ragged lane batch == decode_span_baseline run lane by lane: logits
    at every valid position, advanced cache rows, fresh K/V rows."""
    prefixes, lens_l = [3, 5, 0], [4, 2, 3]  # lane 2 starts from scratch
    T = 4
    cfg, w, kc, vc, rng = _lane_histories(name, prefixes)
    B = len(prefixes)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, T)), jnp.int32)
    starts = jnp.asarray(prefixes, jnp.int32)
    lens = jnp.asarray(lens_l, jnp.int32)
    lg, kb, vb, nk, nv = model.decode_span_batched_baseline(
        cfg, w, toks, starts, lens, kc, vc, use_pallas
    )
    for b in range(B):
        n = lens_l[b]
        lg1, k1, v1, nk1, nv1 = model.decode_span_baseline(
            cfg, w, toks[b, :n], starts[b : b + 1],
            kc[:, b : b + 1], vc[:, b : b + 1], use_pallas,
        )
        assert_allclose(lg[b, :n], lg1, rtol=1e-4, atol=1e-4)
        end = prefixes[b] + n
        assert_allclose(kb[:, b, :end], k1[:, 0, :end], rtol=1e-4, atol=1e-4)
        assert_allclose(vb[:, b, :end], v1[:, 0, :end], rtol=1e-4, atol=1e-4)
        assert_allclose(nk[b, :n], nk1, rtol=1e-4, atol=1e-4)
        assert_allclose(nv[b, :n], nv1, rtol=1e-4, atol=1e-4)


def test_decode_span_batched_inert_lane_and_poison():
    """A lens == 0 lane and poisoned tail tokens must leave every live
    lane bit-compatible with the unpoisoned run."""
    prefixes, lens_l = [4, 2], [3, 0]
    T = 3
    cfg, w, kc, vc, rng = _lane_histories("tiny-serial", prefixes)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, T)), jnp.int32)
    starts = jnp.asarray(prefixes, jnp.int32)
    lens = jnp.asarray(lens_l, jnp.int32)
    lg_a, kb_a, _, nk_a, _ = model.decode_span_batched_baseline(
        cfg, w, toks, starts, lens, kc, vc, False
    )
    # Poison: different dead-lane tokens AND a poisoned dead-lane cache.
    toks_p = np.asarray(toks).copy()
    toks_p[1, :] = (toks_p[1, :] + 11) % cfg.vocab_size
    kc_p = np.asarray(kc).copy()
    kc_p[:, 1] = 1e3
    lg_b, kb_b, _, nk_b, _ = model.decode_span_batched_baseline(
        cfg, w, jnp.asarray(toks_p), starts, lens, jnp.asarray(kc_p), vc, False
    )
    assert_allclose(lg_a[0], lg_b[0], rtol=1e-6, atol=1e-6)
    assert_allclose(nk_a[0], nk_b[0], rtol=1e-6, atol=1e-6)
    assert_allclose(kb_a[:, 0], kb_b[:, 0], rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("use_pallas", [False, True])
def test_decode_span_batched_degenerate_b1(use_pallas):
    """B=1 with lens=[T] must reproduce the PR 5 span artifact."""
    P, T = 5, 6
    cfg, w, kc, vc, rng = _lane_histories("tiny-serial", [P])
    span = jnp.asarray(rng.integers(0, cfg.vocab_size, (T,)), jnp.int32)
    lg1, k1, v1, nk1, nv1 = model.decode_span_baseline(
        cfg, w, span, jnp.asarray([P], jnp.int32), kc, vc, use_pallas
    )
    lgb, kb, vb, nkb, nvb = model.decode_span_batched_baseline(
        cfg, w, span[None], jnp.asarray([P], jnp.int32),
        jnp.asarray([T], jnp.int32), kc, vc, use_pallas,
    )
    assert_allclose(lgb[0], lg1, rtol=1e-5, atol=1e-5)
    end = P + T
    assert_allclose(kb[:, :, :end], k1[:, :, :end], rtol=1e-5, atol=1e-5)
    assert_allclose(vb[:, :, :end], v1[:, :, :end], rtol=1e-5, atol=1e-5)
    assert_allclose(nkb[0], nk1, rtol=1e-5, atol=1e-5)
    assert_allclose(nvb[0], nv1, rtol=1e-5, atol=1e-5)


def test_decode_span_batched_t1_is_batched_decode():
    """T=1 with all lanes live == one batched decode step."""
    prefixes = [3, 1, 4]
    cfg, w, kc, vc, rng = _lane_histories("tiny-serial", prefixes)
    B = len(prefixes)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B,)), jnp.int32)
    pos = jnp.asarray(prefixes, jnp.int32)
    lg_d, kd, vd = model.decode_baseline(cfg, w, toks, pos, kc, vc, False)
    lg_s, ks, vs, _, _ = model.decode_span_batched_baseline(
        cfg, w, toks[:, None], pos, jnp.ones((B,), jnp.int32), kc, vc, False
    )
    assert_allclose(lg_s[:, 0], lg_d, rtol=1e-5, atol=1e-5)
    for b, p in enumerate(prefixes):
        assert_allclose(ks[:, b, : p + 1], kd[:, b, : p + 1], rtol=1e-5, atol=1e-5)
        assert_allclose(vs[:, b, : p + 1], vd[:, b, : p + 1], rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("name", ["tiny-serial", "tiny-moe"])
def test_decode_span_batched_precomp_equivalence(name):
    """Precomputed batched span == baseline batched span (the paper's
    equivalence, lifted to the multi-sequence artifact)."""
    prefixes, lens_l = [2, 4], [3, 2]
    T = 3
    cfg, w, kc, vc, rng = _lane_histories(name, prefixes)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, T)), jnp.int32)
    starts = jnp.asarray(prefixes, jnp.int32)
    lens = jnp.asarray(lens_l, jnp.int32)
    lb, kb, vb, nkb, nvb = model.decode_span_batched_baseline(
        cfg, w, toks, starts, lens, kc, vc, False
    )
    rows = precompute.build_rows(cfg, w, toks.reshape(-1), use_pallas=False)
    rows = rows.reshape(2, T, -1)
    lp, kp, vp, nkp, nvp = model.decode_span_batched_precomp(
        cfg, w, rows, starts, lens, kc, vc, False
    )
    for b in range(2):
        n = lens_l[b]
        assert_allclose(lb[b, :n], lp[b, :n], rtol=1e-5, atol=1e-5)
        end = prefixes[b] + n
        assert_allclose(kb[:, b, :end], kp[:, b, :end], rtol=1e-5, atol=1e-5)
        assert_allclose(nkb[b, :n], nkp[b, :n], rtol=1e-5, atol=1e-5)
        assert_allclose(nvb[b, :n], nvp[b, :n], rtol=1e-5, atol=1e-5)
