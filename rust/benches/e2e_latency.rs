//! Bench: E6 — decode-step latency baseline vs precompute per batch bucket,
//! plus prefill latency, on the real PJRT engine.  This is the "slightly
//! lower latency and cost-per-token" headline measured end to end.
//!
//! ```bash
//! cargo bench --bench e2e_latency [-- tiny-serial]
//! ```

use firstlayer::manifest::Manifest;
use firstlayer::runtime::{CacheBatch, ModelEngine, Runtime, SpanLane, StepPath};
use firstlayer::util::timer::{bench, emit_json, report};

fn main() {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let args: Vec<String> = std::env::args().skip(1).collect();
    let model = args
        .iter()
        .find(|a| !a.starts_with('-'))
        .map(|s| s.as_str())
        .unwrap_or("tiny-serial");

    let rt = Runtime::cpu().unwrap();
    let manifest = Manifest::load(&dir).unwrap();
    let engine = ModelEngine::load(&rt, &manifest, model).unwrap();
    let cfg = engine.config().clone();
    println!("== bench: decode/prefill latency, {model} ==\n");

    for path in [StepPath::Baseline, StepPath::Precompute] {
        engine.warmup(path).unwrap();
        for b in [1usize, 2, 4, 8] {
            let Ok(bucket) = engine.decode_bucket(b, path) else {
                continue;
            };
            if bucket != b {
                continue; // only exact buckets: no padding noise
            }
            let caches = CacheBatch::zeros(
                cfg.n_layers,
                bucket,
                cfg.max_seq,
                cfg.n_kv_heads,
                cfg.head_dim(),
            );
            let tokens: Vec<u32> = (0..b as u32).collect();
            let pos = vec![30u32; b];
            let s = bench(5, 40, || {
                engine.decode(path, &tokens, &pos, &caches).unwrap();
            });
            report(
                &format!("decode {} B={b}", path.label()),
                &s,
                Some((b as f64 / s.mean.as_secs_f64(), "tok/s")),
            );
        }
        // Prefill buckets.
        for (b, t) in [(1usize, 32usize), (4, 32)] {
            if engine.prefill_bucket(b, t, path).is_err() {
                continue;
            }
            let prompts: Vec<Vec<u32>> = (0..b).map(|i| vec![i as u32 + 2; t]).collect();
            let s = bench(2, 10, || {
                engine.prefill(path, &prompts).unwrap();
            });
            report(
                &format!("prefill {} B={b} T={t}", path.label()),
                &s,
                Some(((b * t) as f64 / s.mean.as_secs_f64(), "tok/s")),
            );
        }
        println!();
    }

    // Ablation: rust-side mmap gather vs in-graph Pallas gather.
    println!("-- ablation: gather placement (B=4) --");
    for path in [StepPath::Precompute, StepPath::PrecomputeGather] {
        let Ok(bucket) = engine.decode_bucket(4, path) else {
            continue;
        };
        let caches = CacheBatch::zeros(
            cfg.n_layers,
            bucket,
            cfg.max_seq,
            cfg.n_kv_heads,
            cfg.head_dim(),
        );
        let tokens = [1u32, 2, 3, 4];
        let pos = [10u32; 4];
        let s = bench(5, 40, || {
            engine.decode(path, &tokens, &pos, &caches).unwrap();
        });
        report(&format!("decode {} B=4", path.label()), &s, None);
    }

    // Device-resident KV: chunk-span execution, buffer-chained device
    // cache vs the legacy per-token upload/readback host path.  The
    // transfer counters make the acceptance criterion measurable: the
    // device path performs exactly ONE cache-pair upload per span.
    println!("\n-- decode_span: device-resident vs host cache path --");
    // Token-by-token oracle isolation: this section measures the device
    // buffer-chaining effect alone, so the batched span artifact is off.
    engine.set_span_exec(false);
    if let Ok(bucket) = engine.decode_bucket(1, StepPath::Precompute) {
        let span_len = 16.min(cfg.max_seq.saturating_sub(1)).max(1);
        let tokens: Vec<u32> = (0..span_len)
            .map(|i| (i as u32 * 7) % cfg.vocab_size as u32)
            .collect();
        let (warmup, iters) = (2usize, 10usize);
        let runs = (warmup + iters) as u64;
        for device in [true, false] {
            engine.set_device_kv(device);
            let label = if device { "device" } else { "host" };
            let stats = engine.transfers();
            let before = stats.snapshot();
            let s = bench(warmup, iters, || {
                let mut caches = CacheBatch::zeros(
                    cfg.n_layers,
                    bucket,
                    cfg.max_seq,
                    cfg.n_kv_heads,
                    cfg.head_dim(),
                );
                engine
                    .decode_span(StepPath::Precompute, &tokens, 0, &mut caches)
                    .unwrap();
            });
            let d = stats.snapshot().since(&before);
            report(
                &format!("span {label} len={span_len}"),
                &s,
                Some((span_len as f64 / s.mean.as_secs_f64(), "tok/s")),
            );
            let mib = |b: u64| b as f64 / runs as f64 / (1u64 << 20) as f64;
            println!(
                "  per-span-token {:?};  per span: cache h2d {:.2} MiB \
                 ({} uploads), cache d2h {:.2} MiB ({} syncs)",
                s.mean / span_len as u32,
                mib(d.cache_h2d_bytes),
                d.cache_uploads / runs,
                mib(d.cache_d2h_bytes),
                d.cache_syncs / runs,
            );
            if device && engine.device_kv_active() {
                assert_eq!(
                    d.cache_uploads, runs,
                    "device span must upload the cache pair exactly once per span"
                );
            } else if device {
                println!("  (device path unavailable; numbers are host-path)");
            }
            emit_json(
                &format!("e2e_span_{label}"),
                &[
                    ("span_len", span_len as f64),
                    ("mean_us", s.mean.as_micros() as f64),
                    ("per_token_us", s.mean.as_micros() as f64 / span_len as f64),
                    ("cache_h2d_bytes_per_span", d.cache_h2d_bytes as f64 / runs as f64),
                    ("cache_d2h_bytes_per_span", d.cache_d2h_bytes as f64 / runs as f64),
                    ("cache_uploads_per_span", d.cache_uploads as f64 / runs as f64),
                    ("cache_syncs_per_span", d.cache_syncs as f64 / runs as f64),
                ],
            );
        }
        engine.set_device_kv(true);
    }
    engine.set_span_exec(true);

    // Batched span artifact vs per-token span execution: the tentpole
    // comparison — a 64-token continuation span as ceil(64/T) bucketed
    // executions (one cache upload, logits + fresh rows readback per
    // tile) against one decode dispatch per token.  Execution counts come
    // from the engine's span counters, so the `<= ceil(len/T)` acceptance
    // bound is asserted here, not eyeballed.
    println!("\n-- decode_span: batched span artifact vs per-token --");
    let span_buckets = engine.span_buckets_for(StepPath::Precompute);
    if span_buckets.is_empty() {
        println!("  (no span artifacts in this bundle; re-run `make artifacts`)");
    } else if let Ok(bucket) = engine.decode_bucket(1, StepPath::Precompute) {
        let span_len = 64.min(cfg.max_seq.saturating_sub(1)).max(1);
        let largest = *span_buckets.last().unwrap();
        let (warmup, iters) = (2usize, 10usize);
        let runs = (warmup + iters) as u64;
        let mut per_token_us = Vec::new();
        for batched in [true, false] {
            engine.set_span_exec(batched);
            let label = if batched { "batched" } else { "per_token" };
            let tokens: Vec<u32> = (0..span_len)
                .map(|i| (i as u32 * 7) % cfg.vocab_size as u32)
                .collect();
            let execs_before = engine.span_executions();
            let fallbacks_before = engine.span_fallbacks();
            let stats = engine.transfers();
            let before = stats.snapshot();
            let s = bench(warmup, iters, || {
                let mut caches = CacheBatch::zeros(
                    cfg.n_layers,
                    bucket,
                    cfg.max_seq,
                    cfg.n_kv_heads,
                    cfg.head_dim(),
                );
                engine
                    .decode_span(StepPath::Precompute, &tokens, 0, &mut caches)
                    .unwrap();
            });
            let d = stats.snapshot().since(&before);
            let execs = if batched {
                (engine.span_executions() - execs_before) as f64 / runs as f64
            } else {
                // The oracle dispatches once per token by definition.
                span_len as f64
            };
            let fallbacks = engine.span_fallbacks() - fallbacks_before;
            report(
                &format!("span {label} len={span_len}"),
                &s,
                Some((span_len as f64 / s.mean.as_secs_f64(), "tok/s")),
            );
            println!(
                "  per-span-token {:?};  {execs:.1} executions/span, \
                 cache uploads/span {:.1}",
                s.mean / span_len as u32,
                d.cache_uploads as f64 / runs as f64,
            );
            if batched && fallbacks == 0 {
                let bound = span_len.div_ceil(largest);
                assert!(
                    execs <= bound as f64 + 1e-9,
                    "batched span must run in <= ceil({span_len}/{largest}) = \
                     {bound} executions, measured {execs:.1}"
                );
            } else if batched {
                println!("  (batched path unavailable; numbers are fallback-path)");
            }
            per_token_us.push(s.mean.as_micros() as f64 / span_len as f64);
            emit_json(
                &format!("e2e_span_{label}"),
                &[
                    ("span_len", span_len as f64),
                    ("mean_us", s.mean.as_micros() as f64),
                    ("per_token_us", s.mean.as_micros() as f64 / span_len as f64),
                    ("execs_per_span", execs),
                    (
                        "cache_uploads_per_span",
                        d.cache_uploads as f64 / runs as f64,
                    ),
                    (
                        "cache_h2d_bytes_per_span",
                        d.cache_h2d_bytes as f64 / runs as f64,
                    ),
                ],
            );
        }
        engine.set_span_exec(true);
        if per_token_us.len() == 2 {
            // per_token_us[0] is the batched run, [1] the per-token run.
            println!(
                "  batched span speedup: {:.2}x (batched {:.1} vs \
                 per-token {:.1} us/token)",
                per_token_us[1] / per_token_us[0].max(1e-9),
                per_token_us[0],
                per_token_us[1],
            );
        }
    }

    // Multi-sequence span groups: B ragged continuation lanes advance in
    // ONE `[B, T]` device execution per group tile, vs B serial
    // per-sequence spans over the same lanes.  The greedy pad-minimal
    // plan tiles the LONGEST lane, so the acceptance bound per group is
    // `ceil(max_len / T_largest)` — asserted via the engine's grouped
    // counter, not eyeballed.
    println!("\n-- decode_span_group: [B, T] multi-sequence vs serial spans --");
    match engine.span_batch_for(StepPath::Precompute, 2) {
        None => println!("  (no span-batch artifacts in this bundle)"),
        Some((batch, ts)) => {
            let largest = *ts.last().unwrap();
            let lens: Vec<usize> = (0..batch)
                .map(|i| [24usize, 17, 9, 13][i % 4].min(cfg.max_seq.saturating_sub(1)).max(1))
                .collect();
            let toks: Vec<Vec<u32>> = lens
                .iter()
                .enumerate()
                .map(|(i, &n)| {
                    (0..n)
                        .map(|j| ((i * 131 + j * 7 + 2) % cfg.vocab_size) as u32)
                        .collect()
                })
                .collect();
            let max_len = *lens.iter().max().unwrap();
            let total: usize = lens.iter().sum();
            let (warmup, iters) = (2usize, 10usize);
            let runs = (warmup + iters) as u64;
            let gexecs_before = engine.span_batched_executions();
            let sg = bench(warmup, iters, || {
                let mut caches = CacheBatch::zeros(
                    cfg.n_layers,
                    batch,
                    cfg.max_seq,
                    cfg.n_kv_heads,
                    cfg.head_dim(),
                );
                let lanes: Vec<SpanLane> = toks
                    .iter()
                    .map(|t| SpanLane { tokens: t, start: 0 })
                    .collect();
                engine
                    .decode_span_group(StepPath::Precompute, &lanes, &mut caches)
                    .unwrap();
            });
            let gexecs =
                (engine.span_batched_executions() - gexecs_before) as f64 / runs as f64;
            report(
                &format!("span group B={batch} max_len={max_len}"),
                &sg,
                Some((total as f64 / sg.mean.as_secs_f64(), "tok/s")),
            );
            let bound = max_len.div_ceil(largest);
            println!("  {gexecs:.1} executions/group (bound ceil({max_len}/{largest}) = {bound})");
            assert!(
                gexecs <= bound as f64 + 1e-9,
                "span group must run in <= {bound} executions, measured {gexecs:.1}"
            );
            // Serial oracle: the same lanes one sequence at a time.
            let ss = bench(warmup, iters, || {
                for t in &toks {
                    let mut caches = CacheBatch::zeros(
                        cfg.n_layers,
                        engine.decode_bucket(1, StepPath::Precompute).unwrap(),
                        cfg.max_seq,
                        cfg.n_kv_heads,
                        cfg.head_dim(),
                    );
                    engine
                        .decode_span(StepPath::Precompute, t, 0, &mut caches)
                        .unwrap();
                }
            });
            report(
                &format!("span serial B={batch} max_len={max_len}"),
                &ss,
                Some((total as f64 / ss.mean.as_secs_f64(), "tok/s")),
            );
            println!(
                "  group speedup: {:.2}x over serial per-sequence spans",
                ss.mean.as_secs_f64() / sg.mean.as_secs_f64().max(1e-12),
            );
            emit_json(
                "e2e_span_batched_multi",
                &[
                    ("lanes", batch as f64),
                    ("max_len", max_len as f64),
                    ("total_tokens", total as f64),
                    ("execs_per_group", gexecs),
                    ("group_mean_us", sg.mean.as_micros() as f64),
                    ("serial_mean_us", ss.mean.as_micros() as f64),
                    (
                        "group_speedup",
                        ss.mean.as_secs_f64() / sg.mean.as_secs_f64().max(1e-12),
                    ),
                ],
            );
        }
    }

    // Serving-path tail latency: drive the full coordinator with the
    // simtraffic mixed workload and report request-level quantiles (queue
    // wait, TTFT, e2e) from the serving metrics — p99 included so
    // `scripts/bench_diff.py` gates tail latency, not just the middle of
    // the distribution.
    println!("\n-- serving: coordinator-driven mixed workload tail latency --");
    {
        use firstlayer::config::ServingConfig;
        use firstlayer::coordinator::Coordinator;
        use firstlayer::simtraffic::mixed_workload;
        let scfg = ServingConfig {
            artifacts_dir: dir.to_string_lossy().into_owned(),
            model: model.to_string(),
            max_new_tokens: 8,
            prefill_chunk_tokens: 16,
            ..Default::default()
        };
        match Coordinator::from_config(&scfg) {
            Err(e) => println!("  (coordinator unavailable: {e})"),
            Ok(mut c) => {
                let reqs = mixed_workload(12, 24, 2, 48, 8, cfg.vocab_size as u32, 0xBE7C);
                let n_reqs = reqs.len();
                for r in reqs {
                    let _ = c.submit(r);
                }
                c.run_to_completion(10_000).unwrap();
                let m = &c.metrics;
                let us = |h: &firstlayer::metrics::Histogram, p: f64| {
                    h.quantile(p).as_micros() as f64
                };
                println!(
                    "  {} requests: queue_wait p50/p95/p99 {:.0}/{:.0}/{:.0} us, \
                     ttft {:.0}/{:.0}/{:.0} us, e2e {:.0}/{:.0}/{:.0} us",
                    n_reqs,
                    us(&m.queue_wait, 0.50),
                    us(&m.queue_wait, 0.95),
                    us(&m.queue_wait, 0.99),
                    us(&m.ttft, 0.50),
                    us(&m.ttft, 0.95),
                    us(&m.ttft, 0.99),
                    us(&m.e2e, 0.50),
                    us(&m.e2e, 0.95),
                    us(&m.e2e, 0.99),
                );
                emit_json(
                    "e2e_serving_tail",
                    &[
                        ("requests", n_reqs as f64),
                        ("queue_wait_p50_us", us(&m.queue_wait, 0.50)),
                        ("queue_wait_p95_us", us(&m.queue_wait, 0.95)),
                        ("queue_wait_p99_us", us(&m.queue_wait, 0.99)),
                        ("ttft_p50_us", us(&m.ttft, 0.50)),
                        ("ttft_p95_us", us(&m.ttft, 0.95)),
                        ("ttft_p99_us", us(&m.ttft, 0.99)),
                        ("e2e_p50_us", us(&m.e2e, 0.50)),
                        ("e2e_p95_us", us(&m.e2e, 0.95)),
                        ("e2e_p99_us", us(&m.e2e, 0.99)),
                    ],
                );
            }
        }
    }

    // Speculative decoding payoff: the coordinator path again, on the
    // drafter-friendly repetitive workload with server-side speculation
    // on.  `spec_accept_rate` (accepted / drafted) is the gated
    // higher-is-better metric — both counters are deterministic at
    // temperature 0 on a seeded workload, so a drop means the drafter or
    // the verify/rollback loop regressed, not host noise.
    println!("\n-- serving: speculative decoding accept rate --");
    {
        use firstlayer::config::ServingConfig;
        use firstlayer::coordinator::Coordinator;
        use firstlayer::simtraffic::spec_workload;
        use std::sync::atomic::Ordering::Relaxed;
        let scfg = ServingConfig {
            artifacts_dir: dir.to_string_lossy().into_owned(),
            model: model.to_string(),
            enable_spec_decode: true,
            ..Default::default()
        };
        match Coordinator::from_config(&scfg) {
            Err(e) => println!("  (coordinator unavailable: {e})"),
            Ok(mut c) => {
                let t0 = std::time::Instant::now();
                for r in spec_workload(8, 3, 24, 48, cfg.vocab_size as u32, 0x5BEC) {
                    let _ = c.submit(r);
                }
                c.run_to_completion(10_000).unwrap();
                let run_us = t0.elapsed().as_micros() as f64;
                let m = &c.metrics;
                let execs = m.spec_executions.load(Relaxed);
                let drafted = m.spec_drafted_tokens.load(Relaxed);
                let accepted = m.spec_accepted_tokens.load(Relaxed);
                let rollbacks = m.spec_rollbacks.load(Relaxed);
                if execs == 0 {
                    // Benches that emit nothing never gate, so a bundle
                    // without span artifacts skips cleanly.
                    println!("  (no verify executions — span artifacts absent)");
                } else {
                    let rate = accepted as f64 / drafted.max(1) as f64;
                    println!(
                        "  {execs} verifies: drafted {drafted}, accepted {accepted} \
                         (rate {rate:.2}), rollbacks {rollbacks}, accept_len mean {:.2}",
                        m.spec_accept_len.mean(),
                    );
                    emit_json(
                        "e2e_spec",
                        &[
                            ("spec_executions", execs as f64),
                            ("spec_accept_rate", rate),
                            ("accept_len_mean", m.spec_accept_len.mean()),
                            ("rollbacks", rollbacks as f64),
                            ("run_us", run_us),
                        ],
                    );
                }
            }
        }
    }

    // Overload resilience: the noisy-neighbor storm (one hog tenant
    // flooding Batch work over small interactive tenants) with per-tenant
    // fair share on.  `interactive_goodput_under_overload` — tokens the
    // interactive bystanders actually received — is the gated
    // higher-is-better metric: greedy sampling on a seeded workload makes
    // it a deterministic count, so a drop means the fair-share/admission
    // path started starving interactive work, not host noise.
    println!("\n-- serving: interactive goodput under a hog tenant --");
    {
        use firstlayer::config::ServingConfig;
        use firstlayer::coordinator::Coordinator;
        use firstlayer::scheduler::Priority;
        use firstlayer::simtraffic::hog_workload;
        let scfg = ServingConfig {
            artifacts_dir: dir.to_string_lossy().into_owned(),
            model: model.to_string(),
            enable_fair_share: true,
            prefill_chunk_tokens: 16,
            step_token_budget: 32,
            ..Default::default()
        };
        match Coordinator::from_config(&scfg) {
            Err(e) => println!("  (coordinator unavailable: {e})"),
            Ok(mut c) => {
                let t0 = std::time::Instant::now();
                let reqs = hog_workload(12, 3, 4, 48, 8, 8, cfg.vocab_size as u32, 0x0AD5);
                let mut interactive_ids = Vec::new();
                let mut hog_ids = Vec::new();
                for r in reqs {
                    let interactive = r.priority == Priority::Interactive;
                    if let Ok(id) = c.submit(r) {
                        if interactive {
                            interactive_ids.push(id);
                        } else {
                            hog_ids.push(id);
                        }
                    }
                }
                c.run_to_completion(10_000).unwrap();
                let run_us = t0.elapsed().as_micros() as f64;
                let toks = |ids: &[u64], c: &Coordinator| -> u64 {
                    ids.iter()
                        .map(|id| c.generated(*id).map_or(0, |g| g.len() as u64))
                        .sum()
                };
                let interactive_tokens = toks(&interactive_ids, &c);
                let hog_tokens = toks(&hog_ids, &c);
                let ttft_p99_us = c.metrics.ttft.quantile(0.99).as_micros() as f64;
                println!(
                    "  interactive {} reqs -> {interactive_tokens} tokens; \
                     hog {} reqs -> {hog_tokens} tokens; ttft_p99 {ttft_p99_us:.0} us",
                    interactive_ids.len(),
                    hog_ids.len(),
                );
                emit_json(
                    "e2e_overload",
                    &[
                        ("interactive_requests", interactive_ids.len() as f64),
                        ("interactive_goodput_under_overload", interactive_tokens as f64),
                        ("hog_tokens", hog_tokens as f64),
                        ("ttft_p99_us", ttft_p99_us),
                        ("run_us", run_us),
                    ],
                );
            }
        }
    }
}
