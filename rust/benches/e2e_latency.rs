//! Bench: E6 — decode-step latency baseline vs precompute per batch bucket,
//! plus prefill latency, on the real PJRT engine.  This is the "slightly
//! lower latency and cost-per-token" headline measured end to end.
//!
//! ```bash
//! cargo bench --bench e2e_latency [-- tiny-serial]
//! ```

use firstlayer::manifest::Manifest;
use firstlayer::runtime::{CacheBatch, ModelEngine, Runtime, StepPath};
use firstlayer::util::timer::{bench, report};

fn main() {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let args: Vec<String> = std::env::args().skip(1).collect();
    let model = args
        .iter()
        .find(|a| !a.starts_with('-'))
        .map(|s| s.as_str())
        .unwrap_or("tiny-serial");

    let rt = Runtime::cpu().unwrap();
    let manifest = Manifest::load(&dir).unwrap();
    let engine = ModelEngine::load(&rt, &manifest, model).unwrap();
    let cfg = engine.config().clone();
    println!("== bench: decode/prefill latency, {model} ==\n");

    for path in [StepPath::Baseline, StepPath::Precompute] {
        engine.warmup(path).unwrap();
        for b in [1usize, 2, 4, 8] {
            let Ok(bucket) = engine.decode_bucket(b, path) else {
                continue;
            };
            if bucket != b {
                continue; // only exact buckets: no padding noise
            }
            let caches = CacheBatch::zeros(
                cfg.n_layers,
                bucket,
                cfg.max_seq,
                cfg.n_kv_heads,
                cfg.head_dim(),
            );
            let tokens: Vec<u32> = (0..b as u32).collect();
            let pos = vec![30u32; b];
            let s = bench(5, 40, || {
                engine.decode(path, &tokens, &pos, &caches).unwrap();
            });
            report(
                &format!("decode {} B={b}", path.label()),
                &s,
                Some((b as f64 / s.mean.as_secs_f64(), "tok/s")),
            );
        }
        // Prefill buckets.
        for (b, t) in [(1usize, 32usize), (4, 32)] {
            if engine.prefill_bucket(b, t, path).is_err() {
                continue;
            }
            let prompts: Vec<Vec<u32>> = (0..b).map(|i| vec![i as u32 + 2; t]).collect();
            let s = bench(2, 10, || {
                engine.prefill(path, &prompts).unwrap();
            });
            report(
                &format!("prefill {} B={b} T={t}", path.label()),
                &s,
                Some(((b * t) as f64 / s.mean.as_secs_f64(), "tok/s")),
            );
        }
        println!();
    }

    // Ablation: rust-side mmap gather vs in-graph Pallas gather.
    println!("-- ablation: gather placement (B=4) --");
    for path in [StepPath::Precompute, StepPath::PrecomputeGather] {
        let Ok(bucket) = engine.decode_bucket(4, path) else {
            continue;
        };
        let caches = CacheBatch::zeros(
            cfg.n_layers,
            bucket,
            cfg.max_seq,
            cfg.n_kv_heads,
            cfg.head_dim(),
        );
        let tokens = [1u32, 2, 3, 4];
        let pos = [10u32; 4];
        let s = bench(5, 40, || {
            engine.decode(path, &tokens, &pos, &caches).unwrap();
        });
        report(&format!("decode {} B=4", path.label()), &s, None);
    }
}
