//! Bench: paged KV cache hot operations (S7) — the L3 substrate the decode
//! loop leans on every step: dense gather, row append, fork.
//!
//! ```bash
//! cargo bench --bench kvcache
//! ```

use firstlayer::kvcache::PagedKvCache;
use firstlayer::util::timer::{bench, report};

fn main() {
    println!("== bench: paged KV cache ==\n");
    // tiny-serial shape: L=4, KH=2, hd=32; 16-token blocks.
    let (l, kh, hd, bt) = (4usize, 2usize, 32usize, 16usize);
    let row_w = l * kh * hd;
    let s_cap = 128usize;

    // gather_dense at several sequence lengths
    for len in [16usize, 64, 127] {
        let mut kv = PagedKvCache::new(64, bt, l, kh, hd);
        kv.create(1, len).unwrap();
        let rows = vec![0.5f32; row_w];
        for _ in 0..len {
            kv.append(1, &rows, &rows).unwrap();
        }
        let mut k = vec![0f32; l * s_cap * kh * hd];
        let mut v = k.clone();
        let s = bench(10, 500, || {
            kv.gather_dense(1, s_cap, &mut k, &mut v).unwrap();
        });
        let bytes = 2.0 * (l * len * kh * hd * 4) as f64;
        report(
            &format!("gather_dense len={len}"),
            &s,
            Some((bytes / s.mean.as_secs_f64() / 1e9, "GB/s")),
        );
    }

    // append throughput (with periodic block allocation)
    {
        let s = bench(3, 50, || {
            let mut kv = PagedKvCache::new(512, bt, l, kh, hd);
            kv.create(1, 1).unwrap();
            let rows = vec![0.5f32; row_w];
            for _ in 0..100 {
                kv.append(1, &rows, &rows).unwrap();
            }
        });
        report(
            "append x100 (incl alloc)",
            &s,
            Some((100.0 / s.mean.as_secs_f64(), "appends/s")),
        );
    }

    // fork (CoW tail copy)
    {
        let mut kv = PagedKvCache::new(4096, bt, l, kh, hd);
        kv.create(1, 1).unwrap();
        let rows = vec![0.5f32; row_w];
        for _ in 0..33 {
            kv.append(1, &rows, &rows).unwrap();
        }
        let mut next = 2u64;
        let s = bench(10, 500, || {
            kv.fork(1, next).unwrap();
            kv.remove(next).unwrap();
            next += 1;
        });
        report("fork+remove (33-token seq)", &s, None);
    }

    // invariant check cost (runs in selfcheck/debug builds)
    {
        let mut kv = PagedKvCache::new(256, bt, l, kh, hd);
        for id in 0..32u64 {
            kv.create(id, 16).unwrap();
        }
        let s = bench(10, 200, || {
            kv.check_invariants().unwrap();
        });
        report("check_invariants (32 seqs)", &s, None);
    }
}
