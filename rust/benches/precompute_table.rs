//! Bench: precompute table primitives (S10) — the paper's runtime read.
//! Gather throughput must be memcpy-bound (target >= 1 GB/s, DESIGN §9);
//! also times table open (mmap) and the on-device rebuild.
//!
//! ```bash
//! cargo bench --bench precompute_table
//! ```

use firstlayer::manifest::Manifest;
use firstlayer::precompute::Table;
use firstlayer::runtime::{ModelEngine, Runtime};
use firstlayer::util::rng::Rng;
use firstlayer::util::timer::{bench, report, time_once};

fn main() {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let manifest = Manifest::load(&dir).unwrap();
    let entry = manifest.model("tiny-serial").unwrap();
    println!("== bench: precompute table ==\n");

    // mmap open
    let path = manifest.path(&entry.table_file);
    let s = bench(3, 50, || {
        let t = Table::open(&path).unwrap();
        std::hint::black_box(t.row_width());
    });
    report("Table::open (mmap)", &s, None);

    let table = Table::open(&path).unwrap();
    let mut rng = Rng::new(3);

    // Random-token gather at several batch sizes.
    for b in [1usize, 8, 64, 512, 4096] {
        let tokens: Vec<u32> = (0..b)
            .map(|_| rng.below(table.vocab() as u64) as u32)
            .collect();
        let mut out = vec![0f32; b * table.row_width()];
        let s = bench(10, 300, || {
            table.gather(&tokens, &mut out).unwrap();
            std::hint::black_box(&out);
        });
        let bytes = (b * table.row_width() * 4) as f64;
        report(
            &format!("gather B={b}"),
            &s,
            Some((bytes / s.mean.as_secs_f64() / 1e9, "GB/s")),
        );
    }

    // Sequential full-table scan (page-in + checksum).
    let s = bench(2, 20, || {
        std::hint::black_box(table.payload_crc());
    });
    report(
        "payload_crc (full scan)",
        &s,
        Some((table.data_bytes() as f64 / s.mean.as_secs_f64() / 1e9, "GB/s")),
    );

    // On-device rebuild via the PJRT artifact (the offline pass, timed).
    let rt = Runtime::cpu().unwrap();
    let engine = ModelEngine::load(&rt, &manifest, "tiny-serial").unwrap();
    let (_t, d) = time_once(|| engine.build_table().unwrap());
    println!(
        "\nbuild_table via PJRT: {:.2?} for {} rows ({:.1} rows/ms)",
        d,
        table.vocab(),
        table.vocab() as f64 / d.as_millis().max(1) as f64
    );
}
