//! Bench: scheduler tick latency (S8) — `plan()` must stay microseconds
//! even with hundreds of waiting sequences (perf target: < 5 us @ 256
//! waiting, see DESIGN.md §9) — plus the chunked-prefill mixing scenario:
//! a long-prompt + decode workload must interleave decode steps between
//! prefill chunks instead of head-of-line-blocking on whole prompts.
//!
//! ```bash
//! cargo bench --bench scheduler
//! ```

use firstlayer::config::zoo_get;
use firstlayer::kvcache::PagedKvCache;
use firstlayer::prefixcache::PrefixCache;
use firstlayer::scheduler::{KvBudget, Priority, SchedConfig, Scheduler, State};
use firstlayer::simtraffic::{mixed_workload, tenant_workload};
use firstlayer::util::timer::{bench, emit_json, report};

struct InfiniteKv;

impl KvBudget for InfiniteKv {
    fn free_blocks(&self) -> usize {
        usize::MAX / 2
    }
    fn blocks_for(&self, tokens: usize) -> usize {
        tokens.div_ceil(16)
    }
    fn blocks_held(&self, _id: u64) -> usize {
        2
    }
    fn growth_needs_block(&self, _id: u64) -> bool {
        false
    }
}

struct TightKv;

impl KvBudget for TightKv {
    fn free_blocks(&self) -> usize {
        0
    }
    fn blocks_for(&self, tokens: usize) -> usize {
        tokens.div_ceil(16)
    }
    fn blocks_held(&self, _id: u64) -> usize {
        2
    }
    fn growth_needs_block(&self, _id: u64) -> bool {
        true // everyone needs a block: worst-case preemption churn
    }
}

fn mk(n_waiting: usize, n_running: usize) -> Scheduler {
    let mut s = Scheduler::new(SchedConfig {
        max_batch: 8,
        max_admit: 4,
        max_prompt: 32,
        max_seq: 128,
        chunk_tokens: 0,
        step_token_budget: 0,
        span_bucket_tokens: 0,
        span_group_lanes: 0,
        spec_tokens: 0,
    });
    let mut id = 0u64;
    // Fill running first (via admission on an infinite budget).
    for _ in 0..n_running {
        s.submit(id, vec![1; 16], 32, Priority::Normal).unwrap();
        id += 1;
    }
    while s.n_running() < n_running {
        let p = s.plan(&InfiniteKv);
        for c in p.prefill {
            s.on_chunk(c.id, c.len);
            s.on_token(c.id, false);
        }
    }
    for i in 0..n_waiting {
        let prio = match i % 3 {
            0 => Priority::Interactive,
            1 => Priority::Normal,
            _ => Priority::Batch,
        };
        s.submit(id, vec![1; 16], 32, prio).unwrap();
        id += 1;
    }
    s
}

fn main() {
    println!("== bench: scheduler plan() tick ==\n");
    for (w, r) in [(16usize, 8usize), (64, 8), (256, 8), (1024, 8)] {
        let mut s = mk(w, r);
        let st = bench(10, 1000, || {
            // plan + undo the admission so the state stays stable
            let p = s.plan(&TightKv);
            std::hint::black_box(&p);
        });
        report(&format!("plan() waiting={w} running={r}"), &st, None);
    }

    // Submission throughput.
    {
        let st = bench(3, 100, || {
            let mut s = Scheduler::new(SchedConfig {
                max_batch: 8,
                max_admit: 4,
                max_prompt: 32,
                max_seq: 128,
                chunk_tokens: 0,
                step_token_budget: 0,
                span_bucket_tokens: 0,
                span_group_lanes: 0,
                spec_tokens: 0,
            });
            for id in 0..256u64 {
                s.submit(id, vec![1; 16], 32, Priority::Normal).unwrap();
            }
        });
        report(
            "submit x256",
            &st,
            Some((256.0 / st.mean.as_secs_f64(), "req/s")),
        );
    }

    // Chunked-prefill mixing: long documents + interactive chats.  The
    // figure of merit is the head-of-line bound — the most prefill tokens
    // any single step executes (every decode in that step waits behind
    // them); chunking must cap it at the budget.
    println!("\n== chunked prefill: long-prompt + decode mixing ==\n");
    for (chunk, budget, label) in [
        (0usize, 0usize, "monolithic (chunking off)"),
        (64, 128, "chunk=64 budget=128"),
    ] {
        let (steps, mixed, max_step_tokens) = drive_mixed(chunk, budget);
        // max_prefill_tokens/step is the head-of-line bound: every decode
        // sharing a step waits behind that much prefill compute.
        println!(
            "{label:<28} steps={steps:<5} mixed_steps={mixed:<5} \
             max_prefill_tokens/step={max_step_tokens}"
        );
        if chunk > 0 {
            assert!(
                mixed > 0,
                "chunked run never mixed prefill chunks with decodes"
            );
            assert!(
                max_step_tokens <= budget,
                "a step prefilled {max_step_tokens} tokens, budget {budget}"
            );
        } else {
            assert!(
                max_step_tokens >= 512,
                "monolithic baseline should show whole-prompt prefill steps"
            );
        }
    }

    // plan() latency with chunking enabled (mid-prefill continuations in
    // the running set are the new per-tick work).
    {
        let mut s = Scheduler::new(SchedConfig {
            max_batch: 16,
            max_admit: 4,
            max_prompt: 4096,
            max_seq: 8192,
            chunk_tokens: 64,
            step_token_budget: 128,
            span_bucket_tokens: 0,
            span_group_lanes: 0,
            spec_tokens: 0,
        });
        let mut id = 0u64;
        for r in mixed_workload(12, 32, 4, 1024, 32, 1000, 7) {
            s.submit(id, r.prompt, r.max_new_tokens, r.priority).unwrap();
            id += 1;
        }
        // Warm into a steady mid-prefill state.
        for _ in 0..3 {
            let p = s.plan(&InfiniteKv);
            for c in p.prefill {
                s.on_chunk(c.id, c.len);
            }
        }
        let st = bench(10, 1000, || {
            let p = s.plan(&TightKv);
            std::hint::black_box(&p);
        });
        report("plan() chunked, 4 long prefills in flight", &st, None);
    }

    // Prefix reuse: multi-tenant shared-system-prompt traffic through
    // scheduler + paged KV + radix-tree prefix cache (no engine needed —
    // chunks append zero-valued rows).  The figure of merit is prefill
    // tokens executed before the first token (the TTFT-side work): a
    // cache hit forks the shared prefix's blocks and prefills only the
    // user suffix.
    println!("\n== prefix reuse: shared system prompts (cross-request KV cache) ==\n");
    prefix_reuse_section();

    // Device-resident KV: model the dense-cache bus traffic implied by
    // the mixed workload's plan stream, host path vs buffer-chained
    // sessions.  No engine needed — pair sizes come from the zoo config,
    // composition changes from the plans — so the byte reduction is
    // recorded even in artifact-free environments.
    println!("\n== device-resident KV: modeled cache movement (mixed workload) ==\n");
    kv_movement_section();
}

/// Replay the chunked mixed workload through the scheduler and count
/// dense `[L, B, S, KH, hd]` cache-pair transfers per execution model:
///
/// * host path — every continuation-span token and every decode step
///   uploads AND reads back the full pair;
/// * device-resident — one pair up per span, one pair down at span end;
///   decode uploads only when the batch composition changes and syncs
///   down at the next recomposition.
///
/// Fresh (`start == 0`) chunks run the batched prefill artifact
/// identically on both paths and are omitted.
fn kv_movement_section() {
    let cfg = zoo_get("mistral-7b").unwrap();
    let pair_bytes = |bucket: usize| -> u64 {
        (2 * cfg.n_layers * bucket * cfg.max_seq * cfg.n_kv_heads * cfg.head_dim() * 4)
            as u64
    };
    let max_batch = 16usize;
    let span_pair = pair_bytes(1);
    let decode_pair = pair_bytes(max_batch);
    let mut s = Scheduler::new(SchedConfig {
        max_batch,
        max_admit: 4,
        max_prompt: 4096,
        max_seq: cfg.max_seq,
        chunk_tokens: 64,
        step_token_budget: 128,
        span_bucket_tokens: 0,
        span_group_lanes: 0,
        spec_tokens: 0,
    });
    let mut id = 0u64;
    for r in mixed_workload(12, 32, 4, 1024, 32, 1000, 7) {
        s.submit(id, r.prompt, r.max_new_tokens, r.priority).unwrap();
        id += 1;
    }
    let (mut h_h2d, mut h_d2h, mut d_h2d, mut d_d2h) = (0u64, 0u64, 0u64, 0u64);
    let (mut span_tokens, mut decode_steps, mut sessions) = (0u64, 0u64, 0u64);
    let mut prev_decode: Vec<u64> = Vec::new();
    let mut steps = 0usize;
    loop {
        let p = s.plan(&InfiniteKv);
        if p.prefill.is_empty() && p.decode.is_empty() {
            break;
        }
        for c in &p.prefill {
            if c.start > 0 {
                // Continuation span through decode_span.
                span_tokens += c.len as u64;
                h_h2d += c.len as u64 * span_pair;
                h_d2h += c.len as u64 * span_pair;
                d_h2d += span_pair;
                d_d2h += span_pair;
            }
            s.on_chunk(c.id, c.len);
            if c.last {
                s.on_token(c.id, false);
            }
        }
        // Mirror the coordinator's session policy exactly: the session
        // survives only while plan.decode equals its ids — ANY other
        // plan (including a decode-empty, prefill-only step) syncs the
        // old pair down, and the next decode batch uploads a fresh one.
        if p.decode != prev_decode {
            if !prev_decode.is_empty() {
                d_d2h += decode_pair;
            }
            if !p.decode.is_empty() {
                d_h2d += decode_pair;
                sessions += 1;
            }
            prev_decode = p.decode.clone();
        }
        if !p.decode.is_empty() {
            decode_steps += 1;
            h_h2d += decode_pair;
            h_d2h += decode_pair;
            for &did in &p.decode {
                s.on_token(did, false);
            }
        }
        steps += 1;
        assert!(steps < 1_000_000, "modeled workload did not drain");
    }
    if !prev_decode.is_empty() {
        // Final drain sync of the last live session.
        d_d2h += decode_pair;
    }
    let gb = |b: u64| b as f64 / 1e9;
    println!(
        "cfg {}: span tokens={span_tokens} decode steps={decode_steps} \
         device sessions={sessions}",
        cfg.name
    );
    println!(
        "host path:   h2d {:>8.1} GB   d2h {:>8.1} GB   (full pair per span token / decode step)",
        gb(h_h2d),
        gb(h_d2h)
    );
    println!(
        "device path: h2d {:>8.1} GB   d2h {:>8.1} GB   (pair per span / recomposition)",
        gb(d_h2d),
        gb(d_d2h)
    );
    println!(
        "reduction:   h2d {:.1}x  d2h {:.1}x",
        h_h2d as f64 / d_h2d as f64,
        h_d2h as f64 / d_d2h as f64
    );
    assert!(
        d_h2d < h_h2d && d_d2h < h_d2h,
        "device-resident path must move strictly fewer cache bytes"
    );
    emit_json(
        "sched_kv_movement",
        &[
            ("host_h2d_bytes", h_h2d as f64),
            ("host_d2h_bytes", h_d2h as f64),
            ("device_h2d_bytes", d_h2d as f64),
            ("device_d2h_bytes", d_d2h as f64),
            ("span_tokens", span_tokens as f64),
            ("decode_steps", decode_steps as f64),
            ("sessions", sessions as f64),
        ],
    );
}

/// Drive `tenant_workload` requests sequentially through a real
/// `PagedKvCache` + `PrefixCache`, mirroring the coordinator's
/// match-on-submit / insert-on-finish lifecycle.
fn prefix_reuse_section() {
    // 16-token blocks; 2 layers, kh·hd = 4 keeps the zero rows cheap.
    let mut kv = PagedKvCache::new(256, 16, 2, 1, 4);
    let mut pc = PrefixCache::new(16, 64);
    let mut s = Scheduler::new(SchedConfig {
        max_batch: 8,
        max_admit: 4,
        max_prompt: 4096,
        max_seq: 8192,
        chunk_tokens: 32,
        step_token_budget: 0,
        span_bucket_tokens: 0,
        span_group_lanes: 0,
        spec_tokens: 0,
    });
    // 2 tenants x 3 requests, 96-token system prompts, short suffixes.
    let reqs = tenant_workload(2, 3, 96, 16, 4, 1000, 11);
    let row = vec![0f32; 2 * 4];
    println!(
        "{:<4} {:>8} {:>8} {:>10}  note",
        "req", "prompt", "cached", "prefilled"
    );
    let (mut cold_prefill, mut cold_n) = (0usize, 0usize);
    let (mut warm_prefill, mut warm_n, mut warm_cached) = (0usize, 0usize, 0usize);
    for (i, r) in reqs.iter().enumerate() {
        let id = i as u64;
        s.submit(id, r.prompt.clone(), r.max_new_tokens, r.priority)
            .unwrap();
        let m = pc.match_prefix(&r.prompt);
        if m.tokens > 0 {
            kv.create_shared(id, &m.blocks, m.tokens).unwrap();
            s.set_prefilled(id, m.tokens);
        }
        let mut prefilled = 0usize;
        let mut steps = 0;
        while matches!(s.state(id), Some(State::Waiting | State::Running)) {
            // PagedKvCache implements KvBudget directly (1:1 view).
            let plan = s.plan(&kv);
            assert!(plan.preempt.is_empty(), "unexpected preemption (pool is big)");
            for c in &plan.prefill {
                if kv.seq_len(c.id).is_none() {
                    kv.create(c.id, 1).unwrap();
                }
                for _ in 0..c.len {
                    kv.append(c.id, &row, &row).unwrap();
                }
                s.on_chunk(c.id, c.len);
                prefilled += c.len;
                if c.last {
                    s.on_token(c.id, false);
                }
            }
            for &d in &plan.decode {
                kv.append(d, &row, &row).unwrap();
                s.on_token(d, false);
            }
            steps += 1;
            assert!(steps < 10_000, "bench request did not finish");
        }
        let blocks = kv.seq_blocks(id).unwrap().to_vec();
        pc.insert(&r.prompt, &blocks, &mut kv);
        kv.remove(id).unwrap();
        s.forget(id);
        assert_eq!(
            prefilled + m.tokens,
            r.prompt.len(),
            "prefilled + cached tokens must tile the prompt"
        );
        if m.tokens > 0 {
            warm_prefill += prefilled;
            warm_cached += m.tokens;
            warm_n += 1;
        } else {
            cold_prefill += prefilled;
            cold_n += 1;
        }
        println!(
            "{i:<4} {:>8} {:>8} {:>10}  {}",
            r.prompt.len(),
            m.tokens,
            prefilled,
            if m.tokens > 0 {
                "hit: suffix-only prefill"
            } else {
                "miss: full prefill"
            }
        );
    }
    // First request per tenant is cold; every repeat must hit.
    assert_eq!(cold_n, 2, "expected exactly one cold request per tenant");
    assert!(
        warm_n == 4 && warm_cached > 0,
        "repeat requests must be served from the cache (cached tokens > 0)"
    );
    kv.check_invariants().unwrap();
    let cold_avg = cold_prefill as f64 / cold_n as f64;
    let warm_avg = warm_prefill as f64 / warm_n as f64;
    println!(
        "\ncold: {cold_avg:.1} prefill tokens before first token (avg)\n\
         warm: {warm_avg:.1} (avg; {warm_cached} tokens total served from cache)\n\
         TTFT-side prefill work cut {:.0}% on warm requests",
        100.0 * (1.0 - warm_avg / cold_avg),
    );
}

/// Drive a mixed workload to completion; returns (total steps, steps with
/// both decode and prefill work, max prefill tokens executed in one step).
fn drive_mixed(chunk: usize, budget: usize) -> (usize, usize, usize) {
    let mut s = Scheduler::new(SchedConfig {
        max_batch: 16,
        max_admit: 4,
        max_prompt: 4096,
        max_seq: 8192,
        chunk_tokens: chunk,
        step_token_budget: budget,
        span_bucket_tokens: 0,
        span_group_lanes: 0,
        spec_tokens: 0,
    });
    let mut id = 0u64;
    for r in mixed_workload(12, 32, 4, 1024, 32, 1000, 7) {
        s.submit(id, r.prompt, r.max_new_tokens, r.priority).unwrap();
        id += 1;
    }
    let (mut steps, mut mixed, mut max_tokens) = (0usize, 0usize, 0usize);
    loop {
        let p = s.plan(&InfiniteKv);
        if p.prefill.is_empty() && p.decode.is_empty() {
            break;
        }
        let prefill_tokens: usize = p.prefill.iter().map(|c| c.len).sum();
        max_tokens = max_tokens.max(prefill_tokens);
        if !p.prefill.is_empty() && !p.decode.is_empty() {
            mixed += 1;
        }
        for c in &p.prefill {
            s.on_chunk(c.id, c.len);
            if c.last {
                s.on_token(c.id, false);
            }
        }
        for &pid in &p.decode {
            s.on_token(pid, false);
        }
        steps += 1;
        assert!(steps < 1_000_000, "mixed workload did not drain");
    }
    (steps, mixed, max_tokens)
}
