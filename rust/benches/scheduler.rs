//! Bench: scheduler tick latency (S8) — `plan()` must stay microseconds
//! even with hundreds of waiting sequences (perf target: < 5 us @ 256
//! waiting, see DESIGN.md §9).
//!
//! ```bash
//! cargo bench --bench scheduler
//! ```

use firstlayer::scheduler::{KvBudget, Priority, SchedConfig, Scheduler};
use firstlayer::util::timer::{bench, report};

struct InfiniteKv;

impl KvBudget for InfiniteKv {
    fn free_blocks(&self) -> usize {
        usize::MAX / 2
    }
    fn blocks_for(&self, tokens: usize) -> usize {
        tokens.div_ceil(16)
    }
    fn blocks_held(&self, _id: u64) -> usize {
        2
    }
    fn growth_needs_block(&self, _id: u64) -> bool {
        false
    }
}

struct TightKv;

impl KvBudget for TightKv {
    fn free_blocks(&self) -> usize {
        0
    }
    fn blocks_for(&self, tokens: usize) -> usize {
        tokens.div_ceil(16)
    }
    fn blocks_held(&self, _id: u64) -> usize {
        2
    }
    fn growth_needs_block(&self, _id: u64) -> bool {
        true // everyone needs a block: worst-case preemption churn
    }
}

fn mk(n_waiting: usize, n_running: usize) -> Scheduler {
    let mut s = Scheduler::new(SchedConfig {
        max_batch: 8,
        max_admit: 4,
        max_prompt: 32,
        max_seq: 128,
    });
    let mut id = 0u64;
    // Fill running first (via admission on an infinite budget).
    for _ in 0..n_running {
        s.submit(id, vec![1; 16], 32, Priority::Normal).unwrap();
        id += 1;
    }
    while s.n_running() < n_running {
        let p = s.plan(&InfiniteKv);
        for pid in p.prefill {
            s.on_token(pid, false);
        }
    }
    for i in 0..n_waiting {
        let prio = match i % 3 {
            0 => Priority::Interactive,
            1 => Priority::Normal,
            _ => Priority::Batch,
        };
        s.submit(id, vec![1; 16], 32, prio).unwrap();
        id += 1;
    }
    s
}

fn main() {
    println!("== bench: scheduler plan() tick ==\n");
    for (w, r) in [(16usize, 8usize), (64, 8), (256, 8), (1024, 8)] {
        let mut s = mk(w, r);
        let st = bench(10, 1000, || {
            // plan + undo the admission so the state stays stable
            let p = s.plan(&TightKv);
            std::hint::black_box(&p);
        });
        report(&format!("plan() waiting={w} running={r}"), &st, None);
    }

    // Submission throughput.
    {
        let st = bench(3, 100, || {
            let mut s = Scheduler::new(SchedConfig {
                max_batch: 8,
                max_admit: 4,
                max_prompt: 32,
                max_seq: 128,
            });
            for id in 0..256u64 {
                s.submit(id, vec![1; 16], 32, Priority::Normal).unwrap();
            }
        });
        report(
            "submit x256",
            &st,
            Some((256.0 / st.mean.as_secs_f64(), "req/s")),
        );
    }
}
