//! Bench: paper table 2 (E2) — the first-layer memory traffic, both as the
//! analytical count and as WALL-CLOCK of the two real memory operations on
//! this host: streaming the eliminated weights (baseline) vs gathering
//! `B·2(d+e)` table rows (precompute).
//!
//! The absolute numbers are host-DRAM numbers, not A100 HBM — what must
//! (and does) hold is the *shape*: precompute wins by orders of magnitude
//! at B=1 and the win shrinks as B amortizes the weight streaming.
//!
//! ```bash
//! cargo bench --bench table_reads
//! ```

use firstlayer::config::zoo_get;
use firstlayer::costmodel;
use firstlayer::manifest::Manifest;
use firstlayer::precompute::Table;
use firstlayer::util::fmt;
use firstlayer::util::rng::Rng;
use firstlayer::util::timer::{bench, report};

/// Simulate the baseline's first-layer weight streaming: touch `n` f32s.
fn stream_weights(buf: &[f32]) -> f32 {
    // Sum with stride 16 (one touch per cacheline) — bandwidth-bound like
    // the real weight read, without being optimized out.
    let mut acc = 0f32;
    let mut i = 0;
    while i < buf.len() {
        acc += buf[i];
        i += 16;
    }
    acc
}

fn main() {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    println!("== bench: first-layer reads, baseline weight streaming vs table gather ==\n");

    // Live table for the runnable model.
    let (table, cfg) = if dir.join("manifest.json").exists() {
        let m = Manifest::load(&dir).unwrap();
        let e = m.model("tiny-serial").unwrap();
        (
            Table::open(m.path(&e.table_file)).unwrap(),
            e.config.clone(),
        )
    } else {
        eprintln!("artifacts missing; synthesizing a table");
        let cfg = zoo_get("tiny-serial").unwrap();
        let w = cfg.precomp_row_width();
        let rows: Vec<f32> = (0..cfg.vocab_size * w).map(|i| i as f32).collect();
        (
            Table::from_rows(1, cfg.d as u32, cfg.e() as u32, 0, &rows, cfg.vocab_size as u32)
                .unwrap(),
            cfg,
        )
    };

    // The baseline streams the eliminated weights each batch.
    let n_weights = costmodel::eliminated_weights(&cfg) as usize;
    let weights: Vec<f32> = vec![1.0; n_weights];
    let mut rng = Rng::new(1);

    println!(
        "model tiny-serial: eliminated weights = {}, row width = {}\n",
        fmt::commas(n_weights as u64),
        table.row_width()
    );
    println!(
        "{:>6} {:>16} {:>16} {:>12} {:>12}",
        "batch", "baseline (ns)", "precomp (ns)", "wall ratio", "paper model"
    );
    for b in [1usize, 4, 16, 64, 256] {
        let tokens: Vec<u32> = (0..b)
            .map(|_| rng.below(table.vocab() as u64) as u32)
            .collect();
        let mut out = vec![0f32; b * table.row_width()];
        let sb = bench(3, 30, || {
            std::hint::black_box(stream_weights(&weights));
        });
        let sp = bench(3, 200, || {
            table.gather(&tokens, &mut out).unwrap();
            std::hint::black_box(&out);
        });
        let ratio = sb.mean.as_nanos() as f64 / sp.mean.as_nanos().max(1) as f64;
        println!(
            "{:>6} {:>16} {:>16} {:>11.0}x {:>11.0}x",
            b,
            sb.mean.as_nanos(),
            sp.mean.as_nanos(),
            ratio,
            costmodel::reduction_factor(&cfg, b as u64) / 16.0, // stride-16 touch
        );
    }

    println!("\n-- gather throughput --");
    for b in [1usize, 8, 64, 512] {
        let tokens: Vec<u32> = (0..b)
            .map(|_| rng.below(table.vocab() as u64) as u32)
            .collect();
        let mut out = vec![0f32; b * table.row_width()];
        let s = bench(10, 300, || {
            table.gather(&tokens, &mut out).unwrap();
            std::hint::black_box(&out);
        });
        let bytes = (b * table.row_width() * 4) as f64;
        report(
            &format!("table.gather B={b}"),
            &s,
            Some((bytes / s.mean.as_secs_f64() / 1e9, "GB/s")),
        );
    }
}
