//! Model + serving configuration.
//!
//! `ModelConfig` mirrors `python/compile/configs.py` — the same zoo, the
//! same derived quantities (`e`, `head_dim`, `precomp_row_width`) — and is
//! additionally reconstructible from the AOT `manifest.json`, which is the
//! authoritative source at serving time (`Manifest::config`).

mod zoo;

pub use zoo::{
    default_prefill_chunk, default_prefix_cache_blocks, default_span_bucket,
    mixtral_like_columns, paper_models, runnable_models, zoo, zoo_get,
};

use crate::error::{Error, Result};

/// Attention/FFN arrangement (paper §1 vs §2).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Arch {
    /// GPT-J/Pythia/PaLM-style parallel attention+FFN: the whole first
    /// layer except attention itself and P is precomputable (Figure 1).
    Parallel,
    /// Llama/Mistral/Mixtral-style serial blocks: only Q/K/V are
    /// precomputable (Figure 2).
    Serial,
}

/// FFN flavor; determines the (2 or 3)·d·h·E weight count of paper table 1.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FfnType {
    /// 2-layer GELU MLP (Pythia).
    Mlp,
    /// SwiGLU GLU-variant (Llama 2, Mistral): w1, w3 gate, w2.
    SwiGlu,
    /// Per-expert SwiGLU with top-k routing (Mixtral).
    SwiGluMoe,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NormType {
    RmsNorm,
    LayerNorm,
}

/// Static description of a transformer model (paper table 1 row).
#[derive(Debug, Clone)]
pub struct ModelConfig {
    pub name: String,
    pub arch: Arch,
    /// Embedding dimension (paper's `d` / `dim`).
    pub d: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub n_kv_heads: usize,
    pub ffn_hidden: usize,
    pub ffn_type: FfnType,
    pub n_experts: usize,
    pub moe_top_k: usize,
    pub vocab_size: usize,
    pub max_seq: usize,
    pub norm_type: NormType,
    pub rope_theta: f64,
    pub norm_eps: f64,
    /// False = learned absolute PE added to the embedding (Figure 2a);
    /// precompute is then unsound and the engine refuses to enable it.
    pub rope: bool,
}

impl ModelConfig {
    /// Output dimension of K and V: `e = d · n_kv_heads / n_heads`
    /// (paper: e=d for MHA, d/n_heads for MQA, scaled for GQA).
    pub fn e(&self) -> usize {
        self.d * self.n_kv_heads / self.n_heads
    }

    pub fn head_dim(&self) -> usize {
        self.d / self.n_heads
    }

    /// Precomputed values stored per token: `2(d+e)` (paper §1).
    pub fn precomp_row_width(&self) -> usize {
        2 * (self.d + self.e())
    }

    /// 2 for plain MLP, 3 for GLU variants (paper table 1's "(2 or 3)").
    pub fn ffn_weight_factor(&self) -> usize {
        match self.ffn_type {
            FfnType::Mlp => 2,
            _ => 3,
        }
    }

    pub fn validate(&self) -> Result<()> {
        if self.n_heads % self.n_kv_heads != 0 {
            return Err(Error::Config(format!(
                "{}: n_heads {} not divisible by n_kv_heads {}",
                self.name, self.n_heads, self.n_kv_heads
            )));
        }
        if self.d % self.n_heads != 0 {
            return Err(Error::Config(format!(
                "{}: d {} not divisible by n_heads {}",
                self.name, self.d, self.n_heads
            )));
        }
        if self.ffn_type != FfnType::SwiGluMoe && self.n_experts != 1 {
            return Err(Error::Config(format!(
                "{}: non-MoE model with {} experts",
                self.name, self.n_experts
            )));
        }
        if self.moe_top_k == 0 || self.moe_top_k > self.n_experts {
            return Err(Error::Config(format!("{}: bad moe_top_k", self.name)));
        }
        Ok(())
    }

    /// Whether the paper's trick applies at all (needs RoPE).
    pub fn precompute_applicable(&self) -> bool {
        self.rope
    }
}

/// Serving-side knobs (the L3 equivalent of a vLLM engine config).
#[derive(Debug, Clone)]
pub struct ServingConfig {
    /// Directory with the AOT bundle (manifest.json etc).
    pub artifacts_dir: String,
    /// Model name (must exist in the manifest).
    pub model: String,
    /// Serve with the precomputed first layer (the paper's trick) or the
    /// baseline path. Both artifact families are always loaded so they can
    /// be compared live.
    pub use_precompute: bool,
    /// Max sequences simultaneously in the decode batch (<= largest
    /// compiled decode bucket).
    pub max_batch: usize,
    /// KV cache blocks (paged allocator pool size) and block size in tokens.
    pub kv_blocks: usize,
    pub kv_block_tokens: usize,
    /// Max new tokens per request unless the request overrides.
    pub max_new_tokens: usize,
    /// Scheduler admission: max waiting->running promotions per step.
    pub max_admit_per_step: usize,
    /// Chunked prefill: split prompts into chunks of this many tokens and
    /// mix them into steps alongside ongoing decodes.  0 = monolithic
    /// whole-prompt prefill (the pre-chunking behavior).  See
    /// `zoo::default_prefill_chunk` for a per-model starting point.
    pub prefill_chunk_tokens: usize,
    /// Per-step token budget shared by decode (one token per sequence,
    /// claimed first) and prefill chunks; 0 = unbounded.  Meaningful only
    /// with chunked prefill: it bounds the compute per engine iteration so
    /// decode latency stays flat while long prompts stream in.
    pub step_token_budget: usize,
    /// Admission control: reject new requests (backpressure) once this
    /// many are already waiting; 0 = unbounded queue.
    pub max_waiting: usize,
    /// Cap on simultaneously open chat conversations (`chat.open`);
    /// 0 = unbounded.  Transcripts are server-held until `chat.close`,
    /// so an uncapped count is a memory-exhaustion vector.
    pub max_conversations: usize,
    /// Cross-request prefix cache (`rust/src/prefixcache/`): keep
    /// finished requests' prompt KV alive in a radix tree so later
    /// requests sharing the prefix (system prompts, few-shot templates)
    /// fork the blocks and prefill only their suffix.
    pub enable_prefix_cache: bool,
    /// Max KV blocks the prefix cache may hold.  0 = per-model default
    /// (`zoo::default_prefix_cache_blocks`); the coordinator
    /// additionally caps the cache at half of `kv_blocks` so serving
    /// always keeps pool headroom (eviction is demand-driven on top).
    pub prefix_cache_blocks: usize,
    /// Device-resident KV (`rust/src/runtime/session.rs`): chain decode
    /// steps and prefill-continuation spans through device-held cache
    /// buffers — one cache upload per span / decode-batch session and
    /// logits-only per-step readback — instead of moving the full dense
    /// cache across the bus every step.  Disabling forces the legacy
    /// host path everywhere (the equivalence oracle); the engine also
    /// falls back by itself if the PJRT wrapper cannot chain buffers.
    pub enable_device_kv: bool,
    /// Batched span execution (`ModelEngine::decode_span` tiling through
    /// the compiled span artifacts): a continuation span of S tokens runs
    /// as `ceil(S/T)` bucketed executions instead of S single-token
    /// decode dispatches.  Disabling forces the token-by-token oracle
    /// everywhere (the equivalence baseline); the engine also falls back
    /// by itself — sticky — if a span-artifact execution fails.
    pub enable_span_exec: bool,
    /// Largest span tile (tokens per span execution) serving may use.
    /// 0 = the largest compiled span bucket; see
    /// `zoo::default_span_bucket` for a per-model starting point.
    pub span_bucket_tokens: usize,
    /// Multi-sequence span batching (`ModelEngine::decode_span_group`):
    /// same-bucket continuation chunks from *different* sequences run as
    /// one `[B, T]` span execution per tile instead of one serial span
    /// per sequence.  Requires `enable_span_exec`; disabling falls back
    /// to the per-sequence span path (the equivalence oracle).  The
    /// engine also falls back by itself — sticky — if a batched span
    /// execution fails.
    pub enable_span_batch: bool,
    /// Server-side speculative decoding (`rust/src/specdec/`): draft up
    /// to a span bucket of tokens from the request's own transcript
    /// (n-gram / prompt-lookup) and verify them in ONE span execution —
    /// the `[T, V]` logits output scores every drafted position.  Only
    /// greedy (temperature 0, no stop sequences) steady-state decoders
    /// are eligible; everything else stays on plain decode, which
    /// remains the always-available oracle.  The health registry
    /// (`PathId::SpecDec`) demotes the path on verify faults or
    /// sustained low acceptance.
    pub enable_spec_decode: bool,
    /// Longest draft the drafter may propose per spec chunk.  The
    /// coordinator additionally caps drafts at one less than the span
    /// bucket so draft + the re-fed last token fill exactly one tile
    /// (spec chunks never pad).
    pub spec_draft_max: usize,
    /// Request-lifecycle tracing (`rust/src/trace/`): record every
    /// request's span tree (queue, prefill chunks, span/group tiles,
    /// decode steps, syncs) with per-phase engine timings, exported via
    /// the `trace.dump` server op as Chrome trace-event JSON.  Off by
    /// default; when off, every instrumentation point is a single
    /// relaxed atomic load (tracing is a pure observer — streams, plans,
    /// and schedule counters are identical either way).
    pub enable_trace: bool,
    /// Completed-request ring capacity for the tracer (last N finished
    /// requests retained; older ones dropped and counted).
    pub trace_ring: usize,
    /// Fault-injection plan (`rust/src/faults/`): `;`-separated rules,
    /// each `<point>:<transient|fatal>[:after=N][:every=N][:count=N]
    /// [:delay_us=N]` with point one of h2d|exec|readback|sync|gather.
    /// Empty = plane disarmed (one relaxed atomic load per boundary
    /// crossing, a pure observer).  Counter-based, so a seeded workload
    /// replays the identical fault sequence every run.
    pub fault_spec: String,
    /// Max retries of a TRANSIENT engine error inside one step before
    /// the affected requests finish with `reason:"error"`.  0 = no
    /// retries (first transient fault is terminal for its requests).
    pub retry_max: usize,
    /// Base backoff before the first retry, doubling per attempt
    /// (capped at 100ms).  0 = retry immediately.
    pub retry_backoff_us: u64,
    /// Engine steps a demoted serving path (device KV / span exec /
    /// span batch) stays down before the health registry re-promotes it
    /// for a recovery probe.  0 = demotion is sticky for the process
    /// lifetime (the pre-ladder behavior).
    pub health_cooldown_steps: u64,
    /// Idle conversation TTL in milliseconds: a conversation with no
    /// submit/finish activity for this long is closed by the sweeper
    /// (active turn cancelled, transcript and KV released).  0 = never
    /// expire (the pre-TTL behavior).
    pub conversation_ttl_ms: u64,
    /// Per-stream writer-queue bound (events): when one client reads
    /// its stream slower than the engine produces, the request is
    /// paused at the scheduler once this many events are queued, and
    /// resumed when the reader drains below half.  Only that stream
    /// stalls — peers and the engine never block.  0 = unbounded.
    pub stream_queue_events: usize,
    /// Per-tenant fair-share scheduling (`scheduler::FairShareConfig`):
    /// admission runs as deficit round-robin across tenants and a
    /// tenant's KV-block footprint is bounded by the pool divided by
    /// live tenants.  Off by default — a pure overlay: with it off,
    /// tenant-tagged workloads plan byte-identically to untagged ones.
    pub enable_fair_share: bool,
    /// DRR quantum in prompt tokens; 0 = auto (max(chunk_tokens, 32)).
    pub fair_quantum_tokens: usize,
    /// DRR accrual cap in quanta (how much credit an idle tenant banks).
    pub fair_burst_quanta: usize,
    /// Overload ladder (`rust/src/overload/`): staged admission-time
    /// load shedding driven by queue-wait p95, free-block shortfall and
    /// step-budget saturation, with hysteresis and rung-by-rung
    /// recovery.  Off by default; in-flight work is never shed.
    pub enable_overload_ladder: bool,
    /// Queue-wait p95 above this many milliseconds is a hot signal.
    pub overload_queue_p95_ms: u64,
    /// Free KV blocks at or below this is a hot signal; 0 = auto
    /// (kv_blocks / 16).
    pub overload_free_block_floor: usize,
    /// Consecutive hot steps before the ladder descends one rung.
    pub overload_trip_steps: u64,
    /// Consecutive calm steps before the ladder re-promotes one rung.
    pub overload_clear_steps: u64,
    /// Retry hint attached to `reason:"shed"` responses, milliseconds.
    pub shed_retry_after_ms: u64,
    /// Sampling defaults.
    pub temperature: f64,
    pub top_k: usize,
    pub seed: u64,
}

impl Default for ServingConfig {
    fn default() -> Self {
        ServingConfig {
            artifacts_dir: "artifacts".to_string(),
            model: "tiny-serial".to_string(),
            use_precompute: true,
            max_batch: 8,
            kv_blocks: 256,
            kv_block_tokens: 16,
            max_new_tokens: 32,
            max_admit_per_step: 4,
            prefill_chunk_tokens: 0,
            step_token_budget: 0,
            max_waiting: 256,
            max_conversations: 1024,
            enable_prefix_cache: true,
            prefix_cache_blocks: 0,
            enable_device_kv: true,
            enable_span_exec: true,
            span_bucket_tokens: 0,
            enable_span_batch: true,
            enable_spec_decode: false,
            spec_draft_max: 16,
            enable_trace: false,
            trace_ring: 256,
            fault_spec: String::new(),
            retry_max: 2,
            retry_backoff_us: 200,
            health_cooldown_steps: 256,
            conversation_ttl_ms: 0,
            stream_queue_events: 1024,
            enable_fair_share: false,
            fair_quantum_tokens: 0,
            fair_burst_quanta: 4,
            enable_overload_ladder: false,
            overload_queue_p95_ms: 50,
            overload_free_block_floor: 0,
            overload_trip_steps: 3,
            overload_clear_steps: 16,
            shed_retry_after_ms: 500,
            temperature: 0.0,
            top_k: 0,
            seed: 0xF17A,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn e_matches_paper_examples() {
        // Paper: MHA e=d (Pythia), GQA e = d*n_kv/n_heads = 1024 (Mistral).
        let p = zoo_get("pythia-6.9b").unwrap();
        assert_eq!(p.e(), 4096);
        let m = zoo_get("mistral-7b").unwrap();
        assert_eq!(m.e(), 1024);
    }

    #[test]
    fn row_width_paper_examples() {
        // Paper table: reads with precompute B=1: Pythia 16,384 = 2(d+e);
        // Mistral 10,240 = 2(4096+1024).
        assert_eq!(zoo_get("pythia-6.9b").unwrap().precomp_row_width(), 16_384);
        assert_eq!(zoo_get("mistral-7b").unwrap().precomp_row_width(), 10_240);
        assert_eq!(zoo_get("mixtral-8x7b").unwrap().precomp_row_width(), 10_240);
    }

    #[test]
    fn zoo_validates() {
        for cfg in zoo() {
            cfg.validate().unwrap();
        }
    }

    #[test]
    fn mqa_e_is_d_over_heads() {
        let mut cfg = zoo_get("pythia-6.9b").unwrap();
        cfg.n_kv_heads = 1; // MQA
        assert_eq!(cfg.e(), cfg.d / cfg.n_heads);
    }

    #[test]
    fn abspe_not_applicable() {
        let cfg = zoo_get("tiny-abspe").unwrap();
        assert!(!cfg.precompute_applicable());
    }

    #[test]
    fn default_chunk_block_aligned_and_floored() {
        for cfg in zoo() {
            let c = default_prefill_chunk(&cfg);
            assert!(c >= 16, "{}: chunk {c} below floor", cfg.name);
            assert_eq!(c % 16, 0, "{}: chunk {c} not block-aligned", cfg.name);
            assert!(
                c <= cfg.max_seq.max(16),
                "{}: chunk {c} exceeds context {}",
                cfg.name,
                cfg.max_seq
            );
        }
        // Paper-scale example: Mistral's 4096 context -> 512-token chunks.
        assert_eq!(default_prefill_chunk(&zoo_get("mistral-7b").unwrap()), 512);
    }

    #[test]
    fn default_span_bucket_divides_default_chunk() {
        for cfg in zoo() {
            let b = default_span_bucket(&cfg);
            assert!((8..=64).contains(&b), "{}: span bucket {b}", cfg.name);
            let chunk = default_prefill_chunk(&cfg);
            // Interior tiles must tile the default chunk exactly — no
            // ragged tail mid-prompt (the scheduler aligns to this).
            assert_eq!(
                chunk % b,
                0,
                "{}: span bucket {b} does not divide chunk {chunk}",
                cfg.name
            );
        }
        // Paper-scale example: Mistral's 4096 context -> 64-token tiles
        // under the 512-token default chunk.
        assert_eq!(default_span_bucket(&zoo_get("mistral-7b").unwrap()), 64);
        // Tiny models stay on their compiled 8-token bucket floor.
        assert_eq!(default_span_bucket(&zoo_get("tiny-serial").unwrap()), 8);
        // And the knob composes into a valid serving config.
        let sc = ServingConfig {
            span_bucket_tokens: default_span_bucket(&zoo_get("mistral-7b").unwrap()),
            ..Default::default()
        };
        assert!(sc.enable_span_exec && sc.span_bucket_tokens == 64);
    }

    #[test]
    fn default_prefix_cache_blocks_valid_for_zoo() {
        for cfg in zoo() {
            // Sized in the serving config's block unit, whatever it is.
            for bt in [8usize, 16, 32] {
                let b = default_prefix_cache_blocks(&cfg, bt);
                assert!(b >= 4, "{}: cache default {b} below floor", cfg.name);
                // Holds at least one full context of `bt`-token blocks.
                assert!(
                    b * bt >= cfg.max_seq,
                    "{}: {b} x {bt}-token blocks cannot hold a {}-token context",
                    cfg.name,
                    cfg.max_seq
                );
            }
            // And composes into a valid serving config for every entry.
            let sc = ServingConfig {
                model: cfg.name.clone(),
                prefix_cache_blocks: default_prefix_cache_blocks(&cfg, 16),
                ..Default::default()
            };
            assert!(sc.enable_prefix_cache);
            assert!(sc.prefix_cache_blocks > 0);
        }
        // Paper-scale example: Mistral's 4096-token context, 16-token
        // blocks -> 256 blocks.
        assert_eq!(
            default_prefix_cache_blocks(&zoo_get("mistral-7b").unwrap(), 16),
            256
        );
    }
}
