//! The model zoo: paper-scale configs (§3) + runnable tiny configs.
//! Must stay in lockstep with `python/compile/configs.py`.

use super::{Arch, FfnType, ModelConfig, NormType};

fn base(name: &str) -> ModelConfig {
    ModelConfig {
        name: name.to_string(),
        arch: Arch::Serial,
        d: 0,
        n_layers: 0,
        n_heads: 1,
        n_kv_heads: 1,
        ffn_hidden: 0,
        ffn_type: FfnType::Mlp,
        n_experts: 1,
        moe_top_k: 1,
        vocab_size: 0,
        max_seq: 0,
        norm_type: NormType::RmsNorm,
        rope_theta: 10_000.0,
        norm_eps: 1e-5,
        rope: true,
    }
}

pub fn pythia_6_9b() -> ModelConfig {
    ModelConfig {
        arch: Arch::Parallel,
        d: 4096,
        n_layers: 32,
        n_heads: 32,
        n_kv_heads: 32, // MHA
        ffn_hidden: 16_384,
        ffn_type: FfnType::Mlp,
        vocab_size: 50_400,
        max_seq: 2048,
        norm_type: NormType::LayerNorm,
        ..base("pythia-6.9b")
    }
}

pub fn mistral_7b() -> ModelConfig {
    ModelConfig {
        arch: Arch::Serial,
        d: 4096,
        n_layers: 32,
        n_heads: 32,
        n_kv_heads: 8, // GQA
        ffn_hidden: 14_336,
        ffn_type: FfnType::SwiGlu,
        vocab_size: 32_000,
        max_seq: 4096,
        ..base("mistral-7b")
    }
}

pub fn mixtral_8x7b() -> ModelConfig {
    ModelConfig {
        ffn_type: FfnType::SwiGluMoe,
        n_experts: 8,
        moe_top_k: 2,
        ..{
            let mut m = mistral_7b();
            m.name = "mixtral-8x7b".into();
            m
        }
    }
}

/// The paper's §3 third column: hypothetical Mixtral with parallel
/// attention/FFN, where the 1.4B-weight first-layer MoE FFN becomes
/// precomputable and total memory *shrinks* by 3%.
pub fn mixtral_8x7b_parallel() -> ModelConfig {
    let mut m = mixtral_8x7b();
    m.name = "mixtral-8x7b-parallel".into();
    m.arch = Arch::Parallel;
    m
}

/// Whisper-tiny-like 4-layer decoder dims (the paper's "max 25% savings"
/// example for few-layer models).
pub fn whisper_tiny4() -> ModelConfig {
    ModelConfig {
        d: 384,
        n_layers: 4,
        n_heads: 6,
        n_kv_heads: 6,
        ffn_hidden: 1536,
        vocab_size: 51_865,
        max_seq: 448,
        norm_type: NormType::LayerNorm,
        ..base("whisper-tiny4")
    }
}

pub fn tiny_parallel() -> ModelConfig {
    ModelConfig {
        arch: Arch::Parallel,
        d: 128,
        n_layers: 4,
        n_heads: 4,
        n_kv_heads: 4,
        ffn_hidden: 512,
        vocab_size: 512,
        max_seq: 128,
        norm_type: NormType::LayerNorm,
        ..base("tiny-parallel")
    }
}

pub fn tiny_serial() -> ModelConfig {
    ModelConfig {
        d: 128,
        n_layers: 4,
        n_heads: 4,
        n_kv_heads: 2,
        ffn_hidden: 384,
        ffn_type: FfnType::SwiGlu,
        vocab_size: 512,
        max_seq: 128,
        ..base("tiny-serial")
    }
}

pub fn tiny_moe() -> ModelConfig {
    ModelConfig {
        d: 64,
        n_layers: 2,
        n_heads: 4,
        n_kv_heads: 2,
        ffn_hidden: 128,
        ffn_type: FfnType::SwiGluMoe,
        n_experts: 4,
        moe_top_k: 2,
        vocab_size: 256,
        max_seq: 64,
        ..base("tiny-moe")
    }
}

pub fn tiny_moe_parallel() -> ModelConfig {
    let mut m = tiny_moe();
    m.name = "tiny-moe-parallel".into();
    m.arch = Arch::Parallel;
    m
}

pub fn tiny_abspe() -> ModelConfig {
    let mut m = tiny_serial();
    m.name = "tiny-abspe".into();
    m.rope = false;
    m
}

/// Every config.
pub fn zoo() -> Vec<ModelConfig> {
    vec![
        pythia_6_9b(),
        mistral_7b(),
        mixtral_8x7b(),
        mixtral_8x7b_parallel(),
        whisper_tiny4(),
        tiny_parallel(),
        tiny_serial(),
        tiny_moe(),
        tiny_moe_parallel(),
        tiny_abspe(),
    ]
}

/// The paper's §3 evaluation trio, in table order.
pub fn paper_models() -> Vec<ModelConfig> {
    vec![pythia_6_9b(), mistral_7b(), mixtral_8x7b()]
}

/// Configs with AOT artifacts (CPU-runnable end to end).
pub fn runnable_models() -> Vec<ModelConfig> {
    vec![tiny_serial(), tiny_parallel(), tiny_moe(), tiny_moe_parallel()]
}

pub fn zoo_get(name: &str) -> Option<ModelConfig> {
    zoo().into_iter().find(|m| m.name == name)
}

/// Serving-side default for `ServingConfig::prefill_chunk_tokens`: roughly
/// an eighth of the context window, rounded down to a multiple of 16 (the
/// default KV block size) and floored at 16.  Big enough that the chunk's
/// batched table gather + QKV work amortizes per-step overhead, small
/// enough that decodes interleave several times per long prompt.
pub fn default_prefill_chunk(cfg: &ModelConfig) -> usize {
    let chunk = (cfg.max_seq / 8) & !15;
    chunk.max(16)
}

/// Serving-side default for `ServingConfig::prefix_cache_blocks`: one
/// context window's worth of KV blocks (`block_tokens` =
/// `ServingConfig::kv_block_tokens`), floored at 4.  System prompts and
/// few-shot templates are a fraction of `max_seq`, so this keeps
/// several tenants' shared prefixes resident; the coordinator caps the
/// cache at half the pool regardless, and eviction is demand-driven, so
/// a generous default never starves serving.
pub fn default_prefix_cache_blocks(cfg: &ModelConfig, block_tokens: usize) -> usize {
    cfg.max_seq.div_ceil(block_tokens.max(1)).max(4)
}

/// Serving-side default for `ServingConfig::span_bucket_tokens`: half
/// the default prefill chunk, clamped to [8, 64].  Derived from the
/// chunk (not the raw context) so interior span tiles divide the chunk
/// exactly — a continuation chunk then tiles with no ragged tail; the
/// clamp keeps tiny models on their compiled bucket floor and
/// paper-scale models from wanting enormous single-tile graphs.
pub fn default_span_bucket(cfg: &ModelConfig) -> usize {
    (default_prefill_chunk(cfg) / 2).clamp(8, 64)
}

/// The three columns of the paper's §3 tables: Pythia-6.9B, Mistral-7B and
/// the hypothetical parallel-attention Mixtral-8x7B.
pub fn mixtral_like_columns() -> Vec<ModelConfig> {
    vec![pythia_6_9b(), mistral_7b(), mixtral_8x7b_parallel()]
}
