//! The coordinator (S9): request lifecycle over engine + scheduler + paged
//! KV cache — the L3 composition the paper's trick plugs into.
//!
//! Per iteration ([`Coordinator::step`]):
//! 1. ask the scheduler for a [`StepPlan`] against the KV budget;
//! 2. apply preemptions (drop caches, fold generated tokens back into the
//!    replay prompt);
//! 3. execute the planned prefill chunks: fresh `start == 0` chunks run
//!    through the batched prefill artifact in compile-bucket-sized groups;
//!    continuation chunks (`start > 0`) advance through decode-kernel
//!    spans whose first layer is one batched precompute-table gather.  The
//!    chunk that completes a prompt samples the first token (TTFT);
//! 4. run speculative verifies for eligible steady-state decoders
//!    ([`crate::specdec`]): one scored span execution checks a
//!    self-drafted chunk, the accepted prefix (plus one bonus token) is
//!    emitted, rejected rows never reach the paged store;
//! 5. assemble the decode batch from the paged store, run one decode step,
//!    scatter the new K/V rows back, sample, detect stops.
//!
//! Prefill chunks and the decode batch share the iteration (the scheduler
//! mixes them under one token budget), so long prompts stream in without
//! head-of-line-blocking generation — see `ARCHITECTURE.md` §step-loop.
//!
//! Both serving paths are first-class: `StepPath::Baseline` embeds tokens
//! in-graph; `StepPath::Precompute` gathers `2(d+e)`-value rows from the
//! mmap'd table (the paper's Figure 1b/2c serving mode).

pub mod sampling;

use std::collections::HashMap;
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::config::ServingConfig;
use crate::error::{Error, Result};
use crate::kvcache::PagedKvCache;
use crate::manifest::Manifest;
use crate::metrics::Metrics;
use crate::prefixcache::PrefixCache;
use crate::runtime::{
    CacheBatch, DeviceCacheSession, ModelEngine, Runtime, SpanLane, StepPath,
};
use crate::scheduler::{
    GroupLane, KvBudget, PrefillChunk, Priority, SchedConfig, Scheduler, State, StepPlan,
};
use crate::specdec::{
    accepted_prefix, AcceptanceWindow, Drafter, NGramDrafter, SpecStats, DEMOTE_MEAN_X100,
};
use crate::tokenizer::{Tokenizer, BOS, EOS};
use crate::trace::{SpanKind, Tracer};
use crate::util::rng::Rng;

use sampling::{sample, SamplingParams};

/// Why a request finished.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FinishReason {
    Eos,
    MaxTokens,
    ContextFull,
    /// A stop sequence matched in the detokenized output
    /// ([`SamplingParams::stop`]).
    Stop,
    /// Aborted by [`Coordinator::cancel`] before a natural finish.
    Cancelled,
    /// Terminal engine failure: a fatal error, or a transient one that
    /// exhausted its retries ([`ServingConfig::retry_max`]).  Every
    /// resource the request held is released; survivors are untouched.
    Error,
}

/// Stable wire/trace label for a [`FinishReason`].
pub fn reason_label(r: FinishReason) -> &'static str {
    match r {
        FinishReason::Eos => "eos",
        FinishReason::MaxTokens => "max_tokens",
        FinishReason::ContextFull => "context_full",
        FinishReason::Stop => "stop",
        FinishReason::Cancelled => "cancelled",
        FinishReason::Error => "error",
    }
}

/// Run an engine operation, retrying transient failures (injected
/// transients and PJRT hiccups — [`Error::is_transient`]) with capped
/// exponential backoff: `backoff_us << attempt`, never above 100ms.
/// Fatal errors and exhausted retries propagate to the caller, which
/// converts them into per-request terminal `Error` finishes.
fn retry_transient<T>(
    metrics: &Metrics,
    retry_max: usize,
    backoff_us: u64,
    what: &str,
    mut f: impl FnMut() -> Result<T>,
) -> Result<T> {
    let mut attempt = 0usize;
    loop {
        match f() {
            Ok(v) => return Ok(v),
            Err(e) if e.is_transient() && attempt < retry_max => {
                attempt += 1;
                metrics
                    .fault_retries
                    .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                eprintln!(
                    "[firstlayer] transient {what} error \
                     (retry {attempt}/{retry_max}): {e}"
                );
                if backoff_us > 0 {
                    let shift = (attempt - 1).min(16) as u32;
                    let us = backoff_us.saturating_mul(1 << shift).min(100_000);
                    std::thread::sleep(std::time::Duration::from_micros(us));
                }
            }
            Err(e) => return Err(e),
        }
    }
}

/// Streaming event surfaced to the server / examples.
///
/// Admission rejections are NOT events: [`Coordinator::submit`] returns
/// them as errors, and the protocol layer reports them on its own
/// channel (the wire `rejected` event, correlated by the request's
/// echoed tag) — deliberately outside the event stream, so a rejection
/// can never perturb a live stream's state.
#[derive(Debug, Clone)]
pub enum Event {
    Token { id: u64, token: u32 },
    Finished { id: u64, reason: FinishReason },
}

/// The one typed request shape every front end submits — server ops,
/// `simtraffic` generators, examples and tests all build this instead
/// of the old `submit_text(&str, usize, SamplingParams)` plumbing.
///
/// Inputs, in precedence order:
/// * `conversation: Some(cv)` — a **turn delta**: the prompt is the
///   conversation's transcript plus `text` (tokenized) or `prompt`
///   (raw ids) appended.  At most one turn per conversation may be in
///   flight.
/// * `text: Some(..)` — tokenized server-side, BOS prepended.
/// * otherwise — `prompt` is used verbatim (no BOS added).
#[derive(Debug, Clone)]
pub struct Request {
    /// Raw token-id prompt (or turn delta when `conversation` is set
    /// and `text` is `None`).
    pub prompt: Vec<u32>,
    /// Text input, tokenized at submit (takes precedence over `prompt`).
    pub text: Option<String>,
    /// Conversation handle from [`Coordinator::chat_open`]: submit this
    /// request as the conversation's next turn.
    pub conversation: Option<u64>,
    pub max_new_tokens: usize,
    pub priority: Priority,
    pub params: SamplingParams,
    /// Client-chosen correlation tag; the coordinator ignores it, the
    /// protocol layer echoes it on every event of this request.
    pub tag: Option<String>,
    /// Tenant id (0 = default/anonymous).  Scopes conversation handles
    /// and, with fair-share scheduling on, the request's resource share.
    pub tenant: u64,
}

impl Request {
    /// Request over raw token ids (no BOS prepended).
    pub fn from_tokens(prompt: Vec<u32>, max_new_tokens: usize) -> Request {
        Request {
            prompt,
            text: None,
            conversation: None,
            max_new_tokens,
            priority: Priority::Normal,
            params: SamplingParams::default(),
            tag: None,
            tenant: 0,
        }
    }

    /// Request over text (tokenized at submit, BOS prepended).
    pub fn from_text(text: impl Into<String>, max_new_tokens: usize) -> Request {
        Request {
            prompt: Vec::new(),
            text: Some(text.into()),
            conversation: None,
            max_new_tokens,
            priority: Priority::Normal,
            params: SamplingParams::default(),
            tag: None,
            tenant: 0,
        }
    }

    /// A conversation turn: `text` appended to `conv`'s transcript.
    pub fn turn(conv: u64, text: impl Into<String>, max_new_tokens: usize) -> Request {
        Request {
            conversation: Some(conv),
            ..Request::from_text(text, max_new_tokens)
        }
    }

    pub fn with_params(mut self, params: SamplingParams) -> Request {
        self.params = params;
        self
    }

    pub fn with_priority(mut self, priority: Priority) -> Request {
        self.priority = priority;
        self
    }

    pub fn with_tag(mut self, tag: impl Into<String>) -> Request {
        self.tag = Some(tag.into());
        self
    }

    pub fn with_tenant(mut self, tenant: u64) -> Request {
        self.tenant = tenant;
        self
    }
}

#[derive(Debug, Default)]
struct ReqState {
    generated: Vec<u32>,
    submit_t: Option<Instant>,
    /// When the request's first prefill chunk was scheduled (queue-wait
    /// end: `queue_wait = first_sched_t - submit_t`).
    first_sched_t: Option<Instant>,
    first_token_t: Option<Instant>,
    done: Option<FinishReason>,
    /// Detokenized tail of the output, kept only while the request has
    /// stop sequences (bounded to the longest stop pattern).
    stop_buf: Vec<u8>,
}

/// One open multi-turn conversation ([`Coordinator::chat_open`]).
///
/// The transcript is the full token history (first turn's BOS included,
/// assistant turns appended on finish).  Each `chat.send` submits
/// `transcript + user delta` as an ordinary request; because finished
/// requests insert their block-aligned **generated** spans into the
/// prefix cache, the next turn's prefill is served from the cache for
/// everything but the new user delta.
#[derive(Debug, Default)]
struct ConvState {
    transcript: Vec<u32>,
    /// Tenant that opened the conversation.  Every `chat.*` op on this
    /// handle must present the same tenant id — possession of the
    /// handle alone no longer crosses the namespace boundary.
    owner: u64,
    /// In-flight request id for the current turn (at most one).
    active: Option<u64>,
    /// The prompt the active turn submitted (transcript + user delta);
    /// becomes the new transcript prefix on finish.
    pending_prompt: Vec<u32>,
    /// Last protocol-level activity (open, turn submit, turn finish) —
    /// the idle clock [`Coordinator::sweep_conversations`] expires on.
    last_activity: Option<Instant>,
}

/// A live device-resident decode session and the batch composition it
/// serves.  While the ids (and serving path) are unchanged step over
/// step, the coordinator chains decode through `sess` — no cache upload,
/// no cache readback, no paged-store append — and tracks how far each
/// row's device cache has run ahead of the host store (`pending`).  Any
/// composition change (finish, admission, preemption, path switch)
/// syncs the pair down once and writes the pending rows back via
/// `append_span`.
struct DecodeSessionState {
    /// Batch composition: ordered ids the session's rows are bound to.
    ids: Vec<u64>,
    path: StepPath,
    /// Paged-store length per row when the session was built.
    base: Vec<usize>,
    /// Tokens decoded on-device per row since then (not yet in the
    /// paged store).
    pending: Vec<usize>,
    sess: DeviceCacheSession,
}

/// A resolved speculative-decode job: request `id` verifies `draft`
/// through one scored span execution this step
/// ([`Coordinator::run_spec_chunk`]).
struct SpecJob {
    id: u64,
    draft: Vec<u32>,
}

struct KvView<'a> {
    kv: &'a PagedKvCache,
    /// Prefix-cache blocks reclaimable on demand (refcount == 1: lease
    /// only).  The planner treats them as free; `Coordinator::step`
    /// evicts exactly the shortfall before executing the plan.
    evictable: usize,
    /// Blocks the live decode session's deferred writeback will consume
    /// (device rows not yet in the paged store).  Subtracted from the
    /// planner's free view so admission can never take space the sync
    /// needs.
    reserved: usize,
    /// The live session, for virtual (device-side) sequence lengths.
    sess: Option<&'a DecodeSessionState>,
}

impl KvBudget for KvView<'_> {
    fn free_blocks(&self) -> usize {
        (self.kv.free_blocks() + self.evictable).saturating_sub(self.reserved)
    }
    fn total_blocks(&self) -> usize {
        self.kv.total_blocks()
    }
    fn blocks_for(&self, tokens: usize) -> usize {
        self.kv.blocks_for(tokens)
    }
    fn blocks_held(&self, id: u64) -> usize {
        self.kv.blocks_held(id)
    }
    fn growth_needs_block(&self, id: u64) -> bool {
        // Session rows grow on the device: judge block demand by the
        // virtual length (base + pending), not the lagging paged store.
        // The next token needs a block only beyond BOTH what the
        // sequence already holds (a pre-allocated spare counts, exactly
        // as in `PagedKvCache::growth_needs_block`) and what `reserved`
        // already earmarks for the writeback (`blocks_for(vlen)`).
        if let Some(d) = self.sess {
            if let Some(i) = d.ids.iter().position(|x| *x == id) {
                if self.kv.seq_len(id) == Some(d.base[i]) {
                    let vlen = d.base[i] + d.pending[i];
                    let covered =
                        self.kv.blocks_for(vlen).max(self.kv.blocks_held(id));
                    return self.kv.blocks_for(vlen + 1) > covered;
                }
            }
        }
        self.kv.growth_needs_block(id)
    }
}

/// The serving coordinator for one model.
pub struct Coordinator {
    engine: Arc<ModelEngine>,
    kv: PagedKvCache,
    sched: Scheduler,
    pub tokenizer: Arc<Tokenizer>,
    pub metrics: Arc<Metrics>,
    path: StepPath,
    rng: Rng,
    next_id: u64,
    reqs: HashMap<u64, ReqState>,
    params: HashMap<u64, SamplingParams>,
    events: Vec<Event>,
    /// Largest usable decode bucket (engine-compiled).
    max_decode_bucket: usize,
    /// Backpressure: reject submits once this many requests wait (0 = off).
    max_waiting: usize,
    /// Cross-request prefix cache (None = disabled): match-on-submit,
    /// insert-on-finish, demand-driven eviction in `step`.
    prefix: Option<PrefixCache>,
    /// Live steady-state decode session, reused while the batch
    /// composition is unchanged; synced to host on recomposition,
    /// preemption, and path switches.  Whether the device path is used
    /// at all lives on the engine (`ModelEngine::device_kv_active`, set
    /// from `ServingConfig::enable_device_kv` at construction).
    dsess: Option<DecodeSessionState>,
    /// Open multi-turn conversations, keyed by the handle
    /// [`Coordinator::chat_open`] returned.
    convs: HashMap<u64, ConvState>,
    /// Request id -> owning conversation, for finish-time transcript
    /// updates.
    conv_of: HashMap<u64, u64>,
    /// Handle entropy: a per-process randomly-keyed hasher state
    /// (OS-seeded, independent of the deterministic sampling rng) so
    /// conversation handles are not predictable from the serving seed.
    conv_keys: std::collections::hash_map::RandomState,
    conv_ctr: u64,
    /// Cap on simultaneously open conversations (0 = unbounded).
    max_convs: usize,
    /// Idle-conversation TTL (None = never expire); swept every step
    /// and from the server's idle loop.
    conv_ttl: Option<Duration>,
    /// Transient-error retry budget per engine operation, and the base
    /// backoff (doubling, capped at 100ms) between attempts.
    retry_max: usize,
    retry_backoff_us: u64,
    /// Lifecycle tracer (shared with the engine's runtime; enabled from
    /// `ServingConfig::enable_trace`, otherwise every call is one
    /// relaxed atomic load).
    tracer: Arc<Tracer>,
    /// Server-side speculative decoding: the self-drafting source (v1
    /// n-gram prompt lookup over each request's own transcript).
    drafter: NGramDrafter,
    /// Per-request draft/accept bookkeeping (kept after finish, like
    /// `reqs` — diagnostics and tests read it post-hoc).
    spec_stats: HashMap<u64, SpecStats>,
    /// Sliding window over verify outcomes; a full window below the
    /// floor demotes `PathId::SpecDec` until the cooldown re-probe.
    accept_win: AcceptanceWindow,
    /// Overload ladder (None = off): ticked once per step from the
    /// pressure signals, gates NEW admissions in [`Coordinator::submit`]
    /// and narrows the scheduler's intake via `set_pressure_level`.
    ladder: Option<crate::overload::OverloadLadder>,
    /// Step token budget (0 = unbounded) — kept for the ladder's
    /// budget-saturation pressure signal.
    step_budget: usize,
    /// Whether the previous step's plan spent its whole token budget.
    last_step_saturated: bool,
    /// Sliding window of recent queue waits (µs), newest at the back —
    /// the ladder's p95 signal.  The cumulative `queue_wait` histogram
    /// never forgets a storm, so recovery needs a window that does:
    /// one stale sample also drains per tick, so pressure fades during
    /// calm even with no new arrivals.  Maintained only when the
    /// ladder is on.
    recent_waits: std::collections::VecDeque<u64>,
}

impl Coordinator {
    /// Build the full stack from a serving config (used by `main`, the
    /// server, examples and integration tests).
    pub fn from_config(cfg: &ServingConfig) -> Result<Coordinator> {
        let rt = Runtime::cpu()?;
        let manifest = Manifest::load(&cfg.artifacts_dir)?;
        let engine = Arc::new(ModelEngine::load(&rt, &manifest, &cfg.model)?);
        Coordinator::new(engine, cfg)
    }

    pub fn new(engine: Arc<ModelEngine>, cfg: &ServingConfig) -> Result<Coordinator> {
        let mc = engine.config().clone();
        let path = if cfg.use_precompute {
            if !mc.precompute_applicable() {
                return Err(Error::Config(format!(
                    "model {} uses absolute PE; precompute is unsound (paper §2)",
                    mc.name
                )));
            }
            StepPath::Precompute
        } else {
            StepPath::Baseline
        };
        let max_decode_bucket = engine
            .entry()
            .decode_buckets(cfg.use_precompute)
            .iter()
            .filter_map(|a| a.batch)
            .max()
            .ok_or_else(|| Error::Engine("no decode artifacts".into()))?;
        let max_prefill_t = engine
            .entry()
            .prefill_buckets(cfg.use_precompute)
            .iter()
            .filter_map(|a| a.prompt_len)
            .max()
            .ok_or_else(|| Error::Engine("no prefill artifacts".into()))?;
        let max_batch = cfg.max_batch.min(max_decode_bucket);
        // Batched span execution: knobs land on the engine first so the
        // scheduler can plan against the tile granularity it will get.
        engine.set_span_exec(cfg.enable_span_exec);
        engine.set_span_bucket_cap(cfg.span_bucket_tokens);
        engine.set_span_batch(cfg.enable_span_batch);
        let span_bucket = if cfg.enable_span_exec {
            // Both path families compile the same buckets; the initial
            // path's view is representative across live path switches.
            engine.max_span_bucket(path)
        } else {
            0
        };
        // Multi-sequence span groups: the scheduler composes same-bucket
        // continuation chunks up to the widest compiled span batch.  0
        // (knob off, or a pre-batch AOT bundle) keeps plans group-free.
        let span_lanes = if cfg.enable_span_exec && cfg.enable_span_batch {
            engine.max_span_batch(path)
        } else {
            0
        };
        // Speculative decoding rides the scored span kernel: the draft
        // cap is one below the largest span bucket so a verify span
        // (re-fed last token + draft) fills exactly one tile — a drafted
        // chunk never pads and never spills into a second execution.
        // Without span tiles of >= 2 tokens there is no batched verify,
        // so speculation stays off regardless of the knob.
        let spec_tokens = if cfg.enable_spec_decode && span_bucket >= 2 {
            cfg.spec_draft_max.min(span_bucket - 1)
        } else {
            0
        };
        engine.set_spec_decode(spec_tokens > 0);
        let mut sched = Scheduler::new(SchedConfig {
            max_batch,
            max_admit: cfg.max_admit_per_step,
            max_prompt: max_prefill_t,
            max_seq: mc.max_seq,
            chunk_tokens: cfg.prefill_chunk_tokens,
            step_token_budget: cfg.step_token_budget,
            span_bucket_tokens: span_bucket,
            span_group_lanes: span_lanes,
            spec_tokens,
        });
        // Per-tenant fair share: a pure overlay on the planner — installed
        // only when enabled so the off state is byte-identical planning.
        if cfg.enable_fair_share {
            sched.set_fair_share(crate::scheduler::FairShareConfig {
                enabled: true,
                quantum_tokens: cfg.fair_quantum_tokens,
                burst_quanta: cfg.fair_burst_quanta,
            });
        }
        // Overload ladder: staged admission-time shedding.  The free-block
        // floor's auto default scales with the pool (one sixteenth).
        let ladder = cfg.enable_overload_ladder.then(|| {
            crate::overload::OverloadLadder::new(crate::overload::OverloadConfig {
                queue_p95_us: cfg.overload_queue_p95_ms.saturating_mul(1000),
                free_block_floor: if cfg.overload_free_block_floor == 0 {
                    (cfg.kv_blocks / 16).max(1)
                } else {
                    cfg.overload_free_block_floor
                },
                trip_steps: cfg.overload_trip_steps.max(1),
                clear_steps: cfg.overload_clear_steps.max(1),
                retry_after_ms: cfg.shed_retry_after_ms,
            })
        });
        let kv = PagedKvCache::new(
            cfg.kv_blocks,
            cfg.kv_block_tokens,
            mc.n_layers,
            mc.n_kv_heads,
            mc.head_dim(),
        );
        let tokenizer = Arc::new(Tokenizer::train_or_fallback(
            crate::tokenizer::bundled_corpus(),
            mc.vocab_size,
        )?);
        // Prefix cache: per-model default when the knob is 0, and never
        // more than half the pool — serving keeps headroom even before
        // demand-driven eviction kicks in.
        let prefix = if cfg.enable_prefix_cache {
            let want = if cfg.prefix_cache_blocks == 0 {
                crate::config::default_prefix_cache_blocks(&mc, cfg.kv_block_tokens)
            } else {
                cfg.prefix_cache_blocks
            };
            let cap = want.min(cfg.kv_blocks / 2);
            (cap > 0).then(|| PrefixCache::new(cfg.kv_block_tokens, cap))
        } else {
            None
        };
        engine.set_device_kv(cfg.enable_device_kv);
        // Fault plane + degradation ladder: the plane is shared with the
        // runtime (the injection points live at the engine/device
        // boundaries), and the health registry's cooldown clock advances
        // once per `step()` — engine-only users never tick it, so their
        // demotions stay sticky exactly as before the ladder.
        if !cfg.fault_spec.is_empty() {
            let n = engine.faults().install(&cfg.fault_spec)?;
            eprintln!("[firstlayer] fault plane armed: {n} rule(s)");
        }
        engine.health().set_cooldown(cfg.health_cooldown_steps);
        let tracer = engine.tracer();
        tracer.configure(cfg.enable_trace, cfg.trace_ring);
        Ok(Coordinator {
            engine,
            kv,
            sched,
            tokenizer,
            metrics: Arc::new(Metrics::new()),
            path,
            rng: Rng::new(cfg.seed),
            next_id: 1,
            reqs: HashMap::new(),
            params: HashMap::new(),
            events: Vec::new(),
            max_decode_bucket,
            max_waiting: cfg.max_waiting,
            prefix,
            dsess: None,
            convs: HashMap::new(),
            conv_of: HashMap::new(),
            conv_keys: std::collections::hash_map::RandomState::new(),
            conv_ctr: 0,
            max_convs: cfg.max_conversations,
            conv_ttl: (cfg.conversation_ttl_ms > 0)
                .then(|| Duration::from_millis(cfg.conversation_ttl_ms)),
            retry_max: cfg.retry_max,
            retry_backoff_us: cfg.retry_backoff_us,
            tracer,
            drafter: NGramDrafter::default(),
            spec_stats: HashMap::new(),
            accept_win: AcceptanceWindow::new(),
            ladder,
            step_budget: cfg.step_token_budget,
            last_step_saturated: false,
            recent_waits: std::collections::VecDeque::new(),
        })
    }

    /// The lifecycle tracer (served by the `trace.dump` op; see
    /// [`crate::trace`]).
    pub fn tracer(&self) -> Arc<Tracer> {
        self.tracer.clone()
    }

    pub fn engine(&self) -> &ModelEngine {
        &self.engine
    }

    pub fn path(&self) -> StepPath {
        self.path
    }

    /// Per-request speculative-decoding statistics (drafts proposed,
    /// tokens accepted, rollbacks).  Kept after the request finishes,
    /// like the transcript itself; `None` when the request never hit a
    /// draft attempt (spec off, ineligible, or unknown id).
    pub fn spec_stats(&self, id: u64) -> Option<SpecStats> {
        self.spec_stats.get(&id).copied()
    }

    /// Largest compiled decode bucket for the active path.
    pub fn max_decode_bucket(&self) -> usize {
        self.max_decode_bucket
    }

    /// Switch the serving path live (both artifact families are loaded).
    /// A live decode session is bound to its path's artifacts, so it is
    /// synced to host before the switch.
    pub fn set_path(&mut self, path: StepPath) -> Result<()> {
        if path != StepPath::Baseline && !self.engine.config().rope {
            return Err(Error::Config("precompute needs RoPE".into()));
        }
        if path != self.path {
            self.sync_or_recompute(&[])?;
        }
        self.path = path;
        Ok(())
    }

    /// Whether a device-resident decode session is currently live
    /// (diagnostics and tests).
    pub fn device_session_active(&self) -> bool {
        self.dsess.is_some()
    }

    /// Submit a typed [`Request`]; returns the request id.  Errors with
    /// [`Error::Backpressure`] when the waiting queue is full — the server
    /// surfaces this as a `rejected` protocol event so clients can retry
    /// elsewhere instead of piling onto a saturated engine — and with
    /// [`Error::Chat`] when a turn targets an unknown conversation or one
    /// whose previous turn is still in flight.
    pub fn submit(&mut self, req: Request) -> Result<u64> {
        let Request {
            prompt,
            text,
            conversation,
            max_new_tokens,
            priority,
            params,
            tag: _,
            tenant,
        } = req;
        // Overload ladder: shed NEW work before any state is touched.
        // Strictly an intake decision — in-flight requests (including a
        // conversation's active turn) are never shed — and counted in
        // `requests_shed`, not `requests_rejected`: the response is
        // retriable by design, not a client error.
        if let Some(l) = &self.ladder {
            if !l.admits(priority) {
                self.metrics
                    .requests_shed
                    .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                return Err(Error::Shed {
                    msg: format!(
                        "overload level {} ({})",
                        l.level().index(),
                        l.level().label()
                    ),
                    retry_after_ms: l.config().retry_after_ms,
                });
            }
        }
        // Resolve the input to a token prompt (turn delta > text > ids).
        let reject = |m: &Metrics, e: Error| {
            m.requests_rejected
                .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            e
        };
        let (prompt, conv) = match conversation {
            Some(cv) => {
                let Some(cs) = self.convs.get(&cv) else {
                    return Err(reject(
                        &self.metrics,
                        Error::Chat(format!("unknown conversation {cv}")),
                    ));
                };
                // Conversation namespaces: the handle is scoped to the
                // tenant that opened it — a guessed or leaked handle is
                // useless across the boundary.
                if cs.owner != tenant {
                    return Err(reject(
                        &self.metrics,
                        Error::CrossTenant(format!(
                            "conversation {cv} is not owned by tenant {tenant}"
                        )),
                    ));
                }
                if let Some(active) = cs.active {
                    return Err(reject(
                        &self.metrics,
                        Error::Chat(format!(
                            "conversation {cv} already has a turn in flight \
                             (request {active})"
                        )),
                    ));
                }
                let tlen = cs.transcript.len();
                let mut p = cs.transcript.clone();
                if p.is_empty() {
                    p.push(BOS);
                }
                match &text {
                    Some(t) => p.extend(self.tokenizer.encode(t)),
                    None => p.extend_from_slice(&prompt),
                }
                (p, Some((cv, tlen)))
            }
            None => match &text {
                Some(t) => {
                    let mut p = vec![BOS];
                    p.extend(self.tokenizer.encode(t));
                    (p, None)
                }
                None => (prompt, None),
            },
        };
        if self.max_waiting > 0 && self.sched.n_waiting() >= self.max_waiting {
            self.metrics
                .requests_rejected
                .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            return Err(Error::Backpressure(format!(
                "waiting queue full ({} requests)",
                self.max_waiting
            )));
        }
        let id = self.next_id;
        // Prefix-cache match BEFORE the scheduler takes ownership of the
        // prompt: a hit forks the cached blocks into the new sequence so
        // the scheduler plans (and the engine executes) only the suffix.
        // For a conversation turn the transcript IS the prompt prefix, so
        // this is where multi-turn reuse happens.
        let hit = self
            .prefix
            .as_mut()
            .map(|pc| pc.match_prefix(&prompt))
            .filter(|m| m.tokens > 0);
        let pending = conv.map(|_| prompt.clone());
        let prompt_len = prompt.len();
        match self
            .sched
            .submit_tenant(id, prompt, max_new_tokens, priority, tenant)
        {
            Ok(()) => {
                self.next_id += 1;
                self.metrics
                    .requests_in
                    .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                self.reqs.insert(
                    id,
                    ReqState {
                        submit_t: Some(Instant::now()),
                        ..Default::default()
                    },
                );
                self.tracer.req_submit(id, prompt_len);
                self.params.insert(id, params);
                if let Some(m) = hit {
                    // Sharing moves only refcounts, so this cannot fail
                    // for lack of pool space; treat any error as a miss.
                    if self.kv.create_shared(id, &m.blocks, m.tokens).is_ok() {
                        self.sched.set_prefilled(id, m.tokens);
                        self.record_prefix_hit(m.tokens);
                        self.tracer.req_mark(id, "prefix_hit", m.tokens as u64);
                        // Chat reuse counts only the span served out of
                        // THIS conversation's own transcript — a first
                        // turn hitting another request's cached prompt
                        // is ordinary prefix reuse, not multi-turn
                        // reuse, and must not inflate the chat metric.
                        if let Some((_, tlen)) = conv {
                            self.metrics.chat_reused_tokens.fetch_add(
                                m.tokens.min(tlen) as u64,
                                std::sync::atomic::Ordering::Relaxed,
                            );
                        }
                    } else {
                        self.record_prefix_miss();
                    }
                } else if self.prefix.is_some() {
                    self.record_prefix_miss();
                }
                if let (Some((cv, _)), Some(p)) = (conv, pending) {
                    if let Some(cs) = self.convs.get_mut(&cv) {
                        cs.active = Some(id);
                        cs.pending_prompt = p;
                        cs.last_activity = Some(Instant::now());
                        self.conv_of.insert(id, cv);
                    }
                }
                Ok(id)
            }
            Err(e) => {
                self.metrics
                    .requests_rejected
                    .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                Err(e)
            }
        }
    }

    /// Abort an in-flight request: release its KV blocks and scheduler
    /// state, emit a terminal [`Event::Finished`] with
    /// [`FinishReason::Cancelled`], and finalize its conversation turn
    /// (partial output included) if it was one.
    ///
    /// Safe against the device-resident decode path: if the live
    /// [`DeviceCacheSession`] serves this id, the session is synced (the
    /// *other* rows written back, this id's device-ahead rows dropped)
    /// BEFORE the store removal — exactly the preemption ordering, so a
    /// recycled slot can never alias a stale device row.
    pub fn cancel(&mut self, id: u64) -> Result<()> {
        match self.reqs.get(&id) {
            None => {
                return Err(Error::Cancel(format!("unknown request {id}")));
            }
            Some(st) if st.done.is_some() => {
                return Err(Error::Cancel(format!(
                    "request {id} already finished"
                )));
            }
            Some(_) => {}
        }
        if self
            .dsess
            .as_ref()
            .is_some_and(|d| d.ids.contains(&id))
        {
            self.sync_or_recompute(&[id])?;
        }
        if self.kv.seq_len(id).is_some() {
            self.kv.remove(id)?;
        }
        self.sched.forget(id);
        self.finish_conv_turn(id, FinishReason::Cancelled);
        if let Some(st) = self.reqs.get_mut(&id) {
            st.done = Some(FinishReason::Cancelled);
            if let Some(t) = st.submit_t {
                self.metrics.e2e.record(t.elapsed());
            }
        }
        self.metrics
            .requests_cancelled
            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        let gen = self.reqs.get(&id).map_or(0, |r| r.generated.len());
        self.tracer.req_finish(id, "cancelled", gen);
        self.events.push(Event::Finished {
            id,
            reason: FinishReason::Cancelled,
        });
        Ok(())
    }

    /// Terminal failure of one request after its retries are exhausted
    /// (or on a fatal engine error): release every resource it holds —
    /// device-session rows, KV blocks, prefix leases (refcounts drop
    /// with the blocks), scheduler state, conversation turn — and emit
    /// a terminal [`FinishReason::Error`] event.  Survivors are never
    /// perturbed: this is [`Coordinator::cancel`]'s teardown driven by
    /// the engine instead of the client.  Idempotent on unknown or
    /// already-finished ids.
    fn fail_request(&mut self, id: u64, err: &Error) -> Result<()> {
        match self.reqs.get(&id) {
            None => return Ok(()),
            Some(st) if st.done.is_some() => return Ok(()),
            Some(_) => {}
        }
        eprintln!("[firstlayer] request {id} failed terminally: {err}");
        if self
            .dsess
            .as_ref()
            .is_some_and(|d| d.ids.contains(&id))
        {
            // Write the OTHER rows back; drop this id's device-ahead
            // rows (the preemption/cancel ordering — a recycled slot
            // can never alias a stale device row).
            self.sync_or_recompute(&[id])?;
        }
        if self.kv.seq_len(id).is_some() {
            self.kv.remove(id)?;
        }
        self.sched.forget(id);
        self.finish_conv_turn(id, FinishReason::Error);
        if let Some(st) = self.reqs.get_mut(&id) {
            st.done = Some(FinishReason::Error);
            if let Some(t) = st.submit_t {
                self.metrics.e2e.record(t.elapsed());
            }
        }
        self.metrics
            .requests_errored
            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        let gen = self.reqs.get(&id).map_or(0, |r| r.generated.len());
        self.tracer.req_finish(id, "error", gen);
        self.events.push(Event::Finished {
            id,
            reason: FinishReason::Error,
        });
        Ok(())
    }

    fn fail_requests(&mut self, ids: &[u64], err: &Error) -> Result<()> {
        for id in ids {
            self.fail_request(*id, err)?;
        }
        Ok(())
    }

    /// Open a multi-turn conversation; returns its handle.  Turns are
    /// submitted via [`Request::turn`] (one in flight at a time) and the
    /// transcript grows by `user delta + assistant output` per turn.
    ///
    /// Handles are **capabilities**: conversations are engine-global
    /// (they survive reconnects), so possession of the handle is the
    /// authorization.  Handles are derived from an OS-seeded per-process
    /// random hasher state — NOT the deterministic sampling rng, whose
    /// stream is reproducible from `ServingConfig::seed` — and kept
    /// below 2^53 so they round-trip JSON number encoding exactly.
    ///
    /// Errors with [`Error::Backpressure`] at the
    /// [`ServingConfig::max_conversations`] cap — an uncapped `chat.open`
    /// would be a trivial memory-exhaustion vector (transcripts are
    /// server-held and live until [`Coordinator::chat_close`]).
    pub fn chat_open(&mut self) -> Result<u64> {
        self.chat_open_for(0)
    }

    /// [`Coordinator::chat_open`] scoped to a tenant: every later
    /// `chat.*` op on the handle must present the same tenant id (the
    /// per-client namespace on top of the unguessable handle).
    pub fn chat_open_for(&mut self, tenant: u64) -> Result<u64> {
        if self.max_convs > 0 && self.convs.len() >= self.max_convs {
            return Err(Error::Backpressure(format!(
                "conversation limit reached ({})",
                self.max_convs
            )));
        }
        use std::hash::{BuildHasher, Hasher};
        let cv = loop {
            self.conv_ctr = self.conv_ctr.wrapping_add(1);
            let mut h = self.conv_keys.build_hasher();
            h.write_u64(self.conv_ctr);
            let c = h.finish() & ((1u64 << 53) - 1);
            if c != 0 && !self.convs.contains_key(&c) {
                break c;
            }
        };
        self.convs.insert(
            cv,
            ConvState {
                owner: tenant,
                last_activity: Some(Instant::now()),
                ..ConvState::default()
            },
        );
        Ok(cv)
    }

    /// Close every conversation idle past [`ServingConfig::conversation_ttl_ms`]
    /// (no open/submit/finish activity): the active turn, if any, is
    /// cancelled, the transcript is dropped, and all KV is released.
    /// Returns how many expired.  No-op when the TTL is off; called
    /// once per engine step and from the server's idle loop.
    pub fn sweep_conversations(&mut self) -> Result<usize> {
        let Some(ttl) = self.conv_ttl else {
            return Ok(0);
        };
        let expired: Vec<u64> = self
            .convs
            .iter()
            .filter(|(_, cs)| cs.last_activity.map_or(true, |t| t.elapsed() >= ttl))
            .map(|(cv, _)| *cv)
            .collect();
        let n = expired.len();
        for cv in expired {
            self.chat_close(cv)?;
            self.metrics
                .conversations_expired
                .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            self.tracer.global_mark("conv_expire", cv);
        }
        Ok(n)
    }

    /// Stream flow control: pause/resume one request's scheduling (the
    /// server calls this when a slow reader's per-tag writer queue hits
    /// its bound).  Pausing is planner-only — state, KV, and generated
    /// tokens are untouched, peers and the engine never block — and the
    /// changed decode composition triggers the ordinary device-session
    /// recomposition sync.  Counts stall *transitions* in
    /// `stream_stalls`.
    pub fn set_stalled(&mut self, id: u64, stalled: bool) {
        if self.sched.set_paused(id, stalled) && stalled {
            self.metrics
                .stream_stalls
                .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            self.tracer.req_mark(id, "stream_stall", 1);
        }
    }

    /// Close a conversation, cancelling its in-flight turn if any.
    /// Tenant-blind (internal callers: the TTL sweeper); the protocol
    /// layer goes through [`Coordinator::chat_close_for`].
    pub fn chat_close(&mut self, conv: u64) -> Result<()> {
        let active = self
            .convs
            .get(&conv)
            .ok_or_else(|| Error::Chat(format!("unknown conversation {conv}")))?
            .active;
        if let Some(id) = active {
            self.cancel(id)?;
        }
        self.convs.remove(&conv);
        Ok(())
    }

    /// [`Coordinator::chat_close`] with the namespace check: only the
    /// opening tenant may close the handle.
    pub fn chat_close_for(&mut self, conv: u64, tenant: u64) -> Result<()> {
        let owner = self
            .convs
            .get(&conv)
            .ok_or_else(|| Error::Chat(format!("unknown conversation {conv}")))?
            .owner;
        if owner != tenant {
            return Err(Error::CrossTenant(format!(
                "conversation {conv} is not owned by tenant {tenant}"
            )));
        }
        self.chat_close(conv)
    }

    /// The conversation's token transcript so far (None if unknown).
    pub fn chat_transcript(&self, conv: u64) -> Option<&[u32]> {
        self.convs.get(&conv).map(|c| c.transcript.as_slice())
    }

    /// Open conversations (diagnostics).
    pub fn chat_count(&self) -> usize {
        self.convs.len()
    }

    /// Fold a finishing (or cancelled) turn back into its conversation:
    /// the transcript becomes the submitted prompt plus everything
    /// generated (a trailing EOS is dropped — it would sit mid-sequence
    /// in the next turn's prompt).
    fn finish_conv_turn(&mut self, id: u64, reason: FinishReason) {
        let Some(cv) = self.conv_of.remove(&id) else {
            return;
        };
        let Some(cs) = self.convs.get_mut(&cv) else {
            return;
        };
        let mut t = std::mem::take(&mut cs.pending_prompt);
        if let Some(r) = self.reqs.get(&id) {
            t.extend_from_slice(&r.generated);
        }
        if reason == FinishReason::Eos && t.last() == Some(&EOS) {
            t.pop();
        }
        cs.transcript = t;
        cs.active = None;
        cs.last_activity = Some(Instant::now());
        if !matches!(reason, FinishReason::Cancelled | FinishReason::Error) {
            self.metrics
                .chat_turns
                .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        }
    }

    /// Record a submit-time match.  Preemption re-matches are *not*
    /// recorded: every prefix counter is strictly per-request (one
    /// sample per accepted request), so hits / (hits + misses) is a
    /// true hit rate even when requests are preempted and re-matched.
    fn record_prefix_hit(&self, tokens: usize) {
        use std::sync::atomic::Ordering::Relaxed;
        self.metrics.prefix_hits.fetch_add(1, Relaxed);
        self.metrics
            .prefix_cached_tokens
            .fetch_add(tokens as u64, Relaxed);
        self.metrics.cached_tokens.record(tokens as u64);
    }

    /// First time a request's work is scheduled onto the engine: close
    /// the queue-wait window (`submit → first scheduled chunk`) and the
    /// trace's queue span.  Idempotent per request.
    fn mark_sched(&mut self, id: u64) {
        if let Some(st) = self.reqs.get_mut(&id) {
            if st.first_sched_t.is_none() {
                let now = Instant::now();
                st.first_sched_t = Some(now);
                if let Some(t) = st.submit_t {
                    let wait = now.duration_since(t);
                    self.metrics.queue_wait.record(wait);
                    if self.ladder.is_some() {
                        if self.recent_waits.len() >= 256 {
                            self.recent_waits.pop_front();
                        }
                        self.recent_waits.push_back(wait.as_micros() as u64);
                    }
                }
                self.tracer.req_first_sched(id);
            }
        }
    }

    fn record_prefix_miss(&self) {
        use std::sync::atomic::Ordering::Relaxed;
        self.metrics.prefix_misses.fetch_add(1, Relaxed);
        self.metrics.cached_tokens.record(0);
    }

    /// Blocks the prefix cache currently holds (0 when disabled) —
    /// diagnostics and tests.
    pub fn prefix_cache_blocks_held(&self) -> usize {
        self.prefix.as_ref().map_or(0, |pc| pc.held_blocks())
    }

    /// Whether any request is still in flight.
    pub fn busy(&self) -> bool {
        self.sched.n_waiting() + self.sched.n_running() > 0
    }

    /// Drain accumulated streaming events.
    pub fn take_events(&mut self) -> Vec<Event> {
        std::mem::take(&mut self.events)
    }

    /// Generated tokens so far (including after completion).
    pub fn generated(&self, id: u64) -> Option<&[u32]> {
        self.reqs.get(&id).map(|r| r.generated.as_slice())
    }

    pub fn finished(&self, id: u64) -> Option<FinishReason> {
        self.reqs.get(&id).and_then(|r| r.done)
    }

    /// Advance the degradation ladder's cooldown clock one step and
    /// surface transitions: re-promotions are announced (trace instant +
    /// stderr), and the metrics mirrors of the registry totals are
    /// refreshed so `metrics` / `metrics.prom` always show the ladder's
    /// current counts.
    fn tick_health(&mut self) {
        use std::sync::atomic::Ordering::Relaxed;
        let health = self.engine.health();
        for p in health.tick() {
            eprintln!(
                "[firstlayer] health: {} re-promoted after cooldown \
                 (next use is the recovery probe)",
                p.label()
            );
            self.tracer.global_mark("health_promote", p.index() as u64);
        }
        let dem = health.total_demotions();
        if dem > self.metrics.health_demotions.swap(dem, Relaxed) {
            self.tracer.global_mark("health_demote", dem);
        }
        self.metrics
            .health_promotions
            .store(health.total_promotions(), Relaxed);
        self.metrics
            .fault_injected
            .store(self.engine.faults().fired_total(), Relaxed);
    }

    /// Current overload-ladder rung (0 when the ladder is off).
    pub fn shed_level(&self) -> u8 {
        self.ladder.as_ref().map_or(0, |l| l.level().index())
    }

    /// Lifetime ladder transitions `(descents, ascents)` — the overload
    /// audit asserts a storm fully re-promotes (`descents == ascents`).
    pub fn shed_transitions(&self) -> (u64, u64) {
        self.ladder
            .as_ref()
            .map_or((0, 0), |l| (l.demotions(), l.promotions()))
    }

    /// Feed the overload ladder one pressure sample and propagate rung
    /// changes to the scheduler's intake, the `shed_ladder_level`
    /// gauge, and the trace.  The queue-wait signal is the p95 of the
    /// sliding window (the cumulative histogram never forgets a storm);
    /// one stale sample drains per tick so calm actually clears it.
    fn tick_overload(&mut self) {
        use std::sync::atomic::Ordering::Relaxed;
        let Some(l) = self.ladder.as_mut() else {
            return;
        };
        let p95 = if self.recent_waits.is_empty() {
            0
        } else {
            let mut w: Vec<u64> = self.recent_waits.iter().copied().collect();
            w.sort_unstable();
            w[(w.len() - 1).min(w.len() * 95 / 100)]
        };
        self.recent_waits.pop_front();
        let p = crate::overload::Pressure {
            queue_wait_p95_us: p95,
            free_blocks: self.kv.free_blocks(),
            budget_saturated: self.last_step_saturated,
        };
        if let Some((from, to)) = l.tick(&p) {
            eprintln!(
                "[firstlayer] overload ladder: {} -> {} (queue_p95={}us \
                 free_blocks={} budget_saturated={})",
                from.label(),
                to.label(),
                p.queue_wait_p95_us,
                p.free_blocks,
                p.budget_saturated,
            );
            self.tracer.global_mark("shed_ladder", to.index() as u64);
        }
        let lvl = l.level().index();
        self.metrics.shed_ladder_level.store(lvl as u64, Relaxed);
        self.sched.set_pressure_level(lvl);
    }

    /// Run one engine iteration. Returns the number of sequences touched.
    ///
    /// Failure containment: every engine-facing sub-operation is retried
    /// on transient errors ([`retry_transient`]) and, if it still fails,
    /// terminates ONLY the requests it was serving via
    /// [`Coordinator::fail_request`] — the step itself keeps going, so a
    /// poisoned request (or an injected fault burst) can never wedge the
    /// loop or perturb surviving streams.  Errors that escape this
    /// method are host-side invariant violations (paged-store
    /// corruption), not request failures.
    pub fn step(&mut self) -> Result<usize> {
        self.tick_health();
        self.tick_overload();
        self.sweep_conversations()?;
        // The planner sees reclaimable prefix-cache blocks (lease-only
        // refcounts) as free; the shortfall is evicted below, after the
        // plan's actual block demand is known.  Blocks the live decode
        // session's deferred writeback will need are subtracted from the
        // free view instead (the sync must never lose a race to
        // admission).
        let evictable = self
            .prefix
            .as_ref()
            .map_or(0, |pc| pc.evictable_blocks(&self.kv));
        let reserved = self.session_writeback_blocks(&[]);
        let plan = self.sched.plan(&KvView {
            kv: &self.kv,
            evictable,
            reserved,
            sess: self.dsess.as_ref(),
        });
        // Budget saturation feeds the NEXT tick's overload sample: a plan
        // that fills the whole step-token budget means demand exceeds
        // device throughput right now.
        self.last_step_saturated = {
            let planned = plan.decode.len()
                + plan.prefill.iter().map(|c| c.len).sum::<usize>()
                + plan.spec.iter().map(|s| s.max_draft).sum::<usize>();
            self.step_budget > 0 && planned >= self.step_budget
        };
        let mut touched = 0;

        // -- speculative-decode resolution -----------------------------------
        // Draft and gate the plan's `SpecChunk`s BEFORE the session-reuse
        // check: a spec'd id leaves the plain-decode batch for this step,
        // so the live device session must match the REMAINDER (forcing a
        // sync whenever a session member starts verifying — the paged
        // store catches up to its virtual length first).  Decode ids the
        // planner moved onto spare span-group lanes already left
        // `plan.decode`, so they force the same sync for free.
        let spec_jobs = self.resolve_spec_intents(&plan);
        let rest: Vec<u64> = plan
            .decode
            .iter()
            .copied()
            .filter(|id| !spec_jobs.iter().any(|j| j.id == *id))
            .collect();

        // -- device-session sync on recomposition ---------------------------
        // The session survives only while this plan decodes exactly its
        // ids on its path.  Otherwise write the device-ahead rows back
        // BEFORE preemption removals can recycle a victim's id (a
        // preempted-and-replayed sequence could otherwise coincide with
        // a stale row's expected length).  Victims' pending rows are
        // dropped, not written back — preemption recomputes them from
        // the replay prompt anyway.
        let reuse = self
            .dsess
            .as_ref()
            .is_some_and(|d| d.path == self.path && d.ids == rest);
        if !reuse {
            self.sync_or_recompute(&plan.preempt)?;
        }

        // -- preemptions ----------------------------------------------------
        for id in &plan.preempt {
            self.kv.remove(*id)?;
            let gen = self
                .reqs
                .get(id)
                .map(|r| r.generated.clone())
                .unwrap_or_default();
            self.sched.extend_prompt(*id, &gen);
            self.metrics
                .preemptions
                .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            self.tracer.req_mark(*id, "preempt", gen.len() as u64);
        }

        // -- demand-driven prefix-cache eviction -----------------------------
        // Make the blocks this plan will allocate actually free (the
        // planner counted reclaimable cache blocks as such).  Demand is
        // a cheap upper bound (every chunk grows to its end + a first-
        // token slot; every decode at a block boundary takes one block);
        // over-evicting a little only trims cold cache entries.  This
        // runs after preempt removals (their shared blocks just became
        // evictable) and before the preempt re-matches below (a re-match
        // fork pins blocks, which must not shrink the evictable supply
        // this step's execution was promised).
        if self.prefix.is_some() {
            let mut demand = 0usize;
            for c in &plan.prefill {
                let end = c.start + c.len + 1;
                demand += self
                    .kv
                    .blocks_for(end)
                    .saturating_sub(self.kv.blocks_held(c.id));
            }
            // A reused device session appends nothing to the paged store
            // this step (rows accumulate on-device; their blocks are
            // reserved in the planner's view and claimed at sync time).
            if !reuse {
                for id in &rest {
                    if self.kv.growth_needs_block(*id) {
                        demand += 1;
                    }
                }
            }
            // Group-riding decode lanes and speculative verifies append
            // host-side this step regardless of session reuse: a lane
            // adds one row, a verify up to draft + 1 accepted rows.
            for g in &plan.span_groups {
                for lane in g {
                    if let GroupLane::Decode(id) = lane {
                        if self.kv.growth_needs_block(*id) {
                            demand += 1;
                        }
                    }
                }
            }
            for j in &spec_jobs {
                if let Some(len) = self.kv.seq_len(j.id) {
                    demand += self
                        .kv
                        .blocks_for(len + j.draft.len() + 1)
                        .saturating_sub(self.kv.blocks_held(j.id));
                }
            }
            if self.kv.free_blocks() < demand {
                if let Some(pc) = self.prefix.as_mut() {
                    let evicted = pc.evict_for(&mut self.kv, demand);
                    self.metrics
                        .prefix_evictions
                        .fetch_add(evicted as u64, std::sync::atomic::Ordering::Relaxed);
                    if evicted > 0 {
                        self.tracer.global_mark("prefix_evict", evicted as u64);
                    }
                }
            }
        }

        // Recompute preemption dropped each victim's cache fork along
        // with the rest of its KV; re-match so the replay prefills only
        // the uncached suffix of the (now extended) prompt instead of
        // starting over from token 0.
        for id in &plan.preempt {
            // A victim can be re-admitted within the very plan() that
            // preempted it (its chunk then restarts at 0 with no fork);
            // only re-match sequences still waiting.
            if self.sched.state(*id) != Some(State::Waiting) {
                continue;
            }
            if let Some(pc) = self.prefix.as_mut() {
                let prompt = self
                    .sched
                    .info(*id)
                    .map(|i| i.prompt.clone())
                    .unwrap_or_default();
                let m = pc.match_prefix(&prompt);
                if m.tokens > 0
                    && self.kv.create_shared(*id, &m.blocks, m.tokens).is_ok()
                {
                    self.sched.set_prefilled(*id, m.tokens);
                    // Deliberately not recorded in prefix_hits /
                    // prefix_cached_tokens: those are per-request
                    // (submit-time) counters — see record_prefix_hit.
                }
            }
        }

        // -- prefill chunks --------------------------------------------------
        // Fresh sequences (start == 0) run through the batched prefill
        // artifact; continuations advance through decode-kernel spans with
        // the span's table rows gathered in one batched read.
        let fresh: Vec<PrefillChunk> =
            plan.prefill.iter().copied().filter(|c| c.start == 0).collect();
        if !fresh.is_empty() {
            let max_b = self
                .engine
                .entry()
                .prefill_buckets(self.path != StepPath::Baseline)
                .iter()
                .filter_map(|a| a.batch)
                .max()
                .unwrap_or(1);
            for group in fresh.chunks(max_b) {
                touched += group.len();
                if let Err(e) = self.run_first_chunks(group) {
                    let ids: Vec<u64> = group.iter().map(|c| c.id).collect();
                    self.fail_requests(&ids, &e)?;
                }
            }
        }
        // Continuations: span groups first (one [B, T] device execution
        // per tile advances every lane — spare lanes may carry T=1
        // decode steps the planner pulled out of the decode batch), then
        // whatever the planner left ungrouped goes through the
        // per-sequence span path.
        let mut grouped = vec![false; plan.prefill.len()];
        for g in &plan.span_groups {
            let mut chunks: Vec<PrefillChunk> = Vec::new();
            let mut dec_ids: Vec<u64> = Vec::new();
            for lane in g {
                match *lane {
                    GroupLane::Chunk(i) => {
                        chunks.push(plan.prefill[i]);
                        grouped[i] = true;
                    }
                    GroupLane::Decode(id) => dec_ids.push(id),
                }
            }
            touched += chunks.len() + dec_ids.len();
            if let Err(e) = self.run_span_group(&chunks, &dec_ids) {
                let ids: Vec<u64> = chunks
                    .iter()
                    .map(|c| c.id)
                    .chain(dec_ids.iter().copied())
                    .collect();
                self.fail_requests(&ids, &e)?;
            }
        }
        for (i, c) in plan.prefill.iter().enumerate() {
            if c.start > 0 && !grouped[i] {
                touched += 1;
                if let Err(e) = self.run_continuation(c) {
                    self.fail_request(c.id, &e)?;
                }
            }
        }

        // -- speculative verify ----------------------------------------------
        // One scored span execution per job re-feeds the last generated
        // token plus the draft; the longest argmax-confirmed prefix (and
        // one bonus token) is emitted and the rejected suffix rows never
        // reach the paged store.  A verify that fails past its retries
        // demotes the path and serves the step through plain host decode
        // instead — speculation is an optimization, never a new failure
        // source for the request.
        for j in &spec_jobs {
            touched += 1;
            if let Err(e) = self.run_spec_chunk(j.id, &j.draft) {
                self.fail_request(j.id, &e)?;
            }
        }

        // -- decode ----------------------------------------------------------
        if !rest.is_empty() {
            touched += rest.len();
            if let Err(e) = self.run_decode(&rest) {
                // A decode failure after retries poisons the whole
                // batched operation: every id it was advancing finishes
                // with `error` (waiting requests are untouched and
                // admit next step).
                self.fail_requests(&rest, &e)?;
            }
        }
        Ok(touched)
    }

    /// Run until idle (blocking batch completion). Returns steps executed.
    pub fn run_to_completion(&mut self, max_steps: usize) -> Result<usize> {
        let mut steps = 0;
        while self.busy() {
            if steps >= max_steps {
                return Err(Error::Scheduler(format!(
                    "did not drain in {max_steps} steps"
                )));
            }
            self.step()?;
            steps += 1;
        }
        Ok(steps)
    }

    /// Execute a group of fresh (`start == 0`) prefill chunks through the
    /// batched prefill artifact.  A chunk longer than the largest compiled
    /// prefill bucket T (monolithic replay of a preempted, over-bucket
    /// prompt) prefills the head and continues the excess as a span.
    fn run_first_chunks(&mut self, chunks: &[PrefillChunk]) -> Result<()> {
        let t0 = Instant::now();
        for c in chunks {
            self.mark_sched(c.id);
        }
        self.tracer
            .set_context(&chunks.iter().map(|c| c.id).collect::<Vec<_>>());
        let fulls: Vec<Vec<u32>> = chunks
            .iter()
            .map(|c| {
                self.sched
                    .info(c.id)
                    .map(|i| i.prompt.clone())
                    .ok_or_else(|| {
                        Error::Scheduler(format!("no sched record for {}", c.id))
                    })
            })
            .collect::<Result<_>>()?;
        let t_cap = self
            .engine
            .entry()
            .prefill_buckets(self.path != StepPath::Baseline)
            .iter()
            .filter_map(|a| a.prompt_len)
            .max()
            .unwrap_or(usize::MAX);
        let prompts: Vec<Vec<u32>> = chunks
            .iter()
            .zip(&fulls)
            .map(|(c, f)| f[..c.len.min(t_cap)].to_vec())
            .collect();
        let out = retry_transient(
            &self.metrics,
            self.retry_max,
            self.retry_backoff_us,
            "prefill",
            || self.engine.prefill(self.path, &prompts),
        )?;
        self.metrics.prefill_step.record(t0.elapsed());
        let s = out.caches.s;
        let row = out.caches.kh * out.caches.hd;
        for (i, c) in chunks.iter().enumerate() {
            let executed = prompts[i].len();
            self.kv.create(c.id, executed + 1)?;
            // Slice this sequence's dense [L, S, row] views out of the batch.
            let mut kd = vec![0f32; out.caches.l * s * row];
            let mut vd = vec![0f32; out.caches.l * s * row];
            for l in 0..out.caches.l {
                let src = out.caches.offset(l, i, 0);
                let dst = l * s * row;
                kd[dst..dst + s * row]
                    .copy_from_slice(&out.caches.k[src..src + s * row]);
                vd[dst..dst + s * row]
                    .copy_from_slice(&out.caches.v[src..src + s * row]);
            }
            self.kv.write_prefix(c.id, executed, s, &kd, &vd)?;
            self.sched.on_chunk(c.id, executed);
            self.metrics
                .prefill_chunks
                .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            // Span-continue the chunk's excess over the prefill bucket.
            let tail_logits = if c.len > executed {
                let lg = self.run_span(c.id, &fulls[i][executed..c.len], executed)?;
                self.sched.on_chunk(c.id, c.len - executed);
                Some(lg)
            } else {
                None
            };
            if c.last {
                let logits_vec;
                let logits: &[f32] = match tail_logits {
                    Some(lg) => {
                        logits_vec = lg;
                        &logits_vec
                    }
                    None => &out.logits[i * self.vocab()..(i + 1) * self.vocab()],
                };
                self.finish_prefill(c.id, logits)?;
            }
        }
        Ok(())
    }

    /// Execute a continuation chunk (`start > 0`) as a decode-kernel span.
    fn run_continuation(&mut self, c: &PrefillChunk) -> Result<()> {
        let t0 = Instant::now();
        self.mark_sched(c.id);
        self.tracer.set_context(&[c.id]);
        let full = self
            .sched
            .info(c.id)
            .map(|i| i.prompt.clone())
            .ok_or_else(|| Error::Scheduler(format!("no sched record for {}", c.id)))?;
        let end = (c.start + c.len).min(full.len());
        let logits = self.run_span(c.id, &full[c.start..end], c.start)?;
        self.sched.on_chunk(c.id, end - c.start);
        self.metrics.chunk_step.record(t0.elapsed());
        self.metrics
            .prefill_chunks
            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        if c.last {
            self.finish_prefill(c.id, &logits)?;
        }
        Ok(())
    }

    /// Execute a scheduler-composed span group: B same-step continuation
    /// chunks from different sequences advance through ONE batched `[B, T]`
    /// span execution per tile ([`ModelEngine::decode_span_group`]),
    /// replacing B serial per-sequence spans.  Spare lanes may carry
    /// `dec_ids`: steady-state decoders the planner pulled out of the
    /// plain decode batch, each riding the group as a T=1 span (pure
    /// overlay — decode-only groups never form).  Any capability gap
    /// (knob off, no compiled batch, plan does not fit the cache)
    /// quietly runs chunk lanes per-sequence and decode lanes through
    /// the host decode; a failure AFTER the viability check (and past
    /// the transient-retry budget) demotes the grouped path in the
    /// health registry and falls back the same way — the engine leaves
    /// the gathered caches untouched on error, and both fallbacks
    /// re-gather per lane anyway.
    fn run_span_group(&mut self, chunks: &[PrefillChunk], dec_ids: &[u64]) -> Result<()> {
        let cfg = self.engine.config().clone();
        let s = cfg.max_seq;
        // Each lane's span slice: the chunk's window of the full prompt,
        // then one re-fed last-generated token per decode rider.
        let mut spans: Vec<(Vec<u32>, usize)> = chunks
            .iter()
            .map(|c| {
                let full = self
                    .sched
                    .info(c.id)
                    .map(|i| i.prompt.clone())
                    .ok_or_else(|| {
                        Error::Scheduler(format!("no sched record for {}", c.id))
                    })?;
                let end = (c.start + c.len).min(full.len());
                Ok((full[c.start..end].to_vec(), c.start))
            })
            .collect::<Result<_>>()?;
        for id in dec_ids {
            let tok = self
                .reqs
                .get(id)
                .and_then(|r| r.generated.last().copied())
                .ok_or_else(|| {
                    Error::Scheduler(format!("decode lane before first token of {id}"))
                })?;
            let start = self.kv.seq_len(*id).ok_or_else(|| {
                Error::KvCache(format!("no cache for decode lane {id}"))
            })?;
            spans.push((vec![tok], start));
        }
        let lanes: Vec<SpanLane> = spans
            .iter()
            .map(|(t, st)| SpanLane { tokens: t, start: *st })
            .collect();
        if !self.engine.span_group_viable(self.path, &lanes, s) {
            // Capability gap, not a failure: per-sequence spans / host
            // decode serve the same lanes and the health bit stays
            // untouched.
            for c in chunks {
                self.run_continuation(c)?;
            }
            if !dec_ids.is_empty() {
                self.run_decode_host(dec_ids, Instant::now())?;
            }
            return Ok(());
        }
        let t0 = Instant::now();
        for c in chunks {
            self.mark_sched(c.id);
        }
        self.tracer.set_context(
            &chunks
                .iter()
                .map(|c| c.id)
                .chain(dec_ids.iter().copied())
                .collect::<Vec<_>>(),
        );
        let n = chunks.len() + dec_ids.len();
        let mut caches = CacheBatch::zeros(
            cfg.n_layers,
            n,
            s,
            cfg.n_kv_heads,
            cfg.head_dim(),
        );
        let lane_ids: Vec<u64> = chunks
            .iter()
            .map(|c| c.id)
            .chain(dec_ids.iter().copied())
            .collect();
        for (i, id) in lane_ids.iter().enumerate() {
            let have = self.kv.gather_into_batch(
                *id,
                s,
                n,
                i,
                &mut caches.k,
                &mut caches.v,
            )?;
            if have != spans[i].1 {
                return Err(Error::KvCache(format!(
                    "span group lane {i}: start {} != cached len {have} \
                     for seq {id}",
                    spans[i].1
                )));
            }
        }
        let out = match retry_transient(
            &self.metrics,
            self.retry_max,
            self.retry_backoff_us,
            "span group",
            || self.engine.decode_span_group(self.path, &lanes, &mut caches),
        ) {
            Ok(out) => out,
            Err(e) => {
                // Viability said yes and the artifact still failed (past
                // the transient-retry budget): demote the grouped path and
                // go per-sequence, starting with the lanes in hand.  The
                // health registry re-probes it after the cooldown.
                self.engine.mark_span_batch_unhealthy();
                eprintln!(
                    "[firstlayer] batched span group failed ({e}); \
                     per-sequence spans until the cooldown re-probe"
                );
                for c in chunks {
                    self.run_continuation(c)?;
                }
                if !dec_ids.is_empty() {
                    self.run_decode_host(dec_ids, Instant::now())?;
                }
                return Ok(());
            }
        };
        use std::sync::atomic::Ordering::Relaxed;
        self.metrics
            .span_executions
            .fetch_add(out.executions as u64, Relaxed);
        self.metrics
            .span_batched_executions
            .fetch_add(out.executions as u64, Relaxed);
        for occ in &out.occupancy {
            self.metrics.span_batch_occupancy.record(*occ as u64);
        }
        for (i, c) in chunks.iter().enumerate() {
            let lane = &out.lanes[i];
            let executed = spans[i].0.len();
            self.kv
                .append_span(c.id, executed, &lane.new_k, &lane.new_v)?;
            self.sched.on_chunk(c.id, executed);
            self.metrics.prefill_chunks.fetch_add(1, Relaxed);
            if c.last {
                self.finish_prefill(c.id, &lane.logits)?;
            }
        }
        // Decode riders: one appended row, one emitted token — exactly
        // what a plain decode step would have done for the id.
        for (j, id) in dec_ids.iter().enumerate() {
            let lane = &out.lanes[chunks.len() + j];
            self.kv.append_span(*id, 1, &lane.new_k, &lane.new_v)?;
            self.emit_token(*id, &lane.logits)?;
        }
        self.metrics.chunk_step.record(t0.elapsed());
        Ok(())
    }

    /// Sample the first token from the completed prompt's logits (TTFT).
    fn finish_prefill(&mut self, id: u64, logits: &[f32]) -> Result<()> {
        self.emit_token(id, logits)?;
        if let Some(r) = self.reqs.get_mut(&id) {
            if r.first_token_t.is_none() {
                r.first_token_t = Some(Instant::now());
                if let Some(s0) = r.submit_t {
                    self.metrics.ttft.record(s0.elapsed());
                }
                self.tracer.req_first_token(id);
            }
        }
        Ok(())
    }

    /// Advance `id` by `tokens` starting at absolute prompt position
    /// `start` via [`ModelEngine::decode_span`] (chunk continuations and
    /// over-bucket replays); appends the span's K/V to the paged store and
    /// returns the logits after the last token.
    fn run_span(&mut self, id: u64, tokens: &[u32], start: usize) -> Result<Vec<f32>> {
        self.tracer.set_context(&[id]);
        let cfg = self.engine.config().clone();
        let s = cfg.max_seq;
        let bucket = self.engine.decode_bucket(1, self.path)?;
        let mut caches = CacheBatch::zeros(
            cfg.n_layers,
            bucket,
            s,
            cfg.n_kv_heads,
            cfg.head_dim(),
        );
        let have = self
            .kv
            .gather_into_batch(id, s, bucket, 0, &mut caches.k, &mut caches.v)?;
        if have != start {
            return Err(Error::KvCache(format!(
                "span start {start} != cached len {have} for seq {id}"
            )));
        }
        // Retry-safe: a failed attempt may have scattered some K/V rows
        // into `caches` at slots >= start, but a retry overwrites exactly
        // those slots and attention masks everything past `pos` anyway.
        let out = retry_transient(
            &self.metrics,
            self.retry_max,
            self.retry_backoff_us,
            "span",
            || self.engine.decode_span(self.path, tokens, start, &mut caches),
        )?;
        // Span-execution accounting: how many device executions the span
        // cost (batched tiles vs one per token) and the tokens-per-
        // execution distribution — the observable the batched span
        // artifact exists to improve.
        use std::sync::atomic::Ordering::Relaxed;
        self.metrics
            .span_executions
            .fetch_add(out.executions as u64, Relaxed);
        if !out.batched {
            self.metrics.span_fallbacks.fetch_add(1, Relaxed);
        }
        for t in &out.exec_tokens {
            self.metrics.span_exec_tokens.record(*t as u64);
        }
        self.kv
            .append_span(id, tokens.len(), &out.new_k, &out.new_v)?;
        Ok(out.logits)
    }

    /// Resolve the plan's [`crate::scheduler::SpecChunk`]s into runnable
    /// jobs: draft from each request's own token history and apply the
    /// eligibility gates.  Every gate is a capability gap, never a
    /// health event — a request that fails one simply stays on plain
    /// decode this step:
    ///
    /// * the spec path must be enabled and healthy, with a span bucket
    ///   of >= 2 compiled (the verify kernel);
    /// * greedy only (`temperature == 0`): acceptance compares drafted
    ///   tokens against the argmax, which IS the plain-decode sample —
    ///   temp > 0 would change the output distribution;
    /// * no stop sequences: stop matching is byte-level over the
    ///   detokenized tail and cannot be pre-scanned before the KV rows
    ///   commit (see [`Coordinator::run_spec_chunk`]'s ordering);
    /// * at least one token generated (the verify span re-feeds it) and
    ///   a non-empty draft;
    /// * the paged store — or the live device session's virtual length,
    ///   when the id still rides one — must sit exactly one token
    ///   behind the emitted stream (the steady-state decode invariant);
    /// * the worst-case accepted rows must fit the free block pool.
    fn resolve_spec_intents(&mut self, plan: &StepPlan) -> Vec<SpecJob> {
        if plan.spec.is_empty() || !self.engine.spec_decode_active() {
            return Vec::new();
        }
        let bucket = self.engine.max_span_bucket(self.path);
        if bucket < 2 {
            return Vec::new();
        }
        let mut jobs = Vec::new();
        for sc in &plan.spec {
            let id = sc.id;
            let greedy_plain = self
                .params
                .get(&id)
                .is_some_and(|p| p.temperature <= 0.0 && p.stop.is_empty());
            if !greedy_plain {
                continue;
            }
            let Some(info) = self.sched.info(id) else { continue };
            let Some(st) = self.reqs.get(&id) else { continue };
            if st.generated.is_empty() {
                continue;
            }
            // Token history = prompt + the post-replay generated tail.
            // After a preemption the replayed prompt already CONTAINS
            // the earlier generations (`extend_prompt`), while
            // `reqs.generated` keeps them all — `len` tracks prompt +
            // live generations exactly, so the tail length falls out.
            let tail = info.len.saturating_sub(info.prompt.len());
            if tail > st.generated.len() {
                continue; // defensive: inconsistent history
            }
            let mut history = info.prompt.clone();
            history.extend_from_slice(&st.generated[st.generated.len() - tail..]);
            let cap = sc.max_draft.min(bucket - 1);
            if cap == 0 {
                continue;
            }
            let draft = self.drafter.draft(&history, cap);
            self.spec_stats.entry(id).or_default().on_draft(draft.len());
            if draft.is_empty() {
                continue;
            }
            // Steady-state invariant, on the VIRTUAL length while the id
            // rides the live device session: carving it out of the
            // session's decode batch forces the recomposition sync, so
            // the paged store is caught up before the verify gathers.
            let vlen = match self.dsess.as_ref().and_then(|d| {
                d.ids
                    .iter()
                    .position(|x| *x == id)
                    .map(|i| d.base[i] + d.pending[i])
            }) {
                Some(v) => Some(v),
                None => self.kv.seq_len(id),
            };
            if vlen != Some(info.len - 1) {
                continue;
            }
            // Worst-case block demand (every drafted token accepted,
            // plus the bonus) against the current free pool; the
            // demand-driven prefix eviction in `step()` covers committed
            // jobs against same-step chunk allocations.
            let need = self
                .kv
                .blocks_for(info.len + draft.len())
                .saturating_sub(self.kv.blocks_held(id));
            if need > self.kv.free_blocks() {
                continue;
            }
            jobs.push(SpecJob { id, draft });
        }
        jobs
    }

    /// Execute one speculative verify: ONE scored span execution feeds
    /// `[last_generated, d_1..d_k]` at the cached length, so position
    /// `i`'s logits predict the token after span token `i`; the longest
    /// prefix where the temp-0 argmax equals the draft is accepted, plus
    /// one bonus token from the first divergent position — a fully
    /// rejected draft still nets exactly the token plain decode would
    /// have produced, byte-identically.
    ///
    /// Ordering is the correctness crux.  The emission count `e` is
    /// pre-scanned against the finish conditions (EOS / token budget /
    /// context limit) FIRST, mirroring [`Coordinator::emit_token`]
    /// exactly; then precisely `e` K/V rows are appended (the rejected
    /// suffix never reaches the paged store — rollback is "do not
    /// append"); only then are the `e` tokens emitted.  At most the
    /// final emission can finish the request, so the prefix-cache
    /// insert-on-finish sees a store whose rows match the emitted
    /// stream with no surplus, and no token is ever emitted after a
    /// finish.
    fn run_spec_chunk(&mut self, id: u64, draft: &[u32]) -> Result<()> {
        let t0 = Instant::now();
        self.tracer.set_context(&[id]);
        let cfg = self.engine.config().clone();
        let last = self
            .reqs
            .get(&id)
            .and_then(|r| r.generated.last().copied())
            .ok_or_else(|| {
                Error::Scheduler(format!("spec verify before first token of {id}"))
            })?;
        let mut span = Vec::with_capacity(draft.len() + 1);
        span.push(last);
        span.extend_from_slice(draft);
        let s = cfg.max_seq;
        let bucket = self.engine.decode_bucket(1, self.path)?;
        let mut caches = CacheBatch::zeros(
            cfg.n_layers,
            bucket,
            s,
            cfg.n_kv_heads,
            cfg.head_dim(),
        );
        let start = self
            .kv
            .gather_into_batch(id, s, bucket, 0, &mut caches.k, &mut caches.v)?;
        let expect = self
            .sched
            .info(id)
            .map(|i| (i.len.saturating_sub(1), i.budget_left(), i.len))
            .ok_or_else(|| Error::Scheduler(format!("no sched record for {id}")))?;
        let (want_start, budget_left, len0) = expect;
        if start != want_start {
            return Err(Error::KvCache(format!(
                "spec verify start {start} != expected {want_start} for seq {id}"
            )));
        }
        let out = match retry_transient(
            &self.metrics,
            self.retry_max,
            self.retry_backoff_us,
            "spec verify",
            || self.engine.decode_span_scored(self.path, &span, start, &mut caches),
        ) {
            Ok(out) => out,
            Err(e) => {
                // Past the transient-retry budget: demote the spec path
                // (the cooldown re-probe recovers it) and serve this
                // step through the plain host decode — the request
                // survives, it just stops speculating.  Nothing was
                // appended or emitted, so the host step starts clean.
                self.engine.mark_spec_decode_unhealthy();
                eprintln!(
                    "[firstlayer] spec verify failed ({e}); plain decode \
                     until the cooldown re-probe"
                );
                return self.run_decode_host(&[id], t0);
            }
        };
        use std::sync::atomic::Ordering::Relaxed;
        let vocab = cfg.vocab_size;
        let n = span.len();
        if out.pos_logits.len() != n * vocab {
            return Err(Error::Engine(format!(
                "scored span returned {} logit rows for a {n}-token span",
                out.pos_logits.len() / vocab.max(1)
            )));
        }
        let sampled: Vec<u32> = (0..n)
            .map(|i| sampling::argmax(&out.pos_logits[i * vocab..(i + 1) * vocab]))
            .collect();
        let accepted = accepted_prefix(draft, &sampled);
        // Pre-scan the emission count: walk the accepted prefix + bonus
        // and stop at the first finish condition.  `emit_token` finishes
        // on EOS, on the token budget reaching zero, and on the context
        // limit — the same three tests, in the same order.
        let mut emit = 0usize;
        for &tok in sampled.iter().take(accepted + 1) {
            emit += 1;
            if tok == EOS || emit >= budget_left || len0 + emit >= cfg.max_seq {
                break;
            }
        }
        // Block-headroom trim: prefill chunks this same step may have
        // consumed blocks the resolve-time check saw as free.  Every
        // accepted token is individually valid, so shrink the emission
        // instead of failing the request; the single-row floor is
        // covered by the scheduler's per-decoder growth reserve.
        while emit > 1
            && self
                .kv
                .blocks_for(start + emit)
                .saturating_sub(self.kv.blocks_held(id))
                > self.kv.free_blocks()
        {
            emit -= 1;
        }
        // Append exactly the emitted rows (token-major [n, L, KH*hd]
        // slabs truncate cleanly), THEN emit: a mid-accept finish
        // removes the cache after the rows are already in place.
        let tok_w = cfg.n_layers * cfg.n_kv_heads * cfg.head_dim();
        self.kv.append_span(
            id,
            emit,
            &out.new_k[..emit * tok_w],
            &out.new_v[..emit * tok_w],
        )?;
        self.metrics.spec_executions.fetch_add(1, Relaxed);
        self.metrics
            .spec_drafted_tokens
            .fetch_add(draft.len() as u64, Relaxed);
        self.metrics
            .spec_accepted_tokens
            .fetch_add(accepted as u64, Relaxed);
        if accepted < draft.len() {
            self.metrics.spec_rollbacks.fetch_add(1, Relaxed);
        }
        self.metrics.spec_accept_len.record(emit as u64);
        if let Some(stats) = self.spec_stats.get_mut(&id) {
            stats.on_verify(draft.len(), accepted);
        }
        self.tracer.req_mark(id, "spec_accept", emit as u64);
        // Sustained bonus-only acceptance is waste, not progress: a full
        // window below the floor demotes the path; the cooldown
        // re-promotion is the probe that brings it back.
        if self.accept_win.record(emit as u64) {
            self.engine.mark_spec_decode_unhealthy();
            eprintln!(
                "[firstlayer] spec decode demoted: acceptance window mean \
                 below {}.{:02} tokens/verify",
                DEMOTE_MEAN_X100 / 100,
                DEMOTE_MEAN_X100 % 100,
            );
        }
        for i in 0..emit {
            self.emit_token(id, &out.pos_logits[i * vocab..(i + 1) * vocab])?;
        }
        self.metrics.decode_step.record(t0.elapsed());
        Ok(())
    }

    /// One decode step for `ids`.  On the device-resident path the
    /// coordinator keeps a per-bucket [`DeviceCacheSession`] alive across
    /// steps while the batch composition is unchanged: the cache pair is
    /// uploaded once at session start, each step chains through the
    /// previous step's output buffers reading back only logits, and the
    /// paged store is caught up from the session deltas at the next sync
    /// point.  The legacy host path (gather → upload → execute → full
    /// readback → append, every step) remains the fallback and oracle.
    fn run_decode(&mut self, ids: &[u64]) -> Result<()> {
        let t0 = Instant::now();
        let engine = Arc::clone(&self.engine);
        if !engine.device_kv_active() {
            // Disabled by config, or gone host-sticky mid-run: flush any
            // session built before that.
            self.sync_or_recompute(&[])?;
            return self.run_decode_host(ids, t0);
        }
        let matches = self
            .dsess
            .as_ref()
            .is_some_and(|d| d.ids == ids && d.path == self.path);
        if !matches {
            self.sync_or_recompute(&[])?;
            if !engine.device_kv_active() {
                // The sync's recovery path just went host-sticky.
                return self.run_decode_host(ids, t0);
            }
            let cfg = engine.config().clone();
            let n = ids.len();
            let bucket = engine.decode_bucket(n, self.path)?;
            let s = cfg.max_seq;
            let mut caches = CacheBatch::zeros(
                cfg.n_layers,
                bucket,
                s,
                cfg.n_kv_heads,
                cfg.head_dim(),
            );
            let mut base = vec![0usize; n];
            for (i, id) in ids.iter().enumerate() {
                base[i] = self.kv.gather_into_batch(
                    *id,
                    s,
                    bucket,
                    i,
                    &mut caches.k,
                    &mut caches.v,
                )?;
            }
            match retry_transient(
                &self.metrics,
                self.retry_max,
                self.retry_backoff_us,
                "session begin",
                || engine.begin_cache_session(&caches),
            ) {
                Ok(sess) => {
                    self.metrics
                        .kv_sessions
                        .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                    self.dsess = Some(DecodeSessionState {
                        ids: ids.to_vec(),
                        path: self.path,
                        base,
                        pending: vec![0; n],
                        sess,
                    });
                }
                Err(e) => {
                    engine.mark_device_kv_unhealthy();
                    eprintln!(
                        "[firstlayer] device decode session unavailable ({e}); \
                         host path until the cooldown re-probe"
                    );
                    return self.run_decode_host(ids, t0);
                }
            }
        }
        // The token to feed is the last generated one; positions are the
        // VIRTUAL lengths (paged store + device-ahead rows).
        let path = self.path;
        let mut tokens = Vec::with_capacity(ids.len());
        let mut pos = Vec::with_capacity(ids.len());
        {
            let d = self.dsess.as_ref().expect("session just ensured");
            for (i, id) in ids.iter().enumerate() {
                let st = self.reqs.get(id).ok_or_else(|| {
                    Error::Engine(format!("decode of unknown request {id}"))
                })?;
                let tok = *st
                    .generated
                    .last()
                    .ok_or_else(|| Error::Engine("decode before first token".into()))?;
                tokens.push(tok);
                pos.push((d.base[i] + d.pending[i]) as u32);
            }
        }
        self.tracer.set_context(ids);
        // `dsess.as_mut()` holds a mutable borrow of self, so the retry
        // helper gets its own Arc + copied knobs instead of `&self.*`.
        let metrics = Arc::clone(&self.metrics);
        let (retry_max, retry_backoff_us) = (self.retry_max, self.retry_backoff_us);
        let d = self.dsess.as_mut().expect("session just ensured");
        let logits_all = match retry_transient(
            &metrics,
            retry_max,
            retry_backoff_us,
            "device decode",
            || engine.decode_on_session(path, &tokens, &pos, &mut d.sess, None, true, true),
        ) {
            Ok(l) => l,
            Err(e) => {
                // The session is untouched on error (PJRT buffers are
                // immutable; a failed execution chains nothing): write
                // back what already succeeded and serve host-side until
                // the cooldown re-probe — rebuilding a session per step
                // would pay for a failed device attempt AND the host
                // step.
                engine.mark_device_kv_unhealthy();
                eprintln!(
                    "[firstlayer] device decode step failed ({e}); \
                     syncing session, host path until the cooldown re-probe"
                );
                self.sync_or_recompute(&[])?;
                return self.run_decode_host(ids, t0);
            }
        };
        let d = self.dsess.as_mut().expect("session survives a step");
        for p in d.pending.iter_mut() {
            *p += 1;
        }
        self.metrics
            .kv_session_steps
            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        self.metrics.decode_step.record(t0.elapsed());
        let vocab = self.vocab();
        for (i, id) in ids.iter().enumerate() {
            let logits = &logits_all[i * vocab..(i + 1) * vocab];
            self.emit_token(*id, logits)?;
        }
        Ok(())
    }

    /// [`Coordinator::sync_decode_session`] with last-resort recovery: a
    /// sync that fails once may fail forever (a device gone bad keeps
    /// its buffers unreadable), and the step loop must not wedge
    /// retrying it while the session's requests never finish.  On sync
    /// failure the device path is marked unhealthy (host-sticky) and the
    /// device-ahead rows are *recomputed* through the host span path —
    /// sound because KV is a pure function of the token prefix, and
    /// every fed token is in the request's generated history.
    fn sync_or_recompute(&mut self, skip: &[u64]) -> Result<()> {
        match self.sync_decode_session(skip) {
            Ok(()) => Ok(()),
            Err(e) => {
                self.engine.mark_device_kv_unhealthy();
                eprintln!(
                    "[firstlayer] device session sync failed ({e}); \
                     recomputing the pending rows host-side"
                );
                self.recompute_session_rows(skip)
            }
        }
    }

    /// Drop the live session and recompute each live row's device-ahead
    /// K/V host-side: the tokens fed on the chained steps are the last
    /// `pending` entries of the request's generated history (minus the
    /// not-yet-executed newest token), so a host `decode_span` over them
    /// rebuilds exactly the missing rows into the paged store.
    fn recompute_session_rows(&mut self, skip: &[u64]) -> Result<()> {
        let Some(d) = self.dsess.take() else {
            return Ok(());
        };
        for i in 0..d.ids.len() {
            let (id, p, base) = (d.ids[i], d.pending[i], d.base[i]);
            if p == 0 || skip.contains(&id) {
                continue;
            }
            if self.kv.seq_len(id) != Some(base) {
                continue;
            }
            let Some(gen) = self.reqs.get(&id).map(|r| r.generated.clone()) else {
                continue;
            };
            // Row base+j holds the KV of the token fed at chained step j:
            // generated[g0 - 1 + j] with g0 the generated count at
            // session start (= gen.len() - p while the newest token has
            // not decoded yet).
            if gen.len() < p + 1 {
                continue; // defensive: history shorter than the session
            }
            let toks = gen[gen.len() - p - 1..gen.len() - 1].to_vec();
            // A row recompute that fails terminally fails THAT request —
            // the remaining rows still belong to healthy survivors and
            // must be rebuilt.  (fail_request cannot recurse back here:
            // the session was already taken above, so its sync path is a
            // no-op.)
            if let Err(e) = self.run_span(id, &toks, base) {
                self.fail_request(id, &e)?;
            }
        }
        Ok(())
    }

    /// Blocks the live session's deferred writeback still needs, skipping
    /// ids in `skip` (preemption victims whose rows are dropped).
    fn session_writeback_blocks(&self, skip: &[u64]) -> usize {
        self.dsess
            .as_ref()
            .map_or(0, |d| self.writeback_blocks_of(d, skip))
    }

    fn writeback_blocks_of(&self, d: &DecodeSessionState, skip: &[u64]) -> usize {
        d.ids
            .iter()
            .enumerate()
            .filter(|(_, id)| !skip.contains(id))
            .map(|(i, id)| {
                if self.kv.seq_len(*id) != Some(d.base[i]) {
                    return 0; // finished/removed: nothing to write back
                }
                self.kv
                    .blocks_for(d.base[i] + d.pending[i])
                    .saturating_sub(self.kv.blocks_held(*id))
            })
            .sum()
    }

    /// Sync the live decode session to host: ONE cache-pair readback,
    /// then `append_span` of each row's device-ahead tokens into the
    /// paged store, then drop the session.  Rows of ids in `skip`
    /// (preemption victims) and of sequences no longer in the store
    /// (finished) are dropped.  No-op without a session.
    ///
    /// Failure-safe by construction: the planner may have promised the
    /// writeback's blocks out of *evictable* prefix-cache leases, so the
    /// shortfall is evicted here first (at the sink — every sync call
    /// site gets the guard); and the session is consumed only on
    /// success.  On error the already-written rows are committed into
    /// `base`/`pending`, so a retried sync (or a continued session —
    /// positions are `base + pending` either way) stays exact instead
    /// of silently losing KV rows while their tokens stand.
    fn sync_decode_session(&mut self, skip: &[u64]) -> Result<()> {
        let Some(mut d) = self.dsess.take() else {
            return Ok(());
        };
        // Nothing to write back (no pending rows, or every pending row
        // belongs to a victim / an already-removed sequence): drop the
        // session without paying the pair readback — the common shape
        // when a decode batch drains by finishing.
        let needs_rows = d.ids.iter().enumerate().any(|(i, id)| {
            d.pending[i] > 0
                && !skip.contains(id)
                && self.kv.seq_len(*id) == Some(d.base[i])
        });
        if !needs_rows {
            return Ok(());
        }
        let need = self.writeback_blocks_of(&d, skip);
        if self.kv.free_blocks() < need {
            if let Some(pc) = self.prefix.as_mut() {
                let evicted = pc.evict_for(&mut self.kv, need);
                self.metrics
                    .prefix_evictions
                    .fetch_add(evicted as u64, std::sync::atomic::Ordering::Relaxed);
            }
        }
        self.tracer.set_context(&d.ids);
        self.tracer.exec_begin(SpanKind::Sync, 0, d.ids.len());
        // The readback is side-effect free on the session, so transient
        // failures retry in place before the recompute fallback fires.
        let (kc, vc) = match retry_transient(
            &self.metrics,
            self.retry_max,
            self.retry_backoff_us,
            "session sync",
            || d.sess.read_cache_pair(),
        ) {
            Ok(pair) => pair,
            Err(e) => {
                self.tracer.exec_end(0);
                self.dsess = Some(d); // untouched: retry next sync point
                return Err(e);
            }
        };
        self.metrics
            .kv_session_syncs
            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        let dims = d.sess.dims();
        debug_assert!(d.ids.len() <= dims[1], "session ids exceed the bucket");
        let mut written = 0usize;
        for i in 0..d.ids.len() {
            let (id, p, base) = (d.ids[i], d.pending[i], d.base[i]);
            if p == 0 || skip.contains(&id) {
                continue;
            }
            // Guard against id reuse across incarnations: the store must
            // still be exactly where the session left it.
            if self.kv.seq_len(id) != Some(base) {
                continue;
            }
            let (new_k, new_v) = CacheBatch::extract_rows(dims, &kc, &vc, i, base, p);
            if let Err(e) = self.kv.append_span(id, p, &new_k, &new_v) {
                // append_span may have landed a prefix of the rows;
                // commit exactly what reached the store so a retried
                // sync (or a continued session — positions are
                // base + pending either way) resumes there instead of
                // silently losing KV rows whose tokens already stand.
                let landed = self.kv.seq_len(id).unwrap_or(base) - base;
                d.base[i] = base + landed;
                d.pending[i] = p - landed;
                self.tracer.exec_end(written + landed);
                self.dsess = Some(d);
                return Err(e);
            }
            d.base[i] += p;
            d.pending[i] = 0;
            written += p;
        }
        self.tracer.exec_end(written);
        Ok(())
    }

    /// The legacy host decode step: dense gather from the paged store,
    /// full cache upload + readback, per-sequence append.  Fallback and
    /// equivalence oracle for the session path above.
    fn run_decode_host(&mut self, ids: &[u64], t0: Instant) -> Result<()> {
        let cfg = self.engine.config().clone();
        let n = ids.len();
        let bucket = self.engine.decode_bucket(n, self.path)?;
        let s = cfg.max_seq;
        let mut caches = CacheBatch::zeros(
            cfg.n_layers,
            bucket,
            s,
            cfg.n_kv_heads,
            cfg.head_dim(),
        );
        let row = caches.kh * caches.hd;
        let mut tokens = Vec::with_capacity(n);
        let mut pos = Vec::with_capacity(n);
        for (i, id) in ids.iter().enumerate() {
            // The token to feed is the last generated one (decode always
            // follows a prefill that produced >= 1 token).
            let st = self.reqs.get(id).ok_or_else(|| {
                Error::Engine(format!("decode of unknown request {id}"))
            })?;
            let tok = *st
                .generated
                .last()
                .ok_or_else(|| Error::Engine("decode before first token".into()))?;
            tokens.push(tok);
            let len = self
                .kv
                .seq_len(*id)
                .ok_or_else(|| Error::KvCache(format!("no cache for {id}")))?;
            pos.push(len as u32);
            // Gather this sequence's pages straight into batch row i (§Perf:
            // no intermediate [L, S, ·] copy).
            self.kv
                .gather_into_batch(*id, s, bucket, i, &mut caches.k, &mut caches.v)?;
        }
        self.tracer.set_context(ids);
        // Trivially retry-safe: the gathered caches are read-only here and
        // nothing lands in the paged store until the call succeeds.
        let out = retry_transient(
            &self.metrics,
            self.retry_max,
            self.retry_backoff_us,
            "decode",
            || self.engine.decode(self.path, &tokens, &pos, &caches),
        )?;
        self.metrics.decode_step.record(t0.elapsed());
        let lrow = caches.l * row;
        for (i, id) in ids.iter().enumerate() {
            self.kv.append(
                *id,
                &out.new_k[i * lrow..(i + 1) * lrow],
                &out.new_v[i * lrow..(i + 1) * lrow],
            )?;
            let logits = &out.logits[i * self.vocab()..(i + 1) * self.vocab()];
            self.emit_token(*id, logits)?;
        }
        Ok(())
    }

    fn vocab(&self) -> usize {
        self.engine.config().vocab_size
    }

    /// Sample, record, and update scheduler state for one sequence.
    fn emit_token(&mut self, id: u64, logits: &[f32]) -> Result<()> {
        // Per-token hot path: sampling parameters are read in place
        // (fields are disjoint: params / rng / reqs / tokenizer), never
        // cloned — stop sequences would otherwise cost a Vec + String
        // allocation per generated token.
        let tok = match self.params.get(&id) {
            Some(p) => sample(logits, p, &mut self.rng),
            None => sampling::argmax(logits),
        };
        let eos = tok == EOS;
        let has_stop = self.params.get(&id).is_some_and(|p| !p.stop.is_empty());
        let Some(st) = self.reqs.get_mut(&id) else {
            return Err(Error::Engine(format!("token for unknown request {id}")));
        };
        st.generated.push(tok);
        // Stop sequences: byte-level match over the detokenized tail, so
        // a pattern split across token boundaries still matches.  The
        // token completing the match is emitted; the buffer is bounded
        // by the longest pattern (plus the piece that just landed).
        let mut stop_hit = false;
        if has_stop && !eos {
            if let Some(piece) = self.tokenizer.piece(tok) {
                st.stop_buf.extend_from_slice(piece);
            }
            let p = self.params.get(&id).expect("has_stop checked above");
            stop_hit = p.stop.iter().any(|sq| {
                !sq.is_empty()
                    && st
                        .stop_buf
                        .windows(sq.len())
                        .any(|w| w == sq.as_bytes())
            });
            let keep = p.stop.iter().map(|s| s.len()).max().unwrap_or(1);
            if st.stop_buf.len() > keep {
                st.stop_buf.drain(..st.stop_buf.len() - keep);
            }
        }
        self.metrics
            .tokens_out
            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        self.events.push(Event::Token { id, token: tok });
        self.sched.on_token(id, eos || stop_hit);
        if self.sched.state(id) == Some(State::Finished) {
            let info = self
                .sched
                .info(id)
                .ok_or_else(|| Error::Scheduler(format!("no sched record for {id}")))?;
            let reason = if eos {
                FinishReason::Eos
            } else if stop_hit {
                FinishReason::Stop
            } else if info.budget_left() == 0 {
                FinishReason::MaxTokens
            } else {
                FinishReason::ContextFull
            };
            if let Some(r) = self.reqs.get_mut(&id) {
                r.done = Some(reason);
                if let Some(t) = r.submit_t {
                    self.metrics.e2e.record(t.elapsed());
                }
            }
            self.metrics
                .requests_done
                .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            let gen = self.reqs.get(&id).map_or(0, |r| r.generated.len());
            self.tracer.req_finish(id, reason_label(reason), gen);
            self.events.push(Event::Finished { id, reason });
            // Insert-on-finish: lease the sequence's full blocks into
            // the prefix cache before it releases them.  Granules
            // already cached are skipped (their duplicate blocks free
            // with the sequence).  The cached token path covers the
            // prompt AND the block-aligned **generated** span — every
            // token whose K/V row is in the paged store (all but the
            // newest, never-executed token, and minus any rows still
            // device-ahead in a live decode session).  That is what
            // makes assistant turns the next chat request's prefix:
            // matching is keyed by token content, and KV depends only
            // on the token prefix, so generated rows are as reusable as
            // prompt rows.
            if let Some(pc) = self.prefix.as_mut() {
                if let (Some(info), Some(blocks)) =
                    (self.sched.info(id), self.kv.seq_blocks(id))
                {
                    let blocks = blocks.to_vec();
                    let mut toks = info.prompt.clone();
                    let n_store = self.kv.seq_len(id).unwrap_or(toks.len());
                    // Rows past the prompt hold the tokens fed on decode
                    // steps: with P device-ahead (pending) rows, the
                    // store's extra rows are generated[G-1-extra-P ..
                    // G-1-P] (the newest token was sampled, never fed).
                    let pend = self
                        .dsess
                        .as_ref()
                        .and_then(|d| {
                            d.ids.iter().position(|x| *x == id).map(|i| d.pending[i])
                        })
                        .unwrap_or(0);
                    let extra = n_store.saturating_sub(toks.len());
                    let gen = &self.reqs[&id].generated;
                    if extra > 0 && gen.len() >= extra + pend + 1 {
                        let start = gen.len() - 1 - pend - extra;
                        toks.extend_from_slice(&gen[start..start + extra]);
                    }
                    pc.insert(&toks, &blocks, &mut self.kv);
                }
            }
            self.finish_conv_turn(id, reason);
            self.kv.remove(id)?;
            self.sched.forget(id);
        }
        Ok(())
    }
}

impl Coordinator {
    /// Debug helpers (examples/diagnostics).
    pub fn kv_free_blocks(&self) -> usize {
        self.kv.free_blocks()
    }
    /// Assert the pool partition invariant (free + sequences + leases);
    /// tests call this after cancel/finish churn.
    pub fn check_kv_invariants(&self) -> Result<()> {
        self.kv.check_invariants()
    }
    pub fn debug_state(&self) -> Vec<(u64, Option<usize>, usize)> {
        let mut v: Vec<(u64, Option<usize>, usize)> = self
            .reqs
            .keys()
            .map(|id| (*id, self.kv.seq_len(*id), self.kv.blocks_held(*id)))
            .collect();
        v.sort();
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_builders_compose() {
        let r = Request::from_tokens(vec![1, 2, 3], 8);
        assert_eq!(r.prompt, vec![1, 2, 3]);
        assert!(r.text.is_none() && r.conversation.is_none() && r.tag.is_none());
        assert_eq!(r.max_new_tokens, 8);
        assert_eq!(r.priority, Priority::Normal);

        let r = Request::from_text("hi", 4)
            .with_priority(Priority::Interactive)
            .with_tag("t1")
            .with_params(SamplingParams {
                temperature: 0.7,
                top_k: 5,
                top_p: 0.9,
                stop: vec!["\n".into()],
            });
        assert_eq!(r.text.as_deref(), Some("hi"));
        assert_eq!(r.tag.as_deref(), Some("t1"));
        assert_eq!(r.priority, Priority::Interactive);
        assert_eq!(r.params.top_k, 5);
        assert_eq!(r.params.stop, vec!["\n".to_string()]);

        let r = Request::turn(3, "next", 4);
        assert_eq!(r.conversation, Some(3));
        assert_eq!(r.text.as_deref(), Some("next"));
    }

}
