//! Token sampling: greedy, temperature, top-k — all on rust-side logits
//! (vocab is small; no need to burn an artifact on argmax).

use crate::util::rng::Rng;

/// Sampling parameters for one request.
#[derive(Debug, Clone, Copy)]
pub struct SamplingParams {
    /// 0.0 = greedy.
    pub temperature: f64,
    /// 0 = no top-k truncation.
    pub top_k: usize,
}

impl Default for SamplingParams {
    fn default() -> Self {
        SamplingParams {
            temperature: 0.0,
            top_k: 0,
        }
    }
}

/// Sample a token id from a logits row.
pub fn sample(logits: &[f32], params: SamplingParams, rng: &mut Rng) -> u32 {
    if params.temperature <= 0.0 {
        return argmax(logits);
    }
    // Top-k filter indices.
    let mut idx: Vec<u32> = (0..logits.len() as u32).collect();
    if params.top_k > 0 && params.top_k < logits.len() {
        idx.sort_unstable_by(|&a, &b| {
            logits[b as usize]
                .partial_cmp(&logits[a as usize])
                .unwrap_or(std::cmp::Ordering::Equal)
        });
        idx.truncate(params.top_k);
    }
    // Softmax over the kept set at the given temperature.
    let t = params.temperature as f32;
    let m = idx
        .iter()
        .map(|&i| logits[i as usize])
        .fold(f32::NEG_INFINITY, f32::max);
    let weights: Vec<f64> = idx
        .iter()
        .map(|&i| (((logits[i as usize] - m) / t) as f64).exp())
        .collect();
    idx[rng.weighted(&weights)]
}

/// Greedy argmax with lowest-index tie-break (deterministic).
pub fn argmax(logits: &[f32]) -> u32 {
    let mut best = 0usize;
    for (i, &v) in logits.iter().enumerate() {
        if v > logits[best] {
            best = i;
        }
    }
    best as u32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_is_argmax() {
        let logits = vec![0.1, 3.0, -1.0, 2.9];
        let mut rng = Rng::new(0);
        assert_eq!(sample(&logits, SamplingParams::default(), &mut rng), 1);
    }

    #[test]
    fn argmax_tie_break_lowest_index() {
        assert_eq!(argmax(&[1.0, 5.0, 5.0]), 1);
    }

    #[test]
    fn top_k_restricts_support() {
        let logits = vec![10.0, 9.5, -50.0, -50.0];
        let mut rng = Rng::new(7);
        for _ in 0..100 {
            let t = sample(
                &logits,
                SamplingParams {
                    temperature: 1.0,
                    top_k: 2,
                },
                &mut rng,
            );
            assert!(t < 2, "sampled outside top-2: {t}");
        }
    }

    #[test]
    fn temperature_zero_deterministic() {
        let logits = vec![0.0, 0.5, 0.2];
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let p = SamplingParams {
            temperature: 0.0,
            top_k: 3,
        };
        assert_eq!(sample(&logits, p, &mut a), sample(&logits, p, &mut b));
    }

    #[test]
    fn high_temp_covers_support() {
        let logits = vec![1.0, 1.0, 1.0, 1.0];
        let mut rng = Rng::new(3);
        let mut seen = [false; 4];
        for _ in 0..200 {
            let t = sample(
                &logits,
                SamplingParams {
                    temperature: 5.0,
                    top_k: 0,
                },
                &mut rng,
            );
            seen[t as usize] = true;
        }
        assert!(seen.iter().all(|&s| s), "uniform logits should hit all");
    }
}
