//! Token sampling: greedy, temperature, top-k, top-p — all on rust-side
//! logits (vocab is small; no need to burn an artifact on argmax).
//!
//! [`SamplingParams`] also carries the request's **stop sequences**;
//! matching happens in the coordinator (it owns the tokenizer and the
//! per-request detokenized tail), not here — sampling stays a pure
//! logits→token function.

use crate::util::rng::Rng;

/// Sampling parameters for one request.
///
/// Not `Copy` (stop sequences own heap data): the coordinator stores one
/// per request and clones on the per-token hot path only when a request
/// actually set something beyond the defaults is *not* worth special
/// casing at this scale — the clone is two `usize`s, two `f64`s and an
/// (almost always empty) `Vec`.
#[derive(Debug, Clone)]
pub struct SamplingParams {
    /// 0.0 = greedy.
    pub temperature: f64,
    /// 0 = no top-k truncation.
    pub top_k: usize,
    /// Nucleus sampling: keep the smallest probability mass >= `top_p`.
    /// Values outside (0, 1) disable the truncation.
    pub top_p: f64,
    /// Stop sequences, matched server-side against the detokenized
    /// output (byte-level, so multi-token sequences match across token
    /// boundaries).  A match finishes the request with
    /// `FinishReason::Stop`; the token that completed the match is
    /// still emitted.
    pub stop: Vec<String>,
}

impl Default for SamplingParams {
    fn default() -> Self {
        SamplingParams {
            temperature: 0.0,
            top_k: 0,
            top_p: 1.0,
            stop: Vec::new(),
        }
    }
}

/// Sample a token id from a logits row.
pub fn sample(logits: &[f32], params: &SamplingParams, rng: &mut Rng) -> u32 {
    if params.temperature <= 0.0 {
        return argmax(logits);
    }
    // Top-k filter indices.
    let mut idx: Vec<u32> = (0..logits.len() as u32).collect();
    if params.top_k > 0 && params.top_k < logits.len() {
        idx.sort_unstable_by(|&a, &b| {
            logits[b as usize]
                .partial_cmp(&logits[a as usize])
                .unwrap_or(std::cmp::Ordering::Equal)
        });
        idx.truncate(params.top_k);
    }
    // Softmax over the kept set at the given temperature.
    let t = params.temperature as f32;
    let m = idx
        .iter()
        .map(|&i| logits[i as usize])
        .fold(f32::NEG_INFINITY, f32::max);
    let mut weights: Vec<f64> = idx
        .iter()
        .map(|&i| (((logits[i as usize] - m) / t) as f64).exp())
        .collect();
    // Nucleus (top-p) truncation: keep the smallest weight-ordered set
    // whose probability mass reaches `top_p` (the boundary candidate is
    // kept, matching the usual definition).
    if params.top_p > 0.0 && params.top_p < 1.0 {
        let total: f64 = weights.iter().sum();
        let mut order: Vec<usize> = (0..weights.len()).collect();
        order.sort_unstable_by(|&a, &b| {
            weights[b]
                .partial_cmp(&weights[a])
                .unwrap_or(std::cmp::Ordering::Equal)
        });
        let mut acc = 0.0f64;
        let mut keep = order.len();
        for (rank, &o) in order.iter().enumerate() {
            acc += weights[o];
            if acc >= params.top_p * total {
                keep = rank + 1;
                break;
            }
        }
        order.truncate(keep);
        let idx2: Vec<u32> = order.iter().map(|&o| idx[o]).collect();
        let w2: Vec<f64> = order.iter().map(|&o| weights[o]).collect();
        idx = idx2;
        weights = w2;
    }
    idx[rng.weighted(&weights)]
}

/// Greedy argmax with lowest-index tie-break (deterministic).
pub fn argmax(logits: &[f32]) -> u32 {
    let mut best = 0usize;
    for (i, &v) in logits.iter().enumerate() {
        if v > logits[best] {
            best = i;
        }
    }
    best as u32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_is_argmax() {
        let logits = vec![0.1, 3.0, -1.0, 2.9];
        let mut rng = Rng::new(0);
        assert_eq!(sample(&logits, &SamplingParams::default(), &mut rng), 1);
    }

    #[test]
    fn argmax_tie_break_lowest_index() {
        assert_eq!(argmax(&[1.0, 5.0, 5.0]), 1);
    }

    #[test]
    fn top_k_restricts_support() {
        let logits = vec![10.0, 9.5, -50.0, -50.0];
        let mut rng = Rng::new(7);
        let p = SamplingParams {
            temperature: 1.0,
            top_k: 2,
            ..Default::default()
        };
        for _ in 0..100 {
            let t = sample(&logits, &p, &mut rng);
            assert!(t < 2, "sampled outside top-2: {t}");
        }
    }

    #[test]
    fn temperature_zero_deterministic() {
        let logits = vec![0.0, 0.5, 0.2];
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let p = SamplingParams {
            temperature: 0.0,
            top_k: 3,
            ..Default::default()
        };
        assert_eq!(sample(&logits, &p, &mut a), sample(&logits, &p, &mut b));
    }

    #[test]
    fn high_temp_covers_support() {
        let logits = vec![1.0, 1.0, 1.0, 1.0];
        let mut rng = Rng::new(3);
        let mut seen = [false; 4];
        let p = SamplingParams {
            temperature: 5.0,
            top_k: 0,
            ..Default::default()
        };
        for _ in 0..200 {
            let t = sample(&logits, &p, &mut rng);
            seen[t as usize] = true;
        }
        assert!(seen.iter().all(|&s| s), "uniform logits should hit all");
    }

    #[test]
    fn top_p_restricts_support() {
        // One dominant candidate holds > 90% of the mass: a 0.5 nucleus
        // keeps exactly it, so sampling is deterministic despite heat.
        let logits = vec![10.0, 2.0, 1.0, 0.0];
        let mut rng = Rng::new(11);
        let p = SamplingParams {
            temperature: 1.0,
            top_k: 0,
            top_p: 0.5,
            stop: Vec::new(),
        };
        for _ in 0..100 {
            assert_eq!(sample(&logits, &p, &mut rng), 0);
        }
    }

    #[test]
    fn top_p_one_is_noop_support() {
        let logits = vec![1.0, 1.0, 1.0, 1.0];
        let mut rng = Rng::new(13);
        let p = SamplingParams {
            temperature: 5.0,
            top_k: 0,
            top_p: 1.0,
            stop: Vec::new(),
        };
        let mut seen = [false; 4];
        for _ in 0..200 {
            seen[sample(&logits, &p, &mut rng) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s), "top_p=1.0 must not truncate");
    }

    #[test]
    fn top_p_composes_with_top_k() {
        // top-k keeps {0, 2} (the two largest); a tight nucleus over
        // that near-even pair then keeps only 0.
        let logits = vec![10.0, 5.0, 9.9, 9.8];
        let mut rng = Rng::new(17);
        let p = SamplingParams {
            temperature: 1.0,
            top_k: 2,
            top_p: 0.5,
            stop: Vec::new(),
        };
        for _ in 0..100 {
            let t = sample(&logits, &p, &mut rng);
            assert_eq!(t, 0, "nucleus over the top-k set should keep only 0");
        }
    }
}
