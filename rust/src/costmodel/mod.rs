//! Analytical cost model: the paper's §1–§3 arithmetic, exactly.
//!
//! Every number printed in the paper's two §3 tables is regenerated from
//! these formulas and pinned by golden tests below.  `examples/paper_tables`
//! prints them in the paper's layout (experiments E1/E2); `simtraffic`
//! cross-checks the same quantities *measured* from executed engine steps
//! (E3).
//!
//! Quantities (B = batch size, W = weights eliminated by precompute):
//!
//! * reads without precompute, per batch:  `B·d + W`
//!   (each token reads its d-value embedding; the Q/K/V/FFN weights are
//!   streamed once per batch)
//! * reads with precompute, per batch:     `B·2(d+e)`
//! * first-layer read-reduction factor:    ratio of the two
//! * embedding memory increase: `(d+2e)·vocab` (store `2(d+e)` per token
//!   instead of `d`)
//! * net memory delta: increase − eliminated weights

use crate::config::{Arch, ModelConfig};

/// Per-model weight inventory (paper §3 table 1).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct WeightCounts {
    /// Q + P projections per layer: `2·d²`.
    pub qp_per_layer: u64,
    /// K + V projections per layer: `2·d·e`.
    pub kv_per_layer: u64,
    /// FFN weights per layer: `(2|3)·d·hidden·n_experts`.
    pub ffn_per_layer: u64,
    /// Input + output embeddings: `2·d·vocab`.
    pub embeddings: u64,
    /// Grand total (the paper's "Total weights" row; norm scales are
    /// negligible and excluded, as in the paper).
    pub total: u64,
}

pub fn weight_counts(cfg: &ModelConfig) -> WeightCounts {
    let d = cfg.d as u64;
    let e = cfg.e() as u64;
    let h = cfg.ffn_hidden as u64;
    let v = cfg.vocab_size as u64;
    let l = cfg.n_layers as u64;
    let qp = 2 * d * d;
    let kv = 2 * d * e;
    let ffn = cfg.ffn_weight_factor() as u64 * d * h * cfg.n_experts as u64;
    let emb = 2 * d * v;
    WeightCounts {
        qp_per_layer: qp,
        kv_per_layer: kv,
        ffn_per_layer: ffn,
        embeddings: emb,
        total: l * (qp + kv + ffn) + emb,
    }
}

/// Weights the trick removes from serving memory (paper table 2 row 1).
///
/// Parallel models drop the first layer's Q, K, V *and* FFN
/// (`d² + 2de + ffn`); serial models only Q, K, V (`d² + 2de`).
pub fn eliminated_weights(cfg: &ModelConfig) -> u64 {
    let d = cfg.d as u64;
    let e = cfg.e() as u64;
    let qkv = d * d + 2 * d * e;
    match cfg.arch {
        Arch::Parallel => qkv + weight_counts(cfg).ffn_per_layer,
        Arch::Serial => qkv,
    }
}

/// First-layer memory reads per batch WITHOUT precompute: `B·d + W`.
pub fn reads_without(cfg: &ModelConfig, batch: u64) -> u64 {
    batch * cfg.d as u64 + eliminated_weights(cfg)
}

/// First-layer memory reads per batch WITH precompute: `B·2(d+e)`.
pub fn reads_with(cfg: &ModelConfig, batch: u64) -> u64 {
    batch * cfg.precomp_row_width() as u64
}

/// First-layer read-reduction factor at a batch size (paper rounds to the
/// nearest integer).
pub fn reduction_factor(cfg: &ModelConfig, batch: u64) -> f64 {
    reads_without(cfg, batch) as f64 / reads_with(cfg, batch) as f64
}

/// Memory-size effects (paper table 2, bottom half).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MemoryDelta {
    /// Embedding storage grows by `(d+2e)·vocab` values.
    pub embedding_increase: u64,
    /// Weights removed (`eliminated_weights`).
    pub weights_decrease: u64,
    /// Net change in values stored (may be negative).
    pub net: i64,
    /// Net relative to total weights, in percent (paper rounds).
    pub relative_pct: i64,
}

pub fn memory_delta(cfg: &ModelConfig) -> MemoryDelta {
    let d = cfg.d as u64;
    let e = cfg.e() as u64;
    let v = cfg.vocab_size as u64;
    let inc = (d + 2 * e) * v;
    let dec = eliminated_weights(cfg);
    let net = inc as i64 - dec as i64;
    let total = weight_counts(cfg).total as f64;
    MemoryDelta {
        embedding_increase: inc,
        weights_decrease: dec,
        net,
        relative_pct: (net as f64 / total * 100.0).round() as i64,
    }
}

/// Device executions a continuation span of `len` tokens costs when tiled
/// into `bucket`-token span-artifact executions (`ceil(len/bucket)`); the
/// per-token path costs `len`.
pub fn span_exec_count(len: u64, bucket: u64) -> u64 {
    len.div_ceil(bucket.max(1))
}

/// Weight values streamed per span **execution** — everything the
/// artifact must read besides its per-token inputs.  The precompute path
/// drops the eliminated first-layer weights AND the input embedding (the
/// table rows arrive as data); the baseline keeps the weights but still
/// embeds in-graph (its embedding reads are per-token, counted by
/// `reads_without`, not here).
pub fn streamed_weights(cfg: &ModelConfig, precompute: bool) -> u64 {
    let total = weight_counts(cfg).total;
    let emb_in = (cfg.d * cfg.vocab_size) as u64;
    if precompute {
        total - eliminated_weights(cfg) - emb_in
    } else {
        total - emb_in
    }
}

/// Whole-span weight traffic: weights stream once per execution, so a
/// span of `len` tokens reads `span_exec_count(len, bucket)` times the
/// per-execution streamed weights (vs `len` times on the per-token path).
pub fn span_weight_reads(cfg: &ModelConfig, precompute: bool, len: u64, bucket: u64) -> u64 {
    span_exec_count(len, bucket) * streamed_weights(cfg, precompute)
}

/// Weight-read reduction of batched span execution over per-token span
/// execution: `len / ceil(len/bucket)` — exactly `bucket` when the
/// bucket divides the span.  This is the second batching axis the span
/// artifact adds on top of the paper's first-layer table (which already
/// made the span's layer-1 reads `len·2(d+e)` on either schedule).
pub fn span_read_reduction(len: u64, bucket: u64) -> f64 {
    len as f64 / span_exec_count(len, bucket) as f64
}

/// Device executions a step with `n` same-bucket span continuations
/// costs when composed into `lanes`-lane `[B, T]` span groups:
/// `ceil(n/lanes)` — vs `n` on the per-sequence span path (one group is
/// padded with inert lanes, never split into extra executions).
pub fn span_group_exec_count(n: u64, lanes: u64) -> u64 {
    n.div_ceil(lanes.max(1))
}

/// Weight-read reduction of multi-sequence span execution over per-token
/// per-sequence execution: the single-sequence factor
/// `len / ceil(len/bucket)` scaled by the group's lane occupancy — one
/// `[B, T]` execution streams the weights ONCE for every occupied lane,
/// so `occupancy` sequences amortize the same stream.  Padding lanes
/// contribute nothing (they scale by occupancy, not by compiled lanes).
pub fn span_batched_read_reduction(len: u64, bucket: u64, occupancy: u64) -> f64 {
    occupancy.max(1) as f64 * span_read_reduction(len, bucket)
}

/// Whole-group weight traffic: `occupancy` sequences each advancing
/// `len` tokens through shared `[B, T]` tiles stream the weights
/// `ceil(len/bucket)` times TOTAL — the same bytes `span_weight_reads`
/// charges ONE sequence, now amortized across the group.
pub fn span_group_weight_reads(
    cfg: &ModelConfig,
    precompute: bool,
    len: u64,
    bucket: u64,
) -> u64 {
    span_weight_reads(cfg, precompute, len, bucket)
}

/// Per-tenant fair share of a resource pool of `total` units split
/// across `tenants` active tenants — the bound the DRR scheduler holds
/// KV-block ownership to, and the goodput floor `firstlayer
/// overload-smoke` asserts per bystander tenant.  Floor division, never
/// zero: every live tenant is entitled to at least one unit (matching
/// `Scheduler::kv_fair_share`).
pub fn fair_share(total: u64, tenants: u64) -> u64 {
    (total / tenants.max(1)).max(1)
}

/// Upper bound on whole-model savings from optimizing one layer of `n`:
/// the paper's "4 layers ⇒ ≤25%, 32 layers ⇒ ≤3%" remark (E7).
pub fn max_savings_fraction(n_layers: usize) -> f64 {
    1.0 / n_layers as f64
}

/// Fraction of per-token decode FLOPs the trick removes (used by the E7
/// layer-sweep; attention-score FLOPs depend on context length and are
/// excluded, matching the paper's weight-read framing).
pub fn flops_saved_fraction(cfg: &ModelConfig) -> f64 {
    let wc = weight_counts(cfg);
    let per_layer = (wc.qp_per_layer + wc.kv_per_layer + wc.ffn_per_layer) as f64;
    let saved = match cfg.arch {
        // Q,K,V (= half of qp + all kv) + FFN
        Arch::Parallel => {
            (wc.qp_per_layer / 2 + wc.kv_per_layer + wc.ffn_per_layer) as f64
        }
        Arch::Serial => (wc.qp_per_layer / 2 + wc.kv_per_layer) as f64,
    };
    saved / (per_layer * cfg.n_layers as f64)
}

/// The paper's batch-size grid in table 2.
pub const PAPER_BATCHES: [u64; 4] = [1, 16, 256, 1024];

/// Print the paper's §3 tables (E1/E2) in the paper's layout.
/// Shared by `firstlayer paper-tables` and `examples/paper_tables.rs`.
pub fn print_paper_tables() {
    use crate::config::{mixtral_like_columns, ModelConfig};
    use crate::util::fmt::{cell, commas, commas_i, factor, human_count};

    let cols: Vec<ModelConfig> = mixtral_like_columns();
    let w = 22;

    println!("== Table 1: configurations and number of weights ==");
    let hdr: Vec<String> = cols.iter().map(|c| c.name.clone()).collect();
    println!("{:<38} {}", "Parameter", hdr.iter().map(|h| cell(h, w)).collect::<Vec<_>>().join(" "));
    let row = |label: &str, vals: Vec<String>| {
        println!(
            "{label:<38} {}",
            vals.iter().map(|v| cell(v, w)).collect::<Vec<_>>().join(" ")
        );
    };
    row(
        "Parallel attention/FFN?",
        cols.iter()
            .map(|c| match c.arch {
                crate::config::Arch::Parallel => "parallel".into(),
                crate::config::Arch::Serial => "serial".into(),
            })
            .collect(),
    );
    row("dim (aka d)", cols.iter().map(|c| commas(c.d as u64)).collect());
    row("n_layers", cols.iter().map(|c| c.n_layers.to_string()).collect());
    row(
        "n_heads, n_kv_heads",
        cols.iter()
            .map(|c| format!("{}, {}", c.n_heads, c.n_kv_heads))
            .collect(),
    );
    row("e (output dim of K, V)", cols.iter().map(|c| commas(c.e() as u64)).collect());
    row("FFN hidden_dim", cols.iter().map(|c| commas(c.ffn_hidden as u64)).collect());
    row("FFN n_experts", cols.iter().map(|c| c.n_experts.to_string()).collect());
    row("vocab_size", cols.iter().map(|c| commas(c.vocab_size as u64)).collect());
    let wcs: Vec<WeightCounts> = cols.iter().map(weight_counts).collect();
    row("Q+P weights per layer", wcs.iter().map(|x| commas(x.qp_per_layer)).collect());
    row("K+V weights per layer", wcs.iter().map(|x| commas(x.kv_per_layer)).collect());
    row("FFN weights per layer", wcs.iter().map(|x| commas(x.ffn_per_layer)).collect());
    row("Input+output embed.", wcs.iter().map(|x| commas(x.embeddings)).collect());
    row("Total weights:", wcs.iter().map(|x| human_count(x.total)).collect());

    println!();
    println!("== Table 2: memory-read savings and memory-size deltas ==");
    println!(
        "{:<38} {}",
        "",
        hdr.iter().map(|h| cell(h, w)).collect::<Vec<_>>().join(" ")
    );
    row(
        "Weights eliminated",
        cols.iter().map(|c| commas(eliminated_weights(c))).collect(),
    );
    row(
        "Reads w/o precompute (B=1)",
        cols.iter().map(|c| commas(reads_without(c, 1))).collect(),
    );
    row(
        "Reads with precompute (B=1)",
        cols.iter().map(|c| commas(reads_with(c, 1))).collect(),
    );
    for b in PAPER_BATCHES {
        row(
            &format!("First-layer reduction, batch {b}:"),
            cols.iter().map(|c| factor(reduction_factor(c, b))).collect(),
        );
    }
    let mds: Vec<MemoryDelta> = cols.iter().map(memory_delta).collect();
    row(
        "Embedding memory increase",
        mds.iter().map(|m| commas(m.embedding_increase)).collect(),
    );
    row(
        "Eliminated-weight decrease",
        mds.iter().map(|m| format!("-{}", commas(m.weights_decrease))).collect(),
    );
    row("Net memory delta", mds.iter().map(|m| commas_i(m.net)).collect());
    row(
        "Relative memory delta",
        mds.iter().map(|m| format!("{:+}%", m.relative_pct)).collect(),
    );
}

#[cfg(test)]
mod tests {
    //! Golden tests: every number from the paper's §3 tables.
    use super::*;
    use crate::config::{zoo_get, ModelConfig};

    fn pythia() -> ModelConfig {
        zoo_get("pythia-6.9b").unwrap()
    }
    fn mistral() -> ModelConfig {
        zoo_get("mistral-7b").unwrap()
    }
    fn mixtral() -> ModelConfig {
        zoo_get("mixtral-8x7b").unwrap()
    }
    fn mixtral_par() -> ModelConfig {
        zoo_get("mixtral-8x7b-parallel").unwrap()
    }

    #[test]
    fn table1_per_layer_weights() {
        let p = weight_counts(&pythia());
        assert_eq!(p.qp_per_layer, 33_554_432);
        assert_eq!(p.kv_per_layer, 33_554_432);
        assert_eq!(p.ffn_per_layer, 134_217_728);
        assert_eq!(p.embeddings, 412_876_800);

        let m = weight_counts(&mistral());
        assert_eq!(m.qp_per_layer, 33_554_432);
        assert_eq!(m.kv_per_layer, 8_388_608);
        assert_eq!(m.ffn_per_layer, 176_160_768);
        assert_eq!(m.embeddings, 262_144_000);

        let x = weight_counts(&mixtral());
        assert_eq!(x.ffn_per_layer, 1_409_286_144);
    }

    #[test]
    fn table1_totals() {
        // Paper: 6.9B, 7.2B, 46.7B.
        assert_eq!(weight_counts(&pythia()).total, 6_855_327_744);
        assert_eq!(weight_counts(&mistral()).total, 7_241_465_856);
        assert_eq!(weight_counts(&mixtral()).total, 46_701_477_888);
        assert!((weight_counts(&pythia()).total as f64 / 1e9 - 6.9).abs() < 0.05);
        assert!((weight_counts(&mistral()).total as f64 / 1e9 - 7.2).abs() < 0.05);
        assert!((weight_counts(&mixtral()).total as f64 / 1e9 - 46.7).abs() < 0.05);
    }

    #[test]
    fn table2_eliminated_weights() {
        assert_eq!(eliminated_weights(&pythia()), 184_549_376);
        assert_eq!(eliminated_weights(&mistral()), 25_165_824);
        assert_eq!(eliminated_weights(&mixtral_par()), 1_434_451_968);
    }

    #[test]
    fn table2_reads_batch_1() {
        assert_eq!(reads_without(&pythia(), 1), 184_553_472);
        assert_eq!(reads_with(&pythia(), 1), 16_384);
        assert_eq!(reads_without(&mistral(), 1), 25_169_920);
        assert_eq!(reads_with(&mistral(), 1), 10_240);
        assert_eq!(reads_without(&mixtral_par(), 1), 1_434_456_064);
        assert_eq!(reads_with(&mixtral_par(), 1), 10_240);
    }

    #[test]
    fn table2_reduction_factors() {
        // (model, [factor at B=1, 16, 256, 1024]) — paper's printed values.
        let cases: [(&ModelConfig, [u64; 4]); 3] = [
            (&pythia(), [11_264, 704, 44, 11]),
            (&mistral(), [2_458, 154, 10, 3]),
            (&mixtral_par(), [140_084, 8_756, 548, 137]),
        ];
        for (cfg, expect) in cases {
            for (b, want) in PAPER_BATCHES.iter().zip(expect) {
                let got = reduction_factor(cfg, *b).round() as u64;
                assert_eq!(got, want, "{} B={b}", cfg.name);
            }
        }
    }

    #[test]
    fn table2_memory_deltas() {
        let p = memory_delta(&pythia());
        assert_eq!(p.embedding_increase, 619_315_200);
        assert_eq!(p.net, 434_765_824);
        assert_eq!(p.relative_pct, 6);

        let m = memory_delta(&mistral());
        assert_eq!(m.embedding_increase, 196_608_000);
        assert_eq!(m.net, 171_442_176);
        assert_eq!(m.relative_pct, 2);

        let x = memory_delta(&mixtral_par());
        assert_eq!(x.net, -1_237_843_968);
        assert_eq!(x.relative_pct, -3);
    }

    #[test]
    fn serial_mixtral_keeps_moe() {
        // Plain (serial) Mixtral only drops Q/K/V — same as Mistral.
        assert_eq!(eliminated_weights(&mixtral()), 25_165_824);
    }

    #[test]
    fn layer_bound() {
        // Paper abstract: 4-layer ⇒ 25% cap, 32-layer ⇒ ~3% cap.
        assert_eq!(max_savings_fraction(4), 0.25);
        assert!((max_savings_fraction(32) - 0.03125).abs() < 1e-9);
        // And the realized FLOP fraction is below the cap.
        for cfg in [pythia(), mistral(), mixtral_par()] {
            let f = flops_saved_fraction(&cfg);
            assert!(f > 0.0 && f <= max_savings_fraction(cfg.n_layers) + 1e-12);
        }
    }

    #[test]
    fn span_accounting_matches_tiling() {
        // Mistral, default 512-token chunk tiled at the 64-token default
        // span bucket: 8 executions, 64x fewer weight streams.
        let m = mistral();
        assert_eq!(span_exec_count(512, 64), 8);
        assert_eq!(span_exec_count(64, 32), 2); // the acceptance shape
        assert_eq!(span_exec_count(65, 32), 3); // ragged tail
        assert_eq!(span_exec_count(5, 8), 1);
        assert!((span_read_reduction(512, 64) - 64.0).abs() < 1e-9);
        assert!((span_read_reduction(40, 32) - 20.0).abs() < 1e-9);
        // Streamed weights: precompute drops eliminated + input embedding;
        // baseline only the input embedding (its reads are per-token).
        let emb_in = (m.d * m.vocab_size) as u64;
        assert_eq!(
            streamed_weights(&m, true),
            weight_counts(&m).total - eliminated_weights(&m) - emb_in
        );
        assert_eq!(streamed_weights(&m, false), weight_counts(&m).total - emb_in);
        assert_eq!(
            span_weight_reads(&m, true, 512, 64),
            8 * streamed_weights(&m, true)
        );
        // Batched always no worse than per-token, on both paths.
        for pre in [false, true] {
            assert!(
                span_weight_reads(&m, pre, 512, 64)
                    <= 512 * streamed_weights(&m, pre)
            );
        }
    }

    #[test]
    fn batched_span_accounting_scales_with_occupancy() {
        // A step with N same-bucket continuations and B compiled lanes
        // executes ceil(N/B) groups — the acceptance-criterion shape.
        assert_eq!(span_group_exec_count(4, 4), 1);
        assert_eq!(span_group_exec_count(5, 4), 2);
        assert_eq!(span_group_exec_count(1, 4), 1); // lone sequence
        assert_eq!(span_group_exec_count(8, 2), 4);
        // Occupancy scales the per-sequence weight-stream reduction:
        // 4 lanes full at the dividing bucket = 4 * bucket.
        assert!((span_batched_read_reduction(32, 32, 4) - 128.0).abs() < 1e-9);
        assert!((span_batched_read_reduction(32, 32, 1) - 32.0).abs() < 1e-9);
        // Ragged span, partial group: still exactly occupancy times the
        // single-sequence factor.
        let single = span_read_reduction(40, 32);
        assert!((span_batched_read_reduction(40, 32, 3) - 3.0 * single).abs() < 1e-9);
        // Group traffic equals ONE sequence's traffic: the group's total
        // weight bytes do not grow with occupancy.
        let m = mistral();
        assert_eq!(
            span_group_weight_reads(&m, true, 64, 32),
            span_weight_reads(&m, true, 64, 32)
        );
    }

    #[test]
    fn fair_share_floors_and_divides() {
        assert_eq!(fair_share(64, 4), 16);
        assert_eq!(fair_share(10, 3), 3); // floor division
        assert_eq!(fair_share(2, 8), 1); // never zero
        assert_eq!(fair_share(64, 0), 64); // no tenants = whole pool
        // Matches the scheduler's KV bound: shares over live tenants
        // always sum to at most the pool.
        for tenants in 1..8u64 {
            assert!(fair_share(64, tenants) * tenants <= 64.max(tenants));
        }
    }

    #[test]
    fn reduction_monotone_in_batch() {
        // Savings shrink as batch grows (weights amortize) — the paper's
        // "fewer memory reads for low batch sizes".
        for cfg in [pythia(), mistral(), mixtral_par()] {
            let mut prev = f64::INFINITY;
            for b in [1u64, 4, 16, 64, 256, 1024, 4096] {
                let f = reduction_factor(&cfg, b);
                assert!(f < prev, "{} B={b}", cfg.name);
                prev = f;
            }
        }
    }

    #[test]
    fn reduction_asymptote_is_d_over_2dpe() {
        // As B→∞ the factor tends to d / 2(d+e) < 1: precompute READS MORE
        // per token than the plain embedding at huge batch. The crossover
        // (factor = 1) is at B = W / (2(d+e) - d) = W / (d + 2e).
        let cfg = mistral();
        let asymptote = cfg.d as f64 / cfg.precomp_row_width() as f64;
        let f = reduction_factor(&cfg, 100_000_000);
        assert!((f - asymptote).abs() / asymptote < 1e-3);
        let crossover =
            eliminated_weights(&cfg) as f64 / (cfg.d as f64 + 2.0 * cfg.e() as f64);
        // Mistral's crossover is exactly B = 4096: factor 1.0 there.
        assert!(reduction_factor(&cfg, crossover as u64) >= 1.0);
        assert!(reduction_factor(&cfg, crossover as u64 - 1) > 1.0);
        assert!(reduction_factor(&cfg, crossover as u64 + 1) < 1.0);
    }
}
