//! Crate-wide error type.

use thiserror::Error;

#[derive(Error, Debug)]
pub enum Error {
    #[error("io error: {0}")]
    Io(#[from] std::io::Error),

    #[error("xla error: {0}")]
    Xla(String),

    #[error("json parse error at byte {offset}: {msg}")]
    Json { offset: usize, msg: String },

    #[error("manifest error: {0}")]
    Manifest(String),

    #[error("weights file error: {0}")]
    Weights(String),

    #[error("precompute table error: {0}")]
    Table(String),

    #[error("config error: {0}")]
    Config(String),

    #[error("scheduler error: {0}")]
    Scheduler(String),

    #[error("kv cache error: {0}")]
    KvCache(String),

    #[error("engine error: {0}")]
    Engine(String),

    #[error("tokenizer error: {0}")]
    Tokenizer(String),

    #[error("server error: {0}")]
    Server(String),

    #[error("backpressure: {0}")]
    Backpressure(String),

    #[error("chat error: {0}")]
    Chat(String),

    /// Admission refused by the overload ladder.  Retriable by design:
    /// the server surfaces `reason:"shed"` plus the retry hint so a
    /// well-behaved client backs off instead of treating it as failure.
    #[error("shed: {msg} (retry after {retry_after_ms}ms)")]
    Shed { msg: String, retry_after_ms: u64 },

    /// A `chat.*` op addressed a conversation owned by another tenant.
    #[error("cross-tenant: {0}")]
    CrossTenant(String),

    #[error("cancel error: {0}")]
    Cancel(String),

    /// A fault fired by the injection plane (`crate::faults`).  Carries
    /// its transience class so the retry/degradation ladder can be
    /// exercised deterministically.
    #[error("injected fault at {point} (transient={transient})")]
    Injected {
        point: &'static str,
        transient: bool,
    },

    #[error("{0}")]
    Other(String),
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

pub type Result<T> = std::result::Result<T, Error>;

impl Error {
    pub fn other(msg: impl Into<String>) -> Self {
        Error::Other(msg.into())
    }

    /// Transient errors are worth retrying with backoff; fatal ones fail
    /// the request (or path) immediately.  Device/runtime (`Xla`) errors
    /// are classified transient — a PJRT hiccup is exactly the case the
    /// retry ladder exists for — everything host-side (config, format,
    /// protocol) is deterministic and therefore fatal.
    pub fn is_transient(&self) -> bool {
        match self {
            Error::Injected { transient, .. } => *transient,
            Error::Xla(_) => true,
            _ => false,
        }
    }
}
