//! Deterministic fault-injection plane and the unified path-health
//! registry (the self-healing degradation ladder).
//!
//! Mirrors the tracer's cost discipline (`rust/src/trace/mod.rs`): the
//! plane is constructed unconditionally and threaded through the
//! runtime, the engine, and the cache sessions, but every injection
//! point is a single relaxed `AtomicBool` load until a `--fault-spec`
//! plan is installed — serving pays nothing for the capability to be
//! broken on purpose.
//!
//! # Injection points
//!
//! One [`InjectPoint`] per engine/device boundary:
//!
//! | point      | fires inside                                             |
//! |------------|----------------------------------------------------------|
//! | `h2d`      | host→device upload (`Runtime::upload_f32`/`upload_i32`)  |
//! | `exec`     | artifact execution (`Executable::execute_buffers`)       |
//! | `readback` | logits/pair readback (`Executable::read_output`/host)    |
//! | `sync`     | session cache-pair sync (`DeviceCacheSession`)           |
//! | `gather`   | precompute-table row gather (`ModelEngine`)              |
//!
//! # Fault plans
//!
//! A plan is a `;`-separated list of rules, each
//! `<point>:<transient|fatal>[:after=N][:every=N][:count=N][:delay_us=N]`:
//!
//! * `after=N`  — let the first N crossings of the point pass (warmup);
//! * `every=N`  — past the warmup, fire on every N-th crossing (default
//!   1: every crossing);
//! * `count=N`  — stop after N fires (default 0: unbounded);
//! * `delay_us` — sleep that long before returning the error (a latency
//!   spike riding on the fault).
//!
//! Example: `exec:transient:after=6:every=5:count=4;sync:fatal:after=40`.
//! Rules are evaluated in plan order; the first that decides to fire
//! wins the crossing.  Everything is counter-based — no clocks, no
//! randomness — so a seeded workload replays the exact same fault
//! sequence every run, which is what lets the chaos gate compare
//! faulted streams against a fault-free oracle.
//!
//! # Health registry
//!
//! [`HealthRegistry`] replaces the three ad-hoc sticky booleans the
//! engine grew across PRs 3/5/6 (`device_kv_ok`, `span_ok`,
//! `span_batch_ok`) with one ladder: a path failure *demotes* the path
//! (serving degrades exactly as before), but after
//! `health_cooldown_steps` coordinator steps the path is *re-promoted*
//! and the next use doubles as the recovery probe — if the fault has
//! cleared the path stays fast, if not it re-demotes and the cooldown
//! restarts.  `cooldown = 0` restores the old demote-forever behavior.
//! Mere capability gaps (no compiled bucket, unplannable group) never
//! touch the registry — that rule is inherited unchanged.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering::Relaxed};
use std::sync::OnceLock;

use crate::error::{Error, Result};

/// Engine/device boundaries a fault can be injected at.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum InjectPoint {
    /// Host→device tensor upload.
    H2d,
    /// Device artifact execution.
    Exec,
    /// Device→host output readback.
    Readback,
    /// Device cache-pair sync to host.
    Sync,
    /// Precompute-table row gather.
    Gather,
}

impl InjectPoint {
    pub const ALL: [InjectPoint; 5] = [
        InjectPoint::H2d,
        InjectPoint::Exec,
        InjectPoint::Readback,
        InjectPoint::Sync,
        InjectPoint::Gather,
    ];

    pub fn label(self) -> &'static str {
        match self {
            InjectPoint::H2d => "h2d",
            InjectPoint::Exec => "exec",
            InjectPoint::Readback => "readback",
            InjectPoint::Sync => "sync",
            InjectPoint::Gather => "gather",
        }
    }

    fn parse(s: &str) -> Option<InjectPoint> {
        InjectPoint::ALL.into_iter().find(|p| p.label() == s)
    }
}

/// One parsed fault rule (see the module doc for the grammar).
#[derive(Debug)]
struct Rule {
    point: InjectPoint,
    transient: bool,
    after: u64,
    every: u64,
    count: u64,
    delay_us: u64,
    crossings: AtomicU64,
    fired: AtomicU64,
}

fn parse_rule(s: &str) -> Result<Rule> {
    let mut parts = s.split(':');
    let point = parts
        .next()
        .and_then(InjectPoint::parse)
        .ok_or_else(|| Error::Config(format!("fault-spec `{s}`: unknown injection point")))?;
    let transient = match parts.next() {
        Some("transient") => true,
        Some("fatal") => false,
        other => {
            return Err(Error::Config(format!(
                "fault-spec `{s}`: expected transient|fatal, got {other:?}"
            )))
        }
    };
    let (mut after, mut every, mut count, mut delay_us) = (0u64, 1u64, 0u64, 0u64);
    for kv in parts {
        let (k, v) = kv
            .split_once('=')
            .ok_or_else(|| Error::Config(format!("fault-spec `{s}`: bad field `{kv}`")))?;
        let n: u64 = v
            .parse()
            .map_err(|_| Error::Config(format!("fault-spec `{s}`: bad number `{v}`")))?;
        match k {
            "after" => after = n,
            "every" => every = n.max(1),
            "count" => count = n,
            "delay_us" => delay_us = n,
            _ => {
                return Err(Error::Config(format!(
                    "fault-spec `{s}`: unknown field `{k}`"
                )))
            }
        }
    }
    Ok(Rule {
        point,
        transient,
        after,
        every,
        count,
        delay_us,
        crossings: AtomicU64::new(0),
        fired: AtomicU64::new(0),
    })
}

fn parse_spec(spec: &str) -> Result<Vec<Rule>> {
    spec.split(';')
        .map(str::trim)
        .filter(|s| !s.is_empty())
        .map(parse_rule)
        .collect()
}

/// The fault-injection plane: disarmed by default (one relaxed atomic
/// load per crossing), armed once by [`FaultPlane::install`].
#[derive(Debug, Default)]
pub struct FaultPlane {
    armed: AtomicBool,
    rules: OnceLock<Vec<Rule>>,
    fired_total: AtomicU64,
}

impl FaultPlane {
    pub fn new() -> FaultPlane {
        FaultPlane::default()
    }

    /// Install a fault plan (once per process lifetime of this plane).
    /// An empty spec leaves the plane disarmed.  Returns the rule count.
    pub fn install(&self, spec: &str) -> Result<usize> {
        let rules = parse_spec(spec)?;
        let n = rules.len();
        if n == 0 {
            return Ok(0);
        }
        self.rules
            .set(rules)
            .map_err(|_| Error::Config("fault plane already armed".into()))?;
        self.armed.store(true, Relaxed);
        Ok(n)
    }

    /// Whether any rule is installed.
    pub fn armed(&self) -> bool {
        self.armed.load(Relaxed)
    }

    /// Total faults fired across all rules.
    pub fn fired_total(&self) -> u64 {
        self.fired_total.load(Relaxed)
    }

    /// The gate every boundary calls.  Disarmed: one relaxed load, `Ok`.
    #[inline]
    pub fn check(&self, point: InjectPoint) -> Result<()> {
        if !self.armed.load(Relaxed) {
            return Ok(());
        }
        self.check_armed(point)
    }

    fn check_armed(&self, point: InjectPoint) -> Result<()> {
        let Some(rules) = self.rules.get() else {
            return Ok(());
        };
        for r in rules {
            if r.point != point {
                continue;
            }
            let n = r.crossings.fetch_add(1, Relaxed) + 1;
            if n <= r.after {
                continue;
            }
            if (n - r.after - 1) % r.every != 0 {
                continue;
            }
            if r.count > 0 && r.fired.fetch_add(1, Relaxed) >= r.count {
                continue;
            }
            if r.count == 0 {
                r.fired.fetch_add(1, Relaxed);
            }
            self.fired_total.fetch_add(1, Relaxed);
            if r.delay_us > 0 {
                std::thread::sleep(std::time::Duration::from_micros(r.delay_us));
            }
            return Err(Error::Injected {
                point: point.label(),
                transient: r.transient,
            });
        }
        Ok(())
    }
}

/// The serving paths whose health the ladder tracks.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PathId {
    /// Device-resident KV (buffer-chained cache sessions).
    DeviceKv,
    /// Batched span execution (span artifacts vs token-by-token).
    SpanExec,
    /// Multi-sequence `[B, T]` span groups (vs per-sequence spans).
    SpanBatch,
    /// Server-side speculative decoding (draft + span-verify vs plain
    /// per-token decode).  Demoted on verify faults and on sustained
    /// low acceptance; plain decode is the always-available fallback.
    SpecDec,
}

impl PathId {
    pub const ALL: [PathId; 4] = [
        PathId::DeviceKv,
        PathId::SpanExec,
        PathId::SpanBatch,
        PathId::SpecDec,
    ];

    pub fn label(self) -> &'static str {
        match self {
            PathId::DeviceKv => "device_kv",
            PathId::SpanExec => "span_exec",
            PathId::SpanBatch => "span_batch",
            PathId::SpecDec => "spec_decode",
        }
    }

    /// Stable small integer for trace-instant payloads and metrics
    /// labels (also the path's slot in the registry).
    pub fn index(self) -> usize {
        match self {
            PathId::DeviceKv => 0,
            PathId::SpanExec => 1,
            PathId::SpanBatch => 2,
            PathId::SpecDec => 3,
        }
    }
}

#[derive(Debug)]
struct PathState {
    /// Config gate (`--no-device-kv` etc.); never changed by faults.
    enabled: AtomicBool,
    /// Demoted (false) after a failure, re-promoted by the cooldown.
    healthy: AtomicBool,
    /// Step number (registry ticks) at the last demotion.
    demoted_at: AtomicU64,
    failures: AtomicU64,
    demotions: AtomicU64,
    promotions: AtomicU64,
}

impl Default for PathState {
    fn default() -> PathState {
        PathState {
            enabled: AtomicBool::new(true),
            healthy: AtomicBool::new(true),
            demoted_at: AtomicU64::new(0),
            failures: AtomicU64::new(0),
            demotions: AtomicU64::new(0),
            promotions: AtomicU64::new(0),
        }
    }
}

/// Per-path failure counters, demotion state, and cooldown-driven
/// recovery probes — the unified replacement for the engine's sticky
/// health booleans.  All methods are lock-free; the registry is shared
/// (`Arc`) between the engine (which records failures and answers
/// `active`) and the coordinator (which ticks it once per step and
/// surfaces transitions in metrics and trace instants).
#[derive(Debug)]
pub struct HealthRegistry {
    paths: [PathState; 4],
    /// Steps a demoted path waits before the re-promotion probe
    /// (0 = demote forever, the pre-ladder behavior).
    cooldown: AtomicU64,
    step: AtomicU64,
}

impl HealthRegistry {
    pub fn new(cooldown_steps: u64) -> HealthRegistry {
        HealthRegistry {
            paths: Default::default(),
            cooldown: AtomicU64::new(cooldown_steps),
            step: AtomicU64::new(0),
        }
    }

    pub fn set_cooldown(&self, steps: u64) {
        self.cooldown.store(steps, Relaxed);
    }

    pub fn cooldown(&self) -> u64 {
        self.cooldown.load(Relaxed)
    }

    /// The config gate: enable/disable a path outright.  Does not touch
    /// health — a disabled path keeps its demotion state for when it is
    /// re-enabled.
    pub fn set_enabled(&self, p: PathId, on: bool) {
        self.paths[p.index()].enabled.store(on, Relaxed);
    }

    pub fn enabled(&self, p: PathId) -> bool {
        self.paths[p.index()].enabled.load(Relaxed)
    }

    pub fn healthy(&self, p: PathId) -> bool {
        self.paths[p.index()].healthy.load(Relaxed)
    }

    /// Enabled AND currently healthy — the serving-time switch.
    pub fn active(&self, p: PathId) -> bool {
        let s = &self.paths[p.index()];
        s.enabled.load(Relaxed) && s.healthy.load(Relaxed)
    }

    /// Record a path failure; demotes on the healthy→unhealthy
    /// transition and returns whether this call was that transition.
    pub fn record_failure(&self, p: PathId) -> bool {
        let s = &self.paths[p.index()];
        s.failures.fetch_add(1, Relaxed);
        let was_healthy = s.healthy.swap(false, Relaxed);
        if was_healthy {
            s.demotions.fetch_add(1, Relaxed);
            s.demoted_at.store(self.step.load(Relaxed), Relaxed);
        }
        was_healthy
    }

    /// Advance the registry clock one step and re-promote every demoted
    /// path whose cooldown has elapsed.  The next use of a promoted
    /// path IS the recovery probe: success keeps it fast, failure
    /// re-demotes it and restarts the cooldown.  Returns the promoted
    /// paths so the caller can surface the transitions.
    pub fn tick(&self) -> Vec<PathId> {
        let now = self.step.fetch_add(1, Relaxed) + 1;
        let cd = self.cooldown.load(Relaxed);
        let mut promoted = Vec::new();
        if cd == 0 {
            return promoted;
        }
        for p in PathId::ALL {
            let s = &self.paths[p.index()];
            if s.enabled.load(Relaxed)
                && !s.healthy.load(Relaxed)
                && now.saturating_sub(s.demoted_at.load(Relaxed)) >= cd
            {
                s.healthy.store(true, Relaxed);
                s.promotions.fetch_add(1, Relaxed);
                promoted.push(p);
            }
        }
        promoted
    }

    pub fn failures(&self, p: PathId) -> u64 {
        self.paths[p.index()].failures.load(Relaxed)
    }

    pub fn demotions(&self, p: PathId) -> u64 {
        self.paths[p.index()].demotions.load(Relaxed)
    }

    pub fn promotions(&self, p: PathId) -> u64 {
        self.paths[p.index()].promotions.load(Relaxed)
    }

    pub fn total_demotions(&self) -> u64 {
        PathId::ALL.iter().map(|p| self.demotions(*p)).sum()
    }

    pub fn total_promotions(&self) -> u64 {
        PathId::ALL.iter().map(|p| self.promotions(*p)).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disarmed_plane_never_fires() {
        let p = FaultPlane::new();
        assert!(!p.armed());
        for pt in InjectPoint::ALL {
            for _ in 0..100 {
                p.check(pt).unwrap();
            }
        }
        assert_eq!(p.fired_total(), 0);
    }

    #[test]
    fn empty_spec_stays_disarmed() {
        let p = FaultPlane::new();
        assert_eq!(p.install("").unwrap(), 0);
        assert_eq!(p.install("  ;  ").unwrap(), 0);
        assert!(!p.armed());
    }

    #[test]
    fn spec_parse_errors() {
        for bad in [
            "bogus:transient",
            "exec",
            "exec:sometimes",
            "exec:transient:after",
            "exec:transient:after=x",
            "exec:transient:zorp=3",
        ] {
            assert!(
                FaultPlane::new().install(bad).is_err(),
                "spec `{bad}` should not parse"
            );
        }
    }

    #[test]
    fn after_every_count_semantics() {
        let p = FaultPlane::new();
        assert_eq!(p.install("exec:transient:after=3:every=2:count=2").unwrap(), 1);
        // Crossings 1..=3 pass (warmup); 4 fires, 5 passes, 6 fires,
        // then the count budget is spent and everything passes.
        let fires: Vec<bool> = (1..=10)
            .map(|_| p.check(InjectPoint::Exec).is_err())
            .collect();
        assert_eq!(
            fires,
            [false, false, false, true, false, true, false, false, false, false]
        );
        assert_eq!(p.fired_total(), 2);
        // Other points are untouched by an exec-only rule.
        p.check(InjectPoint::Sync).unwrap();
        p.check(InjectPoint::Gather).unwrap();
    }

    #[test]
    fn deterministic_across_planes() {
        let spec = "h2d:transient:after=2:every=3:count=5;exec:fatal:after=7";
        let run = || -> Vec<(bool, bool)> {
            let p = FaultPlane::new();
            p.install(spec).unwrap();
            (0..20)
                .map(|_| {
                    (
                        p.check(InjectPoint::H2d).is_err(),
                        p.check(InjectPoint::Exec).is_err(),
                    )
                })
                .collect()
        };
        assert_eq!(run(), run(), "same spec must fire the same sequence");
    }

    #[test]
    fn transient_vs_fatal_classification() {
        let p = FaultPlane::new();
        p.install("sync:transient;gather:fatal").unwrap();
        let t = p.check(InjectPoint::Sync).unwrap_err();
        assert!(t.is_transient(), "transient rule must classify transient");
        let f = p.check(InjectPoint::Gather).unwrap_err();
        assert!(!f.is_transient(), "fatal rule must classify fatal");
        assert!(t.to_string().contains("sync"));
        assert!(f.to_string().contains("gather"));
    }

    #[test]
    fn unbounded_rule_fires_every_crossing() {
        let p = FaultPlane::new();
        p.install("readback:transient").unwrap();
        for _ in 0..5 {
            assert!(p.check(InjectPoint::Readback).is_err());
        }
        assert_eq!(p.fired_total(), 5);
    }

    #[test]
    fn health_demote_then_cooldown_promotes() {
        let h = HealthRegistry::new(3);
        assert!(h.active(PathId::DeviceKv));
        // Step a bit, then fail: demotes on the first failure only.
        h.tick();
        assert!(h.record_failure(PathId::DeviceKv));
        assert!(!h.record_failure(PathId::DeviceKv), "already demoted");
        assert!(!h.active(PathId::DeviceKv));
        assert_eq!(h.failures(PathId::DeviceKv), 2);
        assert_eq!(h.demotions(PathId::DeviceKv), 1);
        // Two more ticks: still cooling down.
        assert!(h.tick().is_empty());
        assert!(h.tick().is_empty());
        assert!(!h.active(PathId::DeviceKv));
        // Third tick past the demotion: promoted.
        assert_eq!(h.tick(), vec![PathId::DeviceKv]);
        assert!(h.active(PathId::DeviceKv));
        assert_eq!(h.promotions(PathId::DeviceKv), 1);
        // A failed probe re-demotes and the cooldown restarts.
        assert!(h.record_failure(PathId::DeviceKv));
        assert!(h.tick().is_empty());
    }

    #[test]
    fn zero_cooldown_is_sticky() {
        let h = HealthRegistry::new(0);
        h.record_failure(PathId::SpanExec);
        for _ in 0..100 {
            assert!(h.tick().is_empty());
        }
        assert!(!h.active(PathId::SpanExec));
    }

    #[test]
    fn disabled_paths_never_promote() {
        let h = HealthRegistry::new(1);
        h.record_failure(PathId::SpanBatch);
        h.set_enabled(PathId::SpanBatch, false);
        assert!(h.tick().is_empty(), "disabled path must not probe");
        assert!(!h.active(PathId::SpanBatch));
        // Re-enabling makes it eligible again on the next tick.
        h.set_enabled(PathId::SpanBatch, true);
        assert_eq!(h.tick(), vec![PathId::SpanBatch]);
        assert!(h.active(PathId::SpanBatch));
    }

    #[test]
    fn enable_gate_independent_of_health() {
        let h = HealthRegistry::new(5);
        h.set_enabled(PathId::DeviceKv, false);
        assert!(!h.active(PathId::DeviceKv));
        assert!(h.healthy(PathId::DeviceKv), "disabling is not a demotion");
        h.set_enabled(PathId::DeviceKv, true);
        assert!(h.active(PathId::DeviceKv));
        assert_eq!(h.total_demotions(), 0);
    }
}
