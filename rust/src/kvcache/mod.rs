//! Paged KV cache (S7): vLLM-style block allocator + per-sequence block
//! tables, host-resident.
//!
//! The store is the source of truth for every sequence's K/V history; the
//! engine consumes a dense `[L, B, S, KH, hd]` gather per step and returns
//! one new row per (layer, sequence), which is scattered back here.  Blocks
//! are `block_tokens` slots of `L·KH·hd` values each for K and V.
//!
//! Supports reference-counted block sharing (prefix fork for beam search /
//! n-best sampling) with copy-on-write on the last partial block.

use std::collections::HashMap;

use crate::error::{Error, Result};

/// Fixed-pool block allocator.
#[derive(Debug)]
pub struct BlockAllocator {
    free: Vec<u32>,
    refcount: Vec<u32>,
    total: usize,
}

impl BlockAllocator {
    pub fn new(total: usize) -> BlockAllocator {
        BlockAllocator {
            free: (0..total as u32).rev().collect(),
            refcount: vec![0; total],
            total,
        }
    }

    pub fn alloc(&mut self) -> Result<u32> {
        let b = self
            .free
            .pop()
            .ok_or_else(|| Error::KvCache("out of KV blocks".into()))?;
        debug_assert_eq!(self.refcount[b as usize], 0);
        self.refcount[b as usize] = 1;
        Ok(b)
    }

    pub fn retain(&mut self, block: u32) {
        assert!(self.refcount[block as usize] > 0, "retain of free block");
        self.refcount[block as usize] += 1;
    }

    pub fn release(&mut self, block: u32) {
        let rc = &mut self.refcount[block as usize];
        assert!(*rc > 0, "double free of block {block}");
        *rc -= 1;
        if *rc == 0 {
            self.free.push(block);
        }
    }

    pub fn refcount(&self, block: u32) -> u32 {
        self.refcount[block as usize]
    }

    pub fn free_blocks(&self) -> usize {
        self.free.len()
    }

    pub fn total_blocks(&self) -> usize {
        self.total
    }
}

/// Per-sequence cache state.
#[derive(Debug, Clone)]
struct SeqState {
    blocks: Vec<u32>,
    len: usize,
}

/// The paged store for one model's caches.
pub struct PagedKvCache {
    alloc: BlockAllocator,
    seqs: HashMap<u64, SeqState>,
    /// Blocks leased by the cross-request prefix cache
    /// (`rust/src/prefixcache/`): alive without an owning sequence.
    /// Key = block id, value = lease count (the allocator refcount
    /// carries the same number of retains).
    leases: HashMap<u32, u32>,
    /// Leased blocks whose refcount equals their lease count — held by
    /// the prefix cache alone, reclaimable right now.  Maintained on
    /// every lease/refcount transition so the per-step planner reads it
    /// in O(1) instead of walking the prefix-cache node arena.
    evictable_leased: usize,
    /// Tokens per block.
    block_tokens: usize,
    /// Values per (layer-stacked) slot: `L · KH · hd`.
    slot_width: usize,
    n_layers: usize,
    kv_width: usize, // KH · hd
    /// Block storage: `[block][token_in_block][L][KH·hd]` for K and V.
    k: Vec<f32>,
    v: Vec<f32>,
}

impl PagedKvCache {
    pub fn new(
        total_blocks: usize,
        block_tokens: usize,
        n_layers: usize,
        n_kv_heads: usize,
        head_dim: usize,
    ) -> PagedKvCache {
        let kv_width = n_kv_heads * head_dim;
        let slot_width = n_layers * kv_width;
        let elems = total_blocks * block_tokens * slot_width;
        PagedKvCache {
            alloc: BlockAllocator::new(total_blocks),
            seqs: HashMap::new(),
            leases: HashMap::new(),
            evictable_leased: 0,
            block_tokens,
            slot_width,
            n_layers,
            kv_width,
            k: vec![0.0; elems],
            v: vec![0.0; elems],
        }
    }

    pub fn block_tokens(&self) -> usize {
        self.block_tokens
    }

    pub fn free_blocks(&self) -> usize {
        self.alloc.free_blocks()
    }

    pub fn total_blocks(&self) -> usize {
        self.alloc.total_blocks()
    }

    pub fn seq_len(&self, seq: u64) -> Option<usize> {
        self.seqs.get(&seq).map(|s| s.len)
    }

    /// Blocks currently held by a sequence (preemption accounting).
    pub fn blocks_held(&self, seq: u64) -> usize {
        self.seqs.get(&seq).map(|s| s.blocks.len()).unwrap_or(0)
    }

    /// Whether appending one token to `seq` would require a fresh block.
    pub fn growth_needs_block(&self, seq: u64) -> bool {
        match self.seqs.get(&seq) {
            Some(s) => s.blocks.len() < self.blocks_for(s.len + 1),
            None => true,
        }
    }

    pub fn num_seqs(&self) -> usize {
        self.seqs.len()
    }

    /// Blocks needed to hold `len` tokens.
    pub fn blocks_for(&self, len: usize) -> usize {
        len.div_ceil(self.block_tokens)
    }

    /// Whether `extra` more tokens fit for `seq` without allocation failure.
    pub fn can_grow(&self, seq: u64, extra: usize) -> bool {
        let cur = self.seqs.get(&seq).map(|s| (s.blocks.len(), s.len));
        let (have, len) = cur.unwrap_or((0, 0));
        let need = self.blocks_for(len + extra).saturating_sub(have);
        need <= self.alloc.free_blocks()
    }

    /// Register a new sequence with capacity for `len` tokens.
    pub fn create(&mut self, seq: u64, len_hint: usize) -> Result<()> {
        if self.seqs.contains_key(&seq) {
            return Err(Error::KvCache(format!("seq {seq} already exists")));
        }
        let mut blocks = Vec::new();
        for _ in 0..self.blocks_for(len_hint.max(1)) {
            match self.alloc.alloc() {
                Ok(b) => blocks.push(b),
                Err(e) => {
                    for b in blocks {
                        self.release_block(b);
                    }
                    return Err(e);
                }
            }
        }
        self.seqs.insert(seq, SeqState { blocks, len: 0 });
        Ok(())
    }

    /// Whether `block` is held by prefix-cache leases alone (refcount ==
    /// lease count): reclaimable without touching any sequence.
    fn lease_evictable(&self, block: u32) -> bool {
        self.leases
            .get(&block)
            .is_some_and(|&c| self.alloc.refcount(block) == c)
    }

    /// Re-derive `block`'s contribution to the evictable count after a
    /// refcount or lease transition (`was` = evictable before it).
    fn note_evictable(&mut self, block: u32, was: bool) {
        let now = self.lease_evictable(block);
        match (was, now) {
            (false, true) => self.evictable_leased += 1,
            (true, false) => self.evictable_leased -= 1,
            _ => {}
        }
    }

    /// Refcount retain that keeps the evictable-lease counter exact.
    fn retain_block(&mut self, block: u32) {
        let was = self.lease_evictable(block);
        self.alloc.retain(block);
        self.note_evictable(block, was);
    }

    /// Refcount release that keeps the evictable-lease counter exact.
    fn release_block(&mut self, block: u32) {
        let was = self.lease_evictable(block);
        self.alloc.release(block);
        self.note_evictable(block, was);
    }

    /// Drop a sequence, releasing its blocks — the finish, preemption
    /// AND cancellation path (a `Coordinator::cancel` removes here after
    /// the device-session sync; blocks a prefix-cache lease still holds
    /// survive, everything else returns to the free list).
    pub fn remove(&mut self, seq: u64) -> Result<()> {
        let st = self
            .seqs
            .remove(&seq)
            .ok_or_else(|| Error::KvCache(format!("seq {seq} not found")))?;
        for b in st.blocks {
            self.release_block(b);
        }
        Ok(())
    }

    /// Fork `src` into `dst` sharing all blocks (copy-on-write applies to
    /// the last, partially-filled block which is deep-copied).
    pub fn fork(&mut self, src: u64, dst: u64) -> Result<()> {
        if self.seqs.contains_key(&dst) {
            return Err(Error::KvCache(format!("seq {dst} already exists")));
        }
        let st = self
            .seqs
            .get(&src)
            .ok_or_else(|| Error::KvCache(format!("seq {src} not found")))?
            .clone();
        let mut blocks = st.blocks.clone();
        // Share full blocks.
        for &b in &blocks {
            self.retain_block(b);
        }
        // Deep-copy the partial tail so the fork can diverge.
        if st.len % self.block_tokens != 0 && !blocks.is_empty() {
            let tail = *blocks.last().unwrap();
            let fresh = match self.alloc.alloc() {
                Ok(b) => b,
                Err(e) => {
                    // Roll back the retains: the fork was never created.
                    for &b in &blocks {
                        self.release_block(b);
                    }
                    return Err(e);
                }
            };
            let bw = self.block_tokens * self.slot_width;
            let (src_o, dst_o) = (tail as usize * bw, fresh as usize * bw);
            self.k.copy_within(src_o..src_o + bw, dst_o);
            self.v.copy_within(src_o..src_o + bw, dst_o);
            self.release_block(tail);
            *blocks.last_mut().unwrap() = fresh;
        }
        self.seqs.insert(dst, SeqState { blocks, len: st.len });
        Ok(())
    }

    /// A sequence's block table in position order (prefix-cache insert
    /// harvests the prompt's — and, since protocol v2, the generated
    /// span's — full blocks from here on finish).
    pub fn seq_blocks(&self, seq: u64) -> Option<&[u32]> {
        self.seqs.get(&seq).map(|s| s.blocks.as_slice())
    }

    /// Allocator refcount of one block (0 = free).  The prefix cache
    /// uses this to tell pinned blocks (shared with a live sequence,
    /// refcount > 1) from evictable ones (lease only, refcount == 1).
    pub fn block_refcount(&self, block: u32) -> u32 {
        self.alloc.refcount(block)
    }

    /// Take a lease on an allocated block: keeps it alive independent of
    /// any sequence (the prefix cache's ownership handle).
    pub fn lease_block(&mut self, block: u32) {
        let was = self.lease_evictable(block);
        self.alloc.retain(block);
        *self.leases.entry(block).or_insert(0) += 1;
        self.note_evictable(block, was);
    }

    /// Drop a lease taken with [`PagedKvCache::lease_block`]; the block
    /// returns to the free list once no sequence shares it either.
    pub fn unlease_block(&mut self, block: u32) {
        let was = self.lease_evictable(block);
        let c = self
            .leases
            .get_mut(&block)
            .unwrap_or_else(|| panic!("unlease of unleased block {block}"));
        *c -= 1;
        if *c == 0 {
            self.leases.remove(&block);
        }
        self.alloc.release(block);
        self.note_evictable(block, was);
    }

    /// Blocks currently held by leases (prefix-cache accounting).
    pub fn leased_blocks(&self) -> usize {
        self.leases.values().map(|&c| c as usize).sum()
    }

    /// Leased blocks reclaimable right now (refcount == lease count: the
    /// prefix cache alone holds them).  O(1) — the counter is maintained
    /// on lease/refcount transitions, replacing the per-step O(nodes)
    /// arena walk the prefix cache used to do.
    pub fn evictable_leased_blocks(&self) -> usize {
        self.evictable_leased
    }

    /// Register `seq` sharing `blocks` (all full: `len` must equal
    /// `blocks.len() * block_tokens`) — the prefix-cache fork.  Unlike
    /// [`PagedKvCache::fork`] there is no copy-on-write tail to copy:
    /// block-granular matching guarantees the shared span is
    /// block-aligned, so every subsequent append lands in fresh blocks.
    /// Allocates nothing; only refcounts move, so it cannot fail for
    /// lack of pool space.
    pub fn create_shared(&mut self, seq: u64, blocks: &[u32], len: usize) -> Result<()> {
        if self.seqs.contains_key(&seq) {
            return Err(Error::KvCache(format!("seq {seq} already exists")));
        }
        if len != blocks.len() * self.block_tokens {
            return Err(Error::KvCache(format!(
                "create_shared: len {len} != {} full blocks of {}",
                blocks.len(),
                self.block_tokens
            )));
        }
        for &b in blocks {
            if self.alloc.refcount(b) == 0 {
                return Err(Error::KvCache(format!(
                    "create_shared: block {b} is free"
                )));
            }
        }
        for &b in blocks {
            self.retain_block(b);
        }
        self.seqs.insert(
            seq,
            SeqState {
                blocks: blocks.to_vec(),
                len,
            },
        );
        Ok(())
    }

    fn slot_offset(&self, st: &SeqState, pos: usize, layer: usize) -> usize {
        let block = st.blocks[pos / self.block_tokens] as usize;
        let within = pos % self.block_tokens;
        (block * self.block_tokens + within) * self.slot_width + layer * self.kv_width
    }

    /// Append one token's K/V rows (layout `[L, KH·hd]`) at position
    /// `seq_len`, growing the block table as needed.
    pub fn append(&mut self, seq: u64, k_rows: &[f32], v_rows: &[f32]) -> Result<()> {
        if k_rows.len() != self.slot_width || v_rows.len() != self.slot_width {
            return Err(Error::KvCache(format!(
                "append row width {} != {}",
                k_rows.len(),
                self.slot_width
            )));
        }
        let st = self
            .seqs
            .get(&seq)
            .ok_or_else(|| Error::KvCache(format!("seq {seq} not found")))?;
        let pos = st.len;
        let need_blocks = self.blocks_for(pos + 1);
        if need_blocks > st.blocks.len() {
            let b = self.alloc.alloc()?;
            self.seqs.get_mut(&seq).unwrap().blocks.push(b);
        }
        let st = self.seqs.get(&seq).unwrap().clone();
        for l in 0..self.n_layers {
            let o = self.slot_offset(&st, pos, l);
            self.k[o..o + self.kv_width]
                .copy_from_slice(&k_rows[l * self.kv_width..(l + 1) * self.kv_width]);
            self.v[o..o + self.kv_width]
                .copy_from_slice(&v_rows[l * self.kv_width..(l + 1) * self.kv_width]);
        }
        self.seqs.get_mut(&seq).unwrap().len = pos + 1;
        Ok(())
    }

    /// Append `n` consecutive token rows at the current end of `seq` —
    /// the partial-prompt KV span a chunked-prefill continuation produces.
    /// `k_rows`/`v_rows` are `[n, L, KH·hd]` token-major (the engine's
    /// `SpanOut`/`DecodeOut` layout).  On allocation failure mid-span the
    /// rows appended so far remain (the caller drops the sequence).
    pub fn append_span(
        &mut self,
        seq: u64,
        n: usize,
        k_rows: &[f32],
        v_rows: &[f32],
    ) -> Result<()> {
        let need = n * self.slot_width;
        if k_rows.len() != need || v_rows.len() != need {
            return Err(Error::KvCache(format!(
                "append_span: rows len {} != {n} x {}",
                k_rows.len(),
                self.slot_width
            )));
        }
        for i in 0..n {
            let at = i * self.slot_width..(i + 1) * self.slot_width;
            self.append(seq, &k_rows[at.clone()], &v_rows[at])?;
        }
        Ok(())
    }

    /// Bulk-write a prefilled prefix (from `PrefillOut`): `rows` is
    /// `[L, S, KH·hd]` dense for this sequence, of which the first `len`
    /// slots are valid.
    pub fn write_prefix(
        &mut self,
        seq: u64,
        len: usize,
        s_stride: usize,
        k_dense: &[f32],
        v_dense: &[f32],
    ) -> Result<()> {
        {
            let st = self
                .seqs
                .get(&seq)
                .ok_or_else(|| Error::KvCache(format!("seq {seq} not found")))?;
            if st.len != 0 {
                return Err(Error::KvCache("write_prefix on non-empty seq".into()));
            }
        }
        // Grow block table to fit.
        while self.seqs[&seq].blocks.len() < self.blocks_for(len) {
            let b = self.alloc.alloc()?;
            self.seqs.get_mut(&seq).unwrap().blocks.push(b);
        }
        let st = self.seqs[&seq].clone();
        for l in 0..self.n_layers {
            for pos in 0..len {
                let src = (l * s_stride + pos) * self.kv_width;
                let o = self.slot_offset(&st, pos, l);
                self.k[o..o + self.kv_width]
                    .copy_from_slice(&k_dense[src..src + self.kv_width]);
                self.v[o..o + self.kv_width]
                    .copy_from_slice(&v_dense[src..src + self.kv_width]);
            }
        }
        self.seqs.get_mut(&seq).unwrap().len = len;
        Ok(())
    }

    /// Gather a sequence's cache into a dense `[L, S, KH·hd]` destination
    /// (one batch row of the engine's `CacheBatch`).
    pub fn gather_dense(
        &self,
        seq: u64,
        s_capacity: usize,
        k_out: &mut [f32],
        v_out: &mut [f32],
    ) -> Result<usize> {
        let st = self
            .seqs
            .get(&seq)
            .ok_or_else(|| Error::KvCache(format!("seq {seq} not found")))?;
        if st.len > s_capacity {
            return Err(Error::KvCache(format!(
                "seq len {} exceeds capacity {s_capacity}",
                st.len
            )));
        }
        let need = self.n_layers * s_capacity * self.kv_width;
        if k_out.len() != need || v_out.len() != need {
            return Err(Error::KvCache("gather_dense: bad dst size".into()));
        }
        for l in 0..self.n_layers {
            for pos in 0..st.len {
                let o = self.slot_offset(st, pos, l);
                let dst = (l * s_capacity + pos) * self.kv_width;
                k_out[dst..dst + self.kv_width].copy_from_slice(&self.k[o..o + self.kv_width]);
                v_out[dst..dst + self.kv_width].copy_from_slice(&self.v[o..o + self.kv_width]);
            }
        }
        Ok(st.len)
    }

    /// Gather directly into row `batch_i` of a dense batch cache laid out
    /// `[L, B, S, KH·hd]` (the engine's `CacheBatch`), skipping the
    /// intermediate per-sequence `[L, S, ·]` copy the two-step
    /// `gather_dense` + repack path would make (§Perf: one full cache copy
    /// per sequence per step removed).
    pub fn gather_into_batch(
        &self,
        seq: u64,
        s_capacity: usize,
        batch_b: usize,
        batch_i: usize,
        k_out: &mut [f32],
        v_out: &mut [f32],
    ) -> Result<usize> {
        let st = self
            .seqs
            .get(&seq)
            .ok_or_else(|| Error::KvCache(format!("seq {seq} not found")))?;
        if st.len > s_capacity {
            return Err(Error::KvCache(format!(
                "seq len {} exceeds capacity {s_capacity}",
                st.len
            )));
        }
        let need = self.n_layers * batch_b * s_capacity * self.kv_width;
        if k_out.len() != need || v_out.len() != need || batch_i >= batch_b {
            return Err(Error::KvCache("gather_into_batch: bad dst".into()));
        }
        let w = self.kv_width;
        for l in 0..self.n_layers {
            let base = (l * batch_b + batch_i) * s_capacity * w;
            // Copy whole-block runs where possible: consecutive positions
            // within one block are contiguous in the store.
            let mut pos = 0;
            while pos < st.len {
                let run = (self.block_tokens - pos % self.block_tokens)
                    .min(st.len - pos);
                let o = self.slot_offset(st, pos, l);
                // Slots within a block are slot_width apart, not kv_width —
                // contiguous only when n_layers == 1; copy per slot.
                for r in 0..run {
                    let src = o + r * self.slot_width;
                    let dst = base + (pos + r) * w;
                    k_out[dst..dst + w].copy_from_slice(&self.k[src..src + w]);
                    v_out[dst..dst + w].copy_from_slice(&self.v[src..src + w]);
                }
                pos += run;
            }
        }
        Ok(st.len)
    }

    /// Invariant check used by tests and `firstlayer selfcheck`: the free
    /// list, the per-seq block tables, and the prefix-cache leases
    /// partition the pool, and every refcount matches the number of
    /// owners.
    pub fn check_invariants(&self) -> Result<()> {
        let mut owners = vec![0u32; self.alloc.total_blocks()];
        for st in self.seqs.values() {
            for &b in &st.blocks {
                owners[b as usize] += 1;
            }
        }
        for (&b, &c) in &self.leases {
            owners[b as usize] += c;
        }
        for b in 0..self.alloc.total_blocks() as u32 {
            let rc = self.alloc.refcount(b);
            if rc != owners[b as usize] {
                return Err(Error::KvCache(format!(
                    "block {b}: refcount {rc} != owners {}",
                    owners[b as usize]
                )));
            }
        }
        let used: usize = owners.iter().filter(|&&o| o > 0).count();
        if used + self.alloc.free_blocks() != self.alloc.total_blocks() {
            return Err(Error::KvCache("free list + used != total".into()));
        }
        for st in self.seqs.values() {
            if st.blocks.len() < self.blocks_for(st.len) {
                return Err(Error::KvCache("seq has fewer blocks than len".into()));
            }
        }
        let evictable = self
            .leases
            .iter()
            .filter(|(&b, &c)| self.alloc.refcount(b) == c)
            .count();
        if evictable != self.evictable_leased {
            return Err(Error::KvCache(format!(
                "evictable-lease counter {} != recount {evictable}",
                self.evictable_leased
            )));
        }
        Ok(())
    }
}

/// The paged store is itself a scheduler budget view — the canonical
/// 1:1 delegation (benches and tests plan directly against a cache;
/// the coordinator wraps it in a view that also counts reclaimable
/// prefix-cache blocks as free).
impl crate::scheduler::KvBudget for PagedKvCache {
    fn free_blocks(&self) -> usize {
        PagedKvCache::free_blocks(self)
    }
    fn blocks_for(&self, tokens: usize) -> usize {
        PagedKvCache::blocks_for(self, tokens)
    }
    fn blocks_held(&self, id: u64) -> usize {
        PagedKvCache::blocks_held(self, id)
    }
    fn growth_needs_block(&self, id: u64) -> bool {
        PagedKvCache::growth_needs_block(self, id)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn cache() -> PagedKvCache {
        // 8 blocks of 4 tokens; 2 layers, kh*hd = 6.
        PagedKvCache::new(8, 4, 2, 2, 3)
    }

    fn row(val: f32, w: usize) -> Vec<f32> {
        vec![val; w]
    }

    #[test]
    fn create_append_gather() {
        let mut c = cache();
        c.create(1, 1).unwrap();
        let w = 2 * 6;
        for i in 0..6 {
            c.append(1, &row(i as f32, w), &row(-(i as f32), w)).unwrap();
        }
        assert_eq!(c.seq_len(1), Some(6));
        let cap = 8;
        let mut k = vec![0f32; 2 * cap * 6];
        let mut v = vec![0f32; 2 * cap * 6];
        let len = c.gather_dense(1, cap, &mut k, &mut v).unwrap();
        assert_eq!(len, 6);
        // layer 0, pos 5 == 5.0; layer 1, pos 2 == 2.0
        assert_eq!(k[(0 * cap + 5) * 6], 5.0);
        assert_eq!(k[(1 * cap + 2) * 6], 2.0);
        assert_eq!(v[(0 * cap + 3) * 6], -3.0);
        c.check_invariants().unwrap();
    }

    #[test]
    fn remove_frees_blocks() {
        let mut c = cache();
        c.create(1, 16).unwrap(); // 4 blocks
        assert_eq!(c.free_blocks(), 4);
        c.remove(1).unwrap();
        assert_eq!(c.free_blocks(), 8);
        c.check_invariants().unwrap();
    }

    #[test]
    fn exhaustion_errors_cleanly() {
        let mut c = cache();
        c.create(1, 32).unwrap(); // all 8 blocks
        assert!(c.create(2, 1).is_err());
        assert_eq!(c.num_seqs(), 1); // failed create leaks nothing
        c.check_invariants().unwrap();
    }

    #[test]
    fn fork_shares_full_blocks_and_copies_tail() {
        let mut c = cache();
        c.create(1, 1).unwrap();
        let w = 12;
        for i in 0..5 {
            // 1 full block + 1 partial
            c.append(1, &row(i as f32, w), &row(0.0, w)).unwrap();
        }
        let before = c.free_blocks();
        c.fork(1, 2).unwrap();
        // Fork consumed exactly one fresh block (the CoW tail).
        assert_eq!(c.free_blocks(), before - 1);
        c.check_invariants().unwrap();
        // Divergence: append to the fork must not affect the parent.
        c.append(2, &row(100.0, w), &row(0.0, w)).unwrap();
        let cap = 8;
        let mut k1 = vec![0f32; 2 * cap * 6];
        let mut v1 = k1.clone();
        let mut k2 = k1.clone();
        let mut v2 = k1.clone();
        c.gather_dense(1, cap, &mut k1, &mut v1).unwrap();
        c.gather_dense(2, cap, &mut k2, &mut v2).unwrap();
        assert_eq!(k1[..5 * 6], k2[..5 * 6]); // shared prefix identical
        assert_eq!(k2[5 * 6], 100.0);
        assert_eq!(k1[5 * 6], 0.0); // parent slot untouched
        // Parent can also diverge independently.
        c.append(1, &row(-7.0, w), &row(0.0, w)).unwrap();
        c.gather_dense(2, cap, &mut k2, &mut v2).unwrap();
        assert_eq!(k2[5 * 6], 100.0);
        c.check_invariants().unwrap();
    }

    #[test]
    fn append_span_matches_per_token_appends() {
        let w = 2 * 6;
        let mut a = cache();
        let mut b = cache();
        a.create(1, 1).unwrap();
        b.create(1, 1).unwrap();
        // Prefix of 3 tokens, then a 6-token span crossing a block boundary.
        for i in 0..3 {
            a.append(1, &row(i as f32, w), &row(-(i as f32), w)).unwrap();
            b.append(1, &row(i as f32, w), &row(-(i as f32), w)).unwrap();
        }
        let mut ks = Vec::new();
        let mut vs = Vec::new();
        for i in 3..9 {
            ks.extend(row(i as f32, w));
            vs.extend(row(-(i as f32), w));
            a.append(1, &row(i as f32, w), &row(-(i as f32), w)).unwrap();
        }
        b.append_span(1, 6, &ks, &vs).unwrap();
        assert_eq!(a.seq_len(1), b.seq_len(1));
        let cap = 12;
        let mut ka = vec![0f32; 2 * cap * 6];
        let mut va = ka.clone();
        let mut kb = ka.clone();
        let mut vb = ka.clone();
        a.gather_dense(1, cap, &mut ka, &mut va).unwrap();
        b.gather_dense(1, cap, &mut kb, &mut vb).unwrap();
        assert_eq!(ka, kb);
        assert_eq!(va, vb);
        b.check_invariants().unwrap();
        // Bad span size rejected.
        assert!(b.append_span(1, 2, &ks[..w], &vs[..w]).is_err());
    }

    #[test]
    fn write_prefix_bulk() {
        let mut c = cache();
        c.create(9, 1).unwrap();
        let s_stride = 8;
        let mut kd = vec![0f32; 2 * s_stride * 6];
        let vd = kd.clone();
        for l in 0..2 {
            for p in 0..7 {
                kd[(l * s_stride + p) * 6] = (l * 10 + p) as f32;
            }
        }
        c.write_prefix(9, 7, s_stride, &kd, &vd).unwrap();
        assert_eq!(c.seq_len(9), Some(7));
        let mut k = vec![0f32; 2 * 8 * 6];
        let mut v = k.clone();
        c.gather_dense(9, 8, &mut k, &mut v).unwrap();
        assert_eq!(k[(1 * 8 + 6) * 6], 16.0);
        c.check_invariants().unwrap();
    }

    #[test]
    fn gather_into_batch_matches_gather_dense() {
        let mut c = cache();
        let w = 12;
        for id in [1u64, 2] {
            c.create(id, 1).unwrap();
            for i in 0..7 {
                c.append(id, &row((id * 100 + i) as f32, w), &row(0.25, w))
                    .unwrap();
            }
        }
        let (cap, b) = (8usize, 3usize);
        // Reference: two-step gather + repack.
        let mut kd = vec![0f32; 2 * cap * 6];
        let mut vd = kd.clone();
        c.gather_dense(2, cap, &mut kd, &mut vd).unwrap();
        // Direct strided gather into batch row 1 of 3.
        let mut kb = vec![0f32; 2 * b * cap * 6];
        let mut vb = kb.clone();
        c.gather_into_batch(2, cap, b, 1, &mut kb, &mut vb).unwrap();
        for l in 0..2 {
            for pos in 0..7 {
                for x in 0..6 {
                    let want = kd[(l * cap + pos) * 6 + x];
                    let got = kb[((l * b + 1) * cap + pos) * 6 + x];
                    assert_eq!(got, want, "l={l} pos={pos} x={x}");
                }
            }
        }
        // Other batch rows untouched (still zero).
        assert!(kb[..cap * 6].iter().all(|&x| x == 0.0));
    }

    #[test]
    fn create_shared_and_leases() {
        let mut c = cache(); // 8 blocks x 4 tokens
        let w = 12;
        c.create(1, 1).unwrap();
        for i in 0..8 {
            // exactly 2 full blocks
            c.append(1, &row(i as f32, w), &row(0.5, w)).unwrap();
        }
        let blocks = c.seq_blocks(1).unwrap().to_vec();
        // Lease both (prefix-cache insert shape), then drop the owner.
        for &b in &blocks {
            c.lease_block(b);
        }
        c.remove(1).unwrap();
        assert_eq!(c.leased_blocks(), 2);
        assert_eq!(c.free_blocks(), 6);
        c.check_invariants().unwrap();
        // Fork into a new sequence; shared content must read back.
        c.create_shared(2, &blocks, 8).unwrap();
        assert_eq!(c.seq_len(2), Some(8));
        for &b in &blocks {
            assert_eq!(c.block_refcount(b), 2);
        }
        // Appends land in fresh blocks, never the shared span.
        c.append(2, &row(100.0, w), &row(0.0, w)).unwrap();
        let cap = 12;
        let mut k = vec![0f32; 2 * cap * 6];
        let mut v = k.clone();
        c.gather_dense(2, cap, &mut k, &mut v).unwrap();
        assert_eq!(k[3 * 6], 3.0); // shared block content intact
        assert_eq!(k[8 * 6], 100.0); // the append
        c.check_invariants().unwrap();
        // Misaligned share rejected.
        assert!(c.create_shared(3, &blocks, 7).is_err());
        c.remove(2).unwrap();
        for &b in &blocks {
            c.unlease_block(b);
        }
        assert_eq!(c.free_blocks(), 8);
        c.check_invariants().unwrap();
        // Sharing freed blocks rejected (stale match).
        assert!(c.create_shared(4, &blocks, 8).is_err());
    }

    /// The O(1) evictable-lease counter tracks pin/unpin transitions
    /// exactly: leasing a live sequence's blocks pins them, dropping the
    /// sequence unpins, re-sharing pins again.
    #[test]
    fn evictable_lease_counter_tracks_transitions() {
        let mut c = cache(); // 8 blocks x 4 tokens
        let w = 12;
        c.create(1, 1).unwrap();
        for i in 0..8 {
            c.append(1, &row(i as f32, w), &row(0.5, w)).unwrap();
        }
        let blocks = c.seq_blocks(1).unwrap().to_vec();
        assert_eq!(c.evictable_leased_blocks(), 0);
        for &b in &blocks {
            c.lease_block(b); // refcount 2 (seq + lease): pinned
        }
        assert_eq!(c.evictable_leased_blocks(), 0);
        c.remove(1).unwrap(); // lease only: both become evictable
        assert_eq!(c.evictable_leased_blocks(), 2);
        c.create_shared(2, &blocks, 8).unwrap(); // re-pinned by the fork
        assert_eq!(c.evictable_leased_blocks(), 0);
        c.remove(2).unwrap();
        assert_eq!(c.evictable_leased_blocks(), 2);
        c.unlease_block(blocks[0]);
        assert_eq!(c.evictable_leased_blocks(), 1);
        c.unlease_block(blocks[1]);
        assert_eq!(c.evictable_leased_blocks(), 0);
        c.check_invariants().unwrap();
    }

    /// Property test (in-tree harness): random alloc/append/fork/remove
    /// sequences never violate the partition/refcount invariants, never
    /// double-allocate, and always recover all blocks at the end.
    #[test]
    fn prop_random_ops_preserve_invariants() {
        for seed in 0..30u64 {
            let mut rng = Rng::new(seed);
            let mut c = PagedKvCache::new(24, 4, 2, 1, 4);
            let w = 2 * 4;
            let mut live: Vec<u64> = Vec::new();
            let mut next_id = 0u64;
            for _ in 0..200 {
                match rng.below(10) {
                    0..=2 => {
                        let id = next_id;
                        next_id += 1;
                        if c.create(id, rng.range(1, 6)).is_ok() {
                            live.push(id);
                        }
                    }
                    3..=6 if !live.is_empty() => {
                        let id = live[rng.range(0, live.len())];
                        let _ = c.append(id, &vec![1.0; w], &vec![2.0; w]);
                    }
                    7 if !live.is_empty() => {
                        let src = live[rng.range(0, live.len())];
                        let id = next_id;
                        next_id += 1;
                        if c.fork(src, id).is_ok() {
                            live.push(id);
                        }
                    }
                    _ if !live.is_empty() => {
                        let i = rng.range(0, live.len());
                        let id = live.swap_remove(i);
                        c.remove(id).unwrap();
                    }
                    _ => {}
                }
                c.check_invariants()
                    .unwrap_or_else(|e| panic!("seed {seed}: {e}"));
            }
            for id in live {
                c.remove(id).unwrap();
            }
            assert_eq!(c.free_blocks(), 24, "seed {seed}: blocks leaked");
        }
    }
}
