//! # firstlayer
//!
//! A three-layer serving framework reproducing **"Transformer tricks:
//! Precomputing the first layer"** (Graef, 2024): for RoPE transformers the
//! first layer's Q/K/V projections (plus the FFN and skip-connection for
//! parallel-attention models) depend only on the token embedding, so they
//! can be computed offline for the whole vocabulary and served as a table
//! lookup of `2(d+e)` values per token.
//!
//! Layers:
//! * **L1/L2 (build time, Python)** — Pallas kernels + JAX model, AOT-lowered
//!   to HLO text under `artifacts/` (see `python/compile/`).
//! * **L3 (this crate)** — the serving coordinator: PJRT runtime, paged KV
//!   cache, continuous-batching scheduler, precompute table manager,
//!   tokenizer, metrics, cost model and traffic simulator, TCP server.
//!
//! Python never runs on the request path; the binary is self-contained once
//! `make artifacts` has produced the AOT bundle.
//!
//! `ARCHITECTURE.md` at the repo root has the full layer diagram, the
//! engine-thread ownership model, the request lifecycle, and the chunked
//! prefill step loop; `docs/fpt-format.md` and `docs/protocol.md` specify
//! the table file and the TCP wire protocol.

pub mod config;
pub mod coordinator;
pub mod costmodel;
pub mod error;
pub mod faults;
pub mod kvcache;
pub mod manifest;
pub mod metrics;
pub mod overload;
pub mod precompute;
pub mod prefixcache;
pub mod runtime;
pub mod scheduler;
pub mod server;
pub mod simtraffic;
pub mod specdec;
pub mod tokenizer;
pub mod trace;
pub mod util;
pub mod weights;

pub use error::{Error, Result};
