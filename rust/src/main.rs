//! `firstlayer` CLI: serve / generate / precompute / paper-tables /
//! sweep / selfcheck.
//!
//! The offline build has no clap; flags are parsed by a small in-tree
//! helper (`--key value` or `--flag`).

use std::collections::HashMap;

use firstlayer::config::{zoo_get, ServingConfig};
use firstlayer::coordinator::sampling::SamplingParams;
use firstlayer::coordinator::{Coordinator, Request};
use firstlayer::costmodel;
use firstlayer::manifest::Manifest;
use firstlayer::precompute::validate_table;
use firstlayer::runtime::{ModelEngine, Runtime, StepPath};
use firstlayer::server::Server;
use firstlayer::util::fmt;
use firstlayer::Result;

const USAGE: &str = "\
firstlayer — serving framework with first-layer precompute
  (reproduction of 'Transformer tricks: Precomputing the first layer', 2024)

USAGE: firstlayer <command> [flags]

COMMANDS:
  serve         run the TCP server
                  --addr 127.0.0.1:7411 --model tiny-serial
                  --path precompute|baseline --artifacts artifacts
                  --chunk-tokens N|auto (chunked prefill; 0 = monolithic)
                  --token-budget N (per-step decode+prefill token budget)
                  --max-waiting N (admission backpressure; 0 = unbounded)
                  --max-conversations N (chat.open cap; 0 = unbounded)
                  --prefix-cache-blocks N (0 = per-model zoo default)
                  --no-prefix-cache (disable cross-request KV reuse)
                  --no-device-kv (host-path caches: upload/readback per step)
                  --span-tokens N|auto (largest span tile; 0 = largest compiled;
                    auto with --spec caps the tile at draft+1 so verify
                    spans never pad)
                  --no-span-exec (token-by-token spans: one dispatch per token)
                  --no-span-batch (serial per-sequence spans: no [B, T] groups)
                  --spec (server-side speculative decoding: n-gram
                    self-drafts verified through scored span executions)
                  --spec-draft N (max drafted tokens per verify; default 16,
                    always clamped to span tile - 1)
                  --trace (record request lifecycles; export via trace.dump)
                  --trace-ring N (completed requests the tracer retains)
                  --fault-spec SPEC (deterministic fault plan, e.g.
                    exec:transient:after=6:every=5;sync:fatal:after=40)
                  --retry-max N --retry-backoff-us N (transient-error
                    retries inside the step; backoff doubles per attempt)
                  --health-cooldown N (steps before a demoted path is
                    re-probed; 0 = demote forever)
                  --conversation-ttl MS (expire idle chats; 0 = never)
                  --stream-queue-events N (per-stream writer bound before
                    a slow reader's sequence is paused)
                  --fair-share (per-tenant DRR fair share over the step
                    budget, plus a per-tenant KV-block share bound)
                  --fair-quantum N (DRR token credit per tenant per step;
                    0 = auto from the chunk size)
                  --fair-burst N (quanta of unused credit a tenant banks)
                  --overload-ladder (staged load shedding: throttle ->
                    shed batch -> shed interactive, hysteresis both ways)
                  --overload-queue-p95-ms N (queue-wait trip threshold)
                  --overload-free-floor N (free KV-block trip floor;
                    0 = pool/16)
                  --overload-trip N --overload-clear N (consecutive
                    hot/calm steps before moving one rung down/up)
                  --retry-after-ms N (back-off hint on shed rejections)
  generate      one-shot generation from the CLI
                  --prompt \"text\" --max-new 32 --model tiny-serial
                  --path precompute|baseline --temperature 0 --top-k 0
                  --top-p 1.0 --stop \"sequence\" (finish on a match)
  precompute    rebuild the table via the PJRT artifact and verify/persist
                  --model tiny-serial [--out path.fpt]
  paper-tables  print the paper's §3 tables from the cost model
  sweep         analytical batch sweep for one model
                  --model mistral-7b --batches 1,16,256,1024
  selfcheck     verify artifacts: manifest, weights, table CRC, engine smoke
                  [--model tiny-serial]
  trace-smoke   run a simtraffic burst with tracing on and dump the Chrome
                trace-event JSON (load in Perfetto / chrome://tracing)
                  --out trace.json [--model tiny-serial] [--requests N]
  chaos         fault-injection gate: run a seeded burst fault-free (the
                oracle), re-run it with the fault plane armed, and assert
                every request reaches a terminal event, surviving greedy
                streams match the oracle byte-for-byte, no KV block or
                prefix lease leaks, and demoted paths re-promote after the
                cooldown; finishes with a mass-cancel storm
                  [--model tiny-serial] [--requests N] [--seed N]
                  [--fault-spec SPEC] [--health-cooldown N]
  spec-smoke    speculative-decoding gate: run a repetitive greedy burst
                with speculation OFF (the oracle), re-run it with --spec
                on, and assert every stream is byte-identical, verifies
                actually ran, and the mean emitted tokens per verify
                execution clears the floor (speculation must pay for
                itself, not just not break anything)
                  [--model tiny-serial] [--requests N] [--seed N]
                  [--min-accept X (floor, default 1.5)] [--spec-draft N]
  overload-smoke  overload gate: a noisy-neighbor burst with fair share
                on (every bystander tenant keeps a goodput floor and a
                bounded interactive TTFT), then arrival storms against
                the armed shed ladder (admission sheds by class, nothing
                already in flight is dropped), then a calm stretch that
                must walk the ladder back to rung 0
                  [--model tiny-serial] [--seed N] [--max-ttft-ms N]
";

fn parse_flags(args: &[String]) -> HashMap<String, String> {
    let mut out = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        if let Some(key) = args[i].strip_prefix("--") {
            if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                out.insert(key.to_string(), args[i + 1].clone());
                i += 2;
            } else {
                out.insert(key.to_string(), "true".to_string());
                i += 1;
            }
        } else {
            i += 1;
        }
    }
    out
}

fn serving_config(flags: &HashMap<String, String>) -> ServingConfig {
    let mut cfg = ServingConfig::default();
    if let Some(m) = flags.get("model") {
        cfg.model = m.clone();
    }
    if let Some(a) = flags.get("artifacts") {
        cfg.artifacts_dir = a.clone();
    }
    if let Some(p) = flags.get("path") {
        cfg.use_precompute = p != "baseline";
    }
    if let Some(b) = flags.get("max-batch") {
        cfg.max_batch = b.parse().unwrap_or(cfg.max_batch);
    }
    if let Some(k) = flags.get("kv-blocks") {
        cfg.kv_blocks = k.parse().unwrap_or(cfg.kv_blocks);
    }
    if let Some(c) = flags.get("chunk-tokens") {
        cfg.prefill_chunk_tokens = if c == "auto" {
            match zoo_get(&cfg.model) {
                Some(m) => firstlayer::config::default_prefill_chunk(&m),
                None => {
                    eprintln!(
                        "[firstlayer] --chunk-tokens auto: model {} not in the \
                         zoo; chunking stays OFF (pass an explicit size)",
                        cfg.model
                    );
                    0
                }
            }
        } else {
            c.parse().unwrap_or(cfg.prefill_chunk_tokens)
        };
    }
    if let Some(t) = flags.get("token-budget") {
        cfg.step_token_budget = t.parse().unwrap_or(cfg.step_token_budget);
    }
    if let Some(w) = flags.get("max-waiting") {
        cfg.max_waiting = w.parse().unwrap_or(cfg.max_waiting);
    }
    if let Some(m) = flags.get("max-conversations") {
        cfg.max_conversations = m.parse().unwrap_or(cfg.max_conversations);
    }
    if let Some(p) = flags.get("prefix-cache-blocks") {
        cfg.prefix_cache_blocks = p.parse().unwrap_or(cfg.prefix_cache_blocks);
    }
    if flags.contains_key("no-prefix-cache") {
        cfg.enable_prefix_cache = false;
    }
    if flags.contains_key("no-device-kv") {
        cfg.enable_device_kv = false;
    }
    if flags.contains_key("spec") {
        cfg.enable_spec_decode = true;
    }
    if let Some(d) = flags.get("spec-draft") {
        cfg.spec_draft_max = d.parse().unwrap_or(cfg.spec_draft_max);
    }
    if let Some(t) = flags.get("span-tokens") {
        cfg.span_bucket_tokens = if t == "auto" {
            let zoo = match zoo_get(&cfg.model) {
                Some(m) => firstlayer::config::default_span_bucket(&m),
                None => {
                    eprintln!(
                        "[firstlayer] --span-tokens auto: model {} not in the \
                         zoo; using the largest compiled bucket",
                        cfg.model
                    );
                    0
                }
            };
            // With speculation on, cap the tile at draft + 1: the engine
            // picks the largest compiled bucket <= the cap, so a full
            // verify span (re-fed token + draft) fills exactly one tile
            // and spec chunks never pad.
            if cfg.enable_spec_decode && cfg.spec_draft_max > 0 {
                let cap = cfg.spec_draft_max + 1;
                if zoo == 0 {
                    cap
                } else {
                    zoo.min(cap)
                }
            } else {
                zoo
            }
        } else {
            t.parse().unwrap_or(cfg.span_bucket_tokens)
        };
    }
    if flags.contains_key("no-span-exec") {
        cfg.enable_span_exec = false;
    }
    if flags.contains_key("no-span-batch") {
        cfg.enable_span_batch = false;
    }
    if flags.contains_key("trace") {
        cfg.enable_trace = true;
    }
    if let Some(r) = flags.get("trace-ring") {
        cfg.trace_ring = r.parse().unwrap_or(cfg.trace_ring);
    }
    if let Some(f) = flags.get("fault-spec") {
        cfg.fault_spec = f.clone();
    }
    if let Some(r) = flags.get("retry-max") {
        cfg.retry_max = r.parse().unwrap_or(cfg.retry_max);
    }
    if let Some(b) = flags.get("retry-backoff-us") {
        cfg.retry_backoff_us = b.parse().unwrap_or(cfg.retry_backoff_us);
    }
    if let Some(c) = flags.get("health-cooldown") {
        cfg.health_cooldown_steps = c.parse().unwrap_or(cfg.health_cooldown_steps);
    }
    if let Some(t) = flags.get("conversation-ttl") {
        cfg.conversation_ttl_ms = t.parse().unwrap_or(cfg.conversation_ttl_ms);
    }
    if let Some(q) = flags.get("stream-queue-events") {
        cfg.stream_queue_events = q.parse().unwrap_or(cfg.stream_queue_events);
    }
    if flags.contains_key("fair-share") {
        cfg.enable_fair_share = true;
    }
    if let Some(q) = flags.get("fair-quantum") {
        cfg.fair_quantum_tokens = q.parse().unwrap_or(cfg.fair_quantum_tokens);
    }
    if let Some(b) = flags.get("fair-burst") {
        cfg.fair_burst_quanta = b.parse().unwrap_or(cfg.fair_burst_quanta);
    }
    if flags.contains_key("overload-ladder") {
        cfg.enable_overload_ladder = true;
    }
    if let Some(p) = flags.get("overload-queue-p95-ms") {
        cfg.overload_queue_p95_ms = p.parse().unwrap_or(cfg.overload_queue_p95_ms);
    }
    if let Some(f) = flags.get("overload-free-floor") {
        cfg.overload_free_block_floor = f.parse().unwrap_or(cfg.overload_free_block_floor);
    }
    if let Some(t) = flags.get("overload-trip") {
        cfg.overload_trip_steps = t.parse().unwrap_or(cfg.overload_trip_steps);
    }
    if let Some(c) = flags.get("overload-clear") {
        cfg.overload_clear_steps = c.parse().unwrap_or(cfg.overload_clear_steps);
    }
    if let Some(r) = flags.get("retry-after-ms") {
        cfg.shed_retry_after_ms = r.parse().unwrap_or(cfg.shed_retry_after_ms);
    }
    cfg
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = args.first().cloned().unwrap_or_default();
    let flags = parse_flags(&args[1.min(args.len())..]);
    let r = match cmd.as_str() {
        "serve" => cmd_serve(&flags),
        "generate" => cmd_generate(&flags),
        "precompute" => cmd_precompute(&flags),
        "paper-tables" => cmd_paper_tables(),
        "sweep" => cmd_sweep(&flags),
        "selfcheck" => cmd_selfcheck(&flags),
        "trace-smoke" => cmd_trace_smoke(&flags),
        "chaos" => cmd_chaos(&flags),
        "spec-smoke" => cmd_spec_smoke(&flags),
        "overload-smoke" => cmd_overload_smoke(&flags),
        _ => {
            eprint!("{USAGE}");
            std::process::exit(2);
        }
    };
    if let Err(e) = r {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn cmd_serve(flags: &HashMap<String, String>) -> Result<()> {
    let cfg = serving_config(flags);
    let addr = flags
        .get("addr")
        .cloned()
        .unwrap_or_else(|| "127.0.0.1:7411".to_string());
    eprintln!("[firstlayer] model={} starting…", cfg.model);
    let queue = cfg.stream_queue_events;
    Server::new(addr).with_stream_queue(queue).run(move || {
        let c = Coordinator::from_config(&cfg)?;
        eprintln!(
            "[firstlayer] path={} (warming up artifacts…)",
            c.path().label()
        );
        c.engine().warmup(c.path())?;
        Ok(c)
    })
}

fn cmd_generate(flags: &HashMap<String, String>) -> Result<()> {
    let cfg = serving_config(flags);
    let prompt = flags
        .get("prompt")
        .cloned()
        .unwrap_or_else(|| "the quick brown fox".to_string());
    let max_new: usize = flags
        .get("max-new")
        .and_then(|v| v.parse().ok())
        .unwrap_or(24);
    let params = SamplingParams {
        temperature: flags
            .get("temperature")
            .and_then(|v| v.parse().ok())
            .unwrap_or(0.0),
        top_k: flags.get("top-k").and_then(|v| v.parse().ok()).unwrap_or(0),
        top_p: flags
            .get("top-p")
            .and_then(|v| v.parse().ok())
            .unwrap_or(1.0),
        stop: flags.get("stop").cloned().into_iter().collect(),
    };
    let mut c = Coordinator::from_config(&cfg)?;
    let id = c.submit(Request::from_text(prompt.clone(), max_new).with_params(params))?;
    c.run_to_completion(10_000)?;
    let toks = c.generated(id).unwrap_or(&[]).to_vec();
    println!("prompt : {prompt}");
    println!("output : {}", c.tokenizer.decode(&toks));
    println!("tokens : {toks:?}");
    println!("path   : {}", c.path().label());
    println!("--- metrics ---\n{}", c.metrics.report());
    let t = c.engine().traffic.snapshot();
    println!(
        "l1 reads: baseline={} precompute={}",
        fmt::commas(t.l1_reads_baseline),
        fmt::commas(t.l1_reads_precomp)
    );
    Ok(())
}

fn cmd_precompute(flags: &HashMap<String, String>) -> Result<()> {
    let cfg = serving_config(flags);
    let rt = Runtime::cpu()?;
    let manifest = Manifest::load(&cfg.artifacts_dir)?;
    let engine = ModelEngine::load(&rt, &manifest, &cfg.model)?;
    println!(
        "[precompute] rebuilding table for {} via PJRT ({} vocab rows of {} values)…",
        cfg.model,
        engine.config().vocab_size,
        engine.config().precomp_row_width()
    );
    let rebuilt = engine.build_table()?;
    let diff = firstlayer::precompute::max_abs_diff(&rebuilt, engine.table())?;
    if diff < 1e-4 {
        println!("[precompute] OK — rebuilt table matches shipped (max |Δ| = {diff:.2e})");
    } else {
        println!("[precompute] MISMATCH — max |Δ| = {diff:.3e} vs shipped table");
    }
    if let Some(out) = flags.get("out") {
        rebuilt.save(out)?;
        println!("[precompute] wrote {out}");
    }
    Ok(())
}

fn cmd_paper_tables() -> Result<()> {
    firstlayer::costmodel::print_paper_tables();
    Ok(())
}

fn cmd_sweep(flags: &HashMap<String, String>) -> Result<()> {
    let model = flags
        .get("model")
        .cloned()
        .unwrap_or_else(|| "mistral-7b".to_string());
    let cfg = zoo_get(&model)
        .ok_or_else(|| firstlayer::Error::Config(format!("unknown model {model}")))?;
    let batches: Vec<u64> = flags
        .get("batches")
        .map(|b| b.split(',').filter_map(|x| x.parse().ok()).collect())
        .unwrap_or_else(|| costmodel::PAPER_BATCHES.to_vec());
    println!("first-layer read reduction for {model} (analytical):");
    println!(
        "{:>8} {:>20} {:>20} {:>10}",
        "batch", "reads w/o", "reads with", "factor"
    );
    for b in batches {
        println!(
            "{:>8} {:>20} {:>20} {:>10}",
            b,
            fmt::commas(costmodel::reads_without(&cfg, b)),
            fmt::commas(costmodel::reads_with(&cfg, b)),
            fmt::factor(costmodel::reduction_factor(&cfg, b))
        );
    }
    Ok(())
}

fn cmd_selfcheck(flags: &HashMap<String, String>) -> Result<()> {
    let cfg = serving_config(flags);
    let manifest = Manifest::load(&cfg.artifacts_dir)?;
    println!("[selfcheck] manifest: {} models", manifest.models.len());
    let rt = Runtime::cpu()?;
    println!("[selfcheck] PJRT platform: {}", rt.platform());
    let models: Vec<String> = if flags.contains_key("model") {
        vec![cfg.model.clone()]
    } else {
        manifest.models.keys().cloned().collect()
    };
    for name in models {
        let engine = ModelEngine::load(&rt, &manifest, &name)?;
        let entry = engine.entry();
        validate_table(engine.table(), engine.config(), entry.weights_crc)?;
        println!(
            "[selfcheck] {name}: weights {} params, table {} ({} rows x {}), crc ok",
            fmt::human_count(engine.weights().total_params() as u64),
            fmt::bytes(engine.table().data_bytes() as u64),
            engine.table().vocab(),
            engine.table().row_width(),
        );
        // Engine smoke: one decode step on both paths, argmax must agree.
        let mc = engine.config().clone();
        let caches = firstlayer::runtime::CacheBatch::zeros(
            mc.n_layers,
            engine.decode_bucket(1, StepPath::Baseline)?,
            mc.max_seq,
            mc.n_kv_heads,
            mc.head_dim(),
        );
        let base = engine.decode(StepPath::Baseline, &[3], &[0], &caches)?;
        if mc.rope {
            let pre = engine.decode(StepPath::Precompute, &[3], &[0], &caches)?;
            let am_b = firstlayer::coordinator::sampling::argmax(&base.logits);
            let am_p = firstlayer::coordinator::sampling::argmax(&pre.logits);
            if am_b != am_p {
                return Err(firstlayer::Error::Engine(format!(
                    "{name}: baseline/precompute argmax mismatch ({am_b} vs {am_p})"
                )));
            }
            println!("[selfcheck] {name}: baseline ≡ precompute (argmax {am_b})");
        }
    }
    println!("[selfcheck] all OK");
    Ok(())
}

/// Drive a simtraffic mixed workload through the coordinator with tracing
/// on and write the Chrome trace-event dump — the one-command way to get
/// a Perfetto-loadable timeline out of the stack (and what
/// `scripts/trace_gate.sh` validates in CI).
fn cmd_trace_smoke(flags: &HashMap<String, String>) -> Result<()> {
    let mut cfg = serving_config(flags);
    cfg.enable_trace = true;
    if cfg.prefill_chunk_tokens == 0 {
        cfg.prefill_chunk_tokens = 16;
    }
    let out = flags
        .get("out")
        .cloned()
        .unwrap_or_else(|| "trace.json".to_string());
    let n_short: usize = flags
        .get("requests")
        .and_then(|v| v.parse().ok())
        .unwrap_or(10);
    let mut c = Coordinator::from_config(&cfg)?;
    let vocab = c.engine().config().vocab_size as u32;
    let reqs = firstlayer::simtraffic::mixed_workload(n_short, 24, 2, 48, 8, vocab, 0x7AC3);
    let n_reqs = reqs.len();
    for r in reqs {
        c.submit(r)?;
    }
    c.run_to_completion(10_000)?;
    let tracer = c.tracer();
    let dump = tracer.dump_chrome();
    std::fs::write(&out, firstlayer::util::json::to_string(&dump))?;
    println!(
        "[trace-smoke] {n_reqs} requests traced ({} completed in ring, {} engine steps); \
         wrote {out}",
        tracer.completed_count(),
        tracer.steps_count(),
    );
    println!("--- metrics ---\n{}", c.metrics.report());
    Ok(())
}

/// The chaos gate (`scripts/chaos_gate.sh`): prove the serving loop's
/// fault containment end to end, against a live engine.
///
/// Phase 1 runs a seeded greedy burst fault-free and records each tag's
/// token stream — the oracle.  Phase 2 replays the identical burst with
/// the deterministic fault plane armed and then asserts the robustness
/// contract: every request reaches a terminal event; requests that only
/// retried transients reproduce the oracle stream exactly; terminal
/// failures are `error`-reasoned, bounded in number by the plan, and
/// leak nothing (free blocks + prefix leases add back up to the pool,
/// and the kvcache invariant audit passes).  Phase 3 drives a
/// mass-cancel storm through the SAME coordinator, which both exercises
/// cancellation under a degraded ladder and generates the steps the
/// cooldown needs — the gate then requires every demoted path to have
/// re-promoted.  Any violation is an `Err`, so the script fails on exit
/// code alone.
fn cmd_chaos(flags: &HashMap<String, String>) -> Result<()> {
    use firstlayer::coordinator::FinishReason;
    let mut cfg = serving_config(flags);
    if cfg.prefill_chunk_tokens == 0 {
        cfg.prefill_chunk_tokens = 16;
    }
    if !flags.contains_key("health-cooldown") {
        // Short enough that phase 3's steps cover the re-probe.
        cfg.health_cooldown_steps = 8;
    }
    if cfg.fault_spec.is_empty() {
        // Bounded bursts at three boundary classes: transient exec and
        // readback noise the in-step retries must absorb, plus one
        // fatal sync hit that forces the recompute-from-host path and a
        // device-KV demotion.  Every rule is count-bounded, so phase 3
        // runs fault-free and the recovery probes succeed.
        cfg.fault_spec = "exec:transient:after=12:every=9:count=3;\
                          readback:transient:after=8:every=11:count=2;\
                          sync:fatal:after=2:count=1"
            .to_string();
    }
    let n: usize = flags
        .get("requests")
        .and_then(|v| v.parse().ok())
        .unwrap_or(12);
    let seed: u64 = flags
        .get("seed")
        .and_then(|v| v.parse().ok())
        .unwrap_or(0xFA17);

    // Phase 1: the fault-free oracle.
    let mut oracle_cfg = cfg.clone();
    oracle_cfg.fault_spec = String::new();
    let mut c = Coordinator::from_config(&oracle_cfg)?;
    let vocab = c.engine().config().vocab_size as u32;
    let burst = firstlayer::simtraffic::fault_burst_workload(n, 16, 8, vocab, seed);
    let mut oracle: HashMap<String, Vec<u32>> = HashMap::new();
    let mut ids = Vec::new();
    for r in burst.clone() {
        let tag = r.tag.clone().unwrap_or_default();
        ids.push((tag, c.submit(r)?));
    }
    c.run_to_completion(10_000)?;
    for (tag, id) in &ids {
        match c.finished(*id) {
            Some(FinishReason::Error) | None => {
                return Err(firstlayer::Error::Engine(format!(
                    "[chaos] oracle run must be clean, but `{tag}` did not finish"
                )))
            }
            Some(_) => {
                oracle.insert(tag.clone(), c.generated(*id).unwrap_or(&[]).to_vec());
            }
        }
    }
    println!("[chaos] oracle: {n} requests finished clean");

    // Phase 2: identical burst, fault plane armed.
    let mut c = Coordinator::from_config(&cfg)?;
    println!("[chaos] armed: {}", cfg.fault_spec);
    let mut ids = Vec::new();
    for r in burst {
        let tag = r.tag.clone().unwrap_or_default();
        ids.push((tag, c.submit(r)?));
    }
    c.run_to_completion(10_000)?;
    let mut errored = 0usize;
    for (tag, id) in &ids {
        match c.finished(*id) {
            None => {
                return Err(firstlayer::Error::Engine(format!(
                    "[chaos] `{tag}` reached no terminal event under faults"
                )))
            }
            Some(FinishReason::Error) => errored += 1,
            Some(_) => {
                let got = c.generated(*id).unwrap_or(&[]);
                let want = oracle.get(tag).map_or(&[][..], |v| v);
                if got != want {
                    return Err(firstlayer::Error::Engine(format!(
                        "[chaos] survivor `{tag}` diverged from the oracle \
                         ({got:?} vs {want:?}) — a retry or a peer failure \
                         perturbed its stream"
                    )));
                }
            }
        }
    }
    use std::sync::atomic::Ordering::Relaxed;
    let injected = c.metrics.fault_injected.load(Relaxed);
    let retries = c.metrics.fault_retries.load(Relaxed);
    if injected == 0 {
        return Err(firstlayer::Error::Engine(
            "[chaos] the plan never fired — the gate proved nothing; \
             lower after=/raise count= so faults land inside the burst"
                .into(),
        ));
    }
    if retries > injected.saturating_mul(cfg.retry_max as u64) {
        return Err(firstlayer::Error::Engine(format!(
            "[chaos] unbounded retry: {retries} retries for {injected} injected faults"
        )));
    }
    chaos_leak_check(&c, &cfg, "post-burst")?;
    println!(
        "[chaos] faulted: {errored}/{n} errored terminally, {} survivors \
         oracle-identical ({injected} faults injected, {retries} retried)",
        n - errored
    );

    // Phase 3: mass-cancel storm on the same (possibly demoted) engine;
    // its steps also drive the health cooldown to the re-promotion.
    let storm = firstlayer::simtraffic::fault_burst_workload(n, 16, 24, vocab, seed ^ 0x5707);
    let mut ids = Vec::new();
    for r in storm {
        ids.push(c.submit(r)?);
    }
    for _ in 0..3 {
        if c.busy() {
            c.step()?;
        }
    }
    for id in ids.iter().step_by(2) {
        let _ = c.cancel(*id);
    }
    c.run_to_completion(10_000)?;
    for id in &ids {
        if c.finished(*id).is_none() {
            return Err(firstlayer::Error::Engine(format!(
                "[chaos] storm request {id} reached no terminal event"
            )));
        }
    }
    chaos_leak_check(&c, &cfg, "post-storm")?;
    let health = c.engine().health();
    for p in firstlayer::faults::PathId::ALL {
        if health.demotions(p) > health.promotions(p) {
            return Err(firstlayer::Error::Engine(format!(
                "[chaos] path {} was demoted and never re-promoted \
                 (cooldown {} steps)",
                p.label(),
                health.cooldown()
            )));
        }
    }
    println!(
        "[chaos] storm: {} requests terminal after mass-cancel; \
         demotions={} promotions={}",
        ids.len(),
        health.total_demotions(),
        health.total_promotions()
    );
    println!("[chaos] OK");
    Ok(())
}

/// The speculative-decoding gate (`scripts/spec_gate.sh`): prove
/// server-side speculation is both *correct* and *worth it*, against a
/// live engine.
///
/// Phase 1 runs a repetitive greedy burst (`simtraffic::spec_workload`)
/// with
/// speculation OFF and records each tag's token stream — the oracle.
/// Phase 2 replays the identical burst with `--spec` on and asserts:
/// every stream is byte-identical to the oracle (the verify-accept-
/// rollback loop must be invisible in output space); verifies actually
/// executed (a gate that silently never speculated proves nothing); and
/// the mean emitted tokens per verify execution clears `--min-accept`
/// (default 1.5) — each scored span execution must replace more than
/// 1.5 plain decode steps on this drafter-friendly traffic, or the
/// machinery is overhead.  Any violation is an `Err`, so the script
/// fails on exit code alone.
fn cmd_spec_smoke(flags: &HashMap<String, String>) -> Result<()> {
    use firstlayer::coordinator::FinishReason;
    use std::sync::atomic::Ordering::Relaxed;
    let mut cfg = serving_config(flags);
    cfg.enable_spec_decode = true;
    if cfg.prefill_chunk_tokens == 0 {
        cfg.prefill_chunk_tokens = 16;
    }
    let n: usize = flags
        .get("requests")
        .and_then(|v| v.parse().ok())
        .unwrap_or(8);
    let seed: u64 = flags
        .get("seed")
        .and_then(|v| v.parse().ok())
        .unwrap_or(0x5bec);
    let min_accept: f64 = flags
        .get("min-accept")
        .and_then(|v| v.parse().ok())
        .unwrap_or(1.5);

    // Phase 1: speculation off — the oracle streams.
    let mut oracle_cfg = cfg.clone();
    oracle_cfg.enable_spec_decode = false;
    let mut c = Coordinator::from_config(&oracle_cfg)?;
    let vocab = c.engine().config().vocab_size as u32;
    let burst = firstlayer::simtraffic::spec_workload(n, 3, 24, 64, vocab, seed);
    let mut oracle: HashMap<String, Vec<u32>> = HashMap::new();
    let mut ids = Vec::new();
    for r in burst.clone() {
        let tag = r.tag.clone().unwrap_or_default();
        ids.push((tag, c.submit(r)?));
    }
    c.run_to_completion(10_000)?;
    for (tag, id) in &ids {
        match c.finished(*id) {
            Some(FinishReason::Error) | None => {
                return Err(firstlayer::Error::Engine(format!(
                    "[spec-smoke] oracle run must be clean, but `{tag}` did not finish"
                )))
            }
            Some(_) => {
                oracle.insert(tag.clone(), c.generated(*id).unwrap_or(&[]).to_vec());
            }
        }
    }
    if c.metrics.spec_executions.load(Relaxed) != 0 {
        return Err(firstlayer::Error::Engine(
            "[spec-smoke] oracle run executed verifies with the knob off".into(),
        ));
    }
    println!("[spec-smoke] oracle: {n} requests finished clean, spec off");

    // Phase 2: identical burst, speculation on.
    let mut c = Coordinator::from_config(&cfg)?;
    let mut ids = Vec::new();
    for r in burst {
        let tag = r.tag.clone().unwrap_or_default();
        ids.push((tag, c.submit(r)?));
    }
    c.run_to_completion(10_000)?;
    for (tag, id) in &ids {
        match c.finished(*id) {
            Some(FinishReason::Error) | None => {
                return Err(firstlayer::Error::Engine(format!(
                    "[spec-smoke] `{tag}` did not finish clean with spec on"
                )))
            }
            Some(_) => {
                let got = c.generated(*id).unwrap_or(&[]);
                let want = oracle.get(tag).map_or(&[][..], |v| v);
                if got != want {
                    return Err(firstlayer::Error::Engine(format!(
                        "[spec-smoke] `{tag}` diverged from the oracle \
                         ({got:?} vs {want:?}) — accept/rollback changed \
                         the output stream"
                    )));
                }
            }
        }
    }
    let execs = c.metrics.spec_executions.load(Relaxed);
    let drafted = c.metrics.spec_drafted_tokens.load(Relaxed);
    let accepted = c.metrics.spec_accepted_tokens.load(Relaxed);
    let rollbacks = c.metrics.spec_rollbacks.load(Relaxed);
    if execs == 0 {
        return Err(firstlayer::Error::Engine(
            "[spec-smoke] no verify ever executed — the gate proved nothing; \
             is the span bucket >= 2 and the workload repetitive?"
                .into(),
        ));
    }
    let per_exec = c.metrics.spec_accept_len.mean();
    for (tag, id) in &ids {
        if let Some(s) = c.spec_stats(*id) {
            println!(
                "[spec-smoke] {tag}: {} proposals, {} drafted, {} accepted \
                 ({:.0}% accept), {} rollbacks",
                s.proposals,
                s.drafted,
                s.accepted,
                s.accept_rate() * 100.0,
                s.rollbacks
            );
        }
    }
    println!(
        "[spec-smoke] {execs} verifies: {drafted} drafted, {accepted} accepted, \
         {rollbacks} rollbacks; {per_exec:.2} emitted tokens/execution"
    );
    println!("--- metrics ---\n{}", c.metrics.report());
    if per_exec <= min_accept {
        return Err(firstlayer::Error::Engine(format!(
            "[spec-smoke] {per_exec:.2} emitted tokens per verify execution \
             <= floor {min_accept:.2} — speculation is not paying for itself \
             on drafter-friendly traffic"
        )));
    }
    println!("[spec-smoke] OK ({per_exec:.2} > {min_accept:.2})");
    Ok(())
}

/// The overload gate (`scripts/overload_gate.sh`): prove the front door
/// degrades gracefully instead of collapsing, against a live engine.
///
/// Phase 1 runs the noisy-neighbor shape (`simtraffic::hog_workload`)
/// with fair-share scheduling ON and asserts the bystander contract:
/// every bystander request reaches a clean terminal event, no bystander
/// tenant falls below the peer-group goodput floor
/// (`costmodel::fair_share` with slack), and interactive TTFT p99 stays
/// under `--max-ttft-ms` — the hog's queue depth must not buy it the
/// device.  Phase 2 drives arrival storms (`overload_wave_workload`)
/// into a ladder-armed coordinator with a tight step budget and asserts
/// staged shedding: the ladder actually trips, a `Batch` probe sheds at
/// rung 2 with a `retry_after_ms` hint while in-flight work is
/// untouched, and EVERY admitted request still reaches a clean terminal
/// event (shedding is an admission decision, never an eviction).
/// Phase 3 steps the drained engine through calm and requires the
/// ladder to retrace to rung 0 with demotions == promotions.  Any
/// violation is an `Err`, so the script fails on exit code alone.
fn cmd_overload_smoke(flags: &HashMap<String, String>) -> Result<()> {
    use firstlayer::coordinator::FinishReason;
    use firstlayer::scheduler::Priority;
    use std::sync::atomic::Ordering::Relaxed;
    let mut cfg = serving_config(flags);
    if cfg.prefill_chunk_tokens == 0 {
        cfg.prefill_chunk_tokens = 16;
    }
    let seed: u64 = flags
        .get("seed")
        .and_then(|v| v.parse().ok())
        .unwrap_or(0x0AD5);
    let max_ttft_ms: u64 = flags
        .get("max-ttft-ms")
        .and_then(|v| v.parse().ok())
        .unwrap_or(2_000);

    // Phase 1: noisy neighbor vs fair share.
    let mut fair_cfg = cfg.clone();
    fair_cfg.enable_fair_share = true;
    if fair_cfg.step_token_budget == 0 {
        fair_cfg.step_token_budget = 32;
    }
    let mut c = Coordinator::from_config(&fair_cfg)?;
    let vocab = c.engine().config().vocab_size as u32;
    let (n_hog, n_small, per_tenant, max_new) = (12usize, 3usize, 4usize, 8usize);
    let burst = firstlayer::simtraffic::hog_workload(
        n_hog, n_small, per_tenant, 48, 8, max_new, vocab, seed,
    );
    let mut ids = Vec::new();
    for r in burst {
        let (tenant, tag) = (r.tenant, r.tag.clone().unwrap_or_default());
        ids.push((tenant, tag, c.submit(r)?));
    }
    c.run_to_completion(20_000)?;
    let mut emitted: HashMap<u64, u64> = HashMap::new();
    for (tenant, tag, id) in &ids {
        match c.finished(*id) {
            Some(FinishReason::Error) | None => {
                return Err(firstlayer::Error::Engine(format!(
                    "[overload-smoke] `{tag}` (tenant {tenant}) did not \
                     finish clean under the hog"
                )))
            }
            Some(_) => {
                *emitted.entry(*tenant).or_default() +=
                    c.generated(*id).map_or(0, |g| g.len() as u64);
            }
        }
    }
    // Goodput floor among the bystander peer group: nobody may fall
    // below a quarter of the peers' fair share (slack absorbs early-EOS
    // length variance; outright starvation is zero and always fails).
    let bystander_total: u64 = (0..n_small).map(|t| emitted[&(2 + t as u64)]).sum();
    let floor = costmodel::fair_share(bystander_total, n_small as u64) / 4;
    for t in 0..n_small {
        let tenant = 2 + t as u64;
        if emitted[&tenant] < floor.max(1) {
            return Err(firstlayer::Error::Engine(format!(
                "[overload-smoke] tenant {tenant} emitted {} tokens, \
                 below the goodput floor {floor} — the hog starved it",
                emitted[&tenant]
            )));
        }
    }
    let ttft_p99_ms = c.metrics.ttft.quantile(0.99).as_millis() as u64;
    if ttft_p99_ms > max_ttft_ms {
        return Err(firstlayer::Error::Engine(format!(
            "[overload-smoke] TTFT p99 {ttft_p99_ms}ms exceeds the \
             {max_ttft_ms}ms bound under the hog"
        )));
    }
    println!(
        "[overload-smoke] fair share: hog emitted {}, bystanders {:?} \
         (floor {floor}), ttft_p99 {ttft_p99_ms}ms",
        emitted.get(&1).copied().unwrap_or(0),
        (0..n_small)
            .map(|t| emitted[&(2 + t as u64)])
            .collect::<Vec<_>>(),
    );

    // Phase 2: 2x arrival storms vs the armed ladder.  A tight step
    // budget makes every storm step saturate, which is the hot signal
    // the trip window counts.
    let mut storm_cfg = cfg.clone();
    storm_cfg.enable_overload_ladder = true;
    storm_cfg.overload_trip_steps = 2;
    storm_cfg.overload_clear_steps = 3;
    if !flags.contains_key("token-budget") {
        storm_cfg.step_token_budget = 16;
    }
    let mut c = Coordinator::from_config(&storm_cfg)?;
    let waves =
        firstlayer::simtraffic::overload_wave_workload(2, 12, 4, 8, 4, vocab, seed ^ 0x11);
    let (w1, w2) = waves.split_at(waves.len() / 2);
    let mut admitted = Vec::new();
    let mut shed_seen = 0u64;
    let submit = |c: &mut Coordinator,
                      r: Request,
                      admitted: &mut Vec<u64>,
                      shed_seen: &mut u64|
     -> Result<()> {
        match c.submit(r) {
            Ok(id) => admitted.push(id),
            Err(firstlayer::Error::Shed { .. }) => *shed_seen += 1,
            Err(e) => return Err(e),
        }
        Ok(())
    };
    for r in w1.to_vec() {
        submit(&mut c, r, &mut admitted, &mut shed_seen)?;
    }
    // Step until the ladder reaches the batch-shedding rung (the storm
    // saturates the budget every step, so this is deterministic).
    for _ in 0..200 {
        if c.shed_level() >= 2 || !c.busy() {
            break;
        }
        c.step()?;
    }
    if c.shed_level() < 2 {
        return Err(firstlayer::Error::Engine(
            "[overload-smoke] the storm never tripped the ladder to the \
             batch-shedding rung — the gate proved nothing; is the step \
             budget tight enough?"
                .into(),
        ));
    }
    // Class-aware probe: Batch must shed at rung >= 2, with the
    // retry hint attached.
    match c.submit(
        Request::from_tokens(vec![1, 2, 3], 4).with_priority(Priority::Batch),
    ) {
        Err(firstlayer::Error::Shed { retry_after_ms, .. }) => {
            if retry_after_ms == 0 {
                return Err(firstlayer::Error::Engine(
                    "[overload-smoke] shed rejection carried no retry hint".into(),
                ));
            }
            shed_seen += 1;
        }
        Ok(_) => {
            return Err(firstlayer::Error::Engine(
                "[overload-smoke] a Batch request was admitted at the \
                 batch-shedding rung"
                    .into(),
            ))
        }
        Err(e) => return Err(e),
    }
    // Second wave lands on the degraded ladder: its Batch-class calm
    // tail may shed, interactive still admits below rung 3.
    for r in w2.to_vec() {
        submit(&mut c, r, &mut admitted, &mut shed_seen)?;
    }
    let peak_level = c.shed_level();
    c.run_to_completion(20_000)?;
    // No in-flight shed: every ADMITTED request reaches a clean
    // terminal event even though the ladder was shedding around it.
    for id in &admitted {
        match c.finished(*id) {
            Some(FinishReason::Error) | None => {
                return Err(firstlayer::Error::Engine(format!(
                    "[overload-smoke] admitted request {id} was lost \
                     while the ladder shed — shedding must never touch \
                     in-flight work"
                )))
            }
            Some(_) => {}
        }
    }
    let shed_counted = c.metrics.requests_shed.load(Relaxed);
    if shed_counted != shed_seen {
        return Err(firstlayer::Error::Engine(format!(
            "[overload-smoke] requests_shed={shed_counted} but the \
             driver observed {shed_seen} shed rejections"
        )));
    }
    println!(
        "[overload-smoke] storm: {} admitted all terminal, {shed_seen} \
         shed at the door (peak rung {peak_level})",
        admitted.len()
    );

    // Phase 3: calm recovery — idle steps drain the pressure window and
    // must walk the ladder back down to rung 0, one rung per clear
    // window (sliding-window decay bounds this at well under the cap).
    let mut calm_steps = 0u64;
    for _ in 0..600 {
        if c.shed_level() == 0 {
            break;
        }
        c.step()?;
        calm_steps += 1;
    }
    if c.shed_level() != 0 {
        return Err(firstlayer::Error::Engine(format!(
            "[overload-smoke] ladder stuck at rung {} after {calm_steps} \
             calm steps — recovery hysteresis never cleared",
            c.shed_level()
        )));
    }
    let (demotions, promotions) = c.shed_transitions();
    if demotions != promotions {
        return Err(firstlayer::Error::Engine(format!(
            "[overload-smoke] ladder transitions unbalanced after calm: \
             {demotions} down vs {promotions} up"
        )));
    }
    println!(
        "[overload-smoke] recovery: rung 0 after {calm_steps} calm steps \
         ({demotions} demotions, {promotions} promotions)"
    );
    println!("--- metrics ---\n{}", c.metrics.report());
    println!("[overload-smoke] OK");
    Ok(())
}

/// Leak audit shared by the chaos phases: with every request terminal,
/// the pool must be exactly (free blocks) + (prefix-cache leases), and
/// the kvcache's internal refcount/lease audit must pass.
fn chaos_leak_check(
    c: &Coordinator,
    cfg: &ServingConfig,
    when: &str,
) -> Result<()> {
    c.check_kv_invariants()?;
    let free = c.kv_free_blocks();
    let leased = c.prefix_cache_blocks_held();
    if free + leased != cfg.kv_blocks {
        return Err(firstlayer::Error::Engine(format!(
            "[chaos] {when}: block leak — free {free} + prefix leases {leased} \
             != pool {}",
            cfg.kv_blocks
        )));
    }
    Ok(())
}
