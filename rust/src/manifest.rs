//! AOT manifest: the contract between `python/compile/aot.py` and the
//! serving runtime.  `artifacts/manifest.json` lists, per model, the
//! weights file, the precompute table, and every HLO artifact with its
//! input/output signature and weight parameter order.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::config::{Arch, FfnType, ModelConfig, NormType};
use crate::error::{Error, Result};
use crate::util::json::{self, Value};

/// Element type of an artifact IO slot.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

impl DType {
    fn parse(s: &str) -> Result<Self> {
        match s {
            "f32" => Ok(DType::F32),
            "i32" => Ok(DType::I32),
            other => Err(Error::Manifest(format!("unknown dtype `{other}`"))),
        }
    }
    pub fn size_bytes(self) -> usize {
        4
    }
}

/// One named input/output tensor of an artifact.
#[derive(Debug, Clone)]
pub struct IoSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: DType,
}

impl IoSpec {
    pub fn elems(&self) -> usize {
        self.shape.iter().product()
    }
}

/// Artifact kind (drives how the engine calls it).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ArtifactKind {
    Decode,
    Prefill,
    /// Batched span: T tokens of ONE sequence against the existing KV
    /// history in a single execution (`ModelEngine::decode_span` tiling).
    Span,
    PrecomputeBuild,
}

/// One compiled computation (HLO text file + signature).
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub name: String,
    pub kind: ArtifactKind,
    /// Path relative to the artifacts dir.
    pub file: String,
    pub inputs: Vec<IoSpec>,
    pub outputs: Vec<IoSpec>,
    /// Weight tensors appended after the data inputs, in order.  The
    /// pseudo-name `@table` denotes the precompute table buffer.
    pub weight_params: Vec<String>,
    pub batch: Option<usize>,
    pub prompt_len: Option<usize>,
    /// Span-artifact bucket: tokens advanced per execution (kind == Span).
    pub span_tokens: Option<usize>,
    pub max_seq: Option<usize>,
}

impl ArtifactSpec {
    /// Baseline path (embeds tokens in-graph) vs precompute path.
    pub fn is_precompute(&self) -> bool {
        self.name.contains("precomp")
    }
}

/// Everything the manifest knows about one model.
#[derive(Debug, Clone)]
pub struct ModelEntry {
    pub config: ModelConfig,
    pub weights_file: String,
    pub weights_order: Vec<String>,
    pub table_file: String,
    pub weights_crc: u32,
    pub artifacts: Vec<ArtifactSpec>,
}

impl ModelEntry {
    pub fn artifact(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts
            .iter()
            .find(|a| a.name == name)
            .ok_or_else(|| Error::Manifest(format!("no artifact `{name}`")))
    }

    /// Decode artifacts of a path family, sorted by batch size.
    pub fn decode_buckets(&self, precompute: bool) -> Vec<&ArtifactSpec> {
        let prefix = if precompute {
            "decode_precomp_b"
        } else {
            "decode_baseline_b"
        };
        let mut v: Vec<_> = self
            .artifacts
            .iter()
            .filter(|a| a.name.starts_with(prefix))
            .collect();
        v.sort_by_key(|a| a.batch.unwrap_or(0));
        v
    }

    /// Span artifacts of a path family, sorted by their token bucket.
    pub fn span_buckets(&self, precompute: bool) -> Vec<&ArtifactSpec> {
        let prefix = if precompute {
            "span_precomp_t"
        } else {
            "span_baseline_t"
        };
        let mut v: Vec<_> = self
            .artifacts
            .iter()
            .filter(|a| a.name.starts_with(prefix) && a.kind == ArtifactKind::Span)
            .collect();
        v.sort_by_key(|a| a.span_tokens.unwrap_or(0));
        v
    }

    /// Multi-sequence span artifacts (`span_*_b{B}_t{T}`) of a path
    /// family, sorted by (batch, span_tokens).  These carry per-lane
    /// `starts`/`lens` inputs and a `[L, B, S, KH, hd]` cache pair; the
    /// B=1 family from [`ModelEntry::span_buckets`] is deliberately
    /// excluded (its names carry no `_b` segment).
    pub fn span_batch_buckets(&self, precompute: bool) -> Vec<&ArtifactSpec> {
        let prefix = if precompute {
            "span_precomp_b"
        } else {
            "span_baseline_b"
        };
        let mut v: Vec<_> = self
            .artifacts
            .iter()
            .filter(|a| a.name.starts_with(prefix) && a.kind == ArtifactKind::Span)
            .collect();
        v.sort_by_key(|a| (a.batch.unwrap_or(0), a.span_tokens.unwrap_or(0)));
        v
    }

    /// Prefill artifacts of a family, sorted by (batch, prompt_len).
    pub fn prefill_buckets(&self, precompute: bool) -> Vec<&ArtifactSpec> {
        let prefix = if precompute {
            "prefill_precomp_b"
        } else {
            "prefill_baseline_b"
        };
        let mut v: Vec<_> = self
            .artifacts
            .iter()
            .filter(|a| a.name.starts_with(prefix))
            .collect();
        v.sort_by_key(|a| (a.batch.unwrap_or(0), a.prompt_len.unwrap_or(0)));
        v
    }
}

/// The parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub models: BTreeMap<String, ModelEntry>,
}

impl Manifest {
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).map_err(|e| {
            Error::Manifest(format!("{}: {e} (run `make artifacts`)", path.display()))
        })?;
        let root = json::parse(&text)?;
        let version = root.u64_field("version")?;
        if version != 1 {
            return Err(Error::Manifest(format!("unsupported version {version}")));
        }
        let mut models = BTreeMap::new();
        for (name, entry) in root
            .get("models")?
            .as_obj()
            .ok_or_else(|| Error::Manifest("models not an object".into()))?
        {
            models.insert(name.clone(), parse_model(name, entry)?);
        }
        Ok(Manifest { dir, models })
    }

    pub fn model(&self, name: &str) -> Result<&ModelEntry> {
        self.models
            .get(name)
            .ok_or_else(|| Error::Manifest(format!("model `{name}` not in manifest")))
    }

    pub fn path(&self, rel: &str) -> PathBuf {
        self.dir.join(rel)
    }
}

fn parse_model(name: &str, v: &Value) -> Result<ModelEntry> {
    let config = parse_config(v.get("config")?)?;
    if config.name != name {
        return Err(Error::Manifest(format!(
            "model key `{name}` != config name `{}`",
            config.name
        )));
    }
    let weights_order = v
        .get("weights_order")?
        .as_arr()
        .ok_or_else(|| Error::Manifest("weights_order not an array".into()))?
        .iter()
        .map(|s| s.as_str().unwrap_or_default().to_string())
        .collect();
    let mut artifacts = Vec::new();
    for a in v
        .get("artifacts")?
        .as_arr()
        .ok_or_else(|| Error::Manifest("artifacts not an array".into()))?
    {
        artifacts.push(parse_artifact(a)?);
    }
    Ok(ModelEntry {
        config,
        weights_file: v.str_field("weights_file")?.to_string(),
        weights_order,
        table_file: v.str_field("table_file")?.to_string(),
        weights_crc: v.u64_field("weights_crc")? as u32,
        artifacts,
    })
}

fn parse_config(v: &Value) -> Result<ModelConfig> {
    let arch = match v.str_field("arch")? {
        "parallel" => Arch::Parallel,
        "serial" => Arch::Serial,
        other => return Err(Error::Manifest(format!("bad arch `{other}`"))),
    };
    let ffn_type = match v.str_field("ffn_type")? {
        "mlp" => FfnType::Mlp,
        "swiglu" => FfnType::SwiGlu,
        "swiglu_moe" => FfnType::SwiGluMoe,
        other => return Err(Error::Manifest(format!("bad ffn_type `{other}`"))),
    };
    let norm_type = match v.str_field("norm_type")? {
        "rmsnorm" => NormType::RmsNorm,
        "layernorm" => NormType::LayerNorm,
        other => return Err(Error::Manifest(format!("bad norm_type `{other}`"))),
    };
    let cfg = ModelConfig {
        name: v.str_field("name")?.to_string(),
        arch,
        d: v.u64_field("d")? as usize,
        n_layers: v.u64_field("n_layers")? as usize,
        n_heads: v.u64_field("n_heads")? as usize,
        n_kv_heads: v.u64_field("n_kv_heads")? as usize,
        ffn_hidden: v.u64_field("ffn_hidden")? as usize,
        ffn_type,
        n_experts: v.u64_field("n_experts")? as usize,
        moe_top_k: v.u64_field("moe_top_k")? as usize,
        vocab_size: v.u64_field("vocab_size")? as usize,
        max_seq: v.u64_field("max_seq")? as usize,
        norm_type,
        rope_theta: v.get("rope_theta")?.as_f64().unwrap_or(10_000.0),
        norm_eps: v.get("norm_eps")?.as_f64().unwrap_or(1e-5),
        rope: v.get("rope")?.as_bool().unwrap_or(true),
    };
    cfg.validate()?;
    // Cross-check the derived quantities the python side exported.
    if let Some(e) = v.get_opt("e").and_then(|x| x.as_usize()) {
        if e != cfg.e() {
            return Err(Error::Manifest(format!(
                "{}: e mismatch (manifest {e}, derived {})",
                cfg.name,
                cfg.e()
            )));
        }
    }
    if let Some(w) = v.get_opt("precomp_row_width").and_then(|x| x.as_usize()) {
        if w != cfg.precomp_row_width() {
            return Err(Error::Manifest(format!(
                "{}: row width mismatch (manifest {w}, derived {})",
                cfg.name,
                cfg.precomp_row_width()
            )));
        }
    }
    Ok(cfg)
}

fn parse_artifact(v: &Value) -> Result<ArtifactSpec> {
    let kind = match v.str_field("kind")? {
        "decode" => ArtifactKind::Decode,
        "prefill" => ArtifactKind::Prefill,
        "span" => ArtifactKind::Span,
        "precompute_build" => ArtifactKind::PrecomputeBuild,
        other => return Err(Error::Manifest(format!("bad kind `{other}`"))),
    };
    let io = |key: &str| -> Result<Vec<IoSpec>> {
        let mut out = Vec::new();
        for x in v
            .get(key)?
            .as_arr()
            .ok_or_else(|| Error::Manifest(format!("{key} not an array")))?
        {
            out.push(IoSpec {
                name: x.str_field("name")?.to_string(),
                shape: x
                    .get("shape")?
                    .as_arr()
                    .ok_or_else(|| Error::Manifest("shape not an array".into()))?
                    .iter()
                    .map(|d| d.as_usize().unwrap_or(0))
                    .collect(),
                dtype: DType::parse(x.str_field("dtype")?)?,
            });
        }
        Ok(out)
    };
    Ok(ArtifactSpec {
        name: v.str_field("name")?.to_string(),
        kind,
        file: v.str_field("file")?.to_string(),
        inputs: io("inputs")?,
        outputs: io("outputs")?,
        weight_params: v
            .get("weight_params")?
            .as_arr()
            .ok_or_else(|| Error::Manifest("weight_params not an array".into()))?
            .iter()
            .map(|s| s.as_str().unwrap_or_default().to_string())
            .collect(),
        batch: v.get_opt("batch").and_then(|x| x.as_usize()),
        prompt_len: v.get_opt("prompt_len").and_then(|x| x.as_usize()),
        span_tokens: v.get_opt("span_tokens").and_then(|x| x.as_usize()),
        max_seq: v.get_opt("max_seq").and_then(|x| x.as_usize()),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "version": 1,
      "models": {
        "tiny-serial": {
          "config": {"name": "tiny-serial", "arch": "serial", "d": 128,
            "n_layers": 4, "n_heads": 4, "n_kv_heads": 2, "ffn_hidden": 384,
            "ffn_type": "swiglu", "n_experts": 1, "moe_top_k": 1,
            "vocab_size": 512, "max_seq": 128, "norm_type": "rmsnorm",
            "rope_theta": 10000.0, "norm_eps": 1e-05, "rope": true,
            "e": 64, "head_dim": 32, "precomp_row_width": 384},
          "weights_file": "w.fw",
          "weights_order": ["emb", "unemb"],
          "table_file": "t.fpt",
          "weights_crc": 305419896,
          "artifacts": [
            {"name": "decode_baseline_b1", "kind": "decode",
             "file": "tiny-serial/decode_baseline_b1.hlo.txt",
             "inputs": [{"name": "tokens", "shape": [1], "dtype": "i32"}],
             "outputs": [{"name": "logits", "shape": [1, 512], "dtype": "f32"}],
             "weight_params": ["emb", "unemb"], "batch": 1, "max_seq": 128},
            {"name": "decode_precomp_b4", "kind": "decode",
             "file": "tiny-serial/decode_precomp_b4.hlo.txt",
             "inputs": [{"name": "rows", "shape": [4, 384], "dtype": "f32"}],
             "outputs": [{"name": "logits", "shape": [4, 512], "dtype": "f32"}],
             "weight_params": ["unemb"], "batch": 4, "max_seq": 128},
            {"name": "span_precomp_t8", "kind": "span",
             "file": "tiny-serial/span_precomp_t8.hlo.txt",
             "inputs": [{"name": "rows", "shape": [8, 384], "dtype": "f32"}],
             "outputs": [{"name": "logits", "shape": [8, 512], "dtype": "f32"}],
             "weight_params": ["unemb"], "batch": 1, "span_tokens": 8,
             "max_seq": 128},
            {"name": "span_precomp_b4_t8", "kind": "span",
             "file": "tiny-serial/span_precomp_b4_t8.hlo.txt",
             "inputs": [{"name": "rows", "shape": [4, 8, 384], "dtype": "f32"},
                        {"name": "starts", "shape": [4], "dtype": "i32"},
                        {"name": "lens", "shape": [4], "dtype": "i32"}],
             "outputs": [{"name": "logits", "shape": [4, 8, 512], "dtype": "f32"}],
             "weight_params": ["unemb"], "batch": 4, "span_tokens": 8,
             "max_seq": 128},
            {"name": "span_precomp_b4_t32", "kind": "span",
             "file": "tiny-serial/span_precomp_b4_t32.hlo.txt",
             "inputs": [{"name": "rows", "shape": [4, 32, 384], "dtype": "f32"},
                        {"name": "starts", "shape": [4], "dtype": "i32"},
                        {"name": "lens", "shape": [4], "dtype": "i32"}],
             "outputs": [{"name": "logits", "shape": [4, 32, 512], "dtype": "f32"}],
             "weight_params": ["unemb"], "batch": 4, "span_tokens": 32,
             "max_seq": 128}
          ]
        }
      }
    }"#;

    fn write_sample(dir: &std::path::Path) {
        std::fs::write(dir.join("manifest.json"), SAMPLE).unwrap();
    }

    #[test]
    fn parses_sample() {
        let dir = std::env::temp_dir().join("fl_manifest_test1");
        std::fs::create_dir_all(&dir).unwrap();
        write_sample(&dir);
        let m = Manifest::load(&dir).unwrap();
        let e = m.model("tiny-serial").unwrap();
        assert_eq!(e.config.d, 128);
        assert_eq!(e.config.e(), 64);
        assert_eq!(e.weights_crc, 0x12345678);
        assert_eq!(e.artifacts.len(), 5);
        let a = e.artifact("decode_precomp_b4").unwrap();
        assert!(a.is_precompute());
        assert_eq!(a.inputs[0].shape, vec![4, 384]);
        assert_eq!(a.inputs[0].elems(), 4 * 384);
    }

    #[test]
    fn buckets_sorted() {
        let dir = std::env::temp_dir().join("fl_manifest_test2");
        std::fs::create_dir_all(&dir).unwrap();
        write_sample(&dir);
        let m = Manifest::load(&dir).unwrap();
        let e = m.model("tiny-serial").unwrap();
        assert_eq!(e.decode_buckets(false).len(), 1);
        assert_eq!(e.decode_buckets(true)[0].batch, Some(4));
    }

    #[test]
    fn span_batch_buckets_exclude_b1_family_and_sort() {
        let dir = std::env::temp_dir().join("fl_manifest_test5");
        std::fs::create_dir_all(&dir).unwrap();
        write_sample(&dir);
        let m = Manifest::load(&dir).unwrap();
        let e = m.model("tiny-serial").unwrap();
        // The B=1 family sees only span_precomp_t8…
        let singles = e.span_buckets(true);
        assert_eq!(singles.len(), 1);
        assert_eq!(singles[0].name, "span_precomp_t8");
        // …and the batch family only the _b{B}_t{T} artifacts, sorted by
        // (batch, span_tokens).
        let batched = e.span_batch_buckets(true);
        assert_eq!(batched.len(), 2);
        assert_eq!(batched[0].name, "span_precomp_b4_t8");
        assert_eq!(batched[0].batch, Some(4));
        assert_eq!(batched[0].span_tokens, Some(8));
        assert_eq!(batched[1].name, "span_precomp_b4_t32");
        assert!(e.span_batch_buckets(false).is_empty());
    }

    #[test]
    fn missing_model_errors() {
        let dir = std::env::temp_dir().join("fl_manifest_test3");
        std::fs::create_dir_all(&dir).unwrap();
        write_sample(&dir);
        let m = Manifest::load(&dir).unwrap();
        assert!(m.model("nope").is_err());
    }

    #[test]
    fn row_width_mismatch_rejected() {
        let bad = SAMPLE.replace("\"precomp_row_width\": 384", "\"precomp_row_width\": 999");
        let dir = std::env::temp_dir().join("fl_manifest_test4");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), bad).unwrap();
        assert!(Manifest::load(&dir).is_err());
    }
}
