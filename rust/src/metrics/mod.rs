//! Serving metrics (S15): counters + log-bucketed latency histograms.
//!
//! Lock-free on the hot path (atomics); the histogram uses power-of-√2
//! buckets from 1 µs to ~1 h, which keeps relative error < 20% per bucket —
//! plenty for p50/p95/p99 reporting.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

const BUCKETS: usize = 64;

/// Log-bucketed latency histogram.
#[derive(Debug)]
pub struct Histogram {
    counts: [AtomicU64; BUCKETS],
    sum_ns: AtomicU64,
    n: AtomicU64,
}

fn bucket_of(d: Duration) -> usize {
    let us = d.as_micros() as u64;
    if us == 0 {
        return 0;
    }
    // two buckets per octave: idx = floor(2*log2(us))
    let lz = 63 - us.leading_zeros() as u64;
    let half = if us >= (1u64 << lz) + (1u64 << lz) / 2 { 1 } else { 0 };
    ((2 * lz + half) as usize).min(BUCKETS - 1)
}

fn bucket_upper(idx: usize) -> Duration {
    let oct = idx / 2;
    let us = if idx % 2 == 0 {
        (1u64 << oct) + (1u64 << oct) / 2
    } else {
        1u64 << (oct + 1)
    };
    Duration::from_micros(us)
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Histogram {
        Histogram {
            counts: std::array::from_fn(|_| AtomicU64::new(0)),
            sum_ns: AtomicU64::new(0),
            n: AtomicU64::new(0),
        }
    }

    pub fn record(&self, d: Duration) {
        self.counts[bucket_of(d)].fetch_add(1, Ordering::Relaxed);
        self.sum_ns
            .fetch_add(d.as_nanos() as u64, Ordering::Relaxed);
        self.n.fetch_add(1, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.n.load(Ordering::Relaxed)
    }

    pub fn mean(&self) -> Duration {
        let n = self.count();
        if n == 0 {
            return Duration::ZERO;
        }
        Duration::from_nanos(self.sum_ns.load(Ordering::Relaxed) / n)
    }

    /// Upper bound of the bucket containing the q-quantile.
    pub fn quantile(&self, q: f64) -> Duration {
        let n = self.count();
        if n == 0 {
            return Duration::ZERO;
        }
        let target = ((n as f64) * q).ceil() as u64;
        let mut acc = 0;
        for (i, c) in self.counts.iter().enumerate() {
            acc += c.load(Ordering::Relaxed);
            if acc >= target {
                return bucket_upper(i);
            }
        }
        bucket_upper(BUCKETS - 1)
    }
}

/// All serving-side metrics.
#[derive(Debug, Default)]
pub struct Metrics {
    /// Requests accepted / completed / rejected.
    pub requests_in: AtomicU64,
    pub requests_done: AtomicU64,
    pub requests_rejected: AtomicU64,
    /// Generated tokens.
    pub tokens_out: AtomicU64,
    /// Scheduler preemptions (KV pressure).
    pub preemptions: AtomicU64,
    /// Prefill chunks executed (chunked prefill; monolithic prefills count
    /// as one chunk each).
    pub prefill_chunks: AtomicU64,
    /// Engine step latencies.
    pub decode_step: Histogram,
    pub prefill_step: Histogram,
    /// Continuation-chunk latency (table-gather + decode-kernel spans).
    pub chunk_step: Histogram,
    /// Request end-to-end latency and time-to-first-token.
    pub e2e: Histogram,
    pub ttft: Histogram,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    pub fn report(&self) -> String {
        let mut s = String::new();
        use std::fmt::Write;
        let _ = writeln!(
            s,
            "requests: in={} done={} rejected={}  tokens_out={}  preemptions={}  prefill_chunks={}",
            self.requests_in.load(Ordering::Relaxed),
            self.requests_done.load(Ordering::Relaxed),
            self.requests_rejected.load(Ordering::Relaxed),
            self.tokens_out.load(Ordering::Relaxed),
            self.preemptions.load(Ordering::Relaxed),
            self.prefill_chunks.load(Ordering::Relaxed),
        );
        for (name, h) in [
            ("decode_step", &self.decode_step),
            ("prefill_step", &self.prefill_step),
            ("chunk_step", &self.chunk_step),
            ("ttft", &self.ttft),
            ("e2e", &self.e2e),
        ] {
            let _ = writeln!(
                s,
                "{name:<12} n={:<7} mean={:>10.2?} p50={:>10.2?} p95={:>10.2?} p99={:>10.2?}",
                h.count(),
                h.mean(),
                h.quantile(0.50),
                h.quantile(0.95),
                h.quantile(0.99),
            );
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_monotone() {
        let mut prev = 0;
        for us in [1u64, 2, 3, 5, 10, 100, 1000, 10_000, 1_000_000] {
            let b = bucket_of(Duration::from_micros(us));
            assert!(b >= prev, "us={us}");
            prev = b;
        }
    }

    #[test]
    fn quantile_sane() {
        let h = Histogram::new();
        for i in 1..=100u64 {
            h.record(Duration::from_micros(i * 10));
        }
        let p50 = h.quantile(0.5);
        let p95 = h.quantile(0.95);
        assert!(p50 >= Duration::from_micros(400) && p50 <= Duration::from_micros(800));
        assert!(p95 >= p50);
        assert!(h.mean() >= Duration::from_micros(400));
    }

    #[test]
    fn empty_histogram() {
        let h = Histogram::new();
        assert_eq!(h.quantile(0.5), Duration::ZERO);
        assert_eq!(h.mean(), Duration::ZERO);
    }

    #[test]
    fn report_contains_counts() {
        let m = Metrics::new();
        m.requests_in.fetch_add(3, Ordering::Relaxed);
        m.decode_step.record(Duration::from_millis(2));
        let r = m.report();
        assert!(r.contains("in=3"));
        assert!(r.contains("decode_step"));
    }

    #[test]
    fn bucket_upper_covers_bucket_of() {
        for us in [1u64, 7, 63, 999, 123_456] {
            let d = Duration::from_micros(us);
            assert!(bucket_upper(bucket_of(d)) >= d);
        }
    }
}
