//! Serving metrics (S15): counters + log-bucketed latency histograms.
//!
//! Lock-free on the hot path (atomics); the histogram uses power-of-√2
//! buckets from 1 µs to ~1 h, which keeps relative error < 20% per bucket —
//! plenty for p50/p95/p99 reporting.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

const BUCKETS: usize = 64;

/// Log-bucketed latency histogram: a [`ValueHistogram`] over
/// microseconds with a `Duration` API.
#[derive(Debug)]
pub struct Histogram {
    inner: ValueHistogram,
}

/// Power-of-√2 bucket index for a raw value (µs for latencies, token
/// counts for [`ValueHistogram`]).
fn vbucket_of(v: u64) -> usize {
    if v == 0 {
        return 0;
    }
    // two buckets per octave: idx = floor(2*log2(v))
    let lz = 63 - v.leading_zeros() as u64;
    let half = if v >= (1u64 << lz) + (1u64 << lz) / 2 { 1 } else { 0 };
    ((2 * lz + half) as usize).min(BUCKETS - 1)
}

fn vbucket_upper(idx: usize) -> u64 {
    let oct = idx / 2;
    if idx % 2 == 0 {
        (1u64 << oct) + (1u64 << oct) / 2
    } else {
        1u64 << (oct + 1)
    }
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Histogram {
        Histogram {
            inner: ValueHistogram::new(),
        }
    }

    pub fn record(&self, d: Duration) {
        self.inner.record(d.as_micros() as u64);
    }

    pub fn count(&self) -> u64 {
        self.inner.count()
    }

    pub fn mean(&self) -> Duration {
        Duration::from_nanos((self.inner.mean() * 1_000.0) as u64)
    }

    /// Upper bound of the bucket containing the q-quantile.
    pub fn quantile(&self, q: f64) -> Duration {
        Duration::from_micros(self.inner.quantile(q))
    }

    /// Sum of all recorded durations, in microseconds (Prometheus
    /// summary `_sum`).
    pub fn sum_us(&self) -> u64 {
        self.inner.sum()
    }
}

/// Log-bucketed histogram over unitless `u64` values (token counts and
/// similar) — same power-of-√2 buckets as [`Histogram`], same lock-free
/// hot path.
#[derive(Debug)]
pub struct ValueHistogram {
    counts: [AtomicU64; BUCKETS],
    sum: AtomicU64,
    n: AtomicU64,
}

impl Default for ValueHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl ValueHistogram {
    pub fn new() -> ValueHistogram {
        ValueHistogram {
            counts: std::array::from_fn(|_| AtomicU64::new(0)),
            sum: AtomicU64::new(0),
            n: AtomicU64::new(0),
        }
    }

    pub fn record(&self, v: u64) {
        self.counts[vbucket_of(v)].fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
        self.n.fetch_add(1, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.n.load(Ordering::Relaxed)
    }

    pub fn mean(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            return 0.0;
        }
        self.sum.load(Ordering::Relaxed) as f64 / n as f64
    }

    /// Sum of all recorded values (Prometheus summary `_sum`).
    pub fn sum(&self) -> u64 {
        self.sum.load(Ordering::Relaxed)
    }

    /// Upper bound of the bucket containing the q-quantile.
    pub fn quantile(&self, q: f64) -> u64 {
        let n = self.count();
        if n == 0 {
            return 0;
        }
        let target = ((n as f64) * q).ceil() as u64;
        let mut acc = 0;
        for (i, c) in self.counts.iter().enumerate() {
            acc += c.load(Ordering::Relaxed);
            if acc >= target {
                return vbucket_upper(i);
            }
        }
        vbucket_upper(BUCKETS - 1)
    }
}

/// Host↔device transfer accounting for the PJRT runtime (S17): every
/// upload and readback the engine performs, in bytes, with the KV-cache
/// share broken out.  This is what makes the device-resident KV path
/// auditable: `cache_uploads` counts upload *events* (one per K/V buffer
/// pair), so a decode span that chains N tokens through one
/// `DeviceCacheSession` shows exactly 1 where the legacy host path shows
/// N.  Lock-free (the engine thread records, connection threads read).
#[derive(Debug, Default)]
pub struct TransferStats {
    /// Total host→device / device→host bytes (all tensors).
    pub h2d_bytes: AtomicU64,
    pub d2h_bytes: AtomicU64,
    /// Transfer event counts.
    pub h2d_transfers: AtomicU64,
    pub d2h_transfers: AtomicU64,
    /// KV-cache share of the traffic: bytes uploaded as dense cache
    /// batches and read back as cache syncs (subsets of the totals).
    pub cache_h2d_bytes: AtomicU64,
    pub cache_d2h_bytes: AtomicU64,
    /// Cache upload events (one per K/V pair) and sync-to-host events.
    pub cache_uploads: AtomicU64,
    pub cache_syncs: AtomicU64,
}

impl TransferStats {
    pub fn new() -> TransferStats {
        TransferStats::default()
    }

    pub fn record_h2d(&self, bytes: u64, transfers: u64) {
        self.h2d_bytes.fetch_add(bytes, Ordering::Relaxed);
        self.h2d_transfers.fetch_add(transfers, Ordering::Relaxed);
    }

    pub fn record_d2h(&self, bytes: u64, transfers: u64) {
        self.d2h_bytes.fetch_add(bytes, Ordering::Relaxed);
        self.d2h_transfers.fetch_add(transfers, Ordering::Relaxed);
    }

    /// One K/V cache-pair upload of `bytes` total (already counted in the
    /// generic totals by the upload path; this tags the cache share).
    pub fn record_cache_upload(&self, bytes: u64) {
        self.cache_h2d_bytes.fetch_add(bytes, Ordering::Relaxed);
        self.cache_uploads.fetch_add(1, Ordering::Relaxed);
    }

    /// One cache sync-to-host (full K/V pair readback) of `bytes` total.
    pub fn record_cache_sync(&self, bytes: u64) {
        self.cache_d2h_bytes.fetch_add(bytes, Ordering::Relaxed);
        self.cache_syncs.fetch_add(1, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> TransferSnapshot {
        TransferSnapshot {
            h2d_bytes: self.h2d_bytes.load(Ordering::Relaxed),
            d2h_bytes: self.d2h_bytes.load(Ordering::Relaxed),
            h2d_transfers: self.h2d_transfers.load(Ordering::Relaxed),
            d2h_transfers: self.d2h_transfers.load(Ordering::Relaxed),
            cache_h2d_bytes: self.cache_h2d_bytes.load(Ordering::Relaxed),
            cache_d2h_bytes: self.cache_d2h_bytes.load(Ordering::Relaxed),
            cache_uploads: self.cache_uploads.load(Ordering::Relaxed),
            cache_syncs: self.cache_syncs.load(Ordering::Relaxed),
        }
    }
}

/// Point-in-time copy of [`TransferStats`] (bench deltas, server reports).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct TransferSnapshot {
    pub h2d_bytes: u64,
    pub d2h_bytes: u64,
    pub h2d_transfers: u64,
    pub d2h_transfers: u64,
    pub cache_h2d_bytes: u64,
    pub cache_d2h_bytes: u64,
    pub cache_uploads: u64,
    pub cache_syncs: u64,
}

impl TransferSnapshot {
    /// Field-wise difference against an earlier snapshot (bench sections).
    pub fn since(&self, earlier: &TransferSnapshot) -> TransferSnapshot {
        TransferSnapshot {
            h2d_bytes: self.h2d_bytes - earlier.h2d_bytes,
            d2h_bytes: self.d2h_bytes - earlier.d2h_bytes,
            h2d_transfers: self.h2d_transfers - earlier.h2d_transfers,
            d2h_transfers: self.d2h_transfers - earlier.d2h_transfers,
            cache_h2d_bytes: self.cache_h2d_bytes - earlier.cache_h2d_bytes,
            cache_d2h_bytes: self.cache_d2h_bytes - earlier.cache_d2h_bytes,
            cache_uploads: self.cache_uploads - earlier.cache_uploads,
            cache_syncs: self.cache_syncs - earlier.cache_syncs,
        }
    }
}

/// All serving-side metrics.
#[derive(Debug, Default)]
pub struct Metrics {
    /// Requests accepted / completed / rejected / cancelled (the v2
    /// protocol's `cancel` op, surfaced as finish reason `cancelled`).
    pub requests_in: AtomicU64,
    pub requests_done: AtomicU64,
    pub requests_rejected: AtomicU64,
    /// Requests refused at admission by the overload ladder
    /// (`reason:"shed"`, retriable) — deliberately separate from
    /// `requests_rejected` (malformed / duplicate-tag / backpressure,
    /// client error) so dashboards can tell shedding from bad input.
    pub requests_shed: AtomicU64,
    /// Current overload-ladder rung (gauge, 0 = normal service).
    pub shed_ladder_level: AtomicU64,
    pub requests_cancelled: AtomicU64,
    /// Requests that exhausted transient retries (or hit a fatal engine
    /// error) and finished with `reason:"error"` — terminal, all KV and
    /// scheduler state released.
    pub requests_errored: AtomicU64,
    /// Fault plane (`rust/src/faults/`): injected faults fired so far,
    /// and engine operations re-run after a transient error (each retry
    /// attempt counts once, successful or not).
    pub fault_injected: AtomicU64,
    pub fault_retries: AtomicU64,
    /// Degradation ladder: serving-path demotions (failure marked a path
    /// unhealthy) and cooldown re-promotions (recovery probes re-armed
    /// the path).  Mirrors the engine's `HealthRegistry` totals.
    pub health_demotions: AtomicU64,
    pub health_promotions: AtomicU64,
    /// Slow-reader flow control: transitions of a stream into the
    /// stalled state (its per-tag writer queue hit the bound and the
    /// request was paused at the scheduler until the reader drained).
    pub stream_stalls: AtomicU64,
    /// Idle conversations closed by the TTL sweeper.
    pub conversations_expired: AtomicU64,
    /// Multi-turn chat: completed turns across all conversations, and
    /// prompt tokens a turn reused from the prefix cache instead of
    /// re-prefilling (the prior transcript served from generated-span
    /// KV; a subset of `prefix_cached_tokens`).
    pub chat_turns: AtomicU64,
    pub chat_reused_tokens: AtomicU64,
    /// Generated tokens.
    pub tokens_out: AtomicU64,
    /// Scheduler preemptions (KV pressure).
    pub preemptions: AtomicU64,
    /// Prefill chunks executed (chunked prefill; monolithic prefills count
    /// as one chunk each).
    pub prefill_chunks: AtomicU64,
    /// Cross-request prefix cache: requests whose prompt matched a cached
    /// prefix / requests that missed (counted only when the cache is on).
    pub prefix_hits: AtomicU64,
    pub prefix_misses: AtomicU64,
    /// Cache blocks evicted (capacity LRU + demand-driven KV pressure).
    pub prefix_evictions: AtomicU64,
    /// Total prompt tokens served from the cache instead of prefilled.
    pub prefix_cached_tokens: AtomicU64,
    /// Device-resident KV decode sessions built (each begins with one
    /// cache-pair upload) / steps served by reusing a live session
    /// (buffer-chained, logits-only readback) / sync-to-host writebacks.
    pub kv_sessions: AtomicU64,
    pub kv_session_steps: AtomicU64,
    pub kv_session_syncs: AtomicU64,
    /// Batched span execution: device executions serving continuation
    /// spans (span-artifact tiles, or one per token on the fallback) and
    /// spans that fell back to the token-by-token oracle entirely.
    pub span_executions: AtomicU64,
    pub span_fallbacks: AtomicU64,
    /// Tokens advanced per span execution (bucket-sized on the batched
    /// path, 1 on the fallback) — the distribution that shows whether
    /// spans actually batch.
    pub span_exec_tokens: ValueHistogram,
    /// Multi-sequence span execution: device executions that advanced a
    /// GROUP of sequences through one `[B, T]` span artifact (a subset of
    /// `span_executions`), and the occupied-lane count per such group —
    /// the distribution that shows whether cross-sequence grouping
    /// actually fills lanes instead of padding them.
    pub span_batched_executions: AtomicU64,
    pub span_batch_occupancy: ValueHistogram,
    /// Server-side speculative decoding (`rust/src/specdec/`): verify
    /// executions (device executions spent scoring a drafted span),
    /// tokens drafted across all verifies, tokens the verify emitted
    /// (accepted draft prefix + the bonus token — this over
    /// `spec_executions` is the accepted-tokens-per-execution ratio the
    /// spec gate asserts on), and verifies that rejected at least one
    /// drafted token (the rolled-back suffix rows never reach the host
    /// store).
    pub spec_executions: AtomicU64,
    pub spec_drafted_tokens: AtomicU64,
    pub spec_accepted_tokens: AtomicU64,
    pub spec_rollbacks: AtomicU64,
    /// Tokens netted per verify execution (accepted prefix + bonus; 1 =
    /// fully-rejected draft, never worse than plain decode).
    pub spec_accept_len: ValueHistogram,
    /// Cached-tokens-per-request distribution (0 recorded on a miss).
    pub cached_tokens: ValueHistogram,
    /// Engine step latencies.
    pub decode_step: Histogram,
    pub prefill_step: Histogram,
    /// Continuation-chunk latency (table-gather + decode-kernel spans).
    pub chunk_step: Histogram,
    /// Request end-to-end latency and time-to-first-token.
    pub e2e: Histogram,
    pub ttft: Histogram,
    /// Queue wait: submit → first scheduled prefill chunk (admission plus
    /// head-of-line delay — the scheduler's contribution to TTFT).
    pub queue_wait: Histogram,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    pub fn report(&self) -> String {
        let mut s = String::new();
        use std::fmt::Write;
        let _ = writeln!(
            s,
            "requests: in={} done={} rejected={} shed={} cancelled={} errored={}  tokens_out={}  preemptions={}  prefill_chunks={}",
            self.requests_in.load(Ordering::Relaxed),
            self.requests_done.load(Ordering::Relaxed),
            self.requests_rejected.load(Ordering::Relaxed),
            self.requests_shed.load(Ordering::Relaxed),
            self.requests_cancelled.load(Ordering::Relaxed),
            self.requests_errored.load(Ordering::Relaxed),
            self.tokens_out.load(Ordering::Relaxed),
            self.preemptions.load(Ordering::Relaxed),
            self.prefill_chunks.load(Ordering::Relaxed),
        );
        let _ = writeln!(
            s,
            "faults: injected={} retries={}  health: demotions={} promotions={}  \
             stream_stalls={} conversations_expired={}  shed_ladder_level={}",
            self.fault_injected.load(Ordering::Relaxed),
            self.fault_retries.load(Ordering::Relaxed),
            self.health_demotions.load(Ordering::Relaxed),
            self.health_promotions.load(Ordering::Relaxed),
            self.stream_stalls.load(Ordering::Relaxed),
            self.conversations_expired.load(Ordering::Relaxed),
            self.shed_ladder_level.load(Ordering::Relaxed),
        );
        let _ = writeln!(
            s,
            "chat: turns={} reused_tokens={}",
            self.chat_turns.load(Ordering::Relaxed),
            self.chat_reused_tokens.load(Ordering::Relaxed),
        );
        let _ = writeln!(
            s,
            "prefix_cache: hits={} misses={} evicted={} cached_tokens={}  \
             per-req mean={:.1} p50={} p95={}",
            self.prefix_hits.load(Ordering::Relaxed),
            self.prefix_misses.load(Ordering::Relaxed),
            self.prefix_evictions.load(Ordering::Relaxed),
            self.prefix_cached_tokens.load(Ordering::Relaxed),
            self.cached_tokens.mean(),
            self.cached_tokens.quantile(0.50),
            self.cached_tokens.quantile(0.95),
        );
        let _ = writeln!(
            s,
            "device_kv: sessions={} chained_steps={} syncs={}",
            self.kv_sessions.load(Ordering::Relaxed),
            self.kv_session_steps.load(Ordering::Relaxed),
            self.kv_session_syncs.load(Ordering::Relaxed),
        );
        let _ = writeln!(
            s,
            "span_exec: executions={} fallbacks={} tokens/exec mean={:.1} p50={} p95={}",
            self.span_executions.load(Ordering::Relaxed),
            self.span_fallbacks.load(Ordering::Relaxed),
            self.span_exec_tokens.mean(),
            self.span_exec_tokens.quantile(0.50),
            self.span_exec_tokens.quantile(0.95),
        );
        let _ = writeln!(
            s,
            "span_batch: executions={} occupancy mean={:.1} p50={} p95={}",
            self.span_batched_executions.load(Ordering::Relaxed),
            self.span_batch_occupancy.mean(),
            self.span_batch_occupancy.quantile(0.50),
            self.span_batch_occupancy.quantile(0.95),
        );
        let _ = writeln!(
            s,
            "spec_decode: executions={} drafted={} accepted={} rollbacks={} \
             accept_len mean={:.2} p50={} p95={}",
            self.spec_executions.load(Ordering::Relaxed),
            self.spec_drafted_tokens.load(Ordering::Relaxed),
            self.spec_accepted_tokens.load(Ordering::Relaxed),
            self.spec_rollbacks.load(Ordering::Relaxed),
            self.spec_accept_len.mean(),
            self.spec_accept_len.quantile(0.50),
            self.spec_accept_len.quantile(0.95),
        );
        for (name, h) in [
            ("decode_step", &self.decode_step),
            ("prefill_step", &self.prefill_step),
            ("chunk_step", &self.chunk_step),
            ("queue_wait", &self.queue_wait),
            ("ttft", &self.ttft),
            ("e2e", &self.e2e),
        ] {
            let _ = writeln!(
                s,
                "{name:<12} n={:<7} mean={:>10.2?} p50={:>10.2?} p95={:>10.2?} p99={:>10.2?}",
                h.count(),
                h.mean(),
                h.quantile(0.50),
                h.quantile(0.95),
                h.quantile(0.99),
            );
        }
        s
    }

    /// Prometheus text exposition (format v0.0.4) of every counter and
    /// latency summary, for the v2 `metrics.prom` op.  Latency summaries
    /// carry a `_us` suffix (microseconds); `transfers` is the runtime's
    /// transfer snapshot so bus traffic lands alongside serving counters.
    /// All metric names are prefixed `firstlayer_`.
    pub fn prometheus(&self, transfers: &TransferSnapshot) -> String {
        let mut s = String::new();
        for (name, v) in [
            ("requests_in", self.requests_in.load(Ordering::Relaxed)),
            ("requests_done", self.requests_done.load(Ordering::Relaxed)),
            (
                "requests_rejected",
                self.requests_rejected.load(Ordering::Relaxed),
            ),
            ("requests_shed", self.requests_shed.load(Ordering::Relaxed)),
            (
                "requests_cancelled",
                self.requests_cancelled.load(Ordering::Relaxed),
            ),
            (
                "requests_errored",
                self.requests_errored.load(Ordering::Relaxed),
            ),
            ("fault_injected", self.fault_injected.load(Ordering::Relaxed)),
            ("fault_retries", self.fault_retries.load(Ordering::Relaxed)),
            (
                "health_demotions",
                self.health_demotions.load(Ordering::Relaxed),
            ),
            (
                "health_promotions",
                self.health_promotions.load(Ordering::Relaxed),
            ),
            ("stream_stalls", self.stream_stalls.load(Ordering::Relaxed)),
            (
                "conversations_expired",
                self.conversations_expired.load(Ordering::Relaxed),
            ),
            ("tokens_out", self.tokens_out.load(Ordering::Relaxed)),
            ("preemptions", self.preemptions.load(Ordering::Relaxed)),
            ("prefill_chunks", self.prefill_chunks.load(Ordering::Relaxed)),
            ("chat_turns", self.chat_turns.load(Ordering::Relaxed)),
            (
                "chat_reused_tokens",
                self.chat_reused_tokens.load(Ordering::Relaxed),
            ),
            ("prefix_hits", self.prefix_hits.load(Ordering::Relaxed)),
            ("prefix_misses", self.prefix_misses.load(Ordering::Relaxed)),
            (
                "prefix_evictions",
                self.prefix_evictions.load(Ordering::Relaxed),
            ),
            (
                "prefix_cached_tokens",
                self.prefix_cached_tokens.load(Ordering::Relaxed),
            ),
            ("kv_sessions", self.kv_sessions.load(Ordering::Relaxed)),
            (
                "kv_session_steps",
                self.kv_session_steps.load(Ordering::Relaxed),
            ),
            (
                "kv_session_syncs",
                self.kv_session_syncs.load(Ordering::Relaxed),
            ),
            ("span_executions", self.span_executions.load(Ordering::Relaxed)),
            ("span_fallbacks", self.span_fallbacks.load(Ordering::Relaxed)),
            (
                "span_batched_executions",
                self.span_batched_executions.load(Ordering::Relaxed),
            ),
            ("spec_executions", self.spec_executions.load(Ordering::Relaxed)),
            (
                "spec_drafted_tokens",
                self.spec_drafted_tokens.load(Ordering::Relaxed),
            ),
            (
                "spec_accepted_tokens",
                self.spec_accepted_tokens.load(Ordering::Relaxed),
            ),
            ("spec_rollbacks", self.spec_rollbacks.load(Ordering::Relaxed)),
            ("h2d_bytes", transfers.h2d_bytes),
            ("d2h_bytes", transfers.d2h_bytes),
            ("h2d_transfers", transfers.h2d_transfers),
            ("d2h_transfers", transfers.d2h_transfers),
            ("cache_h2d_bytes", transfers.cache_h2d_bytes),
            ("cache_d2h_bytes", transfers.cache_d2h_bytes),
            ("cache_uploads", transfers.cache_uploads),
            ("cache_syncs", transfers.cache_syncs),
        ] {
            prom_counter(&mut s, name, v);
        }
        prom_gauge(
            &mut s,
            "shed_ladder_level",
            self.shed_ladder_level.load(Ordering::Relaxed),
        );
        for (name, h) in [
            ("decode_step_us", &self.decode_step),
            ("prefill_step_us", &self.prefill_step),
            ("chunk_step_us", &self.chunk_step),
            ("queue_wait_us", &self.queue_wait),
            ("ttft_us", &self.ttft),
            ("e2e_us", &self.e2e),
        ] {
            prom_summary(
                &mut s,
                name,
                h.count(),
                h.sum_us(),
                [
                    (0.5, h.quantile(0.50).as_micros() as u64),
                    (0.95, h.quantile(0.95).as_micros() as u64),
                    (0.99, h.quantile(0.99).as_micros() as u64),
                ],
            );
        }
        for (name, h) in [
            ("span_exec_tokens", &self.span_exec_tokens),
            ("span_batch_occupancy", &self.span_batch_occupancy),
            ("spec_accept_len", &self.spec_accept_len),
            ("cached_tokens", &self.cached_tokens),
        ] {
            prom_summary(
                &mut s,
                name,
                h.count(),
                h.sum(),
                [
                    (0.5, h.quantile(0.50)),
                    (0.95, h.quantile(0.95)),
                    (0.99, h.quantile(0.99)),
                ],
            );
        }
        s
    }
}

fn prom_counter(out: &mut String, name: &str, v: u64) {
    use std::fmt::Write;
    let _ = writeln!(out, "# TYPE firstlayer_{name} counter");
    let _ = writeln!(out, "firstlayer_{name} {v}");
}

fn prom_gauge(out: &mut String, name: &str, v: u64) {
    use std::fmt::Write;
    let _ = writeln!(out, "# TYPE firstlayer_{name} gauge");
    let _ = writeln!(out, "firstlayer_{name} {v}");
}

fn prom_summary(out: &mut String, name: &str, count: u64, sum: u64, quantiles: [(f64, u64); 3]) {
    use std::fmt::Write;
    let _ = writeln!(out, "# TYPE firstlayer_{name} summary");
    for (q, v) in quantiles {
        let _ = writeln!(out, "firstlayer_{name}{{quantile=\"{q}\"}} {v}");
    }
    let _ = writeln!(out, "firstlayer_{name}_sum {sum}");
    let _ = writeln!(out, "firstlayer_{name}_count {count}");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_monotone() {
        let mut prev = 0;
        for v in [1u64, 2, 3, 5, 10, 100, 1000, 10_000, 1_000_000] {
            let b = vbucket_of(v);
            assert!(b >= prev, "v={v}");
            prev = b;
        }
    }

    #[test]
    fn quantile_sane() {
        let h = Histogram::new();
        for i in 1..=100u64 {
            h.record(Duration::from_micros(i * 10));
        }
        let p50 = h.quantile(0.5);
        let p95 = h.quantile(0.95);
        assert!(p50 >= Duration::from_micros(400) && p50 <= Duration::from_micros(800));
        assert!(p95 >= p50);
        assert!(h.mean() >= Duration::from_micros(400));
    }

    #[test]
    fn empty_histogram() {
        let h = Histogram::new();
        assert_eq!(h.quantile(0.5), Duration::ZERO);
        assert_eq!(h.mean(), Duration::ZERO);
    }

    #[test]
    fn report_contains_counts() {
        let m = Metrics::new();
        m.requests_in.fetch_add(3, Ordering::Relaxed);
        m.decode_step.record(Duration::from_millis(2));
        let r = m.report();
        assert!(r.contains("in=3"));
        assert!(r.contains("decode_step"));
    }

    #[test]
    fn value_histogram_tokens() {
        let h = ValueHistogram::new();
        h.record(0); // a prefix-cache miss
        for _ in 0..9 {
            h.record(64); // 64 cached tokens per hit
        }
        assert_eq!(h.count(), 10);
        assert!((h.mean() - 57.6).abs() < 1e-9);
        assert!(h.quantile(0.95) >= 64);
        assert!(h.quantile(0.05) <= 1); // the miss sits in bucket 0
    }

    #[test]
    fn report_contains_prefix_cache_line() {
        let m = Metrics::new();
        m.prefix_hits.fetch_add(2, Ordering::Relaxed);
        m.cached_tokens.record(32);
        assert!(m.report().contains("prefix_cache: hits=2"));
    }

    #[test]
    fn transfer_stats_tag_cache_share() {
        let t = TransferStats::new();
        t.record_h2d(1000, 3);
        t.record_h2d(512, 2);
        t.record_cache_upload(512);
        t.record_d2h(256, 1);
        t.record_cache_sync(256);
        let s = t.snapshot();
        assert_eq!(s.h2d_bytes, 1512);
        assert_eq!(s.h2d_transfers, 5);
        assert_eq!(s.cache_h2d_bytes, 512);
        assert_eq!(s.cache_uploads, 1);
        assert_eq!(s.d2h_bytes, 256);
        assert_eq!(s.cache_d2h_bytes, 256);
        assert_eq!(s.cache_syncs, 1);
        // Delta arithmetic for bench sections.
        let before = s;
        t.record_cache_upload(512);
        let d = t.snapshot().since(&before);
        assert_eq!(d.cache_uploads, 1);
        assert_eq!(d.cache_h2d_bytes, 512);
        assert_eq!(d.h2d_bytes, 0);
    }

    #[test]
    fn report_and_prom_split_shed_from_rejected() {
        let m = Metrics::new();
        m.requests_rejected.fetch_add(2, Ordering::Relaxed);
        m.requests_shed.fetch_add(5, Ordering::Relaxed);
        m.shed_ladder_level.store(2, Ordering::Relaxed);
        let r = m.report();
        assert!(r.contains("rejected=2 shed=5"));
        assert!(r.contains("shed_ladder_level=2"));
        let p = m.prometheus(&TransferSnapshot::default());
        assert!(p.contains("firstlayer_requests_rejected 2"));
        assert!(p.contains("firstlayer_requests_shed 5"));
        assert!(p.contains("# TYPE firstlayer_shed_ladder_level gauge"));
        assert!(p.contains("firstlayer_shed_ladder_level 2"));
    }

    #[test]
    fn report_contains_chat_and_cancel_counters() {
        let m = Metrics::new();
        m.requests_cancelled.fetch_add(1, Ordering::Relaxed);
        m.chat_turns.fetch_add(3, Ordering::Relaxed);
        m.chat_reused_tokens.fetch_add(48, Ordering::Relaxed);
        let r = m.report();
        assert!(r.contains("cancelled=1"));
        assert!(r.contains("chat: turns=3 reused_tokens=48"));
    }

    #[test]
    fn report_contains_span_exec_line() {
        let m = Metrics::new();
        m.span_executions.fetch_add(2, Ordering::Relaxed);
        m.span_fallbacks.fetch_add(1, Ordering::Relaxed);
        m.span_exec_tokens.record(32);
        m.span_exec_tokens.record(8);
        let r = m.report();
        assert!(r.contains("span_exec: executions=2 fallbacks=1"));
        assert!((m.span_exec_tokens.mean() - 20.0).abs() < 1e-9);
    }

    #[test]
    fn report_contains_span_batch_line() {
        let m = Metrics::new();
        m.span_batched_executions.fetch_add(3, Ordering::Relaxed);
        m.span_batch_occupancy.record(4);
        m.span_batch_occupancy.record(2);
        let r = m.report();
        assert!(r.contains("span_batch: executions=3"));
        assert!((m.span_batch_occupancy.mean() - 3.0).abs() < 1e-9);
    }

    #[test]
    fn report_and_prom_contain_spec_decode_counters() {
        let m = Metrics::new();
        m.spec_executions.fetch_add(4, Ordering::Relaxed);
        m.spec_drafted_tokens.fetch_add(12, Ordering::Relaxed);
        m.spec_accepted_tokens.fetch_add(9, Ordering::Relaxed);
        m.spec_rollbacks.fetch_add(2, Ordering::Relaxed);
        m.spec_accept_len.record(3);
        m.spec_accept_len.record(1);
        let r = m.report();
        assert!(r.contains("spec_decode: executions=4 drafted=12 accepted=9 rollbacks=2"));
        assert!((m.spec_accept_len.mean() - 2.0).abs() < 1e-9);
        let p = m.prometheus(&TransferStats::new().snapshot());
        assert!(p.contains("firstlayer_spec_executions 4"));
        assert!(p.contains("firstlayer_spec_drafted_tokens 12"));
        assert!(p.contains("firstlayer_spec_accepted_tokens 9"));
        assert!(p.contains("firstlayer_spec_rollbacks 2"));
        assert!(p.contains("# TYPE firstlayer_spec_accept_len summary"));
        assert!(p.contains("firstlayer_spec_accept_len_count 2"));
    }

    #[test]
    fn report_contains_device_kv_line() {
        let m = Metrics::new();
        m.kv_sessions.fetch_add(2, Ordering::Relaxed);
        m.kv_session_steps.fetch_add(10, Ordering::Relaxed);
        assert!(m.report().contains("device_kv: sessions=2 chained_steps=10"));
    }

    #[test]
    fn bucket_upper_covers_bucket_of() {
        for v in [1u64, 7, 63, 999, 123_456] {
            assert!(vbucket_upper(vbucket_of(v)) >= v);
        }
    }

    #[test]
    fn value_histogram_quantile_empty() {
        let h = ValueHistogram::new();
        assert_eq!(h.quantile(0.0), 0);
        assert_eq!(h.quantile(0.5), 0);
        assert_eq!(h.quantile(1.0), 0);
        assert_eq!(h.mean(), 0.0);
        assert_eq!(h.sum(), 0);
    }

    #[test]
    fn value_histogram_quantile_single_sample() {
        let h = ValueHistogram::new();
        h.record(100);
        // With one sample, every quantile resolves to the one occupied
        // bucket's upper bound, which must cover the sample.
        let upper = h.quantile(0.5);
        assert!(upper >= 100);
        assert_eq!(h.quantile(0.01), upper);
        assert_eq!(h.quantile(1.0), upper);
        assert_eq!(h.sum(), 100);
    }

    #[test]
    fn value_histogram_quantile_bucket_boundary() {
        // Powers of two and the √2 midpoints are bucket edges: a value on
        // an edge must land in a bucket whose upper bound covers it, and
        // neighbors across an edge must land in different buckets.
        for v in [1u64, 2, 3, 4, 6, 8, 1 << 20] {
            let h = ValueHistogram::new();
            h.record(v);
            assert!(h.quantile(1.0) >= v, "v={v}");
        }
        assert_ne!(vbucket_of(2), vbucket_of(3));
        assert_ne!(vbucket_of(3), vbucket_of(4));
        // Top bucket clamps instead of overflowing.
        assert_eq!(vbucket_of(u64::MAX), BUCKETS - 1);
    }

    #[test]
    fn report_and_prom_contain_fault_counters() {
        let m = Metrics::new();
        m.requests_errored.fetch_add(1, Ordering::Relaxed);
        m.fault_injected.fetch_add(4, Ordering::Relaxed);
        m.fault_retries.fetch_add(2, Ordering::Relaxed);
        m.health_demotions.fetch_add(1, Ordering::Relaxed);
        m.health_promotions.fetch_add(1, Ordering::Relaxed);
        m.stream_stalls.fetch_add(3, Ordering::Relaxed);
        m.conversations_expired.fetch_add(5, Ordering::Relaxed);
        let r = m.report();
        assert!(r.contains("errored=1"));
        assert!(r.contains("faults: injected=4 retries=2"));
        assert!(r.contains("health: demotions=1 promotions=1"));
        assert!(r.contains("stream_stalls=3 conversations_expired=5"));
        let p = m.prometheus(&TransferStats::new().snapshot());
        assert!(p.contains("firstlayer_requests_errored 1"));
        assert!(p.contains("firstlayer_fault_injected 4"));
        assert!(p.contains("firstlayer_fault_retries 2"));
        assert!(p.contains("firstlayer_health_demotions 1"));
        assert!(p.contains("firstlayer_health_promotions 1"));
        assert!(p.contains("firstlayer_stream_stalls 3"));
        assert!(p.contains("firstlayer_conversations_expired 5"));
    }

    #[test]
    fn report_contains_queue_wait() {
        let m = Metrics::new();
        m.queue_wait.record(Duration::from_micros(500));
        assert!(m.report().contains("queue_wait"));
    }

    #[test]
    fn prometheus_exposition_well_formed() {
        let m = Metrics::new();
        m.requests_in.fetch_add(2, Ordering::Relaxed);
        m.ttft.record(Duration::from_millis(5));
        m.queue_wait.record(Duration::from_micros(100));
        let t = TransferStats::new();
        t.record_h2d(100, 1);
        let p = m.prometheus(&t.snapshot());
        assert!(p.contains("firstlayer_requests_in 2"));
        assert!(p.contains("# TYPE firstlayer_ttft_us summary"));
        assert!(p.contains("firstlayer_ttft_us{quantile=\"0.99\"}"));
        assert!(p.contains("firstlayer_ttft_us_count 1"));
        assert!(p.contains("firstlayer_queue_wait_us_count 1"));
        assert!(p.contains("firstlayer_h2d_bytes 100"));
        // Every non-comment line is `name[{labels}] value` with a numeric
        // value — the exposition-format contract scrapers rely on.
        for line in p.lines() {
            if line.starts_with('#') || line.is_empty() {
                continue;
            }
            let val = line.rsplit(' ').next().unwrap();
            assert!(val.parse::<f64>().is_ok(), "bad line: {line}");
        }
    }
}
