//! Overload ladder (S10): staged, reversible load shedding for the
//! serving front door.
//!
//! Modeled on the `HealthRegistry` cooldown ladder in `faults/`: a small
//! state machine the coordinator ticks once per engine step, fed by
//! *pressure signals* the stack already measures — queue-wait p95, the
//! KV pool's free-block shortfall, and step-token-budget saturation.
//! Sustained pressure walks the ladder DOWN one rung at a time; sustained
//! calm walks it back UP.  Both directions are hysteresis-gated
//! (`trip_steps` consecutive hot ticks to descend, `clear_steps` calm
//! ticks to ascend) so a single spiky step can't flap the front door.
//!
//! The rungs, in order of increasing pain — each sheds strictly cheaper
//! work than the one below it, and **in-flight requests are never
//! touched** at any level:
//!
//! | level | name            | effect                                          |
//! |-------|-----------------|-------------------------------------------------|
//! | 0     | `Normal`        | baseline planning, byte-identical to ladder off |
//! | 1     | `Throttle`      | spec drafts stop, per-tick admissions halve     |
//! | 2     | `ShedBatch`     | + new batch-class work is shed (retriable)      |
//! | 3     | `ShedInteractive` | + ALL new work is shed (retriable)            |
//!
//! Shedding is an admission-time decision: the coordinator answers a shed
//! submission with a retriable `reason:"shed"` + `retry_after_ms` instead
//! of queueing it.  Decode, continuations, and already-queued work always
//! run to completion — the ladder narrows the intake, never the pipeline.

use std::sync::atomic::{AtomicU64, Ordering};

use crate::scheduler::Priority;

/// One rung of the shed ladder.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum ShedLevel {
    Normal = 0,
    Throttle = 1,
    ShedBatch = 2,
    ShedInteractive = 3,
}

impl ShedLevel {
    pub const ALL: [ShedLevel; 4] = [
        ShedLevel::Normal,
        ShedLevel::Throttle,
        ShedLevel::ShedBatch,
        ShedLevel::ShedInteractive,
    ];

    pub fn from_index(i: u8) -> ShedLevel {
        Self::ALL[(i as usize).min(3)]
    }

    pub fn index(self) -> u8 {
        self as u8
    }

    pub fn label(self) -> &'static str {
        match self {
            ShedLevel::Normal => "normal",
            ShedLevel::Throttle => "throttle",
            ShedLevel::ShedBatch => "shed-batch",
            ShedLevel::ShedInteractive => "shed-interactive",
        }
    }
}

/// Instantaneous pressure sample the coordinator assembles each step.
#[derive(Debug, Clone, Copy, Default)]
pub struct Pressure {
    /// Queue-wait p95 over the metrics histogram, microseconds.
    pub queue_wait_p95_us: u64,
    /// Free blocks in the KV pool right now.
    pub free_blocks: usize,
    /// Whether the last planned step spent its whole token budget.
    pub budget_saturated: bool,
}

/// Thresholds + hysteresis for the ladder.
#[derive(Debug, Clone)]
pub struct OverloadConfig {
    /// Queue-wait p95 above this is a hot signal, microseconds.
    pub queue_p95_us: u64,
    /// Free blocks at or below this is a hot signal.
    pub free_block_floor: usize,
    /// Consecutive hot ticks required to descend one rung.
    pub trip_steps: u64,
    /// Consecutive calm ticks required to ascend one rung.
    pub clear_steps: u64,
    /// Retry hint attached to shed responses, milliseconds.
    pub retry_after_ms: u64,
}

impl Default for OverloadConfig {
    fn default() -> Self {
        OverloadConfig {
            queue_p95_us: 50_000,
            free_block_floor: 16,
            trip_steps: 3,
            clear_steps: 16,
            retry_after_ms: 500,
        }
    }
}

/// The ladder itself.  Plain counters (ticked from the single-threaded
/// coordinator step loop); only the level is an atomic so metrics
/// snapshots can read it without coordination.
pub struct OverloadLadder {
    cfg: OverloadConfig,
    level: AtomicU64,
    hot_streak: u64,
    calm_streak: u64,
    /// Lifetime rung transitions (descents, ascents) — audit counters.
    demotions: u64,
    promotions: u64,
}

impl OverloadLadder {
    pub fn new(cfg: OverloadConfig) -> OverloadLadder {
        OverloadLadder {
            cfg,
            level: AtomicU64::new(0),
            hot_streak: 0,
            calm_streak: 0,
            demotions: 0,
            promotions: 0,
        }
    }

    pub fn config(&self) -> &OverloadConfig {
        &self.cfg
    }

    pub fn level(&self) -> ShedLevel {
        ShedLevel::from_index(self.level.load(Ordering::Relaxed) as u8)
    }

    pub fn demotions(&self) -> u64 {
        self.demotions
    }

    pub fn promotions(&self) -> u64 {
        self.promotions
    }

    /// Whether a NEW submission of class `p` is admitted at the current
    /// level.  In-flight work is never consulted here — only intake.
    pub fn admits(&self, p: Priority) -> bool {
        match self.level() {
            ShedLevel::Normal | ShedLevel::Throttle => true,
            ShedLevel::ShedBatch => p < Priority::Batch,
            ShedLevel::ShedInteractive => false,
        }
    }

    fn is_hot(&self, p: &Pressure) -> bool {
        p.queue_wait_p95_us > self.cfg.queue_p95_us
            || p.free_blocks <= self.cfg.free_block_floor
            || p.budget_saturated
    }

    /// Feed one step's pressure sample; returns `Some((from, to))` when
    /// the ladder moved a rung this tick.  One rung per transition in
    /// either direction — recovery retraces the descent so every shed
    /// path re-promotes through `Throttle` before full service resumes.
    pub fn tick(&mut self, p: &Pressure) -> Option<(ShedLevel, ShedLevel)> {
        let cur = self.level();
        if self.is_hot(p) {
            self.calm_streak = 0;
            self.hot_streak += 1;
            if self.hot_streak >= self.cfg.trip_steps && cur < ShedLevel::ShedInteractive {
                self.hot_streak = 0;
                let next = ShedLevel::from_index(cur.index() + 1);
                self.level.store(next.index() as u64, Ordering::Relaxed);
                self.demotions += 1;
                return Some((cur, next));
            }
        } else {
            self.hot_streak = 0;
            self.calm_streak += 1;
            if self.calm_streak >= self.cfg.clear_steps && cur > ShedLevel::Normal {
                self.calm_streak = 0;
                let next = ShedLevel::from_index(cur.index() - 1);
                self.level.store(next.index() as u64, Ordering::Relaxed);
                self.promotions += 1;
                return Some((cur, next));
            }
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(trip: u64, clear: u64) -> OverloadConfig {
        OverloadConfig {
            queue_p95_us: 1_000,
            free_block_floor: 2,
            trip_steps: trip,
            clear_steps: clear,
            retry_after_ms: 250,
        }
    }

    fn hot() -> Pressure {
        Pressure {
            queue_wait_p95_us: 5_000,
            free_blocks: 100,
            budget_saturated: false,
        }
    }

    fn calm() -> Pressure {
        Pressure {
            queue_wait_p95_us: 10,
            free_blocks: 100,
            budget_saturated: false,
        }
    }

    #[test]
    fn descends_one_rung_per_trip_window() {
        let mut l = OverloadLadder::new(cfg(3, 4));
        assert_eq!(l.level(), ShedLevel::Normal);
        assert!(l.tick(&hot()).is_none());
        assert!(l.tick(&hot()).is_none());
        assert_eq!(
            l.tick(&hot()),
            Some((ShedLevel::Normal, ShedLevel::Throttle))
        );
        // Streak resets: two more hot ticks are not enough.
        assert!(l.tick(&hot()).is_none());
        assert!(l.tick(&hot()).is_none());
        assert_eq!(
            l.tick(&hot()),
            Some((ShedLevel::Throttle, ShedLevel::ShedBatch))
        );
        assert_eq!(l.demotions(), 2);
    }

    #[test]
    fn saturates_at_shed_interactive() {
        let mut l = OverloadLadder::new(cfg(1, 4));
        for _ in 0..10 {
            l.tick(&hot());
        }
        assert_eq!(l.level(), ShedLevel::ShedInteractive);
        assert_eq!(l.demotions(), 3);
    }

    #[test]
    fn recovery_retraces_rung_by_rung_with_hysteresis() {
        let mut l = OverloadLadder::new(cfg(1, 3));
        l.tick(&hot());
        l.tick(&hot());
        assert_eq!(l.level(), ShedLevel::ShedBatch);
        // Two calm ticks: not enough to clear.
        assert!(l.tick(&calm()).is_none());
        assert!(l.tick(&calm()).is_none());
        // A hot blip resets the calm streak.
        l.tick(&hot());
        assert_eq!(l.level(), ShedLevel::ShedInteractive); // trip=1 descends
        for _ in 0..2 {
            assert!(l.tick(&calm()).is_none());
        }
        assert_eq!(
            l.tick(&calm()),
            Some((ShedLevel::ShedInteractive, ShedLevel::ShedBatch))
        );
        for _ in 0..2 {
            assert!(l.tick(&calm()).is_none());
        }
        assert_eq!(
            l.tick(&calm()),
            Some((ShedLevel::ShedBatch, ShedLevel::Throttle))
        );
        for _ in 0..2 {
            assert!(l.tick(&calm()).is_none());
        }
        assert_eq!(
            l.tick(&calm()),
            Some((ShedLevel::Throttle, ShedLevel::Normal))
        );
        assert_eq!(l.level(), ShedLevel::Normal);
        assert_eq!(l.promotions(), 3);
    }

    #[test]
    fn admits_by_class_per_rung() {
        let mut l = OverloadLadder::new(cfg(1, 100));
        assert!(l.admits(Priority::Batch));
        l.tick(&hot()); // Throttle: still admits everything
        assert!(l.admits(Priority::Batch));
        assert!(l.admits(Priority::Interactive));
        l.tick(&hot()); // ShedBatch
        assert!(!l.admits(Priority::Batch));
        assert!(l.admits(Priority::Normal));
        assert!(l.admits(Priority::Interactive));
        l.tick(&hot()); // ShedInteractive
        assert!(!l.admits(Priority::Interactive));
    }

    #[test]
    fn any_hot_signal_trips() {
        for p in [
            Pressure {
                queue_wait_p95_us: 5_000,
                free_blocks: 100,
                budget_saturated: false,
            },
            Pressure {
                queue_wait_p95_us: 0,
                free_blocks: 1,
                budget_saturated: false,
            },
            Pressure {
                queue_wait_p95_us: 0,
                free_blocks: 100,
                budget_saturated: true,
            },
        ] {
            let mut l = OverloadLadder::new(cfg(1, 4));
            assert!(l.tick(&p).is_some(), "signal {p:?} must trip");
        }
    }
}
