//! Precompute table manager: the paper's runtime half (S10).
//!
//! The offline pass (python `precompute.py`, or `firstlayer precompute`
//! re-running the `precompute_build` artifact) stores, for every vocab
//! token, the first layer's `[q | k | v | r]` row of `2(d+e)` f32 values.
//! At serving time the embedding lookup of the first layer becomes
//! [`Table::gather`]: one contiguous row read per token — exactly the
//! memory operation the paper counts.
//!
//! The file is mmap'd read-only; rows are 4-byte aligned and row-major, so
//! a gather is `B` memcpys of `row_width * 4` bytes.

mod table;

pub use table::{Table, TableHeader, ARCH_PARALLEL, ARCH_SERIAL};

use crate::config::ModelConfig;
use crate::error::{Error, Result};

/// Max absolute element difference between two same-shape tables (used to
/// compare a PJRT-rebuilt table against the shipped one: different compiler
/// stacks need not be bit-identical, but must agree numerically).
pub fn max_abs_diff(a: &Table, b: &Table) -> Result<f32> {
    if a.vocab() != b.vocab() || a.row_width() != b.row_width() {
        return Err(Error::Table("shape mismatch".into()));
    }
    let mut worst = 0f32;
    let tokens: Vec<u32> = (0..a.vocab() as u32).collect();
    let ra = a.gather_vec(&tokens)?;
    let rb = b.gather_vec(&tokens)?;
    for (x, y) in ra.iter().zip(&rb) {
        worst = worst.max((x - y).abs());
    }
    Ok(worst)
}

/// Validate a loaded table against the model config + manifest CRC.
pub fn validate_table(table: &Table, cfg: &ModelConfig, expect_crc: u32) -> Result<()> {
    let h = table.header();
    if h.vocab as usize != cfg.vocab_size {
        return Err(Error::Table(format!(
            "vocab mismatch: table {} vs config {}",
            h.vocab, cfg.vocab_size
        )));
    }
    if h.row_width as usize != cfg.precomp_row_width() {
        return Err(Error::Table(format!(
            "row width mismatch: table {} vs config {}",
            h.row_width,
            cfg.precomp_row_width()
        )));
    }
    if h.d as usize != cfg.d || h.e as usize != cfg.e() {
        return Err(Error::Table("d/e mismatch".into()));
    }
    let want_arch = match cfg.arch {
        crate::config::Arch::Parallel => ARCH_PARALLEL,
        crate::config::Arch::Serial => ARCH_SERIAL,
    };
    if h.arch != want_arch {
        return Err(Error::Table("arch mismatch".into()));
    }
    if h.weights_crc != expect_crc {
        return Err(Error::Table(format!(
            "weights CRC mismatch: table {:#010x} vs manifest {:#010x} — \
             table was built from different weights",
            h.weights_crc, expect_crc
        )));
    }
    Ok(())
}
