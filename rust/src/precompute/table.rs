//! `.fpt` table file: header + mmap'd row store.
//!
//! The byte-level format (44-byte little-endian header, f32 row payload,
//! `row_width = 2(d+e)`, CRC rules) is specified normatively in
//! `docs/fpt-format.md`; the writer is `python/compile/precompute.py`.
//! Keep all three in lockstep.

use std::path::Path;

use crate::error::{Error, Result};

pub const MAGIC: &[u8; 4] = b"FPT1";
pub const ARCH_PARALLEL: u32 = 0;
pub const ARCH_SERIAL: u32 = 1;
const HEADER_SIZE: usize = 4 + 4 * 6 + 8 + 4 + 4; // see python precompute.py

/// Parsed `.fpt` header.
#[derive(Debug, Clone, Copy)]
pub struct TableHeader {
    pub version: u32,
    pub arch: u32,
    pub d: u32,
    pub e: u32,
    pub vocab: u32,
    pub dtype: u32,
    pub row_width: u64,
    pub weights_crc: u32,
}

enum Backing {
    /// Read-only mmap of the file (zero-copy rows).
    Mmap { ptr: *const u8, len: usize },
    /// Heap copy (used for tables built in memory / tests).
    Owned(Vec<u8>),
}

// The mmap is read-only and lives as long as the Table.
unsafe impl Send for Backing {}
unsafe impl Sync for Backing {}

/// The precompute table: `vocab` rows of `row_width` f32 values.
pub struct Table {
    header: TableHeader,
    backing: Backing,
    /// Byte offset of row 0 within the backing.
    data_off: usize,
}

impl Drop for Table {
    fn drop(&mut self) {
        if let Backing::Mmap { ptr, len } = self.backing {
            unsafe {
                libc::munmap(ptr as *mut libc::c_void, len);
            }
        }
    }
}

fn parse_header(bytes: &[u8]) -> Result<TableHeader> {
    if bytes.len() < HEADER_SIZE {
        return Err(Error::Table("file shorter than header".into()));
    }
    if &bytes[0..4] != MAGIC {
        return Err(Error::Table("bad magic".into()));
    }
    let u32_at = |off: usize| {
        u32::from_le_bytes([bytes[off], bytes[off + 1], bytes[off + 2], bytes[off + 3]])
    };
    let u64_at = |off: usize| {
        let mut b = [0u8; 8];
        b.copy_from_slice(&bytes[off..off + 8]);
        u64::from_le_bytes(b)
    };
    let h = TableHeader {
        version: u32_at(4),
        arch: u32_at(8),
        d: u32_at(12),
        e: u32_at(16),
        vocab: u32_at(20),
        dtype: u32_at(24),
        row_width: u64_at(28),
        weights_crc: u32_at(36),
    };
    if h.version != 1 {
        return Err(Error::Table(format!("unsupported version {}", h.version)));
    }
    if h.dtype != 0 {
        return Err(Error::Table("only f32 tables supported".into()));
    }
    if h.row_width != 2 * (h.d + h.e) as u64 {
        return Err(Error::Table(format!(
            "row_width {} != 2(d+e) = {}",
            h.row_width,
            2 * (h.d + h.e)
        )));
    }
    Ok(h)
}

impl Table {
    /// mmap the file read-only.  The paper's "parameter memory" residency:
    /// the table is paged in on demand and shared across processes.
    pub fn open(path: impl AsRef<Path>) -> Result<Table> {
        let path = path.as_ref();
        let file = std::fs::File::open(path)
            .map_err(|e| Error::Table(format!("{}: {e}", path.display())))?;
        let len = file.metadata()?.len() as usize;
        let mut head = vec![0u8; HEADER_SIZE.min(len)];
        use std::io::Read;
        (&file).read_exact(&mut head)?;
        let header = parse_header(&head)?;
        let expect = HEADER_SIZE + header.vocab as usize * header.row_width as usize * 4;
        if len != expect {
            return Err(Error::Table(format!(
                "file size {len} != expected {expect}"
            )));
        }
        use std::os::unix::io::AsRawFd;
        let ptr = unsafe {
            libc::mmap(
                std::ptr::null_mut(),
                len,
                libc::PROT_READ,
                libc::MAP_PRIVATE,
                file.as_raw_fd(),
                0,
            )
        };
        if ptr == libc::MAP_FAILED {
            return Err(Error::Table("mmap failed".into()));
        }
        Ok(Table {
            header,
            backing: Backing::Mmap {
                ptr: ptr as *const u8,
                len,
            },
            data_off: HEADER_SIZE,
        })
    }

    /// Build an in-memory table (used by `firstlayer precompute` when
    /// rebuilding via the PJRT artifact, and by tests).
    pub fn from_rows(
        arch: u32,
        d: u32,
        e: u32,
        weights_crc: u32,
        rows: &[f32],
        vocab: u32,
    ) -> Result<Table> {
        let row_width = 2 * (d + e) as u64;
        if rows.len() as u64 != vocab as u64 * row_width {
            return Err(Error::Table(format!(
                "rows len {} != vocab {} * width {}",
                rows.len(),
                vocab,
                row_width
            )));
        }
        let mut bytes = Vec::with_capacity(HEADER_SIZE + rows.len() * 4);
        bytes.extend_from_slice(MAGIC);
        for v in [1u32, arch, d, e, vocab, 0u32] {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        bytes.extend_from_slice(&row_width.to_le_bytes());
        bytes.extend_from_slice(&weights_crc.to_le_bytes());
        bytes.extend_from_slice(&0u32.to_le_bytes());
        for v in rows {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        let header = parse_header(&bytes)?;
        Ok(Table {
            header,
            backing: Backing::Owned(bytes),
            data_off: HEADER_SIZE,
        })
    }

    /// Persist (for `firstlayer precompute --out`).
    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        std::fs::write(path.as_ref(), self.bytes())?;
        Ok(())
    }

    fn bytes(&self) -> &[u8] {
        match &self.backing {
            Backing::Mmap { ptr, len } => unsafe { std::slice::from_raw_parts(*ptr, *len) },
            Backing::Owned(v) => v,
        }
    }

    pub fn header(&self) -> &TableHeader {
        &self.header
    }

    pub fn row_width(&self) -> usize {
        self.header.row_width as usize
    }

    pub fn vocab(&self) -> usize {
        self.header.vocab as usize
    }

    /// Total table bytes (the paper's memory-size accounting).
    pub fn data_bytes(&self) -> usize {
        self.vocab() * self.row_width() * 4
    }

    /// One row as raw bytes — a single `2(d+e)·4`-byte read.
    pub fn row_bytes(&self, token: u32) -> Result<&[u8]> {
        if token >= self.header.vocab {
            return Err(Error::Table(format!(
                "token {token} out of range (vocab {})",
                self.header.vocab
            )));
        }
        let w = self.row_width() * 4;
        let start = self.data_off + token as usize * w;
        Ok(&self.bytes()[start..start + w])
    }

    /// Gather rows for a token batch into `out` (len `tokens.len() * width`).
    /// This is the serving hot path: `B` contiguous memcpys.
    pub fn gather(&self, tokens: &[u32], out: &mut [f32]) -> Result<()> {
        let w = self.row_width();
        if out.len() != tokens.len() * w {
            return Err(Error::Table(format!(
                "gather out len {} != {}*{w}",
                out.len(),
                tokens.len()
            )));
        }
        for (i, &t) in tokens.iter().enumerate() {
            let src = self.row_bytes(t)?;
            // f32 LE on a LE host: byte copy is the value copy.
            let dst = &mut out[i * w..(i + 1) * w];
            let dst_bytes = unsafe {
                std::slice::from_raw_parts_mut(dst.as_mut_ptr() as *mut u8, w * 4)
            };
            dst_bytes.copy_from_slice(src);
        }
        Ok(())
    }

    /// Allocating convenience wrapper around [`Table::gather`].
    pub fn gather_vec(&self, tokens: &[u32]) -> Result<Vec<f32>> {
        let mut out = vec![0f32; tokens.len() * self.row_width()];
        self.gather(tokens, &mut out)?;
        Ok(out)
    }

    /// CRC32 of the row payload (integrity self-check, `firstlayer selfcheck`).
    pub fn payload_crc(&self) -> u32 {
        let mut h = crc32fast::Hasher::new();
        h.update(&self.bytes()[self.data_off..]);
        h.finalize()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk_table() -> Table {
        // d=2, e=1 -> width 6; vocab 4.
        let rows: Vec<f32> = (0..24).map(|i| i as f32).collect();
        Table::from_rows(ARCH_SERIAL, 2, 1, 0xDEAD, &rows, 4).unwrap()
    }

    #[test]
    fn header_fields() {
        let t = mk_table();
        assert_eq!(t.row_width(), 6);
        assert_eq!(t.vocab(), 4);
        assert_eq!(t.header().weights_crc, 0xDEAD);
        assert_eq!(t.data_bytes(), 96);
    }

    #[test]
    fn gather_exact_rows() {
        let t = mk_table();
        let out = t.gather_vec(&[2, 0, 2]).unwrap();
        assert_eq!(&out[0..6], &[12., 13., 14., 15., 16., 17.]);
        assert_eq!(&out[6..12], &[0., 1., 2., 3., 4., 5.]);
        assert_eq!(&out[12..18], &out[0..6]);
    }

    #[test]
    fn out_of_range_token() {
        let t = mk_table();
        assert!(t.gather_vec(&[4]).is_err());
    }

    #[test]
    fn save_open_roundtrip() {
        let t = mk_table();
        let p = std::env::temp_dir().join("fl_table_test.fpt");
        t.save(&p).unwrap();
        let t2 = Table::open(&p).unwrap();
        assert_eq!(t2.row_width(), 6);
        assert_eq!(t2.gather_vec(&[3]).unwrap(), t.gather_vec(&[3]).unwrap());
        assert_eq!(t2.payload_crc(), t.payload_crc());
    }

    #[test]
    fn truncated_file_rejected() {
        let t = mk_table();
        let p = std::env::temp_dir().join("fl_table_trunc.fpt");
        t.save(&p).unwrap();
        let data = std::fs::read(&p).unwrap();
        std::fs::write(&p, &data[..data.len() - 4]).unwrap();
        assert!(Table::open(&p).is_err());
    }

    #[test]
    fn bad_width_rejected() {
        let rows: Vec<f32> = vec![0.0; 24];
        // d=2,e=2 -> width 8, but 24 = 4*6 mismatches vocab*width = 32.
        assert!(Table::from_rows(ARCH_SERIAL, 2, 2, 0, &rows, 4).is_err());
    }
}
