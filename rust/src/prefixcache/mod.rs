//! Cross-request prefix cache (S16): token-level radix tree over
//! ref-counted [`PagedKvCache`] blocks.
//!
//! Millions of users share system prompts and few-shot templates; without
//! reuse their KV is recomputed per request.  This module keeps finished
//! requests' prompt KV alive, keyed by token content, so a later request
//! with the same prefix forks the blocks instead of re-prefilling them —
//! and because the chunked-prefill scheduler executes `start > 0` chunks
//! through the table-served `decode_span` path, a hit skips both the
//! attention compute *and* the first-layer table gather for the cached
//! span.
//!
//! **Granularity.**  Matching is block-granular: one radix-tree node per
//! full KV block (`block_tokens` tokens), children keyed by the child
//! block's exact token content.  A prefix matches only through blocks
//! whose every token agrees, which is precisely the granule the paged
//! allocator can share without copy-on-write (full blocks are never
//! written again — appends only touch positions `>= len`, and a cached
//! prefix is always block-aligned).  A match never covers the whole
//! prompt: at least one token is left to prefill so the final chunk
//! produces the first-token logits.
//!
//! **Lifecycle.**  `match_prefix` on submit (the coordinator forks the
//! returned blocks into the new sequence), `insert` on finish.  The
//! inserted token path covers the prompt **and the block-aligned
//! generated span** — every token whose K/V row landed in the paged
//! store (the tree is keyed by token content and KV depends only on the
//! token prefix, so generated rows are as reusable as prompt rows).
//! This is what serves multi-turn chat: an assistant turn's KV becomes
//! the next request's cached prefix, so each turn re-prefills only the
//! new user delta.  Leases are real allocator refcounts
//! ([`PagedKvCache::lease_block`]), so the free list, the sequences and
//! the cache always partition the pool —
//! `PagedKvCache::check_invariants` covers all three.
//!
//! **Eviction.**  LRU over *evictable* nodes.  A node is evictable when
//! its block's refcount is exactly 1 (only the cache's lease: no live
//! sequence shares it) — in-use nodes are pinned by construction, which
//! is how eviction coordinates with scheduler preemption: preempting a
//! sequence releases its fork refs and thereby *unpins* the cached
//! prefix, it never yanks KV out from under a running sequence.  A
//! refcount-1 node can have no pinned descendant (any sequence sharing a
//! child block shares its whole prefix, including this block), so
//! leaf-first LRU eviction always makes progress.  The coordinator
//! evicts on demand: the scheduler plans against `free + evictable`, and
//! `evict_for` releases exactly the shortfall before execution.
//!
//! Both per-step quantities are cheap by construction: `evictable` is an
//! O(1) counter the pool maintains on lease/refcount transitions
//! ([`PagedKvCache::evictable_leased_blocks`]), and victim selection
//! walks an intrusive LRU list from the cold end instead of min-scanning
//! the node arena.  The property test below checks LRU-order
//! equivalence against a stamped oracle on top of the set equivalence.

use std::collections::HashMap;
use std::sync::Arc;

use crate::kvcache::PagedKvCache;

/// Root node index in the arena.
const ROOT: usize = 0;

/// Null link in the intrusive LRU list.
const NONE: usize = usize::MAX;

/// Result of [`PrefixCache::match_prefix`]: the longest cached prefix.
#[derive(Debug, Clone, Default)]
pub struct PrefixMatch {
    /// KV block ids covering the matched prefix, in order.
    pub blocks: Vec<u32>,
    /// Matched prefix length in tokens (`blocks.len() * block_tokens`).
    pub tokens: usize,
}

#[derive(Debug)]
struct Node {
    /// Token content of this node's block (`block_tokens` tokens; empty
    /// for the root, which owns no block).  Shared with the parent's
    /// `children` key — one allocation per node, not two.
    tokens: Arc<[u32]>,
    /// The leased KV block (undefined for the root).
    block: u32,
    parent: usize,
    /// Children keyed by the child block's full token content.
    children: HashMap<Arc<[u32]>, usize>,
    /// LRU clock value of the last match/insert touching this node
    /// (eviction order lives in the intrusive list below; the stamp
    /// remains the in-progress-insert protection token).
    last_used: u64,
    /// Intrusive LRU list links (head = least recent, tail = most
    /// recent; `NONE` terminates).  Every live non-root node is linked.
    lru_prev: usize,
    lru_next: usize,
}

/// The radix tree.  One instance per [`PagedKvCache`]; all block
/// refcounting goes through the cache passed into each call (the tree
/// itself never owns the pool, so the coordinator keeps a single
/// mutable `PagedKvCache`).
#[derive(Debug)]
pub struct PrefixCache {
    block_tokens: usize,
    /// Capacity in blocks (the coordinator sizes this off
    /// `ServingConfig::prefix_cache_blocks` / the zoo default).
    max_blocks: usize,
    nodes: Vec<Option<Node>>,
    free_nodes: Vec<usize>,
    /// Blocks currently leased (live non-root nodes).
    held: usize,
    clock: u64,
    /// Intrusive LRU list ends (`NONE` when empty): eviction walks from
    /// `lru_head` instead of min-scanning the arena.
    lru_head: usize,
    lru_tail: usize,
}

impl PrefixCache {
    /// `block_tokens` must match the paged cache; `max_blocks >= 1`.
    pub fn new(block_tokens: usize, max_blocks: usize) -> PrefixCache {
        assert!(block_tokens >= 1, "prefix cache needs block_tokens >= 1");
        assert!(max_blocks >= 1, "prefix cache needs capacity >= 1 block");
        PrefixCache {
            block_tokens,
            max_blocks,
            nodes: vec![Some(Node {
                tokens: Vec::new().into(),
                block: u32::MAX,
                parent: ROOT,
                children: HashMap::new(),
                last_used: 0,
                lru_prev: NONE,
                lru_next: NONE,
            })],
            free_nodes: Vec::new(),
            held: 0,
            clock: 0,
            lru_head: NONE,
            lru_tail: NONE,
        }
    }

    /// Remove node `i` from the LRU list (it must be linked).
    fn lru_unlink(&mut self, i: usize) {
        let (prev, next) = {
            let n = self.node(i);
            (n.lru_prev, n.lru_next)
        };
        if prev == NONE {
            self.lru_head = next;
        } else {
            self.node_mut(prev).lru_next = next;
        }
        if next == NONE {
            self.lru_tail = prev;
        } else {
            self.node_mut(next).lru_prev = prev;
        }
        let n = self.node_mut(i);
        n.lru_prev = NONE;
        n.lru_next = NONE;
    }

    /// Append node `i` (currently unlinked) at the most-recent end.
    fn lru_push_mru(&mut self, i: usize) {
        let tail = self.lru_tail;
        {
            let n = self.node_mut(i);
            n.lru_prev = tail;
            n.lru_next = NONE;
        }
        if tail == NONE {
            self.lru_head = i;
        } else {
            self.node_mut(tail).lru_next = i;
        }
        self.lru_tail = i;
    }

    /// Mark node `i` most-recently used.
    fn lru_touch(&mut self, i: usize) {
        if self.lru_tail == i {
            return;
        }
        self.lru_unlink(i);
        self.lru_push_mru(i);
    }

    /// Blocks currently held (leased) by the tree.
    pub fn held_blocks(&self) -> usize {
        self.held
    }

    pub fn max_blocks(&self) -> usize {
        self.max_blocks
    }

    pub fn block_tokens(&self) -> usize {
        self.block_tokens
    }

    fn node(&self, i: usize) -> &Node {
        self.nodes[i].as_ref().expect("live node")
    }

    fn node_mut(&mut self, i: usize) -> &mut Node {
        self.nodes[i].as_mut().expect("live node")
    }

    /// Longest cached block-aligned prefix of `prompt`, capped at
    /// `prompt.len() - 1` tokens (at least one token must remain for
    /// the final prefill chunk to produce logits).  Touches the matched
    /// path's LRU stamps.
    pub fn match_prefix(&mut self, prompt: &[u32]) -> PrefixMatch {
        self.clock += 1;
        let clock = self.clock;
        let path = self.walk_prefix(prompt);
        let mut blocks = Vec::with_capacity(path.len());
        // Root-to-leaf touch order leaves the deepest node most recent,
        // matching the stamp ordering.
        for &i in &path {
            let n = self.node_mut(i);
            n.last_used = clock;
            blocks.push(n.block);
            self.lru_touch(i);
        }
        let tokens = blocks.len() * self.block_tokens;
        PrefixMatch { blocks, tokens }
    }

    /// [`PrefixCache::match_prefix`] without the LRU side effects — for
    /// diagnostics and tests that must probe the tree without promoting
    /// entries.
    pub fn match_prefix_peek(&self, prompt: &[u32]) -> PrefixMatch {
        let path = self.walk_prefix(prompt);
        let blocks: Vec<u32> = path.iter().map(|&i| self.node(i).block).collect();
        let tokens = blocks.len() * self.block_tokens;
        PrefixMatch { blocks, tokens }
    }

    /// The single traversal core behind both matchers: node indices of
    /// the longest cached block-aligned prefix, capped at
    /// `prompt.len() - 1` tokens.
    fn walk_prefix(&self, prompt: &[u32]) -> Vec<usize> {
        let bt = self.block_tokens;
        let max_granules = prompt.len().saturating_sub(1) / bt;
        let mut at = ROOT;
        let mut path = Vec::new();
        for g in 0..max_granules {
            let key = &prompt[g * bt..(g + 1) * bt];
            match self.node(at).children.get(key) {
                Some(&child) => {
                    path.push(child);
                    at = child;
                }
                None => break,
            }
        }
        path
    }

    /// Insert the block-aligned prefix of `prompt` into the tree,
    /// leasing the corresponding entries of `seq_blocks` (the finishing
    /// sequence's block table, position-ordered).  Granules already
    /// cached keep their existing block (the duplicate is simply not
    /// leased and is freed when the sequence is removed).  Stops early —
    /// keeping the tree prefix-closed — when capacity cannot be made by
    /// evicting unpinned LRU nodes.  Returns the number of blocks newly
    /// leased.
    pub fn insert(
        &mut self,
        prompt: &[u32],
        seq_blocks: &[u32],
        kv: &mut PagedKvCache,
    ) -> usize {
        let bt = self.block_tokens;
        let full = (prompt.len() / bt).min(seq_blocks.len());
        self.clock += 1;
        let clock = self.clock;
        let mut at = ROOT;
        let mut inserted = 0;
        for g in 0..full {
            let key = &prompt[g * bt..(g + 1) * bt];
            if let Some(&child) = self.node(at).children.get(key) {
                self.node_mut(child).last_used = clock;
                self.lru_touch(child);
                at = child;
                continue;
            }
            // Make room.  Nodes touched or created this call carry the
            // current clock and are excluded, so eviction can never
            // cannibalize the path being walked/built (newly inserted
            // nodes are additionally pinned: the finishing sequence
            // still holds its blocks).
            while self.held >= self.max_blocks {
                if self.evict_lru(kv, Some(clock)).is_none() {
                    return inserted;
                }
            }
            let block = seq_blocks[g];
            kv.lease_block(block);
            let key: Arc<[u32]> = key.into();
            let node = Node {
                tokens: key.clone(),
                block,
                parent: at,
                children: HashMap::new(),
                last_used: clock,
                lru_prev: NONE,
                lru_next: NONE,
            };
            let id = match self.free_nodes.pop() {
                Some(slot) => {
                    self.nodes[slot] = Some(node);
                    slot
                }
                None => {
                    self.nodes.push(Some(node));
                    self.nodes.len() - 1
                }
            };
            self.node_mut(at).children.insert(key, id);
            self.lru_push_mru(id);
            self.held += 1;
            inserted += 1;
            at = id;
        }
        inserted
    }

    /// Blocks reclaimable right now: live nodes whose block refcount is
    /// 1 (the lease alone — no sequence shares it).  The coordinator
    /// adds this to the scheduler's free-block view every step, so this
    /// is O(1): the pool maintains the count on lease/refcount
    /// transitions ([`PagedKvCache::evictable_leased_blocks`]) — all
    /// leases are this tree's, one per live node.
    pub fn evictable_blocks(&self, kv: &PagedKvCache) -> usize {
        if self.held == 0 {
            return 0;
        }
        kv.evictable_leased_blocks()
    }

    /// Evict the least-recently-used unpinned leaf, releasing its lease.
    /// Returns the evicted prefix (root-to-node token path) and block,
    /// or `None` when nothing is evictable.  Leaf-first is safe *and*
    /// complete: an unpinned interior node (refcount 1) can have no
    /// pinned descendant, so repeated calls drain whole unpinned chains.
    pub fn evict_one(&mut self, kv: &mut PagedKvCache) -> Option<(Vec<u32>, u32)> {
        self.evict_lru(kv, None)
    }

    /// LRU eviction core: walk the intrusive list from the
    /// least-recently-used end and take the first evictable leaf — no
    /// arena min-scan.  Pinned and interior nodes cluster near the
    /// recent end in practice (matching re-touches whole paths), so the
    /// walk is typically O(1).  `protect_clock` excludes nodes stamped
    /// with that clock value — the path an in-progress `insert` is
    /// standing on.
    fn evict_lru(
        &mut self,
        kv: &mut PagedKvCache,
        protect_clock: Option<u64>,
    ) -> Option<(Vec<u32>, u32)> {
        let mut at = self.lru_head;
        let i = loop {
            if at == NONE {
                return None;
            }
            let n = self.node(at);
            if n.children.is_empty()
                && kv.block_refcount(n.block) == 1
                && protect_clock != Some(n.last_used)
            {
                break at;
            }
            at = n.lru_next;
        };
        let path = self.path_tokens(i);
        let (parent, key, block) = {
            let n = self.node(i);
            (n.parent, n.tokens.clone(), n.block)
        };
        self.lru_unlink(i);
        self.node_mut(parent).children.remove(&key[..]);
        self.nodes[i] = None;
        self.free_nodes.push(i);
        self.held -= 1;
        kv.unlease_block(block);
        Some((path, block))
    }

    /// Evict until the pool has at least `target_free` free blocks (or
    /// nothing evictable remains).  Returns the number evicted.
    pub fn evict_for(&mut self, kv: &mut PagedKvCache, target_free: usize) -> usize {
        let mut evicted = 0;
        while kv.free_blocks() < target_free {
            if self.evict_one(kv).is_none() {
                break;
            }
            evicted += 1;
        }
        evicted
    }

    /// Full token path from the root down to node `i`.
    fn path_tokens(&self, i: usize) -> Vec<u32> {
        let mut rev: Vec<usize> = Vec::new();
        let mut at = i;
        while at != ROOT {
            rev.push(at);
            at = self.node(at).parent;
        }
        let mut out = Vec::with_capacity(rev.len() * self.block_tokens);
        for &n in rev.iter().rev() {
            out.extend_from_slice(&self.node(n).tokens);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;
    use std::collections::{HashMap, HashSet};

    const BT: usize = 4;

    fn kv(total: usize) -> PagedKvCache {
        // `BT`-token blocks; 1 layer, kh*hd = 2 keeps appends cheap.
        PagedKvCache::new(total, BT, 1, 1, 2)
    }

    /// Materialize a prompt as a real sequence (zero-valued KV rows) and
    /// return its block table.
    fn grow_seq(kv: &mut PagedKvCache, id: u64, prompt: &[u32]) -> Vec<u32> {
        kv.create(id, 1).unwrap();
        let row = vec![0f32; 2];
        for _ in prompt {
            kv.append(id, &row, &row).unwrap();
        }
        kv.seq_blocks(id).unwrap().to_vec()
    }

    #[test]
    fn match_is_block_granular_and_never_whole_prompt() {
        let mut kv = kv(16);
        let mut pc = PrefixCache::new(BT, 16);
        let prompt: Vec<u32> = (0..12).collect();
        let blocks = grow_seq(&mut kv, 1, &prompt);
        assert_eq!(pc.insert(&prompt, &blocks, &mut kv), 3);
        kv.remove(1).unwrap();
        kv.check_invariants().unwrap();

        // Exact prompt: capped at len-1 -> 2 of 3 blocks match.
        let m = pc.match_prefix(&prompt);
        assert_eq!(m.tokens, 8);
        assert_eq!(m.blocks.len(), 2);
        // Longer prompt with same prefix: all 3 cached blocks match.
        let mut longer = prompt.clone();
        longer.extend([90, 91, 92]);
        assert_eq!(pc.match_prefix(&longer).tokens, 12);
        // One token differs inside block 2: only block 1 matches.
        let mut diverged = prompt.clone();
        diverged[5] = 99;
        assert_eq!(pc.match_prefix(&diverged).tokens, 4);
        // Shorter than one block: no match possible.
        assert_eq!(pc.match_prefix(&prompt[..3]).tokens, 0);
    }

    /// The multi-turn chat shape: insert a finished turn's FULL token
    /// path (prompt + generated span), then match the next turn's
    /// prompt — the whole prior transcript is served, so only the new
    /// user delta would prefill.
    #[test]
    fn generated_span_serves_next_turn() {
        let mut kv = kv(16);
        let mut pc = PrefixCache::new(BT, 16);
        // Turn 1: 6-token prompt + 6 generated tokens with KV rows.
        let prompt: Vec<u32> = (0..6).collect();
        let generated: Vec<u32> = (100..106).collect();
        let mut transcript = prompt.clone();
        transcript.extend_from_slice(&generated);
        let blocks = grow_seq(&mut kv, 1, &transcript);
        // 12 tokens = 3 full 4-token blocks, generated span included.
        assert_eq!(pc.insert(&transcript, &blocks, &mut kv), 3);
        kv.remove(1).unwrap();
        // Turn 2: transcript + new user delta matches all 3 blocks.
        let mut next = transcript.clone();
        next.extend([7, 8, 9]);
        let m = pc.match_prefix(&next);
        assert_eq!(m.tokens, 12, "prior transcript must be fully served");
        assert_eq!(m.blocks.len(), 3);
        kv.check_invariants().unwrap();
    }

    #[test]
    fn shared_prefix_inserts_once() {
        let mut kv = kv(16);
        let mut pc = PrefixCache::new(BT, 16);
        let a: Vec<u32> = (0..8).collect();
        let mut b = a.clone();
        b.extend([50, 51, 52, 53]);
        let ba = grow_seq(&mut kv, 1, &a);
        assert_eq!(pc.insert(&a, &ba, &mut kv), 2);
        kv.remove(1).unwrap();
        let bb = grow_seq(&mut kv, 2, &b);
        // First two granules already cached: only the third leases.
        assert_eq!(pc.insert(&b, &bb, &mut kv), 1);
        kv.remove(2).unwrap();
        assert_eq!(pc.held_blocks(), 3);
        kv.check_invariants().unwrap();
        // The duplicate blocks from seq 2's prefix went back to the pool.
        assert_eq!(kv.free_blocks(), 16 - 3);
    }

    #[test]
    fn pinned_nodes_survive_eviction() {
        let mut kv = kv(16);
        let mut pc = PrefixCache::new(BT, 16);
        let a: Vec<u32> = (0..8).collect();
        let ba = grow_seq(&mut kv, 1, &a);
        pc.insert(&a, &ba, &mut kv);
        kv.remove(1).unwrap();
        // Fork the cached prefix into a live sequence: both blocks pinned.
        let m = pc.match_prefix(&[0, 1, 2, 3, 4, 5, 6, 7, 99]);
        assert_eq!(m.tokens, 8);
        kv.create_shared(7, &m.blocks, m.tokens).unwrap();
        assert_eq!(pc.evictable_blocks(&kv), 0);
        assert!(pc.evict_one(&mut kv).is_none());
        // Dropping the sequence unpins; leaf-first LRU then drains both.
        kv.remove(7).unwrap();
        assert_eq!(pc.evictable_blocks(&kv), 2);
        assert!(pc.evict_one(&mut kv).is_some());
        assert!(pc.evict_one(&mut kv).is_some());
        assert_eq!(pc.held_blocks(), 0);
        assert_eq!(kv.free_blocks(), 16);
        kv.check_invariants().unwrap();
    }

    #[test]
    fn capacity_evicts_lru_cold_path() {
        let mut kv = kv(32);
        let mut pc = PrefixCache::new(BT, 2); // room for two granules
        let a = vec![1u32; 4];
        let b = vec![2u32; 4];
        let c = vec![3u32; 4];
        for (id, p) in [(1u64, &a), (2, &b)] {
            let bl = grow_seq(&mut kv, id, p);
            pc.insert(p, &bl, &mut kv);
            kv.remove(id).unwrap();
        }
        // Touch `a` so `b` is the LRU victim.
        assert_eq!(pc.match_prefix(&[1, 1, 1, 1, 9]).tokens, 4);
        let bl = grow_seq(&mut kv, 3, &c);
        pc.insert(&c, &bl, &mut kv);
        kv.remove(3).unwrap();
        assert_eq!(pc.held_blocks(), 2);
        assert_eq!(pc.match_prefix(&[1, 1, 1, 1, 9]).tokens, 4); // kept
        assert_eq!(pc.match_prefix(&[2, 2, 2, 2, 9]).tokens, 0); // evicted
        assert_eq!(pc.match_prefix(&[3, 3, 3, 3, 9]).tokens, 4); // inserted
        kv.check_invariants().unwrap();
    }

    #[test]
    fn evict_for_frees_exactly_the_shortfall() {
        let mut kv = kv(8);
        let mut pc = PrefixCache::new(BT, 8);
        let p: Vec<u32> = (0..24).collect(); // 6 blocks
        let bl = grow_seq(&mut kv, 1, &p);
        pc.insert(&p, &bl, &mut kv);
        kv.remove(1).unwrap();
        assert_eq!(kv.free_blocks(), 2);
        assert_eq!(pc.evict_for(&mut kv, 4), 2);
        assert_eq!(kv.free_blocks(), 4);
        // Already satisfied: no-op.
        assert_eq!(pc.evict_for(&mut kv, 4), 0);
        kv.check_invariants().unwrap();
    }

    /// Property test (in-tree harness, like the kvcache one): random
    /// insert/match/evict against a naive `HashMap<Vec<u32>, u32>`
    /// oracle of cached block-aligned prefixes.  Asserts match lengths
    /// agree with the oracle, pool invariants hold after every op,
    /// ref-counts never leak blocks once everything is torn down, AND —
    /// via a parallel stamp map mirroring every touch — that the
    /// intrusive-list eviction picks a least-recently-used evictable
    /// leaf, exactly like the arena min-scan it replaced.
    #[test]
    fn prop_matches_oracle_and_never_leaks() {
        for seed in 0..25u64 {
            let mut rng = Rng::new(seed);
            let total = 48;
            let mut kv = kv(total);
            let mut pc = PrefixCache::new(BT, rng.range(2, 12));
            // Oracle: cached prefix -> block id at that granule, plus
            // the LRU stamp of the last op that touched it.
            let mut oracle: HashMap<Vec<u32>, u32> = HashMap::new();
            let mut stamps: HashMap<Vec<u32>, u64> = HashMap::new();
            let mut oclock = 0u64;
            let mut next_id = 0u64;
            // A small template pool makes prefix collisions likely.
            let templates: Vec<Vec<u32>> = (0..4)
                .map(|_| (0..BT * 3).map(|_| rng.below(6) as u32).collect())
                .collect();
            let mk_prompt = |rng: &mut Rng| -> Vec<u32> {
                let mut p = templates[rng.range(0, templates.len())]
                    [..rng.range(1, BT * 3 + 1)]
                    .to_vec();
                for _ in 0..rng.range(0, 5) {
                    p.push(rng.below(6) as u32);
                }
                p
            };
            for _ in 0..300 {
                match rng.below(10) {
                    0..=4 => {
                        // Insert: materialize a sequence, cache it, drop it
                        // (the coordinator's insert-on-finish shape).
                        let prompt = mk_prompt(&mut rng);
                        let id = next_id;
                        next_id += 1;
                        if kv.free_blocks() < prompt.len().div_ceil(BT) {
                            continue;
                        }
                        let blocks = grow_seq(&mut kv, id, &prompt);
                        oclock += 1;
                        let n = pc.insert(&prompt, &blocks, &mut kv);
                        // Resync the oracle against the tree: capacity
                        // pressure inside `insert` may have evicted old
                        // entries, and `n` new granules joined.  A path
                        // is cached iff probing it (with one extra token
                        // to sidestep the len-1 cap) matches fully; the
                        // probe must NOT touch the LRU state, hence peek.
                        let cached = |pc: &PrefixCache, key: &[u32]| {
                            let mut probe = key.to_vec();
                            probe.push(0);
                            pc.match_prefix_peek(&probe).tokens >= key.len()
                        };
                        let stale: Vec<Vec<u32>> = oracle.keys().cloned().collect();
                        for k in stale {
                            if !cached(&pc, &k) {
                                oracle.remove(&k);
                            }
                        }
                        let full = prompt.len() / BT;
                        let mut added = 0;
                        for g in 0..full {
                            let key = prompt[..(g + 1) * BT].to_vec();
                            if cached(&pc, &key) {
                                added += usize::from(!oracle.contains_key(&key));
                                oracle.entry(key).or_insert(blocks[g]);
                            }
                        }
                        assert_eq!(added, n, "seed {seed}: insert count drift");
                        // Mirror the insert's LRU touches: the walked
                        // path (existing + created granules, stopping at
                        // the first one insert couldn't place) all carry
                        // this op's stamp.
                        stamps.retain(|k, _| oracle.contains_key(k));
                        for g in 0..full {
                            let key = prompt[..(g + 1) * BT].to_vec();
                            if oracle.contains_key(&key) {
                                stamps.insert(key, oclock);
                            } else {
                                break;
                            }
                        }
                        kv.remove(id).unwrap();
                    }
                    5..=7 => {
                        let prompt = mk_prompt(&mut rng);
                        oclock += 1;
                        let m = pc.match_prefix(&prompt);
                        let mut want = 0;
                        let cap = prompt.len().saturating_sub(1) / BT;
                        for g in 0..cap {
                            if oracle.contains_key(&prompt[..(g + 1) * BT]) {
                                want = (g + 1) * BT;
                            } else {
                                break;
                            }
                        }
                        assert_eq!(
                            m.tokens, want,
                            "seed {seed}: match {} != oracle {want} for {prompt:?}",
                            m.tokens
                        );
                        // Returned blocks agree with the oracle's ids,
                        // and the matched path was LRU-touched.
                        for (g, &b) in m.blocks.iter().enumerate() {
                            let key = prompt[..(g + 1) * BT].to_vec();
                            assert_eq!(oracle[&key], b);
                            stamps.insert(key, oclock);
                        }
                    }
                    _ => {
                        // Expected victim class: evictable leaves (no
                        // cached extension; nothing pinned here — every
                        // grown sequence is removed within its op) with
                        // the minimal stamp.
                        let min_stamp = oracle
                            .keys()
                            .filter(|k| {
                                !oracle.keys().any(|o| {
                                    o.len() == k.len() + BT && o.starts_with(k)
                                })
                            })
                            .map(|k| stamps[k.as_slice()])
                            .min();
                        match pc.evict_one(&mut kv) {
                            Some((path, _)) => {
                                assert!(
                                    oracle.remove(&path).is_some(),
                                    "seed {seed}: evicted {path:?} unknown to oracle"
                                );
                                let vstamp = stamps
                                    .remove(&path)
                                    .expect("victim carries a stamp");
                                assert_eq!(
                                    Some(vstamp),
                                    min_stamp,
                                    "seed {seed}: eviction of {path:?} not LRU"
                                );
                            }
                            None => assert!(
                                min_stamp.is_none(),
                                "seed {seed}: evictable leaf left unevicted"
                            ),
                        }
                    }
                }
                kv.check_invariants()
                    .unwrap_or_else(|e| panic!("seed {seed}: {e}"));
                assert_eq!(
                    pc.held_blocks(),
                    oracle.len(),
                    "seed {seed}: tree size diverged from oracle"
                );
                assert!(pc.held_blocks() <= pc.max_blocks(), "seed {seed}");
                // Nothing is pinned between ops here, so the O(1)
                // evictable counter must equal the tree's full holding.
                assert_eq!(
                    pc.evictable_blocks(&kv),
                    pc.held_blocks(),
                    "seed {seed}: evictable-lease counter drifted"
                );
                // Leased block ids are distinct (no double-lease).
                let ids: HashSet<u32> = oracle.values().copied().collect();
                assert_eq!(ids.len(), oracle.len(), "seed {seed}");
            }
            // Teardown: everything must come back.
            while pc.evict_one(&mut kv).is_some() {}
            assert_eq!(pc.held_blocks(), 0, "seed {seed}: cache not drained");
            assert_eq!(
                kv.free_blocks(),
                total,
                "seed {seed}: blocks leaked through the prefix cache"
            );
            kv.check_invariants().unwrap();
        }
    }
}
