//! Model engine: one loaded model (weights + table + executables) with
//! decode/prefill step entry points for both serving paths.
//!
//! The engine is deliberately *stateless about sequences* — the coordinator
//! owns the paged KV store and batch composition; the engine turns one
//! assembled step into PJRT calls:
//!
//! * weights are uploaded to the device once at construction and reused by
//!   every call (`execute_b`),
//! * `decode` gathers precomputed rows from the mmap'd table (precompute
//!   path) or passes token ids (baseline),
//! * `decode_span` advances one sequence through a chunk of prompt tokens
//!   (chunked prefill), serving the whole span's first layer from the
//!   table in a single batched row-gather and — on the device-resident
//!   path — chaining the whole span through one [`DeviceCacheSession`]
//!   (one cache upload per span, logits-only per-token readback),
//! * returns the logits plus only the *new* K/V rows extracted from the
//!   returned caches, so the paged store is updated with one row per
//!   (layer, sequence) instead of a full-cache writeback.

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use crate::config::ModelConfig;
use crate::error::{Error, Result};
use crate::faults::{FaultPlane, HealthRegistry, InjectPoint, PathId};
use crate::manifest::{ArtifactKind, Manifest, ModelEntry};
use crate::metrics::TransferStats;
use crate::precompute::{validate_table, Table};
use crate::simtraffic::Recorder;
use crate::trace::{Phase, SpanKind, Tracer};
use crate::weights::WeightsFile;

use super::{trace_enabled, DeviceCacheSession, Executable, HostTensor, Runtime};

/// Which serving path a step runs (the paper's comparison axis).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StepPath {
    /// Full first layer from the embedding (Figure 1a / 2b).
    Baseline,
    /// Precomputed first layer: table gather + attention only (Fig 1b / 2c).
    Precompute,
    /// Ablation: precompute with the gather *inside* the graph (the table
    /// lives as a device buffer).
    PrecomputeGather,
}

impl StepPath {
    pub fn label(self) -> &'static str {
        match self {
            StepPath::Baseline => "baseline",
            StepPath::Precompute => "precompute",
            StepPath::PrecomputeGather => "precompute-gather",
        }
    }
}

/// Dense batched KV cache input: `[L, B, S, KH, hd]` f32, row-major.
#[derive(Debug, Clone)]
pub struct CacheBatch {
    pub l: usize,
    pub b: usize,
    pub s: usize,
    pub kh: usize,
    pub hd: usize,
    pub k: Vec<f32>,
    pub v: Vec<f32>,
}

impl CacheBatch {
    pub fn zeros(l: usize, b: usize, s: usize, kh: usize, hd: usize) -> CacheBatch {
        let n = l * b * s * kh * hd;
        CacheBatch {
            l,
            b,
            s,
            kh,
            hd,
            k: vec![0.0; n],
            v: vec![0.0; n],
        }
    }

    pub fn dims(&self) -> [usize; 5] {
        [self.l, self.b, self.s, self.kh, self.hd]
    }

    /// Offset of `[layer, seq, slot, 0, 0]` in a dense cache of `dims`
    /// `[l, b, s, kh, hd]` — the one place the layout math lives, shared
    /// with views that hold only the dims (session syncs, output
    /// unpacking).
    pub fn offset_in(dims: [usize; 5], layer: usize, seq: usize, slot: usize) -> usize {
        let [_, b, s, kh, hd] = dims;
        ((layer * b + seq) * s + slot) * kh * hd
    }

    /// Offset of `[layer, seq, slot, 0, 0]`.
    pub fn offset(&self, layer: usize, seq: usize, slot: usize) -> usize {
        CacheBatch::offset_in(self.dims(), layer, seq, slot)
    }

    /// Slice `n` consecutive slots (`start..start + n`) of batch row
    /// `seq` out of a dense K/V pair laid out per `dims`, into the
    /// token-major `[n, L, KH·hd]` row layout shared by `DecodeOut` /
    /// `SpanOut` / the paged-store writeback.  The one copy of this
    /// extraction loop — the device sync, the span path, and output
    /// unpacking all go through it.
    pub fn extract_rows(
        dims: [usize; 5],
        kc: &[f32],
        vc: &[f32],
        seq: usize,
        start: usize,
        n: usize,
    ) -> (Vec<f32>, Vec<f32>) {
        let [l, _, _, kh, hd] = dims;
        let mut k = vec![0f32; n * l * kh * hd];
        let mut v = vec![0f32; n * l * kh * hd];
        CacheBatch::extract_rows_into(dims, kc, vc, seq, start, n, &mut k, &mut v);
        (k, v)
    }

    /// [`CacheBatch::extract_rows`] into caller-owned row buffers (each
    /// `n · L · KH·hd` long) — the hot host-decode loop writes straight
    /// into its batch output without per-sequence allocations.
    #[allow(clippy::too_many_arguments)]
    pub fn extract_rows_into(
        dims: [usize; 5],
        kc: &[f32],
        vc: &[f32],
        seq: usize,
        start: usize,
        n: usize,
        k_out: &mut [f32],
        v_out: &mut [f32],
    ) {
        let [l, _, _, kh, hd] = dims;
        let row = kh * hd;
        debug_assert_eq!(k_out.len(), n * l * row, "row buffer size mismatch");
        for j in 0..n {
            for li in 0..l {
                let o = CacheBatch::offset_in(dims, li, seq, start + j);
                let dst = (j * l + li) * row;
                k_out[dst..dst + row].copy_from_slice(&kc[o..o + row]);
                v_out[dst..dst + row].copy_from_slice(&vc[o..o + row]);
            }
        }
    }

    /// One (layer, seq, slot) row, `kh*hd` long.
    pub fn row<'a>(
        &self,
        kv: &'a [f32],
        layer: usize,
        seq: usize,
        slot: usize,
    ) -> &'a [f32] {
        let o = self.offset(layer, seq, slot);
        &kv[o..o + self.kh * self.hd]
    }
}

/// Result of one decode step over `n` real sequences.
#[derive(Debug, Clone)]
pub struct DecodeOut {
    /// `[n, vocab]` logits for the sampled next token.
    pub logits: Vec<f32>,
    /// New K rows: `[n, L, kh*hd]` (seq-major for easy page writeback).
    pub new_k: Vec<f32>,
    /// New V rows, same layout.
    pub new_v: Vec<f32>,
    /// The compiled batch bucket that served this step.
    pub bucket: usize,
}

/// Result of a prefill over `n` real sequences.
#[derive(Debug, Clone)]
pub struct PrefillOut {
    /// `[n, vocab]` logits at each sequence's last prompt position.
    pub logits: Vec<f32>,
    /// Full caches `[L, n, S, KH, hd]` (slots < len valid).
    pub caches: CacheBatch,
    pub bucket: (usize, usize),
}

/// Result of advancing ONE sequence through a span of prompt tokens
/// ([`ModelEngine::decode_span`]: chunked-prefill continuations and
/// post-preemption replays).
#[derive(Debug, Clone)]
pub struct SpanOut {
    /// `[vocab]` logits after the last span token.
    pub logits: Vec<f32>,
    /// `[n, vocab]` logits after EVERY span token, token-major — the
    /// draft-verification surface of speculative decoding (position `i`
    /// scores the token following span token `i`).  Populated only by
    /// [`ModelEngine::decode_span_scored`]; plain spans leave it empty
    /// and skip the extra readback.
    pub pos_logits: Vec<f32>,
    /// New K rows for the span: `[n, L, kh*hd]`, token-major append order.
    pub new_k: Vec<f32>,
    /// New V rows, same layout.
    pub new_v: Vec<f32>,
    /// Device executions this span cost: `ceil(S/T)` span-artifact tiles
    /// on the batched path, one per token on the fallback oracle.
    pub executions: usize,
    /// Tokens advanced per execution, in order (feeds the
    /// `span_exec_tokens` histogram).
    pub exec_tokens: Vec<usize>,
    /// Whether the batched span artifact served this span (false = the
    /// token-by-token oracle ran).
    pub batched: bool,
}

/// One lane of a multi-sequence span group
/// ([`ModelEngine::decode_span_group`]): a sequence's continuation chunk
/// plus the absolute position its first token lands on.
#[derive(Debug, Clone)]
pub struct SpanLane<'a> {
    pub tokens: &'a [u32],
    pub start: usize,
}

/// Per-lane result of a grouped span step — the lane-local view of
/// [`SpanGroupOut`], same row layout as [`SpanOut`].
#[derive(Debug, Clone)]
pub struct SpanLaneOut {
    /// `[vocab]` logits after the lane's last span token.
    pub logits: Vec<f32>,
    /// New K rows for the lane's span: `[n, L, kh*hd]`, token-major.
    pub new_k: Vec<f32>,
    /// New V rows, same layout.
    pub new_v: Vec<f32>,
}

/// Result of advancing a GROUP of sequences through the batched
/// `span_*_b{B}_t{T}` artifacts: each tile executes the device ONCE for
/// the whole group instead of once per sequence.
#[derive(Debug, Clone)]
pub struct SpanGroupOut {
    /// Per-lane logits + fresh rows, in the caller's lane order.
    pub lanes: Vec<SpanLaneOut>,
    /// Device executions the group cost (= tiles, NOT lanes · tiles).
    pub executions: usize,
    /// Occupied (non-inert) lanes per execution, in order — feeds the
    /// `span_batch_occupancy` histogram.
    pub occupancy: Vec<usize>,
    /// The compiled batch width that served the group.
    pub batch: usize,
}

struct Loaded {
    exe: Arc<Executable>,
    /// Device-resident weight buffers in artifact parameter order.
    weight_bufs: Vec<Arc<xla::PjRtBuffer>>,
}

/// Greedy span tiling over the compiled buckets (ascending): per tile the
/// smallest bucket covering the remainder (pad-minimal), else the largest;
/// shrunk to whatever still fits the cache capacity `s` (a padded tile
/// writes up to `pos + bucket` slots, and `dynamic_update_slice` would
/// clamp — corrupting history — past the end).  Returns `(bucket, take)`
/// pairs with `take` summing to `n`, or `None` when no compiled bucket
/// fits — the caller then serves the span token-by-token (a capability
/// gap near `max_seq`, not a health event).
fn plan_span_tiles(
    buckets: &[usize],
    n: usize,
    start: usize,
    s: usize,
) -> Option<Vec<(usize, usize)>> {
    if buckets.is_empty() {
        return None;
    }
    let mut tiles = Vec::new();
    let mut done = 0usize;
    while done < n {
        let remaining = n - done;
        let pos = start + done;
        let want = buckets
            .iter()
            .copied()
            .find(|b| *b >= remaining)
            .unwrap_or(*buckets.last().expect("nonempty"));
        let bucket = if pos + want <= s {
            want
        } else {
            buckets.iter().rev().copied().find(|b| pos + *b <= s)?
        };
        let take = bucket.min(remaining);
        tiles.push((bucket, take));
        done += take;
    }
    Some(tiles)
}

/// One loaded model.
pub struct ModelEngine {
    rt: Runtime,
    entry: ModelEntry,
    dir: PathBuf,
    weights: WeightsFile,
    table: Table,
    /// Tensor-name → uploaded device buffer (shared across artifacts).
    buf_by_name: Mutex<HashMap<String, Arc<xla::PjRtBuffer>>>,
    loaded: Mutex<HashMap<String, Arc<Loaded>>>,
    pub traffic: Arc<Recorder>,
    /// Unified path-health ladder (see [`crate::faults::HealthRegistry`]):
    /// per-path config gate + failure-demoted health + cooldown-driven
    /// re-promotion, replacing the three sticky booleans the engine
    /// carried before.  The engine records failures and answers
    /// `*_active()`; the coordinator ticks the cooldown clock once per
    /// step and surfaces demotions/promotions in metrics and trace
    /// instants.  A missing bucket or an unplannable group is a
    /// capability gap, NOT a health event — it must never demote a path.
    health: Arc<HealthRegistry>,
    /// Fault-injection plane shared with the runtime (table row-gathers
    /// are the engine-owned injection point; the runtime owns the rest).
    faults: Arc<FaultPlane>,
    /// Largest span tile serving may use (`ServingConfig::
    /// span_bucket_tokens`; 0 = the largest compiled bucket).
    span_bucket_cap: AtomicUsize,
    /// Cumulative span-artifact executions / spans served token-by-token
    /// (the execution counters the acceptance tests assert against).
    span_execs: AtomicU64,
    span_fallback_count: AtomicU64,
    /// Cumulative grouped-span executions (one per group tile — a subset
    /// of `span_execs`).
    span_batched_execs: AtomicU64,
}

impl ModelEngine {
    pub fn load(rt: &Runtime, manifest: &Manifest, model: &str) -> Result<ModelEngine> {
        let entry = manifest.model(model)?.clone();
        let weights = WeightsFile::load(manifest.path(&entry.weights_file))?;
        // Sanity: every manifest weight tensor exists on disk.
        for name in &entry.weights_order {
            weights.get(name)?;
        }
        let table = Table::open(manifest.path(&entry.table_file))?;
        validate_table(&table, &entry.config, entry.weights_crc)?;
        Ok(ModelEngine {
            rt: rt.clone(),
            entry,
            dir: manifest.dir.clone(),
            weights,
            table,
            buf_by_name: Mutex::new(HashMap::new()),
            loaded: Mutex::new(HashMap::new()),
            traffic: Arc::new(Recorder::new()),
            // Default cooldown matches `ServingConfig::health_cooldown_steps`;
            // the coordinator overrides it from config.  Engine-only users
            // never tick the registry, so demotions stay sticky for them
            // exactly as before.
            health: Arc::new(HealthRegistry::new(256)),
            faults: rt.faults(),
            span_bucket_cap: AtomicUsize::new(0),
            span_execs: AtomicU64::new(0),
            span_fallback_count: AtomicU64::new(0),
            span_batched_execs: AtomicU64::new(0),
        })
    }

    pub fn config(&self) -> &ModelConfig {
        &self.entry.config
    }

    /// The engine's path-health ladder (shared with the coordinator,
    /// which ticks its cooldown clock and surfaces transitions).
    pub fn health(&self) -> Arc<HealthRegistry> {
        self.health.clone()
    }

    /// The fault-injection plane (shared with the runtime; see
    /// [`crate::faults`]).
    pub fn faults(&self) -> Arc<FaultPlane> {
        self.faults.clone()
    }

    /// Enable/disable the device-resident KV path (spans and decode
    /// sessions).  Disabling forces the legacy host path — the
    /// equivalence oracle the integration tests compare against.
    pub fn set_device_kv(&self, on: bool) {
        self.health.set_enabled(PathId::DeviceKv, on);
    }

    /// Whether device-resident execution is both enabled and healthy.
    pub fn device_kv_active(&self) -> bool {
        self.health.active(PathId::DeviceKv)
    }

    /// Record a device-resident-path failure: the path demotes and every
    /// later span/session takes the host path directly instead of
    /// rebuilding a session, failing the same way, and paying for both.
    /// After the registry's cooldown the path is re-promoted and the next
    /// session doubles as the recovery probe.  `set_device_kv(true)` does
    /// NOT clear a demotion — health reflects the runtime's observed
    /// capability, not intent.
    pub fn mark_device_kv_unhealthy(&self) {
        self.health.record_failure(PathId::DeviceKv);
    }

    /// Enable/disable batched span execution.  Disabling forces every
    /// span through the token-by-token oracle — the equivalence baseline
    /// the integration tests and benches compare against.
    pub fn set_span_exec(&self, on: bool) {
        self.health.set_enabled(PathId::SpanExec, on);
    }

    /// Whether batched span execution is both enabled and healthy.
    pub fn span_exec_active(&self) -> bool {
        self.health.active(PathId::SpanExec)
    }

    /// Record a batched-span failure (demotes like the device-KV path):
    /// later spans go token-by-token directly instead of failing the same
    /// way per chunk, until the cooldown re-promotes the path.
    pub fn mark_span_exec_unhealthy(&self) {
        self.health.record_failure(PathId::SpanExec);
    }

    /// Cap the largest span tile serving may use
    /// (`ServingConfig::span_bucket_tokens`; 0 = largest compiled).
    pub fn set_span_bucket_cap(&self, cap: usize) {
        self.span_bucket_cap.store(cap, Ordering::Relaxed);
    }

    /// Cumulative span-artifact executions (one per tile).
    pub fn span_executions(&self) -> u64 {
        self.span_execs.load(Ordering::Relaxed)
    }

    /// Cumulative spans served by the token-by-token fallback.
    pub fn span_fallbacks(&self) -> u64 {
        self.span_fallback_count.load(Ordering::Relaxed)
    }

    /// Enable/disable multi-sequence span grouping.  Disabling forces
    /// every continuation through the per-sequence span path — the
    /// equivalence oracle the batched-serving property test compares
    /// against.  Grouping also requires span execution itself to be on.
    pub fn set_span_batch(&self, on: bool) {
        self.health.set_enabled(PathId::SpanBatch, on);
    }

    /// Whether grouped span execution is enabled and healthy (and span
    /// execution itself is).
    pub fn span_batch_active(&self) -> bool {
        self.span_exec_active() && self.health.active(PathId::SpanBatch)
    }

    /// Record a grouped-span failure (demotes like the other two paths):
    /// later steps go per-sequence directly until the cooldown
    /// re-promotes the group path.
    pub fn mark_span_batch_unhealthy(&self) {
        self.health.record_failure(PathId::SpanBatch);
    }

    /// Cumulative grouped-span executions (one per group tile; a subset
    /// of [`ModelEngine::span_executions`]).
    pub fn span_batched_executions(&self) -> u64 {
        self.span_batched_execs.load(Ordering::Relaxed)
    }

    /// Enable/disable server-side speculative decoding
    /// (`ServingConfig::enable_spec_decode`).  Disabling keeps every
    /// decoder on the plain per-token path — the equivalence oracle the
    /// spec property tests compare against.
    pub fn set_spec_decode(&self, on: bool) {
        self.health.set_enabled(PathId::SpecDec, on);
    }

    /// Whether speculative decoding is both enabled and healthy.
    pub fn spec_decode_active(&self) -> bool {
        self.health.active(PathId::SpecDec)
    }

    /// Record a speculative-decoding failure — a verify span that
    /// exhausted its transient retries, or a demotion-window's worth of
    /// low acceptance.  Later decoders stay on plain decode until the
    /// cooldown re-promotes the path for a probe.
    pub fn mark_spec_decode_unhealthy(&self) {
        self.health.record_failure(PathId::SpecDec);
    }

    /// Compiled span buckets (tokens per execution) usable for `path`,
    /// ascending, after the serving-side cap.  Empty when the bundle has
    /// no span artifacts (pre-span AOT builds keep working).
    pub fn span_buckets_for(&self, path: StepPath) -> Vec<usize> {
        if path == StepPath::PrecomputeGather {
            // No span family for the in-graph-gather ablation.
            return Vec::new();
        }
        let mut v: Vec<usize> = self
            .entry
            .span_buckets(path != StepPath::Baseline)
            .iter()
            .filter_map(|a| a.span_tokens)
            .collect();
        v.sort_unstable();
        v.dedup();
        let cap = self.span_bucket_cap.load(Ordering::Relaxed);
        if cap > 0 && !v.is_empty() {
            let capped: Vec<usize> = v.iter().copied().filter(|t| *t <= cap).collect();
            if !capped.is_empty() {
                return capped;
            }
            // Cap below the smallest compiled bucket: the smallest tile
            // still beats one execution per token.
            v.truncate(1);
        }
        v
    }

    /// Largest span tile serving would use for `path` (0 = none compiled)
    /// — the granularity the scheduler aligns continuation chunks to.
    pub fn max_span_bucket(&self, path: StepPath) -> usize {
        self.span_buckets_for(path).last().copied().unwrap_or(0)
    }

    /// Widest compiled span batch for `path` (0 = none compiled) — the
    /// lane count the scheduler composes continuation groups toward.
    pub fn max_span_batch(&self, path: StepPath) -> usize {
        if path == StepPath::PrecomputeGather {
            return 0;
        }
        self.entry
            .span_batch_buckets(path != StepPath::Baseline)
            .iter()
            .filter_map(|a| a.batch)
            .max()
            .unwrap_or(0)
    }

    /// The `(B, [T...])` span-batch bucket a group of `n_lanes` sequences
    /// would serve from: the smallest compiled batch that fits the group,
    /// with that batch's tile sizes ascending (after the serving-side
    /// cap, mirroring [`ModelEngine::span_buckets_for`]).  `None` when no
    /// compiled batch fits — pre-batch AOT bundles keep working on the
    /// per-sequence path.
    pub fn span_batch_for(&self, path: StepPath, n_lanes: usize) -> Option<(usize, Vec<usize>)> {
        if path == StepPath::PrecomputeGather {
            return None;
        }
        let specs = self.entry.span_batch_buckets(path != StepPath::Baseline);
        let b = specs
            .iter()
            .filter_map(|a| a.batch)
            .filter(|b| *b >= n_lanes)
            .min()?;
        let mut ts: Vec<usize> = specs
            .iter()
            .filter(|a| a.batch == Some(b))
            .filter_map(|a| a.span_tokens)
            .collect();
        ts.sort_unstable();
        ts.dedup();
        let cap = self.span_bucket_cap.load(Ordering::Relaxed);
        if cap > 0 && !ts.is_empty() {
            let capped: Vec<usize> = ts.iter().copied().filter(|t| *t <= cap).collect();
            if !capped.is_empty() {
                ts = capped;
            } else {
                ts.truncate(1);
            }
        }
        if ts.is_empty() {
            None
        } else {
            Some((b, ts))
        }
    }

    /// Whether [`ModelEngine::decode_span_group`] could serve these lanes
    /// against a cache of capacity `s`: grouping enabled and healthy, a
    /// compiled batch fits the group, and the group plan clears every
    /// lane's capacity guard.  Callers check this BEFORE gathering the
    /// group cache; an error after a true answer is a real failure worth
    /// [`ModelEngine::mark_span_batch_unhealthy`].
    pub fn span_group_viable(&self, path: StepPath, lanes: &[SpanLane], s: usize) -> bool {
        if !self.span_batch_active() || lanes.len() < 2 {
            return false;
        }
        let Some((_, ts)) = self.span_batch_for(path, lanes.len()) else {
            return false;
        };
        let max_len = lanes.iter().map(|l| l.tokens.len()).max().unwrap_or(0);
        let max_start = lanes.iter().map(|l| l.start).max().unwrap_or(0);
        // Planning from the rightmost lane guards every lane: tile j
        // writes `bucket` slots from `start_b + done`, and
        // `start_b <= max_start` for all lanes.
        max_len > 0 && plan_span_tiles(&ts, max_len, max_start, s).is_some()
    }

    /// The runtime's host↔device transfer counters.
    pub fn transfers(&self) -> Arc<TransferStats> {
        self.rt.transfers()
    }

    /// The runtime's lifecycle tracer (see [`crate::trace`]).
    pub fn tracer(&self) -> Arc<Tracer> {
        self.rt.tracer()
    }

    pub fn entry(&self) -> &ModelEntry {
        &self.entry
    }

    pub fn table(&self) -> &Table {
        &self.table
    }

    pub fn weights(&self) -> &WeightsFile {
        &self.weights
    }

    /// Upload (or fetch the cached) device buffer for a weight tensor or
    /// the `@table` pseudo-tensor.
    fn weight_buffer(&self, name: &str) -> Result<Arc<xla::PjRtBuffer>> {
        if let Some(b) = self.buf_by_name.lock().unwrap().get(name) {
            return Ok(b.clone());
        }
        let buf = if name == "@table" {
            let rows = self.table.gather_vec(
                &(0..self.table.vocab() as u32).collect::<Vec<_>>(),
            )?;
            self.rt
                .upload_f32(&rows, &[self.table.vocab(), self.table.row_width()])?
        } else {
            let t = self.weights.get(name)?;
            let data = t.to_f32_vec()?;
            self.rt.upload_f32(&data, &t.dims)?
        };
        let buf = Arc::new(buf);
        self.buf_by_name
            .lock()
            .unwrap()
            .insert(name.to_string(), buf.clone());
        Ok(buf)
    }

    fn load_artifact(&self, name: &str) -> Result<Arc<Loaded>> {
        if let Some(l) = self.loaded.lock().unwrap().get(name) {
            return Ok(l.clone());
        }
        let spec = self.entry.artifact(name)?.clone();
        let exe = self.rt.load(&self.dir.join(&spec.file), spec.clone())?;
        let mut weight_bufs = Vec::with_capacity(spec.weight_params.len());
        for w in &spec.weight_params {
            weight_bufs.push(self.weight_buffer(w)?);
        }
        let loaded = Arc::new(Loaded { exe, weight_bufs });
        self.loaded
            .lock()
            .unwrap()
            .insert(name.to_string(), loaded.clone());
        Ok(loaded)
    }

    /// Eagerly compile every artifact of a path family (avoids first-request
    /// latency spikes; `firstlayer serve --warmup`).
    pub fn warmup(&self, path: StepPath) -> Result<()> {
        let names: Vec<String> = self
            .entry
            .artifacts
            .iter()
            .filter(|a| match path {
                StepPath::Baseline => a.name.contains("baseline"),
                StepPath::Precompute => {
                    a.name.contains("precomp") && !a.name.contains("gather")
                }
                StepPath::PrecomputeGather => a.name.contains("gather"),
            })
            .map(|a| a.name.clone())
            .collect();
        for n in names {
            self.load_artifact(&n)?;
        }
        Ok(())
    }

    /// Smallest compiled decode bucket that fits `n` sequences.
    pub fn decode_bucket(&self, n: usize, path: StepPath) -> Result<usize> {
        let precomp = path != StepPath::Baseline;
        let prefix = match path {
            StepPath::Baseline => "decode_baseline_b",
            StepPath::Precompute => "decode_precomp_b",
            StepPath::PrecomputeGather => "decode_precomp_gather_b",
        };
        self.entry
            .artifacts
            .iter()
            .filter(|a| a.name.starts_with(prefix) && a.kind == ArtifactKind::Decode)
            .filter_map(|a| a.batch)
            .filter(|b| *b >= n)
            .min()
            .ok_or_else(|| {
                Error::Engine(format!(
                    "no decode bucket >= {n} for path {} (precomp={precomp})",
                    path.label()
                ))
            })
    }

    /// Smallest compiled prefill bucket fitting `n` sequences of `t` tokens.
    pub fn prefill_bucket(&self, n: usize, t: usize, path: StepPath) -> Result<(usize, usize)> {
        let prefix = match path {
            StepPath::Baseline => "prefill_baseline_b",
            _ => "prefill_precomp_b",
        };
        self.entry
            .artifacts
            .iter()
            .filter(|a| a.name.starts_with(prefix))
            .filter_map(|a| Some((a.batch?, a.prompt_len?)))
            .filter(|(b, pt)| *b >= n && *pt >= t)
            .min()
            .ok_or_else(|| {
                Error::Engine(format!("no prefill bucket >= ({n}, {t})"))
            })
    }

    /// One decode step.  `tokens[i]` is the token to feed for sequence `i`,
    /// `pos[i]` its position (= current length), `caches` the dense batch
    /// KV with `b == bucket` rows (callers pad with zero rows).
    pub fn decode(
        &self,
        path: StepPath,
        tokens: &[u32],
        pos: &[u32],
        caches: &CacheBatch,
    ) -> Result<DecodeOut> {
        self.decode_inner(path, tokens, pos, caches, None, true)
    }

    /// Decode with optionally pre-gathered table rows (`n * row_width`
    /// f32s) — [`ModelEngine::decode_span`] batches the whole span's table
    /// read up front — and optional traffic recording (span tokens are
    /// accounted as prefill, not decode, traffic).
    fn decode_inner(
        &self,
        path: StepPath,
        tokens: &[u32],
        pos: &[u32],
        caches: &CacheBatch,
        pregathered: Option<&[f32]>,
        record: bool,
    ) -> Result<DecodeOut> {
        let n = tokens.len();
        if n == 0 || n != pos.len() {
            return Err(Error::Engine("decode: empty or mismatched batch".into()));
        }
        if path != StepPath::Baseline && !self.entry.config.rope {
            return Err(Error::Engine(
                "precompute path requires RoPE (paper §2 — abs-PE models \
                 cannot precompute the first layer)"
                    .into(),
            ));
        }
        let bucket = self.decode_bucket(n, path)?;
        let cfg = &self.entry.config;
        if caches.b != bucket {
            return Err(Error::Engine(format!(
                "caches padded to {} but bucket is {bucket}",
                caches.b
            )));
        }
        let name = match path {
            StepPath::Baseline => format!("decode_baseline_b{bucket}"),
            StepPath::Precompute => format!("decode_precomp_b{bucket}"),
            StepPath::PrecomputeGather => format!("decode_precomp_gather_b{bucket}"),
        };
        let loaded = self.load_artifact(&name)?;

        let tracer = self.rt.tracer();
        tracer.exec_begin(SpanKind::DecodeStep, bucket, n);
        let mut data_bufs = self.decode_data_bufs(path, tokens, pos, bucket, pregathered)?;
        let t_up = std::time::Instant::now();
        data_bufs.push(self.rt.upload_f32(&caches.k, &caches.dims().to_vec())?);
        data_bufs.push(self.rt.upload_f32(&caches.v, &caches.dims().to_vec())?);
        self.rt
            .transfers()
            .record_cache_upload((caches.k.len() + caches.v.len()) as u64 * 4);
        let up = t_up.elapsed();

        let mut args: Vec<&xla::PjRtBuffer> = data_bufs.iter().collect();
        for wb in &loaded.weight_bufs {
            args.push(wb);
        }
        let t_exec = std::time::Instant::now();
        let out = loaded.exe.execute_host(&args)?;
        let exec = t_exec.elapsed();
        // The host path reads the full cache pair back every step.
        self.rt
            .transfers()
            .record_cache_sync((caches.k.len() + caches.v.len()) as u64 * 4);
        if record {
            self.traffic.record_decode(cfg, path, n as u64);
        }
        let t_unpack = std::time::Instant::now();
        let res = self.unpack_decode(out, n, bucket, pos, caches);
        tracer.exec_end(n);
        if trace_enabled() {
            eprintln!(
                "[trace] decode {} B={n}/{bucket}: upload={up:?} exec+readback={exec:?} unpack={:?}",
                path.label(),
                t_unpack.elapsed()
            );
        }
        res
    }

    /// Build the per-step data inputs shared by the host and
    /// device-resident decode paths: the token ids (baseline / in-graph
    /// gather) or pre-gathered table rows (precompute), then the
    /// positions — both padded out to the bucket.  The K/V cache
    /// arguments follow these in the artifacts' parameter order.
    fn decode_data_bufs(
        &self,
        path: StepPath,
        tokens: &[u32],
        pos: &[u32],
        bucket: usize,
        pregathered: Option<&[f32]>,
    ) -> Result<Vec<xla::PjRtBuffer>> {
        let n = tokens.len();
        let mut data_bufs: Vec<xla::PjRtBuffer> = Vec::new();
        match path {
            StepPath::Baseline | StepPath::PrecomputeGather => {
                let mut toks: Vec<i32> = tokens.iter().map(|t| *t as i32).collect();
                toks.resize(bucket, 0);
                data_bufs.push(self.rt.upload_i32(&toks, &[bucket])?);
            }
            StepPath::Precompute => {
                // The paper's runtime read: one 2(d+e) row per token
                // (already gathered when the caller batched a whole span).
                let w = self.table.row_width();
                let mut rows = vec![0f32; bucket * w];
                match pregathered {
                    Some(r) if r.len() == n * w => rows[..n * w].copy_from_slice(r),
                    Some(r) => {
                        return Err(Error::Engine(format!(
                            "decode: pregathered rows len {} != {}",
                            r.len(),
                            n * w
                        )))
                    }
                    None => {
                        self.faults.check(InjectPoint::Gather)?;
                        let t0 = self.rt.tracer().now();
                        self.table.gather(tokens, &mut rows[..n * w])?;
                        self.rt.tracer().phase_since(Phase::Gather, t0);
                    }
                }
                data_bufs.push(self.rt.upload_f32(&rows, &[bucket, w])?);
            }
        }
        let mut pos_p: Vec<i32> = pos.iter().map(|p| *p as i32).collect();
        pos_p.resize(bucket, 0);
        data_bufs.push(self.rt.upload_i32(&pos_p, &[bucket])?);
        Ok(data_bufs)
    }

    /// Open a device-resident cache session over `caches` (ONE K/V pair
    /// upload).  The caller drives it with
    /// [`ModelEngine::decode_on_session`] and syncs via
    /// [`DeviceCacheSession::read_cache_pair`].
    pub fn begin_cache_session(&self, caches: &CacheBatch) -> Result<DeviceCacheSession> {
        DeviceCacheSession::begin(&self.rt, caches)
    }

    /// One buffer-chained decode step against a live
    /// [`DeviceCacheSession`]: the resident cache pair goes in as
    /// execution arguments, the step's output cache buffers replace it,
    /// and at most the logits (`n · vocab` f32s) are read back —
    /// `read_logits = false` skips even that (span interiors: only the
    /// final token's logits are ever used) and returns an empty vec.  On
    /// error the session is untouched (PJRT buffers are immutable), so
    /// callers can sync what succeeded and fall back to the host path.
    pub fn decode_on_session(
        &self,
        path: StepPath,
        tokens: &[u32],
        pos: &[u32],
        sess: &mut DeviceCacheSession,
        pregathered: Option<&[f32]>,
        read_logits: bool,
        record: bool,
    ) -> Result<Vec<f32>> {
        let n = tokens.len();
        if n == 0 || n != pos.len() {
            return Err(Error::Engine("decode: empty or mismatched batch".into()));
        }
        if path != StepPath::Baseline && !self.entry.config.rope {
            return Err(Error::Engine(
                "precompute path requires RoPE (paper §2 — abs-PE models \
                 cannot precompute the first layer)"
                    .into(),
            ));
        }
        let bucket = self.decode_bucket(n, path)?;
        if sess.bucket() != bucket {
            return Err(Error::Engine(format!(
                "session cache padded to {} but bucket is {bucket}",
                sess.bucket()
            )));
        }
        let cfg = &self.entry.config;
        let name = match path {
            StepPath::Baseline => format!("decode_baseline_b{bucket}"),
            StepPath::Precompute => format!("decode_precomp_b{bucket}"),
            StepPath::PrecomputeGather => format!("decode_precomp_gather_b{bucket}"),
        };
        let loaded = self.load_artifact(&name)?;
        let tracer = self.rt.tracer();
        tracer.exec_begin(SpanKind::DecodeStep, bucket, n);
        let data_bufs = self.decode_data_bufs(path, tokens, pos, bucket, pregathered)?;
        let mut args: Vec<&xla::PjRtBuffer> = data_bufs.iter().collect();
        let (kb, vb) = sess.cache_args();
        args.push(kb);
        args.push(vb);
        for wb in &loaded.weight_bufs {
            args.push(wb);
        }
        let t_exec = std::time::Instant::now();
        let mut out = loaded.exe.execute_buffers(&args)?;
        // Chaining needs one buffer per output leaf — and exactly the
        // [logits, k, v] triple.  A wrapper that hands back a single
        // tuple buffer (or a malformed spec) cannot be buffer-chained;
        // the caller falls back to the host path (sticky).
        if out.len() != 3 || loaded.exe.spec.outputs.len() != 3 {
            return Err(Error::Engine(format!(
                "{name}: {} output buffers for {} declared outputs — buffer \
                 chaining needs untupled [logits, k, v]",
                out.len(),
                loaded.exe.spec.outputs.len()
            )));
        }
        let v_buf = out.pop().expect("three outputs");
        let k_buf = out.pop().expect("three outputs");
        let logits_buf = out.pop().expect("three outputs");
        let logits = if read_logits {
            let logits_all = loaded.exe.read_output(&logits_buf, 0)?;
            let logits_all = logits_all.as_f32()?;
            logits_all[..n * cfg.vocab_size].to_vec()
        } else {
            Vec::new()
        };
        if record {
            self.traffic.record_decode(cfg, path, n as u64);
        }
        sess.advance(k_buf, v_buf);
        tracer.exec_end(n);
        if trace_enabled() {
            eprintln!(
                "[trace] decode {} B={n}/{bucket} (session step {}): exec+logits={:?}",
                path.label(),
                sess.steps(),
                t_exec.elapsed()
            );
        }
        Ok(logits)
    }

    fn unpack_decode(
        &self,
        out: Vec<HostTensor>,
        n: usize,
        bucket: usize,
        pos: &[u32],
        caches: &CacheBatch,
    ) -> Result<DecodeOut> {
        let cfg = &self.entry.config;
        let vocab = cfg.vocab_size;
        let logits_all = out[0].as_f32()?;
        let kc = out[1].as_f32()?;
        let vc = out[2].as_f32()?;
        let row = caches.kh * caches.hd;
        let mut logits = vec![0f32; n * vocab];
        logits.copy_from_slice(&logits_all[..n * vocab]);
        let lrow = caches.l * row;
        let mut new_k = vec![0f32; n * lrow];
        let mut new_v = vec![0f32; n * lrow];
        // Extract the freshly written slot pos[i] per (seq, layer): the only
        // rows the paged store needs.
        let out_dims = [caches.l, bucket, caches.s, caches.kh, caches.hd];
        for i in 0..n {
            CacheBatch::extract_rows_into(
                out_dims,
                kc,
                vc,
                i,
                pos[i] as usize,
                1,
                &mut new_k[i * lrow..(i + 1) * lrow],
                &mut new_v[i * lrow..(i + 1) * lrow],
            );
        }
        Ok(DecodeOut {
            logits,
            new_k,
            new_v,
            bucket,
        })
    }

    /// Advance ONE sequence through `tokens` starting at absolute position
    /// `start_pos` — the chunked-prefill continuation path (and the
    /// post-preemption replay of over-bucket prompts, prefix-cache suffix
    /// fills, and chat turn deltas).
    ///
    /// `caches` holds the sequence's history in batch row 0, padded to the
    /// B=1 decode bucket.  The first layer of the WHOLE span is served from
    /// the precompute table in one batched row-gather (the paper's read
    /// pattern: `len·2(d+e)` contiguous values).  Layers 2..L then advance
    /// through the **batched span artifact** when one is compiled
    /// ([`ModelEngine::span_exec_active`]): the span tiles into
    /// `ceil(S/T)` bucketed executions — ragged tails padded to the
    /// bucket and masked — each emitting the tile's logits plus its fresh
    /// K/V rows.  On the device-resident path the tiles buffer-chain
    /// through ONE [`DeviceCacheSession`] (a single cache-pair upload per
    /// span, per-execution readback of logits + fresh rows only, no
    /// span-end pair sync at all).  The token-by-token decode loop is
    /// kept verbatim below as the fallback and equivalence oracle, with a
    /// sticky health switch mirroring the device-KV one.  Either way
    /// `caches` holds the advanced history on return, and span tokens are
    /// recorded as prefill traffic.
    pub fn decode_span(
        &self,
        path: StepPath,
        tokens: &[u32],
        start_pos: usize,
        caches: &mut CacheBatch,
    ) -> Result<SpanOut> {
        self.decode_span_inner(path, tokens, start_pos, caches, false)
    }

    /// [`ModelEngine::decode_span`] with the per-position logits kept:
    /// the verify kernel of server-side speculative decoding.  The span
    /// artifacts already compute `[T, V]` logits for every position —
    /// a plain span discards all but the last row; this entry reads
    /// them all back (`SpanOut::pos_logits`) so the coordinator can
    /// score a drafted span in the same device executions.  Execution
    /// windows trace as `spec_verify` instead of `span_tile`.
    pub fn decode_span_scored(
        &self,
        path: StepPath,
        tokens: &[u32],
        start_pos: usize,
        caches: &mut CacheBatch,
    ) -> Result<SpanOut> {
        self.decode_span_inner(path, tokens, start_pos, caches, true)
    }

    fn decode_span_inner(
        &self,
        path: StepPath,
        tokens: &[u32],
        start_pos: usize,
        caches: &mut CacheBatch,
        score: bool,
    ) -> Result<SpanOut> {
        let n = tokens.len();
        if n == 0 {
            return Err(Error::Engine("decode_span: empty span".into()));
        }
        if start_pos + n > caches.s {
            return Err(Error::Engine(format!(
                "decode_span: span end {} exceeds cache capacity {}",
                start_pos + n,
                caches.s
            )));
        }
        let cfg = self.entry.config.clone();
        if path != StepPath::Baseline && !cfg.rope {
            return Err(Error::Engine(
                "precompute path requires RoPE (paper §2 — abs-PE models \
                 cannot precompute the first layer)"
                    .into(),
            ));
        }
        let rows = if path == StepPath::Precompute {
            self.faults.check(InjectPoint::Gather)?;
            let t0 = self.rt.tracer().now();
            let r = self.table.gather_vec(tokens)?;
            self.rt.tracer().phase_since(Phase::Gather, t0);
            Some(r)
        } else {
            None
        };
        self.traffic.record_prefill(&cfg, path, n as u64);
        if self.span_exec_active() {
            let buckets = self.span_buckets_for(path);
            // A plan can fail only when the span ends too close to the
            // cache capacity for any compiled bucket (or none exist) —
            // a capability gap, not a health event.
            if let Some(tiles) = plan_span_tiles(&buckets, n, start_pos, caches.s) {
                match self.decode_span_batched(
                    path,
                    tokens,
                    start_pos,
                    caches,
                    rows.as_deref(),
                    &tiles,
                    score,
                ) {
                    Ok(out) => return Ok(out),
                    Err(e) => {
                        self.mark_span_exec_unhealthy();
                        eprintln!(
                            "[firstlayer] batched span execution failed ({e}); \
                             demoted to the token-by-token path until the \
                             health cooldown re-probes it"
                        );
                    }
                }
            }
        }
        self.span_fallback_count.fetch_add(1, Ordering::Relaxed);
        if self.device_kv_active() {
            // Device writes never touch `caches` until the final sync, so
            // a mid-span failure leaves the host state pristine and the
            // legacy loop below can re-run the whole span.
            match self.decode_span_device(path, tokens, start_pos, caches, rows.as_deref(), score)
            {
                Ok(out) => return Ok(out),
                Err(e) => {
                    self.mark_device_kv_unhealthy();
                    eprintln!(
                        "[firstlayer] device-resident span failed ({e}); \
                         demoted to the host cache path until the health \
                         cooldown re-probes it"
                    );
                }
            }
        }
        self.decode_span_host(path, tokens, start_pos, caches, rows.as_deref(), score)
    }

    fn span_artifact_name(&self, path: StepPath, bucket: usize) -> String {
        match path {
            StepPath::Baseline => format!("span_baseline_t{bucket}"),
            _ => format!("span_precomp_t{bucket}"),
        }
    }

    /// Data inputs for one span tile: the tile's tokens (baseline) or
    /// pre-gathered table rows (precompute) padded out to the bucket,
    /// then the `[1]`-shaped absolute start position.  The cache pair and
    /// weights follow in the artifact's parameter order.
    fn span_data_bufs(
        &self,
        path: StepPath,
        tokens: &[u32],
        bucket: usize,
        start: usize,
        rows: Option<&[f32]>,
    ) -> Result<Vec<xla::PjRtBuffer>> {
        let n = tokens.len();
        let mut bufs: Vec<xla::PjRtBuffer> = Vec::new();
        match path {
            StepPath::Baseline => {
                let mut toks: Vec<i32> = tokens.iter().map(|t| *t as i32).collect();
                toks.resize(bucket, 0);
                bufs.push(self.rt.upload_i32(&toks, &[bucket])?);
            }
            _ => {
                let w = self.table.row_width();
                let r = rows.ok_or_else(|| {
                    Error::Engine("span tile: missing pregathered rows".into())
                })?;
                if r.len() != n * w {
                    return Err(Error::Engine(format!(
                        "span tile: rows len {} != {}",
                        r.len(),
                        n * w
                    )));
                }
                let mut padded = vec![0f32; bucket * w];
                padded[..n * w].copy_from_slice(r);
                bufs.push(self.rt.upload_f32(&padded, &[bucket, w])?);
            }
        }
        bufs.push(self.rt.upload_i32(&[start as i32], &[1])?);
        Ok(bufs)
    }

    /// Serve a span through the compiled span artifact: `tiles` bucketed
    /// executions instead of one decode dispatch per token.  `caches` is
    /// written only on success (the final fresh-row scatter), so a
    /// mid-span failure leaves the host state pristine for the
    /// token-by-token fallback to re-run the whole span.
    fn decode_span_batched(
        &self,
        path: StepPath,
        tokens: &[u32],
        start_pos: usize,
        caches: &mut CacheBatch,
        rows: Option<&[f32]>,
        tiles: &[(usize, usize)],
        score: bool,
    ) -> Result<SpanOut> {
        let n = tokens.len();
        let device = self.device_kv_active();
        // The span artifacts are compiled against a B=1 cache; callers
        // holding a wider decode bucket get a local B=1 view of batch row
        // 0.  Host-mode tiles additionally write the full updated pair
        // back between executions, so they must never run on the caller's
        // mirror directly (failure safety).
        let mut local: Option<CacheBatch> = None;
        if caches.b != 1 || !device {
            let mut c1 = CacheBatch::zeros(caches.l, 1, caches.s, caches.kh, caches.hd);
            let srow = caches.s * caches.kh * caches.hd;
            for l in 0..caches.l {
                let src = caches.offset(l, 0, 0);
                let dst = c1.offset(l, 0, 0);
                c1.k[dst..dst + srow].copy_from_slice(&caches.k[src..src + srow]);
                c1.v[dst..dst + srow].copy_from_slice(&caches.v[src..src + srow]);
            }
            local = Some(c1);
        }
        let out = if device {
            let work: &CacheBatch = local.as_ref().unwrap_or(caches);
            self.span_tiles_device(path, tokens, start_pos, work, rows, tiles, score)?
        } else {
            let work = local.as_mut().expect("host mode always copies");
            self.span_tiles_host(path, tokens, start_pos, work, rows, tiles, score)?
        };
        // Refresh ONLY the span's rows in the caller's mirror — the same
        // scatter every other span path performs; padding-tile garbage
        // past the span end never leaves the device/local copy.
        let row = caches.kh * caches.hd;
        for i in 0..n {
            for l in 0..caches.l {
                let o = caches.offset(l, 0, start_pos + i);
                let src = (i * caches.l + l) * row;
                caches.k[o..o + row].copy_from_slice(&out.new_k[src..src + row]);
                caches.v[o..o + row].copy_from_slice(&out.new_v[src..src + row]);
            }
        }
        Ok(out)
    }

    /// Device-resident span tiles: ONE cache-pair upload for the whole
    /// span, each tile buffer-chained through the session, per-execution
    /// readback of the tile's fresh rows (and the last tile's logits).
    /// The fresh-row outputs make the span-end full-pair sync of the
    /// token-by-token device path unnecessary.
    fn span_tiles_device(
        &self,
        path: StepPath,
        tokens: &[u32],
        start_pos: usize,
        caches: &CacheBatch,
        rows: Option<&[f32]>,
        tiles: &[(usize, usize)],
        score: bool,
    ) -> Result<SpanOut> {
        let cfg = &self.entry.config;
        let w = self.table.row_width();
        let row = caches.kh * caches.hd;
        let lrow = caches.l * row;
        let n = tokens.len();
        let mut sess = self.begin_cache_session(caches)?;
        let mut new_k = vec![0f32; n * lrow];
        let mut new_v = vec![0f32; n * lrow];
        let mut logits = Vec::new();
        let mut pos_logits = if score { vec![0f32; n * cfg.vocab_size] } else { Vec::new() };
        let mut exec_tokens = Vec::with_capacity(tiles.len());
        let mut done = 0usize;
        let tracer = self.rt.tracer();
        let kind = if score { SpanKind::SpecVerify } else { SpanKind::SpanTile };
        for (ti, &(bucket, take)) in tiles.iter().enumerate() {
            let last = ti + 1 == tiles.len();
            let name = self.span_artifact_name(path, bucket);
            let loaded = self.load_artifact(&name)?;
            tracer.exec_begin(kind, bucket, 1);
            let tile_rows = rows.map(|r| &r[done * w..(done + take) * w]);
            let data = self.span_data_bufs(
                path,
                &tokens[done..done + take],
                bucket,
                start_pos + done,
                tile_rows,
            )?;
            let mut args: Vec<&xla::PjRtBuffer> = data.iter().collect();
            let (kb, vb) = sess.cache_args();
            args.push(kb);
            args.push(vb);
            for wb in &loaded.weight_bufs {
                args.push(wb);
            }
            let t_exec = std::time::Instant::now();
            let mut out = loaded.exe.execute_buffers(&args)?;
            // Chaining needs one buffer per output leaf — exactly the
            // [logits, k, v, new_k, new_v] quintuple.
            if out.len() != 5 || loaded.exe.spec.outputs.len() != 5 {
                return Err(Error::Engine(format!(
                    "{name}: {} output buffers for {} declared outputs — span \
                     chaining needs untupled [logits, k, v, new_k, new_v]",
                    out.len(),
                    loaded.exe.spec.outputs.len()
                )));
            }
            let vr_buf = out.pop().expect("five outputs");
            let kr_buf = out.pop().expect("five outputs");
            let v_buf = out.pop().expect("five outputs");
            let k_buf = out.pop().expect("five outputs");
            let logits_buf = out.pop().expect("five outputs");
            // Selective readback: the tile's fresh rows always (the paged
            // store needs them), logits only on the last tile (interior
            // logits are never consumed).
            let kr = self.read_span_rows(&loaded.exe, &kr_buf, 3, take, lrow)?;
            let vr = self.read_span_rows(&loaded.exe, &vr_buf, 4, take, lrow)?;
            new_k[done * lrow..(done + take) * lrow].copy_from_slice(&kr);
            new_v[done * lrow..(done + take) * lrow].copy_from_slice(&vr);
            if last || score {
                let la = loaded.exe.read_output(&logits_buf, 0)?;
                let la = la.as_f32()?;
                if score {
                    // Scored spans keep every position's logits — that's
                    // the verify surface.  Padding rows never escape:
                    // only the tile's `take` valid rows are copied.
                    pos_logits[done * cfg.vocab_size..(done + take) * cfg.vocab_size]
                        .copy_from_slice(&la[..take * cfg.vocab_size]);
                }
                if last {
                    logits =
                        la[(take - 1) * cfg.vocab_size..take * cfg.vocab_size].to_vec();
                }
            }
            sess.advance(k_buf, v_buf);
            self.span_execs.fetch_add(1, Ordering::Relaxed);
            tracer.exec_end(take);
            exec_tokens.push(take);
            done += take;
            if trace_enabled() {
                eprintln!(
                    "[trace] span {} tile T={bucket} take={take} (device): {:?}",
                    path.label(),
                    t_exec.elapsed()
                );
            }
        }
        Ok(SpanOut {
            logits,
            pos_logits,
            new_k,
            new_v,
            executions: tiles.len(),
            exec_tokens,
            batched: true,
        })
    }

    /// Read a tile's `new_k`/`new_v` output (`[T, L, KH, hd]`, token-major
    /// — exactly the [`SpanOut`] row layout) and slice the valid prefix.
    fn read_span_rows(
        &self,
        exe: &Executable,
        buf: &xla::PjRtBuffer,
        idx: usize,
        take: usize,
        lrow: usize,
    ) -> Result<Vec<f32>> {
        let t = exe.read_output(buf, idx)?;
        let t = t.as_f32()?;
        if t.len() < take * lrow {
            return Err(Error::Engine(format!(
                "span rows output {idx}: {} elems < {}",
                t.len(),
                take * lrow
            )));
        }
        Ok(t[..take * lrow].to_vec())
    }

    /// Host span tiles: the fallback when buffer chaining is unavailable
    /// — each tile uploads the full pair and reads the updated pair back,
    /// but the execution count stays `ceil(S/T)` instead of `S`.
    fn span_tiles_host(
        &self,
        path: StepPath,
        tokens: &[u32],
        start_pos: usize,
        work: &mut CacheBatch,
        rows: Option<&[f32]>,
        tiles: &[(usize, usize)],
        score: bool,
    ) -> Result<SpanOut> {
        let cfg = &self.entry.config;
        let w = self.table.row_width();
        let row = work.kh * work.hd;
        let lrow = work.l * row;
        let n = tokens.len();
        let pair_bytes = (work.k.len() + work.v.len()) as u64 * 4;
        let mut new_k = vec![0f32; n * lrow];
        let mut new_v = vec![0f32; n * lrow];
        let mut logits = Vec::new();
        let mut pos_logits = if score { vec![0f32; n * cfg.vocab_size] } else { Vec::new() };
        let mut exec_tokens = Vec::with_capacity(tiles.len());
        let mut done = 0usize;
        let tracer = self.rt.tracer();
        let kind = if score { SpanKind::SpecVerify } else { SpanKind::SpanTile };
        for (ti, &(bucket, take)) in tiles.iter().enumerate() {
            let last = ti + 1 == tiles.len();
            let name = self.span_artifact_name(path, bucket);
            let loaded = self.load_artifact(&name)?;
            tracer.exec_begin(kind, bucket, 1);
            let tile_rows = rows.map(|r| &r[done * w..(done + take) * w]);
            let mut data = self.span_data_bufs(
                path,
                &tokens[done..done + take],
                bucket,
                start_pos + done,
                tile_rows,
            )?;
            data.push(self.rt.upload_f32(&work.k, &work.dims().to_vec())?);
            data.push(self.rt.upload_f32(&work.v, &work.dims().to_vec())?);
            self.rt.transfers().record_cache_upload(pair_bytes);
            let mut args: Vec<&xla::PjRtBuffer> = data.iter().collect();
            for wb in &loaded.weight_bufs {
                args.push(wb);
            }
            let out = loaded.exe.execute_host(&args)?;
            // The full updated pair comes back; the next tile attends the
            // span rows this one wrote.
            work.k.copy_from_slice(out[1].as_f32()?);
            work.v.copy_from_slice(out[2].as_f32()?);
            self.rt.transfers().record_cache_sync(pair_bytes);
            let kr = out[3].as_f32()?;
            let vr = out[4].as_f32()?;
            new_k[done * lrow..(done + take) * lrow]
                .copy_from_slice(&kr[..take * lrow]);
            new_v[done * lrow..(done + take) * lrow]
                .copy_from_slice(&vr[..take * lrow]);
            if last || score {
                let la = out[0].as_f32()?;
                if score {
                    pos_logits[done * cfg.vocab_size..(done + take) * cfg.vocab_size]
                        .copy_from_slice(&la[..take * cfg.vocab_size]);
                }
                if last {
                    logits =
                        la[(take - 1) * cfg.vocab_size..take * cfg.vocab_size].to_vec();
                }
            }
            self.span_execs.fetch_add(1, Ordering::Relaxed);
            tracer.exec_end(take);
            exec_tokens.push(take);
            done += take;
        }
        Ok(SpanOut {
            logits,
            pos_logits,
            new_k,
            new_v,
            executions: tiles.len(),
            exec_tokens,
            batched: true,
        })
    }

    /// Device-resident span execution: one session, `n` chained steps,
    /// one sync.
    fn decode_span_device(
        &self,
        path: StepPath,
        tokens: &[u32],
        start_pos: usize,
        caches: &mut CacheBatch,
        rows: Option<&[f32]>,
        score: bool,
    ) -> Result<SpanOut> {
        let w = self.table.row_width();
        let mut sess = self.begin_cache_session(caches)?;
        let mut logits = Vec::new();
        let mut pos_logits = Vec::new();
        for (i, &tok) in tokens.iter().enumerate() {
            let pos = (start_pos + i) as u32;
            let pre = rows.map(|r| &r[i * w..(i + 1) * w]);
            // Only the final token's logits are ever consumed: interior
            // steps skip even the logits readback.  Scored spans read
            // every step — each position is a verify surface.
            let last = i + 1 == tokens.len();
            logits = self.decode_on_session(
                path,
                &[tok],
                &[pos],
                &mut sess,
                pre,
                last || score,
                false,
            )?;
            if score {
                pos_logits.extend_from_slice(&logits);
            }
        }
        // One selective sync: the pair comes down once, the span's rows
        // are sliced out host-side, and the host mirror is refreshed so
        // the caller sees the advanced history.
        let (kc, vc) = sess.read_cache_pair()?;
        let n = tokens.len();
        let (new_k, new_v) =
            CacheBatch::extract_rows(caches.dims(), &kc, &vc, 0, start_pos, n);
        // Refresh ONLY the span's rows in the host mirror — the same
        // scatter the host path performs, and the only slots this call
        // changed (the pair was uploaded from `caches`, and chained
        // steps pass everything else through).  Copying the whole pair
        // back would cost two full-cache memcpys per span for a mirror
        // most callers drop.
        let row = caches.kh * caches.hd;
        for i in 0..n {
            for l in 0..caches.l {
                let o = caches.offset(l, 0, start_pos + i);
                let src = (i * caches.l + l) * row;
                caches.k[o..o + row].copy_from_slice(&new_k[src..src + row]);
                caches.v[o..o + row].copy_from_slice(&new_v[src..src + row]);
            }
        }
        Ok(SpanOut {
            logits,
            pos_logits,
            new_k,
            new_v,
            executions: n,
            exec_tokens: vec![1; n],
            batched: false,
        })
    }

    /// Legacy host span execution: per-token full cache upload + readback
    /// with a host-side scatter between tokens.  Kept as the fallback and
    /// the equivalence oracle for the device-resident path.
    fn decode_span_host(
        &self,
        path: StepPath,
        tokens: &[u32],
        start_pos: usize,
        caches: &mut CacheBatch,
        rows: Option<&[f32]>,
        score: bool,
    ) -> Result<SpanOut> {
        let n = tokens.len();
        let w = self.table.row_width();
        let row = caches.kh * caches.hd;
        let lrow = caches.l * row;
        let mut new_k = vec![0f32; n * lrow];
        let mut new_v = vec![0f32; n * lrow];
        let mut logits = Vec::new();
        let mut pos_logits = Vec::new();
        for (i, &tok) in tokens.iter().enumerate() {
            let pos = start_pos + i;
            let pre = rows.map(|r| &r[i * w..(i + 1) * w]);
            let out =
                self.decode_inner(path, &[tok], &[pos as u32], caches, pre, false)?;
            // Scatter the fresh row so the next span token attends to it.
            for l in 0..caches.l {
                let o = caches.offset(l, 0, pos);
                let src = l * row..(l + 1) * row;
                caches.k[o..o + row].copy_from_slice(&out.new_k[src.clone()]);
                caches.v[o..o + row].copy_from_slice(&out.new_v[src]);
            }
            new_k[i * lrow..(i + 1) * lrow].copy_from_slice(&out.new_k);
            new_v[i * lrow..(i + 1) * lrow].copy_from_slice(&out.new_v);
            logits = out.logits;
            if score {
                pos_logits.extend_from_slice(&logits);
            }
        }
        Ok(SpanOut {
            logits,
            pos_logits,
            new_k,
            new_v,
            executions: n,
            exec_tokens: vec![1; n],
            batched: false,
        })
    }

    fn span_batch_artifact_name(&self, path: StepPath, b: usize, t: usize) -> String {
        match path {
            StepPath::Baseline => format!("span_baseline_b{b}_t{t}"),
            _ => format!("span_precomp_b{b}_t{t}"),
        }
    }

    /// Advance a GROUP of sequences through one batched span step: every
    /// tile executes the device once for the whole group, replacing the
    /// serial per-sequence span loop on the steady-state decode path.
    ///
    /// `caches` holds lane `i`'s history in batch row `i`
    /// (`caches.b == lanes.len()`); the engine widens it to the compiled
    /// batch (extra lanes zero, `lens == 0`, inert throughout).  The
    /// group tiles over the LONGEST lane (`ceil(max_len / T)`
    /// executions); shorter lanes go inert once exhausted — their
    /// per-tile `lens[b]` hits 0 and the kernel masks every slot, while
    /// the in-graph insert keeps writing `T` garbage rows at
    /// `start_b + done`, strictly beyond the lane's valid frontier and
    /// capacity-guarded by planning from the rightmost lane.  Per lane
    /// the first layer is served from the precompute table in one
    /// batched row-gather, exactly like the single-sequence path.
    ///
    /// On success `caches` holds the advanced history (only each lane's
    /// span rows are refreshed — padding-tile garbage never leaves the
    /// device/local copy) and the per-lane fresh rows + last-token logits
    /// come back in lane order.  On error `caches` is untouched, so the
    /// caller can replay each lane through [`ModelEngine::decode_span`].
    pub fn decode_span_group(
        &self,
        path: StepPath,
        lanes: &[SpanLane],
        caches: &mut CacheBatch,
    ) -> Result<SpanGroupOut> {
        let nl = lanes.len();
        if nl == 0 || lanes.iter().any(|l| l.tokens.is_empty()) {
            return Err(Error::Engine("span group: empty group or lane".into()));
        }
        if caches.b != nl {
            return Err(Error::Engine(format!(
                "span group: {} cache rows for {nl} lanes",
                caches.b
            )));
        }
        let cfg = self.entry.config.clone();
        if path != StepPath::Baseline && !cfg.rope {
            return Err(Error::Engine(
                "precompute path requires RoPE (paper §2 — abs-PE models \
                 cannot precompute the first layer)"
                    .into(),
            ));
        }
        let (batch, ts) = self.span_batch_for(path, nl).ok_or_else(|| {
            Error::Engine(format!("span group: no compiled batch >= {nl} lanes"))
        })?;
        let max_len = lanes.iter().map(|l| l.tokens.len()).max().unwrap_or(0);
        let max_start = lanes.iter().map(|l| l.start).max().unwrap_or(0);
        let tiles = plan_span_tiles(&ts, max_len, max_start, caches.s).ok_or_else(|| {
            Error::Engine("span group: no tile plan fits the cache capacity".into())
        })?;
        let rows: Option<Vec<Vec<f32>>> = if path == StepPath::Precompute {
            self.faults.check(InjectPoint::Gather)?;
            let t0 = self.rt.tracer().now();
            let mut v = Vec::with_capacity(nl);
            for l in lanes {
                v.push(self.table.gather_vec(l.tokens)?);
            }
            self.rt.tracer().phase_since(Phase::Gather, t0);
            Some(v)
        } else {
            None
        };
        let total: u64 = lanes.iter().map(|l| l.tokens.len() as u64).sum();
        self.traffic.record_prefill(&cfg, path, total);
        // Widen to the compiled batch width.  Real lanes copy in; the
        // padding lanes stay zero with len 0 every tile (inert).
        let mut work = CacheBatch::zeros(caches.l, batch, caches.s, caches.kh, caches.hd);
        let srow = caches.s * caches.kh * caches.hd;
        for l in 0..caches.l {
            for i in 0..nl {
                let src = caches.offset(l, i, 0);
                let dst = work.offset(l, i, 0);
                work.k[dst..dst + srow].copy_from_slice(&caches.k[src..src + srow]);
                work.v[dst..dst + srow].copy_from_slice(&caches.v[src..src + srow]);
            }
        }
        let out = if self.device_kv_active() {
            self.span_group_tiles_device(path, lanes, rows.as_deref(), &tiles, batch, &work)?
        } else {
            self.span_group_tiles_host(path, lanes, rows.as_deref(), &tiles, batch, &mut work)?
        };
        // Refresh ONLY each lane's span rows in the caller's mirror (the
        // per-sequence scatter, per lane).
        let row = caches.kh * caches.hd;
        for (i, lane) in lanes.iter().enumerate() {
            let lo = &out.lanes[i];
            for j in 0..lane.tokens.len() {
                for l in 0..caches.l {
                    let o = caches.offset(l, i, lane.start + j);
                    let src = (j * caches.l + l) * row;
                    caches.k[o..o + row].copy_from_slice(&lo.new_k[src..src + row]);
                    caches.v[o..o + row].copy_from_slice(&lo.new_v[src..src + row]);
                }
            }
        }
        Ok(out)
    }

    /// Per-tile data inputs for a span group: the `[B, T]` token grid
    /// (baseline) or `[B, T, W]` pre-gathered rows (precompute) — each
    /// lane's live slice, zero-padded — then per-lane `starts` (always
    /// `start_b + done`, advancing even for inert lanes so garbage lands
    /// beyond the frontier) and per-lane valid `lens`.  Returns the
    /// buffers plus the tile's occupancy (lanes with `lens > 0`).
    #[allow(clippy::too_many_arguments)]
    fn span_group_data_bufs(
        &self,
        path: StepPath,
        lanes: &[SpanLane],
        rows: Option<&[Vec<f32>]>,
        b: usize,
        t: usize,
        done: usize,
    ) -> Result<(Vec<xla::PjRtBuffer>, usize)> {
        let mut starts = vec![0i32; b];
        let mut lens = vec![0i32; b];
        let mut occ = 0usize;
        for (i, lane) in lanes.iter().enumerate() {
            starts[i] = (lane.start + done) as i32;
            let take = lane.tokens.len().saturating_sub(done).min(t);
            lens[i] = take as i32;
            if take > 0 {
                occ += 1;
            }
        }
        let mut bufs: Vec<xla::PjRtBuffer> = Vec::new();
        match path {
            StepPath::Baseline => {
                let mut toks = vec![0i32; b * t];
                for (i, lane) in lanes.iter().enumerate() {
                    let take = lane.tokens.len().saturating_sub(done).min(t);
                    for (j, tok) in lane.tokens[done..done + take].iter().enumerate() {
                        toks[i * t + j] = *tok as i32;
                    }
                }
                bufs.push(self.rt.upload_i32(&toks, &[b, t])?);
            }
            _ => {
                let w = self.table.row_width();
                let rs = rows.ok_or_else(|| {
                    Error::Engine("span group tile: missing pregathered rows".into())
                })?;
                let mut padded = vec![0f32; b * t * w];
                for (i, r) in rs.iter().enumerate() {
                    let take = (r.len() / w).saturating_sub(done).min(t);
                    padded[i * t * w..(i * t + take) * w]
                        .copy_from_slice(&r[done * w..(done + take) * w]);
                }
                bufs.push(self.rt.upload_f32(&padded, &[b, t, w])?);
            }
        }
        bufs.push(self.rt.upload_i32(&starts, &[b])?);
        bufs.push(self.rt.upload_i32(&lens, &[b])?);
        Ok((bufs, occ))
    }

    /// Device-resident group tiles: ONE cache-pair upload for the whole
    /// group (every lane rides the same session), each tile
    /// buffer-chained, per-execution readback of the fresh rows and —
    /// only on tiles where some lane finishes — the logits grid.
    fn span_group_tiles_device(
        &self,
        path: StepPath,
        lanes: &[SpanLane],
        rows: Option<&[Vec<f32>]>,
        tiles: &[(usize, usize)],
        batch: usize,
        work: &CacheBatch,
    ) -> Result<SpanGroupOut> {
        let cfg = &self.entry.config;
        let vocab = cfg.vocab_size;
        let lrow = work.l * work.kh * work.hd;
        let mut sess = self.begin_cache_session(work)?;
        let mut outs: Vec<SpanLaneOut> = lanes
            .iter()
            .map(|l| SpanLaneOut {
                logits: Vec::new(),
                new_k: vec![0f32; l.tokens.len() * lrow],
                new_v: vec![0f32; l.tokens.len() * lrow],
            })
            .collect();
        let mut occupancy = Vec::with_capacity(tiles.len());
        let mut done = 0usize;
        let tracer = self.rt.tracer();
        for &(t, take) in tiles {
            let name = self.span_batch_artifact_name(path, batch, t);
            let loaded = self.load_artifact(&name)?;
            let (data, occ) = self.span_group_data_bufs(path, lanes, rows, batch, t, done)?;
            tracer.exec_begin(SpanKind::GroupTile, t, occ);
            let mut args: Vec<&xla::PjRtBuffer> = data.iter().collect();
            let (kb, vb) = sess.cache_args();
            args.push(kb);
            args.push(vb);
            for wb in &loaded.weight_bufs {
                args.push(wb);
            }
            let t_exec = std::time::Instant::now();
            let mut out = loaded.exe.execute_buffers(&args)?;
            if out.len() != 5 || loaded.exe.spec.outputs.len() != 5 {
                return Err(Error::Engine(format!(
                    "{name}: {} output buffers for {} declared outputs — span \
                     chaining needs untupled [logits, k, v, new_k, new_v]",
                    out.len(),
                    loaded.exe.spec.outputs.len()
                )));
            }
            let vr_buf = out.pop().expect("five outputs");
            let kr_buf = out.pop().expect("five outputs");
            let v_buf = out.pop().expect("five outputs");
            let k_buf = out.pop().expect("five outputs");
            let logits_buf = out.pop().expect("five outputs");
            // Fresh rows come back as [B, T, L, KH, hd]: each lane's tile
            // rows are one contiguous run.
            let kr = loaded.exe.read_output(&kr_buf, 3)?;
            let kr = kr.as_f32()?;
            let vr = loaded.exe.read_output(&vr_buf, 4)?;
            let vr = vr.as_f32()?;
            let mut finishing = false;
            for (i, lane) in lanes.iter().enumerate() {
                let lt = lane.tokens.len().saturating_sub(done).min(t);
                if lt == 0 {
                    continue;
                }
                let src = i * t * lrow;
                outs[i].new_k[done * lrow..(done + lt) * lrow]
                    .copy_from_slice(&kr[src..src + lt * lrow]);
                outs[i].new_v[done * lrow..(done + lt) * lrow]
                    .copy_from_slice(&vr[src..src + lt * lrow]);
                if done + lt == lane.tokens.len() {
                    finishing = true;
                }
            }
            if finishing {
                let la = loaded.exe.read_output(&logits_buf, 0)?;
                let la = la.as_f32()?;
                for (i, lane) in lanes.iter().enumerate() {
                    let lt = lane.tokens.len().saturating_sub(done).min(t);
                    if lt > 0 && done + lt == lane.tokens.len() {
                        let o = (i * t + lt - 1) * vocab;
                        outs[i].logits = la[o..o + vocab].to_vec();
                    }
                }
            }
            sess.advance(k_buf, v_buf);
            self.span_execs.fetch_add(1, Ordering::Relaxed);
            self.span_batched_execs.fetch_add(1, Ordering::Relaxed);
            let tile_tokens: usize = lanes
                .iter()
                .map(|l| l.tokens.len().saturating_sub(done).min(t))
                .sum();
            tracer.exec_end(tile_tokens);
            occupancy.push(occ);
            done += take;
            if trace_enabled() {
                eprintln!(
                    "[trace] span-group {} B={batch} T={t} occ={occ} (device): {:?}",
                    path.label(),
                    t_exec.elapsed()
                );
            }
        }
        Ok(SpanGroupOut {
            lanes: outs,
            executions: tiles.len(),
            occupancy,
            batch,
        })
    }

    /// Host group tiles: the fallback when buffer chaining is
    /// unavailable — each tile uploads the widened pair and reads the
    /// updated pair back, but still ONE execution per tile for the whole
    /// group.
    fn span_group_tiles_host(
        &self,
        path: StepPath,
        lanes: &[SpanLane],
        rows: Option<&[Vec<f32>]>,
        tiles: &[(usize, usize)],
        batch: usize,
        work: &mut CacheBatch,
    ) -> Result<SpanGroupOut> {
        let cfg = &self.entry.config;
        let vocab = cfg.vocab_size;
        let lrow = work.l * work.kh * work.hd;
        let pair_bytes = (work.k.len() + work.v.len()) as u64 * 4;
        let mut outs: Vec<SpanLaneOut> = lanes
            .iter()
            .map(|l| SpanLaneOut {
                logits: Vec::new(),
                new_k: vec![0f32; l.tokens.len() * lrow],
                new_v: vec![0f32; l.tokens.len() * lrow],
            })
            .collect();
        let mut occupancy = Vec::with_capacity(tiles.len());
        let mut done = 0usize;
        let tracer = self.rt.tracer();
        for &(t, take) in tiles {
            let name = self.span_batch_artifact_name(path, batch, t);
            let loaded = self.load_artifact(&name)?;
            let (mut data, occ) =
                self.span_group_data_bufs(path, lanes, rows, batch, t, done)?;
            tracer.exec_begin(SpanKind::GroupTile, t, occ);
            data.push(self.rt.upload_f32(&work.k, &work.dims().to_vec())?);
            data.push(self.rt.upload_f32(&work.v, &work.dims().to_vec())?);
            self.rt.transfers().record_cache_upload(pair_bytes);
            let mut args: Vec<&xla::PjRtBuffer> = data.iter().collect();
            for wb in &loaded.weight_bufs {
                args.push(wb);
            }
            let out = loaded.exe.execute_host(&args)?;
            work.k.copy_from_slice(out[1].as_f32()?);
            work.v.copy_from_slice(out[2].as_f32()?);
            self.rt.transfers().record_cache_sync(pair_bytes);
            let kr = out[3].as_f32()?;
            let vr = out[4].as_f32()?;
            let la = out[0].as_f32()?;
            for (i, lane) in lanes.iter().enumerate() {
                let lt = lane.tokens.len().saturating_sub(done).min(t);
                if lt == 0 {
                    continue;
                }
                let src = i * t * lrow;
                outs[i].new_k[done * lrow..(done + lt) * lrow]
                    .copy_from_slice(&kr[src..src + lt * lrow]);
                outs[i].new_v[done * lrow..(done + lt) * lrow]
                    .copy_from_slice(&vr[src..src + lt * lrow]);
                if done + lt == lane.tokens.len() {
                    let o = (i * t + lt - 1) * vocab;
                    outs[i].logits = la[o..o + vocab].to_vec();
                }
            }
            self.span_execs.fetch_add(1, Ordering::Relaxed);
            self.span_batched_execs.fetch_add(1, Ordering::Relaxed);
            let tile_tokens: usize = lanes
                .iter()
                .map(|l| l.tokens.len().saturating_sub(done).min(t))
                .sum();
            tracer.exec_end(tile_tokens);
            occupancy.push(occ);
            done += take;
        }
        Ok(SpanGroupOut {
            lanes: outs,
            executions: tiles.len(),
            occupancy,
            batch,
        })
    }

    /// Prefill `n` prompts (ragged, padded to the bucket's `[B, T]`).
    pub fn prefill(
        &self,
        path: StepPath,
        prompts: &[Vec<u32>],
    ) -> Result<PrefillOut> {
        let n = prompts.len();
        if n == 0 {
            return Err(Error::Engine("prefill: empty batch".into()));
        }
        if prompts.iter().any(|p| p.is_empty()) {
            return Err(Error::Engine("prefill: empty prompt".into()));
        }
        if path != StepPath::Baseline && !self.entry.config.rope {
            return Err(Error::Engine("precompute path requires RoPE".into()));
        }
        let tmax = prompts.iter().map(|p| p.len()).max().unwrap();
        let (b, t) = self.prefill_bucket(n, tmax, path)?;
        let cfg = &self.entry.config;
        let name = match path {
            StepPath::Baseline => format!("prefill_baseline_b{b}t{t}"),
            _ => format!("prefill_precomp_b{b}t{t}"),
        };
        let loaded = self.load_artifact(&name)?;
        let spec = &loaded.exe.spec;
        let tracer = self.rt.tracer();
        tracer.exec_begin(SpanKind::PrefillChunk, t, n);

        let mut lens: Vec<i32> = prompts.iter().map(|p| p.len() as i32).collect();
        // Padding sequences must still have len >= 1 to keep the masked
        // softmax + last-position gather well-defined; their output is
        // discarded.
        lens.resize(b, 1);

        let mut data_bufs: Vec<xla::PjRtBuffer> = Vec::new();
        match path {
            StepPath::Baseline => {
                let mut toks = vec![0i32; b * t];
                for (i, p) in prompts.iter().enumerate() {
                    for (j, tok) in p.iter().enumerate() {
                        toks[i * t + j] = *tok as i32;
                    }
                }
                data_bufs.push(self.rt.upload_i32(&toks, &[b, t])?);
            }
            _ => {
                self.faults.check(InjectPoint::Gather)?;
                let w = self.table.row_width();
                let mut rows = vec![0f32; b * t * w];
                let tg = tracer.now();
                for (i, p) in prompts.iter().enumerate() {
                    self.table
                        .gather(p, &mut rows[i * t * w..(i * t + p.len()) * w])?;
                }
                tracer.phase_since(Phase::Gather, tg);
                data_bufs.push(self.rt.upload_f32(&rows, &[b, t, w])?);
            }
        }
        data_bufs.push(self.rt.upload_i32(&lens, &[b])?);
        let mut args: Vec<&xla::PjRtBuffer> = data_bufs.iter().collect();
        for wb in &loaded.weight_bufs {
            args.push(wb);
        }
        let out = loaded.exe.execute_host(&args)?;
        let total_tokens: u64 = prompts.iter().map(|p| p.len() as u64).sum();
        tracer.exec_end(total_tokens as usize);
        self.traffic.record_prefill(cfg, path, total_tokens);

        let s = spec
            .max_seq
            .ok_or_else(|| Error::Engine("prefill artifact missing max_seq".into()))?;
        let vocab = cfg.vocab_size;
        let logits_all = out[0].as_f32()?;
        let mut logits = vec![0f32; n * vocab];
        logits.copy_from_slice(&logits_all[..n * vocab]);
        // Repack caches [L, b, S, ...] -> [L, n, S, ...] dropping pad seqs.
        let (l, kh, hd) = (cfg.n_layers, cfg.n_kv_heads, cfg.head_dim());
        let full_k = out[1].as_f32()?;
        let full_v = out[2].as_f32()?;
        let mut caches = CacheBatch::zeros(l, n, s, kh, hd);
        let row = s * kh * hd;
        for li in 0..l {
            for i in 0..n {
                let src = (li * b + i) * row;
                let dst = (li * n + i) * row;
                caches.k[dst..dst + row].copy_from_slice(&full_k[src..src + row]);
                caches.v[dst..dst + row].copy_from_slice(&full_v[src..src + row]);
            }
        }
        Ok(PrefillOut {
            logits,
            caches,
            bucket: (b, t),
        })
    }

    /// Rebuild the precompute table on-device via the `precompute_build`
    /// artifact (proves the offline pass is reproducible from the serving
    /// binary alone; used by `firstlayer precompute` and integration tests).
    pub fn build_table(&self) -> Result<Table> {
        let loaded = self.load_artifact("precompute_build")?;
        let spec = &loaded.exe.spec;
        let chunk = spec.inputs[0].shape[0];
        let cfg = &self.entry.config;
        let w = cfg.precomp_row_width();
        let vocab = cfg.vocab_size;
        let mut rows = vec![0f32; vocab * w];
        let mut start = 0usize;
        while start < vocab {
            let n = chunk.min(vocab - start);
            let mut toks: Vec<i32> = (start..start + n).map(|t| t as i32).collect();
            toks.resize(chunk, 0);
            let tok_buf = self.rt.upload_i32(&toks, &[chunk])?;
            let mut args: Vec<&xla::PjRtBuffer> = vec![&tok_buf];
            for wb in &loaded.weight_bufs {
                args.push(wb);
            }
            let out = loaded.exe.execute_host(&args)?;
            let data = out[0].as_f32()?;
            rows[start * w..(start + n) * w].copy_from_slice(&data[..n * w]);
            start += n;
        }
        let arch = match cfg.arch {
            crate::config::Arch::Parallel => crate::precompute::ARCH_PARALLEL,
            crate::config::Arch::Serial => crate::precompute::ARCH_SERIAL,
        };
        Table::from_rows(
            arch,
            cfg.d as u32,
            cfg.e() as u32,
            self.entry.weights_crc,
            &rows,
            vocab as u32,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::plan_span_tiles;

    #[test]
    fn span_tiling_covers_exactly_with_minimal_executions() {
        let buckets = [8usize, 32];
        // 64-token span, plenty of capacity: ceil(64/32) = 2 executions.
        let tiles = plan_span_tiles(&buckets, 64, 0, 128).unwrap();
        assert_eq!(tiles, vec![(32, 32), (32, 32)]);
        // Ragged: 40 = 32 + 8 (the tail picks the pad-minimal bucket).
        let tiles = plan_span_tiles(&buckets, 40, 10, 128).unwrap();
        assert_eq!(tiles, vec![(32, 32), (8, 8)]);
        // Shorter than every bucket: one padded execution.
        let tiles = plan_span_tiles(&buckets, 3, 5, 128).unwrap();
        assert_eq!(tiles, vec![(8, 3)]);
        // Mid-size: smallest covering bucket, not the largest.
        let tiles = plan_span_tiles(&buckets, 7, 0, 128).unwrap();
        assert_eq!(tiles, vec![(8, 7)]);
        for (n, start) in [(64usize, 0usize), (40, 10), (3, 5), (33, 60)] {
            let tiles = plan_span_tiles(&buckets, n, start, 128).unwrap();
            let total: usize = tiles.iter().map(|(_, t)| t).sum();
            assert_eq!(total, n);
            assert!(tiles.len() <= n.div_ceil(8));
            // Every tile's padded write stays inside the cache.
            let mut pos = start;
            for (b, t) in tiles {
                assert!(pos + b <= 128);
                pos += t;
            }
        }
    }

    #[test]
    fn span_tiling_respects_cache_capacity() {
        let buckets = [8usize, 32];
        // Span ending at capacity: the tail tile must shrink to a bucket
        // that still fits (120 + 8 = 128 <= 128).
        let tiles = plan_span_tiles(&buckets, 40, 88, 128).unwrap();
        let mut pos = 88;
        for &(b, t) in &tiles {
            assert!(pos + b <= 128, "tile ({b},{t}) at {pos} would clamp");
            pos += t;
        }
        assert_eq!(pos, 128);
        // No bucket fits at all (125 + 8 > 128): the caller must fall
        // back token-by-token, never risk a clamped cache write.
        assert!(plan_span_tiles(&buckets, 3, 125, 128).is_none());
        // No compiled buckets: nothing to plan with.
        assert!(plan_span_tiles(&[], 4, 0, 128).is_none());
    }

    #[test]
    fn span_group_plan_from_rightmost_lane_guards_every_lane() {
        // A group plans over the LONGEST lane from the RIGHTMOST start;
        // every lane's per-tile write (bucket slots from start_b + done,
        // advancing even while inert) must then stay inside the cache.
        let buckets = [8usize, 32];
        let s = 128;
        let starts = [10usize, 30, 88];
        let lens = [40usize, 17, 8];
        let max_len = *lens.iter().max().unwrap();
        let max_start = *starts.iter().max().unwrap();
        let tiles = plan_span_tiles(&buckets, max_len, max_start, s).unwrap();
        let total: usize = tiles.iter().map(|(_, t)| t).sum();
        assert_eq!(total, max_len);
        let mut done = 0usize;
        for &(b, take) in &tiles {
            for &st in &starts {
                assert!(
                    st + done + b <= s,
                    "lane at {st} tile ({b},{take}) offset {done} would clamp"
                );
            }
            done += take;
        }
        // Ragged lanes go inert mid-group: lane 2 (len 8) is exhausted
        // after tile 0 regardless of the tile split.
        let first_take = tiles[0].1;
        assert!(first_take >= 8);
    }
}
