//! Model engine: one loaded model (weights + table + executables) with
//! decode/prefill step entry points for both serving paths.
//!
//! The engine is deliberately *stateless about sequences* — the coordinator
//! owns the paged KV store and batch composition; the engine turns one
//! assembled step into PJRT calls:
//!
//! * weights are uploaded to the device once at construction and reused by
//!   every call (`execute_b`),
//! * `decode` gathers precomputed rows from the mmap'd table (precompute
//!   path) or passes token ids (baseline),
//! * `decode_span` advances one sequence through a chunk of prompt tokens
//!   (chunked prefill), serving the whole span's first layer from the
//!   table in a single batched row-gather,
//! * returns the logits plus only the *new* K/V rows extracted from the
//!   returned caches, so the paged store is updated with one row per
//!   (layer, sequence) instead of a full-cache writeback.

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::{Arc, Mutex};

use crate::config::ModelConfig;
use crate::error::{Error, Result};
use crate::manifest::{ArtifactKind, Manifest, ModelEntry};
use crate::precompute::{validate_table, Table};
use crate::simtraffic::Recorder;
use crate::weights::WeightsFile;

use super::{Executable, HostTensor, Runtime};

/// Which serving path a step runs (the paper's comparison axis).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StepPath {
    /// Full first layer from the embedding (Figure 1a / 2b).
    Baseline,
    /// Precomputed first layer: table gather + attention only (Fig 1b / 2c).
    Precompute,
    /// Ablation: precompute with the gather *inside* the graph (the table
    /// lives as a device buffer).
    PrecomputeGather,
}

impl StepPath {
    pub fn label(self) -> &'static str {
        match self {
            StepPath::Baseline => "baseline",
            StepPath::Precompute => "precompute",
            StepPath::PrecomputeGather => "precompute-gather",
        }
    }
}

/// Dense batched KV cache input: `[L, B, S, KH, hd]` f32, row-major.
#[derive(Debug, Clone)]
pub struct CacheBatch {
    pub l: usize,
    pub b: usize,
    pub s: usize,
    pub kh: usize,
    pub hd: usize,
    pub k: Vec<f32>,
    pub v: Vec<f32>,
}

impl CacheBatch {
    pub fn zeros(l: usize, b: usize, s: usize, kh: usize, hd: usize) -> CacheBatch {
        let n = l * b * s * kh * hd;
        CacheBatch {
            l,
            b,
            s,
            kh,
            hd,
            k: vec![0.0; n],
            v: vec![0.0; n],
        }
    }

    pub fn dims(&self) -> [usize; 5] {
        [self.l, self.b, self.s, self.kh, self.hd]
    }

    /// Offset of `[layer, seq, slot, 0, 0]`.
    pub fn offset(&self, layer: usize, seq: usize, slot: usize) -> usize {
        ((layer * self.b + seq) * self.s + slot) * self.kh * self.hd
    }

    /// One (layer, seq, slot) row, `kh*hd` long.
    pub fn row<'a>(
        &self,
        kv: &'a [f32],
        layer: usize,
        seq: usize,
        slot: usize,
    ) -> &'a [f32] {
        let o = self.offset(layer, seq, slot);
        &kv[o..o + self.kh * self.hd]
    }
}

/// Result of one decode step over `n` real sequences.
#[derive(Debug, Clone)]
pub struct DecodeOut {
    /// `[n, vocab]` logits for the sampled next token.
    pub logits: Vec<f32>,
    /// New K rows: `[n, L, kh*hd]` (seq-major for easy page writeback).
    pub new_k: Vec<f32>,
    /// New V rows, same layout.
    pub new_v: Vec<f32>,
    /// The compiled batch bucket that served this step.
    pub bucket: usize,
}

/// Result of a prefill over `n` real sequences.
#[derive(Debug, Clone)]
pub struct PrefillOut {
    /// `[n, vocab]` logits at each sequence's last prompt position.
    pub logits: Vec<f32>,
    /// Full caches `[L, n, S, KH, hd]` (slots < len valid).
    pub caches: CacheBatch,
    pub bucket: (usize, usize),
}

/// Result of advancing ONE sequence through a span of prompt tokens
/// ([`ModelEngine::decode_span`]: chunked-prefill continuations and
/// post-preemption replays).
#[derive(Debug, Clone)]
pub struct SpanOut {
    /// `[vocab]` logits after the last span token.
    pub logits: Vec<f32>,
    /// New K rows for the span: `[n, L, kh*hd]`, token-major append order.
    pub new_k: Vec<f32>,
    /// New V rows, same layout.
    pub new_v: Vec<f32>,
}

struct Loaded {
    exe: Arc<Executable>,
    /// Device-resident weight buffers in artifact parameter order.
    weight_bufs: Vec<Arc<xla::PjRtBuffer>>,
}

/// One loaded model.
pub struct ModelEngine {
    rt: Runtime,
    entry: ModelEntry,
    dir: PathBuf,
    weights: WeightsFile,
    table: Table,
    /// Tensor-name → uploaded device buffer (shared across artifacts).
    buf_by_name: Mutex<HashMap<String, Arc<xla::PjRtBuffer>>>,
    loaded: Mutex<HashMap<String, Arc<Loaded>>>,
    pub traffic: Arc<Recorder>,
}

impl ModelEngine {
    pub fn load(rt: &Runtime, manifest: &Manifest, model: &str) -> Result<ModelEngine> {
        let entry = manifest.model(model)?.clone();
        let weights = WeightsFile::load(manifest.path(&entry.weights_file))?;
        // Sanity: every manifest weight tensor exists on disk.
        for name in &entry.weights_order {
            weights.get(name)?;
        }
        let table = Table::open(manifest.path(&entry.table_file))?;
        validate_table(&table, &entry.config, entry.weights_crc)?;
        Ok(ModelEngine {
            rt: rt.clone(),
            entry,
            dir: manifest.dir.clone(),
            weights,
            table,
            buf_by_name: Mutex::new(HashMap::new()),
            loaded: Mutex::new(HashMap::new()),
            traffic: Arc::new(Recorder::new()),
        })
    }

    pub fn config(&self) -> &ModelConfig {
        &self.entry.config
    }

    pub fn entry(&self) -> &ModelEntry {
        &self.entry
    }

    pub fn table(&self) -> &Table {
        &self.table
    }

    pub fn weights(&self) -> &WeightsFile {
        &self.weights
    }

    /// Upload (or fetch the cached) device buffer for a weight tensor or
    /// the `@table` pseudo-tensor.
    fn weight_buffer(&self, name: &str) -> Result<Arc<xla::PjRtBuffer>> {
        if let Some(b) = self.buf_by_name.lock().unwrap().get(name) {
            return Ok(b.clone());
        }
        let buf = if name == "@table" {
            let rows = self.table.gather_vec(
                &(0..self.table.vocab() as u32).collect::<Vec<_>>(),
            )?;
            self.rt
                .upload_f32(&rows, &[self.table.vocab(), self.table.row_width()])?
        } else {
            let t = self.weights.get(name)?;
            let data = t.to_f32_vec()?;
            self.rt.upload_f32(&data, &t.dims)?
        };
        let buf = Arc::new(buf);
        self.buf_by_name
            .lock()
            .unwrap()
            .insert(name.to_string(), buf.clone());
        Ok(buf)
    }

    fn load_artifact(&self, name: &str) -> Result<Arc<Loaded>> {
        if let Some(l) = self.loaded.lock().unwrap().get(name) {
            return Ok(l.clone());
        }
        let spec = self.entry.artifact(name)?.clone();
        let exe = self.rt.load(&self.dir.join(&spec.file), spec.clone())?;
        let mut weight_bufs = Vec::with_capacity(spec.weight_params.len());
        for w in &spec.weight_params {
            weight_bufs.push(self.weight_buffer(w)?);
        }
        let loaded = Arc::new(Loaded { exe, weight_bufs });
        self.loaded
            .lock()
            .unwrap()
            .insert(name.to_string(), loaded.clone());
        Ok(loaded)
    }

    /// Eagerly compile every artifact of a path family (avoids first-request
    /// latency spikes; `firstlayer serve --warmup`).
    pub fn warmup(&self, path: StepPath) -> Result<()> {
        let names: Vec<String> = self
            .entry
            .artifacts
            .iter()
            .filter(|a| match path {
                StepPath::Baseline => a.name.contains("baseline"),
                StepPath::Precompute => {
                    a.name.contains("precomp") && !a.name.contains("gather")
                }
                StepPath::PrecomputeGather => a.name.contains("gather"),
            })
            .map(|a| a.name.clone())
            .collect();
        for n in names {
            self.load_artifact(&n)?;
        }
        Ok(())
    }

    /// Smallest compiled decode bucket that fits `n` sequences.
    pub fn decode_bucket(&self, n: usize, path: StepPath) -> Result<usize> {
        let precomp = path != StepPath::Baseline;
        let prefix = match path {
            StepPath::Baseline => "decode_baseline_b",
            StepPath::Precompute => "decode_precomp_b",
            StepPath::PrecomputeGather => "decode_precomp_gather_b",
        };
        self.entry
            .artifacts
            .iter()
            .filter(|a| a.name.starts_with(prefix) && a.kind == ArtifactKind::Decode)
            .filter_map(|a| a.batch)
            .filter(|b| *b >= n)
            .min()
            .ok_or_else(|| {
                Error::Engine(format!(
                    "no decode bucket >= {n} for path {} (precomp={precomp})",
                    path.label()
                ))
            })
    }

    /// Smallest compiled prefill bucket fitting `n` sequences of `t` tokens.
    pub fn prefill_bucket(&self, n: usize, t: usize, path: StepPath) -> Result<(usize, usize)> {
        let prefix = match path {
            StepPath::Baseline => "prefill_baseline_b",
            _ => "prefill_precomp_b",
        };
        self.entry
            .artifacts
            .iter()
            .filter(|a| a.name.starts_with(prefix))
            .filter_map(|a| Some((a.batch?, a.prompt_len?)))
            .filter(|(b, pt)| *b >= n && *pt >= t)
            .min()
            .ok_or_else(|| {
                Error::Engine(format!("no prefill bucket >= ({n}, {t})"))
            })
    }

    /// One decode step.  `tokens[i]` is the token to feed for sequence `i`,
    /// `pos[i]` its position (= current length), `caches` the dense batch
    /// KV with `b == bucket` rows (callers pad with zero rows).
    pub fn decode(
        &self,
        path: StepPath,
        tokens: &[u32],
        pos: &[u32],
        caches: &CacheBatch,
    ) -> Result<DecodeOut> {
        self.decode_inner(path, tokens, pos, caches, None, true)
    }

    /// Decode with optionally pre-gathered table rows (`n * row_width`
    /// f32s) — [`ModelEngine::decode_span`] batches the whole span's table
    /// read up front — and optional traffic recording (span tokens are
    /// accounted as prefill, not decode, traffic).
    fn decode_inner(
        &self,
        path: StepPath,
        tokens: &[u32],
        pos: &[u32],
        caches: &CacheBatch,
        pregathered: Option<&[f32]>,
        record: bool,
    ) -> Result<DecodeOut> {
        let n = tokens.len();
        if n == 0 || n != pos.len() {
            return Err(Error::Engine("decode: empty or mismatched batch".into()));
        }
        if path != StepPath::Baseline && !self.entry.config.rope {
            return Err(Error::Engine(
                "precompute path requires RoPE (paper §2 — abs-PE models \
                 cannot precompute the first layer)"
                    .into(),
            ));
        }
        let bucket = self.decode_bucket(n, path)?;
        let cfg = &self.entry.config;
        if caches.b != bucket {
            return Err(Error::Engine(format!(
                "caches padded to {} but bucket is {bucket}",
                caches.b
            )));
        }
        let name = match path {
            StepPath::Baseline => format!("decode_baseline_b{bucket}"),
            StepPath::Precompute => format!("decode_precomp_b{bucket}"),
            StepPath::PrecomputeGather => format!("decode_precomp_gather_b{bucket}"),
        };
        let loaded = self.load_artifact(&name)?;

        // Pad per-token inputs out to the bucket.
        let mut pos_p: Vec<i32> = pos.iter().map(|p| *p as i32).collect();
        pos_p.resize(bucket, 0);

        // Data inputs per path.
        let mut data_bufs: Vec<xla::PjRtBuffer> = Vec::new();
        match path {
            StepPath::Baseline | StepPath::PrecomputeGather => {
                let mut toks: Vec<i32> = tokens.iter().map(|t| *t as i32).collect();
                toks.resize(bucket, 0);
                data_bufs.push(self.rt.upload_i32(&toks, &[bucket])?);
            }
            StepPath::Precompute => {
                // The paper's runtime read: one 2(d+e) row per token
                // (already gathered when the caller batched a whole span).
                let w = self.table.row_width();
                let mut rows = vec![0f32; bucket * w];
                match pregathered {
                    Some(r) if r.len() == n * w => rows[..n * w].copy_from_slice(r),
                    Some(r) => {
                        return Err(Error::Engine(format!(
                            "decode: pregathered rows len {} != {}",
                            r.len(),
                            n * w
                        )))
                    }
                    None => self.table.gather(tokens, &mut rows[..n * w])?,
                }
                data_bufs.push(self.rt.upload_f32(&rows, &[bucket, w])?);
            }
        }
        data_bufs.push(self.rt.upload_i32(&pos_p, &[bucket])?);
        let t_up = std::time::Instant::now();
        data_bufs.push(self.rt.upload_f32(&caches.k, &caches.dims().to_vec())?);
        data_bufs.push(self.rt.upload_f32(&caches.v, &caches.dims().to_vec())?);
        let up = t_up.elapsed();

        let mut args: Vec<&xla::PjRtBuffer> = data_bufs.iter().collect();
        for wb in &loaded.weight_bufs {
            args.push(wb);
        }
        let t_exec = std::time::Instant::now();
        let out = loaded.exe.execute_host(&args)?;
        let exec = t_exec.elapsed();
        if record {
            self.traffic.record_decode(cfg, path, n as u64);
        }
        let t_unpack = std::time::Instant::now();
        let res = self.unpack_decode(out, n, bucket, pos, caches);
        if std::env::var_os("FIRSTLAYER_TRACE").is_some() {
            eprintln!(
                "[trace] decode {} B={n}/{bucket}: upload={up:?} exec+readback={exec:?} unpack={:?}",
                path.label(),
                t_unpack.elapsed()
            );
        }
        res
    }

    fn unpack_decode(
        &self,
        out: Vec<HostTensor>,
        n: usize,
        bucket: usize,
        pos: &[u32],
        caches: &CacheBatch,
    ) -> Result<DecodeOut> {
        let cfg = &self.entry.config;
        let vocab = cfg.vocab_size;
        let logits_all = out[0].as_f32()?;
        let kc = out[1].as_f32()?;
        let vc = out[2].as_f32()?;
        let row = caches.kh * caches.hd;
        let mut logits = vec![0f32; n * vocab];
        logits.copy_from_slice(&logits_all[..n * vocab]);
        let mut new_k = vec![0f32; n * caches.l * row];
        let mut new_v = vec![0f32; n * caches.l * row];
        // Extract the freshly written slot pos[i] per (seq, layer): the only
        // rows the paged store needs.
        let out_cb = CacheBatch {
            l: caches.l,
            b: bucket,
            s: caches.s,
            kh: caches.kh,
            hd: caches.hd,
            k: Vec::new(),
            v: Vec::new(),
        };
        for i in 0..n {
            for l in 0..caches.l {
                let o = out_cb.offset(l, i, pos[i] as usize);
                let dst = (i * caches.l + l) * row;
                new_k[dst..dst + row].copy_from_slice(&kc[o..o + row]);
                new_v[dst..dst + row].copy_from_slice(&vc[o..o + row]);
            }
        }
        Ok(DecodeOut {
            logits,
            new_k,
            new_v,
            bucket,
        })
    }

    /// Advance ONE sequence through `tokens` starting at absolute position
    /// `start_pos` — the chunked-prefill continuation path (and the
    /// post-preemption replay of over-bucket prompts).
    ///
    /// `caches` holds the sequence's history in batch row 0, padded to the
    /// B=1 decode bucket.  The first layer of the WHOLE span is served from
    /// the precompute table in one batched row-gather (the paper's read
    /// pattern: `len·2(d+e)` contiguous values); attention then advances
    /// token by token through the compiled decode artifact, with each new
    /// K/V row scattered into `caches` host-side so the next token attends
    /// to it.  Span tokens are recorded as prefill traffic.
    pub fn decode_span(
        &self,
        path: StepPath,
        tokens: &[u32],
        start_pos: usize,
        caches: &mut CacheBatch,
    ) -> Result<SpanOut> {
        let n = tokens.len();
        if n == 0 {
            return Err(Error::Engine("decode_span: empty span".into()));
        }
        if start_pos + n > caches.s {
            return Err(Error::Engine(format!(
                "decode_span: span end {} exceeds cache capacity {}",
                start_pos + n,
                caches.s
            )));
        }
        let cfg = self.entry.config.clone();
        let w = self.table.row_width();
        let rows = if path == StepPath::Precompute {
            Some(self.table.gather_vec(tokens)?)
        } else {
            None
        };
        self.traffic.record_prefill(&cfg, path, n as u64);
        let row = caches.kh * caches.hd;
        let lrow = caches.l * row;
        let mut new_k = vec![0f32; n * lrow];
        let mut new_v = vec![0f32; n * lrow];
        let mut logits = Vec::new();
        for (i, &tok) in tokens.iter().enumerate() {
            let pos = start_pos + i;
            let pre = rows.as_ref().map(|r| &r[i * w..(i + 1) * w]);
            // Known cost: decode_inner re-uploads the full dense cache per
            // token even though only the previous position changed — a
            // device-resident cache buffer reused across the span would cut
            // host-to-device traffic by the span length (open ROADMAP
            // item; requires donated/aliased PJRT buffers).
            let out =
                self.decode_inner(path, &[tok], &[pos as u32], caches, pre, false)?;
            // Scatter the fresh row so the next span token attends to it.
            for l in 0..caches.l {
                let o = caches.offset(l, 0, pos);
                let src = l * row..(l + 1) * row;
                caches.k[o..o + row].copy_from_slice(&out.new_k[src.clone()]);
                caches.v[o..o + row].copy_from_slice(&out.new_v[src]);
            }
            new_k[i * lrow..(i + 1) * lrow].copy_from_slice(&out.new_k);
            new_v[i * lrow..(i + 1) * lrow].copy_from_slice(&out.new_v);
            logits = out.logits;
        }
        Ok(SpanOut {
            logits,
            new_k,
            new_v,
        })
    }

    /// Prefill `n` prompts (ragged, padded to the bucket's `[B, T]`).
    pub fn prefill(
        &self,
        path: StepPath,
        prompts: &[Vec<u32>],
    ) -> Result<PrefillOut> {
        let n = prompts.len();
        if n == 0 {
            return Err(Error::Engine("prefill: empty batch".into()));
        }
        if prompts.iter().any(|p| p.is_empty()) {
            return Err(Error::Engine("prefill: empty prompt".into()));
        }
        if path != StepPath::Baseline && !self.entry.config.rope {
            return Err(Error::Engine("precompute path requires RoPE".into()));
        }
        let tmax = prompts.iter().map(|p| p.len()).max().unwrap();
        let (b, t) = self.prefill_bucket(n, tmax, path)?;
        let cfg = &self.entry.config;
        let name = match path {
            StepPath::Baseline => format!("prefill_baseline_b{b}t{t}"),
            _ => format!("prefill_precomp_b{b}t{t}"),
        };
        let loaded = self.load_artifact(&name)?;
        let spec = &loaded.exe.spec;

        let mut lens: Vec<i32> = prompts.iter().map(|p| p.len() as i32).collect();
        // Padding sequences must still have len >= 1 to keep the masked
        // softmax + last-position gather well-defined; their output is
        // discarded.
        lens.resize(b, 1);

        let mut data_bufs: Vec<xla::PjRtBuffer> = Vec::new();
        match path {
            StepPath::Baseline => {
                let mut toks = vec![0i32; b * t];
                for (i, p) in prompts.iter().enumerate() {
                    for (j, tok) in p.iter().enumerate() {
                        toks[i * t + j] = *tok as i32;
                    }
                }
                data_bufs.push(self.rt.upload_i32(&toks, &[b, t])?);
            }
            _ => {
                let w = self.table.row_width();
                let mut rows = vec![0f32; b * t * w];
                for (i, p) in prompts.iter().enumerate() {
                    self.table
                        .gather(p, &mut rows[i * t * w..(i * t + p.len()) * w])?;
                }
                data_bufs.push(self.rt.upload_f32(&rows, &[b, t, w])?);
            }
        }
        data_bufs.push(self.rt.upload_i32(&lens, &[b])?);
        let mut args: Vec<&xla::PjRtBuffer> = data_bufs.iter().collect();
        for wb in &loaded.weight_bufs {
            args.push(wb);
        }
        let out = loaded.exe.execute_host(&args)?;
        let total_tokens: u64 = prompts.iter().map(|p| p.len() as u64).sum();
        self.traffic.record_prefill(cfg, path, total_tokens);

        let s = spec
            .max_seq
            .ok_or_else(|| Error::Engine("prefill artifact missing max_seq".into()))?;
        let vocab = cfg.vocab_size;
        let logits_all = out[0].as_f32()?;
        let mut logits = vec![0f32; n * vocab];
        logits.copy_from_slice(&logits_all[..n * vocab]);
        // Repack caches [L, b, S, ...] -> [L, n, S, ...] dropping pad seqs.
        let (l, kh, hd) = (cfg.n_layers, cfg.n_kv_heads, cfg.head_dim());
        let full_k = out[1].as_f32()?;
        let full_v = out[2].as_f32()?;
        let mut caches = CacheBatch::zeros(l, n, s, kh, hd);
        let row = s * kh * hd;
        for li in 0..l {
            for i in 0..n {
                let src = (li * b + i) * row;
                let dst = (li * n + i) * row;
                caches.k[dst..dst + row].copy_from_slice(&full_k[src..src + row]);
                caches.v[dst..dst + row].copy_from_slice(&full_v[src..src + row]);
            }
        }
        Ok(PrefillOut {
            logits,
            caches,
            bucket: (b, t),
        })
    }

    /// Rebuild the precompute table on-device via the `precompute_build`
    /// artifact (proves the offline pass is reproducible from the serving
    /// binary alone; used by `firstlayer precompute` and integration tests).
    pub fn build_table(&self) -> Result<Table> {
        let loaded = self.load_artifact("precompute_build")?;
        let spec = &loaded.exe.spec;
        let chunk = spec.inputs[0].shape[0];
        let cfg = &self.entry.config;
        let w = cfg.precomp_row_width();
        let vocab = cfg.vocab_size;
        let mut rows = vec![0f32; vocab * w];
        let mut start = 0usize;
        while start < vocab {
            let n = chunk.min(vocab - start);
            let mut toks: Vec<i32> = (start..start + n).map(|t| t as i32).collect();
            toks.resize(chunk, 0);
            let tok_buf = self.rt.upload_i32(&toks, &[chunk])?;
            let mut args: Vec<&xla::PjRtBuffer> = vec![&tok_buf];
            for wb in &loaded.weight_bufs {
                args.push(wb);
            }
            let out = loaded.exe.execute_host(&args)?;
            let data = out[0].as_f32()?;
            rows[start * w..(start + n) * w].copy_from_slice(&data[..n * w]);
            start += n;
        }
        let arch = match cfg.arch {
            crate::config::Arch::Parallel => crate::precompute::ARCH_PARALLEL,
            crate::config::Arch::Serial => crate::precompute::ARCH_SERIAL,
        };
        Table::from_rows(
            arch,
            cfg.d as u32,
            cfg.e() as u32,
            self.entry.weights_crc,
            &rows,
            vocab as u32,
        )
    }
}
