//! PJRT runtime: load AOT HLO-text artifacts and execute them.
//!
//! Wraps the `xla` crate (xla_extension 0.5.1): `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `compile` → `execute`.  HLO *text* is
//! the interchange format (jax ≥ 0.5 protos use 64-bit ids this XLA
//! rejects).  All artifacts are lowered with `return_tuple=True`; outputs
//! may surface as one tuple literal or as untupled buffers depending on the
//! PJRT wrapper — [`Executable::execute`] normalizes both.

mod engine;
mod session;

pub use engine::{
    CacheBatch, DecodeOut, ModelEngine, PrefillOut, SpanGroupOut, SpanLane, SpanLaneOut,
    SpanOut, StepPath,
};
pub use session::DeviceCacheSession;

use std::collections::HashMap;
use std::path::Path;
use std::sync::{Arc, Mutex, OnceLock};

use crate::error::{Error, Result};
use crate::faults::{FaultPlane, InjectPoint};
use crate::manifest::{ArtifactSpec, DType, IoSpec};
use crate::metrics::TransferStats;
use crate::trace::{Phase, Tracer};

/// Cached `FIRSTLAYER_TRACE` lookup — the env var cannot change mid-run,
/// so it is read once per process instead of once per decode step /
/// artifact execution (hot-path hygiene).
pub(crate) fn trace_enabled() -> bool {
    static TRACE: OnceLock<bool> = OnceLock::new();
    *TRACE.get_or_init(|| std::env::var_os("FIRSTLAYER_TRACE").is_some())
}

/// Shared PJRT client handle.
#[derive(Clone)]
pub struct Runtime {
    client: Arc<xla::PjRtClient>,
    /// Compile cache keyed by artifact file path.
    cache: Arc<Mutex<HashMap<String, Arc<Executable>>>>,
    /// Host↔device transfer accounting (uploads here, readbacks in
    /// [`Executable`] and [`DeviceCacheSession`]).
    transfers: Arc<TransferStats>,
    /// Lifecycle/phase tracer (disabled by default; see [`crate::trace`]).
    tracer: Arc<Tracer>,
    /// Fault-injection plane (disarmed by default; see [`crate::faults`]).
    faults: Arc<FaultPlane>,
}

impl Runtime {
    pub fn cpu() -> Result<Runtime> {
        Ok(Runtime {
            client: Arc::new(xla::PjRtClient::cpu()?),
            cache: Arc::new(Mutex::new(HashMap::new())),
            transfers: Arc::new(TransferStats::new()),
            tracer: Arc::new(Tracer::new()),
            faults: Arc::new(FaultPlane::new()),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn client(&self) -> &xla::PjRtClient {
        &self.client
    }

    /// The runtime's transfer counters (shared with every clone).
    pub fn transfers(&self) -> Arc<TransferStats> {
        self.transfers.clone()
    }

    /// The runtime's lifecycle tracer (shared with every clone).
    pub fn tracer(&self) -> Arc<Tracer> {
        self.tracer.clone()
    }

    /// The runtime's fault-injection plane (shared with every clone and
    /// every [`Executable`]/[`DeviceCacheSession`] it creates).
    pub fn faults(&self) -> Arc<FaultPlane> {
        self.faults.clone()
    }

    /// Load + compile an HLO text artifact (cached by path).
    pub fn load(&self, path: &Path, spec: ArtifactSpec) -> Result<Arc<Executable>> {
        let key = path.to_string_lossy().to_string();
        if let Some(e) = self.cache.lock().unwrap().get(&key) {
            return Ok(e.clone());
        }
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str()
                .ok_or_else(|| Error::other("non-utf8 artifact path"))?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        let exe = Arc::new(Executable {
            exe,
            spec,
            stats: self.transfers.clone(),
            tracer: self.tracer.clone(),
            faults: self.faults.clone(),
        });
        self.cache
            .lock()
            .unwrap()
            .insert(key, exe.clone());
        Ok(exe)
    }

    /// Upload a host f32 tensor to the device.
    pub fn upload_f32(&self, data: &[f32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        self.faults.check(InjectPoint::H2d)?;
        self.transfers.record_h2d(data.len() as u64 * 4, 1);
        let t0 = self.tracer.now();
        let buf = self.client.buffer_from_host_buffer(data, dims, None)?;
        self.tracer.phase_since(Phase::H2d, t0);
        Ok(buf)
    }

    /// Upload a host i32 tensor to the device.
    pub fn upload_i32(&self, data: &[i32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        self.faults.check(InjectPoint::H2d)?;
        self.transfers.record_h2d(data.len() as u64 * 4, 1);
        let t0 = self.tracer.now();
        let buf = self.client.buffer_from_host_buffer(data, dims, None)?;
        self.tracer.phase_since(Phase::H2d, t0);
        Ok(buf)
    }
}

/// Host-side value for one artifact input/output.
#[derive(Debug, Clone)]
pub enum HostTensor {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl HostTensor {
    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            HostTensor::F32(v) => Ok(v),
            _ => Err(Error::Engine("expected f32 tensor".into())),
        }
    }
    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            HostTensor::I32(v) => Ok(v),
            _ => Err(Error::Engine("expected i32 tensor".into())),
        }
    }
    pub fn len(&self) -> usize {
        match self {
            HostTensor::F32(v) => v.len(),
            HostTensor::I32(v) => v.len(),
        }
    }
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// A compiled artifact bound to its manifest signature.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    pub spec: ArtifactSpec,
    stats: Arc<TransferStats>,
    tracer: Arc<Tracer>,
    faults: Arc<FaultPlane>,
}

impl Executable {
    /// Execute with device buffers (weights stay resident across calls).
    /// Returns one buffer per output leaf.
    pub fn execute_buffers(
        &self,
        args: &[&xla::PjRtBuffer],
    ) -> Result<Vec<xla::PjRtBuffer>> {
        self.faults.check(InjectPoint::Exec)?;
        let t0 = self.tracer.now();
        let out = self.exe.execute_b(args)?;
        self.tracer.phase_since(Phase::Exec, t0);
        let row = out
            .into_iter()
            .next()
            .ok_or_else(|| Error::Engine("no outputs".into()))?;
        Ok(row)
    }

    /// Execute and read every output back to host, normalizing the
    /// tuple-vs-untupled output convention.
    pub fn execute_host(&self, args: &[&xla::PjRtBuffer]) -> Result<Vec<HostTensor>> {
        let t0 = std::time::Instant::now();
        let bufs = self.execute_buffers(args)?;
        let exec_d = t0.elapsed();
        let t1 = std::time::Instant::now();
        let out = self.read_back(bufs);
        if trace_enabled() {
            eprintln!(
                "[trace]   {}: execute={exec_d:?} readback={:?}",
                self.spec.name,
                t1.elapsed()
            );
        }
        out
    }

    /// Read ONE output buffer back to host (selective readback: the
    /// device-resident decode path reads logits this way and leaves the
    /// cache outputs on the device for the next chained step).  `idx` is
    /// the output's position in the artifact signature; the caller must
    /// pass a buffer from an *untupled* [`Executable::execute_buffers`]
    /// result.
    pub fn read_output(&self, buf: &xla::PjRtBuffer, idx: usize) -> Result<HostTensor> {
        self.faults.check(InjectPoint::Readback)?;
        let io = self
            .spec
            .outputs
            .get(idx)
            .ok_or_else(|| Error::Engine(format!("{}: no output {idx}", self.spec.name)))?;
        let t0 = self.tracer.now();
        let lit = buf.to_literal_sync()?;
        let out = host_tensor(&lit, io)?;
        self.tracer.phase_since(Phase::Readback, t0);
        self.stats.record_d2h(out.len() as u64 * 4, 1);
        Ok(out)
    }

    fn read_back(&self, bufs: Vec<xla::PjRtBuffer>) -> Result<Vec<HostTensor>> {
        self.faults.check(InjectPoint::Readback)?;
        let tr0 = self.tracer.now();
        let n_out = self.spec.outputs.len();
        let tupled = bufs.len() == 1
            && bufs[0]
                .on_device_shape()
                .map(|s| s.is_tuple())
                .unwrap_or(false);
        let literals: Vec<xla::Literal> = if bufs.len() == n_out && !tupled {
            bufs.iter()
                .map(|b| b.to_literal_sync().map_err(Error::from))
                .collect::<Result<_>>()?
        } else if bufs.len() == 1 {
            // Single tuple buffer: decompose on the host.
            let mut lit = bufs[0].to_literal_sync()?;
            let parts = lit.decompose_tuple()?;
            if parts.len() != n_out {
                return Err(Error::Engine(format!(
                    "{}: tuple arity {} != {} outputs",
                    self.spec.name,
                    parts.len(),
                    n_out
                )));
            }
            parts
        } else {
            return Err(Error::Engine(format!(
                "{}: unexpected output count {} (want {n_out})",
                self.spec.name,
                bufs.len()
            )));
        };
        let out: Vec<HostTensor> = literals
            .iter()
            .zip(&self.spec.outputs)
            .map(|(lit, io)| host_tensor(lit, io))
            .collect::<Result<_>>()?;
        let bytes: u64 = out.iter().map(|t| t.len() as u64 * 4).sum();
        self.stats.record_d2h(bytes, out.len() as u64);
        self.tracer.phase_since(Phase::Readback, tr0);
        Ok(out)
    }
}

fn host_tensor(lit: &xla::Literal, io: &IoSpec) -> Result<HostTensor> {
    let out = match io.dtype {
        DType::F32 => HostTensor::F32(lit.to_vec::<f32>()?),
        DType::I32 => HostTensor::I32(lit.to_vec::<i32>()?),
    };
    if out.len() != io.elems() {
        return Err(Error::Engine(format!(
            "output `{}`: {} elems, expected {}",
            io.name,
            out.len(),
            io.elems()
        )));
    }
    Ok(out)
}
