//! Device-resident KV cache session: the buffer-chaining half of the
//! device-resident serving path (see `ARCHITECTURE.md` §Device-resident
//! KV).
//!
//! A [`DeviceCacheSession`] uploads one dense `[L, B, S, KH, hd]` K/V
//! [`CacheBatch`] to the device ONCE and then hands the live buffer pair
//! to every subsequent decode step as execution arguments; each step's
//! output cache buffers (the decode artifacts return the full updated
//! caches as PJRT buffers) are swapped in as the next step's inputs via
//! [`DeviceCacheSession::advance`].  While the session is live, the only
//! per-step device→host traffic is the logits tensor — the cache crosses
//! the bus exactly twice per session lifetime: once up at `begin`, once
//! down at the first [`DeviceCacheSession::read_cache_pair`] sync.
//!
//! Sync points are explicit and owned by the caller (`ModelEngine` for
//! spans, the coordinator for steady-state decode): span end, decode
//! batch recomposition, preemption, serving-path switch, and paged-store
//! writeback.  The PJRT wrapper (`xla` 0.5.1) only exposes whole-buffer
//! literal transfer, so a sync reads the full pair and the caller slices
//! out the freshly written rows host-side; "selective readback" is
//! therefore about *frequency* (one pair per session instead of one per
//! token) plus the logits-only per-step read.
//!
//! The session never owns a PJRT client — buffers keep their client
//! alive — and is `!Send` like every other PJRT handle: it lives and
//! dies on the engine thread.

use std::sync::Arc;

use crate::error::{Error, Result};
use crate::faults::{FaultPlane, InjectPoint};
use crate::metrics::TransferStats;
use crate::trace::{Phase, Tracer};

use super::{CacheBatch, Runtime};

/// A device-resident K/V cache pair being advanced by chained decode
/// steps.
pub struct DeviceCacheSession {
    k: xla::PjRtBuffer,
    v: xla::PjRtBuffer,
    /// `[L, B, S, KH, hd]` of the resident pair.
    dims: [usize; 5],
    /// Chained steps executed since `begin` (diagnostics).
    steps: u64,
    stats: Arc<TransferStats>,
    tracer: Arc<Tracer>,
    faults: Arc<FaultPlane>,
}

impl DeviceCacheSession {
    /// Upload `caches` once and open the session.  This is the single
    /// cache-pair host→device transfer of the session's lifetime.
    pub(crate) fn begin(rt: &Runtime, caches: &CacheBatch) -> Result<DeviceCacheSession> {
        let dims = caches.dims();
        let shape = dims.to_vec();
        let k = rt.upload_f32(&caches.k, &shape)?;
        let v = rt.upload_f32(&caches.v, &shape)?;
        let stats = rt.transfers();
        stats.record_cache_upload((caches.k.len() + caches.v.len()) as u64 * 4);
        Ok(DeviceCacheSession {
            k,
            v,
            dims,
            steps: 0,
            stats,
            tracer: rt.tracer(),
            faults: rt.faults(),
        })
    }

    /// `[L, B, S, KH, hd]` of the resident cache pair.
    pub fn dims(&self) -> [usize; 5] {
        self.dims
    }

    /// The compiled batch bucket the pair was built for (`dims[1]`).
    pub fn bucket(&self) -> usize {
        self.dims[1]
    }

    /// Chained steps executed since the upload.
    pub fn steps(&self) -> u64 {
        self.steps
    }

    /// The live cache pair, in the decode artifacts' (K, V) argument
    /// order.
    pub(crate) fn cache_args(&self) -> (&xla::PjRtBuffer, &xla::PjRtBuffer) {
        (&self.k, &self.v)
    }

    /// Swap in one step's output cache buffers as the next step's inputs.
    /// PJRT buffers are immutable, so on any step failure the previous
    /// pair is still valid and the session state is unchanged — callers
    /// can sync and fall back to the host path without data loss.
    pub(crate) fn advance(&mut self, k: xla::PjRtBuffer, v: xla::PjRtBuffer) {
        self.k = k;
        self.v = v;
        self.steps += 1;
    }

    /// Sync the resident pair to host (ONE full K/V readback — the
    /// session's only cache device→host transfer).  Callers slice the
    /// freshly written rows out of the returned dense pair; the buffers
    /// stay resident, so the session remains usable afterwards.
    pub fn read_cache_pair(&self) -> Result<(Vec<f32>, Vec<f32>)> {
        self.faults.check(InjectPoint::Sync)?;
        let elems: usize = self.dims.iter().product();
        let read = |buf: &xla::PjRtBuffer| -> Result<Vec<f32>> {
            let lit = buf.to_literal_sync()?;
            let v = lit.to_vec::<f32>()?;
            if v.len() != elems {
                return Err(Error::Engine(format!(
                    "cache sync read {} elems, expected {elems}",
                    v.len()
                )));
            }
            Ok(v)
        };
        let t0 = self.tracer.now();
        let kc = read(&self.k)?;
        let vc = read(&self.v)?;
        self.tracer.phase_since(Phase::Sync, t0);
        let bytes = 2 * elems as u64 * 4;
        self.stats.record_d2h(bytes, 2);
        self.stats.record_cache_sync(bytes);
        Ok((kc, vc))
    }
}
