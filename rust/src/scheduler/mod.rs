//! Continuous-batching scheduler (S8), Orca/vLLM-shaped, with
//! Sarathi-style **chunked prefill** (prefill/decode mixing).
//!
//! Sequences move `Waiting → Running → Finished`, with `Preempted` as the
//! KV-pressure escape hatch (preempted sequences drop their cache and
//! re-queue at the front for re-prefill — "recompute" preemption, vLLM's
//! default).  [`Scheduler::forget`] removes a sequence from whatever
//! state it is in — it is both the finish cleanup and the **cancel**
//! primitive (`Coordinator::cancel` drops the KV, then forgets here; a
//! forgotten id is never planned again).  Each engine iteration the
//! scheduler produces a [`StepPlan`]:
//!
//! 1. if the pool cannot grow every decoding sequence by one token,
//!    preempt the lowest-priority / youngest sequence until it can;
//! 2. assemble the decode batch from every *fully prefilled* running
//!    sequence — decode claims its share of the step token budget first,
//!    so a long prompt can never head-of-line-block token generation;
//! 3. spend the remaining budget on prefill chunks: first continue
//!    in-flight chunked prefills (they already hold KV and a batch slot),
//!    then admit waiting sequences (FCFS within priority class) while KV
//!    blocks, batch slots, and budget allow.
//!
//! The unit of prefill work is a [`PrefillChunk`] of at most
//! `chunk_tokens` prompt tokens (`chunk_tokens == 0` restores the seed's
//! monolithic whole-prompt prefill).  A sequence decodes only once its
//! `prefilled` counter covers the whole prompt; the chunk that completes
//! the prompt carries `last == true` and its logits produce the first
//! generated token (TTFT).
//!
//! The scheduler is deliberately engine-agnostic (it never touches PJRT):
//! decisions are pure data, which is what the proptests below exercise.
//! How the coordinator executes a chunk (batched prefill kernel vs
//! table-gather + decode-kernel span) is described in `ARCHITECTURE.md`.

use std::collections::VecDeque;

use crate::error::Result;

/// Request priority class (lower value schedules first).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Priority {
    Interactive = 0,
    Normal = 1,
    Batch = 2,
}

/// One sequence's scheduling view.
#[derive(Debug, Clone)]
pub struct SeqInfo {
    pub id: u64,
    pub priority: Priority,
    /// Prompt tokens (needed again on re-prefill after preemption).
    pub prompt: Vec<u32>,
    /// Prompt tokens whose K/V are already in the cache (chunked-prefill
    /// progress; equals `prompt.len()` once prefill is complete).
    pub prefilled: usize,
    /// Tokens generated so far.
    pub generated: usize,
    pub max_new_tokens: usize,
    /// Current context length (prompt + generated) while Running.
    pub len: usize,
    /// Monotone admission counter (FCFS tie-break).
    pub arrival: u64,
    /// Tenant that owns the sequence (0 = the default/anonymous
    /// tenant).  Ignored unless fair-share scheduling is enabled.
    pub tenant: u64,
}

impl SeqInfo {
    pub fn budget_left(&self) -> usize {
        self.max_new_tokens.saturating_sub(self.generated)
    }

    /// Whether the whole prompt is in the KV cache (the sequence decodes).
    pub fn prefill_done(&self) -> bool {
        self.prefilled >= self.prompt.len()
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum State {
    Waiting,
    Running,
    Finished,
}

/// One prefill chunk: `len` prompt tokens of sequence `id` starting at
/// prompt position `start` (== the sequence's KV length when the chunk
/// runs).  With `chunk_tokens == 0` every chunk covers the whole prompt
/// (monolithic prefill, the seed behavior).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PrefillChunk {
    pub id: u64,
    /// First prompt position covered by this chunk.
    pub start: usize,
    /// Number of prompt tokens in this chunk (>= 1).
    pub len: usize,
    /// True when this chunk completes the prompt: its logits produce the
    /// sequence's first generated token.
    pub last: bool,
}

/// One lane of a span step-group: either a continuation prefill chunk
/// (by index into [`StepPlan::prefill`]) or a decoding sequence riding
/// the group's spare capacity as a 1-token span.  A `Decode` lane's id
/// is REMOVED from [`StepPlan::decode`] — the group execution IS its
/// decode step this iteration, it must not be advanced twice.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GroupLane {
    /// Index into `StepPlan::prefill` (a `start > 0` continuation chunk).
    Chunk(usize),
    /// Sequence id decoding one token through a spare group lane.
    Decode(u64),
}

/// A planned speculative-decode chunk: the coordinator MAY advance
/// steady-state decoder `id` by draft-and-verify (one span execution
/// scoring up to `max_draft` drafted tokens) instead of plain decode.
/// The id STAYS in [`StepPlan::decode`] — the chunk is an option, not a
/// commitment: an ineligible request (sampling on, no draft material,
/// path demoted, ...) simply falls back to its plain decode slot.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SpecChunk {
    pub id: u64,
    /// Scheduler-side draft cap: leftover step budget, the request's
    /// remaining token budget past the decode token it already claimed,
    /// and context headroom.  The coordinator further caps at
    /// span-bucket - 1 so the verify span never pads.
    pub max_draft: usize,
}

/// What the coordinator must do this iteration.
#[derive(Debug, Default)]
pub struct StepPlan {
    /// Prefill chunks to execute (fresh admissions have `start == 0`;
    /// continuations of in-flight chunked prefills have `start > 0`).
    pub prefill: Vec<PrefillChunk>,
    /// Multi-sequence span step-groups: each entry lists lanes one
    /// batched `[B, T]` span execution advances together (disjoint,
    /// >= 2 lanes each; chunks in no group run per-sequence).
    /// `Chunk` lanes index into `prefill`; `Decode` lanes carry ids
    /// pulled out of `decode` to ride a group's spare capacity.
    /// Composed only when `span_group_lanes >= 2`.
    pub span_groups: Vec<Vec<GroupLane>>,
    /// Sequences to decode one token for, ids (fully prefilled running
    /// sequences; a sequence whose final chunk runs this iteration decodes
    /// from the next one).
    pub decode: Vec<u64>,
    /// Speculative-decode options for ids in `decode`, planned from
    /// whatever step budget decode and prefill left unspent (empty
    /// unless `spec_tokens > 0`).
    pub spec: Vec<SpecChunk>,
    /// Sequences preempted this iteration (caches must be dropped).
    pub preempt: Vec<u64>,
}

/// Resource view the scheduler plans against.
pub trait KvBudget {
    /// Free blocks in the pool.
    fn free_blocks(&self) -> usize;
    /// Blocks needed to hold `tokens` for a fresh sequence.
    fn blocks_for(&self, tokens: usize) -> usize;
    /// Blocks a sequence currently holds (released if it is preempted).
    fn blocks_held(&self, id: u64) -> usize;
    /// Whether growing `id` by one token requires a fresh block right now.
    fn growth_needs_block(&self, id: u64) -> bool;
    /// Total blocks in the pool (free + held + leased).  Consulted only
    /// by the per-tenant fair-share bound; the default (`usize::MAX`)
    /// disables that bound for budget views without a fixed pool.
    fn total_blocks(&self) -> usize {
        usize::MAX
    }
}

/// Per-tenant fair-share overlay configuration (deficit round-robin over
/// the step token budget, plus a per-tenant KV-block share bound).
/// Default-off: with `enabled == false` the scheduler plans exactly as
/// it would without the overlay.
#[derive(Debug, Clone)]
pub struct FairShareConfig {
    pub enabled: bool,
    /// Prompt-token credit each waiting tenant accrues per `plan()` tick
    /// (the DRR quantum); 0 = auto (`max(chunk_tokens, 32)`).
    pub quantum_tokens: usize,
    /// Accrual cap in quanta: an idle-then-bursty tenant banks at most
    /// this many quanta of credit, bounding how far it can jump ahead.
    pub burst_quanta: usize,
}

impl Default for FairShareConfig {
    fn default() -> Self {
        FairShareConfig {
            enabled: false,
            quantum_tokens: 0,
            burst_quanta: 4,
        }
    }
}

/// Scheduler configuration.
#[derive(Debug, Clone)]
pub struct SchedConfig {
    /// Hard cap on the decode batch (largest compiled bucket).
    pub max_batch: usize,
    /// Cap on prefills admitted per iteration (compile-bucket width).
    pub max_admit: usize,
    /// Largest compiled prefill bucket T (advisory: longer prompts still
    /// admit — their excess executes as decode-kernel spans; the hard
    /// bound is `max_seq`).
    pub max_prompt: usize,
    /// Max context (cache capacity S).
    pub max_seq: usize,
    /// Prefill chunk size in prompt tokens; 0 = monolithic (each prompt
    /// prefills in one whole-prompt chunk, the seed behavior).
    pub chunk_tokens: usize,
    /// Per-iteration token budget shared by decode (one token per
    /// sequence, claimed first) and prefill chunks; 0 = unbounded.
    pub step_token_budget: usize,
    /// Span-artifact granularity (tokens per batched span execution;
    /// 0 = no span artifacts / alignment off).  Continuation chunks
    /// (`start > 0` — they execute as span-artifact tiles) that do NOT
    /// finish the prompt are rounded down to a multiple of this, so every
    /// interior tile is one full bucket and ragged padding only ever
    /// happens on a prompt's final chunk.
    pub span_bucket_tokens: usize,
    /// Widest multi-sequence span batch the engine compiled (lanes per
    /// `span_*_b{B}_t{T}` execution); < 2 = no grouping, every
    /// continuation chunk runs per-sequence.  When >= 2, `plan()`
    /// composes same-bucket continuation chunks from different sequences
    /// into [`StepPlan::span_groups`] after the budget is spent — the
    /// decode-first budget and priority/arrival fairness are unchanged,
    /// grouping only batches the work already planned.
    pub span_group_lanes: usize,
    /// Max draft tokens planned per steady-state decoder per iteration
    /// ([`StepPlan::spec`]); 0 = speculative decoding off.  Draft
    /// tokens are charged to the step token budget AFTER decode and
    /// prefill chunks claim theirs — speculation only ever spends
    /// budget nobody else wanted.
    pub spec_tokens: usize,
}

/// The scheduler.
///
/// Waiting sequences are kept in one FIFO per priority class, so each
/// `plan()` tick walks them in admission order directly — no per-tick sort
/// (this took the tick from 59.7 µs to O(admitted) at 256 waiting; see
/// EXPERIMENTS.md §Perf).
pub struct Scheduler {
    cfg: SchedConfig,
    waiting: [VecDeque<u64>; 3],
    running: Vec<u64>,
    seqs: std::collections::HashMap<u64, (SeqInfo, State)>,
    arrivals: u64,
    /// Flow-control pause set (slow stream readers): a paused id keeps
    /// its state, KV blocks, and batch slot but is never planned — no
    /// decode token, no prefill chunk, no admission — until unpaused.
    /// It remains a preemption *victim* candidate, so a stalled reader
    /// cannot pin blocks against KV pressure.
    paused: std::collections::HashSet<u64>,
    /// Fair-share overlay (off by default — see [`FairShareConfig`]).
    fair: FairShareConfig,
    /// DRR deficit per tenant, in prompt tokens (fair-share on only).
    deficits: std::collections::HashMap<u64, u64>,
    /// Rotates which tenant admits first each tick (fair-share on only).
    rr_cursor: u64,
    /// Overload-ladder pressure level set by the coordinator.  0 = no
    /// pressure (byte-identical planning); >= 1 halves `max_admit`
    /// (min 1) and suppresses speculative-draft planning.
    pressure: u8,
}

fn class_of(p: Priority) -> usize {
    p as usize
}

impl Scheduler {
    pub fn new(cfg: SchedConfig) -> Scheduler {
        Scheduler {
            cfg,
            waiting: [VecDeque::new(), VecDeque::new(), VecDeque::new()],
            running: Vec::new(),
            seqs: std::collections::HashMap::new(),
            arrivals: 0,
            paused: std::collections::HashSet::new(),
            fair: FairShareConfig::default(),
            deficits: std::collections::HashMap::new(),
            rr_cursor: 0,
            pressure: 0,
        }
    }

    /// Install (or reconfigure) the fair-share overlay.
    pub fn set_fair_share(&mut self, fair: FairShareConfig) {
        self.fair = fair;
    }

    pub fn fair_share(&self) -> &FairShareConfig {
        &self.fair
    }

    /// Overload-ladder hook: level 0 restores baseline planning; any
    /// level >= 1 halves per-tick admissions (min 1) and stops planning
    /// speculative drafts.  In-flight work (decode, continuations) is
    /// never touched — pressure only slows the intake.
    pub fn set_pressure_level(&mut self, level: u8) {
        self.pressure = level;
    }

    pub fn pressure_level(&self) -> u8 {
        self.pressure
    }

    /// Pause/resume planning for one sequence (stream flow control).
    /// Returns true when the flag actually changed.  Pausing is
    /// planner-only: state, KV, and progress counters are untouched, so
    /// resuming continues exactly where the sequence stopped.
    pub fn set_paused(&mut self, id: u64, paused: bool) -> bool {
        if paused {
            self.paused.insert(id)
        } else {
            self.paused.remove(&id)
        }
    }

    pub fn is_paused(&self, id: u64) -> bool {
        self.paused.contains(&id)
    }

    pub fn config(&self) -> &SchedConfig {
        &self.cfg
    }

    /// Enqueue a new request. Returns Err if the request can never fit
    /// the context.  Prompts longer than the compiled prefill bucket
    /// (`max_prompt`) are admissible: the coordinator prefills the head
    /// through the batched artifact and advances the excess as
    /// decode-kernel spans (the same machinery preemption replay uses) —
    /// which is what lets multi-turn chat transcripts keep growing past
    /// one bucket.  Only the context bound is a hard limit.
    pub fn submit(
        &mut self,
        id: u64,
        prompt: Vec<u32>,
        max_new_tokens: usize,
        priority: Priority,
    ) -> Result<()> {
        self.submit_tenant(id, prompt, max_new_tokens, priority, 0)
    }

    /// [`Scheduler::submit`] with an explicit tenant id.  The tenant is
    /// inert bookkeeping unless fair-share scheduling is enabled
    /// ([`Scheduler::set_fair_share`]): with it off, a tenant-tagged
    /// workload plans byte-identically to an untagged one.
    pub fn submit_tenant(
        &mut self,
        id: u64,
        prompt: Vec<u32>,
        max_new_tokens: usize,
        priority: Priority,
        tenant: u64,
    ) -> Result<()> {
        if prompt.is_empty() {
            return Err(crate::Error::Scheduler("empty prompt".into()));
        }
        if prompt.len() + max_new_tokens > self.cfg.max_seq {
            return Err(crate::Error::Scheduler(format!(
                "prompt {} + max_new {} exceeds context {}",
                prompt.len(),
                max_new_tokens,
                self.cfg.max_seq
            )));
        }
        let info = SeqInfo {
            id,
            priority,
            len: prompt.len(),
            prompt,
            prefilled: 0,
            generated: 0,
            max_new_tokens,
            arrival: self.arrivals,
            tenant,
        };
        self.arrivals += 1;
        let class = class_of(info.priority);
        self.seqs.insert(id, (info, State::Waiting));
        self.waiting[class].push_back(id);
        Ok(())
    }

    pub fn info(&self, id: u64) -> Option<&SeqInfo> {
        self.seqs.get(&id).map(|(i, _)| i)
    }

    pub fn state(&self, id: u64) -> Option<State> {
        self.seqs.get(&id).map(|(_, s)| *s)
    }

    pub fn n_waiting(&self) -> usize {
        self.waiting.iter().map(|q| q.len()).sum()
    }

    pub fn n_running(&self) -> usize {
        self.running.len()
    }

    /// Running sequences still mid-prefill (chunked-prefill in flight).
    pub fn n_prefilling(&self) -> usize {
        self.running
            .iter()
            .filter(|id| !self.seqs[*id].0.prefill_done())
            .count()
    }

    /// Mark the first `n` prompt tokens as already in the KV cache — a
    /// cross-request prefix-cache hit (the coordinator forked the cached
    /// blocks into this sequence's block table).  Valid only while the
    /// sequence is `Waiting`; admission then plans a first chunk with
    /// `start == prefilled`, which the coordinator executes through the
    /// continuation (table-gather + `decode_span`) path.  Capped at
    /// `prompt.len() - 1` so at least one token is prefilled and the
    /// final chunk produces the first-token logits.
    pub fn set_prefilled(&mut self, id: u64, n: usize) {
        if let Some((info, st)) = self.seqs.get_mut(&id) {
            if *st == State::Waiting {
                info.prefilled = n.min(info.prompt.len().saturating_sub(1));
            }
        }
    }

    /// Chunk length for a sequence with `remaining` unprefilled tokens.
    fn chunk_len(&self, remaining: usize) -> usize {
        if self.cfg.chunk_tokens == 0 {
            remaining
        } else {
            self.cfg.chunk_tokens.min(remaining)
        }
    }

    /// Align a continuation chunk (`start > 0`, executed as span-artifact
    /// tiles) to the span-bucket granularity: an interior chunk that
    /// cannot finish the prompt is rounded DOWN to whole buckets, so the
    /// engine's tiling never pads mid-prompt — the deferred tokens ride
    /// the next chunk instead of a mostly-empty tile.  Final chunks and
    /// sub-bucket takes pass through unchanged (padding the prompt's last
    /// tile is unavoidable and correct).
    fn align_span_take(&self, start: usize, take: usize, remaining: usize) -> usize {
        let b = self.cfg.span_bucket_tokens;
        if b == 0 || start == 0 || take >= remaining {
            return take;
        }
        let aligned = take - take % b;
        if aligned == 0 {
            take
        } else {
            aligned
        }
    }

    /// Plan one engine iteration against the KV budget.
    pub fn plan(&mut self, kv: &dyn KvBudget) -> StepPlan {
        let mut plan = StepPlan::default();

        // 1. Preempt until the BATCH-WIDE growth demand fits: each decoding
        //    sequence about to cross a block boundary needs one fresh block
        //    *this* step, and they draw from the same pool — checking each
        //    against the full free count independently would over-commit.
        //    Mid-prefill sequences don't decode (their chunks reserve blocks
        //    in step 3 instead), but they are preemption candidates: a
        //    victim's released blocks count toward the supply.  Victims:
        //    lowest priority, then latest arrival (LIFO within class —
        //    preserves the oldest work, vLLM's policy).
        let mut freed_blocks = 0usize;
        loop {
            let demand = self
                .running
                .iter()
                .filter(|id| {
                    self.seqs[*id].0.prefill_done()
                        && !self.paused.contains(*id)
                        && kv.growth_needs_block(**id)
                })
                .count();
            if demand <= kv.free_blocks() + freed_blocks {
                break;
            }
            let victim = if self.fair.enabled {
                // SLO-aware: batch before interactive (unchanged), but
                // within a class prefer tenants holding more than their
                // KV fair share — the hog pays for the pressure it made.
                let share = self.kv_fair_share(kv);
                *self
                    .running
                    .iter()
                    .max_by_key(|id| {
                        let (info, _) = &self.seqs[*id];
                        let over = self.tenant_blocks(kv, info.tenant) > share;
                        (info.priority, over, info.arrival)
                    })
                    .expect("running nonempty while demand positive")
            } else {
                *self
                    .running
                    .iter()
                    .max_by_key(|id| {
                        let (info, _) = &self.seqs[*id];
                        (info.priority, info.arrival)
                    })
                    .expect("running nonempty while demand positive")
            };
            self.running.retain(|&x| x != victim);
            freed_blocks += kv.blocks_held(victim);
            let (info, st) = self.seqs.get_mut(&victim).unwrap();
            *st = State::Waiting;
            // Re-prefill will replay prompt + generated-so-far; genuinely a
            // recompute (generated tokens were already reported upstream,
            // the coordinator extends the stored prompt with them).  A
            // mid-prefill victim restarts from chunk 0 — unless the
            // coordinator re-matches the prefix cache on requeue and
            // calls `set_prefilled` with the cached span.
            info.len = info.prompt.len();
            info.prefilled = 0;
            let class = class_of(info.priority);
            self.waiting[class].push_front(victim);
            plan.preempt.push(victim);
            if self.running.is_empty() {
                break;
            }
        }

        // 2. Decode every fully prefilled running sequence.  Decode claims
        //    its token budget (one per sequence) before any prefill chunk:
        //    prompt processing can never head-of-line-block generation.
        plan.decode = self
            .running
            .iter()
            .copied()
            .filter(|id| self.seqs[id].0.prefill_done() && !self.paused.contains(id))
            .collect();
        plan.decode.truncate(self.cfg.max_batch);
        let budget_total = if self.cfg.step_token_budget == 0 {
            usize::MAX
        } else {
            self.cfg.step_token_budget
        };
        let mut budget = budget_total.saturating_sub(plan.decode.len());

        // Reserve one block for every decoding sequence that will cross a
        // block boundary on this step — chunks must never starve growth.
        let growth_reserve = self
            .running
            .iter()
            .filter(|id| {
                self.seqs[*id].0.prefill_done()
                    && !self.paused.contains(*id)
                    && kv.growth_needs_block(**id)
            })
            .count();
        let mut free = kv.free_blocks().saturating_sub(growth_reserve);

        // Blocks the already-admitted mid-prefill sequences still need to
        // finish their prompts (+1 slot for the first token).  Admission
        // (step 4) must not eat into this reserve: blocks are allocated
        // lazily chunk by chunk, so without it two long prompts can
        // over-commit the pool and starve each other's continuations —
        // and with no decoding sequence in flight the preemption loop has
        // nothing to evict, a livelock.  Reserving the full remainder
        // keeps the seed's invariant: every admitted sequence can always
        // eventually hold its whole prompt.  This step's continuation
        // chunks (step 3) draw from the same reserve, so subtracting the
        // full remainder up front also covers them.
        let outstanding: usize = self
            .running
            .iter()
            .filter(|id| !self.seqs[*id].0.prefill_done())
            .map(|id| {
                let (info, _) = &self.seqs[id];
                kv.blocks_for(info.prompt.len() + 1)
                    .saturating_sub(kv.blocks_held(*id))
            })
            .sum();
        let mut admit_free = free.saturating_sub(outstanding);

        // 3. Continue in-flight chunked prefills (priority, then arrival
        //    order).  They already hold a batch slot and partial KV;
        //    finishing them first bounds the number of half-prefilled
        //    sequences and releases their first token sooner.
        let mut midway: Vec<u64> = self
            .running
            .iter()
            .copied()
            .filter(|id| !self.seqs[id].0.prefill_done() && !self.paused.contains(id))
            .collect();
        midway.sort_by_key(|id| {
            let (info, _) = &self.seqs[id];
            (info.priority, info.arrival)
        });
        for id in midway {
            if budget == 0 {
                break;
            }
            let (info, _) = &self.seqs[&id];
            let remaining = info.prompt.len() - info.prefilled;
            let take = self.chunk_len(remaining).min(budget);
            let take = self.align_span_take(info.prefilled, take, remaining);
            let last = info.prefilled + take == info.prompt.len();
            // Blocks to extend the cache through this chunk (+1 slot for
            // the first generated token when the chunk completes the
            // prompt).  If the pool can't serve it this step, the chunk
            // simply waits; decodes finishing will free blocks.
            let end = info.prefilled + take + usize::from(last);
            let need = kv.blocks_for(end).saturating_sub(kv.blocks_held(id));
            if need > free {
                continue;
            }
            free -= need;
            budget -= take;
            plan.prefill.push(PrefillChunk {
                id,
                start: info.prefilled,
                len: take,
                last,
            });
        }

        // 4. Admit waiting sequences while slots, budget and blocks allow
        //    (FCFS within priority class).  Block demand is checked against
        //    the WHOLE prompt (+1), the seed's conservative policy: never
        //    admit a sequence the pool cannot eventually hold.  Under
        //    overload-ladder pressure the intake narrows (never the
        //    in-flight work); with fair share on, admission runs as
        //    deficit round-robin across tenants instead of class-wide
        //    FCFS.
        let max_admit = if self.pressure >= 1 {
            (self.cfg.max_admit / 2).max(1)
        } else {
            self.cfg.max_admit
        };
        let mut admitted: Vec<u64> = Vec::new();
        if self.fair.enabled {
            self.admit_fair(kv, &mut plan, &mut admitted, &mut budget, &mut admit_free, max_admit);
        } else {
            'classes: for class in 0..3 {
                for &id in &self.waiting[class] {
                    if budget == 0 || admitted.len() >= max_admit {
                        break 'classes;
                    }
                    if self.running.len() + admitted.len() >= self.cfg.max_batch {
                        break 'classes;
                    }
                    // A paused waiting sequence cannot make progress: skip it
                    // without tripping the FCFS head-of-line stop below.
                    if self.paused.contains(&id) {
                        continue;
                    }
                    let (info, _) = &self.seqs[&id];
                    // A prefix-cache hit arrives already holding its cached
                    // blocks (forked at submit): only the suffix needs fresh
                    // pool space, and the first chunk starts past the
                    // cached span.
                    let need = kv
                        .blocks_for(info.prompt.len() + 1)
                        .saturating_sub(kv.blocks_held(id));
                    if need > admit_free {
                        // FCFS head-of-line: stop rather than skip, so a large
                        // request cannot be starved by smaller late arrivals.
                        break 'classes;
                    }
                    let remaining = info.prompt.len() - info.prefilled;
                    let take = self.chunk_len(remaining).min(budget);
                    // Prefix-cache hits admit mid-prompt: their first chunk is
                    // already a span continuation, so it aligns too.
                    let take = self.align_span_take(info.prefilled, take, remaining);
                    admit_free -= need;
                    budget -= take;
                    admitted.push(id);
                    plan.prefill.push(PrefillChunk {
                        id,
                        start: info.prefilled,
                        len: take,
                        last: info.prefilled + take == info.prompt.len(),
                    });
                }
            }
        }
        for id in &admitted {
            let class = class_of(self.seqs[id].0.priority);
            self.waiting[class].retain(|x| x != id);
            let (_, st) = self.seqs.get_mut(id).unwrap();
            *st = State::Running;
            self.running.push(*id);
        }

        // 5. Compose continuation chunks from different sequences into
        //    span step-groups: one batched [B, T] execution per group
        //    tile instead of one serial span per sequence.  Groups with
        //    spare lanes absorb decoding sequences as T=1 lanes.
        self.compose_span_groups(&mut plan);

        // 6. Spend whatever budget is still left on speculative drafts
        //    for the steady-state decoders.  Plain decode stays planned
        //    (the spec chunk is an option the coordinator may take);
        //    caps keep a draft from proposing tokens the request could
        //    never emit: its remaining token budget past the decode
        //    token it already claimed, and the context headroom past
        //    this step's +1 growth.  The overload ladder's first rung
        //    (pressure >= 1) shrinks speculative drafts to zero — spec
        //    work is the cheapest thing to shed because plain decode
        //    stays planned for every id.
        if self.cfg.spec_tokens > 0 && self.pressure == 0 {
            for &id in &plan.decode {
                if budget == 0 {
                    break;
                }
                let (info, _) = &self.seqs[&id];
                let head = info.budget_left().saturating_sub(1);
                let room = self.cfg.max_seq.saturating_sub(info.len + 1);
                let max_draft = self.cfg.spec_tokens.min(budget).min(head).min(room);
                if max_draft == 0 {
                    continue;
                }
                budget -= max_draft;
                plan.spec.push(SpecChunk { id, max_draft });
            }
        }
        plan
    }

    /// KV blocks currently held by `tenant` across its running sequences.
    fn tenant_blocks(&self, kv: &dyn KvBudget, tenant: u64) -> usize {
        self.running
            .iter()
            .filter(|id| self.seqs[*id].0.tenant == tenant)
            .map(|id| kv.blocks_held(*id))
            .sum()
    }

    /// Per-tenant KV-block fair share: the pool divided by the number of
    /// tenants with live work.  `usize::MAX` (no bound) when the budget
    /// view doesn't expose a fixed pool.
    fn kv_fair_share(&self, kv: &dyn KvBudget) -> usize {
        let total = kv.total_blocks();
        if total == usize::MAX {
            return usize::MAX;
        }
        let mut tenants: Vec<u64> = self
            .running
            .iter()
            .chain(self.waiting.iter().flatten())
            .map(|id| self.seqs[id].0.tenant)
            .collect();
        tenants.sort_unstable();
        tenants.dedup();
        (total / tenants.len().max(1)).max(1)
    }

    /// Fair-share admission: deficit round-robin across tenants, within
    /// each priority class.  Every waiting tenant accrues `quantum`
    /// prompt-token credit per tick (capped at `quantum * burst_quanta`);
    /// a sequence admits when its tenant's banked credit covers its
    /// unprefilled prompt (a cost itself clamped at the cap, so one huge
    /// prompt can't starve forever behind an unreachable price).  The
    /// head-of-line stop is per TENANT, not per class: a hog tenant
    /// blocked on blocks or credit no longer stalls everyone behind it.
    #[allow(clippy::too_many_arguments)]
    fn admit_fair(
        &mut self,
        kv: &dyn KvBudget,
        plan: &mut StepPlan,
        admitted: &mut Vec<u64>,
        budget: &mut usize,
        admit_free: &mut usize,
        max_admit: usize,
    ) {
        let quantum = if self.fair.quantum_tokens == 0 {
            self.cfg.chunk_tokens.max(32) as u64
        } else {
            self.fair.quantum_tokens as u64
        };
        let cap = quantum.saturating_mul(self.fair.burst_quanta.max(1) as u64);
        // Credit every tenant with waiting work; prune everyone else so
        // the ledger can't grow without bound.
        let mut live: Vec<u64> = self
            .waiting
            .iter()
            .flatten()
            .chain(self.running.iter())
            .map(|id| self.seqs[id].0.tenant)
            .collect();
        live.sort_unstable();
        live.dedup();
        self.deficits.retain(|t, _| live.binary_search(t).is_ok());
        // One quantum per distinct waiting tenant per tick — queue depth
        // buys a tenant nothing, which is the whole point of DRR.
        let mut waiting_tenants: Vec<u64> = self
            .waiting
            .iter()
            .flatten()
            .map(|id| self.seqs[id].0.tenant)
            .collect();
        waiting_tenants.sort_unstable();
        waiting_tenants.dedup();
        for t in waiting_tenants {
            let d = self.deficits.entry(t).or_insert(0);
            *d = (*d + quantum).min(cap);
        }
        let share = self.kv_fair_share(kv);
        'classes: for class in 0..3 {
            let mut tenants: Vec<u64> = self.waiting[class]
                .iter()
                .map(|id| self.seqs[id].0.tenant)
                .collect();
            tenants.sort_unstable();
            tenants.dedup();
            if tenants.is_empty() {
                continue;
            }
            let n = tenants.len();
            let start = (self.rr_cursor as usize) % n;
            let mut progressed = true;
            while progressed {
                progressed = false;
                for k in 0..n {
                    let t = tenants[(start + k) % n];
                    if *budget == 0 || admitted.len() >= max_admit {
                        break 'classes;
                    }
                    if self.running.len() + admitted.len() >= self.cfg.max_batch {
                        break 'classes;
                    }
                    // This tenant's FCFS head still waiting this tick.
                    let Some(id) = self
                        .waiting[class]
                        .iter()
                        .copied()
                        .find(|id| {
                            self.seqs[id].0.tenant == t
                                && !admitted.contains(id)
                                && !self.paused.contains(id)
                        })
                    else {
                        continue;
                    };
                    let (plen, prefilled) = {
                        let (info, _) = &self.seqs[&id];
                        (info.prompt.len(), info.prefilled)
                    };
                    let need = kv.blocks_for(plen + 1).saturating_sub(kv.blocks_held(id));
                    // Per-tenant head-of-line: a blocked head skips only
                    // its OWN tenant's turn this round.
                    if need > *admit_free {
                        continue;
                    }
                    // KV fair share: while other tenants have live work,
                    // no tenant grows past its block share.
                    if n > 1 && self.tenant_blocks(kv, t).saturating_add(need) > share {
                        continue;
                    }
                    let cost = ((plen - prefilled) as u64).min(cap);
                    let d = self.deficits.entry(t).or_insert(0);
                    if *d < cost {
                        continue;
                    }
                    *d -= cost;
                    let remaining = plen - prefilled;
                    let take = self.chunk_len(remaining).min(*budget);
                    let take = self.align_span_take(prefilled, take, remaining);
                    *admit_free -= need;
                    *budget -= take;
                    admitted.push(id);
                    plan.prefill.push(PrefillChunk {
                        id,
                        start: prefilled,
                        len: take,
                        last: prefilled + take == plen,
                    });
                    progressed = true;
                }
            }
        }
        self.rr_cursor = self.rr_cursor.wrapping_add(1);
    }

    /// Group the plan's continuation chunks (`start > 0` — they execute
    /// as span tiles; fresh chunks ride the batched prefill artifact)
    /// into step-groups of at most `span_group_lanes` lanes.
    ///
    /// Occupancy before padding: chunks with IDENTICAL span lengths are
    /// grouped first — equal lanes share one tile plan, so every group
    /// execution runs fully occupied.  Only then are the leftover
    /// singletons merged into ragged groups (shorter lanes go inert on
    /// later tiles), which still beats executing them serially.  Within
    /// a class, plan order is kept, preserving the priority/arrival
    /// fairness steps 3–4 established; the budget was already spent, so
    /// grouping never changes WHAT runs, only how many dispatches it
    /// takes.
    ///
    /// Decode-as-lane overlay: a composed group whose lane count is
    /// below `span_group_lanes` absorbs decoding sequences as 1-token
    /// lanes — the batched execution that was dispatching anyway
    /// advances them for free (the decode lane goes inert after the
    /// first tile, the PR 6 ragged-lane machinery).  Pure overlay:
    /// decode ids join only an EXISTING chunk group; decode-only groups
    /// are never formed (plain batched decode already serves them), so
    /// with no prefill traffic the decode path is byte-identical to
    /// grouping off.
    fn compose_span_groups(&self, plan: &mut StepPlan) {
        let lanes = self.cfg.span_group_lanes;
        if lanes < 2 {
            return;
        }
        let eligible: Vec<usize> = plan
            .prefill
            .iter()
            .enumerate()
            .filter(|(_, c)| c.start > 0)
            .map(|(i, _)| i)
            .collect();
        // Same-length classes in first-seen (= plan) order.
        let mut by_len: Vec<(usize, Vec<usize>)> = Vec::new();
        for &i in &eligible {
            let len = plan.prefill[i].len;
            match by_len.iter_mut().find(|(l, _)| *l == len) {
                Some((_, v)) => v.push(i),
                None => by_len.push((len, vec![i])),
            }
        }
        let mut groups: Vec<Vec<usize>> = Vec::new();
        let mut leftovers: Vec<usize> = Vec::new();
        for (_, idxs) in by_len {
            for g in idxs.chunks(lanes) {
                if g.len() >= 2 {
                    groups.push(g.to_vec());
                } else {
                    leftovers.extend_from_slice(g);
                }
            }
        }
        leftovers.sort_unstable(); // back to plan order across classes
        for g in leftovers.chunks(lanes) {
            if g.len() >= 2 {
                groups.push(g.to_vec());
            }
        }
        // Overlay: fill spare lanes with decoders (front of the decode
        // batch first — oldest running, deterministic) and pull the
        // absorbed ids out of the decode batch.
        let mut pulled = 0usize;
        for g in groups {
            let mut out: Vec<GroupLane> = g.into_iter().map(GroupLane::Chunk).collect();
            while out.len() < lanes && pulled < plan.decode.len() {
                out.push(GroupLane::Decode(plan.decode[pulled]));
                pulled += 1;
            }
            plan.span_groups.push(out);
        }
        plan.decode.drain(..pulled);
    }

    /// Report an executed prefill chunk: `n` more prompt tokens of `id`
    /// are in the KV cache.  The chunk that completes the prompt is
    /// followed by [`Scheduler::on_token`] for its sampled first token.
    pub fn on_chunk(&mut self, id: u64, n: usize) {
        if let Some((info, _)) = self.seqs.get_mut(&id) {
            info.prefilled = (info.prefilled + n).min(info.prompt.len());
        }
    }

    /// Report a prefill/decode outcome: token appended to `id`.
    pub fn on_token(&mut self, id: u64, finished: bool) {
        let Some((info, st)) = self.seqs.get_mut(&id) else {
            return;
        };
        info.generated += 1;
        info.len += 1;
        if finished || info.budget_left() == 0 || info.len >= self.cfg.max_seq {
            *st = State::Finished;
            self.running.retain(|&x| x != id);
        }
    }

    /// After a preempted sequence is re-admitted its previously generated
    /// tokens are part of the replayed prompt.
    pub fn extend_prompt(&mut self, id: u64, tokens: &[u32]) {
        if let Some((info, _)) = self.seqs.get_mut(&id) {
            info.prompt.extend_from_slice(tokens);
            info.len = info.prompt.len();
        }
    }

    /// Remove a sequence's record in ANY state — finish cleanup and the
    /// cancel primitive (waiting entries leave their queue, running ones
    /// leave the batch; callers drop the KV separately).
    pub fn forget(&mut self, id: u64) {
        self.seqs.remove(&id);
        for q in &mut self.waiting {
            q.retain(|&x| x != id);
        }
        self.running.retain(|&x| x != id);
        self.paused.remove(&id);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;
    use std::collections::HashMap;

    /// Toy budget: fixed pool, 4-token blocks, per-seq ledger.
    struct Budget {
        free: usize,
        lens: HashMap<u64, usize>,
    }

    impl Budget {
        fn new(free: usize) -> Budget {
            Budget {
                free,
                lens: HashMap::new(),
            }
        }
        fn commit_prefill(&mut self, id: u64, len: usize) {
            self.free -= len.div_ceil(4);
            self.lens.insert(id, len);
        }
        /// Extend `id` by a chunk of `n` tokens (first chunk creates it).
        fn commit_chunk(&mut self, id: u64, n: usize) {
            let l = self.lens.entry(id).or_insert(0);
            let before = l.div_ceil(4);
            *l += n;
            let after = l.div_ceil(4);
            self.free -= after - before;
        }
        fn commit_decode(&mut self, id: u64) {
            let l = self.lens.get_mut(&id).unwrap();
            *l += 1;
            if *l % 4 == 1 && *l > 1 {
                self.free -= 1;
            }
        }
        fn release(&mut self, id: u64) {
            if let Some(l) = self.lens.remove(&id) {
                self.free += l.div_ceil(4);
            }
        }
    }

    impl KvBudget for Budget {
        fn free_blocks(&self) -> usize {
            self.free
        }
        fn blocks_for(&self, tokens: usize) -> usize {
            tokens.div_ceil(4)
        }
        fn blocks_held(&self, id: u64) -> usize {
            self.lens.get(&id).copied().unwrap_or(0).div_ceil(4)
        }
        fn growth_needs_block(&self, id: u64) -> bool {
            self.lens.get(&id).copied().unwrap_or(0) % 4 == 0
        }
    }

    fn sched(max_batch: usize) -> Scheduler {
        Scheduler::new(SchedConfig {
            max_batch,
            max_admit: 4,
            max_prompt: 32,
            max_seq: 64,
            chunk_tokens: 0,
            step_token_budget: 0,
            span_bucket_tokens: 0,
            span_group_lanes: 0,
            spec_tokens: 0,
        })
    }

    fn sched_chunked(chunk: usize, budget: usize) -> Scheduler {
        Scheduler::new(SchedConfig {
            max_batch: 8,
            max_admit: 4,
            max_prompt: 64,
            max_seq: 128,
            chunk_tokens: chunk,
            step_token_budget: budget,
            span_bucket_tokens: 0,
            span_group_lanes: 0,
            spec_tokens: 0,
        })
    }

    fn ids_of(p: &StepPlan) -> Vec<u64> {
        p.prefill.iter().map(|c| c.id).collect()
    }

    fn chunk_lanes(idxs: &[usize]) -> Vec<GroupLane> {
        idxs.iter().map(|&i| GroupLane::Chunk(i)).collect()
    }

    #[test]
    fn fcfs_admission() {
        let mut s = sched(2);
        let mut b = Budget::new(100);
        s.submit(1, vec![5; 4], 4, Priority::Normal).unwrap();
        s.submit(2, vec![5; 4], 4, Priority::Normal).unwrap();
        s.submit(3, vec![5; 4], 4, Priority::Normal).unwrap();
        let p = s.plan(&b);
        assert_eq!(ids_of(&p), vec![1, 2]); // batch cap 2
        // Monolithic mode: one whole-prompt chunk each.
        assert!(p.prefill.iter().all(|c| c.start == 0 && c.len == 4 && c.last));
        assert!(p.decode.is_empty());
        for c in &p.prefill {
            b.commit_prefill(c.id, c.len);
            s.on_chunk(c.id, c.len);
        }
        // Next iteration: 1 and 2 decode; 3 still waiting (batch full).
        let p2 = s.plan(&b);
        assert!(p2.prefill.is_empty());
        assert_eq!(p2.decode, vec![1, 2]);
    }

    #[test]
    fn priority_beats_arrival() {
        let mut s = sched(1);
        let b = Budget::new(100);
        s.submit(1, vec![5; 4], 4, Priority::Batch).unwrap();
        s.submit(2, vec![5; 4], 4, Priority::Interactive).unwrap();
        let p = s.plan(&b);
        assert_eq!(ids_of(&p), vec![2]);
    }

    #[test]
    fn finish_frees_slot() {
        let mut s = sched(1);
        let mut b = Budget::new(100);
        s.submit(1, vec![5; 4], 1, Priority::Normal).unwrap();
        s.submit(2, vec![5; 4], 1, Priority::Normal).unwrap();
        let p = s.plan(&b);
        assert_eq!(ids_of(&p), vec![1]);
        b.commit_prefill(1, 4);
        s.on_chunk(1, 4);
        s.on_token(1, false); // budget 1 -> finished
        assert_eq!(s.state(1), Some(State::Finished));
        b.release(1);
        let p2 = s.plan(&b);
        assert_eq!(ids_of(&p2), vec![2]);
    }

    #[test]
    fn preempts_when_pool_exhausted() {
        let mut s = sched(4);
        let mut b = Budget::new(4); // 4 blocks of 4 tokens
        s.submit(1, vec![5; 7], 8, Priority::Normal).unwrap();
        s.submit(2, vec![5; 7], 8, Priority::Normal).unwrap();
        let p = s.plan(&b);
        assert_eq!(ids_of(&p), vec![1, 2]); // each reserves 2 blocks
        for c in &p.prefill {
            b.commit_prefill(c.id, c.len);
            s.on_chunk(c.id, c.len);
        }
        // First decode fills slot 8 inside block 2 of each — no pressure.
        let p2 = s.plan(&b);
        assert_eq!(p2.decode, vec![1, 2]);
        assert!(p2.preempt.is_empty());
        b.commit_decode(1);
        b.commit_decode(2);
        s.on_token(1, false);
        s.on_token(2, false);
        // Pool empty, both at a block boundary -> youngest is preempted and
        // its freed blocks unblock the survivor.
        let p3 = s.plan(&b);
        assert_eq!(p3.preempt, vec![2]);
        assert_eq!(p3.decode, vec![1]);
        assert_eq!(s.state(2), Some(State::Waiting));
    }

    #[test]
    fn chunks_cover_prompt_in_order() {
        let mut s = sched_chunked(4, 0);
        let b = Budget::new(100);
        s.submit(1, vec![7; 10], 4, Priority::Normal).unwrap();
        let mut seen = Vec::new();
        for _ in 0..3 {
            let p = s.plan(&b);
            assert_eq!(p.prefill.len(), 1);
            let c = p.prefill[0];
            assert_eq!(c.id, 1);
            seen.push((c.start, c.len, c.last));
            s.on_chunk(1, c.len);
            if c.last {
                s.on_token(1, false);
            }
        }
        assert_eq!(seen, vec![(0, 4, false), (4, 4, false), (8, 2, true)]);
        // Prefill complete: the sequence now decodes, no more chunks.
        let p = s.plan(&b);
        assert!(p.prefill.is_empty());
        assert_eq!(p.decode, vec![1]);
    }

    #[test]
    fn decode_never_blocked_by_long_prefill() {
        // Two decoding chats + one long document: every step must decode
        // both while the document advances chunk by chunk, and the shared
        // token budget must hold (decode first, chunks with the remainder).
        let mut s = sched_chunked(4, 6);
        let b = Budget::new(1000);
        s.submit(1, vec![1; 4], 16, Priority::Normal).unwrap();
        s.submit(2, vec![1; 4], 16, Priority::Normal).unwrap();
        // Drain both prefills (the second may be budget-split over steps).
        while s.n_waiting() > 0 || s.n_prefilling() > 0 {
            let p = s.plan(&b);
            for c in &p.prefill {
                s.on_chunk(c.id, c.len);
                if c.last {
                    s.on_token(c.id, false);
                }
            }
            for &id in &p.decode {
                s.on_token(id, false);
            }
        }
        s.submit(3, vec![2; 20], 4, Priority::Normal).unwrap();
        let mut mixed_steps = 0;
        while !s.info(3).unwrap().prefill_done() {
            let p = s.plan(&b);
            assert_eq!(p.decode.len(), 2, "decode starved by long prefill");
            let chunk_tokens: usize = p.prefill.iter().map(|c| c.len).sum();
            assert!(
                p.decode.len() + chunk_tokens <= 6,
                "step token budget violated"
            );
            if !p.prefill.is_empty() {
                mixed_steps += 1;
            }
            for c in &p.prefill {
                s.on_chunk(c.id, c.len);
                if c.last {
                    s.on_token(c.id, false);
                }
            }
            for &id in &p.decode {
                s.on_token(id, false);
            }
        }
        // 20 prompt tokens at (6 - 2) tokens/step = 5 mixed steps.
        assert_eq!(mixed_steps, 5);
    }

    #[test]
    fn continuation_beats_new_admission() {
        let mut s = sched_chunked(4, 4);
        let b = Budget::new(1000);
        s.submit(1, vec![1; 12], 4, Priority::Normal).unwrap();
        let p = s.plan(&b);
        assert_eq!(ids_of(&p), vec![1]);
        s.on_chunk(1, p.prefill[0].len);
        s.submit(2, vec![1; 4], 4, Priority::Normal).unwrap();
        // Budget 4/step: the in-flight prefill's next chunk takes it all;
        // seq 2 waits rather than fragmenting another prompt.
        let p2 = s.plan(&b);
        assert_eq!(p2.prefill.len(), 1);
        assert_eq!(p2.prefill[0], PrefillChunk { id: 1, start: 4, len: 4, last: false });
        assert_eq!(s.n_prefilling(), 1);
    }

    #[test]
    fn mid_prefill_waits_for_blocks_and_resumes() {
        let mut s = sched_chunked(4, 0);
        let mut b = Budget::new(4); // 16 token slots
        s.submit(1, vec![5; 8], 8, Priority::Normal).unwrap();
        let p = s.plan(&b);
        assert_eq!(p.prefill[0].len, 4);
        b.commit_chunk(1, 4);
        s.on_chunk(1, 4);
        // Fill the pool with a decoding sequence's growth pressure: submit
        // a second seq that eats the remaining blocks, then force demand.
        b.free = 0;
        // Seq 1 is mid-prefill: it cannot get its next chunk (no blocks),
        // but it must not deadlock the planner either.
        let p2 = s.plan(&b);
        assert!(p2.prefill.is_empty());
        assert!(p2.preempt.is_empty()); // no decode demand -> no preemption
        // Blocks return; the prefill resumes where it left off.
        b.free = 4;
        let p3 = s.plan(&b);
        assert_eq!(p3.prefill[0], PrefillChunk { id: 1, start: 4, len: 4, last: true });
    }

    /// Regression: two long prompts whose total block need exceeds the
    /// pool must NOT be admitted concurrently — blocks are allocated
    /// lazily per chunk, so concurrent admission would let them starve
    /// each other's continuations with no decoder left to preempt
    /// (livelock).  Admission reserves mid-prefill remainders instead.
    #[test]
    fn overcommitted_long_prompts_do_not_livelock() {
        let mut s = Scheduler::new(SchedConfig {
            max_batch: 8,
            max_admit: 4,
            max_prompt: 64,
            max_seq: 64,
            chunk_tokens: 4,
            step_token_budget: 0,
            span_bucket_tokens: 0,
            span_group_lanes: 0,
            spec_tokens: 0,
        });
        // Pool of 10 four-token blocks.  A needs blocks_for(37) = 10,
        // B needs blocks_for(29) = 8: both fit alone, never together.
        let mut b = Budget::new(10);
        s.submit(1, vec![1; 36], 2, Priority::Normal).unwrap();
        s.submit(2, vec![1; 28], 2, Priority::Normal).unwrap();
        let mut finished = std::collections::HashSet::new();
        for step in 0..400 {
            let plan = s.plan(&b);
            assert!(
                s.n_prefilling() <= 1,
                "step {step}: two over-committing prefills admitted together"
            );
            assert!(
                !(plan.prefill.is_empty()
                    && plan.decode.is_empty()
                    && plan.preempt.is_empty()
                    && s.n_waiting() + s.n_running() > 0
                    && b.free > 0),
                "step {step}: planner stalled with work pending and blocks free"
            );
            for id in &plan.preempt {
                b.release(*id);
            }
            for c in &plan.prefill {
                b.commit_chunk(c.id, c.len);
                s.on_chunk(c.id, c.len);
                if c.last {
                    s.on_token(c.id, false);
                    b.commit_decode(c.id);
                }
            }
            for &id in &plan.decode {
                s.on_token(id, false);
                if s.state(id) == Some(State::Finished) {
                    b.release(id);
                    finished.insert(id);
                } else {
                    b.commit_decode(id);
                }
            }
            if finished.len() == 2 {
                break;
            }
        }
        assert_eq!(finished.len(), 2, "long prompts livelocked");
    }

    /// Property: chunk plans tile each prompt exactly — starts are
    /// monotone, lengths sum to the prompt, `last` fires exactly once —
    /// and the per-step token budget holds.
    #[test]
    fn prop_chunk_tiling_and_budget() {
        for seed in 0..20u64 {
            let mut rng = Rng::new(seed);
            let chunk = rng.range(1, 6);
            let budget = rng.range(4, 12);
            let mut s = Scheduler::new(SchedConfig {
                max_batch: 6,
                max_admit: 3,
                max_prompt: 32,
                max_seq: 64,
                chunk_tokens: chunk,
                step_token_budget: budget,
                span_bucket_tokens: 0,
            span_group_lanes: 0,
            spec_tokens: 0,
            });
            let mut b = Budget::new(200);
            let mut next = 0u64;
            let mut covered: HashMap<u64, usize> = HashMap::new();
            let mut lasts: HashMap<u64, usize> = HashMap::new();
            for _ in 0..300 {
                if rng.chance(0.4) && next < 30 {
                    let plen = rng.range(1, 17);
                    s.submit(next, vec![1; plen], rng.range(1, 4), Priority::Normal)
                        .unwrap();
                    next += 1;
                }
                let plan = s.plan(&b);
                // Decode claims the budget first; chunks only get the rest
                // (decode itself is capped by max_batch, not the budget —
                // generation never stalls on a misconfigured budget).
                let chunk_tokens: usize =
                    plan.prefill.iter().map(|c| c.len).sum();
                assert!(
                    chunk_tokens <= budget.saturating_sub(plan.decode.len()),
                    "seed {seed}: budget {budget} exceeded"
                );
                for id in &plan.preempt {
                    b.release(*id);
                    covered.insert(*id, 0); // recompute restarts coverage
                    lasts.remove(id);
                }
                for c in &plan.prefill {
                    let prev = covered.get(&c.id).copied().unwrap_or(0);
                    assert_eq!(
                        c.start, prev,
                        "seed {seed}: chunk start not contiguous"
                    );
                    assert!(c.len >= 1);
                    covered.insert(c.id, prev + c.len);
                    b.commit_chunk(c.id, c.len);
                    s.on_chunk(c.id, c.len);
                    if c.last {
                        *lasts.entry(c.id).or_insert(0) += 1;
                        assert_eq!(
                            covered[&c.id],
                            s.info(c.id).unwrap().prompt.len(),
                            "seed {seed}: last chunk before full coverage"
                        );
                        s.on_token(c.id, false);
                        if s.state(c.id) == Some(State::Finished) {
                            b.release(c.id);
                        } else {
                            b.commit_decode(c.id);
                        }
                    }
                }
                for &id in &plan.decode {
                    assert!(
                        s.info(id).unwrap().prefill_done(),
                        "seed {seed}: decode planned mid-prefill"
                    );
                    s.on_token(id, rng.chance(0.2));
                    if s.state(id) == Some(State::Finished) {
                        b.release(id);
                    } else {
                        b.commit_decode(id);
                    }
                }
            }
            for (id, n) in lasts {
                assert!(n <= 2, "seed {seed}: seq {id} fired last {n} times");
            }
        }
    }

    /// Prefix-cache hit: a waiting sequence marked partially prefilled
    /// (its cached blocks already forked into the pool ledger) admits
    /// with a suffix-only chunk and needs only suffix blocks.
    #[test]
    fn cached_prefix_admits_suffix_only() {
        let mut s = sched_chunked(4, 0);
        let mut b = Budget::new(4); // 16 token slots
        s.submit(1, vec![7; 14], 4, Priority::Normal).unwrap();
        b.commit_chunk(1, 8); // the forked blocks the hit already holds
        s.set_prefilled(1, 8);
        let p = s.plan(&b);
        assert_eq!(p.prefill.len(), 1);
        assert_eq!(
            p.prefill[0],
            PrefillChunk { id: 1, start: 8, len: 4, last: false }
        );
        b.commit_chunk(1, 4);
        s.on_chunk(1, 4);
        let p2 = s.plan(&b);
        assert_eq!(
            p2.prefill[0],
            PrefillChunk { id: 1, start: 12, len: 2, last: true }
        );

        // A fully-cached prompt is capped at len-1: the final token is
        // always prefilled so the last chunk produces logits.
        s.submit(2, vec![9; 8], 4, Priority::Normal).unwrap();
        s.set_prefilled(2, 8);
        assert_eq!(s.info(2).unwrap().prefilled, 7);
        // set_prefilled is a no-op once the sequence is running.
        s.set_prefilled(1, 0);
        assert_eq!(s.info(1).unwrap().prefilled, 12);
    }

    /// Span-bucket alignment: interior continuation chunks round down to
    /// whole span buckets (no mid-prompt ragged tiles), the final chunk
    /// takes whatever remains, and coverage still tiles the prompt
    /// exactly.  Fresh (`start == 0`) chunks are untouched — they run
    /// through the batched prefill artifact, not span tiles.
    #[test]
    fn continuation_chunks_align_to_span_buckets() {
        let mut s = Scheduler::new(SchedConfig {
            max_batch: 8,
            max_admit: 4,
            max_prompt: 64,
            max_seq: 128,
            chunk_tokens: 14,
            step_token_budget: 0,
            span_bucket_tokens: 8,
            span_group_lanes: 0,
            spec_tokens: 0,
        });
        let b = Budget::new(1000);
        s.submit(1, vec![1; 40], 4, Priority::Normal).unwrap();
        let mut seen = Vec::new();
        while !s.info(1).unwrap().prefill_done() {
            let p = s.plan(&b);
            assert_eq!(p.prefill.len(), 1);
            let c = p.prefill[0];
            seen.push((c.start, c.len, c.last));
            s.on_chunk(1, c.len);
            if c.last {
                s.on_token(1, false);
            }
        }
        // First chunk (start == 0, prefill artifact): full 14.  Interior
        // continuations: 14 -> 8 (one whole bucket).  The final chunk
        // takes its whole remainder (10 <= chunk), unaligned — ragged
        // padding is allowed there only.
        assert_eq!(
            seen,
            vec![(0, 14, false), (14, 8, false), (22, 8, false), (30, 10, true)]
        );
        // A cached-prefix admission (start > 0 from the first chunk)
        // aligns the same way.
        s.submit(2, vec![2; 30], 4, Priority::Normal).unwrap();
        s.set_prefilled(2, 6);
        let p = s.plan(&b);
        assert_eq!(
            p.prefill[0],
            PrefillChunk { id: 2, start: 6, len: 8, last: false }
        );
        // Alignment never zeroes a chunk: a sub-bucket take passes through.
        let mut s = Scheduler::new(SchedConfig {
            max_batch: 8,
            max_admit: 4,
            max_prompt: 64,
            max_seq: 128,
            chunk_tokens: 4,
            step_token_budget: 0,
            span_bucket_tokens: 8,
            span_group_lanes: 0,
            spec_tokens: 0,
        });
        s.submit(1, vec![1; 12], 4, Priority::Normal).unwrap();
        let p = s.plan(&b);
        s.on_chunk(1, p.prefill[0].len);
        let p2 = s.plan(&b);
        assert_eq!(
            p2.prefill[0],
            PrefillChunk { id: 1, start: 4, len: 4, last: false }
        );
    }

    /// Cross-sequence span composition: same-bucket continuation chunks
    /// from different sequences land in ONE step-group (one batched
    /// device execution), fresh admissions never do (they ride the
    /// prefill artifact), and grouping changes nothing about WHAT was
    /// planned — chunks, order, budget are identical with lanes off.
    #[test]
    fn span_groups_compose_same_bucket_continuations() {
        let cfg = SchedConfig {
            max_batch: 8,
            max_admit: 4,
            max_prompt: 64,
            max_seq: 128,
            chunk_tokens: 8,
            step_token_budget: 0,
            span_bucket_tokens: 8,
            span_group_lanes: 4,
            spec_tokens: 0,
        };
        let mut s = Scheduler::new(cfg.clone());
        let b = Budget::new(1000);
        for id in 1..=3 {
            s.submit(id, vec![1; 24], 4, Priority::Normal).unwrap();
        }
        // Step 1: three fresh chunks (start == 0) — no grouping.
        let p = s.plan(&b);
        assert_eq!(p.prefill.len(), 3);
        assert!(p.span_groups.is_empty(), "fresh chunks must not group");
        for c in &p.prefill {
            s.on_chunk(c.id, c.len);
        }
        // A fourth sequence arrives: its first chunk is fresh while the
        // three continuations (equal 8-token spans) form one group.
        s.submit(4, vec![1; 24], 4, Priority::Normal).unwrap();
        let p2 = s.plan(&b);
        assert_eq!(p2.prefill.len(), 4);
        assert_eq!(p2.span_groups, vec![chunk_lanes(&[0, 1, 2])]);
        let fresh = &p2.prefill[3];
        assert_eq!((fresh.id, fresh.start), (4, 0));
        // Same workload with grouping off: identical chunks, no groups —
        // composition batches the plan, it never changes it.
        let mut s2 = Scheduler::new(SchedConfig {
            span_group_lanes: 0,
            spec_tokens: 0,
            ..cfg
        });
        for id in 1..=3 {
            s2.submit(id, vec![1; 24], 4, Priority::Normal).unwrap();
        }
        let q = s2.plan(&b);
        for c in &q.prefill {
            s2.on_chunk(c.id, c.len);
        }
        s2.submit(4, vec![1; 24], 4, Priority::Normal).unwrap();
        let q2 = s2.plan(&b);
        assert_eq!(q2.prefill, p2.prefill);
        assert!(q2.span_groups.is_empty());
    }

    /// Occupancy before padding: equal-length chunks pair up first (every
    /// group tile fully occupied), even when the plan interleaves them
    /// with other lengths; only the leftover singletons merge into a
    /// ragged group.
    #[test]
    fn span_groups_prefer_occupancy_before_padding() {
        let mk = |lanes: usize| {
            Scheduler::new(SchedConfig {
                max_batch: 8,
                max_admit: 8,
                max_prompt: 64,
                max_seq: 128,
                chunk_tokens: 8,
                step_token_budget: 0,
                span_bucket_tokens: 8,
                span_group_lanes: lanes,
                spec_tokens: 0,
            })
        };
        let b = Budget::new(1000);
        // Arrival order A(16) C(13) B(16) D(13): continuations come out
        // len 8, 5, 8, 5.  With 2 lanes the same-length pairs group —
        // [A, B] and [C, D] — NOT the adjacent-but-ragged [A, C].
        let mut s = mk(2);
        s.submit(1, vec![1; 16], 4, Priority::Normal).unwrap();
        s.submit(2, vec![1; 13], 4, Priority::Normal).unwrap();
        s.submit(3, vec![1; 16], 4, Priority::Normal).unwrap();
        s.submit(4, vec![1; 13], 4, Priority::Normal).unwrap();
        let p = s.plan(&b);
        for c in &p.prefill {
            s.on_chunk(c.id, c.len);
        }
        let p2 = s.plan(&b);
        let lens: Vec<usize> = p2.prefill.iter().map(|c| c.len).collect();
        assert_eq!(lens, vec![8, 5, 8, 5]);
        assert_eq!(
            p2.span_groups,
            vec![chunk_lanes(&[0, 2]), chunk_lanes(&[1, 3])]
        );

        // Leftover singletons (one 8, one 5) still merge: a ragged group
        // (the short lane goes inert) beats two serial executions.
        let mut s = mk(2);
        s.submit(1, vec![1; 16], 4, Priority::Normal).unwrap();
        s.submit(2, vec![1; 13], 4, Priority::Normal).unwrap();
        let p = s.plan(&b);
        for c in &p.prefill {
            s.on_chunk(c.id, c.len);
        }
        let p2 = s.plan(&b);
        assert_eq!(p2.span_groups, vec![chunk_lanes(&[0, 1])]);
    }

    /// Decode-as-lane overlay: decoding sequences ride a chunk group's
    /// spare lanes as T=1 spans and leave the decode batch; with no
    /// chunk group there is nothing to ride (decode-only groups never
    /// form); and overlay changes only the dispatch shape — the chunks
    /// and the set of advanced sequences are identical with lanes off.
    #[test]
    fn decode_lanes_ride_spare_group_capacity() {
        let mk = |lanes: usize| {
            Scheduler::new(SchedConfig {
                max_batch: 8,
                max_admit: 4,
                max_prompt: 64,
                max_seq: 128,
                chunk_tokens: 8,
                step_token_budget: 0,
                span_bucket_tokens: 8,
                span_group_lanes: lanes,
                spec_tokens: 0,
            })
        };
        let b = Budget::new(1000);
        let drive = |s: &mut Scheduler| {
            // Three short chats reach steady-state decode...
            for id in 3..=5 {
                s.submit(id, vec![1; 4], 8, Priority::Normal).unwrap();
            }
            let p = s.plan(&b);
            for c in &p.prefill {
                s.on_chunk(c.id, c.len);
                s.on_token(c.id, false);
            }
            // ...then two long documents admit (fresh chunks).
            for id in 1..=2 {
                s.submit(id, vec![1; 24], 8, Priority::Normal).unwrap();
            }
            let p = s.plan(&b);
            assert!(p.span_groups.is_empty(), "fresh chunks must not group");
            for c in &p.prefill {
                s.on_chunk(c.id, c.len);
            }
            for &id in &p.decode {
                s.on_token(id, false);
            }
            s.plan(&b)
        };
        // Lanes on: the two continuations form a group with two spare
        // lanes, which absorb the two oldest decoders; the third stays
        // in the decode batch.
        let mut s = mk(4);
        let p = drive(&mut s);
        assert_eq!(
            p.span_groups,
            vec![vec![
                GroupLane::Chunk(0),
                GroupLane::Chunk(1),
                GroupLane::Decode(3),
                GroupLane::Decode(4),
            ]]
        );
        assert_eq!(p.decode, vec![5]);
        assert!(p.spec.is_empty());
        // Lanes off: same chunks, and the advanced-sequence set is the
        // same — overlay moved ids 3 and 4, it never added or dropped
        // work.
        let mut s2 = mk(0);
        let q = drive(&mut s2);
        assert_eq!(q.prefill, p.prefill);
        assert!(q.span_groups.is_empty());
        assert_eq!(q.decode, vec![3, 4, 5]);
        // Pure overlay: decoders alone (no continuation chunks) never
        // group — plain batched decode already serves them.
        let mut s3 = mk(4);
        for id in 3..=5 {
            s3.submit(id, vec![1; 4], 8, Priority::Normal).unwrap();
        }
        let p = s3.plan(&b);
        for c in &p.prefill {
            s3.on_chunk(c.id, c.len);
            s3.on_token(c.id, false);
        }
        let p2 = s3.plan(&b);
        assert!(p2.span_groups.is_empty(), "decode-only group formed");
        assert_eq!(p2.decode, vec![3, 4, 5]);
    }

    /// Speculative chunks: steady-state decoders get a [`SpecChunk`]
    /// capped by leftover step budget, the request's remaining token
    /// budget, and context headroom; planned ids STAY in `decode`
    /// (the chunk is an option, not a commitment); `spec_tokens == 0`
    /// plans none.
    #[test]
    fn spec_chunks_cap_by_budget_and_headroom() {
        let mk = |max_seq: usize, budget: usize| {
            Scheduler::new(SchedConfig {
                max_batch: 8,
                max_admit: 4,
                max_prompt: 32,
                max_seq,
                chunk_tokens: 0,
                step_token_budget: budget,
                span_bucket_tokens: 0,
                span_group_lanes: 0,
                spec_tokens: 6,
            })
        };
        let b = Budget::new(1000);
        // Near-finished requests draft little: id 2 has one token of
        // budget left (its decode claims it), so it gets no chunk.
        let mut s = mk(64, 10);
        s.submit(1, vec![1; 4], 16, Priority::Normal).unwrap();
        s.submit(2, vec![1; 4], 2, Priority::Normal).unwrap();
        let p = s.plan(&b);
        assert!(p.spec.is_empty(), "spec planned before steady state");
        for c in &p.prefill {
            s.on_chunk(c.id, c.len);
            s.on_token(c.id, false);
        }
        let p2 = s.plan(&b);
        assert_eq!(p2.decode, vec![1, 2]);
        assert_eq!(p2.spec, vec![SpecChunk { id: 1, max_draft: 6 }]);
        // Leftover budget is the hard pool: 9 - 2 decode tokens leaves
        // 7, so the first decoder drafts its full 6 and the second gets
        // the single remaining token.
        let mut s = mk(64, 9);
        s.submit(1, vec![1; 4], 16, Priority::Normal).unwrap();
        s.submit(2, vec![1; 4], 16, Priority::Normal).unwrap();
        let p = s.plan(&b);
        for c in &p.prefill {
            s.on_chunk(c.id, c.len);
            s.on_token(c.id, false);
        }
        let p2 = s.plan(&b);
        assert_eq!(
            p2.spec,
            vec![
                SpecChunk { id: 1, max_draft: 6 },
                SpecChunk { id: 2, max_draft: 1 },
            ]
        );
        // Token-budget headroom binds: 5 allowed tokens, one generated,
        // one claimed by this step's decode -> at most 3 drafted.
        let mut s = mk(16, 0);
        s.submit(1, vec![1; 3], 5, Priority::Normal).unwrap();
        let p = s.plan(&b);
        for c in &p.prefill {
            s.on_chunk(c.id, c.len);
            s.on_token(c.id, false);
        }
        let p2 = s.plan(&b);
        assert_eq!(p2.spec, vec![SpecChunk { id: 1, max_draft: 3 }]);
        // Context headroom binds the same way near max_seq.
        let mut s = mk(8, 0);
        s.submit(1, vec![1; 3], 5, Priority::Normal).unwrap();
        let p = s.plan(&b);
        for c in &p.prefill {
            s.on_chunk(c.id, c.len);
            s.on_token(c.id, false);
        }
        // len 4 after the first token: growth takes one slot, drafts
        // may fill the remaining 8 - 5 = 3.
        let p2 = s.plan(&b);
        assert_eq!(p2.spec, vec![SpecChunk { id: 1, max_draft: 3 }]);
        // spec_tokens == 0: nothing is ever planned.
        let mut s = sched(4);
        s.submit(1, vec![1; 4], 8, Priority::Normal).unwrap();
        let p = s.plan(&b);
        for c in &p.prefill {
            s.on_chunk(c.id, c.len);
            s.on_token(c.id, false);
        }
        assert!(s.plan(&b).spec.is_empty());
    }

    /// A lone mid-prefill sequence gets no group (nothing to batch with)
    /// but its interior chunks still round down to whole span buckets —
    /// grouping layers on top of the PR 5 alignment, it does not replace
    /// it.
    #[test]
    fn lone_sequence_still_aligns_interior_chunks() {
        let mut s = Scheduler::new(SchedConfig {
            max_batch: 8,
            max_admit: 4,
            max_prompt: 64,
            max_seq: 128,
            chunk_tokens: 14,
            step_token_budget: 0,
            span_bucket_tokens: 8,
            span_group_lanes: 4,
            spec_tokens: 0,
        });
        let b = Budget::new(1000);
        s.submit(1, vec![1; 40], 4, Priority::Normal).unwrap();
        let mut seen = Vec::new();
        while !s.info(1).unwrap().prefill_done() {
            let p = s.plan(&b);
            assert!(p.span_groups.is_empty(), "singleton must not group");
            assert_eq!(p.prefill.len(), 1);
            let c = p.prefill[0];
            seen.push((c.start, c.len, c.last));
            s.on_chunk(1, c.len);
            if c.last {
                s.on_token(1, false);
            }
        }
        assert_eq!(
            seen,
            vec![(0, 14, false), (14, 8, false), (22, 8, false), (30, 10, true)]
        );
    }

    /// `forget` as the cancel primitive: a mid-prefill running sequence
    /// and a waiting one both vanish from every future plan, and the
    /// survivors keep decoding.
    #[test]
    fn forget_cancels_waiting_and_running() {
        let mut s = sched_chunked(4, 0);
        let b = Budget::new(100);
        s.submit(1, vec![7; 10], 4, Priority::Normal).unwrap();
        s.submit(2, vec![5; 4], 4, Priority::Normal).unwrap();
        let p = s.plan(&b);
        for c in &p.prefill {
            s.on_chunk(c.id, c.len);
            if c.last {
                s.on_token(c.id, false);
            }
        }
        // Seq 1 (10-token prompt, 4-token chunks) is mid-prefill.
        assert_eq!(s.n_prefilling(), 1);
        s.forget(1);
        assert_eq!(s.state(1), None);
        assert_eq!(s.n_prefilling(), 0);
        let p2 = s.plan(&b);
        assert!(p2.prefill.iter().all(|c| c.id != 1), "cancelled id planned");
        assert_eq!(p2.decode, vec![2], "survivor must keep decoding");
        // A waiting sequence cancels out of its queue the same way.
        s.submit(3, vec![9; 4], 4, Priority::Normal).unwrap();
        s.forget(3);
        assert_eq!(s.state(3), None);
        let p3 = s.plan(&b);
        assert!(p3.prefill.iter().all(|c| c.id != 3));
    }

    /// Flow-control pause: a paused decoding sequence drops out of every
    /// plan but keeps its state; peers are unaffected; resuming picks up
    /// exactly where it stopped.  Paused waiting sequences don't block
    /// FCFS admission behind them.
    #[test]
    fn paused_sequence_skipped_then_resumes() {
        let mut s = sched_chunked(4, 0);
        let b = Budget::new(100);
        s.submit(1, vec![7; 10], 8, Priority::Normal).unwrap();
        s.submit(2, vec![5; 4], 8, Priority::Normal).unwrap();
        let p = s.plan(&b);
        for c in &p.prefill {
            s.on_chunk(c.id, c.len);
            if c.last {
                s.on_token(c.id, false);
            }
        }
        // Seq 1 is mid-prefill (4/10); pause it: no chunk is planned, the
        // peer keeps decoding.
        assert!(s.set_paused(1, true));
        assert!(!s.set_paused(1, true), "second pause is a no-op");
        assert!(s.is_paused(1));
        let p2 = s.plan(&b);
        assert!(p2.prefill.is_empty(), "paused id got a chunk");
        assert_eq!(p2.decode, vec![2]);
        s.on_token(2, false);
        // Resume: the prefill continues from where it stopped.
        assert!(s.set_paused(1, false));
        let p3 = s.plan(&b);
        assert_eq!(
            p3.prefill[0],
            PrefillChunk { id: 1, start: 4, len: 4, last: false }
        );
        // A paused WAITING sequence doesn't head-of-line-block admission.
        s.submit(3, vec![9; 4], 4, Priority::Normal).unwrap();
        s.submit(4, vec![9; 4], 4, Priority::Normal).unwrap();
        s.set_paused(3, true);
        let p4 = s.plan(&b);
        assert!(p4.prefill.iter().any(|c| c.id == 4));
        assert!(p4.prefill.iter().all(|c| c.id != 3));
        // forget clears the pause flag with the rest of the record.
        s.forget(3);
        assert!(!s.is_paused(3));
    }

    #[test]
    fn rejects_oversized() {
        let mut s = sched(4);
        // Over the prefill bucket (max_prompt 32) but within context:
        // admissible — the excess runs as spans (chat transcripts grow).
        assert!(s.submit(1, vec![0; 33], 4, Priority::Normal).is_ok());
        // Over the context (max_seq 64): never fits, hard reject.
        assert!(s.submit(2, vec![0; 8], 60, Priority::Normal).is_err());
        assert!(s.submit(3, vec![], 4, Priority::Normal).is_err());
        assert!(s.submit(4, vec![0; 65], 0, Priority::Normal).is_err());
    }

    /// Property: under random arrivals/finishes the scheduler never plans
    /// more than max_batch work, never decodes a non-running sequence, and
    /// every submitted sequence eventually finishes (no starvation) when
    /// capacity is adequate.
    #[test]
    fn prop_no_starvation_and_caps_hold() {
        for seed in 0..20u64 {
            let mut rng = Rng::new(seed);
            let mut s = sched(4);
            let mut b = Budget::new(64);
            let mut submitted = Vec::new();
            let mut finished = std::collections::HashSet::new();
            let mut next = 0u64;
            for step in 0..400 {
                if rng.chance(0.3) && next < 40 {
                    let plen = rng.range(1, 9);
                    let gen = rng.range(1, 5);
                    s.submit(next, vec![1; plen], gen, Priority::Normal)
                        .unwrap();
                    submitted.push(next);
                    next += 1;
                }
                let plan = s.plan(&b);
                assert!(
                    plan.prefill.len() + plan.decode.len() <= 4,
                    "seed {seed} step {step}: batch cap violated"
                );
                for id in &plan.preempt {
                    b.release(*id);
                }
                for c in &plan.prefill {
                    // Monolithic config: every chunk is a whole prompt.
                    assert!(c.start == 0 && c.last, "seed {seed}");
                    let id = c.id;
                    b.commit_prefill(id, c.len);
                    s.on_chunk(id, c.len);
                    s.on_token(id, false); // prefill emits first token
                    if s.state(id) == Some(State::Finished) {
                        b.release(id);
                        finished.insert(id);
                    } else {
                        b.commit_decode(id);
                    }
                }
                for &id in &plan.decode {
                    assert_eq!(s.state(id), Some(State::Running), "seed {seed}");
                    s.on_token(id, rng.chance(0.1));
                    if s.state(id) == Some(State::Finished) {
                        b.release(id);
                        finished.insert(id);
                    } else {
                        b.commit_decode(id);
                    }
                }
            }
            // Drain: no new arrivals, everything must finish.
            for _ in 0..600 {
                let plan = s.plan(&b);
                for id in &plan.preempt {
                    b.release(*id);
                }
                for c in &plan.prefill {
                    let id = c.id;
                    b.commit_prefill(id, c.len);
                    s.on_chunk(id, c.len);
                    s.on_token(id, false);
                    if s.state(id) == Some(State::Finished) {
                        b.release(id);
                        finished.insert(id);
                    } else {
                        b.commit_decode(id);
                    }
                }
                for &id in &plan.decode {
                    s.on_token(id, false);
                    if s.state(id) == Some(State::Finished) {
                        b.release(id);
                        finished.insert(id);
                    } else {
                        b.commit_decode(id);
                    }
                }
            }
            for id in submitted {
                assert!(
                    finished.contains(&id),
                    "seed {seed}: seq {id} starved (state {:?})",
                    s.state(id)
                );
            }
        }
    }

    /// Render a plan as comparable bytes (debug form covers every field).
    fn plan_bytes(p: &StepPlan) -> String {
        format!(
            "prefill={:?} groups={:?} decode={:?} spec={:?} preempt={:?}",
            p.prefill, p.span_groups, p.decode, p.spec, p.preempt
        )
    }

    /// Overlay purity: tenant-tagged submissions with fair share OFF plan
    /// byte-identically to the same workload submitted untagged — the
    /// tenant id is inert bookkeeping until the overlay is enabled.
    #[test]
    fn fair_share_off_with_tenants_is_byte_identical() {
        let mut rng = Rng::new(0xFA1);
        let mut base = sched_chunked(4, 12);
        let mut tagged = sched_chunked(4, 12);
        let mut b1 = Budget::new(24);
        let mut b2 = Budget::new(24);
        let prios = [Priority::Interactive, Priority::Normal, Priority::Batch];
        for id in 1..=10u64 {
            let plen = 3 + (rng.next_u64() % 9) as usize;
            let pr = prios[(rng.next_u64() % 3) as usize];
            let prompt = vec![7u32; plen];
            base.submit(id, prompt.clone(), 3, pr).unwrap();
            tagged.submit_tenant(id, prompt, 3, pr, 1 + id % 3).unwrap();
        }
        for _ in 0..24 {
            let p1 = base.plan(&b1);
            let p2 = tagged.plan(&b2);
            assert_eq!(plan_bytes(&p1), plan_bytes(&p2));
            for (s, b, p) in [(&mut base, &mut b1, &p1), (&mut tagged, &mut b2, &p2)] {
                for &id in &p.preempt {
                    b.release(id);
                }
                for c in &p.prefill {
                    b.commit_chunk(c.id, c.len);
                    s.on_chunk(c.id, c.len);
                    if c.last {
                        s.on_token(c.id, false);
                        if s.state(c.id) == Some(State::Finished) {
                            b.release(c.id);
                        } else {
                            b.commit_decode(c.id);
                        }
                    }
                }
                for &id in &p.decode {
                    s.on_token(id, false);
                    if s.state(id) == Some(State::Finished) {
                        b.release(id);
                    } else {
                        b.commit_decode(id);
                    }
                }
            }
        }
        assert_eq!(base.n_running() + base.n_waiting(), 0);
        assert_eq!(tagged.n_running() + tagged.n_waiting(), 0);
    }

    /// Starvation regression: a hog tenant floods the queue ahead of a
    /// small tenant.  Plain FCFS admits the hog's whole backlog first;
    /// DRR must interleave the small tenant's request within the first
    /// few ticks.
    #[test]
    fn drr_prevents_hog_starvation() {
        let mut s = sched_chunked(4, 8);
        s.set_fair_share(FairShareConfig {
            enabled: true,
            quantum_tokens: 8,
            burst_quanta: 2,
        });
        let b = Budget::new(1000);
        // Hog tenant 1: ids 1..=12 submitted first, same class.
        for id in 1..=12u64 {
            s.submit_tenant(id, vec![7; 8], 2, Priority::Normal, 1).unwrap();
        }
        // Small tenant 2 arrives behind the flood.
        s.submit_tenant(100, vec![7; 8], 2, Priority::Normal, 2).unwrap();
        let mut small_admitted_at = None;
        let mut hog_admitted = 0usize;
        for tick in 0..6 {
            let p = s.plan(&b);
            for c in &p.prefill {
                if c.id == 100 && c.start == 0 {
                    small_admitted_at = Some(tick);
                } else if c.start == 0 {
                    hog_admitted += 1;
                }
                s.on_chunk(c.id, c.len);
                if c.last {
                    s.on_token(c.id, false);
                }
            }
            for &id in &p.decode {
                s.on_token(id, false);
            }
            if small_admitted_at.is_some() {
                break;
            }
        }
        let at = small_admitted_at.expect("small tenant starved behind hog backlog");
        assert!(at <= 2, "small tenant admitted only at tick {at}");
        assert!(
            hog_admitted < 12,
            "hog drained completely before the small tenant got a slot"
        );
    }

    /// The overload ladder's first rung narrows the intake: admissions
    /// halve and speculative drafts stop; level 0 restores both.
    #[test]
    fn pressure_level_throttles_admission_and_spec() {
        let mk = || {
            Scheduler::new(SchedConfig {
                max_batch: 8,
                max_admit: 4,
                max_prompt: 32,
                max_seq: 64,
                chunk_tokens: 0,
                step_token_budget: 0,
                span_bucket_tokens: 0,
                span_group_lanes: 0,
                spec_tokens: 4,
            })
        };
        let b = Budget::new(1000);
        let mut s = mk();
        for id in 1..=6u64 {
            s.submit(id, vec![7; 4], 8, Priority::Normal).unwrap();
        }
        s.set_pressure_level(1);
        let p = s.plan(&b);
        assert_eq!(p.prefill.len(), 2, "pressure must halve max_admit");
        // Promote the admitted pair to steady-state decoders.
        for c in &p.prefill {
            s.on_chunk(c.id, c.len);
            s.on_token(c.id, false);
        }
        let p2 = s.plan(&b);
        assert_eq!(p2.decode.len(), 2);
        assert!(p2.spec.is_empty(), "pressure must suppress spec drafts");
        for c in &p2.prefill {
            s.on_chunk(c.id, c.len);
            s.on_token(c.id, false);
        }
        s.set_pressure_level(0);
        let p3 = s.plan(&b);
        assert_eq!(p3.prefill.len(), 2, "recovery restores full admission");
        assert!(
            !p3.spec.is_empty(),
            "recovery restores speculative planning"
        );
        // Control: an unpressured scheduler admits all four at once.
        let mut c = mk();
        for id in 1..=6u64 {
            c.submit(id, vec![7; 4], 8, Priority::Normal).unwrap();
        }
        assert_eq!(c.plan(&b).prefill.len(), 4);
    }

    /// KV fair share bounds a tenant's block footprint while another
    /// tenant has live work.
    #[test]
    fn fair_share_bounds_tenant_kv() {
        /// Budget exposing a fixed total pool.
        struct FixedPool {
            inner: Budget,
            total: usize,
        }
        impl KvBudget for FixedPool {
            fn free_blocks(&self) -> usize {
                self.inner.free_blocks()
            }
            fn blocks_for(&self, tokens: usize) -> usize {
                self.inner.blocks_for(tokens)
            }
            fn blocks_held(&self, id: u64) -> usize {
                self.inner.blocks_held(id)
            }
            fn growth_needs_block(&self, id: u64) -> bool {
                self.inner.growth_needs_block(id)
            }
            fn total_blocks(&self) -> usize {
                self.total
            }
        }
        let mut s = sched(8);
        s.set_fair_share(FairShareConfig {
            enabled: true,
            quantum_tokens: 64,
            burst_quanta: 4,
        });
        let mut pool = FixedPool {
            inner: Budget::new(10),
            total: 10,
        };
        // Two tenants; each 8-token request reserves 3 blocks (2 prompt
        // + growth slot).  Share = 10/2 = 5 blocks: tenant 1's second
        // request would push it to 6 > 5, so it must wait even though
        // the pool still has free blocks for it.
        s.submit_tenant(1, vec![7; 8], 4, Priority::Normal, 1).unwrap();
        s.submit_tenant(2, vec![7; 8], 4, Priority::Normal, 1).unwrap();
        s.submit_tenant(3, vec![7; 8], 4, Priority::Normal, 2).unwrap();
        let p = s.plan(&pool);
        let admitted = ids_of(&p);
        assert!(admitted.contains(&1), "tenant 1's head admits");
        assert!(admitted.contains(&3), "tenant 2 admits within its share");
        assert!(
            !admitted.contains(&2),
            "tenant 1's second request exceeds its 5-block share"
        );
        for c in &p.prefill {
            pool.inner.commit_prefill(c.id, c.len);
            s.on_chunk(c.id, c.len);
        }
        // Tenant 2 finishes: tenant 1's share grows to the whole pool and
        // its queued request admits.
        s.forget(3);
        pool.inner.release(3);
        let p2 = s.plan(&pool);
        assert!(ids_of(&p2).contains(&2), "share relaxes when tenant leaves");
    }
}
