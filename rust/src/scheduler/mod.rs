//! Continuous-batching scheduler (S8), Orca/vLLM-shaped.
//!
//! Sequences move `Waiting → Running → Finished`, with `Preempted` as the
//! KV-pressure escape hatch (preempted sequences drop their cache and
//! re-queue at the front for re-prefill — "recompute" preemption, vLLM's
//! default).  Each engine iteration the scheduler produces a [`StepPlan`]:
//!
//! 1. admit waiting sequences (FCFS within priority class) while KV blocks
//!    and batch-bucket budget allow, batching their prefills;
//! 2. assemble the decode batch from every running sequence;
//! 3. if the pool cannot grow every running sequence by one token, preempt
//!    the lowest-priority / youngest sequence until it can.
//!
//! The scheduler is deliberately engine-agnostic (it never touches PJRT):
//! decisions are pure data, which is what the proptests below exercise.

use std::collections::VecDeque;

use crate::error::Result;

/// Request priority class (lower value schedules first).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Priority {
    Interactive = 0,
    Normal = 1,
    Batch = 2,
}

/// One sequence's scheduling view.
#[derive(Debug, Clone)]
pub struct SeqInfo {
    pub id: u64,
    pub priority: Priority,
    /// Prompt tokens (needed again on re-prefill after preemption).
    pub prompt: Vec<u32>,
    /// Tokens generated so far.
    pub generated: usize,
    pub max_new_tokens: usize,
    /// Current context length (prompt + generated) while Running.
    pub len: usize,
    /// Monotone admission counter (FCFS tie-break).
    pub arrival: u64,
}

impl SeqInfo {
    pub fn budget_left(&self) -> usize {
        self.max_new_tokens.saturating_sub(self.generated)
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum State {
    Waiting,
    Running,
    Finished,
}

/// What the coordinator must do this iteration.
#[derive(Debug, Default)]
pub struct StepPlan {
    /// Sequences to prefill (newly admitted or re-admitted), ids.
    pub prefill: Vec<u64>,
    /// Sequences to decode one token for, ids (current running set minus
    /// fresh prefills — those decode from the next iteration).
    pub decode: Vec<u64>,
    /// Sequences preempted this iteration (caches must be dropped).
    pub preempt: Vec<u64>,
}

/// Resource view the scheduler plans against.
pub trait KvBudget {
    /// Free blocks in the pool.
    fn free_blocks(&self) -> usize;
    /// Blocks needed to hold `tokens` for a fresh sequence.
    fn blocks_for(&self, tokens: usize) -> usize;
    /// Blocks a sequence currently holds (released if it is preempted).
    fn blocks_held(&self, id: u64) -> usize;
    /// Whether growing `id` by one token requires a fresh block right now.
    fn growth_needs_block(&self, id: u64) -> bool;
}

/// Scheduler configuration.
#[derive(Debug, Clone)]
pub struct SchedConfig {
    /// Hard cap on the decode batch (largest compiled bucket).
    pub max_batch: usize,
    /// Cap on prefills admitted per iteration (compile-bucket width).
    pub max_admit: usize,
    /// Longest admissible prompt (prefill bucket T).
    pub max_prompt: usize,
    /// Max context (cache capacity S).
    pub max_seq: usize,
}

/// The scheduler.
///
/// Waiting sequences are kept in one FIFO per priority class, so each
/// `plan()` tick walks them in admission order directly — no per-tick sort
/// (this took the tick from 59.7 µs to O(admitted) at 256 waiting; see
/// EXPERIMENTS.md §Perf).
pub struct Scheduler {
    cfg: SchedConfig,
    waiting: [VecDeque<u64>; 3],
    running: Vec<u64>,
    seqs: std::collections::HashMap<u64, (SeqInfo, State)>,
    arrivals: u64,
}

fn class_of(p: Priority) -> usize {
    p as usize
}

impl Scheduler {
    pub fn new(cfg: SchedConfig) -> Scheduler {
        Scheduler {
            cfg,
            waiting: [VecDeque::new(), VecDeque::new(), VecDeque::new()],
            running: Vec::new(),
            seqs: std::collections::HashMap::new(),
            arrivals: 0,
        }
    }

    pub fn config(&self) -> &SchedConfig {
        &self.cfg
    }

    /// Enqueue a new request. Returns Err if the prompt can never fit.
    pub fn submit(
        &mut self,
        id: u64,
        prompt: Vec<u32>,
        max_new_tokens: usize,
        priority: Priority,
    ) -> Result<()> {
        if prompt.is_empty() {
            return Err(crate::Error::Scheduler("empty prompt".into()));
        }
        if prompt.len() > self.cfg.max_prompt {
            return Err(crate::Error::Scheduler(format!(
                "prompt len {} exceeds max {}",
                prompt.len(),
                self.cfg.max_prompt
            )));
        }
        if prompt.len() + max_new_tokens > self.cfg.max_seq {
            return Err(crate::Error::Scheduler(format!(
                "prompt {} + max_new {} exceeds context {}",
                prompt.len(),
                max_new_tokens,
                self.cfg.max_seq
            )));
        }
        let info = SeqInfo {
            id,
            priority,
            len: prompt.len(),
            prompt,
            generated: 0,
            max_new_tokens,
            arrival: self.arrivals,
        };
        self.arrivals += 1;
        let class = class_of(info.priority);
        self.seqs.insert(id, (info, State::Waiting));
        self.waiting[class].push_back(id);
        Ok(())
    }

    pub fn info(&self, id: u64) -> Option<&SeqInfo> {
        self.seqs.get(&id).map(|(i, _)| i)
    }

    pub fn state(&self, id: u64) -> Option<State> {
        self.seqs.get(&id).map(|(_, s)| *s)
    }

    pub fn n_waiting(&self) -> usize {
        self.waiting.iter().map(|q| q.len()).sum()
    }

    pub fn n_running(&self) -> usize {
        self.running.len()
    }

    /// Plan one engine iteration against the KV budget.
    pub fn plan(&mut self, kv: &dyn KvBudget) -> StepPlan {
        let mut plan = StepPlan::default();

        // 1. Preempt until the BATCH-WIDE growth demand fits: each running
        //    sequence about to cross a block boundary needs one fresh block
        //    *this* step, and they draw from the same pool — checking each
        //    against the full free count independently would over-commit.
        //    A victim's released blocks count toward the supply.  Victims:
        //    lowest priority, then latest arrival (LIFO within class —
        //    preserves the oldest work, vLLM's policy).
        let mut freed_blocks = 0usize;
        loop {
            let demand = self
                .running
                .iter()
                .filter(|id| kv.growth_needs_block(**id))
                .count();
            if demand <= kv.free_blocks() + freed_blocks {
                break;
            }
            let victim = *self
                .running
                .iter()
                .max_by_key(|id| {
                    let (info, _) = &self.seqs[*id];
                    (info.priority, info.arrival)
                })
                .expect("running nonempty while demand positive");
            self.running.retain(|&x| x != victim);
            freed_blocks += kv.blocks_held(victim);
            let (info, st) = self.seqs.get_mut(&victim).unwrap();
            *st = State::Waiting;
            // Re-prefill will replay prompt + generated-so-far; genuinely a
            // recompute (generated tokens were already reported upstream,
            // the coordinator extends the stored prompt with them).
            info.len = info.prompt.len();
            let class = class_of(info.priority);
            self.waiting[class].push_front(victim);
            plan.preempt.push(victim);
            if self.running.is_empty() {
                break;
            }
        }

        // 2. Admit waiting sequences while room allows.  Reserve one block
        //    for every running sequence that will cross a block boundary on
        //    this step's decode — admission must never starve growth.
        let growth_reserve = self
            .running
            .iter()
            .filter(|id| kv.growth_needs_block(**id))
            .count();
        let mut admitted = 0usize;
        let mut free = kv.free_blocks().saturating_sub(growth_reserve);
        'classes: for class in 0..3 {
            for &id in &self.waiting[class] {
                if admitted >= self.cfg.max_admit {
                    break 'classes;
                }
                if self.running.len() + plan.prefill.len() >= self.cfg.max_batch {
                    break 'classes;
                }
                let (info, _) = &self.seqs[&id];
                let need = kv.blocks_for(info.prompt.len() + 1);
                if need > free {
                    // FCFS head-of-line: stop rather than skip, so a large
                    // request cannot be starved by smaller late arrivals.
                    break 'classes;
                }
                free -= need;
                admitted += 1;
                plan.prefill.push(id);
            }
        }
        for id in &plan.prefill {
            let class = class_of(self.seqs[id].0.priority);
            self.waiting[class].retain(|x| x != id);
            let (_, st) = self.seqs.get_mut(id).unwrap();
            *st = State::Running;
            self.running.push(*id);
        }

        // 3. Decode everything that was already running (not fresh prefills).
        plan.decode = self
            .running
            .iter()
            .copied()
            .filter(|id| !plan.prefill.contains(id))
            .collect();
        // Cap at max_batch (fresh prefills have priority for their slot).
        plan.decode
            .truncate(self.cfg.max_batch.saturating_sub(plan.prefill.len()));
        plan
    }

    /// Report a prefill/decode outcome: token appended to `id`.
    pub fn on_token(&mut self, id: u64, finished: bool) {
        let Some((info, st)) = self.seqs.get_mut(&id) else {
            return;
        };
        info.generated += 1;
        info.len += 1;
        if finished || info.budget_left() == 0 || info.len >= self.cfg.max_seq {
            *st = State::Finished;
            self.running.retain(|&x| x != id);
        }
    }

    /// After a preempted sequence is re-admitted its previously generated
    /// tokens are part of the replayed prompt.
    pub fn extend_prompt(&mut self, id: u64, tokens: &[u32]) {
        if let Some((info, _)) = self.seqs.get_mut(&id) {
            info.prompt.extend_from_slice(tokens);
            info.len = info.prompt.len();
        }
    }

    /// Remove a finished sequence's record.
    pub fn forget(&mut self, id: u64) {
        self.seqs.remove(&id);
        for q in &mut self.waiting {
            q.retain(|&x| x != id);
        }
        self.running.retain(|&x| x != id);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;
    use std::collections::HashMap;

    /// Toy budget: fixed pool, 4-token blocks, per-seq ledger.
    struct Budget {
        free: usize,
        lens: HashMap<u64, usize>,
    }

    impl Budget {
        fn new(free: usize) -> Budget {
            Budget {
                free,
                lens: HashMap::new(),
            }
        }
        fn commit_prefill(&mut self, id: u64, len: usize) {
            self.free -= len.div_ceil(4);
            self.lens.insert(id, len);
        }
        fn commit_decode(&mut self, id: u64) {
            let l = self.lens.get_mut(&id).unwrap();
            *l += 1;
            if *l % 4 == 1 && *l > 1 {
                self.free -= 1;
            }
        }
        fn release(&mut self, id: u64) {
            if let Some(l) = self.lens.remove(&id) {
                self.free += l.div_ceil(4);
            }
        }
    }

    impl KvBudget for Budget {
        fn free_blocks(&self) -> usize {
            self.free
        }
        fn blocks_for(&self, tokens: usize) -> usize {
            tokens.div_ceil(4)
        }
        fn blocks_held(&self, id: u64) -> usize {
            self.lens.get(&id).copied().unwrap_or(0).div_ceil(4)
        }
        fn growth_needs_block(&self, id: u64) -> bool {
            self.lens.get(&id).copied().unwrap_or(0) % 4 == 0
        }
    }

    fn sched(max_batch: usize) -> Scheduler {
        Scheduler::new(SchedConfig {
            max_batch,
            max_admit: 4,
            max_prompt: 32,
            max_seq: 64,
        })
    }

    #[test]
    fn fcfs_admission() {
        let mut s = sched(2);
        let mut b = Budget::new(100);
        s.submit(1, vec![5; 4], 4, Priority::Normal).unwrap();
        s.submit(2, vec![5; 4], 4, Priority::Normal).unwrap();
        s.submit(3, vec![5; 4], 4, Priority::Normal).unwrap();
        let p = s.plan(&b);
        assert_eq!(p.prefill, vec![1, 2]); // batch cap 2
        assert!(p.decode.is_empty());
        for &id in &p.prefill {
            b.commit_prefill(id, 4);
        }
        // Next iteration: 1 and 2 decode; 3 still waiting (batch full).
        let p2 = s.plan(&b);
        assert!(p2.prefill.is_empty());
        assert_eq!(p2.decode, vec![1, 2]);
    }

    #[test]
    fn priority_beats_arrival() {
        let mut s = sched(1);
        let b = Budget::new(100);
        s.submit(1, vec![5; 4], 4, Priority::Batch).unwrap();
        s.submit(2, vec![5; 4], 4, Priority::Interactive).unwrap();
        let p = s.plan(&b);
        assert_eq!(p.prefill, vec![2]);
    }

    #[test]
    fn finish_frees_slot() {
        let mut s = sched(1);
        let mut b = Budget::new(100);
        s.submit(1, vec![5; 4], 1, Priority::Normal).unwrap();
        s.submit(2, vec![5; 4], 1, Priority::Normal).unwrap();
        let p = s.plan(&b);
        assert_eq!(p.prefill, vec![1]);
        b.commit_prefill(1, 4);
        s.on_token(1, false); // budget 1 -> finished
        assert_eq!(s.state(1), Some(State::Finished));
        b.release(1);
        let p2 = s.plan(&b);
        assert_eq!(p2.prefill, vec![2]);
    }

    #[test]
    fn preempts_when_pool_exhausted() {
        let mut s = sched(4);
        let mut b = Budget::new(4); // 4 blocks of 4 tokens
        s.submit(1, vec![5; 7], 8, Priority::Normal).unwrap();
        s.submit(2, vec![5; 7], 8, Priority::Normal).unwrap();
        let p = s.plan(&b);
        assert_eq!(p.prefill, vec![1, 2]); // each reserves 2 blocks
        b.commit_prefill(1, 7);
        b.commit_prefill(2, 7);
        // First decode fills slot 8 inside block 2 of each — no pressure.
        let p2 = s.plan(&b);
        assert_eq!(p2.decode, vec![1, 2]);
        assert!(p2.preempt.is_empty());
        b.commit_decode(1);
        b.commit_decode(2);
        s.on_token(1, false);
        s.on_token(2, false);
        // Pool empty, both at a block boundary -> youngest is preempted and
        // its freed blocks unblock the survivor.
        let p3 = s.plan(&b);
        assert_eq!(p3.preempt, vec![2]);
        assert_eq!(p3.decode, vec![1]);
        assert_eq!(s.state(2), Some(State::Waiting));
    }

    #[test]
    fn rejects_oversized() {
        let mut s = sched(4);
        assert!(s.submit(1, vec![0; 33], 4, Priority::Normal).is_err());
        assert!(s.submit(2, vec![0; 8], 60, Priority::Normal).is_err());
        assert!(s.submit(3, vec![], 4, Priority::Normal).is_err());
    }

    /// Property: under random arrivals/finishes the scheduler never plans
    /// more than max_batch work, never decodes a non-running sequence, and
    /// every submitted sequence eventually finishes (no starvation) when
    /// capacity is adequate.
    #[test]
    fn prop_no_starvation_and_caps_hold() {
        for seed in 0..20u64 {
            let mut rng = Rng::new(seed);
            let mut s = sched(4);
            let mut b = Budget::new(64);
            let mut submitted = Vec::new();
            let mut finished = std::collections::HashSet::new();
            let mut next = 0u64;
            for step in 0..400 {
                if rng.chance(0.3) && next < 40 {
                    let plen = rng.range(1, 9);
                    let gen = rng.range(1, 5);
                    s.submit(next, vec![1; plen], gen, Priority::Normal)
                        .unwrap();
                    submitted.push(next);
                    next += 1;
                }
                let plan = s.plan(&b);
                assert!(
                    plan.prefill.len() + plan.decode.len() <= 4,
                    "seed {seed} step {step}: batch cap violated"
                );
                for id in &plan.preempt {
                    b.release(*id);
                }
                for &id in &plan.prefill {
                    let len = s.info(id).unwrap().prompt.len();
                    b.commit_prefill(id, len);
                    s.on_token(id, false); // prefill emits first token
                    if s.state(id) == Some(State::Finished) {
                        b.release(id);
                        finished.insert(id);
                    } else {
                        b.commit_decode(id);
                    }
                }
                for &id in &plan.decode {
                    assert_eq!(s.state(id), Some(State::Running), "seed {seed}");
                    s.on_token(id, rng.chance(0.1));
                    if s.state(id) == Some(State::Finished) {
                        b.release(id);
                        finished.insert(id);
                    } else {
                        b.commit_decode(id);
                    }
                }
            }
            // Drain: no new arrivals, everything must finish.
            for _ in 0..600 {
                let plan = s.plan(&b);
                for id in &plan.preempt {
                    b.release(*id);
                }
                for &id in &plan.prefill {
                    let len = s.info(id).unwrap().prompt.len();
                    b.commit_prefill(id, len);
                    s.on_token(id, false);
                    if s.state(id) == Some(State::Finished) {
                        b.release(id);
                        finished.insert(id);
                    } else {
                        b.commit_decode(id);
                    }
                }
                for &id in &plan.decode {
                    s.on_token(id, false);
                    if s.state(id) == Some(State::Finished) {
                        b.release(id);
                        finished.insert(id);
                    } else {
                        b.commit_decode(id);
                    }
                }
            }
            for id in submitted {
                assert!(
                    finished.contains(&id),
                    "seed {seed}: seq {id} starved (state {:?})",
                    s.state(id)
                );
            }
        }
    }
}
