//! TCP line-protocol server (S14): the deployable front of the stack,
//! speaking **protocol v2** — session-oriented, multiplexed, cancellable.
//! The wire format is specified normatively in `docs/protocol.md`; this
//! doc block is a summary and must stay in sync with it.
//!
//! One JSON object per line.  Every request may carry a client-chosen
//! `tag`; the tag is echoed on every event the request produces, and a
//! **tagged** `generate`/`chat.send` returns control to the line reader
//! immediately, so one connection can hold many in-flight requests whose
//! token streams interleave (demultiplex by `tag`).  **Untagged**
//! requests keep the v1 contract: the connection blocks until the
//! terminal event.
//!
//! ```text
//! → {"op":"generate","tag":"a","prompt":"the quick","max_new_tokens":16,
//!    "temperature":0.0,"top_k":0,"top_p":1.0,"stop":["\n"]}
//! ← {"event":"token","tag":"a","id":3,"token":287,"text":" brown"}
//! ← {"event":"done","tag":"a","id":3,"reason":"max_tokens","text":"…"}
//!   (admission failure / invalid request → terminal instead of stream;
//!    `reason` is "rejected", or "shed" + retry_after_ms when the
//!    overload ladder refused the priority class:)
//! ← {"event":"rejected","tag":"a","id":0,"reason":"rejected","msg":"backpressure: …"}
//! ← {"event":"rejected","tag":"a","id":0,"reason":"shed",
//!    "msg":"overload level 2 (shed-batch)","retry_after_ms":500}
//!
//! → {"op":"cancel","tag":"a"}        ← {"event":"ok","op":"cancel","tag":"a"}
//!                                      (stream then ends with
//!                                       {"event":"done","tag":"a","reason":"cancelled",…})
//!
//! → {"op":"chat.open"}               ← {"event":"chat.opened","conv":1}
//! → {"op":"chat.send","conv":1,"tag":"t1","text":"hello","max_new_tokens":16}
//! ← token*/done as for generate (the turn's prompt is the transcript
//!   plus the new text; prior turns are served from cached KV)
//! → {"op":"chat.close","conv":1}     ← {"event":"chat.closed","conv":1}
//!   (generate/chat.* all take a numeric `tenant`, default 0 — the
//!    fair-share accounting key; conversation handles are scoped to the
//!    tenant that opened them, cross-tenant use is a typed error)
//!
//! → {"op":"metrics"}   ← {"event":"metrics","report":"…", …structured
//!                         prefix_*/kv_*/chat_*/spec_*/requests_cancelled
//!                         fields plus ttft/e2e/queue_wait p50/p95/p99 in µs}
//! → {"op":"traffic"}   ← {"event":"traffic", …counters…}
//! → {"op":"trace.dump"}   ← {"event":"trace","enabled":true,
//!                            "trace":{…Chrome trace-event JSON…}}
//! → {"op":"metrics.prom"} ← {"event":"prom","text":"…Prometheus text…"}
//! → {"op":"metrics.stream","tag":"m","interval_ms":500}
//!                      ← {"event":"ok","op":"metrics.stream","tag":"m"},
//!                        then periodic {"event":"metrics.delta","tag":"m",
//!                        "seq":0,"d_tokens_out":…,"ttft_p99_us":…} until
//! → {"op":"metrics.stream","stop":true,"tag":"m"}
//!                      ← {"event":"ok",…} then terminal
//!                        {"event":"metrics.end","tag":"m","pushes":N}
//! → {"op":"path","value":"baseline"|"precompute"}  ← {"event":"ok"}
//! → {"op":"ping"}      ← {"event":"pong"}
//! ```
//!
//! Malformed JSON, an unknown `op`, or bad field values produce
//! `{"event":"error","msg":…}` on the offending line — with the failing
//! `op` and the request's `tag` echoed when they could be parsed — and
//! the connection stays open.
//!
//! A request whose engine work fails terminally (a device fault that
//! survives the transient-retry budget) still gets its terminal event:
//! `{"event":"done",…,"reason":"error"}`.  Slow readers are flow
//! controlled per stream: when a request's writer queue exceeds the
//! configured bound its sequence is paused in the scheduler (counted in
//! `stream_stalls`) and resumed once the reader drains — the engine and
//! every other stream keep running.  Idle conversations are expired by
//! the `--conversation-ttl` sweep as if `chat.close` had been sent.
//!
//! Threading: a single engine loop owns the coordinator (PJRT calls are
//! not assumed thread-safe); connection threads only enqueue requests.
//! Each connection runs one reader thread (parses ops, serves v1
//! blocking requests inline) and one writer thread (streams tagged
//! events as the engine fans them out); both write lines under the same
//! socket mutex, so lines never interleave mid-record.  No tokio in the
//! offline build — plain `std::net` + threads.  See `ARCHITECTURE.md`
//! for the thread/ownership diagram.

use std::collections::HashMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::coordinator::sampling::SamplingParams;
use crate::coordinator::{Coordinator, Event, FinishReason, Request};
use crate::error::{Error, Result};
use crate::runtime::StepPath;
use crate::scheduler::Priority;
use crate::tokenizer::Tokenizer;
use crate::util::json::{self, n, obj, s, Value};

/// A streamed event plus its routing metadata: the tag it must be
/// echoed with and the request's writer-queue depth counter, which the
/// writing side decrements once the event has reached the socket.  The
/// counter is the flow-control signal: the engine loop stalls a request
/// (scheduler pause — see [`Coordinator::set_stalled`]) when its queue
/// depth crosses the configured bound, and resumes it when the slow
/// reader drains back below half the bound.  Only that request stalls;
/// the engine loop and every other stream keep running.
struct StreamItem {
    tag: Option<String>,
    ev: Event,
    depth: Arc<AtomicU64>,
}

/// Why a request never entered the engine, as reported on the wire's
/// terminal `rejected` event.  `reason` separates hard admission
/// failures (`"rejected"`: backpressure, bad conversation, duplicate
/// tag) from deliberate overload shedding (`"shed"`), which carries the
/// ladder's retry hint so clients back off instead of hammering.
struct Reject {
    msg: String,
    reason: &'static str,
    retry_after_ms: Option<u64>,
}

impl Reject {
    fn rejected(msg: impl Into<String>) -> Reject {
        Reject {
            msg: msg.into(),
            reason: "rejected",
            retry_after_ms: None,
        }
    }

    /// Classify an admission error: the overload ladder's `Shed`
    /// variant becomes `reason:"shed"` + retry hint, everything else
    /// stays a plain rejection.
    fn from_error(e: &Error) -> Reject {
        match e {
            Error::Shed { msg, retry_after_ms } => Reject {
                msg: msg.clone(),
                reason: "shed",
                retry_after_ms: Some(*retry_after_ms),
            },
            other => Reject::rejected(other.to_string()),
        }
    }
}

/// Commands from connection threads to the engine loop.
enum Cmd {
    /// Submit a typed request.  `admit` gets the admission outcome
    /// (`Err` = rejected or shed, with the classified reason); on
    /// success `reply` receives every event of the request (tag
    /// attached by the engine loop).  Keeping rejection OFF the event
    /// channel matters: the shared writer keys per-stream state by tag,
    /// and a rejection must never be able to touch a live stream's
    /// accumulation (duplicate tags).
    Generate {
        conn: u64,
        req: Request,
        admit: Sender<std::result::Result<u64, Reject>>,
        reply: Sender<StreamItem>,
    },
    /// Cancel the in-flight request `tag` on connection `conn`.
    /// `reply` gets `None` on success, `Some(msg)` when nothing matched.
    Cancel {
        conn: u64,
        tag: String,
        reply: Sender<Option<String>>,
    },
    /// Open a conversation owned by `tenant`; `reply` gets the handle,
    /// or the refusal reason (conversation cap).
    ChatOpen {
        tenant: u64,
        reply: Sender<std::result::Result<u64, String>>,
    },
    /// Close a conversation (cancelling its in-flight turn, if any).
    /// `tenant` must match the conversation's owner.
    ChatClose {
        conv: u64,
        tenant: u64,
        reply: Sender<Option<String>>,
    },
    SetPath(StepPath),
}

/// Server handle.
pub struct Server {
    addr: String,
    /// Per-request writer-queue bound before the stream is stalled
    /// (slow-reader flow control); see [`ServingConfig::stream_queue_events`]
    /// [`crate::config::ServingConfig`].
    stream_queue_events: usize,
}

/// Shared handles the engine thread exports once the coordinator is built.
/// (PJRT handles are `!Send`, so the coordinator itself must be constructed
/// and owned entirely by the engine thread.)
struct EngineHandles {
    metrics: Arc<crate::metrics::Metrics>,
    traffic: Arc<crate::simtraffic::Recorder>,
    tokenizer: Arc<crate::tokenizer::Tokenizer>,
    transfers: Arc<crate::metrics::TransferStats>,
    tracer: Arc<crate::trace::Tracer>,
}

impl Server {
    pub fn new(addr: impl Into<String>) -> Server {
        Server {
            addr: addr.into(),
            stream_queue_events: 1024,
        }
    }

    /// Override the per-request writer-queue bound (events buffered for a
    /// slow reader before its stream stalls).  Clamped to >= 2 so the
    /// unstall watermark (half the bound) stays meaningful.
    pub fn with_stream_queue(mut self, events: usize) -> Server {
        self.stream_queue_events = events.max(2);
        self
    }

    /// Run forever (blocking).  `make` builds the coordinator inside the
    /// engine thread (xla handles cannot cross threads).
    pub fn run<F>(&self, make: F) -> Result<()>
    where
        F: FnOnce() -> Result<Coordinator> + Send + 'static,
    {
        let listener = TcpListener::bind(&self.addr)
            .map_err(|e| Error::Server(format!("bind {}: {e}", self.addr)))?;
        eprintln!("[firstlayer] serving on {}", self.addr);
        let (tx, rx) = channel::<Cmd>();
        let (htx, hrx) = channel::<Result<EngineHandles>>();
        let queue_limit = self.stream_queue_events;
        std::thread::spawn(move || {
            let c = match make() {
                Ok(c) => {
                    let _ = htx.send(Ok(EngineHandles {
                        metrics: c.metrics.clone(),
                        traffic: c.engine().traffic.clone(),
                        tokenizer: c.tokenizer.clone(),
                        transfers: c.engine().transfers(),
                        tracer: c.tracer(),
                    }));
                    c
                }
                Err(e) => {
                    let _ = htx.send(Err(e));
                    return;
                }
            };
            engine_loop(c, rx, queue_limit);
        });
        let handles = hrx
            .recv()
            .map_err(|_| Error::Server("engine thread died".into()))??;
        let conn_ids = AtomicU64::new(1);
        for stream in listener.incoming() {
            let Ok(stream) = stream else { continue };
            let tx = tx.clone();
            let metrics = handles.metrics.clone();
            let traffic = handles.traffic.clone();
            let tokenizer = handles.tokenizer.clone();
            let transfers = handles.transfers.clone();
            let tracer = handles.tracer.clone();
            let conn = conn_ids.fetch_add(1, Ordering::Relaxed);
            std::thread::spawn(move || {
                let _ = handle_conn(
                    stream, tx, metrics, traffic, tokenizer, transfers, tracer, conn,
                );
            });
        }
        Ok(())
    }
}

/// Per-request event routing state the engine loop keeps.
struct Sink {
    tx: Sender<StreamItem>,
    tag: Option<String>,
    conn: u64,
    /// Events enqueued for the connection's writer but not yet written
    /// to the socket (the writing side decrements).
    depth: Arc<AtomicU64>,
    /// Stalled by flow control: the request is paused in the scheduler
    /// until the reader drains below the unstall watermark.
    stalled: bool,
}

/// The engine loop: owns the coordinator, interleaves request intake with
/// `step()`, and fans events back out to the requesting connections.
/// Tags are attached here (the coordinator speaks ids only); the
/// `(conn, tag) -> id` index is what `cancel` resolves against.
///
/// Flow control: `queue_limit` bounds each request's writer queue.  A
/// stream whose reader cannot keep up is stalled in the scheduler
/// (pause, not cancel — its KV and batch slot survive) and resumed once
/// the queue drains below half the bound; the engine loop itself never
/// blocks on a slow socket.  A send to a torn-down connection cancels
/// the request instead — nobody is left to read the stream.
fn engine_loop(mut c: Coordinator, rx: Receiver<Cmd>, queue_limit: usize) {
    let mut sinks: HashMap<u64, Sink> = HashMap::new();
    let mut by_tag: HashMap<(u64, String), u64> = HashMap::new();
    loop {
        // Intake: block when idle, drain opportunistically when busy.
        if c.busy() {
            while let Ok(cmd) = rx.try_recv() {
                apply(&mut c, cmd, &mut sinks, &mut by_tag);
            }
        } else {
            match rx.recv_timeout(Duration::from_millis(200)) {
                Ok(cmd) => apply(&mut c, cmd, &mut sinks, &mut by_tag),
                Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {
                    // Idle tick: busy loops sweep inside step(), but an
                    // idle engine must still expire stale conversations.
                    if let Err(e) = c.sweep_conversations() {
                        eprintln!("[firstlayer] conversation sweep: {e}");
                    }
                    continue;
                }
                Err(_) => return, // all senders dropped: shut down
            }
        }
        // Resume streams whose slow reader caught up (below half the
        // bound, so a reader hovering at the edge does not flap).
        for (id, sink) in sinks.iter_mut() {
            if sink.stalled && (sink.depth.load(Ordering::Relaxed) as usize) <= queue_limit / 2
            {
                sink.stalled = false;
                c.set_stalled(*id, false);
            }
        }
        if c.busy() {
            if let Err(e) = c.step() {
                eprintln!("[firstlayer] step error: {e}");
            }
        }
        for ev in c.take_events() {
            let id = match &ev {
                Event::Token { id, .. } | Event::Finished { id, .. } => *id,
            };
            let done = matches!(ev, Event::Finished { .. });
            let mut drop_sink = done;
            if let Some(sink) = sinks.get_mut(&id) {
                sink.depth.fetch_add(1, Ordering::Relaxed);
                if sink
                    .tx
                    .send(StreamItem {
                        tag: sink.tag.clone(),
                        ev,
                        depth: Arc::clone(&sink.depth),
                    })
                    .is_err()
                {
                    // Connection torn down: stop paying for a stream
                    // nobody reads (the Cancelled event that follows
                    // finds no sink and is dropped).
                    drop_sink = true;
                    if !done {
                        let _ = c.cancel(id);
                    }
                } else if !done
                    && !sink.stalled
                    && sink.depth.load(Ordering::Relaxed) as usize >= queue_limit
                {
                    sink.stalled = true;
                    c.set_stalled(id, true);
                }
            }
            if drop_sink {
                if let Some(sink) = sinks.remove(&id) {
                    if let Some(t) = sink.tag {
                        by_tag.remove(&(sink.conn, t));
                    }
                }
            }
        }
    }
}

fn apply(
    c: &mut Coordinator,
    cmd: Cmd,
    sinks: &mut HashMap<u64, Sink>,
    by_tag: &mut HashMap<(u64, String), u64>,
) {
    match cmd {
        Cmd::Generate {
            conn,
            req,
            admit,
            reply,
        } => {
            let tag = req.tag.clone();
            if let Some(t) = &tag {
                if by_tag.contains_key(&(conn, t.clone())) {
                    // A duplicate tag would make the interleaved streams
                    // un-demultiplexable; refuse up front (counted like
                    // any admission rejection).
                    c.metrics
                        .requests_rejected
                        .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                    let _ = admit.send(Err(Reject::rejected(format!(
                        "tag `{t}` already in flight on this connection"
                    ))));
                    return;
                }
            }
            match c.submit(req) {
                Ok(id) => {
                    if let Some(t) = &tag {
                        by_tag.insert((conn, t.clone()), id);
                    }
                    sinks.insert(
                        id,
                        Sink {
                            tx: reply,
                            tag,
                            conn,
                            depth: Arc::new(AtomicU64::new(0)),
                            stalled: false,
                        },
                    );
                    let _ = admit.send(Ok(id));
                }
                Err(e) => {
                    // Surface admission failure (backpressure, context
                    // overflow, bad conversation, overload shed, ...)
                    // back to the reader, which writes the `rejected`
                    // event — never through the shared event writer, so
                    // a rejection cannot perturb a live stream.  The
                    // coordinator already counted it (requests_rejected
                    // or requests_shed); here we only classify.
                    eprintln!("[firstlayer] rejected: {e}");
                    let _ = admit.send(Err(Reject::from_error(&e)));
                }
            }
        }
        Cmd::Cancel { conn, tag, reply } => {
            let outcome = match by_tag.get(&(conn, tag.clone())).copied() {
                Some(id) => c.cancel(id).err().map(|e| e.to_string()),
                None => Some(format!("no in-flight request tagged `{tag}`")),
            };
            let _ = reply.send(outcome);
        }
        Cmd::ChatOpen { tenant, reply } => {
            let _ = reply.send(c.chat_open_for(tenant).map_err(|e| e.to_string()));
        }
        Cmd::ChatClose { conv, tenant, reply } => {
            let _ = reply.send(
                c.chat_close_for(conv, tenant)
                    .err()
                    .map(|e| e.to_string()),
            );
        }
        Cmd::SetPath(p) => {
            if let Err(e) = c.set_path(p) {
                eprintln!("[firstlayer] set_path: {e}");
            }
        }
    }
}

fn reason_str(r: FinishReason) -> &'static str {
    match r {
        FinishReason::Eos => "eos",
        FinishReason::MaxTokens => "max_tokens",
        FinishReason::ContextFull => "context_full",
        FinishReason::Stop => "stop",
        FinishReason::Cancelled => "cancelled",
        FinishReason::Error => "error",
    }
}

/// Append `tag` to an event's field list when present.
fn push_tag(fields: &mut Vec<(&str, Value)>, tag: &Option<String>) {
    if let Some(t) = tag {
        fields.push(("tag", s(t.clone())));
    }
}

/// An `error` event, attributing the failure to `op` and `tag` when the
/// offending line carried them (multiplexed clients demand this — see
/// `docs/protocol.md` §errors).
fn err_line(op: Option<&str>, tag: &Option<String>, msg: String) -> Value {
    let mut fields = vec![("event", s("error")), ("msg", s(msg))];
    if let Some(o) = op {
        fields.push(("op", s(o)));
    }
    push_tag(&mut fields, tag);
    obj(fields)
}

/// Format one streamed event as a protocol line.  `acc` carries the
/// per-request token accumulation the terminal `done` event reports as
/// full decoded text.
fn event_line(
    tag: &Option<String>,
    ev: &Event,
    acc: &mut Vec<u32>,
    tokenizer: &Tokenizer,
) -> (Value, bool) {
    match ev {
        Event::Token { id, token } => {
            acc.push(*token);
            let mut fields = vec![
                ("event", s("token")),
                ("id", n(*id as f64)),
                ("token", n(*token as f64)),
                ("text", s(tokenizer.decode(&[*token]))),
            ];
            push_tag(&mut fields, tag);
            (obj(fields), false)
        }
        Event::Finished { id, reason } => {
            let mut fields = vec![
                ("event", s("done")),
                ("id", n(*id as f64)),
                ("reason", s(reason_str(*reason))),
                ("text", s(tokenizer.decode(acc))),
            ];
            push_tag(&mut fields, tag);
            (obj(fields), true)
        }
    }
}

/// The per-connection writer thread: streams every tagged (multiplexed)
/// event as it arrives, accumulating tokens per tag so `done` can carry
/// the full decoded output.  Exits when the last sender (reader thread +
/// engine-side sinks) is gone, or on a write error (client hung up).
fn conn_writer(
    rx: Receiver<StreamItem>,
    out: Arc<Mutex<TcpStream>>,
    tokenizer: Arc<Tokenizer>,
) {
    let mut acc: HashMap<String, Vec<u32>> = HashMap::new();
    for item in rx {
        let key = item.tag.clone().unwrap_or_default();
        let tokens = acc.entry(key.clone()).or_default();
        let (line, terminal) = event_line(&item.tag, &item.ev, tokens, &tokenizer);
        if terminal {
            acc.remove(&key);
        }
        let wrote = send(&out, &line);
        // The depth decrement is the flow-control ack: it happens after
        // the (possibly blocking) socket write, so a slow reader keeps
        // its queue deep and stays stalled engine-side.
        item.depth.fetch_sub(1, Ordering::Relaxed);
        if wrote.is_err() {
            return; // client gone; the engine cancels on next send
        }
    }
}

/// Numeric `tenant` field (0 = default/anonymous tenant).  Declared
/// per-op: the protocol has no connection-level identity, so each
/// `generate`/`chat.*` line names the tenant it acts as.
fn parse_tenant(req: &Value) -> u64 {
    req.get_opt("tenant").and_then(|v| v.as_u64()).unwrap_or(0)
}

/// Parse the generation-shaped fields shared by `generate` and
/// `chat.send`: budget, sampling (including `top_p` and `stop`),
/// priority, tag, tenant.
fn parse_gen_fields(req: &Value) -> (usize, SamplingParams, Priority, Option<String>, u64) {
    let max_new = req
        .get_opt("max_new_tokens")
        .and_then(|v| v.as_usize())
        .unwrap_or(32);
    let stop = match req.get_opt("stop") {
        Some(Value::Str(one)) => vec![one.clone()],
        Some(v) => v
            .as_arr()
            .map(|a| {
                a.iter()
                    .filter_map(|x| x.as_str().map(|s| s.to_string()))
                    .collect()
            })
            .unwrap_or_default(),
        None => Vec::new(),
    };
    let params = SamplingParams {
        temperature: req
            .get_opt("temperature")
            .and_then(|v| v.as_f64())
            .unwrap_or(0.0),
        top_k: req.get_opt("top_k").and_then(|v| v.as_usize()).unwrap_or(0),
        top_p: req
            .get_opt("top_p")
            .and_then(|v| v.as_f64())
            .unwrap_or(1.0),
        stop,
    };
    let priority = match req.get_opt("priority").and_then(|v| v.as_str()) {
        Some("interactive") => Priority::Interactive,
        Some("batch") => Priority::Batch,
        _ => Priority::Normal,
    };
    let tag = req
        .get_opt("tag")
        .and_then(|v| v.as_str())
        .map(|t| t.to_string());
    (max_new, params, priority, tag, parse_tenant(req))
}

#[allow(clippy::too_many_arguments)]
fn handle_conn(
    stream: TcpStream,
    tx: Sender<Cmd>,
    metrics: Arc<crate::metrics::Metrics>,
    traffic: Arc<crate::simtraffic::Recorder>,
    tokenizer: Arc<crate::tokenizer::Tokenizer>,
    transfers: Arc<crate::metrics::TransferStats>,
    tracer: Arc<crate::trace::Tracer>,
    conn: u64,
) -> Result<()> {
    let reader = BufReader::new(stream.try_clone()?);
    let out = Arc::new(Mutex::new(stream));
    // Live `metrics.stream` subscriptions on this connection: tag ->
    // stop flag (stores are the only cross-thread signal the pusher
    // threads need).
    let mut streams: HashMap<String, Arc<AtomicBool>> = HashMap::new();
    // The multiplexed path: tagged requests stream through this channel
    // and the writer thread, so the reader below can keep accepting ops.
    let (atx, arx) = channel::<StreamItem>();
    {
        let out = Arc::clone(&out);
        let tokenizer = Arc::clone(&tokenizer);
        std::thread::spawn(move || conn_writer(arx, out, tokenizer));
    }
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let req = match json::parse(&line) {
            Ok(v) => v,
            Err(e) => {
                send(&out, &err_line(None, &None, e.to_string()))?;
                continue;
            }
        };
        let op = req.get_opt("op").and_then(|v| v.as_str()).map(|s| s.to_string());
        let tag = req
            .get_opt("tag")
            .and_then(|v| v.as_str())
            .map(|t| t.to_string());
        match op.as_deref() {
            Some("ping") => {
                let mut fields = vec![("event", s("pong"))];
                push_tag(&mut fields, &tag);
                send(&out, &obj(fields))?;
            }
            Some("metrics") => {
                use std::sync::atomic::Ordering::Relaxed;
                let t = transfers.snapshot();
                let mut fields = vec![
                    ("event", s("metrics")),
                    ("report", s(metrics.report())),
                    // Prefix-cache stats as structured fields so
                    // clients need not parse the report text.
                    ("prefix_hits", n(metrics.prefix_hits.load(Relaxed) as f64)),
                    (
                        "prefix_misses",
                        n(metrics.prefix_misses.load(Relaxed) as f64),
                    ),
                    (
                        "prefix_evictions",
                        n(metrics.prefix_evictions.load(Relaxed) as f64),
                    ),
                    (
                        "prefix_cached_tokens",
                        n(metrics.prefix_cached_tokens.load(Relaxed) as f64),
                    ),
                    // Host↔device transfer accounting (device-resident
                    // KV observability; `kv_*` is the cache share).
                    ("h2d_bytes", n(t.h2d_bytes as f64)),
                    ("d2h_bytes", n(t.d2h_bytes as f64)),
                    ("kv_h2d_bytes", n(t.cache_h2d_bytes as f64)),
                    ("kv_d2h_bytes", n(t.cache_d2h_bytes as f64)),
                    ("kv_cache_uploads", n(t.cache_uploads as f64)),
                    ("kv_cache_syncs", n(t.cache_syncs as f64)),
                    // Batched span execution: device executions per
                    // continuation span vs token-by-token fallbacks,
                    // plus the tokens-per-execution median.
                    (
                        "span_executions",
                        n(metrics.span_executions.load(Relaxed) as f64),
                    ),
                    (
                        "span_fallbacks",
                        n(metrics.span_fallbacks.load(Relaxed) as f64),
                    ),
                    (
                        "span_exec_tokens_p50",
                        n(metrics.span_exec_tokens.quantile(0.50) as f64),
                    ),
                    // Multi-sequence span grouping: group tiles executed
                    // (a subset of span_executions — each advanced B
                    // lanes at once) and the occupied-lane distribution.
                    (
                        "span_batched_executions",
                        n(metrics.span_batched_executions.load(Relaxed) as f64),
                    ),
                    (
                        "span_batch_occupancy_mean",
                        n(metrics.span_batch_occupancy.mean()),
                    ),
                    (
                        "span_batch_occupancy_p50",
                        n(metrics.span_batch_occupancy.quantile(0.50) as f64),
                    ),
                    // Speculative decoding: verify executions, drafted /
                    // accepted token totals, rollbacks, and the emitted-
                    // tokens-per-verify median (see docs/protocol.md).
                    (
                        "spec_executions",
                        n(metrics.spec_executions.load(Relaxed) as f64),
                    ),
                    (
                        "spec_drafted_tokens",
                        n(metrics.spec_drafted_tokens.load(Relaxed) as f64),
                    ),
                    (
                        "spec_accepted_tokens",
                        n(metrics.spec_accepted_tokens.load(Relaxed) as f64),
                    ),
                    (
                        "spec_rollbacks",
                        n(metrics.spec_rollbacks.load(Relaxed) as f64),
                    ),
                    (
                        "spec_accept_len_p50",
                        n(metrics.spec_accept_len.quantile(0.50) as f64),
                    ),
                    // v2: conversation + cancellation counters.
                    (
                        "requests_cancelled",
                        n(metrics.requests_cancelled.load(Relaxed) as f64),
                    ),
                    ("chat_turns", n(metrics.chat_turns.load(Relaxed) as f64)),
                    (
                        "chat_reused_tokens",
                        n(metrics.chat_reused_tokens.load(Relaxed) as f64),
                    ),
                    // Fault plane + degradation ladder + flow control
                    // (see docs/protocol.md §metrics).
                    (
                        "requests_errored",
                        n(metrics.requests_errored.load(Relaxed) as f64),
                    ),
                    (
                        "fault_injected",
                        n(metrics.fault_injected.load(Relaxed) as f64),
                    ),
                    (
                        "fault_retries",
                        n(metrics.fault_retries.load(Relaxed) as f64),
                    ),
                    (
                        "health_demotions",
                        n(metrics.health_demotions.load(Relaxed) as f64),
                    ),
                    (
                        "health_promotions",
                        n(metrics.health_promotions.load(Relaxed) as f64),
                    ),
                    (
                        "stream_stalls",
                        n(metrics.stream_stalls.load(Relaxed) as f64),
                    ),
                    (
                        "conversations_expired",
                        n(metrics.conversations_expired.load(Relaxed) as f64),
                    ),
                    // Overload front door: deliberate sheds (split from
                    // hard rejections) and the ladder's current rung.
                    (
                        "requests_shed",
                        n(metrics.requests_shed.load(Relaxed) as f64),
                    ),
                    (
                        "shed_ladder_level",
                        n(metrics.shed_ladder_level.load(Relaxed) as f64),
                    ),
                    // Request-level latency quantiles in µs — p99
                    // included so dashboards gate the tail, not just
                    // the middle of the distribution.
                    (
                        "ttft_p50_us",
                        n(metrics.ttft.quantile(0.50).as_micros() as f64),
                    ),
                    (
                        "ttft_p95_us",
                        n(metrics.ttft.quantile(0.95).as_micros() as f64),
                    ),
                    (
                        "ttft_p99_us",
                        n(metrics.ttft.quantile(0.99).as_micros() as f64),
                    ),
                    (
                        "e2e_p50_us",
                        n(metrics.e2e.quantile(0.50).as_micros() as f64),
                    ),
                    (
                        "e2e_p95_us",
                        n(metrics.e2e.quantile(0.95).as_micros() as f64),
                    ),
                    (
                        "e2e_p99_us",
                        n(metrics.e2e.quantile(0.99).as_micros() as f64),
                    ),
                    (
                        "queue_wait_p50_us",
                        n(metrics.queue_wait.quantile(0.50).as_micros() as f64),
                    ),
                    (
                        "queue_wait_p95_us",
                        n(metrics.queue_wait.quantile(0.95).as_micros() as f64),
                    ),
                    (
                        "queue_wait_p99_us",
                        n(metrics.queue_wait.quantile(0.99).as_micros() as f64),
                    ),
                ];
                push_tag(&mut fields, &tag);
                send(&out, &obj(fields))?;
            }
            Some("trace.dump") => {
                // The tracer holds its lock only while cloning the span
                // trees; serialization happens here, off the engine
                // thread.
                let mut fields = vec![
                    ("event", s("trace")),
                    ("enabled", Value::Bool(tracer.enabled())),
                    ("trace", tracer.dump_chrome()),
                ];
                push_tag(&mut fields, &tag);
                send(&out, &obj(fields))?;
            }
            Some("metrics.prom") => {
                let mut fields = vec![
                    ("event", s("prom")),
                    ("text", s(metrics.prometheus(&transfers.snapshot()))),
                ];
                push_tag(&mut fields, &tag);
                send(&out, &obj(fields))?;
            }
            Some("metrics.stream") => {
                let Some(t) = tag.clone() else {
                    send(
                        &out,
                        &err_line(
                            Some("metrics.stream"),
                            &None,
                            "metrics.stream needs a tag".into(),
                        ),
                    )?;
                    continue;
                };
                if req.get_opt("stop").and_then(|v| v.as_bool()).unwrap_or(false) {
                    match streams.remove(&t) {
                        Some(flag) => {
                            flag.store(true, Ordering::Relaxed);
                            let fields = vec![
                                ("event", s("ok")),
                                ("op", s("metrics.stream")),
                                ("tag", s(t)),
                            ];
                            send(&out, &obj(fields))?;
                        }
                        None => send(
                            &out,
                            &err_line(
                                Some("metrics.stream"),
                                &tag,
                                format!("no metric stream tagged `{t}`"),
                            ),
                        )?,
                    }
                    continue;
                }
                if streams.contains_key(&t) {
                    send(
                        &out,
                        &err_line(
                            Some("metrics.stream"),
                            &tag,
                            format!("metric stream `{t}` already running"),
                        ),
                    )?;
                    continue;
                }
                let interval = Duration::from_millis(
                    req.get_opt("interval_ms")
                        .and_then(|v| v.as_u64())
                        .unwrap_or(1000)
                        .clamp(20, 60_000),
                );
                let flag = Arc::new(AtomicBool::new(false));
                streams.insert(t.clone(), flag.clone());
                {
                    let out = Arc::clone(&out);
                    let metrics = Arc::clone(&metrics);
                    let transfers = Arc::clone(&transfers);
                    let t = t.clone();
                    std::thread::spawn(move || {
                        metrics_pusher(out, metrics, transfers, t, interval, flag)
                    });
                }
                let fields = vec![
                    ("event", s("ok")),
                    ("op", s("metrics.stream")),
                    ("tag", s(t)),
                ];
                send(&out, &obj(fields))?;
            }
            Some("traffic") => {
                let t = traffic.snapshot();
                let mut fields = vec![
                    ("event", s("traffic")),
                    ("l1_reads_baseline", n(t.l1_reads_baseline as f64)),
                    ("l1_reads_precomp", n(t.l1_reads_precomp as f64)),
                    ("decode_tokens", n(t.decode_tokens as f64)),
                    ("prefill_tokens", n(t.prefill_tokens as f64)),
                    ("prefill_calls", n(t.prefill_calls as f64)),
                    ("table_bytes_read", n(t.table_bytes_read as f64)),
                ];
                push_tag(&mut fields, &tag);
                send(&out, &obj(fields))?;
            }
            Some("path") => {
                let p = match req.get_opt("value").and_then(|v| v.as_str()) {
                    Some("baseline") => StepPath::Baseline,
                    Some("precompute") => StepPath::Precompute,
                    _ => {
                        send(&out, &err_line(Some("path"), &tag, "bad path".into()))?;
                        continue;
                    }
                };
                tx.send(Cmd::SetPath(p))
                    .map_err(|_| Error::Server("engine gone".into()))?;
                let mut fields = vec![("event", s("ok")), ("op", s("path"))];
                push_tag(&mut fields, &tag);
                send(&out, &obj(fields))?;
            }
            Some("generate") => {
                let text = req
                    .get_opt("prompt")
                    .and_then(|v| v.as_str())
                    .unwrap_or("")
                    .to_string();
                let (max_new, params, priority, tag, tenant) = parse_gen_fields(&req);
                let mut r = Request::from_text(text, max_new)
                    .with_params(params)
                    .with_priority(priority)
                    .with_tenant(tenant);
                r.tag = tag;
                submit_request(&out, &tx, &atx, &tokenizer, conn, r)?;
            }
            Some("chat.open") => {
                let (rtx, rrx) = channel();
                tx.send(Cmd::ChatOpen {
                    tenant: parse_tenant(&req),
                    reply: rtx,
                })
                .map_err(|_| Error::Server("engine gone".into()))?;
                match rrx.recv() {
                    Ok(Ok(conv)) => {
                        let mut fields =
                            vec![("event", s("chat.opened")), ("conv", n(conv as f64))];
                        push_tag(&mut fields, &tag);
                        send(&out, &obj(fields))?;
                    }
                    Ok(Err(msg)) => {
                        send(&out, &err_line(Some("chat.open"), &tag, msg))?
                    }
                    Err(_) => return Err(Error::Server("engine gone".into())),
                }
            }
            Some("chat.send") => {
                let Some(conv) = req.get_opt("conv").and_then(|v| v.as_u64()) else {
                    send(
                        &out,
                        &err_line(Some("chat.send"), &tag, "missing conv".into()),
                    )?;
                    continue;
                };
                let text = req
                    .get_opt("text")
                    .and_then(|v| v.as_str())
                    .unwrap_or("")
                    .to_string();
                let (max_new, params, priority, tag, tenant) = parse_gen_fields(&req);
                let mut r = Request::turn(conv, text, max_new)
                    .with_params(params)
                    .with_priority(priority)
                    .with_tenant(tenant);
                r.tag = tag;
                submit_request(&out, &tx, &atx, &tokenizer, conn, r)?;
            }
            Some("chat.close") => {
                let Some(conv) = req.get_opt("conv").and_then(|v| v.as_u64()) else {
                    send(
                        &out,
                        &err_line(Some("chat.close"), &tag, "missing conv".into()),
                    )?;
                    continue;
                };
                let (rtx, rrx) = channel();
                tx.send(Cmd::ChatClose {
                    conv,
                    tenant: parse_tenant(&req),
                    reply: rtx,
                })
                .map_err(|_| Error::Server("engine gone".into()))?;
                match rrx.recv() {
                    Ok(None) => {
                        let mut fields =
                            vec![("event", s("chat.closed")), ("conv", n(conv as f64))];
                        push_tag(&mut fields, &tag);
                        send(&out, &obj(fields))?;
                    }
                    Ok(Some(msg)) => {
                        send(&out, &err_line(Some("chat.close"), &tag, msg))?
                    }
                    Err(_) => return Err(Error::Server("engine gone".into())),
                }
            }
            Some("cancel") => {
                let Some(t) = tag.clone() else {
                    send(
                        &out,
                        &err_line(Some("cancel"), &None, "cancel needs a tag".into()),
                    )?;
                    continue;
                };
                let (rtx, rrx) = channel();
                tx.send(Cmd::Cancel {
                    conn,
                    tag: t.clone(),
                    reply: rtx,
                })
                .map_err(|_| Error::Server("engine gone".into()))?;
                match rrx.recv() {
                    Ok(None) => {
                        let fields = vec![
                            ("event", s("ok")),
                            ("op", s("cancel")),
                            ("tag", s(t)),
                        ];
                        send(&out, &obj(fields))?;
                    }
                    Ok(Some(msg)) => send(&out, &err_line(Some("cancel"), &tag, msg))?,
                    Err(_) => return Err(Error::Server("engine gone".into())),
                }
            }
            other => {
                let msg = match other {
                    Some(o) => format!("unknown op `{o}`"),
                    None => "missing op".to_string(),
                };
                send(&out, &err_line(other, &tag, msg))?;
            }
        }
    }
    // Reader gone (client hung up): stop any live metric streams so
    // their pusher threads exit instead of spinning on a dead socket.
    for flag in streams.values() {
        flag.store(true, Ordering::Relaxed);
    }
    Ok(())
}

/// Cumulative counter base for `metrics.stream` deltas.
struct DeltaBase {
    requests_done: u64,
    requests_shed: u64,
    tokens_out: u64,
    span_executions: u64,
    span_fallbacks: u64,
    spec_executions: u64,
    spec_accepted_tokens: u64,
    prefix_evictions: u64,
    preemptions: u64,
    transfers: crate::metrics::TransferSnapshot,
}

fn delta_base(m: &crate::metrics::Metrics, t: &crate::metrics::TransferStats) -> DeltaBase {
    use std::sync::atomic::Ordering::Relaxed;
    DeltaBase {
        requests_done: m.requests_done.load(Relaxed),
        requests_shed: m.requests_shed.load(Relaxed),
        tokens_out: m.tokens_out.load(Relaxed),
        span_executions: m.span_executions.load(Relaxed),
        span_fallbacks: m.span_fallbacks.load(Relaxed),
        spec_executions: m.spec_executions.load(Relaxed),
        spec_accepted_tokens: m.spec_accepted_tokens.load(Relaxed),
        prefix_evictions: m.prefix_evictions.load(Relaxed),
        preemptions: m.preemptions.load(Relaxed),
        transfers: t.snapshot(),
    }
}

/// One `metrics.stream` subscription: pushes a tagged `metrics.delta`
/// event every `interval` until the stop flag is set (explicit
/// `{"op":"metrics.stream","stop":true,…}` or connection teardown) or
/// the client hangs up.  Counter fields are deltas since the previous
/// push (`d_` prefix); latency quantiles are cumulative — the
/// log-bucketed histograms cannot be differenced.
fn metrics_pusher(
    out: Arc<Mutex<TcpStream>>,
    metrics: Arc<crate::metrics::Metrics>,
    transfers: Arc<crate::metrics::TransferStats>,
    tag: String,
    interval: Duration,
    stop: Arc<AtomicBool>,
) {
    let mut prev = delta_base(&metrics, &transfers);
    let mut seq = 0u64;
    while !stop.load(Ordering::Relaxed) {
        std::thread::sleep(interval);
        if stop.load(Ordering::Relaxed) {
            break;
        }
        let curr = delta_base(&metrics, &transfers);
        let dt = curr.transfers.since(&prev.transfers);
        let us = |h: &crate::metrics::Histogram, q: f64| n(h.quantile(q).as_micros() as f64);
        let fields = vec![
            ("event", s("metrics.delta")),
            ("tag", s(tag.clone())),
            ("seq", n(seq as f64)),
            (
                "d_requests_done",
                n((curr.requests_done - prev.requests_done) as f64),
            ),
            (
                "d_requests_shed",
                n((curr.requests_shed - prev.requests_shed) as f64),
            ),
            // Gauge, not a delta: the ladder's rung right now.
            (
                "shed_ladder_level",
                n(metrics.shed_ladder_level.load(Ordering::Relaxed) as f64),
            ),
            ("d_tokens_out", n((curr.tokens_out - prev.tokens_out) as f64)),
            (
                "d_span_executions",
                n((curr.span_executions - prev.span_executions) as f64),
            ),
            (
                "d_span_fallbacks",
                n((curr.span_fallbacks - prev.span_fallbacks) as f64),
            ),
            (
                "d_spec_executions",
                n((curr.spec_executions - prev.spec_executions) as f64),
            ),
            (
                "d_spec_accepted_tokens",
                n((curr.spec_accepted_tokens - prev.spec_accepted_tokens) as f64),
            ),
            (
                "d_prefix_evictions",
                n((curr.prefix_evictions - prev.prefix_evictions) as f64),
            ),
            (
                "d_preemptions",
                n((curr.preemptions - prev.preemptions) as f64),
            ),
            ("d_h2d_bytes", n(dt.h2d_bytes as f64)),
            ("d_d2h_bytes", n(dt.d2h_bytes as f64)),
            ("d_kv_h2d_bytes", n(dt.cache_h2d_bytes as f64)),
            ("d_kv_d2h_bytes", n(dt.cache_d2h_bytes as f64)),
            ("ttft_p50_us", us(&metrics.ttft, 0.50)),
            ("ttft_p95_us", us(&metrics.ttft, 0.95)),
            ("ttft_p99_us", us(&metrics.ttft, 0.99)),
            ("e2e_p50_us", us(&metrics.e2e, 0.50)),
            ("e2e_p95_us", us(&metrics.e2e, 0.95)),
            ("e2e_p99_us", us(&metrics.e2e, 0.99)),
            ("queue_wait_p50_us", us(&metrics.queue_wait, 0.50)),
            ("queue_wait_p95_us", us(&metrics.queue_wait, 0.95)),
            ("queue_wait_p99_us", us(&metrics.queue_wait, 0.99)),
            (
                "span_batch_occupancy_mean",
                n(metrics.span_batch_occupancy.mean()),
            ),
        ];
        if send(&out, &obj(fields)).is_err() {
            return; // client gone; no terminal event possible
        }
        prev = curr;
        seq += 1;
    }
    let _ = send(
        &out,
        &obj(vec![
            ("event", s("metrics.end")),
            ("tag", s(tag)),
            ("pushes", n(seq as f64)),
        ]),
    );
}

/// The terminal `rejected` event for an unadmitted request.  Written by
/// the READER thread on the raw socket — deliberately not routed through
/// the shared tagged writer, whose per-tag accumulation must never be
/// touched by a request that was never admitted (see `Cmd::Generate`).
/// `reason` is `"rejected"` or `"shed"`; shed lines carry the ladder's
/// `retry_after_ms` back-off hint.
fn rejected_line(tag: &Option<String>, r: &Reject) -> Value {
    let mut fields = vec![
        ("event", s("rejected")),
        ("id", n(0.0)),
        ("reason", s(r.reason)),
        ("msg", s(r.msg.clone())),
    ];
    if let Some(ms) = r.retry_after_ms {
        fields.push(("retry_after_ms", n(ms as f64)));
    }
    push_tag(&mut fields, tag);
    obj(fields)
}

/// Route a typed request.  Admission is resolved synchronously (the
/// engine answers on `admit` between steps): a rejection is written
/// here as the terminal `rejected` event — it never enters the shared
/// event writer, so it cannot perturb a live stream's accumulation.
/// On admission, tagged requests stream through the connection's
/// multiplexed writer (the reader returns immediately); untagged
/// requests keep the v1 contract — drain the stream inline, blocking
/// this connection until the terminal event.
fn submit_request(
    out: &Arc<Mutex<TcpStream>>,
    tx: &Sender<Cmd>,
    atx: &Sender<StreamItem>,
    tokenizer: &Tokenizer,
    conn: u64,
    req: Request,
) -> Result<()> {
    let tag = req.tag.clone();
    let tagged = tag.is_some();
    let (admit_tx, admit_rx) = channel();
    let (etx, erx) = channel();
    let reply = if tagged { atx.clone() } else { etx };
    tx.send(Cmd::Generate {
        conn,
        req,
        admit: admit_tx,
        reply,
    })
    .map_err(|_| Error::Server("engine gone".into()))?;
    match admit_rx.recv() {
        Ok(Ok(_id)) => {}
        Ok(Err(reject)) => {
            send(out, &rejected_line(&tag, &reject))?;
            return Ok(());
        }
        Err(_) => return Err(Error::Server("engine gone".into())),
    }
    if tagged {
        return Ok(());
    }
    let mut tokens: Vec<u32> = Vec::new();
    for item in erx {
        let (line, terminal) = event_line(&item.tag, &item.ev, &mut tokens, tokenizer);
        let wrote = send(out, &line);
        item.depth.fetch_sub(1, Ordering::Relaxed);
        wrote?;
        if terminal {
            break;
        }
    }
    Ok(())
}

fn send(out: &Arc<Mutex<TcpStream>>, v: &Value) -> Result<()> {
    let mut line = json::to_string(v);
    line.push('\n');
    // A poisoned socket mutex (a writer panicked mid-line) tears down
    // this connection only — never the process.
    let mut sock = out
        .lock()
        .map_err(|_| Error::Server("socket lock poisoned".into()))?;
    sock.write_all(line.as_bytes())
        .map_err(|e| Error::Server(e.to_string()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reason_str_covers_every_finish_reason() {
        // Exhaustive match in `reason_str` guarantees coverage at
        // compile time; pin the wire words so they cannot drift from
        // docs/protocol.md silently.
        assert_eq!(reason_str(FinishReason::Eos), "eos");
        assert_eq!(reason_str(FinishReason::MaxTokens), "max_tokens");
        assert_eq!(reason_str(FinishReason::ContextFull), "context_full");
        assert_eq!(reason_str(FinishReason::Stop), "stop");
        assert_eq!(reason_str(FinishReason::Cancelled), "cancelled");
        assert_eq!(reason_str(FinishReason::Error), "error");
    }

    #[test]
    fn parse_gen_fields_reads_v2_sampling() {
        let req = json::parse(
            r#"{"op":"generate","tag":"a","prompt":"x","max_new_tokens":7,
                "temperature":0.5,"top_k":3,"top_p":0.9,
                "stop":["\n","END"],"priority":"interactive","tenant":42}"#,
        )
        .unwrap();
        let (max_new, params, priority, tag, tenant) = parse_gen_fields(&req);
        assert_eq!(max_new, 7);
        assert_eq!(params.top_k, 3);
        assert!((params.top_p - 0.9).abs() < 1e-12);
        assert!((params.temperature - 0.5).abs() < 1e-12);
        assert_eq!(params.stop, vec!["\n".to_string(), "END".to_string()]);
        assert_eq!(priority, Priority::Interactive);
        assert_eq!(tag.as_deref(), Some("a"));
        assert_eq!(tenant, 42);
    }

    #[test]
    fn parse_gen_fields_defaults_and_scalar_stop() {
        let req = json::parse(r#"{"op":"generate","stop":"\n\n"}"#).unwrap();
        let (max_new, params, priority, tag, tenant) = parse_gen_fields(&req);
        assert_eq!(max_new, 32);
        assert_eq!(params.top_k, 0);
        assert!((params.top_p - 1.0).abs() < 1e-12);
        assert_eq!(params.stop, vec!["\n\n".to_string()]);
        assert_eq!(priority, Priority::Normal);
        assert!(tag.is_none());
        assert_eq!(tenant, 0);
    }

    #[test]
    fn rejected_line_distinguishes_shed_from_rejected() {
        // Hard rejection: reason "rejected", no retry hint.
        let v = rejected_line(
            &Some("a".into()),
            &Reject::rejected("backpressure: queue full"),
        );
        let back = json::parse(&json::to_string(&v)).unwrap();
        assert_eq!(
            back.get_opt("event").and_then(|e| e.as_str()),
            Some("rejected")
        );
        assert_eq!(
            back.get_opt("reason").and_then(|r| r.as_str()),
            Some("rejected")
        );
        assert_eq!(back.get_opt("tag").and_then(|t| t.as_str()), Some("a"));
        assert!(back.get_opt("retry_after_ms").is_none());
        // Shed: classified off the typed error, carries retry_after_ms.
        let v = rejected_line(
            &None,
            &Reject::from_error(&Error::Shed {
                msg: "overload level 2 (shed-batch)".into(),
                retry_after_ms: 500,
            }),
        );
        let back = json::parse(&json::to_string(&v)).unwrap();
        assert_eq!(
            back.get_opt("reason").and_then(|r| r.as_str()),
            Some("shed")
        );
        assert_eq!(
            back.get_opt("retry_after_ms").and_then(|r| r.as_u64()),
            Some(500)
        );
        assert!(back.get_opt("tag").is_none());
        // Non-shed errors classify as plain rejections.
        let r = Reject::from_error(&Error::Backpressure("queue full".into()));
        assert_eq!(r.reason, "rejected");
        assert!(r.retry_after_ms.is_none());
    }

    #[test]
    fn err_line_attributes_op_and_tag() {
        let v = err_line(Some("chat.send"), &Some("t7".into()), "missing conv".into());
        let line = json::to_string(&v);
        let back = json::parse(&line).unwrap();
        assert_eq!(back.get_opt("event").and_then(|e| e.as_str()), Some("error"));
        assert_eq!(back.get_opt("op").and_then(|o| o.as_str()), Some("chat.send"));
        assert_eq!(back.get_opt("tag").and_then(|t| t.as_str()), Some("t7"));
        // Unparseable lines carry neither.
        let v = err_line(None, &None, "bad json".into());
        assert!(v.get_opt("op").is_none() && v.get_opt("tag").is_none());
    }

    #[test]
    fn event_line_tags_and_accumulates() {
        let tok = Tokenizer::train_or_fallback(
            crate::tokenizer::bundled_corpus(),
            512,
        )
        .unwrap();
        let tag = Some("a".to_string());
        let mut acc = Vec::new();
        let piece = tok.encode("hi")[0];
        let (v, terminal) =
            event_line(&tag, &Event::Token { id: 3, token: piece }, &mut acc, &tok);
        assert!(!terminal);
        assert_eq!(v.get_opt("tag").and_then(|t| t.as_str()), Some("a"));
        assert_eq!(acc, vec![piece]);
        let (v, terminal) = event_line(
            &tag,
            &Event::Finished {
                id: 3,
                reason: FinishReason::Cancelled,
            },
            &mut acc,
            &tok,
        );
        assert!(terminal);
        assert_eq!(
            v.get_opt("reason").and_then(|r| r.as_str()),
            Some("cancelled")
        );
        assert_eq!(
            v.get_opt("text").and_then(|t| t.as_str()),
            Some(tok.decode(&acc)).as_deref()
        );
        // Untagged (v1) events carry no tag field at all.
        let (v, _) =
            event_line(&None, &Event::Token { id: 1, token: piece }, &mut acc, &tok);
        assert!(v.get_opt("tag").is_none());
    }
}
