//! TCP line-protocol server (S14): the deployable front of the stack.
//! The wire format is specified normatively in `docs/protocol.md`; this
//! doc block is a summary and must stay in sync with it.
//!
//! One JSON object per line, request → streamed response lines:
//!
//! ```text
//! → {"op":"generate","prompt":"the quick","max_new_tokens":16,
//!    "temperature":0.0,"top_k":0}
//! ← {"event":"token","id":3,"token":287,"text":" brown"}
//! ← {"event":"done","id":3,"reason":"max_tokens","text":"<full output>"}
//!   (or, under admission-control backpressure / on an invalid request:)
//! ← {"event":"rejected","id":0,"msg":"backpressure: waiting queue full"}
//!
//! → {"op":"metrics"}      ← {"event":"metrics","report":"...",
//!                            "prefix_hits":…,"prefix_misses":…,
//!                            "prefix_evictions":…,"prefix_cached_tokens":…,
//!                            "h2d_bytes":…,"d2h_bytes":…,"kv_h2d_bytes":…,
//!                            "kv_d2h_bytes":…,"kv_cache_uploads":…,
//!                            "kv_cache_syncs":…}
//! → {"op":"traffic"}      ← {"event":"traffic", ...counters...}
//! → {"op":"path","value":"baseline"|"precompute"}  ← {"event":"ok"}
//! → {"op":"ping"}         ← {"event":"pong"}
//! ```
//!
//! Malformed JSON, an unknown `op`, or a bad `path` value produce
//! `{"event":"error","msg":...}` on the offending line; the connection
//! stays open.
//!
//! Threading: a single engine loop owns the coordinator (PJRT calls are
//! not assumed thread-safe); connection threads only enqueue requests and
//! wait on per-request channels.  No tokio in the offline build — plain
//! `std::net` + threads, which a coordinator at this scale genuinely
//! doesn't need more than.  See `ARCHITECTURE.md` for the thread/ownership
//! diagram.

use std::collections::HashMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::coordinator::sampling::SamplingParams;
use crate::coordinator::{Coordinator, Event, FinishReason};
use crate::error::{Error, Result};
use crate::runtime::StepPath;
use crate::util::json::{self, n, obj, s, Value};

/// Commands from connection threads to the engine loop.
enum Cmd {
    Generate {
        text: String,
        max_new_tokens: usize,
        params: SamplingParams,
        /// Streamed events go back through this.
        reply: Sender<Event>,
    },
    SetPath(StepPath),
}

/// Server handle.
pub struct Server {
    addr: String,
}

/// Shared handles the engine thread exports once the coordinator is built.
/// (PJRT handles are `!Send`, so the coordinator itself must be constructed
/// and owned entirely by the engine thread.)
struct EngineHandles {
    metrics: Arc<crate::metrics::Metrics>,
    traffic: Arc<crate::simtraffic::Recorder>,
    tokenizer: Arc<crate::tokenizer::Tokenizer>,
    transfers: Arc<crate::metrics::TransferStats>,
}

impl Server {
    pub fn new(addr: impl Into<String>) -> Server {
        Server { addr: addr.into() }
    }

    /// Run forever (blocking).  `make` builds the coordinator inside the
    /// engine thread (xla handles cannot cross threads).
    pub fn run<F>(&self, make: F) -> Result<()>
    where
        F: FnOnce() -> Result<Coordinator> + Send + 'static,
    {
        let listener = TcpListener::bind(&self.addr)
            .map_err(|e| Error::Server(format!("bind {}: {e}", self.addr)))?;
        eprintln!("[firstlayer] serving on {}", self.addr);
        let (tx, rx) = channel::<Cmd>();
        let (htx, hrx) = channel::<Result<EngineHandles>>();
        std::thread::spawn(move || {
            let c = match make() {
                Ok(c) => {
                    let _ = htx.send(Ok(EngineHandles {
                        metrics: c.metrics.clone(),
                        traffic: c.engine().traffic.clone(),
                        tokenizer: c.tokenizer.clone(),
                        transfers: c.engine().transfers(),
                    }));
                    c
                }
                Err(e) => {
                    let _ = htx.send(Err(e));
                    return;
                }
            };
            engine_loop(c, rx);
        });
        let handles = hrx
            .recv()
            .map_err(|_| Error::Server("engine thread died".into()))??;
        for stream in listener.incoming() {
            let Ok(stream) = stream else { continue };
            let tx = tx.clone();
            let metrics = handles.metrics.clone();
            let traffic = handles.traffic.clone();
            let tokenizer = handles.tokenizer.clone();
            let transfers = handles.transfers.clone();
            std::thread::spawn(move || {
                let _ = handle_conn(stream, tx, metrics, traffic, tokenizer, transfers);
            });
        }
        Ok(())
    }
}

/// The engine loop: owns the coordinator, interleaves request intake with
/// `step()`, and fans events back out to the requesting connections.
fn engine_loop(mut c: Coordinator, rx: Receiver<Cmd>) {
    let mut sinks: HashMap<u64, Sender<Event>> = HashMap::new();
    loop {
        // Intake: block when idle, drain opportunistically when busy.
        if c.busy() {
            while let Ok(cmd) = rx.try_recv() {
                apply(&mut c, cmd, &mut sinks);
            }
        } else {
            match rx.recv_timeout(Duration::from_millis(200)) {
                Ok(cmd) => apply(&mut c, cmd, &mut sinks),
                Err(std::sync::mpsc::RecvTimeoutError::Timeout) => continue,
                Err(_) => return, // all senders dropped: shut down
            }
        }
        if c.busy() {
            if let Err(e) = c.step() {
                eprintln!("[firstlayer] step error: {e}");
            }
        }
        for ev in c.take_events() {
            let id = match &ev {
                Event::Token { id, .. }
                | Event::Finished { id, .. }
                | Event::Rejected { id, .. } => *id,
            };
            let done = matches!(ev, Event::Finished { .. } | Event::Rejected { .. });
            if let Some(sink) = sinks.get(&id) {
                let _ = sink.send(ev);
            }
            if done {
                sinks.remove(&id);
            }
        }
    }
}

fn apply(c: &mut Coordinator, cmd: Cmd, sinks: &mut HashMap<u64, Sender<Event>>) {
    match cmd {
        Cmd::Generate {
            text,
            max_new_tokens,
            params,
            reply,
        } => match c.submit_text(&text, max_new_tokens, params) {
            Ok(id) => {
                sinks.insert(id, reply);
            }
            Err(e) => {
                // Surface admission failure (backpressure, oversized
                // prompt, ...) as an immediate `rejected` event so the
                // client can back off and retry instead of hanging.
                let _ = reply.send(Event::Rejected {
                    id: 0,
                    msg: e.to_string(),
                });
                eprintln!("[firstlayer] rejected: {e}");
            }
        },
        Cmd::SetPath(p) => {
            if let Err(e) = c.set_path(p) {
                eprintln!("[firstlayer] set_path: {e}");
            }
        }
    }
}

fn reason_str(r: FinishReason) -> &'static str {
    match r {
        FinishReason::Eos => "eos",
        FinishReason::MaxTokens => "max_tokens",
        FinishReason::ContextFull => "context_full",
    }
}

fn handle_conn(
    stream: TcpStream,
    tx: Sender<Cmd>,
    metrics: Arc<crate::metrics::Metrics>,
    traffic: Arc<crate::simtraffic::Recorder>,
    tokenizer: Arc<crate::tokenizer::Tokenizer>,
    transfers: Arc<crate::metrics::TransferStats>,
) -> Result<()> {
    let peer = stream.peer_addr().ok();
    let reader = BufReader::new(stream.try_clone()?);
    let out = Arc::new(Mutex::new(stream));
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let req = match json::parse(&line) {
            Ok(v) => v,
            Err(e) => {
                send(&out, &obj(vec![("event", s("error")), ("msg", s(e.to_string()))]))?;
                continue;
            }
        };
        match req.get_opt("op").and_then(|v| v.as_str()) {
            Some("ping") => send(&out, &obj(vec![("event", s("pong"))]))?,
            Some("metrics") => {
                use std::sync::atomic::Ordering::Relaxed;
                let t = transfers.snapshot();
                send(
                    &out,
                    &obj(vec![
                        ("event", s("metrics")),
                        ("report", s(metrics.report())),
                        // Prefix-cache stats as structured fields so
                        // clients need not parse the report text.
                        ("prefix_hits", n(metrics.prefix_hits.load(Relaxed) as f64)),
                        (
                            "prefix_misses",
                            n(metrics.prefix_misses.load(Relaxed) as f64),
                        ),
                        (
                            "prefix_evictions",
                            n(metrics.prefix_evictions.load(Relaxed) as f64),
                        ),
                        (
                            "prefix_cached_tokens",
                            n(metrics.prefix_cached_tokens.load(Relaxed) as f64),
                        ),
                        // Host↔device transfer accounting (device-resident
                        // KV observability; `kv_*` is the cache share).
                        ("h2d_bytes", n(t.h2d_bytes as f64)),
                        ("d2h_bytes", n(t.d2h_bytes as f64)),
                        ("kv_h2d_bytes", n(t.cache_h2d_bytes as f64)),
                        ("kv_d2h_bytes", n(t.cache_d2h_bytes as f64)),
                        ("kv_cache_uploads", n(t.cache_uploads as f64)),
                        ("kv_cache_syncs", n(t.cache_syncs as f64)),
                    ]),
                )?
            }
            Some("traffic") => {
                let t = traffic.snapshot();
                send(
                    &out,
                    &obj(vec![
                        ("event", s("traffic")),
                        ("l1_reads_baseline", n(t.l1_reads_baseline as f64)),
                        ("l1_reads_precomp", n(t.l1_reads_precomp as f64)),
                        ("decode_tokens", n(t.decode_tokens as f64)),
                        ("prefill_tokens", n(t.prefill_tokens as f64)),
                        ("prefill_calls", n(t.prefill_calls as f64)),
                        ("table_bytes_read", n(t.table_bytes_read as f64)),
                    ]),
                )?
            }
            Some("path") => {
                let p = match req.get_opt("value").and_then(|v| v.as_str()) {
                    Some("baseline") => StepPath::Baseline,
                    Some("precompute") => StepPath::Precompute,
                    _ => {
                        send(&out, &obj(vec![("event", s("error")), ("msg", s("bad path"))]))?;
                        continue;
                    }
                };
                tx.send(Cmd::SetPath(p))
                    .map_err(|_| Error::Server("engine gone".into()))?;
                send(&out, &obj(vec![("event", s("ok"))]))?;
            }
            Some("generate") => {
                let text = req
                    .get_opt("prompt")
                    .and_then(|v| v.as_str())
                    .unwrap_or("")
                    .to_string();
                let max_new = req
                    .get_opt("max_new_tokens")
                    .and_then(|v| v.as_usize())
                    .unwrap_or(32);
                let params = SamplingParams {
                    temperature: req
                        .get_opt("temperature")
                        .and_then(|v| v.as_f64())
                        .unwrap_or(0.0),
                    top_k: req.get_opt("top_k").and_then(|v| v.as_usize()).unwrap_or(0),
                };
                let (etx, erx) = channel();
                tx.send(Cmd::Generate {
                    text,
                    max_new_tokens: max_new,
                    params,
                    reply: etx,
                })
                .map_err(|_| Error::Server("engine gone".into()))?;
                let mut tokens: Vec<u32> = Vec::new();
                for ev in erx {
                    match ev {
                        Event::Token { id, token } => {
                            tokens.push(token);
                            let piece = tokenizer.decode(&[token]);
                            send(
                                &out,
                                &obj(vec![
                                    ("event", s("token")),
                                    ("id", n(id as f64)),
                                    ("token", n(token as f64)),
                                    ("text", s(piece)),
                                ]),
                            )?;
                        }
                        Event::Finished { id, reason } => {
                            send(
                                &out,
                                &obj(vec![
                                    ("event", s("done")),
                                    ("id", n(id as f64)),
                                    ("reason", s(reason_str(reason))),
                                    ("text", s(tokenizer.decode(&tokens))),
                                ]),
                            )?;
                            break;
                        }
                        Event::Rejected { id, msg } => {
                            send(
                                &out,
                                &obj(vec![
                                    ("event", s("rejected")),
                                    ("id", n(id as f64)),
                                    ("msg", s(msg)),
                                ]),
                            )?;
                            break;
                        }
                    }
                }
            }
            _ => send(&out, &obj(vec![("event", s("error")), ("msg", s("unknown op"))]))?,
        }
    }
    let _ = peer;
    Ok(())
}

fn send(out: &Arc<Mutex<TcpStream>>, v: &Value) -> Result<()> {
    let mut line = json::to_string(v);
    line.push('\n');
    out.lock()
        .unwrap()
        .write_all(line.as_bytes())
        .map_err(|e| Error::Server(e.to_string()))
}
