//! Memory-traffic simulator (S12): *measured* read accounting.
//!
//! The paper's evaluation is a count of memory reads in the bandwidth-bound
//! decode regime.  We have no A100-class testbed, so the substitution
//! (DESIGN.md §7) is to count, inside the live engine, exactly the reads
//! the paper counts, per executed step:
//!
//! * baseline first layer, per decode batch of `B`:
//!   `B·d` embedding values + `W` weight values (Q,K,V [+FFN]) streamed,
//! * precompute first layer: `B·2(d+e)` table values, nothing else.
//!
//! E3 (`examples/batch_sweep`) then reports the measured ratio next to the
//! analytical `costmodel` prediction — they must agree exactly, which is
//! the point: the analytical table is validated by execution, not by a
//! second copy of the same formula.  Counters are atomics: the server path
//! records from multiple worker threads.

use std::sync::atomic::{AtomicU64, Ordering};

use crate::config::ModelConfig;
use crate::costmodel;
use crate::runtime::StepPath;

/// Aggregated traffic counters (values = f32 element reads, as in the paper).
#[derive(Debug, Default)]
pub struct Recorder {
    /// Decode steps executed per path.
    pub decode_steps_baseline: AtomicU64,
    pub decode_steps_precomp: AtomicU64,
    /// First-layer reads per path (the paper's table-2 quantity).
    pub l1_reads_baseline: AtomicU64,
    pub l1_reads_precomp: AtomicU64,
    /// Tokens processed.
    pub decode_tokens: AtomicU64,
    pub prefill_tokens: AtomicU64,
    /// Prefill executions (one per `record_prefill` call: a batched
    /// prefill group or one continuation span — NOT one per chunk; the
    /// per-chunk count lives in `Metrics::prefill_chunks`).
    pub prefill_calls: AtomicU64,
    /// Precompute-table bytes actually gathered (cross-check against
    /// `l1_reads_precomp * 4`).
    pub table_bytes_read: AtomicU64,
}

impl Recorder {
    pub fn new() -> Recorder {
        Recorder::default()
    }

    pub fn record_decode(&self, cfg: &ModelConfig, path: StepPath, batch: u64) {
        self.decode_tokens.fetch_add(batch, Ordering::Relaxed);
        match path {
            StepPath::Baseline => {
                self.decode_steps_baseline.fetch_add(1, Ordering::Relaxed);
                self.l1_reads_baseline
                    .fetch_add(costmodel::reads_without(cfg, batch), Ordering::Relaxed);
            }
            StepPath::Precompute | StepPath::PrecomputeGather => {
                self.decode_steps_precomp.fetch_add(1, Ordering::Relaxed);
                let reads = costmodel::reads_with(cfg, batch);
                self.l1_reads_precomp.fetch_add(reads, Ordering::Relaxed);
                self.table_bytes_read
                    .fetch_add(reads * 4, Ordering::Relaxed);
            }
        }
    }

    pub fn record_prefill(&self, cfg: &ModelConfig, path: StepPath, tokens: u64) {
        self.prefill_tokens.fetch_add(tokens, Ordering::Relaxed);
        self.prefill_calls.fetch_add(1, Ordering::Relaxed);
        // Prefill reads weights once per batch too; same formulas with
        // B = total prompt tokens in the batch.
        match path {
            StepPath::Baseline => {
                self.l1_reads_baseline
                    .fetch_add(costmodel::reads_without(cfg, tokens), Ordering::Relaxed);
            }
            _ => {
                let reads = costmodel::reads_with(cfg, tokens);
                self.l1_reads_precomp.fetch_add(reads, Ordering::Relaxed);
                self.table_bytes_read
                    .fetch_add(reads * 4, Ordering::Relaxed);
            }
        }
    }

    pub fn snapshot(&self) -> Snapshot {
        Snapshot {
            decode_steps_baseline: self.decode_steps_baseline.load(Ordering::Relaxed),
            decode_steps_precomp: self.decode_steps_precomp.load(Ordering::Relaxed),
            l1_reads_baseline: self.l1_reads_baseline.load(Ordering::Relaxed),
            l1_reads_precomp: self.l1_reads_precomp.load(Ordering::Relaxed),
            decode_tokens: self.decode_tokens.load(Ordering::Relaxed),
            prefill_tokens: self.prefill_tokens.load(Ordering::Relaxed),
            prefill_calls: self.prefill_calls.load(Ordering::Relaxed),
            table_bytes_read: self.table_bytes_read.load(Ordering::Relaxed),
        }
    }

    pub fn reset(&self) {
        self.decode_steps_baseline.store(0, Ordering::Relaxed);
        self.decode_steps_precomp.store(0, Ordering::Relaxed);
        self.l1_reads_baseline.store(0, Ordering::Relaxed);
        self.l1_reads_precomp.store(0, Ordering::Relaxed);
        self.decode_tokens.store(0, Ordering::Relaxed);
        self.prefill_tokens.store(0, Ordering::Relaxed);
        self.prefill_calls.store(0, Ordering::Relaxed);
        self.table_bytes_read.store(0, Ordering::Relaxed);
    }
}

/// Point-in-time copy of the counters.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct Snapshot {
    pub decode_steps_baseline: u64,
    pub decode_steps_precomp: u64,
    pub l1_reads_baseline: u64,
    pub l1_reads_precomp: u64,
    pub decode_tokens: u64,
    pub prefill_tokens: u64,
    pub prefill_calls: u64,
    pub table_bytes_read: u64,
}

impl Snapshot {
    /// Measured first-layer read-reduction factor (needs both paths run on
    /// the same workload; `examples/batch_sweep` does exactly that).
    pub fn measured_reduction(&self) -> Option<f64> {
        if self.l1_reads_precomp == 0 || self.l1_reads_baseline == 0 {
            return None;
        }
        Some(self.l1_reads_baseline as f64 / self.l1_reads_precomp as f64)
    }
}

/// Synthetic mixed workload (S12b): a pool of short interactive chats plus
/// occasional long documents — the traffic shape that motivates chunked
/// prefill (`rust/benches/scheduler.rs` and the prefill/decode-mixing
/// tests drive the scheduler with it).  Short requests arrive as
/// `Interactive`, long ones as `Batch`; the order is a deterministic
/// seed-keyed shuffle so arrivals interleave.
///
/// Generators emit the serving stack's typed
/// [`Request`](crate::coordinator::Request) — the same shape the server
/// and examples submit — so a workload can be replayed against a bare
/// `Scheduler` (fields) or a full `Coordinator` (`submit`) unchanged.
pub fn mixed_workload(
    n_short: usize,
    short_prompt: usize,
    n_long: usize,
    long_prompt: usize,
    max_new: usize,
    vocab: u32,
    seed: u64,
) -> Vec<crate::coordinator::Request> {
    use crate::coordinator::Request;
    use crate::scheduler::Priority;
    use crate::util::rng::Rng;
    let mut rng = Rng::new(seed);
    let mut out = Vec::with_capacity(n_short + n_long);
    let prompt = |len: usize, rng: &mut Rng| -> Vec<u32> {
        (0..len.max(1))
            .map(|_| rng.below(vocab.max(1) as u64) as u32)
            .collect()
    };
    for _ in 0..n_short {
        let plen = rng.range(1, short_prompt.max(2));
        out.push(
            Request::from_tokens(prompt(plen, &mut rng), max_new)
                .with_priority(Priority::Interactive),
        );
    }
    for _ in 0..n_long {
        let lo = long_prompt / 2 + 1;
        let plen = rng.range(lo, (long_prompt + 1).max(lo + 1));
        out.push(
            Request::from_tokens(prompt(plen, &mut rng), max_new)
                .with_priority(Priority::Batch),
        );
    }
    // Fisher-Yates with the same deterministic stream.
    for i in (1..out.len()).rev() {
        let j = rng.range(0, i + 1);
        out.swap(i, j);
    }
    out
}

/// Multi-tenant shared-system-prompt workload (S12c): `n_tenants`
/// tenants each own a fixed random system prompt of `system_tokens`
/// tokens; every request is that shared prefix plus a fresh user suffix
/// of 1..=`user_tokens` tokens.  This is the traffic shape the
/// cross-request prefix cache (`rust/src/prefixcache/`) targets: within
/// a tenant every request after the first should prefill only its
/// suffix.  Arrivals are a deterministic seed-keyed shuffle so tenants
/// interleave (the cache must match across unrelated traffic, not in a
/// convenient back-to-back order).
#[allow(clippy::too_many_arguments)]
pub fn tenant_workload(
    n_tenants: usize,
    requests_per_tenant: usize,
    system_tokens: usize,
    user_tokens: usize,
    max_new: usize,
    vocab: u32,
    seed: u64,
) -> Vec<crate::coordinator::Request> {
    use crate::coordinator::Request;
    use crate::util::rng::Rng;
    let mut rng = Rng::new(seed);
    let tok = |rng: &mut Rng| rng.below(vocab.max(1) as u64) as u32;
    let systems: Vec<Vec<u32>> = (0..n_tenants)
        .map(|_| (0..system_tokens.max(1)).map(|_| tok(&mut rng)).collect())
        .collect();
    let mut out = Vec::with_capacity(n_tenants * requests_per_tenant);
    for sys in &systems {
        for _ in 0..requests_per_tenant {
            let mut prompt = sys.clone();
            for _ in 0..rng.range(1, user_tokens.max(1) + 1) {
                prompt.push(tok(&mut rng));
            }
            out.push(Request::from_tokens(prompt, max_new));
        }
    }
    // Fisher-Yates with the same deterministic stream.
    for i in (1..out.len()).rev() {
        let j = rng.range(0, i + 1);
        out.swap(i, j);
    }
    out
}

/// Speculative fan-out workload (S12d): `n_groups` base prompts, each
/// fanned out as `fanout` tagged variants — the shared prompt plus one
/// variant-specific seed token, tagged `s{group}.{variant}` so a driver
/// can demultiplex and **cancel the losers when the first variant
/// finishes** (first-done-wins, the v2 `cancel` shape from the ROADMAP).
/// Every variant is span-heavy by construction: the shared prompt hits
/// the prefix cache after the first variant prefills, so siblings admit
/// mid-prompt and execute as span-artifact suffix fills.  Arrivals are a
/// deterministic seed-keyed shuffle so groups interleave.
///
/// Naming note: this is CLIENT-side speculation — N complete requests
/// racing, the server unaware.  SERVER-side speculative decoding (one
/// request, drafted tokens verified in one scored span execution) lives
/// in [`crate::specdec`] and is exercised by [`spec_workload`] /
/// `scripts/spec_gate.sh` instead.
pub fn speculative_workload(
    n_groups: usize,
    fanout: usize,
    prompt_tokens: usize,
    max_new: usize,
    vocab: u32,
    seed: u64,
) -> Vec<crate::coordinator::Request> {
    use crate::coordinator::Request;
    use crate::scheduler::Priority;
    use crate::util::rng::Rng;
    let mut rng = Rng::new(seed);
    let tok = |rng: &mut Rng| rng.below(vocab.max(1) as u64) as u32;
    let mut out = Vec::with_capacity(n_groups * fanout);
    for g in 0..n_groups {
        let base: Vec<u32> = (0..prompt_tokens.max(1)).map(|_| tok(&mut rng)).collect();
        for v in 0..fanout.max(1) {
            let mut p = base.clone();
            p.push(tok(&mut rng)); // variant divergence point
            out.push(
                Request::from_tokens(p, max_new)
                    .with_priority(Priority::Interactive)
                    .with_tag(format!("s{g}.{v}")),
            );
        }
    }
    // Fisher-Yates with the same deterministic stream.
    for i in (1..out.len()).rev() {
        let j = rng.range(0, i + 1);
        out.swap(i, j);
    }
    out
}

/// Server-side speculative-decoding workload (S12f): `n` tagged greedy
/// requests (`p{i}`) whose prompts are a short random phrase repeated
/// until `prompt_tokens` — the repetitive, template-heavy shape
/// (boilerplate headers, format scaffolding, multi-turn echoes) where
/// the [`crate::specdec::NGramDrafter`]'s prompt lookup lands.  Greedy
/// sampling is load-bearing twice over: it is the spec-decode
/// eligibility gate (acceptance compares drafts against the argmax) and
/// it drives a tiny model into periodic token cycles, which the n-gram
/// drafter then predicts from the request's own transcript — so
/// `scripts/spec_gate.sh` can assert a real accepted-tokens-per-
/// execution floor, not just "it ran".  Arrivals are the usual
/// deterministic seed-keyed shuffle.
pub fn spec_workload(
    n: usize,
    phrase_tokens: usize,
    prompt_tokens: usize,
    max_new: usize,
    vocab: u32,
    seed: u64,
) -> Vec<crate::coordinator::Request> {
    use crate::coordinator::Request;
    use crate::util::rng::Rng;
    let mut rng = Rng::new(seed);
    let tok = |rng: &mut Rng| rng.below(vocab.max(1) as u64) as u32;
    let mut out = Vec::with_capacity(n);
    for i in 0..n {
        let phrase: Vec<u32> = (0..phrase_tokens.max(1)).map(|_| tok(&mut rng)).collect();
        let prompt: Vec<u32> = phrase
            .iter()
            .cycle()
            .take(prompt_tokens.max(1))
            .copied()
            .collect();
        out.push(Request::from_tokens(prompt, max_new).with_tag(format!("p{i}")));
    }
    // Fisher-Yates with the same deterministic stream.
    for i in (1..out.len()).rev() {
        let j = rng.range(0, i + 1);
        out.swap(i, j);
    }
    out
}

/// Fault-burst adversary workload (S12e): `n` tagged greedy requests
/// (`f{i}`, default sampling params: temperature 0 → argmax) built for
/// the chaos gate —
/// run once fault-free as the oracle, then again with `--fault-spec`
/// armed, and compare per-tag outputs.  Greedy decoding makes the
/// comparison exact: a request that only *retried* transient faults
/// must produce the oracle's token stream verbatim, and a request that
/// failed terminally must end in `reason:"error"` while its neighbors
/// stay byte-identical.  Prompt lengths vary 1..=`prompt_tokens` and a
/// third of the requests arrive `Interactive` so chunked prefill,
/// decode batching, and priority admission all participate; arrivals
/// are the usual deterministic seed-keyed shuffle.
pub fn fault_burst_workload(
    n: usize,
    prompt_tokens: usize,
    max_new: usize,
    vocab: u32,
    seed: u64,
) -> Vec<crate::coordinator::Request> {
    use crate::coordinator::Request;
    use crate::scheduler::Priority;
    use crate::util::rng::Rng;
    let mut rng = Rng::new(seed);
    let tok = |rng: &mut Rng| rng.below(vocab.max(1) as u64) as u32;
    let mut out = Vec::with_capacity(n);
    for i in 0..n {
        let plen = rng.range(1, prompt_tokens.max(2));
        let prompt: Vec<u32> = (0..plen).map(|_| tok(&mut rng)).collect();
        let prio = if i % 3 == 0 {
            Priority::Interactive
        } else {
            Priority::Normal
        };
        out.push(
            Request::from_tokens(prompt, max_new)
                .with_priority(prio)
                .with_tag(format!("f{i}")),
        );
    }
    // Fisher-Yates with the same deterministic stream.
    for i in (1..out.len()).rev() {
        let j = rng.range(0, i + 1);
        out.swap(i, j);
    }
    out
}

/// Noisy-neighbor adversary workload (S12g): one **hog** tenant (tenant
/// id 1) floods the queue with `n_hog` long `Batch` requests, while
/// `n_small` bystander tenants (ids 2..) each submit
/// `small_per_tenant` short `Interactive` requests.  This is the
/// traffic shape the fair-share scheduler (DRR over the step-token
/// budget) and the overload ladder's class-aware shedding exist for:
/// without them the hog's queue depth buys it the whole device and the
/// bystanders starve.  `firstlayer overload-smoke` drives this shape
/// and asserts per-tenant goodput floors; tags are `h{i}` for the hog
/// and `t{tenant}.{i}` for bystanders so a driver can attribute every
/// stream.  Arrivals are the usual deterministic seed-keyed shuffle —
/// the hog must not win merely by arriving first.
#[allow(clippy::too_many_arguments)]
pub fn hog_workload(
    n_hog: usize,
    n_small: usize,
    small_per_tenant: usize,
    hog_prompt: usize,
    small_prompt: usize,
    max_new: usize,
    vocab: u32,
    seed: u64,
) -> Vec<crate::coordinator::Request> {
    use crate::coordinator::Request;
    use crate::scheduler::Priority;
    use crate::util::rng::Rng;
    let mut rng = Rng::new(seed);
    let tok = |rng: &mut Rng| rng.below(vocab.max(1) as u64) as u32;
    let mut out = Vec::with_capacity(n_hog + n_small * small_per_tenant);
    for i in 0..n_hog {
        let prompt: Vec<u32> = (0..hog_prompt.max(1)).map(|_| tok(&mut rng)).collect();
        out.push(
            Request::from_tokens(prompt, max_new)
                .with_priority(Priority::Batch)
                .with_tenant(1)
                .with_tag(format!("h{i}")),
        );
    }
    for t in 0..n_small {
        let tenant = 2 + t as u64;
        for i in 0..small_per_tenant {
            let plen = rng.range(1, small_prompt.max(2));
            let prompt: Vec<u32> = (0..plen).map(|_| tok(&mut rng)).collect();
            out.push(
                Request::from_tokens(prompt, max_new)
                    .with_priority(Priority::Interactive)
                    .with_tenant(tenant)
                    .with_tag(format!("t{tenant}.{i}")),
            );
        }
    }
    // Fisher-Yates with the same deterministic stream.
    for i in (1..out.len()).rev() {
        let j = rng.range(0, i + 1);
        out.swap(i, j);
    }
    out
}

/// Overload-wave adversary workload (S12h): `waves` bursts of `peak`
/// `Interactive` requests each, separated by calm segments of `base`
/// `Normal` requests — the 2× arrival storm the overload ladder's trip
/// thresholds are tuned against.  Unlike every other generator this one
/// is deliberately NOT shuffled: the burst/calm clumping IS the
/// adversarial shape (a shuffle would smear the waves into a steady
/// trickle the ladder never sees).  The driver replays segments in
/// order, pausing admission between them to let the ladder's clear
/// window run.  Tags are `w{wave}.{i}` inside bursts and `c{seg}.{i}`
/// in calm segments.
#[allow(clippy::too_many_arguments)]
pub fn overload_wave_workload(
    waves: usize,
    peak: usize,
    base: usize,
    prompt_tokens: usize,
    max_new: usize,
    vocab: u32,
    seed: u64,
) -> Vec<crate::coordinator::Request> {
    use crate::coordinator::Request;
    use crate::scheduler::Priority;
    use crate::util::rng::Rng;
    let mut rng = Rng::new(seed);
    let tok = |rng: &mut Rng| rng.below(vocab.max(1) as u64) as u32;
    let mut out = Vec::with_capacity(waves * (peak + base));
    for w in 0..waves {
        for i in 0..peak {
            let plen = rng.range(1, prompt_tokens.max(2));
            let prompt: Vec<u32> = (0..plen).map(|_| tok(&mut rng)).collect();
            out.push(
                Request::from_tokens(prompt, max_new)
                    .with_priority(Priority::Interactive)
                    .with_tag(format!("w{w}.{i}")),
            );
        }
        for i in 0..base {
            let plen = rng.range(1, prompt_tokens.max(2));
            let prompt: Vec<u32> = (0..plen).map(|_| tok(&mut rng)).collect();
            out.push(
                Request::from_tokens(prompt, max_new)
                    .with_priority(Priority::Normal)
                    .with_tag(format!("c{w}.{i}")),
            );
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::zoo_get;

    #[test]
    fn decode_accounting_matches_costmodel() {
        let cfg = zoo_get("mistral-7b").unwrap();
        let r = Recorder::new();
        r.record_decode(&cfg, StepPath::Baseline, 1);
        r.record_decode(&cfg, StepPath::Precompute, 1);
        let s = r.snapshot();
        assert_eq!(s.l1_reads_baseline, 25_169_920); // paper value
        assert_eq!(s.l1_reads_precomp, 10_240); // paper value
        assert_eq!(s.table_bytes_read, 10_240 * 4);
        let f = s.measured_reduction().unwrap();
        assert_eq!(f.round() as u64, 2_458); // paper's 2,458x
    }

    #[test]
    fn steps_accumulate() {
        let cfg = zoo_get("tiny-serial").unwrap();
        let r = Recorder::new();
        for _ in 0..5 {
            r.record_decode(&cfg, StepPath::Precompute, 4);
        }
        let s = r.snapshot();
        assert_eq!(s.decode_steps_precomp, 5);
        assert_eq!(s.decode_tokens, 20);
        assert_eq!(s.l1_reads_precomp, 5 * 4 * cfg.precomp_row_width() as u64);
    }

    #[test]
    fn reset_clears() {
        let cfg = zoo_get("tiny-serial").unwrap();
        let r = Recorder::new();
        r.record_prefill(&cfg, StepPath::Baseline, 32);
        assert_eq!(r.snapshot().prefill_calls, 1);
        r.reset();
        assert_eq!(r.snapshot(), Snapshot::default());
    }

    #[test]
    fn mixed_workload_shape() {
        use crate::scheduler::Priority;
        let w = mixed_workload(10, 8, 3, 64, 16, 512, 42);
        assert_eq!(w.len(), 13);
        let longs: Vec<&crate::coordinator::Request> = w
            .iter()
            .filter(|r| r.priority == Priority::Batch)
            .collect();
        assert_eq!(longs.len(), 3);
        for r in &longs {
            assert!(r.prompt.len() > 32 && r.prompt.len() <= 64);
        }
        for r in &w {
            assert!(r.prompt.iter().all(|&t| t < 512));
            assert_eq!(r.max_new_tokens, 16);
        }
        // Deterministic per seed.
        let w2 = mixed_workload(10, 8, 3, 64, 16, 512, 42);
        assert_eq!(w.len(), w2.len());
        assert!(w.iter().zip(&w2).all(|(a, b)| a.prompt == b.prompt));
    }

    #[test]
    fn speculative_workload_fans_out_tagged_variants() {
        use crate::scheduler::Priority;
        let w = speculative_workload(3, 4, 20, 16, 512, 11);
        assert_eq!(w.len(), 12);
        for g in 0..3 {
            let variants: Vec<_> = w
                .iter()
                .filter(|r| {
                    r.tag
                        .as_deref()
                        .is_some_and(|t| t.starts_with(&format!("s{g}.")))
                })
                .collect();
            assert_eq!(variants.len(), 4, "group {g} fanout");
            // All variants of a group share the 20-token base prompt and
            // differ only in the divergence token.
            let base = variants[0].prompt[..20].to_vec();
            for r in &variants {
                assert_eq!(r.prompt.len(), 21);
                assert_eq!(r.prompt[..20], base[..]);
                assert_eq!(r.priority, Priority::Interactive);
            }
            let tags: std::collections::HashSet<_> =
                variants.iter().map(|r| r.tag.clone().unwrap()).collect();
            assert_eq!(tags.len(), 4, "group {g} tags must be distinct");
        }
        // Deterministic per seed.
        let w2 = speculative_workload(3, 4, 20, 16, 512, 11);
        assert!(w.iter().zip(&w2).all(|(a, b)| a.prompt == b.prompt
            && a.tag == b.tag));
    }

    #[test]
    fn spec_workload_is_repetitive_greedy_and_deterministic() {
        let w = spec_workload(6, 4, 20, 32, 512, 0x5bec);
        assert_eq!(w.len(), 6);
        let tags: std::collections::HashSet<_> =
            w.iter().map(|r| r.tag.clone().unwrap()).collect();
        assert_eq!(tags.len(), 6);
        for r in &w {
            // Spec-decode eligibility: greedy, no stop sequences.
            assert_eq!(r.params.temperature, 0.0);
            assert!(r.params.stop.is_empty());
            assert_eq!(r.prompt.len(), 20);
            // The prompt is its own 4-periodic repetition — the shape
            // the n-gram drafter's prompt lookup exists for.
            for (i, &t) in r.prompt.iter().enumerate() {
                assert_eq!(t, r.prompt[i % 4], "prompt must cycle its phrase");
            }
            assert!(r.prompt.iter().all(|&t| t < 512));
        }
        // Deterministic per seed.
        let w2 = spec_workload(6, 4, 20, 32, 512, 0x5bec);
        assert!(w
            .iter()
            .zip(&w2)
            .all(|(a, b)| a.prompt == b.prompt && a.tag == b.tag));
    }

    #[test]
    fn fault_burst_workload_is_deterministic_and_greedy() {
        use crate::scheduler::Priority;
        let w = fault_burst_workload(9, 16, 8, 512, 77);
        assert_eq!(w.len(), 9);
        // Tags f0..f8, each exactly once (the oracle comparison keys
        // streams by tag, so duplicates would be un-matchable).
        let tags: std::collections::HashSet<_> =
            w.iter().map(|r| r.tag.clone().unwrap()).collect();
        assert_eq!(tags.len(), 9);
        for i in 0..9 {
            assert!(tags.contains(&format!("f{i}")));
        }
        let interactive = w
            .iter()
            .filter(|r| r.priority == Priority::Interactive)
            .count();
        assert_eq!(interactive, 3, "every third request is interactive");
        for r in &w {
            // Greedy: temperature 0 argmaxes, which is what makes the
            // chaos-gate oracle comparison exact.
            assert_eq!(r.params.temperature, 0.0);
            assert!(r.params.stop.is_empty());
            assert!(!r.prompt.is_empty() && r.prompt.len() <= 16);
            assert!(r.prompt.iter().all(|&t| t < 512));
        }
        // Deterministic per seed; a different seed reshuffles.
        let w2 = fault_burst_workload(9, 16, 8, 512, 77);
        assert!(w
            .iter()
            .zip(&w2)
            .all(|(a, b)| a.prompt == b.prompt && a.tag == b.tag));
    }

    #[test]
    fn hog_workload_pins_tenants_classes_and_tags() {
        use crate::scheduler::Priority;
        let w = hog_workload(8, 2, 3, 32, 6, 16, 512, 0x406);
        assert_eq!(w.len(), 8 + 2 * 3);
        let hogs: Vec<_> = w.iter().filter(|r| r.tenant == 1).collect();
        assert_eq!(hogs.len(), 8);
        for r in &hogs {
            assert_eq!(r.priority, Priority::Batch);
            assert_eq!(r.prompt.len(), 32);
            assert!(r.tag.as_deref().unwrap().starts_with('h'));
        }
        for tenant in [2u64, 3] {
            let small: Vec<_> = w.iter().filter(|r| r.tenant == tenant).collect();
            assert_eq!(small.len(), 3, "tenant {tenant} request count");
            for r in &small {
                assert_eq!(r.priority, Priority::Interactive);
                assert!(!r.prompt.is_empty() && r.prompt.len() < 6);
                assert!(r
                    .tag
                    .as_deref()
                    .unwrap()
                    .starts_with(&format!("t{tenant}.")));
            }
        }
        // Tags are distinct (drivers key per-stream state by tag).
        let tags: std::collections::HashSet<_> =
            w.iter().map(|r| r.tag.clone().unwrap()).collect();
        assert_eq!(tags.len(), w.len());
        // Deterministic per seed.
        let w2 = hog_workload(8, 2, 3, 32, 6, 16, 512, 0x406);
        assert!(w
            .iter()
            .zip(&w2)
            .all(|(a, b)| a.prompt == b.prompt && a.tag == b.tag && a.tenant == b.tenant));
    }

    #[test]
    fn overload_wave_workload_keeps_burst_ordering() {
        use crate::scheduler::Priority;
        let w = overload_wave_workload(2, 5, 3, 8, 4, 512, 0x0A5);
        assert_eq!(w.len(), 2 * (5 + 3));
        // NOT shuffled: each wave is a dense run of interactive
        // requests followed by its calm segment — the clumping is the
        // point.
        for (wave, chunk) in w.chunks(8).enumerate() {
            for (i, r) in chunk[..5].iter().enumerate() {
                assert_eq!(r.priority, Priority::Interactive);
                assert_eq!(r.tag.as_deref(), Some(format!("w{wave}.{i}").as_str()));
            }
            for (i, r) in chunk[5..].iter().enumerate() {
                assert_eq!(r.priority, Priority::Normal);
                assert_eq!(r.tag.as_deref(), Some(format!("c{wave}.{i}").as_str()));
            }
        }
        for r in &w {
            assert!(!r.prompt.is_empty() && r.prompt.len() < 8);
            assert!(r.prompt.iter().all(|&t| t < 512));
            assert_eq!(r.max_new_tokens, 4);
        }
        // Deterministic per seed.
        let w2 = overload_wave_workload(2, 5, 3, 8, 4, 512, 0x0A5);
        assert!(w.iter().zip(&w2).all(|(a, b)| a.prompt == b.prompt));
    }

    #[test]
    fn tenant_workload_shares_system_prompts() {
        let w = tenant_workload(3, 4, 32, 8, 16, 512, 9);
        assert_eq!(w.len(), 12);
        // Recover the tenant system prompts from the 32-token prefixes:
        // exactly 3 distinct ones, each shared by exactly 4 requests.
        let mut prefixes: Vec<Vec<u32>> =
            w.iter().map(|r| r.prompt[..32].to_vec()).collect();
        prefixes.sort();
        prefixes.dedup();
        assert_eq!(prefixes.len(), 3, "expected one prefix per tenant");
        for p in &prefixes {
            let n = w.iter().filter(|r| r.prompt[..32] == p[..]).count();
            assert_eq!(n, 4, "tenant prefix not shared by all its requests");
        }
        for r in &w {
            let suffix = r.prompt.len() - 32;
            assert!((1..=8).contains(&suffix));
            assert!(r.prompt.iter().all(|&t| t < 512));
        }
        // Deterministic per seed.
        let w2 = tenant_workload(3, 4, 32, 8, 16, 512, 9);
        assert!(w.iter().zip(&w2).all(|(a, b)| a.prompt == b.prompt));
    }
}
