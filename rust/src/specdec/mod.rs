//! Server-side speculative decoding: drafting (this module) + the
//! verify-accept-rollback loop in the coordinator.
//!
//! The span artifacts built for chunked prefill (PR 5/6) are already a
//! draft-verification kernel: `decode_span` scores T proposed tokens
//! against the cache in ONE device execution, and its `[T, V]` logits
//! output ranks every drafted position.  What was missing is a source
//! of drafts.  This module supplies it: a pluggable [`Drafter`] trait
//! and the v1 [`NGramDrafter`], which drafts from the request's OWN
//! token history (prompt + generated) by prompt lookup — find the
//! longest recent n-gram suffix that occurred earlier in the history
//! and propose the tokens that followed it.  Repetitive traffic
//! (multi-turn chat, shared templates, the token cycles tiny greedy
//! models fall into) makes such drafts land often enough that accepted
//! tokens cost one execution instead of one each.
//!
//! # Contract with the coordinator
//!
//! The drafter only *proposes*; the verify loop in
//! `rust/src/coordinator/` owns correctness:
//!
//! * the span executes `[last_generated, d_1..d_k]`, so position `i` of
//!   the scored logits predicts the token after `d_i`;
//! * the accepted prefix is computed by [`accepted_prefix`] against the
//!   temp-0 argmax at each position — greedy-only, byte-identical to
//!   plain decode by construction;
//! * rejected suffix rows never reach the paged host store, and one
//!   bonus token is emitted from the first divergent position so a
//!   fully-rejected draft still nets one token.
//!
//! Sustained low acceptance is a health signal, not just waste: the
//! coordinator feeds per-verify emitted-token counts into an
//! [`AcceptanceWindow`] and demotes `PathId::SpecDec` (cooldown ladder,
//! PR 8) when a full window averages below [`DEMOTE_MEAN_X100`]/100
//! tokens per execution.

/// Verify executions per low-acceptance evaluation window.
pub const DEMOTE_WINDOW: u64 = 32;

/// Demotion floor for the windowed mean of emitted tokens per verify
/// execution, times 100.  A verify always nets >= 1 token (the bonus),
/// so a mean at 1.00 means drafts never land; 1.05 gives the drafter a
/// little slack before the path is demoted to plain decode.
pub const DEMOTE_MEAN_X100: u64 = 105;

/// A draft source: proposes likely next tokens for one request given
/// its full token history (prompt + generated so far, newest last).
pub trait Drafter {
    /// Propose up to `max` tokens expected to follow `history`.  An
    /// empty draft means "no idea" — the request stays on plain decode
    /// this step (a capability gap, never a health event).
    fn draft(&self, history: &[u32], max: usize) -> Vec<u32>;

    /// Short name for logs and traces.
    fn label(&self) -> &'static str;
}

/// v1 drafter: n-gram prompt lookup over the request's own transcript.
///
/// Tries suffix n-grams from `max_n` down to 1 and scans the history
/// right-to-left for the most recent earlier occurrence; the tokens
/// that followed that occurrence become the draft.  Deterministic and
/// allocation-light — the draft is copied straight out of the history.
#[derive(Debug, Clone)]
pub struct NGramDrafter {
    /// Longest suffix n-gram to look up (longer matches are tried
    /// first; a longer match is stronger evidence of repetition).
    pub max_n: usize,
}

impl Default for NGramDrafter {
    fn default() -> Self {
        NGramDrafter { max_n: 3 }
    }
}

impl NGramDrafter {
    pub fn new(max_n: usize) -> NGramDrafter {
        NGramDrafter { max_n: max_n.max(1) }
    }
}

impl Drafter for NGramDrafter {
    fn draft(&self, history: &[u32], max: usize) -> Vec<u32> {
        let len = history.len();
        if max == 0 || len < 2 {
            return Vec::new();
        }
        for n in (1..=self.max_n.min(len.saturating_sub(1))).rev() {
            let suffix = &history[len - n..];
            // Most recent earlier occurrence wins: recency tracks the
            // current phase of a repeating transcript best.
            for j in (0..len - n).rev() {
                if &history[j..j + n] == suffix {
                    let start = j + n;
                    let take = max.min(len - start);
                    if take > 0 {
                        return history[start..start + take].to_vec();
                    }
                }
            }
        }
        Vec::new()
    }

    fn label(&self) -> &'static str {
        "ngram"
    }
}

/// Longest prefix of `draft` confirmed by the verify pass: `sampled[i]`
/// is the temp-0 argmax at drafted position `i`.
pub fn accepted_prefix(draft: &[u32], sampled: &[u32]) -> usize {
    draft
        .iter()
        .zip(sampled.iter())
        .take_while(|(d, s)| d == s)
        .count()
}

/// Per-request drafting statistics (the match bookkeeping the drafter
/// trait itself stays free of).
#[derive(Debug, Clone, Copy, Default)]
pub struct SpecStats {
    /// Draft attempts, and attempts that produced no draft.
    pub proposals: u64,
    pub misses: u64,
    /// Tokens drafted / drafted tokens the verify accepted.
    pub drafted: u64,
    pub accepted: u64,
    /// Verifies that rejected at least one drafted token.
    pub rollbacks: u64,
}

impl SpecStats {
    /// Record one draft attempt of `k` tokens (0 = miss).
    pub fn on_draft(&mut self, k: usize) {
        self.proposals += 1;
        if k == 0 {
            self.misses += 1;
        } else {
            self.drafted += k as u64;
        }
    }

    /// Record one verify outcome: `accepted` of `drafted` tokens stood.
    pub fn on_verify(&mut self, drafted: usize, accepted: usize) {
        self.accepted += accepted as u64;
        if accepted < drafted {
            self.rollbacks += 1;
        }
    }

    /// Fraction of drafted tokens the verify accepted (0 when nothing
    /// was drafted yet).
    pub fn accept_rate(&self) -> f64 {
        if self.drafted == 0 {
            return 0.0;
        }
        self.accepted as f64 / self.drafted as f64
    }
}

/// Sliding demotion window over verify outcomes: every
/// [`DEMOTE_WINDOW`] executions, checks whether the mean emitted
/// tokens per execution stayed above the floor; if not, the caller
/// should demote `PathId::SpecDec`.
#[derive(Debug, Default)]
pub struct AcceptanceWindow {
    execs: u64,
    tokens: u64,
}

impl AcceptanceWindow {
    pub fn new() -> AcceptanceWindow {
        AcceptanceWindow::default()
    }

    /// Record one verify that emitted `emitted` tokens.  Returns `true`
    /// when a full window just closed below the floor (demote now);
    /// the window resets either way once full.
    pub fn record(&mut self, emitted: u64) -> bool {
        self.execs += 1;
        self.tokens += emitted;
        if self.execs < DEMOTE_WINDOW {
            return false;
        }
        let low = self.tokens * 100 < DEMOTE_MEAN_X100 * self.execs;
        self.execs = 0;
        self.tokens = 0;
        low
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ngram_drafts_repeating_cycle() {
        // ... a b c a b c a b -> suffix [a b] recurs; draft continues
        // the cycle: c a b c ...
        let h = [5u32, 1, 2, 3, 1, 2, 3, 1, 2];
        let d = NGramDrafter::new(3);
        assert_eq!(d.draft(&h, 4), vec![3, 1, 2, 3]);
        // A shorter cap clips the draft, never pads it.
        assert_eq!(d.draft(&h, 2), vec![3, 1]);
    }

    #[test]
    fn ngram_prefers_longest_suffix_match() {
        // Suffix [7 8] matched at one place, lone [8] at another; the
        // bigram match must win over the more recent unigram one.
        let h = [7u32, 8, 9, 4, 8, 5, 7, 8];
        let d = NGramDrafter::new(3);
        assert_eq!(d.draft(&h, 1), vec![9]);
    }

    #[test]
    fn ngram_prefers_most_recent_occurrence() {
        // [1 2] occurs twice with different continuations; the later
        // occurrence's continuation (9) must be drafted, not 3.
        let h = [1u32, 2, 3, 1, 2, 9, 1, 2];
        let d = NGramDrafter::new(2);
        assert_eq!(d.draft(&h, 1), vec![9]);
    }

    #[test]
    fn ngram_no_match_is_empty() {
        let d = NGramDrafter::new(3);
        assert!(d.draft(&[1, 2, 3, 4, 5], 4).is_empty());
        assert!(d.draft(&[], 4).is_empty());
        assert!(d.draft(&[1], 4).is_empty());
        assert!(d.draft(&[1, 1, 2], 0).is_empty());
    }

    #[test]
    fn ngram_deterministic() {
        let h: Vec<u32> = (0..40).map(|i| i % 7).collect();
        let d = NGramDrafter::default();
        assert_eq!(d.draft(&h, 8), d.draft(&h, 8));
    }

    #[test]
    fn accepted_prefix_cases() {
        assert_eq!(accepted_prefix(&[1, 2, 3], &[1, 2, 3]), 3);
        assert_eq!(accepted_prefix(&[1, 2, 3], &[1, 9, 3]), 1);
        assert_eq!(accepted_prefix(&[1, 2, 3], &[9, 2, 3]), 0);
        assert_eq!(accepted_prefix(&[], &[1]), 0);
        // Sampled may be longer (it includes the bonus position).
        assert_eq!(accepted_prefix(&[1, 2], &[1, 2, 7]), 2);
    }

    #[test]
    fn stats_track_rates() {
        let mut s = SpecStats::default();
        s.on_draft(4);
        s.on_verify(4, 3);
        s.on_draft(0);
        s.on_draft(2);
        s.on_verify(2, 2);
        assert_eq!(s.proposals, 3);
        assert_eq!(s.misses, 1);
        assert_eq!(s.drafted, 6);
        assert_eq!(s.accepted, 5);
        assert_eq!(s.rollbacks, 1);
        assert!((s.accept_rate() - 5.0 / 6.0).abs() < 1e-12);
    }

    #[test]
    fn acceptance_window_demotes_on_bonus_only_traffic() {
        // Every verify netting exactly the bonus token (mean 1.0) must
        // trip the floor when the window closes, and only then.
        let mut w = AcceptanceWindow::new();
        for i in 1..DEMOTE_WINDOW {
            assert!(!w.record(1), "fired early at {i}");
        }
        assert!(w.record(1), "full window at mean 1.0 must demote");
        // Healthy acceptance never trips it.
        for _ in 0..DEMOTE_WINDOW * 3 {
            assert!(!w.record(2));
        }
    }
}
