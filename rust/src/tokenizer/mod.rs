//! Tokenizer substrate (S13): byte-level vocabulary + greedy BPE-style
//! merges, trained on a corpus at startup.
//!
//! The paper's system assumes "the token-ID provides the read-address";
//! serving real text therefore needs real token ids.  Production systems
//! ship a trained BPE; offline we train a small one: start from the 256
//! byte tokens, repeatedly merge the most frequent adjacent pair until the
//! target vocab size is reached.  Encoding replays the merges in training
//! order (canonical BPE), so `decode(encode(x)) == x` for any bytes.
//!
//! Special tokens: `BOS` (0), `EOS` (1), then the 256 byte tokens, then
//! merges.  Vocab size must match the model config's (tiny models: 256/512).

use std::collections::HashMap;

use crate::error::{Error, Result};

pub const BOS: u32 = 0;
pub const EOS: u32 = 1;
const N_SPECIAL: u32 = 2;

/// A trained byte-pair tokenizer.
#[derive(Debug, Clone)]
pub struct Tokenizer {
    vocab_size: usize,
    /// Byte bucket count: 256 for trained BPE; smaller for the fallback.
    n_byte_buckets: usize,
    /// Merge rules in training order: (left, right) -> merged id.
    merges: Vec<(u32, u32)>,
    merge_map: HashMap<(u32, u32), u32>,
    /// Token id -> byte string.
    pieces: Vec<Vec<u8>>,
}

impl Tokenizer {
    /// Train on `corpus` to exactly `vocab_size` tokens (>= 258).
    pub fn train(corpus: &str, vocab_size: usize) -> Result<Tokenizer> {
        if vocab_size < (N_SPECIAL as usize) + 256 {
            return Err(Error::Tokenizer(format!(
                "vocab_size {vocab_size} < {}",
                N_SPECIAL as usize + 256
            )));
        }
        let mut pieces: Vec<Vec<u8>> = Vec::with_capacity(vocab_size);
        pieces.push(b"<bos>".to_vec());
        pieces.push(b"<eos>".to_vec());
        for b in 0..=255u8 {
            pieces.push(vec![b]);
        }
        // Working sequence of token ids over the corpus.
        let mut seq: Vec<u32> = corpus.bytes().map(|b| b as u32 + N_SPECIAL).collect();
        let mut merges = Vec::new();
        let mut merge_map = HashMap::new();
        while pieces.len() < vocab_size {
            // Count adjacent pairs.
            let mut counts: HashMap<(u32, u32), usize> = HashMap::new();
            for w in seq.windows(2) {
                *counts.entry((w[0], w[1])).or_insert(0) += 1;
            }
            let Some((&pair, &cnt)) = counts
                .iter()
                .max_by_key(|(p, c)| (**c, std::cmp::Reverse(**p)))
            else {
                break;
            };
            if cnt < 2 {
                break; // nothing merges twice: corpus exhausted
            }
            let id = pieces.len() as u32;
            let mut piece = pieces[pair.0 as usize].clone();
            piece.extend_from_slice(&pieces[pair.1 as usize]);
            pieces.push(piece);
            merges.push(pair);
            merge_map.insert(pair, id);
            // Apply the merge over the working sequence.
            let mut out = Vec::with_capacity(seq.len());
            let mut i = 0;
            while i < seq.len() {
                if i + 1 < seq.len() && (seq[i], seq[i + 1]) == pair {
                    out.push(id);
                    i += 2;
                } else {
                    out.push(seq[i]);
                    i += 1;
                }
            }
            seq = out;
        }
        // Pad the vocabulary with unused slots if the corpus ran dry: ids
        // stay valid (they decode to empty) so model vocab_size is honored.
        while pieces.len() < vocab_size {
            pieces.push(Vec::new());
        }
        Ok(Tokenizer {
            vocab_size,
            n_byte_buckets: 256,
            merges,
            merge_map,
            pieces,
        })
    }

    /// Degenerate byte-fallback tokenizer for demo models whose vocab is
    /// too small for the 256 byte pieces (e.g. tiny-moe, vocab 256): bytes
    /// hash into `vocab - 2` buckets.  Decode is lossy (demo-quality), but
    /// ids are valid and deterministic — enough to exercise the engine.
    pub fn byte_fallback(vocab_size: usize) -> Result<Tokenizer> {
        if vocab_size < 4 {
            return Err(Error::Tokenizer(format!("vocab {vocab_size} too small")));
        }
        let n = vocab_size - N_SPECIAL as usize;
        let mut pieces: Vec<Vec<u8>> = Vec::with_capacity(vocab_size);
        pieces.push(b"<bos>".to_vec());
        pieces.push(b"<eos>".to_vec());
        for i in 0..n {
            pieces.push(vec![if i < 256 { i as u8 } else { b'?' }]);
        }
        Ok(Tokenizer {
            vocab_size,
            n_byte_buckets: n,
            merges: Vec::new(),
            merge_map: HashMap::new(),
            pieces,
        })
    }

    /// Train if the vocab allows BPE, else fall back to the byte hasher.
    pub fn train_or_fallback(corpus: &str, vocab_size: usize) -> Result<Tokenizer> {
        if vocab_size >= N_SPECIAL as usize + 256 {
            Tokenizer::train(corpus, vocab_size)
        } else {
            Tokenizer::byte_fallback(vocab_size)
        }
    }

    pub fn vocab_size(&self) -> usize {
        self.vocab_size
    }

    pub fn n_merges(&self) -> usize {
        self.merges.len()
    }

    /// Encode text (no BOS/EOS added — the coordinator does that).
    ///
    /// Canonical BPE: repeatedly merge the present pair with the lowest
    /// training rank.  `O(len · log(len))`-ish via the merge map instead of
    /// replaying every merge rule over the text.
    pub fn encode(&self, text: &str) -> Vec<u32> {
        let mut seq: Vec<u32> = text
            .bytes()
            .map(|b| (b as usize % self.n_byte_buckets) as u32 + N_SPECIAL)
            .collect();
        loop {
            // Find the lowest-rank (earliest-trained) applicable merge.
            let mut best: Option<(u32, usize)> = None; // (merged id, position)
            for i in 0..seq.len().saturating_sub(1) {
                if let Some(&id) = self.merge_map.get(&(seq[i], seq[i + 1])) {
                    if best.map_or(true, |(bid, _)| id < bid) {
                        best = Some((id, i));
                    }
                }
            }
            let Some((id, _)) = best else { break };
            let pair = self.merges[(id - N_SPECIAL - 256) as usize];
            let mut out = Vec::with_capacity(seq.len());
            let mut i = 0;
            while i < seq.len() {
                if i + 1 < seq.len() && (seq[i], seq[i + 1]) == pair {
                    out.push(id);
                    i += 2;
                } else {
                    out.push(seq[i]);
                    i += 1;
                }
            }
            seq = out;
        }
        seq
    }

    /// Decode token ids back to text (lossy UTF-8 for byte fragments).
    pub fn decode(&self, tokens: &[u32]) -> String {
        let mut bytes = Vec::new();
        for &t in tokens {
            if t == BOS || t == EOS {
                continue;
            }
            if let Some(p) = self.pieces.get(t as usize) {
                bytes.extend_from_slice(p);
            }
        }
        String::from_utf8_lossy(&bytes).into_owned()
    }

    pub fn piece(&self, token: u32) -> Option<&[u8]> {
        self.pieces.get(token as usize).map(|v| v.as_slice())
    }
}

/// The tiny corpus bundled for examples/tests (examples/data/corpus.txt).
pub fn bundled_corpus() -> &'static str {
    include_str!("../../../examples/data/corpus.txt")
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tok() -> Tokenizer {
        Tokenizer::train(bundled_corpus(), 512).unwrap()
    }

    #[test]
    fn roundtrip_corpus_lines() {
        let t = tok();
        for line in bundled_corpus().lines().take(50) {
            assert_eq!(t.decode(&t.encode(line)), line);
        }
    }

    #[test]
    fn roundtrip_unseen_text() {
        let t = tok();
        let s = "zzz completely unseen!! 12345 \u{1F600}";
        assert_eq!(t.decode(&t.encode(s)), s);
    }

    #[test]
    fn merges_actually_compress() {
        let t = tok();
        assert!(t.n_merges() > 50, "corpus should yield many merges");
        let line = "the precompute table stores the first layer";
        let ids = t.encode(line);
        assert!(
            ids.len() < line.len(),
            "encoding should be shorter than bytes ({} vs {})",
            ids.len(),
            line.len()
        );
    }

    #[test]
    fn ids_in_range() {
        let t = tok();
        for line in bundled_corpus().lines().take(20) {
            for id in t.encode(line) {
                assert!((id as usize) < t.vocab_size());
            }
        }
    }

    #[test]
    fn vocab_too_small_rejected() {
        assert!(Tokenizer::train("abc", 10).is_err());
    }

    #[test]
    fn empty_text() {
        let t = tok();
        assert!(t.encode("").is_empty());
        assert_eq!(t.decode(&[]), "");
    }

    #[test]
    fn specials_skipped_in_decode() {
        let t = tok();
        let mut ids = vec![BOS];
        ids.extend(t.encode("hi"));
        ids.push(EOS);
        assert_eq!(t.decode(&ids), "hi");
    }
}
